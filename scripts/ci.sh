#!/usr/bin/env bash
# Tier-1 verify plus formatting hygiene — the single entry point CI runs
# and the one command to run locally before pushing.
#
# The Cargo workspace manifest is materialized by the build harness, not
# tracked in this tree (the `xla` PJRT dependency needs a vendored toolchain
# that cannot be expressed as a plain crates.io dependency). When no
# manifest is present this script says so and exits cleanly instead of
# failing every run with a misleading cargo error.
set -euo pipefail

cd "$(dirname "$0")/.."
# Prefer the rust/ subtree when it carries its own manifest.
if [ -f rust/Cargo.toml ] && [ ! -f Cargo.toml ]; then
  cd rust
fi

if [ ! -f Cargo.toml ]; then
  echo "ci.sh: no Cargo.toml in $(pwd) — workspace not materialized; skipping tier-1 verify." >&2
  exit 0
fi

cargo fmt --check

# Lint leg: clippy across every target (lib, tests, benches, examples)
# with warnings promoted to errors, so lint rot fails fast. The probe
# separates "clippy component not installed in the materialized toolchain"
# (legitimate skip, mirrors the missing-manifest skip above) from real
# lint failures.
if cargo clippy --version >/dev/null 2>&1; then
  cargo clippy --all-targets -- -D warnings
  echo "ci.sh: clippy leg OK (no warnings)"
else
  echo "ci.sh: cargo clippy unavailable in this toolchain; skipping lint leg" >&2
fi

cargo build --release
cargo test -q

# API-docs leg: the request/session surface is documented; drift (broken
# intra-doc links, bad code fences) fails fast instead of rotting.
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet
echo "ci.sh: cargo doc leg OK (no rustdoc warnings)"

# Example-compile smoke leg: examples/ are the public-API contract surface;
# API drift that breaks them must fail CI. A manifest without example
# targets makes this a no-op, which is the correct skip.
cargo build --release --examples
echo "ci.sh: examples compile leg OK"

# Quick-mode perf smoke: run the three kernel variants (scalar-f64,
# simd-f64, simd-f32) on one small shape and fail if the machine-readable
# trail is missing any variant's entries. The --no-run probe separates
# "bench target not declared in the materialized manifest" (legitimate
# skip) from a broken bench build (hard failure — `cargo test` above does
# not compile bench targets).
probe_log=$(mktemp)
if PERF_HOTPATH_QUICK=1 cargo bench --bench perf_hotpath --no-run >"$probe_log" 2>&1; then
  PERF_HOTPATH_QUICK=1 cargo bench --bench perf_hotpath
  for key in seed_scalar_ms scalar_f64_ms simd_f64_ms simd_f32_ms simd_level \
             cold_session_ms warm_session_ms; do
    if ! grep -q "\"$key\"" BENCH_hotpath.json; then
      echo "ci.sh: BENCH_hotpath.json is missing '$key' entries" >&2
      exit 1
    fi
  done
  echo "ci.sh: perf_hotpath smoke leg OK (BENCH_hotpath.json has all kernel variants)"
elif grep -qi "no bench target named" "$probe_log"; then
  echo "ci.sh: perf_hotpath bench target not declared in this manifest; skipping smoke leg" >&2
else
  echo "ci.sh: perf_hotpath bench failed to build:" >&2
  cat "$probe_log" >&2
  exit 1
fi
rm -f "$probe_log"

# Quick-mode mini-batch smoke: run the streaming sweep on one small shape
# and fail if the machine-readable trail is missing any engine variant
# (Lloyd target, minibatch+AA, minibatch plain), the epochs-to-target
# columns, or the stream-saturation sweep's throughput/guard columns
# (prefetch off/on rows-per-sec and the sampled-vs-exact epoch delta).
# Same probe pattern as perf_hotpath above.
mb_probe_log=$(mktemp)
if PERF_MINIBATCH_QUICK=1 cargo bench --bench perf_minibatch --no-run >"$mb_probe_log" 2>&1; then
  PERF_MINIBATCH_QUICK=1 cargo bench --bench perf_minibatch
  for key in lloyd_energy minibatch_aa minibatch_plain epochs_to_target \
             aa_beats_plain stream_sweep rows_per_sec prefetch_speedup \
             guard_epoch_delta; do
    if ! grep -q "\"$key\"" BENCH_minibatch.json; then
      echo "ci.sh: BENCH_minibatch.json is missing '$key' entries" >&2
      exit 1
    fi
  done
  echo "ci.sh: perf_minibatch smoke leg OK (BENCH_minibatch.json has all engine variants)"
elif grep -qi "no bench target named" "$mb_probe_log"; then
  echo "ci.sh: perf_minibatch bench target not declared in this manifest; skipping smoke leg" >&2
else
  echo "ci.sh: perf_minibatch bench failed to build:" >&2
  cat "$mb_probe_log" >&2
  exit 1
fi
rm -f "$mb_probe_log"

# Quick-mode model-lifecycle perf smoke: cold fit vs warm-start refresh
# plus predict throughput on two small shapes; fail if the trail is
# missing the cold/warm iteration counts, the predict throughput column
# or the warm-no-slower verdict. Same probe pattern as above.
rg_probe_log=$(mktemp)
if PERF_REGISTRY_QUICK=1 cargo bench --bench perf_registry --no-run >"$rg_probe_log" 2>&1; then
  PERF_REGISTRY_QUICK=1 cargo bench --bench perf_registry
  for key in cold warm predict_rows_per_sec warm_no_slower \
             warm_no_slower_everywhere; do
    if ! grep -q "\"$key\"" BENCH_registry.json; then
      echo "ci.sh: BENCH_registry.json is missing '$key' entries" >&2
      exit 1
    fi
  done
  if [ "$(grep -c '"shape"' BENCH_registry.json)" -lt 2 ]; then
    echo "ci.sh: BENCH_registry.json must cover at least two shapes" >&2
    exit 1
  fi
  echo "ci.sh: perf_registry smoke leg OK (BENCH_registry.json has cold/warm/predict columns)"
elif grep -qi "no bench target named" "$rg_probe_log"; then
  echo "ci.sh: perf_registry bench target not declared in this manifest; skipping smoke leg" >&2
else
  echo "ci.sh: perf_registry bench failed to build:" >&2
  cat "$rg_probe_log" >&2
  exit 1
fi
rm -f "$rg_probe_log"

# Quick-mode observability-overhead smoke: serve the same jobs with
# telemetry off, metrics on, and metrics+events, and fail if the
# machine-readable trail is missing the per-mode iteration costs or the
# overhead columns. Same probe pattern as above.
ob_probe_log=$(mktemp)
if PERF_OBSERVE_QUICK=1 cargo bench --bench perf_observe --no-run >"$ob_probe_log" 2>&1; then
  PERF_OBSERVE_QUICK=1 cargo bench --bench perf_observe
  for key in off_iter_us metrics_iter_us metrics_events_iter_us \
             metrics_overhead_pct events_overhead_pct; do
    if ! grep -q "\"$key\"" BENCH_observe.json; then
      echo "ci.sh: BENCH_observe.json is missing '$key' entries" >&2
      exit 1
    fi
  done
  if [ "$(grep -c '"engine"' BENCH_observe.json)" -lt 2 ]; then
    echo "ci.sh: BENCH_observe.json must cover at least two engines" >&2
    exit 1
  fi
  echo "ci.sh: perf_observe smoke leg OK (BENCH_observe.json has all telemetry modes)"
elif grep -qi "no bench target named" "$ob_probe_log"; then
  echo "ci.sh: perf_observe bench target not declared in this manifest; skipping smoke leg" >&2
else
  echo "ci.sh: perf_observe bench failed to build:" >&2
  cat "$ob_probe_log" >&2
  exit 1
fi
rm -f "$ob_probe_log"

# Fault-injection smoke: replay the coordinator robustness sweep
# (tests/fault_injection.rs) on a wider fixed seed set than the 0..8
# default `cargo test` already ran — injected chunk-read faults, PJRT
# load failures, worker panics/kills, shed admission — proving every
# job handle resolves typed and shutdown completes under each schedule.
# Deterministic by construction (seeded schedules), so failures replay.
# Same probe pattern as the bench legs: a manifest without the test
# target is a legitimate skip, a broken build is a hard failure.
fi_probe_log=$(mktemp)
if cargo test --test fault_injection --no-run >"$fi_probe_log" 2>&1; then
  AAKM_FAULT_SEEDS=0,1,2,3,4,5,6,7,11,29 cargo test -q --test fault_injection
  echo "ci.sh: fault-injection smoke leg OK (fixed 10-seed sweep)"
elif grep -qi "no test target named" "$fi_probe_log"; then
  echo "ci.sh: fault_injection test target not declared in this manifest; skipping smoke leg" >&2
else
  echo "ci.sh: fault_injection tests failed to build:" >&2
  cat "$fi_probe_log" >&2
  exit 1
fi
rm -f "$fi_probe_log"

# Durability sweep: replay tests/recovery.rs — per-engine bit-identical
# resume, injected checkpoint-write faults in both write windows,
# snapshot/shard corruption fuzz, journal recovery — on a wider fixed
# seed set than the in-crate default. Same probe pattern as above.
rc_probe_log=$(mktemp)
if cargo test --test recovery --no-run >"$rc_probe_log" 2>&1; then
  AAKM_FAULT_SEEDS=0,1,2,3,4,5,6,7,13,23 cargo test -q --test recovery
  echo "ci.sh: recovery smoke leg OK (fixed 10-seed sweep)"
elif grep -qi "no test target named" "$rc_probe_log"; then
  echo "ci.sh: recovery test target not declared in this manifest; skipping smoke leg" >&2
else
  echo "ci.sh: recovery tests failed to build:" >&2
  cat "$rc_probe_log" >&2
  exit 1
fi
rm -f "$rc_probe_log"

# Prefetch-parity smoke: replay the saturated-streaming contract tests —
# prefetch on/off bit-identical per sampling mode, the sampled energy
# guard tracking the exact one, and resume-across-prefetch parity — as a
# named leg so a pipeline ordering regression is called out by name even
# though the default `cargo test` above also runs these. Same probe
# pattern as above.
pp_probe_log=$(mktemp)
if cargo test --test integration_stream --no-run >"$pp_probe_log" 2>&1; then
  cargo test -q --test integration_stream -- \
    prefetch_runs_are_bit_identical_per_sampling_mode \
    sampled_guard_tracks_the_exact_guard
  cargo test -q --test recovery -- minibatch_resume_with_prefetch_is_bit_identical
  echo "ci.sh: prefetch-parity smoke leg OK (bit-identical on/off + sampled-guard envelope + resume)"
elif grep -qi "no test target named" "$pp_probe_log"; then
  echo "ci.sh: integration_stream test target not declared in this manifest; skipping smoke leg" >&2
else
  echo "ci.sh: integration_stream tests failed to build:" >&2
  cat "$pp_probe_log" >&2
  exit 1
fi
rm -f "$pp_probe_log"

# Crash-recovery smoke: a checkpointed CLI solve interrupted mid-run —
# first gracefully (SIGINT flushes a final snapshot and reports the run
# as resumable), then hard (kill -9, the crash the atomic temp-file-
# then-rename snapshot write exists for) — must resume onto the
# uninterrupted reference trajectory: identical total iteration count
# and final energy in the summary line.
crash_bin=""
for cand in target/release/repro target/release/aakm; do
  if [ -x "$cand" ]; then crash_bin="$cand"; break; fi
done
if [ -z "$crash_bin" ]; then
  crash_bin=$(find target/release -maxdepth 1 -type f -perm -111 ! -name '*.*' 2>/dev/null | head -1 || true)
fi
if [ -z "$crash_bin" ]; then
  echo "ci.sh: no release binary found under target/release; skipping crash-recovery smoke leg" >&2
else
  crash_flags="run --dataset Birch --scale 0.5 --k 40 --engine naive --accel none --seed 7 --threads 1"
  ck_dir=$(mktemp -d)
  ref_log=$(mktemp); int_log=$(mktemp); rec_log=$(mktemp)
  # Trajectory signature: iteration count + final energy from the
  # summary line (timing and resume-local dist-eval counters excluded).
  sig() { sed -n 's/^ours[^:]*: \([0-9]*\) iters.*\(energy [^,]*\),.*/\1 iters \2/p' "$1"; }
  "$crash_bin" $crash_flags > "$ref_log"
  [ -n "$(sig "$ref_log")" ] || { echo "ci.sh: reference solve produced no summary" >&2; exit 1; }

  for sig_kind in INT KILL; do
    rm -rf "$ck_dir"; mkdir -p "$ck_dir"
    "$crash_bin" $crash_flags --checkpoint-dir "$ck_dir" --checkpoint-every 1 > "$int_log" 2>&1 &
    crash_pid=$!
    for _ in $(seq 1 100); do
      [ -f "$ck_dir/snapshot.ck" ] && break
      sleep 0.1
    done
    if kill "-$sig_kind" "$crash_pid" 2>/dev/null; then
      if [ "$sig_kind" = INT ]; then
        # Graceful: first signal cancels at an iteration boundary,
        # flushes a final snapshot, exits cleanly with a resume hint.
        if ! wait "$crash_pid"; then
          echo "ci.sh: SIGINT shutdown exited nonzero:" >&2; cat "$int_log" >&2; exit 1
        fi
        grep -q "interrupted" "$int_log" || {
          echo "ci.sh: SIGINT run printed no resumable-interrupt message:" >&2
          cat "$int_log" >&2; exit 1
        }
      else
        wait "$crash_pid" 2>/dev/null || true
      fi
    else
      # The solve outran the signal on this machine; the resume below
      # still verifies the trajectory (from scratch, snapshot consumed).
      wait "$crash_pid" 2>/dev/null || true
      echo "ci.sh: solve finished before SIG$sig_kind could land; resume check still runs" >&2
    fi
    "$crash_bin" $crash_flags --checkpoint-dir "$ck_dir" > "$rec_log"
    if [ "$(sig "$rec_log")" != "$(sig "$ref_log")" ]; then
      echo "ci.sh: SIG$sig_kind recovery diverged from the reference trajectory:" >&2
      echo "  reference: $(sig "$ref_log")" >&2
      echo "  recovered: $(sig "$rec_log")" >&2
      exit 1
    fi
  done
  echo "ci.sh: crash-recovery smoke leg OK (SIGINT + kill -9 both resume onto the reference trajectory)"
  rm -rf "$ck_dir"; rm -f "$ref_log" "$int_log" "$rec_log"
fi

# Model-lifecycle smoke: fit -> predict -> refresh through the CLI, then
# the durability cross-check — a refresh killed hard mid-run (kill -9
# between checkpoint snapshots) must, on re-run, resume onto the exact
# trajectory of an uninterrupted reference refresh: the two served models
# produce byte-identical predict output. Two identical fits (same flags,
# same seed -> deterministic identical models) give the reference and the
# interrupted lifecycle each their own model id.
if [ -z "${crash_bin:-}" ]; then
  echo "ci.sh: no release binary found under target/release; skipping model-lifecycle smoke leg" >&2
else
  reg_dir=$(mktemp -d); rck_dir=$(mktemp -d)
  ref_pred=$(mktemp); int_pred=$(mktemp); rfl_log=$(mktemp)
  fit_flags="--dataset Birch --scale 0.4 --k 40 --engine naive --accel none --seed 7 --threads 1"
  # The refresh re-clusters *drifted* data (a larger cut of the same
  # generator), so it does real solver work — enough iterations for the
  # kill to land between snapshots.
  refresh_flags="--dataset Birch --scale 0.5 --k 40 --engine naive --accel none --seed 7 --threads 1"
  predict_flags="--dataset Birch --scale 0.5 --threads 1"
  "$crash_bin" fit $fit_flags --registry "$reg_dir" --model ref > "$rfl_log"
  grep -q "registered 'ref'" "$rfl_log" || {
    echo "ci.sh: fit did not register its model:" >&2; cat "$rfl_log" >&2; exit 1
  }
  "$crash_bin" fit $fit_flags --registry "$reg_dir" --model int > /dev/null
  # Reference lifecycle: uninterrupted refresh, then serve.
  "$crash_bin" refresh $refresh_flags --registry "$reg_dir" --model ref > /dev/null
  "$crash_bin" predict $predict_flags --registry "$reg_dir" --model ref --out "$ref_pred" > /dev/null
  [ -s "$ref_pred" ] || { echo "ci.sh: reference predict wrote no output" >&2; exit 1; }
  # Interrupted lifecycle: kill -9 once the first snapshot exists, then
  # re-run the same refresh (it resumes from the snapshot; the model
  # fingerprint excludes init, so the warm-started run matches).
  "$crash_bin" refresh $refresh_flags --registry "$reg_dir" --model int \
    --checkpoint-dir "$rck_dir" --checkpoint-every 1 > /dev/null 2>&1 &
  refresh_pid=$!
  for _ in $(seq 1 100); do
    [ -f "$rck_dir/snapshot.ck" ] && break
    sleep 0.1
  done
  if ! kill -KILL "$refresh_pid" 2>/dev/null; then
    # The refresh outran the kill on this machine; the re-run below still
    # verifies idempotence (a second refresh re-converges to the same
    # fixed point, so the predict parity check stays meaningful).
    echo "ci.sh: refresh finished before kill -9 could land; parity check still runs" >&2
  fi
  wait "$refresh_pid" 2>/dev/null || true
  "$crash_bin" refresh $refresh_flags --registry "$reg_dir" --model int \
    --checkpoint-dir "$rck_dir" > /dev/null
  "$crash_bin" predict $predict_flags --registry "$reg_dir" --model int --out "$int_pred" > /dev/null
  if ! cmp -s "$ref_pred" "$int_pred"; then
    echo "ci.sh: recovered refresh serves different predictions than the reference:" >&2
    diff "$ref_pred" "$int_pred" | head -5 >&2
    exit 1
  fi
  echo "ci.sh: model-lifecycle smoke leg OK (fit -> predict -> kill -9 mid-refresh -> recover -> predict parity)"
  rm -rf "$reg_dir" "$rck_dir"; rm -f "$ref_pred" "$int_pred" "$rfl_log"
fi

# Observability smoke: a telemetry-instrumented serve must leave behind a
# scrapeable Prometheus exposition (with the solver and queue families
# populated) and a schema-valid JSONL event log, and the `telemetry check`
# subcommand must accept that log end-to-end.
if [ -z "${crash_bin:-}" ]; then
  echo "ci.sh: no release binary found under target/release; skipping observability smoke leg" >&2
else
  tel_dir=$(mktemp -d); tel_log=$(mktemp)
  "$crash_bin" serve --workers 2 --jobs 4 --k 5 --scale 0.005 --engine hamerly \
    --metrics-out "$tel_dir/metrics.prom" --events-out "$tel_dir/events.jsonl" > "$tel_log"
  for fam in aakm_jobs_submitted_total aakm_solver_iterations_total \
             aakm_job_queue_wait_seconds_bucket aakm_queue_depth; do
    grep -q "^$fam" "$tel_dir/metrics.prom" || {
      echo "ci.sh: serve exposition is missing the '$fam' family:" >&2
      cat "$tel_dir/metrics.prom" >&2; exit 1
    }
  done
  grep -q "queue wait: p50" "$tel_log" || {
    echo "ci.sh: serve printed no queue-wait quantile line:" >&2
    cat "$tel_log" >&2; exit 1
  }
  check_out=$("$crash_bin" telemetry check --events "$tel_dir/events.jsonl") || {
    echo "ci.sh: telemetry check rejected the serve event log" >&2; exit 1
  }
  echo "$check_out" | grep -q "valid event(s)" || {
    echo "ci.sh: telemetry check produced no summary: $check_out" >&2; exit 1
  }
  for kind in submit pickup outcome iter; do
    echo "$check_out" | grep -q "$kind" || {
      echo "ci.sh: serve event log has no '$kind' events: $check_out" >&2; exit 1
    }
  done
  echo "ci.sh: observability smoke leg OK (metrics exposition + schema-valid event log)"
  rm -rf "$tel_dir"; rm -f "$tel_log"
fi
