#!/usr/bin/env bash
# Tier-1 verify plus formatting hygiene — the single entry point CI runs
# and the one command to run locally before pushing.
#
# The Cargo workspace manifest is materialized by the build harness, not
# tracked in this tree (the `xla` PJRT dependency needs a vendored toolchain
# that cannot be expressed as a plain crates.io dependency). When no
# manifest is present this script says so and exits cleanly instead of
# failing every run with a misleading cargo error.
set -euo pipefail

cd "$(dirname "$0")/.."
# Prefer the rust/ subtree when it carries its own manifest.
if [ -f rust/Cargo.toml ] && [ ! -f Cargo.toml ]; then
  cd rust
fi

if [ ! -f Cargo.toml ]; then
  echo "ci.sh: no Cargo.toml in $(pwd) — workspace not materialized; skipping tier-1 verify." >&2
  exit 0
fi

cargo fmt --check
cargo build --release
cargo test -q
