#!/usr/bin/env bash
# Tier-1 verify plus formatting hygiene — the single entry point CI runs
# and the one command to run locally before pushing.
#
# The Cargo workspace manifest is materialized by the build harness, not
# tracked in this tree (the `xla` PJRT dependency needs a vendored toolchain
# that cannot be expressed as a plain crates.io dependency). When no
# manifest is present this script says so and exits cleanly instead of
# failing every run with a misleading cargo error.
set -euo pipefail

cd "$(dirname "$0")/.."
# Prefer the rust/ subtree when it carries its own manifest.
if [ -f rust/Cargo.toml ] && [ ! -f Cargo.toml ]; then
  cd rust
fi

if [ ! -f Cargo.toml ]; then
  echo "ci.sh: no Cargo.toml in $(pwd) — workspace not materialized; skipping tier-1 verify." >&2
  exit 0
fi

cargo fmt --check

# Lint leg: clippy across every target (lib, tests, benches, examples)
# with warnings promoted to errors, so lint rot fails fast. The probe
# separates "clippy component not installed in the materialized toolchain"
# (legitimate skip, mirrors the missing-manifest skip above) from real
# lint failures.
if cargo clippy --version >/dev/null 2>&1; then
  cargo clippy --all-targets -- -D warnings
  echo "ci.sh: clippy leg OK (no warnings)"
else
  echo "ci.sh: cargo clippy unavailable in this toolchain; skipping lint leg" >&2
fi

cargo build --release
cargo test -q

# API-docs leg: the request/session surface is documented; drift (broken
# intra-doc links, bad code fences) fails fast instead of rotting.
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet
echo "ci.sh: cargo doc leg OK (no rustdoc warnings)"

# Example-compile smoke leg: examples/ are the public-API contract surface;
# API drift that breaks them must fail CI. A manifest without example
# targets makes this a no-op, which is the correct skip.
cargo build --release --examples
echo "ci.sh: examples compile leg OK"

# Quick-mode perf smoke: run the three kernel variants (scalar-f64,
# simd-f64, simd-f32) on one small shape and fail if the machine-readable
# trail is missing any variant's entries. The --no-run probe separates
# "bench target not declared in the materialized manifest" (legitimate
# skip) from a broken bench build (hard failure — `cargo test` above does
# not compile bench targets).
probe_log=$(mktemp)
if PERF_HOTPATH_QUICK=1 cargo bench --bench perf_hotpath --no-run >"$probe_log" 2>&1; then
  PERF_HOTPATH_QUICK=1 cargo bench --bench perf_hotpath
  for key in seed_scalar_ms scalar_f64_ms simd_f64_ms simd_f32_ms simd_level \
             cold_session_ms warm_session_ms; do
    if ! grep -q "\"$key\"" BENCH_hotpath.json; then
      echo "ci.sh: BENCH_hotpath.json is missing '$key' entries" >&2
      exit 1
    fi
  done
  echo "ci.sh: perf_hotpath smoke leg OK (BENCH_hotpath.json has all kernel variants)"
elif grep -qi "no bench target named" "$probe_log"; then
  echo "ci.sh: perf_hotpath bench target not declared in this manifest; skipping smoke leg" >&2
else
  echo "ci.sh: perf_hotpath bench failed to build:" >&2
  cat "$probe_log" >&2
  exit 1
fi
rm -f "$probe_log"

# Quick-mode mini-batch smoke: run the streaming sweep on one small shape
# and fail if the machine-readable trail is missing any engine variant
# (Lloyd target, minibatch+AA, minibatch plain) or the epochs-to-target
# columns. Same probe pattern as perf_hotpath above.
mb_probe_log=$(mktemp)
if PERF_MINIBATCH_QUICK=1 cargo bench --bench perf_minibatch --no-run >"$mb_probe_log" 2>&1; then
  PERF_MINIBATCH_QUICK=1 cargo bench --bench perf_minibatch
  for key in lloyd_energy minibatch_aa minibatch_plain epochs_to_target \
             aa_beats_plain; do
    if ! grep -q "\"$key\"" BENCH_minibatch.json; then
      echo "ci.sh: BENCH_minibatch.json is missing '$key' entries" >&2
      exit 1
    fi
  done
  echo "ci.sh: perf_minibatch smoke leg OK (BENCH_minibatch.json has all engine variants)"
elif grep -qi "no bench target named" "$mb_probe_log"; then
  echo "ci.sh: perf_minibatch bench target not declared in this manifest; skipping smoke leg" >&2
else
  echo "ci.sh: perf_minibatch bench failed to build:" >&2
  cat "$mb_probe_log" >&2
  exit 1
fi
rm -f "$mb_probe_log"

# Fault-injection smoke: replay the coordinator robustness sweep
# (tests/fault_injection.rs) on a wider fixed seed set than the 0..8
# default `cargo test` already ran — injected chunk-read faults, PJRT
# load failures, worker panics/kills, shed admission — proving every
# job handle resolves typed and shutdown completes under each schedule.
# Deterministic by construction (seeded schedules), so failures replay.
# Same probe pattern as the bench legs: a manifest without the test
# target is a legitimate skip, a broken build is a hard failure.
fi_probe_log=$(mktemp)
if cargo test --test fault_injection --no-run >"$fi_probe_log" 2>&1; then
  AAKM_FAULT_SEEDS=0,1,2,3,4,5,6,7,11,29 cargo test -q --test fault_injection
  echo "ci.sh: fault-injection smoke leg OK (fixed 10-seed sweep)"
elif grep -qi "no test target named" "$fi_probe_log"; then
  echo "ci.sh: fault_injection test target not declared in this manifest; skipping smoke leg" >&2
else
  echo "ci.sh: fault_injection tests failed to build:" >&2
  cat "$fi_probe_log" >&2
  exit 1
fi
rm -f "$fi_probe_log"
