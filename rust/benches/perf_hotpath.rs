//! §Perf harness — the per-layer profiling the optimization pass records in
//! EXPERIMENTS.md:
//!
//! * Kernel sweep: the seed's scalar per-pair assign loop vs the blocked
//!   norm-decomposed `DistanceKernel` in its three variants — forced-scalar
//!   f64, runtime-dispatched SIMD f64, and SIMD f32 sample storage — across
//!   a d×K grid (machine-readable results land in `BENCH_hotpath.json` so
//!   the perf trajectory is tracked PR over PR).
//! * L3 micro: assignment-engine cost per call (cold vs warm vs post-jump),
//!   the fused update+energy pass vs separate passes, AA solve cost vs m.
//! * L3 macro: per-iteration overhead of Algorithm 1 vs plain Lloyd.
//! * PJRT: G-step execution cost per bucket (when artifacts exist).
//!
//! Set `PERF_HOTPATH_QUICK=1` for the CI smoke leg: a single small shape
//! through the three kernel variants, micro/macro/PJRT sections skipped,
//! `BENCH_hotpath.json` still written (that is what CI asserts on).

mod common;

use aakm::anderson::AndersonAccelerator;
use aakm::config::{Acceleration, SolverConfig};
use aakm::data::{synth, DataMatrix};
use aakm::init::{seed_centroids, InitMethod};
use aakm::kmeans::Solver;
use aakm::linalg::kernel::simd::detect;
use aakm::linalg::{dist_sq, DistanceKernel, Precision, SimdLevel};
use aakm::lloyd::{self, AssignmentEngine, HamerlyEngine, NaiveEngine};
use aakm::metrics::Stopwatch;
use aakm::par::ThreadPool;
use aakm::rng::{Pcg32, Rng};

fn time_ms(iters: usize, mut f: impl FnMut()) -> f64 {
    let sw = Stopwatch::start();
    for _ in 0..iters {
        f();
    }
    sw.seconds() * 1000.0 / iters as f64
}

/// Steady-state cost of one full assign sweep on a configured kernel:
/// per-iteration `prepare` (centroid norms + f32 centroid mirror) plus the
/// fused argmin over every sample — exactly what an engine pays per Lloyd
/// iteration once the sample-side caches are warm.
fn time_kernel_ms(
    x: &DataMatrix,
    c: &DataMatrix,
    precision: Precision,
    simd: SimdLevel,
    pool: &ThreadPool,
    iters: usize,
) -> f64 {
    let mut kern = DistanceKernel::with_options(precision, simd);
    kern.prepare(x, c, pool); // warm the sample norms / f32 mirror
    let mut sink = 0u32;
    let t = time_ms(iters, || {
        kern.prepare(x, c, pool);
        kern.argmin2_range(x, c, 0..x.n(), |_, b| sink = sink.wrapping_add(b.best));
    });
    std::hint::black_box(sink);
    t
}

/// Machine-readable trail for the perf trajectory (CI smoke-checks the
/// per-variant keys are present).
fn write_json(
    n: usize,
    simd: SimdLevel,
    quick: bool,
    sweep_rows: &[String],
    macro_rows: &[String],
    session_row: &str,
) {
    let json = format!(
        "{{\n  \"bench\": \"perf_hotpath\",\n  \"n\": {n},\n  \"simd_level\": \"{}\",\n  \
         \"quick\": {quick},\n  \"kernel_sweep\": [\n{}\n  ],\n  \"session\": [\n{}\n  ],\n  \
         \"macro\": [\n{}\n  ]\n}}\n",
        simd.name(),
        sweep_rows.join(",\n"),
        session_row,
        macro_rows.join(",\n"),
    );
    match std::fs::write("BENCH_hotpath.json", &json) {
        Ok(()) => println!("\nwrote BENCH_hotpath.json"),
        Err(e) => println!("\ncould not write BENCH_hotpath.json: {e}"),
    }
}

/// Warm-vs-cold session comparison: a fresh `ClusterSession` per run
/// (engine, pool and scratch rebuilt, data re-seeded — the old
/// fresh-solver-per-call pattern) vs one warm session with report
/// recycling (the workspace-reuse contract of the request/session API).
fn session_leg(quick: bool) -> String {
    use aakm::{ClusterRequest, ClusterSession};
    use std::sync::Arc;
    let n = if quick { 5_000 } else { 50_000 };
    let mut rng = Pcg32::seed_from_u64(0x5E55);
    let x = Arc::new(synth::gaussian_blobs_ex(&mut rng, n, 8, 10, 2.0, 0.4, 0.05, 2.0));
    let build = || {
        ClusterRequest::builder()
            .inline(Arc::clone(&x))
            .k(10)
            .threads(1)
            .seed(3)
            .build()
            .expect("valid request")
    };
    let reps = if quick { 2 } else { 5 };
    let t_cold = time_ms(reps, || {
        let mut s = ClusterSession::open(build()).expect("open");
        let r = s.run().expect("run");
        std::hint::black_box(r.iterations);
    });
    let mut warm = ClusterSession::open(build()).expect("open");
    let r0 = warm.run().expect("warm-up");
    warm.recycle(r0);
    let t_warm = time_ms(reps, || {
        let r = warm.run().expect("run");
        std::hint::black_box(r.iterations);
        warm.recycle(r);
    });
    println!("\n## Session reuse — cold open-per-run vs warm session (n={n}, 1 thread)\n");
    println!("cold (open per run):  {t_cold:8.2} ms/run");
    println!(
        "warm (session reuse): {t_warm:8.2} ms/run  ({:.2}x)",
        t_cold / t_warm.max(1e-12)
    );
    format!(
        "    {{\"n\": {n}, \"cold_session_ms\": {t_cold:.4}, \"warm_session_ms\": {t_warm:.4}, \
         \"warm_speedup\": {:.3}}}",
        t_cold / t_warm.max(1e-12)
    )
}

/// The seed's naive assignment path, kept verbatim as the scalar baseline
/// the kernel sweep measures against: per-pair subtract-square `dist_sq`,
/// no norm caching, no blocking.
fn assign_scalar(x: &DataMatrix, c: &DataMatrix, out: &mut Vec<u32>) {
    out.resize(x.n(), 0);
    for i in 0..x.n() {
        let row = x.row(i);
        let (mut best, mut best_d) = (0u32, f64::INFINITY);
        for j in 0..c.n() {
            let dsq = dist_sq(row, c.row(j));
            if dsq < best_d {
                best_d = dsq;
                best = j as u32;
            }
        }
        out[i] = best;
    }
}

fn main() {
    let quick = std::env::var("PERF_HOTPATH_QUICK").is_ok();
    let mut rng = Pcg32::seed_from_u64(0x9E8F);
    let n = if quick { 20_000 } else { 100_000 };
    let (d, k) = (8usize, 10usize);
    let x = synth::gaussian_blobs_ex(&mut rng, n, d, k, 2.0, 0.4, 0.05, 2.0);
    let c = seed_centroids(&x, k, InitMethod::KMeansPlusPlus, &mut rng);
    let pool = ThreadPool::new(1);
    let simd = detect();

    // ---- Kernel sweep: seed scalar loop vs the three kernel variants.
    println!(
        "## Kernel sweep — seed scalar vs scalar-f64 / simd-f64 / simd-f32 kernels \
         (n={n}, 1 thread, dispatch={})\n",
        simd.name()
    );
    let shapes: &[(usize, usize)] = if quick {
        &[(8usize, 64usize)]
    } else {
        &[(2usize, 10usize), (8, 10), (8, 64), (16, 10), (32, 64), (100, 10)]
    };
    let mut sweep_rows: Vec<String> = Vec::new();
    for &(sd, sk) in shapes {
        let mut srng = Pcg32::seed_from_u64(0xBEEF ^ ((sd * 131 + sk) as u64));
        let sx = synth::gaussian_blobs(&mut srng, n, sd, sk.min(16), 2.0, 0.4);
        let sc = seed_centroids(&sx, sk, InitMethod::Random, &mut srng);
        // Budget ~2e8 pair-flops per timing arm, at least 2 reps.
        let iters = (200_000_000 / (n * sk * sd)).clamp(2, 10);
        let mut out = Vec::new();
        let t_seed = time_ms(iters, || assign_scalar(&sx, &sc, &mut out));
        let t_scalar =
            time_kernel_ms(&sx, &sc, Precision::F64, SimdLevel::Scalar, &pool, iters);
        let t_simd64 = time_kernel_ms(&sx, &sc, Precision::F64, simd, &pool, iters);
        let t_simd32 = time_kernel_ms(&sx, &sc, Precision::F32, simd, &pool, iters);
        let su64 = t_scalar / t_simd64.max(1e-12);
        let su32 = t_simd64 / t_simd32.max(1e-12);
        println!(
            "d={sd:<4} K={sk:<4} seed {t_seed:8.2} ms | scalar-f64 {t_scalar:8.2} ms | \
             simd-f64 {t_simd64:8.2} ms ({su64:4.2}x) | simd-f32 {t_simd32:8.2} ms ({su32:4.2}x)"
        );
        sweep_rows.push(format!(
            "    {{\"d\": {sd}, \"k\": {sk}, \"seed_scalar_ms\": {t_seed:.4}, \
             \"scalar_f64_ms\": {t_scalar:.4}, \"simd_f64_ms\": {t_simd64:.4}, \
             \"simd_f32_ms\": {t_simd32:.4}, \"simd_f64_speedup\": {su64:.3}, \
             \"simd_f32_speedup\": {su32:.3}}}"
        ));
    }

    let session_row = session_leg(quick);

    let mut macro_rows: Vec<String> = Vec::new();
    if quick {
        write_json(n, simd, quick, &sweep_rows, &macro_rows, &session_row);
        println!("\nquick mode: micro/macro/PJRT sections skipped");
        return;
    }

    println!("\n## L3 micro (n={n}, d={d}, K={k}, 1 thread)\n");

    // Assignment engines: cold, warm (small Lloyd motion), post-jump.
    let mut out = Vec::new();
    let mut naive = NaiveEngine::new();
    let t_naive = time_ms(3, || naive.assign(&x, &c, &pool, &mut out));
    println!("naive assign:            {t_naive:8.2} ms/call");
    let mut ham = HamerlyEngine::new();
    ham.assign(&x, &c, &pool, &mut out); // cold init
    let mut c_small = c.clone();
    let t_warm = time_ms(5, || {
        // small Lloyd-like motion
        for j in 0..k {
            for t in 0..d {
                c_small[(j, t)] += 1e-4;
            }
        }
        ham.assign(&x, &c_small, &pool, &mut out);
    });
    println!("hamerly warm (small step): {t_warm:6.2} ms/call");
    let mut c_jump = c.clone();
    let mut jrng = Pcg32::seed_from_u64(1);
    let t_jump = time_ms(5, || {
        for j in 0..k {
            for t in 0..d {
                c_jump[(j, t)] += 0.05 * jrng.next_gaussian();
            }
        }
        ham.assign(&x, &c_jump, &pool, &mut out);
    });
    println!("hamerly post-jump:       {t_jump:8.2} ms/call  ({:.2}x warm)", t_jump / t_warm);

    // Fused update+energy vs separate passes.
    let assign = lloyd::brute_force_assign(&x, &c);
    let mut cn = c.clone();
    let t_sep = time_ms(10, || {
        lloyd::update_step(&x, &assign, &c, &mut cn, &pool);
        let _ = lloyd::energy(&x, &c, &assign, &pool);
    });
    let t_fused = time_ms(10, || {
        let _ = lloyd::update_and_energy(&x, &assign, &c, &mut cn, &pool);
    });
    println!(
        "update+energy separate:  {t_sep:8.2} ms | fused: {t_fused:6.2} ms ({:.2}x)",
        t_sep / t_fused
    );

    // AA solve cost vs m (dim = K*d).
    println!("\nAA propose cost vs m (dim = {}):", k * d);
    for m in [2usize, 5, 10, 30] {
        let mut acc = AndersonAccelerator::new(m, k * d);
        let mut grng = Pcg32::seed_from_u64(m as u64);
        let g: Vec<f64> = (0..k * d).map(|_| grng.next_gaussian()).collect();
        let f: Vec<f64> = (0..k * d).map(|_| grng.next_gaussian()).collect();
        let mut next = vec![0.0; k * d];
        // warm the history
        for _ in 0..m + 1 {
            let g2: Vec<f64> = g.iter().map(|v| v + grng.next_gaussian() * 0.01).collect();
            let f2: Vec<f64> = f.iter().map(|v| v * 0.9 + grng.next_gaussian() * 0.01).collect();
            let _ = acc.propose_into(&g2, &f2, m, &mut next);
        }
        let g2: Vec<f64> = g.iter().map(|v| v + 0.001).collect();
        let f2: Vec<f64> = f.iter().map(|v| v * 0.9).collect();
        let t = time_ms(200, || {
            let _ = acc.propose_into(&g2, &f2, m, &mut next);
        });
        println!("  m={m:<3} {t:8.4} ms/propose");
    }

    // Macro: per-iteration cost ratio ours vs lloyd.
    println!("\n## L3 macro — per-iteration overhead vs Lloyd\n");
    for (name, num) in [("Eb", 8usize), ("Colorment", 11), ("Birch", 13)] {
        let spec = &aakm::data::REGISTRY[num - 1];
        let x = spec.generate_scaled((50_000.0 / spec.n as f64).min(1.0));
        let mut srng = Pcg32::seed_from_u64(7);
        let c0 = seed_centroids(&x, 10, InitMethod::KMeansPlusPlus, &mut srng);
        let lloyd = Solver::try_new(SolverConfig {
            accel: Acceleration::None,
            threads: 1,
            ..SolverConfig::default()
        })
        .expect("CPU engine")
        .run(&x, c0.clone());
        let ours = Solver::try_new(SolverConfig { threads: 1, ..SolverConfig::default() })
            .expect("CPU engine")
            .run(&x, c0);
        let per_l = lloyd.seconds / lloyd.iterations.max(1) as f64 * 1000.0;
        let per_o = ours.seconds / ours.iterations.max(1) as f64 * 1000.0;
        println!(
            "{name:<12} lloyd {:>4} it ({per_l:6.2} ms/it) | ours {:>4} it ({per_o:6.2} ms/it) | overhead {:.2}x | time ratio {:.2}x",
            lloyd.iterations,
            ours.iterations,
            per_o / per_l,
            lloyd.seconds / ours.seconds.max(1e-12),
        );
        macro_rows.push(format!(
            "    {{\"dataset\": \"{name}\", \"lloyd_iters\": {}, \"lloyd_ms_per_iter\": {per_l:.4}, \"ours_iters\": {}, \"ours_ms_per_iter\": {per_o:.4}, \"overhead\": {:.3}, \"time_ratio\": {:.3}}}",
            lloyd.iterations,
            ours.iterations,
            per_o / per_l,
            lloyd.seconds / ours.seconds.max(1e-12),
        ));
    }

    write_json(n, simd, quick, &sweep_rows, &macro_rows, &session_row);

    // PJRT G-step cost per bucket.
    println!("\n## PJRT G-step (AOT artifact) cost\n");
    match aakm::runtime::PjrtRuntime::open(&aakm::runtime::default_artifact_dir()) {
        Ok(rt) => {
            for (bn, bd) in [(1024usize, 8usize), (4096, 8), (16384, 8)] {
                let mut prng = Pcg32::seed_from_u64(3);
                let xb = synth::gaussian_blobs(&mut prng, bn - 7, bd, 10, 2.0, 0.3);
                let cb = seed_centroids(&xb, 10, InitMethod::Random, &mut prng);
                let _ = rt.g_step(&xb, &cb).expect("warm-up/compile");
                let t = time_ms(10, || {
                    let _ = rt.g_step(&xb, &cb).expect("g_step");
                });
                println!("  bucket n={bn:<6} d={bd}: {t:8.2} ms/G-step");
            }
        }
        Err(e) => println!("  skipped (no artifacts): {e}"),
    }
}
