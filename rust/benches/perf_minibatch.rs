//! Streaming mini-batch sweep: full-batch Lloyd baseline vs mini-batch
//! with and without epoch-level Anderson acceleration, across bench
//! shapes, with the machine-readable trail in `BENCH_minibatch.json`.
//!
//! For each shape the harness records the Lloyd(Hamerly) final energy
//! `E*`, then runs both mini-batch variants from the same seeding and
//! reports the number of *epochs* each needs to reach the 5%-of-Lloyd
//! target (`1.05 · E*`) plus final energies and wall-clock — the
//! acceptance trail for the streaming engine (AA should reach the target
//! in fewer epochs than plain mini-batch on at least one shape).
//!
//! Set `PERF_MINIBATCH_QUICK=1` for the CI smoke leg: one small shape,
//! `BENCH_minibatch.json` still written (that is what CI asserts on).

use aakm::config::{Acceleration, EnergyGuard, EngineKind, SolverConfig};
use aakm::data::{synth, DataMatrix, InMemoryChunks, ShardWriter};
use aakm::init::{seed_centroids, InitMethod};
use aakm::kmeans::Solver;
use aakm::metrics::Stopwatch;
use aakm::rng::Pcg32;
use aakm::stream::{MiniBatchConfig, MiniBatchSolver};
use aakm::{ClusterRequest, ClusterSession};
use std::sync::Arc;

struct ShapeResult {
    row: String,
    aa_beats_plain: bool,
}

fn minibatch_cfg(accel: Acceleration, chunk: usize, max_epochs: usize) -> MiniBatchConfig {
    MiniBatchConfig {
        solver: SolverConfig {
            engine: EngineKind::MiniBatch,
            accel,
            threads: 1,
            max_iters: max_epochs,
            record_trace: true,
            ..SolverConfig::default()
        },
        chunk_size: chunk,
        batches_per_epoch: 0,
        // Tight tolerance: the sweep measures epochs-to-target, so the
        // run must not plateau-stop above the target band.
        convergence_tol: 1e-7,
        ..MiniBatchConfig::default()
    }
}

/// First 1-based epoch whose checkpoint energy is within the target.
fn epochs_to_target(trace: &[f64], target: f64) -> Option<usize> {
    trace.iter().position(|&e| e <= target).map(|idx| idx + 1)
}

fn fmt_epochs(e: Option<usize>) -> String {
    match e {
        Some(v) => v.to_string(),
        None => "null".to_string(),
    }
}

fn run_shape(
    name: &str,
    x: Arc<DataMatrix>,
    k: usize,
    chunk: usize,
    max_epochs: usize,
) -> ShapeResult {
    let mut srng = Pcg32::seed_from_u64(0x5EED);
    let c0 = seed_centroids(&x, k, InitMethod::KMeansPlusPlus, &mut srng);

    // Full-batch Lloyd baseline (the quality target).
    let sw = Stopwatch::start();
    let lloyd = Solver::try_new(SolverConfig {
        accel: Acceleration::None,
        threads: 1,
        ..SolverConfig::default()
    })
    .expect("CPU engine")
    .run(&x, c0.clone());
    let lloyd_ms = sw.seconds() * 1000.0;
    let target = 1.05 * lloyd.energy;

    let variant = |accel: Acceleration| {
        let mut solver = MiniBatchSolver::try_new(minibatch_cfg(accel, chunk, max_epochs))
            .expect("minibatch workspace");
        let mut source = InMemoryChunks::new(Arc::clone(&x));
        let sw = Stopwatch::start();
        let report = solver.run(&mut source, &c0).expect("minibatch run");
        let ms = sw.seconds() * 1000.0;
        let reached = epochs_to_target(&report.energy_trace, target);
        (report, ms, reached)
    };
    let (aa, aa_ms, aa_epochs) = variant(Acceleration::DynamicM(2));
    let (plain, plain_ms, plain_epochs) = variant(Acceleration::None);

    let aa_beats_plain = match (aa_epochs, plain_epochs) {
        (Some(a), Some(p)) => a < p,
        (Some(_), None) => true,
        _ => false,
    };
    println!(
        "{name:<16} lloyd E*={:.4e} ({:.0} ms, {} it) | AA: {} epochs to 1.05E* \
         ({} total, {} accepted, {:.0} ms) | plain: {} epochs to 1.05E* ({} total, {:.0} ms)",
        lloyd.energy,
        lloyd_ms,
        lloyd.iterations,
        fmt_epochs(aa_epochs),
        aa.iterations,
        aa.accepted,
        aa_ms,
        fmt_epochs(plain_epochs),
        plain.iterations,
        plain_ms,
    );
    let row = format!(
        "    {{\"shape\": \"{name}\", \"n\": {}, \"d\": {}, \"k\": {k}, \
         \"chunk\": {chunk}, \"lloyd_energy\": {:.6e}, \"lloyd_ms\": {lloyd_ms:.2}, \
         \"minibatch_aa\": {{\"epochs_to_target\": {}, \"epochs\": {}, \"accepted\": {}, \
         \"final_energy\": {:.6e}, \"ms\": {aa_ms:.2}}}, \
         \"minibatch_plain\": {{\"epochs_to_target\": {}, \"epochs\": {}, \
         \"final_energy\": {:.6e}, \"ms\": {plain_ms:.2}}}, \
         \"aa_beats_plain\": {aa_beats_plain}}}",
        x.n(),
        x.d(),
        lloyd.energy,
        fmt_epochs(aa_epochs),
        aa.iterations,
        aa.accepted,
        aa.energy,
        fmt_epochs(plain_epochs),
        plain.iterations,
        plain.energy,
    );
    ShapeResult { row, aa_beats_plain }
}

/// Saturation sweep for the streaming engine: one mmap shard roughly 10×
/// the chunk budget, streamed through the session path (which owns the
/// prefetch pipeline), prefetch off/on × guard exact/sampled. Reports
/// rows/sec per variant — the throughput acceptance trail for the
/// pipelined prefetcher — and epochs-to-target per guard (the sampled
/// guard must land within one epoch of the exact one). Prefetch is
/// trajectory-neutral, so within a guard the off/on runs are bit-identical
/// and the speedup column isolates pure overlap gains.
fn run_stream_sweep(quick: bool) -> String {
    let (n, d, k, chunk, max_epochs) = if quick {
        (10_240usize, 8usize, 8usize, 1024usize, 25usize)
    } else {
        (40_960, 16, 12, 4096, 40)
    };
    let guard_rows = chunk; // one chunk's worth of reservoir rows
    let mut rng = Pcg32::seed_from_u64(0x57EA);
    let x = Arc::new(synth::gaussian_blobs(&mut rng, n, d, k, 2.5, 0.4));
    let mut srng = Pcg32::seed_from_u64(0x5EED);
    let c0 = Arc::new(seed_centroids(&x, k, InitMethod::KMeansPlusPlus, &mut srng));

    // Shard the matrix to disk so the sweep exercises the mmap + madvise
    // read path the prefetcher exists to hide.
    let dir = std::env::temp_dir().join("aakm_bench");
    std::fs::create_dir_all(&dir).expect("bench temp dir");
    let shard = dir.join(format!("stream_sweep_{n}x{d}.fv"));
    let mut w = ShardWriter::create(&shard, d).expect("shard create");
    w.append(&x).expect("shard append");
    w.finish().expect("shard finish");

    // Quality target from the same full-batch Lloyd baseline the shape
    // sweep uses, expressed per-row so exact (sum over n) and sampled
    // (sum over the reservoir) traces are comparable.
    let lloyd = Solver::try_new(SolverConfig {
        accel: Acceleration::None,
        threads: 1,
        ..SolverConfig::default()
    })
    .expect("CPU engine")
    .run(&x, (*c0).clone());
    let target_mse = 1.05 * lloyd.energy / n as f64;

    let variant = |prefetch: bool, guard: EnergyGuard| {
        let request = ClusterRequest::builder()
            .shard(&shard)
            .k(k)
            .engine(EngineKind::MiniBatch)
            .accel(Acceleration::DynamicM(2))
            .chunk_size(chunk)
            .prefetch(prefetch)
            .guard(guard)
            .initial_centroids(Arc::clone(&c0))
            .max_iters(max_epochs)
            .record_trace(true)
            .threads(1)
            .seed(0x57EA)
            .build()
            .expect("stream sweep request");
        let mut session = ClusterSession::open(request).expect("stream sweep session");
        let sw = Stopwatch::start();
        let report = session.run().expect("stream sweep run");
        let secs = sw.seconds();
        let eval_rows = match guard {
            EnergyGuard::Exact => n,
            EnergyGuard::Sampled { rows } => rows.min(n),
        };
        let mse_trace: Vec<f64> =
            report.energy_trace.iter().map(|e| e / eval_rows as f64).collect();
        let reached = epochs_to_target(&mse_trace, target_mse);
        let rows_per_sec = (n * report.iterations) as f64 / secs.max(1e-9);
        (report, secs * 1000.0, rows_per_sec, reached)
    };

    let mut rows = Vec::new();
    let mut rps = [[0.0f64; 2]; 2]; // [guard][prefetch]
    let mut reached = [[None; 2]; 2];
    for (gi, guard) in [EnergyGuard::Exact, EnergyGuard::Sampled { rows: guard_rows }]
        .into_iter()
        .enumerate()
    {
        for (pi, prefetch) in [false, true].into_iter().enumerate() {
            let (report, ms, rows_per_sec, epochs) = variant(prefetch, guard);
            rps[gi][pi] = rows_per_sec;
            reached[gi][pi] = epochs;
            let gname = match guard {
                EnergyGuard::Exact => "exact".to_string(),
                EnergyGuard::Sampled { rows } => format!("sampled:{rows}"),
            };
            println!(
                "stream-sweep     guard={gname:<14} prefetch={prefetch:<5} \
                 {rows_per_sec:>12.0} rows/s  {} epochs to 1.05E* ({} total, {ms:.0} ms)",
                fmt_epochs(epochs),
                report.iterations,
            );
            rows.push(format!(
                "      {{\"guard\": \"{gname}\", \"prefetch\": {prefetch}, \
                 \"rows_per_sec\": {rows_per_sec:.0}, \"ms\": {ms:.2}, \
                 \"epochs\": {}, \"epochs_to_target\": {}, \"final_energy\": {:.6e}}}",
                report.iterations,
                fmt_epochs(epochs),
                report.energy,
            ));
        }
    }
    let _ = std::fs::remove_file(&shard);

    // Headline numbers: prefetch speedup on the exact-guard pair, and the
    // sampled guard's epoch gap vs exact (prefetch does not change either
    // trajectory, so the exact/on pairing is representative).
    let prefetch_speedup = rps[0][1] / rps[0][0].max(1e-9);
    let guard_epoch_delta = match (reached[0][1], reached[1][1]) {
        (Some(e), Some(s)) => (s as i64 - e as i64).to_string(),
        _ => "null".to_string(),
    };
    println!(
        "stream-sweep     prefetch speedup {prefetch_speedup:.2}x (exact guard), \
         sampled-vs-exact epoch delta {guard_epoch_delta}"
    );
    format!(
        "    {{\"shard_rows\": {n}, \"d\": {d}, \"k\": {k}, \"chunk\": {chunk}, \
         \"guard_rows\": {guard_rows}, \"lloyd_energy\": {:.6e}, \
         \"prefetch_speedup\": {prefetch_speedup:.3}, \
         \"guard_epoch_delta\": {guard_epoch_delta}, \"variants\": [\n{}\n    ]}}",
        lloyd.energy,
        rows.join(",\n"),
    )
}

fn main() {
    let quick = std::env::var("PERF_MINIBATCH_QUICK").is_ok();
    println!(
        "## Mini-batch sweep — Lloyd target vs minibatch ±Anderson (quick={quick})\n"
    );
    let mut results: Vec<ShapeResult> = Vec::new();
    if quick {
        let mut rng = Pcg32::seed_from_u64(0x7A11);
        let x = Arc::new(synth::gaussian_blobs(&mut rng, 20_000, 8, 8, 2.0, 0.4));
        results.push(run_shape("blobs-20k", x, 8, 2048, 40));
    } else {
        let mut rng = Pcg32::seed_from_u64(0x7A11);
        let blobs =
            Arc::new(synth::gaussian_blobs_ex(&mut rng, 100_000, 8, 10, 2.0, 0.4, 0.05, 2.0));
        results.push(run_shape("blobs-100k", blobs, 10, 4096, 60));
        let curve = Arc::new(synth::noisy_curve(&mut rng, 50_000, 4, 0.3));
        results.push(run_shape("curve-50k", curve, 16, 4096, 60));
        let manifold = Arc::new(synth::sin_manifold(&mut rng, 60_000, 10, 2, 4.0, 0.05));
        results.push(run_shape("manifold-60k", manifold, 12, 4096, 60));
    }
    let any_aa_win = results.iter().any(|r| r.aa_beats_plain);
    println!(
        "\nAA reached the 5%-of-Lloyd target in fewer epochs than plain mini-batch on \
         {} of {} shapes\n",
        results.iter().filter(|r| r.aa_beats_plain).count(),
        results.len()
    );
    let stream_sweep = run_stream_sweep(quick);
    let rows: Vec<String> = results.into_iter().map(|r| r.row).collect();
    let json = format!(
        "{{\n  \"bench\": \"perf_minibatch\",\n  \"quick\": {quick},\n  \
         \"variants\": [\"lloyd\", \"minibatch_aa\", \"minibatch_plain\"],\n  \
         \"aa_beats_plain_somewhere\": {any_aa_win},\n  \"shapes\": [\n{}\n  ],\n  \
         \"stream_sweep\":\n{stream_sweep}\n}}\n",
        rows.join(",\n"),
    );
    match std::fs::write("BENCH_minibatch.json", &json) {
        Ok(()) => println!("\nwrote BENCH_minibatch.json"),
        Err(e) => println!("\ncould not write BENCH_minibatch.json: {e}"),
    }
}
