//! Model-lifecycle sweep: cold fit vs warm-start refresh, plus batch
//! prediction throughput against the registered model, with the
//! machine-readable trail in `BENCH_registry.json`.
//!
//! For each shape the harness cold-fits a model into a scratch
//! [`aakm::ModelRegistry`], refreshes it on the *same* data (the paper's
//! best-case regime — the iterate starts at the fixed point, so the
//! refresh should converge in no more iterations than the cold fit, and
//! for the full-batch engines in exactly one round trip), and then
//! measures steady-state predict throughput (rows/sec) on the SIMD
//! fused-argmin kernels with recycled prediction buffers.
//!
//! Set `PERF_REGISTRY_QUICK=1` for the CI smoke leg: smaller shapes, the
//! same two-shape `BENCH_registry.json` (that is what CI asserts on).

use aakm::config::{EngineKind, Precision};
use aakm::coordinator::{Coordinator, CoordinatorConfig};
use aakm::data::{synth, DataMatrix};
use aakm::kmeans::{Workspace, WorkspaceSpec};
use aakm::metrics::Stopwatch;
use aakm::registry::{predict, ModelRegistry};
use aakm::rng::Pcg32;
use aakm::ClusterRequest;
use std::sync::Arc;

struct ShapeResult {
    row: String,
    warm_no_slower: bool,
}

fn run_shape(
    coord: &Coordinator,
    registry_dir: &std::path::Path,
    name: &str,
    x: Arc<DataMatrix>,
    k: usize,
) -> ShapeResult {
    let builder = || {
        ClusterRequest::builder()
            .inline(Arc::clone(&x))
            .k(k)
            .seed(0x5EED)
            .engine(EngineKind::Hamerly)
            .threads(1)
    };
    // Cold fit: full solve from a k-means++ seeding, registered.
    let fit = builder().fit_into(registry_dir, name).build().expect("fit request");
    let cold = coord.submit(fit).expect("submit fit").wait();
    let cold_out = cold.outcome.expect("cold fit");
    let cold_ms = cold.service_time.as_secs_f64() * 1000.0;
    // Warm refresh on unchanged data: seeded from the stored centroids.
    let refresh = builder().refresh_model(registry_dir, name).build().expect("refresh");
    let warm = coord.submit(refresh).expect("submit refresh").wait();
    let warm_out = warm.outcome.expect("warm refresh");
    let warm_ms = warm.service_time.as_secs_f64() * 1000.0;
    let warm_no_slower = warm_out.iterations <= cold_out.iterations;

    // Predict throughput: one cold call builds the kernel + buffers, then
    // the measured reps rerun on recycled pools (the serving steady state).
    let record = ModelRegistry::open(registry_dir)
        .and_then(|r| r.load(name))
        .expect("registered model loads");
    let mut ws = Workspace::open(&WorkspaceSpec {
        engine: EngineKind::Naive,
        precision: Precision::F64,
        threads: 1,
        artifact_dir: None,
    })
    .expect("CPU workspace");
    let p = predict(&record, &x, &mut ws).expect("cold predict");
    ws.recycle_prediction(p.labels, p.distances);
    let reps = 5;
    let sw = Stopwatch::start();
    for _ in 0..reps {
        let p = predict(&record, &x, &mut ws).expect("warm predict");
        ws.recycle_prediction(p.labels, p.distances);
    }
    let predict_secs = sw.seconds();
    let rows_per_sec = (x.n() * reps) as f64 / predict_secs;

    println!(
        "{name:<16} cold: {} it ({cold_ms:.0} ms) | warm refresh: {} it \
         ({warm_ms:.0} ms) | predict: {rows_per_sec:.3e} rows/s \
         | warm_no_slower={warm_no_slower}",
        cold_out.iterations, warm_out.iterations,
    );
    let row = format!(
        "    {{\"shape\": \"{name}\", \"n\": {}, \"d\": {}, \"k\": {k}, \
         \"cold\": {{\"iterations\": {}, \"ms\": {cold_ms:.2}}}, \
         \"warm\": {{\"iterations\": {}, \"ms\": {warm_ms:.2}}}, \
         \"predict_rows_per_sec\": {rows_per_sec:.3}, \
         \"warm_no_slower\": {warm_no_slower}}}",
        x.n(),
        x.d(),
        cold_out.iterations,
        warm_out.iterations,
    );
    ShapeResult { row, warm_no_slower }
}

fn main() {
    let quick = std::env::var("PERF_REGISTRY_QUICK").is_ok();
    println!("## Model lifecycle — cold fit vs warm refresh vs predict (quick={quick})\n");
    let registry_dir = std::env::temp_dir().join("aakm_perf_registry");
    let _ = std::fs::remove_dir_all(&registry_dir);
    let coord = Coordinator::start(CoordinatorConfig {
        workers: 1,
        queue_depth: 2,
        ..CoordinatorConfig::default()
    });
    let mut rng = Pcg32::seed_from_u64(0x9E61);
    let (n_blobs, n_curve) = if quick { (10_000, 8_000) } else { (60_000, 40_000) };
    let blobs = Arc::new(synth::gaussian_blobs(&mut rng, n_blobs, 8, 16, 2.0, 0.4));
    let curve = Arc::new(synth::noisy_curve(&mut rng, n_curve, 4, 0.3));
    let results = vec![
        run_shape(&coord, &registry_dir, "blobs", blobs, 16),
        run_shape(&coord, &registry_dir, "curve", curve, 12),
    ];
    coord.shutdown();
    let _ = std::fs::remove_dir_all(&registry_dir);

    let all_no_slower = results.iter().all(|r| r.warm_no_slower);
    println!(
        "\nwarm refresh converged in <= cold iterations on {} of {} shapes",
        results.iter().filter(|r| r.warm_no_slower).count(),
        results.len()
    );
    let rows: Vec<String> = results.into_iter().map(|r| r.row).collect();
    let json = format!(
        "{{\n  \"bench\": \"perf_registry\",\n  \"quick\": {quick},\n  \
         \"warm_no_slower_everywhere\": {all_no_slower},\n  \"shapes\": [\n{}\n  ]\n}}\n",
        rows.join(",\n"),
    );
    match std::fs::write("BENCH_registry.json", &json) {
        Ok(()) => println!("\nwrote BENCH_registry.json"),
        Err(e) => println!("\ncould not write BENCH_registry.json: {e}"),
    }
}
