//! Shared support for the paper-table bench harnesses.
//!
//! `AAKM_BENCH_SCALE` selects the workload size:
//! * `smoke` (default) — datasets capped at [`SMOKE_CAP`] samples so the
//!   whole `cargo bench` suite completes in minutes on one core;
//! * `paper` — the full Table-1 sample counts (hours; use for the record).
//!
//! Every harness prints the paper's table as markdown and writes a CSV
//! next to it under `bench_results/`.
//!
//! (Each bench target compiles this module independently and uses a
//! subset of the helpers, hence the blanket `allow(dead_code)`.)
#![allow(dead_code)]

use aakm::config::{Acceleration, SolverConfig};
use aakm::data::{DatasetSpec, REGISTRY};
use aakm::init::{seed_centroids, InitMethod};
use aakm::kmeans::{RunReport, Solver};
use aakm::rng::Pcg32;
use std::path::PathBuf;

/// Sample cap in smoke mode.
pub const SMOKE_CAP: usize = 20_000;

/// Benchmark scale selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    Smoke,
    Paper,
}

impl Scale {
    /// Read from `AAKM_BENCH_SCALE`.
    pub fn from_env() -> Self {
        match std::env::var("AAKM_BENCH_SCALE").as_deref() {
            Ok("paper") => Scale::Paper,
            _ => Scale::Smoke,
        }
    }

    /// Generation scale for a dataset.
    pub fn factor(&self, spec: &DatasetSpec) -> f64 {
        match self {
            Scale::Paper => 1.0,
            Scale::Smoke => (SMOKE_CAP as f64 / spec.n as f64).min(1.0),
        }
    }
}

/// Generate dataset `spec` at the chosen scale.
pub fn dataset(spec: &DatasetSpec, scale: Scale) -> aakm::data::DataMatrix {
    spec.generate_scaled(scale.factor(spec))
}

/// Generate dataset `spec` at an explicit fraction of the paper's N
/// (clamped to (0, 1]); used by harness columns that need a tighter cap.
#[allow(dead_code)]
pub fn dataset_capped(spec: &DatasetSpec, fraction: f64) -> aakm::data::DataMatrix {
    spec.generate_scaled(fraction.clamp(1e-6, 1.0))
}

/// The solver config used across benches (paper defaults, single thread —
/// the container has one core and the paper reports per-config wall-clock).
pub fn solver_config(accel: Acceleration) -> SolverConfig {
    SolverConfig { accel, threads: 1, ..SolverConfig::default() }
}

/// Run one (dataset, init, accel, K) case from a deterministic seed.
pub fn run_case(
    x: &aakm::data::DataMatrix,
    k: usize,
    init: InitMethod,
    accel: Acceleration,
    seed: u64,
) -> RunReport {
    let mut rng = Pcg32::seed_from_u64(seed);
    let c0 = seed_centroids(x, k, init, &mut rng);
    Solver::try_new(solver_config(accel)).expect("CPU engine").run(x, c0)
}

/// Where bench CSVs land.
pub fn results_dir() -> PathBuf {
    let dir = PathBuf::from("bench_results");
    let _ = std::fs::create_dir_all(&dir);
    dir
}

/// Iterate the registry (all 20 paper datasets).
pub fn registry() -> &'static [DatasetSpec] {
    &REGISTRY
}

/// Paper-style time cell.
pub fn fmt_time(seconds: f64) -> String {
    format!("{seconds:.2}")
}

/// Paper-style MSE cell.
pub fn fmt_mse(mse: f64) -> String {
    format!("{mse:.2}")
}
