//! Convergence-rate figure: per-iteration energy traces of Lloyd vs the
//! accelerated solver on four representative datasets (the evidence behind
//! the paper's §2 convergence discussion — the paper prints tables only;
//! we emit the underlying series as CSV plus an ASCII preview).

mod common;

use aakm::config::Acceleration;
use aakm::init::InitMethod;
use aakm::rng::Pcg32;
use aakm::init::seed_centroids;
use aakm::kmeans::Solver;
use aakm::config::SolverConfig;
use common::{dataset, registry, results_dir, Scale};

fn main() {
    let scale = Scale::from_env();
    let picks = [2usize, 8, 11, 13]; // Slice (manifold), Eb (curve), Colorment (blobs), Birch (grid)
    let dir = results_dir();
    for num in picks {
        let spec = &registry()[num - 1];
        let x = dataset(spec, scale);
        let mut rng = Pcg32::seed_from_u64(0xF16 + num as u64);
        let c0 = seed_centroids(&x, 10, InitMethod::KMeansPlusPlus, &mut rng);
        let run = |accel| {
            let cfg = SolverConfig { accel, threads: 1, record_trace: true, ..SolverConfig::default() };
            Solver::try_new(cfg).expect("CPU engine").run(&x, c0.clone())
        };
        let lloyd = run(Acceleration::None);
        let ours = run(Acceleration::DynamicM(2));
        // CSV: iter, lloyd_energy, ours_energy, ours_m
        let mut csv = String::from("iter,lloyd_energy,ours_energy,ours_m\n");
        let len = lloyd.energy_trace.len().max(ours.energy_trace.len());
        for i in 0..len {
            let l = lloyd.energy_trace.get(i).map_or(String::new(), |v| format!("{v}"));
            let o = ours.energy_trace.get(i).map_or(String::new(), |v| format!("{v}"));
            let m = ours.m_trace.get(i).map_or(String::new(), |v| format!("{v}"));
            csv.push_str(&format!("{i},{l},{o},{m}\n"));
        }
        let path = dir.join(format!("fig_convergence_{}.csv", spec.name));
        std::fs::write(&path, csv).expect("write csv");
        // ASCII summary.
        let e_star = lloyd.energy.min(ours.energy);
        let progress = |trace: &[f64], frac: f64| {
            let target = e_star + (trace[0] - e_star) * frac;
            trace.iter().position(|&e| e <= target).unwrap_or(trace.len())
        };
        println!(
            "#{:<2} {:<18} lloyd {:>4} iters / ours {:>4} ({:>4} acc) | iters to 99% progress: lloyd {:>4}, ours {:>4} | csv {}",
            spec.number,
            spec.name,
            lloyd.iterations,
            ours.iterations,
            ours.accepted,
            progress(&lloyd.energy_trace, 0.01),
            progress(&ours.energy_trace, 0.01),
            path.display()
        );
    }
    println!("(scale = {scale:?})");
}
