//! Regenerates **Table 1**: the 20-dataset inventory (name, N, d), plus the
//! synthetic structure used as the stand-in and a generation smoke check.

mod common;

use aakm::metrics::{Table, TableCell};
use common::{registry, results_dir, Scale};

fn main() {
    let scale = Scale::from_env();
    let mut table = Table::new(
        "Table 1 — the 20 datasets used in our experiments",
        &["No.", "Name", "N", "d", "stand-in structure", "bench N"],
    );
    for spec in registry() {
        // Generate a tiny sample to prove the generator is healthy.
        let sample = spec.generate_scaled(0.001_f64.max(64.0 / spec.n as f64));
        assert_eq!(sample.d(), spec.d);
        let bench_n = ((spec.n as f64) * scale.factor(spec)) as usize;
        table.push_row(vec![
            TableCell::plain(spec.number.to_string()),
            TableCell::plain(spec.name),
            TableCell::plain(spec.n.to_string()),
            TableCell::plain(spec.d.to_string()),
            TableCell::plain(format!("{:?}", spec.structure)),
            TableCell::plain(bench_n.to_string()),
        ]);
    }
    println!("{}", table.to_markdown());
    let csv = results_dir().join("table1_datasets.csv");
    table.save_csv(&csv).expect("write csv");
    println!("(scale = {scale:?}; csv -> {})", csv.display());
}
