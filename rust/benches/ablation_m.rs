//! Ablation B — history cap m̄ and initial window m₀ (the paper uses
//! m̄ = 30 and m₀ ∈ {2, 5}): shows the dynamic controller is robust to
//! both, and what a too-small cap costs.

mod common;

use aakm::config::{Acceleration, SolverConfig};
use aakm::init::{seed_centroids, InitMethod};
use aakm::kmeans::Solver;
use aakm::metrics::{Table, TableCell};
use aakm::rng::Pcg32;
use common::{dataset, registry, results_dir, Scale};

fn main() {
    let scale = Scale::from_env();
    let picks = [2usize, 9, 13]; // Slicelocalization, AllUsers, Birch
    let mut table = Table::new(
        "Ablation — m̄ (cap) × m₀ (initial m): iterations (accepted)",
        &["m̄", "m₀", "Slicelocalization", "AllUsers", "Birch"],
    );
    for m_max in [5usize, 10, 30, 60] {
        for m0 in [1usize, 2, 5, 10] {
            if m0 > m_max {
                continue;
            }
            let mut row =
                vec![TableCell::plain(m_max.to_string()), TableCell::plain(m0.to_string())];
            for &num in &picks {
                let spec = &registry()[num - 1];
                let x = dataset(spec, scale);
                let mut rng = Pcg32::seed_from_u64(0xAB1B + num as u64);
                let c0 = seed_centroids(&x, 10, InitMethod::KMeansPlusPlus, &mut rng);
                let cfg = SolverConfig {
                    accel: Acceleration::DynamicM(m0),
                    m_max,
                    threads: 1,
                    ..SolverConfig::default()
                };
                let r = Solver::try_new(cfg).expect("CPU engine").run(&x, c0);
                row.push(TableCell::plain(format!("{} ({})", r.iterations, r.accepted)));
            }
            table.push_row(row);
        }
        eprintln!("done m̄={m_max}");
    }
    println!("{}", table.to_markdown());
    println!("paper: m̄=30, m₀=2 by default (Table 2 also reports m₀=5)");
    let csv = results_dir().join("ablation_m.csv");
    table.save_csv(&csv).expect("write csv");
    println!("(scale = {scale:?}; csv -> {})", csv.display());
}
