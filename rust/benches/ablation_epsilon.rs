//! Ablation A — sensitivity to the dynamic-m thresholds (ε₁, ε₂). The
//! paper fixes ε₁ = 0.02, ε₂ = 0.5 for every dataset; this harness shows
//! the neighborhood is flat (i.e. the defaults are not cherry-picked).

mod common;

use aakm::config::{Acceleration, SolverConfig};
use aakm::init::{seed_centroids, InitMethod};
use aakm::kmeans::Solver;
use aakm::metrics::{Table, TableCell};
use aakm::rng::Pcg32;
use common::{dataset, registry, results_dir, Scale};

fn main() {
    let scale = Scale::from_env();
    let picks = [5usize, 8, 11]; // HTRU2, Eb, Colorment
    let eps1s = [0.005, 0.02, 0.05, 0.1];
    let eps2s = [0.3, 0.5, 0.7, 0.9];
    let mut table = Table::new(
        "Ablation — (ε₁, ε₂) grid: iterations (time s) per dataset",
        &["ε₁", "ε₂", "HTRU2", "Eb", "Colorment"],
    );
    for &e1 in &eps1s {
        for &e2 in &eps2s {
            if e1 >= e2 {
                continue;
            }
            let mut row = vec![TableCell::plain(format!("{e1}")), TableCell::plain(format!("{e2}"))];
            for &num in &picks {
                let spec = &registry()[num - 1];
                let x = dataset(spec, scale);
                let mut rng = Pcg32::seed_from_u64(0xAB1A + num as u64);
                let c0 = seed_centroids(&x, 10, InitMethod::KMeansPlusPlus, &mut rng);
                let cfg = SolverConfig {
                    accel: Acceleration::DynamicM(2),
                    epsilon1: e1,
                    epsilon2: e2,
                    threads: 1,
                    ..SolverConfig::default()
                };
                let r = Solver::try_new(cfg).expect("CPU engine").run(&x, c0);
                row.push(TableCell::plain(format!("{} ({:.2})", r.iterations, r.seconds)));
            }
            table.push_row(row);
        }
        eprintln!("done ε₁={e1}");
    }
    println!("{}", table.to_markdown());
    println!("paper defaults: ε₁=0.02, ε₂=0.5 (used unchanged for all datasets)");
    let csv = results_dir().join("ablation_epsilon.csv");
    table.save_csv(&csv).expect("write csv");
    println!("(scale = {scale:?}; csv -> {})", csv.display());
}
