//! Regenerates **Table 3**: our method vs Lloyd(Hamerly) across the four
//! initializations (k-means++, afk-mc², bf, CLARANS) at K=10, plus the
//! CLARANS columns at K=100 and K=1000, and the paper's headline summary
//! (wins out of 120 cases; mean computational-time decrease).

mod common;

use aakm::config::Acceleration;
use aakm::init::InitMethod;
use aakm::metrics::{HeadlineStats, Table, TableCell};
use common::{dataset, dataset_capped, fmt_mse, fmt_time, registry, results_dir, run_case, Scale};

fn main() {
    let scale = Scale::from_env();
    // K=1000 on full-size data is the paper's heaviest column (their #20
    // case runs 10k+ seconds); smoke mode covers K=100 only and the K=1000
    // column is produced by AAKM_BENCH_SCALE=paper.
    let big_ks: &[usize] =
        if scale == Scale::Paper { &[100, 1000] } else { &[100] };

    let mut header: Vec<String> = vec!["Dataset".into()];
    for init in InitMethod::PAPER_SET {
        header.push(format!("{} L:#It", init.name()));
        header.push("ours:#It".into());
        header.push("L:T(s)".into());
        header.push("ours:T(s)".into());
        header.push("MSE".into());
    }
    for k in big_ks {
        header.push(format!("clarans K={k} L:#It"));
        header.push("ours:#It".into());
        header.push("L:T(s)".into());
        header.push("ours:T(s)".into());
    }
    let header_refs: Vec<&str> = header.iter().map(String::as_str).collect();
    let mut table = Table::new(
        "Table 3 — ours vs Lloyd (Hamerly assignment) across initializations and K",
        &header_refs,
    );

    let mut headline = HeadlineStats::new();
    let mut iter_wins = 0usize;
    let mut iter_cases = 0usize;
    for spec in registry() {
        let x = dataset(spec, scale);
        let mut row = vec![TableCell::plain(format!("{} {}", spec.number, spec.name))];
        // Four initializations at K=10.
        for (ii, init) in InitMethod::PAPER_SET.iter().enumerate() {
            let seed = 0x7AB3 * spec.number as u64 + ii as u64;
            let lloyd = run_case(&x, 10, *init, Acceleration::None, seed);
            let ours = run_case(&x, 10, *init, Acceleration::DynamicM(2), seed);
            headline.record(ours.seconds, lloyd.seconds);
            iter_cases += 1;
            if ours.iterations < lloyd.iterations {
                iter_wins += 1;
            }
            let (lt, ot) = if ours.seconds < lloyd.seconds {
                (TableCell::plain(fmt_time(lloyd.seconds)), TableCell::bold(fmt_time(ours.seconds)))
            } else {
                (TableCell::bold(fmt_time(lloyd.seconds)), TableCell::plain(fmt_time(ours.seconds)))
            };
            row.push(TableCell::plain(lloyd.iterations.to_string()));
            row.push(TableCell::plain(ours.iter_cell()));
            row.push(lt);
            row.push(ot);
            row.push(TableCell::plain(fmt_mse(ours.mse)));
        }
        // CLARANS at large K. Smoke mode shrinks the sample count further
        // for this column — CLARANS seeding + two K=100 solves per dataset
        // dominate the suite's runtime otherwise (the paper's own K=1000
        // column runs for hours on its testbed).
        let x_big;
        let x_ref = if scale == Scale::Paper {
            &x
        } else {
            let cap = 6000.0 / spec.n as f64;
            x_big = dataset_capped(spec, cap);
            &x_big
        };
        for (ki, &k) in big_ks.iter().enumerate() {
            let k_eff = k.min(x_ref.n() / 2);
            let seed = 0x5EED_C1A4 + spec.number as u64 + ki as u64;
            let lloyd = run_case(x_ref, k_eff, InitMethod::Clarans, Acceleration::None, seed);
            let ours = run_case(x_ref, k_eff, InitMethod::Clarans, Acceleration::DynamicM(2), seed);
            headline.record(ours.seconds, lloyd.seconds);
            iter_cases += 1;
            if ours.iterations < lloyd.iterations {
                iter_wins += 1;
            }
            row.push(TableCell::plain(lloyd.iterations.to_string()));
            row.push(TableCell::plain(ours.iter_cell()));
            row.push(TableCell::plain(fmt_time(lloyd.seconds)));
            row.push(TableCell::plain(fmt_time(ours.seconds)));
        }
        table.push_row(row);
        eprintln!("done #{:<2} {}", spec.number, spec.name);
    }

    println!("{}", table.to_markdown());
    println!("headline: {}", headline.summary());
    println!(
        "iteration wins: {iter_wins}/{iter_cases} cases use fewer iterations than Lloyd"
    );
    println!("paper: wins 106/120 cases; mean time decrease > 33%");
    let csv = results_dir().join("table3_vs_lloyd.csv");
    table.save_csv(&csv).expect("write csv");
    println!("(scale = {scale:?}; csv -> {})", csv.display());
}
