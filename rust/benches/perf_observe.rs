//! Observability overhead sweep: the same jobs served three ways —
//! telemetry off, metrics registry enabled, metrics + JSONL event log —
//! with the machine-readable trail in `BENCH_observe.json`.
//!
//! Jobs run through a single-worker [`Coordinator`] (the real serve
//! path: queue metrics, pickup instrumentation, the forwarding
//! observer), warm after a discarded first job, and the per-iteration
//! solver cost is taken from each outcome's `run_time` so queue time
//! never pollutes the measurement. The event-log mode includes the
//! per-iteration energy evaluation that live iteration events imply —
//! that is the honest price of turning them on.
//!
//! Set `PERF_OBSERVE_QUICK=1` for the CI smoke leg: smaller shape and
//! fewer jobs, `BENCH_observe.json` still written (what CI asserts on).

use aakm::config::{Acceleration, EngineKind};
use aakm::coordinator::{Coordinator, CoordinatorConfig};
use aakm::data::{synth, DataMatrix};
use aakm::rng::Pcg32;
use aakm::telemetry::{self, events};
use aakm::ClusterRequest;
use std::sync::Arc;

struct ModeStats {
    /// Mean solver-reported run time per productive iteration, in µs.
    iter_us: f64,
    total_iterations: u64,
    events_dropped: u64,
}

fn request(x: &Arc<DataMatrix>, engine: EngineKind, k: usize, seed: u64) -> ClusterRequest {
    let mut builder = ClusterRequest::builder()
        .inline(Arc::clone(x))
        .k(k)
        .seed(seed)
        .accel(Acceleration::DynamicM(2))
        .engine(engine)
        .threads(1);
    if engine == EngineKind::MiniBatch {
        builder = builder.chunk_size(2048);
    }
    builder.build().expect("valid request")
}

/// Serve `jobs` identical-shape requests sequentially on one warm worker
/// and average the solver's own run time per iteration.
fn serve_mode(
    x: &Arc<DataMatrix>,
    engine: EngineKind,
    k: usize,
    jobs: usize,
    events_path: Option<&std::path::Path>,
) -> ModeStats {
    let dropped_before = events::dropped();
    let guard = events_path.map(|p| {
        let _ = std::fs::remove_file(p);
        events::install(p).expect("install event log")
    });
    let coord = Coordinator::start(CoordinatorConfig {
        workers: 1,
        queue_depth: 4,
        solver_threads: 1,
        ..CoordinatorConfig::default()
    });
    // Discarded warm-up job: builds the worker's workspace so the timed
    // jobs all reuse warm scratch.
    coord
        .submit(request(x, engine, k, 1))
        .unwrap()
        .wait()
        .outcome
        .expect("warm-up job");
    let mut run_secs = 0.0;
    let mut iterations = 0u64;
    for j in 0..jobs {
        let out = coord
            .submit(request(x, engine, k, 2 + j as u64))
            .unwrap()
            .wait()
            .outcome
            .expect("timed job");
        run_secs += out.run_time.as_secs_f64();
        iterations += out.iterations as u64;
    }
    coord.shutdown();
    if let Some(g) = guard {
        g.close();
    }
    ModeStats {
        iter_us: run_secs * 1e6 / iterations.max(1) as f64,
        total_iterations: iterations,
        events_dropped: events::dropped() - dropped_before,
    }
}

fn overhead_pct(mode: &ModeStats, off: &ModeStats) -> f64 {
    if off.iter_us > 0.0 {
        (mode.iter_us - off.iter_us) / off.iter_us * 100.0
    } else {
        0.0
    }
}

fn main() {
    let quick = std::env::var("PERF_OBSERVE_QUICK").is_ok();
    let (n, jobs) = if quick { (20_000, 3) } else { (100_000, 8) };
    println!("## Telemetry overhead — off vs metrics vs metrics+events (quick={quick})\n");

    let mut rng = Pcg32::seed_from_u64(0x0B5E);
    let x = Arc::new(synth::gaussian_blobs(&mut rng, n, 8, 8, 2.0, 0.4));
    let events_name = format!("aakm-perf-observe-{}.jsonl", std::process::id());
    let events_path = std::env::temp_dir().join(events_name);

    let mut rows: Vec<String> = Vec::new();
    for (name, engine) in [("hamerly", EngineKind::Hamerly), ("minibatch", EngineKind::MiniBatch)] {
        // Mode order matters: the event log is process-global, so it is
        // installed only for the final mode of each engine.
        telemetry::disable();
        let off = serve_mode(&x, engine, 8, jobs, None);
        telemetry::enable();
        let metrics = serve_mode(&x, engine, 8, jobs, None);
        let with_events = serve_mode(&x, engine, 8, jobs, Some(&events_path));
        telemetry::disable();

        let m_pct = overhead_pct(&metrics, &off);
        let e_pct = overhead_pct(&with_events, &off);
        println!(
            "{name:<10} off {:.2} µs/it ({} it) | metrics {:.2} µs/it ({:+.2}%) | \
             +events {:.2} µs/it ({:+.2}%, {} dropped)",
            off.iter_us,
            off.total_iterations,
            metrics.iter_us,
            m_pct,
            with_events.iter_us,
            e_pct,
            with_events.events_dropped,
        );
        rows.push(format!(
            "    {{\"engine\": \"{name}\", \"n\": {n}, \"jobs\": {jobs}, \
             \"off_iter_us\": {:.3}, \"metrics_iter_us\": {:.3}, \
             \"metrics_events_iter_us\": {:.3}, \"metrics_overhead_pct\": {m_pct:.2}, \
             \"events_overhead_pct\": {e_pct:.2}, \"iterations\": {}, \
             \"events_dropped\": {}}}",
            off.iter_us,
            metrics.iter_us,
            with_events.iter_us,
            off.total_iterations,
            with_events.events_dropped,
        ));
    }
    let _ = std::fs::remove_file(&events_path);

    let json = format!(
        "{{\n  \"bench\": \"perf_observe\",\n  \"quick\": {quick},\n  \
         \"modes\": [\"off\", \"metrics\", \"metrics_events\"],\n  \"engines\": [\n{}\n  ]\n}}\n",
        rows.join(",\n"),
    );
    match std::fs::write("BENCH_observe.json", &json) {
        Ok(()) => println!("\nwrote BENCH_observe.json"),
        Err(e) => println!("\ncould not write BENCH_observe.json: {e}"),
    }
}
