//! Regenerates **Table 2**: fixed vs dynamic `m` (m ∈ {2, 5}) across the 20
//! datasets — `a/b` iteration cells (accepted / total), wall-clock seconds,
//! MSE — with the fastest of each fixed/dynamic pair bolded, plus the
//! paper's summary claim (dynamic ≥ fixed on most datasets).

mod common;

use aakm::config::Acceleration;
use aakm::init::InitMethod;
use aakm::metrics::{Table, TableCell};
use common::{dataset, fmt_mse, fmt_time, registry, results_dir, run_case, Scale};

fn main() {
    let scale = Scale::from_env();
    let mut table = Table::new(
        "Table 2 — fixed vs dynamic m (K=10, k-means++ seeds)",
        &[
            "Dataset",
            "Fixed m=2 #Iter",
            "Time(s)",
            "MSE",
            "Dyn m=2 #Iter",
            "Time(s)",
            "MSE",
            "Fixed m=5 #Iter",
            "Time(s)",
            "MSE",
            "Dyn m=5 #Iter",
            "Time(s)",
            "MSE",
        ],
    );
    let mut dynamic_wins_2 = 0usize;
    let mut dynamic_wins_5 = 0usize;
    for spec in registry() {
        let x = dataset(spec, scale);
        let seed = 0xBE2C * spec.number as u64;
        let cases = [
            Acceleration::FixedM(2),
            Acceleration::DynamicM(2),
            Acceleration::FixedM(5),
            Acceleration::DynamicM(5),
        ];
        let reports: Vec<_> = cases
            .iter()
            .map(|&accel| run_case(&x, 10, InitMethod::KMeansPlusPlus, accel, seed))
            .collect();
        if reports[1].seconds <= reports[0].seconds {
            dynamic_wins_2 += 1;
        }
        if reports[3].seconds <= reports[2].seconds {
            dynamic_wins_5 += 1;
        }
        let mut row = vec![TableCell::plain(format!("{} {}", spec.number, spec.name))];
        for pair in [(0usize, 1usize), (2, 3)] {
            for idx in [pair.0, pair.1] {
                let r = &reports[idx];
                let faster = r.seconds
                    <= reports[if idx == pair.0 { pair.1 } else { pair.0 }].seconds;
                let time = if faster {
                    TableCell::bold(fmt_time(r.seconds))
                } else {
                    TableCell::plain(fmt_time(r.seconds))
                };
                row.push(TableCell::plain(r.iter_cell()));
                row.push(time);
                row.push(TableCell::plain(fmt_mse(r.mse)));
            }
        }
        table.push_row(row);
        eprintln!("done #{:<2} {}", spec.number, spec.name);
    }
    println!("{}", table.to_markdown());
    println!(
        "summary: dynamic m beats fixed m on {dynamic_wins_2}/20 datasets (m=2) and {dynamic_wins_5}/20 (m=5)"
    );
    println!("paper: dynamic adjustment reduces time on the majority of datasets (>20% on most)");
    let csv = results_dir().join("table2_dynamic_m.csv");
    table.save_csv(&csv).expect("write csv");
    println!("(scale = {scale:?}; csv -> {})", csv.display());
}
