//! Deterministic fault-injection harness for robustness testing.
//!
//! The coordinator's fault-tolerance contract — every submitted job
//! resolves to a typed outcome, never a hang — is only worth anything if
//! it is exercised against real failures. This module plants cheap,
//! normally-inert injection points at the three places transient faults
//! actually enter the system:
//!
//! * [`FaultSite::ChunkRead`] — a [`crate::data::ChunkSource`] read
//!   (mmap page-in, in-memory chunk handoff),
//! * [`FaultSite::PjrtOpen`] — PJRT runtime / artifact-manifest load,
//! * [`FaultSite::SolverIteration`] — the top of the shared
//!   fixed-point driver loop,
//! * [`FaultSite::CheckpointWrite`] — a durable snapshot write in
//!   [`crate::persist`] (clean failure, torn temp file, or a kill between
//!   the write and the atomic rename),
//! * [`FaultSite::RegistryWrite`] — a model-registry save in
//!   [`crate::registry`], with the same two write windows as
//!   `CheckpointWrite` (the registry reuses the atomic
//!   temp-write-fsync-rename discipline).
//!
//! A [`FaultPlan`] describes *when* each site fires and *how*
//! ([`FaultKind`]): a typed error, an ordinary panic (caught by the
//! worker's per-job isolation), or a worker kill (a panic that escapes
//! isolation so the supervisor's respawn path runs). Plans are
//! deterministic: counted rules fire on exact hit indices, rate-based
//! rules draw from a [`crate::rng::Pcg32`] seeded by the caller, so a
//! fixed seed replays the identical fault schedule.
//!
//! The harness is process-global (the injection points live on hot paths
//! with no plumbing to thread a handle through) and serialized:
//! [`FaultPlan::install`] holds a global lock until the returned
//! [`FaultGuard`] drops, so concurrent tests cannot interleave plans.
//! Unit tests that hit sites from the test thread itself should prefer
//! [`FaultPlan::install_for_current_thread`], which additionally scopes
//! firing to the installing thread — a concurrently running bystander
//! test cannot steal (or be broken by) the armed schedule. With no plan
//! installed the per-site cost is one relaxed atomic load.

use crate::error::ClusterError;
use crate::rng::{Pcg32, Rng};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};

/// Where a fault is injected.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultSite {
    /// A `ChunkSource::next_chunk` read.
    ChunkRead,
    /// `PjrtRuntime::open` (manifest + client bring-up).
    PjrtOpen,
    /// The top of one fixed-point driver iteration.
    SolverIteration,
    /// A checkpoint snapshot write (`persist::write_snapshot`). The site is
    /// hit twice per write — once before the temp file is written (a clean
    /// failure leaves no new bytes on disk) and once between the write and
    /// the atomic rename (an error there truncates the temp file to a torn
    /// prefix, a kill dies with the rename never performed) — so a plan can
    /// target either window.
    CheckpointWrite,
    /// A model-registry save (`registry::ModelRegistry::save`). Like
    /// `CheckpointWrite`, the site is hit twice per save — before the temp
    /// file is written and between the write and the atomic rename (an
    /// error there truncates the temp file to a torn prefix) — so a
    /// previously registered model always survives an injected failure
    /// intact.
    RegistryWrite,
}

/// How an armed site fails when it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Return the site's typed error (e.g. a chunk read comes back as
    /// [`ClusterError::Data`]).
    Error,
    /// Panic with a string payload — exercises the worker's per-job
    /// `catch_unwind` isolation.
    Panic,
    /// Panic with the [`WorkerKilled`] payload — the worker resolves the
    /// job's handle and then dies, exercising supervisor respawn.
    KillWorker,
}

/// Panic payload of [`FaultKind::KillWorker`]: a worker that catches it
/// resolves the in-flight job and then resumes unwinding so the thread
/// genuinely dies.
#[derive(Debug, Clone, Copy)]
pub struct WorkerKilled;

/// One injection rule: after `skip` hits at `site`, the next `count`
/// qualifying hits fire `kind`. A rate-based rule qualifies a hit by a
/// seeded Bernoulli draw instead of unconditionally.
#[derive(Debug, Clone)]
struct FaultRule {
    site: FaultSite,
    kind: FaultKind,
    skip: u64,
    remaining: u64,
    rate: Option<(f64, Pcg32)>,
}

/// A deterministic schedule of injected faults. Build one with the
/// chainable constructors, then [`FaultPlan::install`] it; it stays
/// active until the returned [`FaultGuard`] drops.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    rules: Vec<FaultRule>,
}

impl FaultPlan {
    /// An empty plan (no site ever fires).
    pub fn new() -> Self {
        Self::default()
    }

    /// Fire `kind` on the next `count` hits at `site`.
    pub fn fail_next(self, site: FaultSite, kind: FaultKind, count: u64) -> Self {
        self.fail_after(site, kind, 0, count)
    }

    /// Skip the first `skip` hits at `site`, then fire `kind` on the
    /// following `count` hits.
    pub fn fail_after(mut self, site: FaultSite, kind: FaultKind, skip: u64, count: u64) -> Self {
        self.rules.push(FaultRule { site, kind, skip, remaining: count, rate: None });
        self
    }

    /// Fire `kind` on each hit at `site` with probability `rate`, drawn
    /// from a [`Pcg32`] seeded with `seed` (so a fixed seed replays the
    /// identical schedule), for at most `count` firings.
    pub fn fail_with_rate(
        mut self,
        site: FaultSite,
        kind: FaultKind,
        rate: f64,
        seed: u64,
        count: u64,
    ) -> Self {
        self.rules.push(FaultRule {
            site,
            kind,
            skip: 0,
            remaining: count,
            rate: Some((rate.clamp(0.0, 1.0), Pcg32::seed_from_u64(seed))),
        });
        self
    }

    /// Arm the plan process-wide (any thread's site hits can fire it —
    /// what the coordinator integration harness needs, where worker
    /// threads are the ones reaching the sites). Serialized: the call
    /// blocks while another plan is installed, and the plan disarms when
    /// the returned guard drops.
    pub fn install(self) -> FaultGuard {
        self.install_scoped(Scope::Process)
    }

    /// Arm the plan for site hits made by the *calling thread* only.
    /// Other threads see every site inert, so a unit test that consumes
    /// its schedule synchronously cannot interfere with (or be robbed
    /// by) tests running in parallel. Same serialization as
    /// [`FaultPlan::install`].
    pub fn install_for_current_thread(self) -> FaultGuard {
        self.install_scoped(Scope::Thread(std::thread::current().id()))
    }

    fn install_scoped(self, scope: Scope) -> FaultGuard {
        let permit = INSTALL_LOCK.lock().unwrap_or_else(PoisonError::into_inner);
        *state().lock().unwrap_or_else(PoisonError::into_inner) =
            Some(Installed { rules: self.rules, scope });
        ACTIVE.store(true, Ordering::Release);
        FaultGuard { _permit: permit }
    }
}

/// Which threads an installed plan fires for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Scope {
    /// Any thread (integration harness: coordinator workers hit sites).
    Process,
    /// Only the installing thread (unit tests, contamination-proof).
    Thread(std::thread::ThreadId),
}

/// An armed plan plus its firing scope.
struct Installed {
    rules: Vec<FaultRule>,
    scope: Scope,
}

/// Keeps a [`FaultPlan`] armed; dropping it disarms the plan and releases
/// the global install lock.
pub struct FaultGuard {
    _permit: MutexGuard<'static, ()>,
}

impl Drop for FaultGuard {
    fn drop(&mut self) {
        ACTIVE.store(false, Ordering::Release);
        *state().lock().unwrap_or_else(PoisonError::into_inner) = None;
    }
}

static ACTIVE: AtomicBool = AtomicBool::new(false);
static INSTALL_LOCK: Mutex<()> = Mutex::new(());
static STATE: Mutex<Option<Installed>> = Mutex::new(None);

fn state() -> &'static Mutex<Option<Installed>> {
    &STATE
}

/// Consume one hit at `site`, returning the kind to fire, if any.
fn fire(site: FaultSite) -> Option<FaultKind> {
    if !ACTIVE.load(Ordering::Acquire) {
        return None;
    }
    let mut guard = state().lock().unwrap_or_else(PoisonError::into_inner);
    let installed = guard.as_mut()?;
    if let Scope::Thread(owner) = installed.scope {
        if owner != std::thread::current().id() {
            return None;
        }
    }
    for rule in installed.rules.iter_mut().filter(|r| r.site == site) {
        if rule.skip > 0 {
            rule.skip -= 1;
            continue;
        }
        if rule.remaining == 0 {
            continue;
        }
        let fires = match &mut rule.rate {
            None => true,
            Some((rate, rng)) => rng.next_f64() < *rate,
        };
        if fires {
            rule.remaining -= 1;
            return Some(rule.kind);
        }
    }
    None
}

/// Injection point: called by instrumented sites on every hit. Returns
/// `Ok(())` when inert, the site's typed error for [`FaultKind::Error`],
/// and panics for the panic kinds.
pub(crate) fn check(site: FaultSite) -> Result<(), ClusterError> {
    match fire(site) {
        None => Ok(()),
        Some(kind) => {
            crate::telemetry::metrics().fault_injections.inc();
            match kind {
                FaultKind::Error => Err(injected_error(site)),
                FaultKind::Panic => panic!("injected fault: panic at {site:?}"),
                FaultKind::KillWorker => std::panic::panic_any(WorkerKilled),
            }
        }
    }
}

/// The typed error each site surfaces for [`FaultKind::Error`], shaped
/// like the real failure at that site so retry classification matches.
fn injected_error(site: FaultSite) -> ClusterError {
    match site {
        FaultSite::ChunkRead => ClusterError::Data {
            source: "fault-injection".to_string(),
            reason: "injected chunk-read failure".to_string(),
        },
        FaultSite::PjrtOpen => ClusterError::Engine {
            engine: "pjrt",
            reason: "injected runtime-load failure".to_string(),
        },
        FaultSite::SolverIteration => {
            ClusterError::Internal("injected solver-iteration failure".to_string())
        }
        FaultSite::CheckpointWrite => ClusterError::Snapshot {
            path: "fault-injection".to_string(),
            reason: "injected checkpoint-write failure".to_string(),
        },
        FaultSite::RegistryWrite => ClusterError::Snapshot {
            path: "fault-injection".to_string(),
            reason: "injected registry-write failure".to_string(),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counted_rules_fire_exactly_on_schedule() {
        let guard = FaultPlan::new()
            .fail_after(FaultSite::ChunkRead, FaultKind::Error, 2, 1)
            .install_for_current_thread();
        assert_eq!(fire(FaultSite::PjrtOpen), None, "other sites stay inert");
        assert_eq!(fire(FaultSite::ChunkRead), None);
        assert_eq!(fire(FaultSite::ChunkRead), None);
        assert_eq!(fire(FaultSite::ChunkRead), Some(FaultKind::Error));
        assert_eq!(fire(FaultSite::ChunkRead), None, "budget consumed");
        drop(guard);
        assert_eq!(fire(FaultSite::ChunkRead), None, "disarmed after drop");
    }

    #[test]
    fn rate_rules_replay_identically_for_a_seed() {
        let schedule = |seed: u64| -> Vec<bool> {
            let _guard = FaultPlan::new()
                .fail_with_rate(FaultSite::SolverIteration, FaultKind::Error, 0.3, seed, u64::MAX)
                .install_for_current_thread();
            (0..64).map(|_| fire(FaultSite::SolverIteration).is_some()).collect()
        };
        let a = schedule(7);
        let b = schedule(7);
        assert_eq!(a, b, "same seed, same fault schedule");
        assert!(a.iter().any(|&f| f) && a.iter().any(|&f| !f), "rate is neither 0 nor 1");
        assert_ne!(a, schedule(8), "different seed, different schedule");
    }

    #[test]
    fn error_kind_surfaces_the_site_typed() {
        let _guard = FaultPlan::new()
            .fail_next(FaultSite::ChunkRead, FaultKind::Error, 1)
            .install_for_current_thread();
        let err = check(FaultSite::ChunkRead).unwrap_err();
        assert!(matches!(err, ClusterError::Data { .. }));
        assert!(check(FaultSite::ChunkRead).is_ok());
    }

    #[test]
    fn thread_scoped_plans_are_inert_elsewhere() {
        let _guard = FaultPlan::new()
            .fail_next(FaultSite::ChunkRead, FaultKind::Error, 1)
            .install_for_current_thread();
        let stolen = std::thread::spawn(|| fire(FaultSite::ChunkRead).is_some())
            .join()
            .expect("probe thread must not panic");
        assert!(!stolen, "another thread cannot consume a thread-scoped fault");
        assert_eq!(
            fire(FaultSite::ChunkRead),
            Some(FaultKind::Error),
            "the schedule is intact for the installer"
        );
    }
}
