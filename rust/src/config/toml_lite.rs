//! A small TOML-subset parser: sections, scalar values, flat arrays,
//! comments. Error messages carry line numbers.

use std::collections::BTreeMap;
use std::fmt;

/// Parse/typing error.
#[derive(Debug, Clone)]
pub struct ConfigError {
    msg: String,
}

impl ConfigError {
    pub fn new(msg: impl Into<String>) -> Self {
        Self { msg: msg.into() }
    }
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config error: {}", self.msg)
    }
}

impl std::error::Error for ConfigError {}

/// A parsed scalar or flat array.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    /// String view (strings only).
    pub fn as_str(&self) -> Result<&str, ConfigError> {
        match self {
            Value::Str(s) => Ok(s),
            other => Err(ConfigError::new(format!("expected string, got {other:?}"))),
        }
    }

    /// Integer view (ints only).
    pub fn as_int(&self) -> Result<i64, ConfigError> {
        match self {
            Value::Int(v) => Ok(*v),
            other => Err(ConfigError::new(format!("expected integer, got {other:?}"))),
        }
    }

    /// Float view (accepts ints too).
    pub fn as_float(&self) -> Result<f64, ConfigError> {
        match self {
            Value::Float(v) => Ok(*v),
            Value::Int(v) => Ok(*v as f64),
            other => Err(ConfigError::new(format!("expected float, got {other:?}"))),
        }
    }

    /// Bool view.
    pub fn as_bool(&self) -> Result<bool, ConfigError> {
        match self {
            Value::Bool(v) => Ok(*v),
            other => Err(ConfigError::new(format!("expected bool, got {other:?}"))),
        }
    }

    /// Array view.
    pub fn as_array(&self) -> Result<&[Value], ConfigError> {
        match self {
            Value::Array(v) => Ok(v),
            other => Err(ConfigError::new(format!("expected array, got {other:?}"))),
        }
    }
}

/// A parsed document: `(section, key) → value`. Root-level keys use the
/// empty-string section.
#[derive(Debug, Clone, Default)]
pub struct ConfigDoc {
    entries: BTreeMap<(String, String), Value>,
}

impl ConfigDoc {
    /// Parse a document.
    pub fn parse(text: &str) -> Result<Self, ConfigError> {
        let mut doc = ConfigDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| ConfigError::new(format!("line {}: unterminated section", lineno + 1)))?
                    .trim();
                section = name.to_string();
                continue;
            }
            let (key, value) = line.split_once('=').ok_or_else(|| {
                ConfigError::new(format!("line {}: expected key = value", lineno + 1))
            })?;
            let key = key.trim();
            if key.is_empty() {
                return Err(ConfigError::new(format!("line {}: empty key", lineno + 1)));
            }
            let value = parse_value(value.trim())
                .map_err(|e| ConfigError::new(format!("line {}: {}", lineno + 1, e.msg)))?;
            doc.entries.insert((section.clone(), key.to_string()), value);
        }
        Ok(doc)
    }

    /// Parse a file.
    pub fn parse_file(path: &std::path::Path) -> Result<Self, ConfigError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| ConfigError::new(format!("read {}: {e}", path.display())))?;
        Self::parse(&text)
    }

    /// Look up `key` in `section` ("" for root).
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.entries.get(&(section.to_string(), key.to_string()))
    }

    /// All `(section, key)` pairs (sorted).
    pub fn keys(&self) -> impl Iterator<Item = (&str, &str)> {
        self.entries.keys().map(|(s, k)| (s.as_str(), k.as_str()))
    }

    /// Insert / override a value (CLI `--set section.key=value` support).
    pub fn set(&mut self, section: &str, key: &str, value: Value) {
        self.entries.insert((section.to_string(), key.to_string()), value);
    }
}

/// Strip a `#` comment, respecting quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str) -> Result<Value, ConfigError> {
    if text.is_empty() {
        return Err(ConfigError::new("empty value"));
    }
    if let Some(rest) = text.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| ConfigError::new("unterminated string"))?;
        if inner.contains('"') {
            return Err(ConfigError::new("embedded quote in string"));
        }
        return Ok(Value::Str(inner.to_string()));
    }
    if let Some(rest) = text.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| ConfigError::new("unterminated array"))?
            .trim();
        if inner.is_empty() {
            return Ok(Value::Array(Vec::new()));
        }
        let items: Result<Vec<Value>, _> =
            split_array_items(inner).iter().map(|s| parse_value(s.trim())).collect();
        return Ok(Value::Array(items?));
    }
    match text {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(v) = text.parse::<i64>() {
        return Ok(Value::Int(v));
    }
    if let Ok(v) = text.parse::<f64>() {
        return Ok(Value::Float(v));
    }
    Err(ConfigError::new(format!("cannot parse value '{text}'")))
}

/// Split array items on commas outside quotes.
fn split_array_items(inner: &str) -> Vec<String> {
    let mut items = Vec::new();
    let mut current = String::new();
    let mut in_str = false;
    for c in inner.chars() {
        match c {
            '"' => {
                in_str = !in_str;
                current.push(c);
            }
            ',' if !in_str => {
                items.push(std::mem::take(&mut current));
            }
            _ => current.push(c),
        }
    }
    if !current.trim().is_empty() {
        items.push(current);
    }
    items
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let doc = ConfigDoc::parse(
            "top = 1\n[alpha]\nname = \"hello\"  # trailing comment\nratio = 0.5\nflag = true\n",
        )
        .unwrap();
        assert_eq!(doc.get("", "top").unwrap().as_int().unwrap(), 1);
        assert_eq!(doc.get("alpha", "name").unwrap().as_str().unwrap(), "hello");
        assert_eq!(doc.get("alpha", "ratio").unwrap().as_float().unwrap(), 0.5);
        assert!(doc.get("alpha", "flag").unwrap().as_bool().unwrap());
    }

    #[test]
    fn parses_arrays() {
        let doc = ConfigDoc::parse("ks = [10, 100, 1000]\nnames = [\"a\", \"b,c\"]\n").unwrap();
        let ks = doc.get("", "ks").unwrap().as_array().unwrap();
        assert_eq!(ks.len(), 3);
        assert_eq!(ks[2].as_int().unwrap(), 1000);
        let names = doc.get("", "names").unwrap().as_array().unwrap();
        assert_eq!(names[1].as_str().unwrap(), "b,c");
    }

    #[test]
    fn comment_inside_string_preserved() {
        let doc = ConfigDoc::parse("path = \"/tmp/#not-a-comment\"\n").unwrap();
        assert_eq!(doc.get("", "path").unwrap().as_str().unwrap(), "/tmp/#not-a-comment");
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = ConfigDoc::parse("good = 1\nbad_line\n").unwrap_err();
        assert!(err.to_string().contains("line 2"), "{err}");
    }

    #[test]
    fn unterminated_constructs_error() {
        assert!(ConfigDoc::parse("[open\n").is_err());
        assert!(ConfigDoc::parse("s = \"oops\n").is_err());
        assert!(ConfigDoc::parse("a = [1, 2\n").is_err());
    }

    #[test]
    fn int_float_coercion() {
        let doc = ConfigDoc::parse("x = 3\n").unwrap();
        assert_eq!(doc.get("", "x").unwrap().as_float().unwrap(), 3.0);
        assert!(doc.get("", "x").unwrap().as_str().is_err());
    }

    #[test]
    fn set_overrides() {
        let mut doc = ConfigDoc::parse("k = 10\n").unwrap();
        doc.set("", "k", Value::Int(99));
        assert_eq!(doc.get("", "k").unwrap().as_int().unwrap(), 99);
    }

    #[test]
    fn empty_array_ok() {
        let doc = ConfigDoc::parse("xs = []\n").unwrap();
        assert!(doc.get("", "xs").unwrap().as_array().unwrap().is_empty());
    }
}
