//! Configuration substrate: a minimal TOML-subset parser (no `serde`/`toml`
//! offline) plus the typed experiment configuration used by the CLI, the
//! coordinator and the bench harnesses.
//!
//! Supported syntax: `[section]` headers, `key = value` pairs where value is
//! a quoted string, integer, float, bool, or a flat array of those; `#`
//! comments. This covers every config file the repo ships.

mod toml_lite;

pub use toml_lite::{ConfigDoc, ConfigError, Value};

/// Storage precision of the assignment kernel (defined next to the kernel
/// in [`crate::linalg::kernel`]; re-exported here as the config surface).
pub use crate::linalg::kernel::Precision;

/// Mini-batch epoch sampling mode (defined next to the streaming solver
/// in [`crate::stream`]; re-exported here as the config surface).
pub use crate::stream::BatchSampling;

/// Mini-batch energy-checkpoint mode (defined next to the streaming solver
/// in [`crate::stream`]; re-exported here as the config surface).
pub use crate::stream::EnergyGuard;

use crate::init::InitMethod;

/// Which assignment engine backs the solver.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// O(NK) direct distances.
    Naive,
    /// Hamerly 2010 bounds (paper's baseline assignment).
    Hamerly,
    /// Elkan 2003 triangle-inequality bounds.
    Elkan,
    /// Yinyang group bounds (Ding et al. 2015) — best at large K.
    Yinyang,
    /// PJRT-executed AOT G-step (the three-layer hot path).
    Pjrt,
    /// Streaming mini-batch solver (Sculley 2010 with epoch-level Anderson
    /// acceleration): data flows through the SIMD assign kernels one chunk
    /// at a time, so datasets larger than RAM cluster in bounded memory.
    /// Selecting this routes a session to [`crate::stream::MiniBatchSolver`]
    /// instead of the full-batch loop.
    MiniBatch,
}

impl EngineKind {
    /// Parse from a config / CLI string.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "naive" => Some(Self::Naive),
            "hamerly" => Some(Self::Hamerly),
            "elkan" => Some(Self::Elkan),
            "yinyang" => Some(Self::Yinyang),
            "pjrt" => Some(Self::Pjrt),
            "minibatch" | "mini-batch" => Some(Self::MiniBatch),
            _ => None,
        }
    }

    /// Canonical name.
    pub fn name(&self) -> &'static str {
        match self {
            Self::Naive => "naive",
            Self::Hamerly => "hamerly",
            Self::Elkan => "elkan",
            Self::Yinyang => "yinyang",
            Self::Pjrt => "pjrt",
            Self::MiniBatch => "minibatch",
        }
    }
}

/// Acceleration mode of the solver.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Acceleration {
    /// Plain Lloyd's algorithm (baseline).
    None,
    /// Anderson acceleration with a fixed window `m`.
    FixedM(usize),
    /// Anderson acceleration with the paper's dynamic-m controller.
    DynamicM(usize),
}

impl Acceleration {
    /// Canonical text form — the inverse of [`parse_accel`], used by
    /// checkpoint fingerprints and the coordinator journal.
    pub fn label(&self) -> String {
        match self {
            Self::None => "none".to_string(),
            Self::FixedM(m) => format!("fixed:{m}"),
            Self::DynamicM(m) => format!("dynamic:{m}"),
        }
    }
}

/// Solver-level configuration (what [`crate::kmeans::Solver`] needs; the
/// dataset/seeding fields live in [`ExperimentConfig`]).
#[derive(Debug, Clone)]
pub struct SolverConfig {
    /// Acceleration mode (paper's method = `DynamicM(2)`).
    pub accel: Acceleration,
    /// Assignment engine.
    pub engine: EngineKind,
    /// ε₁ from Algorithm 1, paper default 0.02.
    pub epsilon1: f64,
    /// ε₂ from Algorithm 1, paper default 0.5.
    pub epsilon2: f64,
    /// m̄ history cap, paper default 30.
    pub m_max: usize,
    /// Iteration safety cap.
    pub max_iters: usize,
    /// Optional wall-clock budget; the run stops at the first iteration
    /// boundary past it and reports `stopped_early` (never mid-iteration).
    pub time_limit: Option<std::time::Duration>,
    /// Worker threads (0 = host-sized).
    pub threads: usize,
    /// Record per-iteration energy / m traces (small overhead).
    pub record_trace: bool,
    /// Assignment-kernel sample storage precision. `F32` halves the assign
    /// sweep's memory traffic and doubles its FMA lanes; centroids, bounds
    /// and energies stay `f64`. Pair with [`crate::data::center`] — see the
    /// accuracy notes in [`crate::linalg::kernel`].
    pub precision: Precision,
    /// Durable-snapshot policy: `Some` makes the solver write crash-safe
    /// `AAKMCK01` checkpoints into the policy's directory and resume from
    /// an existing matching snapshot found there (see [`crate::persist`]).
    pub checkpoint: Option<crate::persist::CheckpointPolicy>,
    /// Opt-in empty-cluster recovery: when a centroid loses all samples,
    /// re-seed it deterministically by splitting the highest-energy
    /// cluster (see [`crate::lloyd::reseed_empty_clusters`]). Off by
    /// default — the classical behavior keeps empty centroids in place.
    pub reseed_empty: bool,
    /// Run identity: seeds the re-seed RNG stream and is baked into the
    /// checkpoint fingerprint so a snapshot from a differently-seeded run
    /// is rejected instead of silently resumed.
    pub seed: u64,
}

impl Default for SolverConfig {
    fn default() -> Self {
        Self {
            accel: Acceleration::DynamicM(2),
            engine: EngineKind::Hamerly,
            epsilon1: 0.02,
            epsilon2: 0.5,
            m_max: 30,
            max_iters: 5000,
            time_limit: None,
            threads: 0,
            record_trace: false,
            precision: Precision::F64,
            checkpoint: None,
            reseed_empty: false,
            seed: 42,
        }
    }
}

/// A full experiment description (one solver run on one dataset).
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// Registry dataset name or a path to a CSV/fvecs file.
    pub dataset: String,
    /// Number of clusters.
    pub k: usize,
    /// Seeding method.
    pub init: InitMethod,
    /// Assignment engine.
    pub engine: EngineKind,
    /// Acceleration mode.
    pub accel: Acceleration,
    /// ε₁ from Algorithm 1 (shrink threshold), paper default 0.02.
    pub epsilon1: f64,
    /// ε₂ from Algorithm 1 (grow threshold), paper default 0.5.
    pub epsilon2: f64,
    /// m̄, the history cap, paper default 30.
    pub m_max: usize,
    /// Iteration safety cap (the paper runs to convergence; this guards CI).
    pub max_iters: usize,
    /// RNG seed for data generation and seeding.
    pub seed: u64,
    /// Fraction of the paper's N to generate (1.0 = full size).
    pub scale: f64,
    /// Worker threads for the assignment step (0 = host-sized).
    pub threads: usize,
    /// Assignment-kernel sample storage precision (`f64` default; `f32`
    /// trades ~1e-7-relative distance accuracy for 2× sweep bandwidth).
    pub precision: Precision,
    /// Samples per mini-batch chunk (`--engine minibatch` only).
    pub chunk_size: usize,
    /// Mini-batches per epoch; 0 = one full pass over the source.
    pub batches_per_epoch: usize,
    /// How mini-batch epochs draw their batches (`--engine minibatch`
    /// only): the deterministic sequential pass, or uniform draws with
    /// replacement.
    pub sampling: BatchSampling,
    /// Overlap chunk reads with the sweep via the background prefetcher
    /// (`--engine minibatch` only). Trajectory-neutral: the epoch math is
    /// bit-identical with it on or off.
    pub prefetch: bool,
    /// Energy-checkpoint mode for mini-batch epochs: the exact full pass,
    /// or a fixed reservoir sample of rows (`sampled:N`).
    pub guard: EnergyGuard,
    /// Pin worker lanes (and the prefetcher) to distinct CPUs on Linux;
    /// a no-op elsewhere.
    pub pin_threads: bool,
    /// Directory for durable `AAKMCK01` snapshots (`None` = no
    /// checkpointing). A run started with an existing matching snapshot
    /// in this directory resumes from it.
    pub checkpoint_dir: Option<String>,
    /// Snapshot cadence in iterations/epochs (used when `checkpoint_dir`
    /// is set).
    pub checkpoint_every: usize,
    /// Opt-in deterministic empty-cluster re-seeding (split the
    /// highest-energy cluster).
    pub reseed_empty: bool,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        Self {
            dataset: "Birch".to_string(),
            k: 10,
            init: InitMethod::KMeansPlusPlus,
            engine: EngineKind::Hamerly,
            accel: Acceleration::DynamicM(2),
            epsilon1: 0.02,
            epsilon2: 0.5,
            m_max: 30,
            max_iters: 5000,
            seed: 42,
            scale: 1.0,
            threads: 0,
            precision: Precision::F64,
            chunk_size: 4096,
            batches_per_epoch: 0,
            sampling: BatchSampling::Sequential,
            prefetch: false,
            guard: EnergyGuard::Exact,
            pin_threads: false,
            checkpoint_dir: None,
            checkpoint_every: 1,
            reseed_empty: false,
        }
    }
}

impl ExperimentConfig {
    /// Read from a parsed TOML-lite document; missing keys keep defaults.
    /// Recognized keys live in the `[experiment]` section (or the root).
    pub fn from_doc(doc: &ConfigDoc) -> Result<Self, ConfigError> {
        let mut cfg = Self::default();
        let sect = |key: &str| {
            doc.get("experiment", key).or_else(|| doc.get("", key))
        };
        if let Some(v) = sect("dataset") {
            cfg.dataset = v.as_str()?.to_string();
        }
        if let Some(v) = sect("k") {
            cfg.k = v.as_int()? as usize;
        }
        if let Some(v) = sect("init") {
            let s = v.as_str()?;
            cfg.init = InitMethod::parse(s)
                .ok_or_else(|| ConfigError::new(format!("unknown init method '{s}'")))?;
        }
        if let Some(v) = sect("engine") {
            let s = v.as_str()?;
            cfg.engine = EngineKind::parse(s)
                .ok_or_else(|| ConfigError::new(format!("unknown engine '{s}'")))?;
        }
        if let Some(v) = sect("accel") {
            cfg.accel = parse_accel(v.as_str()?)
                .ok_or_else(|| ConfigError::new("bad accel (none|fixed:M|dynamic:M)"))?;
        }
        if let Some(v) = sect("epsilon1") {
            cfg.epsilon1 = v.as_float()?;
        }
        if let Some(v) = sect("epsilon2") {
            cfg.epsilon2 = v.as_float()?;
        }
        if let Some(v) = sect("m_max") {
            cfg.m_max = v.as_int()? as usize;
        }
        if let Some(v) = sect("max_iters") {
            cfg.max_iters = v.as_int()? as usize;
        }
        if let Some(v) = sect("seed") {
            cfg.seed = v.as_int()? as u64;
        }
        if let Some(v) = sect("scale") {
            cfg.scale = v.as_float()?;
        }
        if let Some(v) = sect("threads") {
            cfg.threads = v.as_int()? as usize;
        }
        if let Some(v) = sect("precision") {
            let s = v.as_str()?;
            cfg.precision = Precision::parse(s)
                .ok_or_else(|| ConfigError::new(format!("unknown precision '{s}' (f64|f32)")))?;
        }
        if let Some(v) = sect("chunk_size") {
            cfg.chunk_size = v.as_int()? as usize;
        }
        if let Some(v) = sect("batches_per_epoch") {
            cfg.batches_per_epoch = v.as_int()? as usize;
        }
        if let Some(v) = sect("sampling") {
            let s = v.as_str()?;
            cfg.sampling = BatchSampling::parse(s).ok_or_else(|| {
                ConfigError::new(format!("unknown sampling '{s}' (sequential|replacement)"))
            })?;
        }
        if let Some(v) = sect("prefetch") {
            cfg.prefetch = v.as_bool()?;
        }
        if let Some(v) = sect("guard") {
            let s = v.as_str()?;
            cfg.guard = EnergyGuard::parse(s).ok_or_else(|| {
                ConfigError::new(format!("unknown guard '{s}' (exact|sampled:N)"))
            })?;
        }
        if let Some(v) = sect("pin_threads") {
            cfg.pin_threads = v.as_bool()?;
        }
        if let Some(v) = sect("checkpoint_dir") {
            cfg.checkpoint_dir = Some(v.as_str()?.to_string());
        }
        if let Some(v) = sect("checkpoint_every") {
            cfg.checkpoint_every = v.as_int()? as usize;
        }
        if let Some(v) = sect("reseed_empty") {
            cfg.reseed_empty = v.as_bool()?;
        }
        Ok(cfg)
    }
}

impl ExperimentConfig {
    /// Project the solver-level part of this experiment.
    pub fn solver_config(&self) -> SolverConfig {
        SolverConfig {
            accel: self.accel,
            engine: self.engine,
            epsilon1: self.epsilon1,
            epsilon2: self.epsilon2,
            m_max: self.m_max,
            max_iters: self.max_iters,
            time_limit: None,
            threads: self.threads,
            record_trace: false,
            precision: self.precision,
            checkpoint: self.checkpoint_policy(),
            reseed_empty: self.reseed_empty,
            seed: self.seed,
        }
    }

    /// The durable-snapshot policy this experiment asked for, if any.
    pub fn checkpoint_policy(&self) -> Option<crate::persist::CheckpointPolicy> {
        self.checkpoint_dir
            .as_ref()
            .map(|dir| crate::persist::CheckpointPolicy::new(dir, self.checkpoint_every.max(1)))
    }
}

/// Parse an acceleration spec: `none`, `fixed:M`, `dynamic:M`.
pub fn parse_accel(s: &str) -> Option<Acceleration> {
    let s = s.to_ascii_lowercase();
    if s == "none" || s == "lloyd" {
        return Some(Acceleration::None);
    }
    let (kind, m) = s.split_once(':')?;
    let m: usize = m.parse().ok()?;
    match kind {
        "fixed" => Some(Acceleration::FixedM(m)),
        "dynamic" => Some(Acceleration::DynamicM(m)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn experiment_from_doc_full() {
        let text = r#"
            [experiment]
            dataset = "HTRU2"
            k = 100
            init = "clarans"
            engine = "elkan"
            accel = "dynamic:5"
            epsilon1 = 0.01
            epsilon2 = 0.6
            m_max = 20
            max_iters = 123
            seed = 7
            scale = 0.25
            threads = 2
        "#;
        let doc = ConfigDoc::parse(text).unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.dataset, "HTRU2");
        assert_eq!(cfg.k, 100);
        assert_eq!(cfg.init, InitMethod::Clarans);
        assert_eq!(cfg.engine, EngineKind::Elkan);
        assert_eq!(cfg.accel, Acceleration::DynamicM(5));
        assert_eq!(cfg.epsilon1, 0.01);
        assert_eq!(cfg.m_max, 20);
        assert_eq!(cfg.max_iters, 123);
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.scale, 0.25);
        assert_eq!(cfg.threads, 2);
    }

    #[test]
    fn experiment_defaults_on_empty_doc() {
        let doc = ConfigDoc::parse("").unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.k, 10);
        assert_eq!(cfg.epsilon1, 0.02);
        assert_eq!(cfg.epsilon2, 0.5);
        assert_eq!(cfg.m_max, 30);
        assert_eq!(cfg.accel, Acceleration::DynamicM(2));
        assert_eq!(cfg.precision, Precision::F64);
    }

    #[test]
    fn sampling_from_doc() {
        let doc = ConfigDoc::parse("sampling = \"replacement\"").unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.sampling, BatchSampling::Replacement);
        let empty = ConfigDoc::parse("").unwrap();
        let cfg = ExperimentConfig::from_doc(&empty).unwrap();
        assert_eq!(cfg.sampling, BatchSampling::Sequential);
        let bad = ConfigDoc::parse("sampling = \"shuffled\"").unwrap();
        assert!(ExperimentConfig::from_doc(&bad).is_err());
    }

    #[test]
    fn streaming_knobs_from_doc() {
        let text = r#"
            prefetch = true
            guard = "sampled:4096"
            pin_threads = true
        "#;
        let doc = ConfigDoc::parse(text).unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert!(cfg.prefetch);
        assert_eq!(cfg.guard, EnergyGuard::Sampled { rows: 4096 });
        assert!(cfg.pin_threads);
        let empty = ConfigDoc::parse("").unwrap();
        let cfg = ExperimentConfig::from_doc(&empty).unwrap();
        assert!(!cfg.prefetch);
        assert_eq!(cfg.guard, EnergyGuard::Exact);
        assert!(!cfg.pin_threads);
        let bad = ConfigDoc::parse("guard = \"approx\"").unwrap();
        assert!(ExperimentConfig::from_doc(&bad).is_err());
    }

    #[test]
    fn precision_from_doc_and_projection() {
        let doc = ConfigDoc::parse("precision = \"f32\"").unwrap();
        let cfg = ExperimentConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.precision, Precision::F32);
        assert_eq!(cfg.solver_config().precision, Precision::F32);
        let bad = ConfigDoc::parse("precision = \"f16\"").unwrap();
        assert!(ExperimentConfig::from_doc(&bad).is_err());
    }

    #[test]
    fn parse_accel_variants() {
        assert_eq!(parse_accel("none"), Some(Acceleration::None));
        assert_eq!(parse_accel("fixed:2"), Some(Acceleration::FixedM(2)));
        assert_eq!(parse_accel("dynamic:5"), Some(Acceleration::DynamicM(5)));
        assert_eq!(parse_accel("what:3"), None);
        assert_eq!(parse_accel("fixed:x"), None);
    }

    #[test]
    fn engine_kind_roundtrip() {
        for kind in [
            EngineKind::Naive,
            EngineKind::Hamerly,
            EngineKind::Elkan,
            EngineKind::Yinyang,
            EngineKind::Pjrt,
            EngineKind::MiniBatch,
        ] {
            assert_eq!(EngineKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(EngineKind::parse("mini-batch"), Some(EngineKind::MiniBatch));
        assert_eq!(EngineKind::parse("gpu"), None);
    }

    #[test]
    fn bad_init_is_error() {
        let doc = ConfigDoc::parse("init = \"quantum\"").unwrap();
        assert!(ExperimentConfig::from_doc(&doc).is_err());
    }
}
