//! Anderson acceleration — generic over fixed-point maps.
//!
//! This module packages the paper's two algorithmic ingredients in reusable
//! form (the paper's conclusion explicitly points at "other problems" with
//! Lloyd-like structure):
//!
//! * [`AndersonAccelerator`] — the stabilized AA step of Peng et al. 2018:
//!   feed the map output `G^t` and residual `F^t = G^t − C^t` each
//!   iteration, get the extrapolated next iterate (Eq. 7–8). The caller
//!   applies the energy-decrease guard and reverts to the plain iterate when
//!   the extrapolation fails (Algorithm 1 lines 13–15).
//! * [`MController`] — the paper's dynamic-`m` trust-region-style rule
//!   (Algorithm 1 lines 8–12, §2.2).
//!
//! [`accelerated_fixed_point`] glues both onto an arbitrary map + energy
//! function through the shared safeguarded loop in [`crate::accel`]; the
//! K-Means solver in [`crate::kmeans`] drives the same
//! [`crate::accel::FixedPointDriver`] with engine-aware assignment reuse.

use crate::linalg::AndersonLsWorkspace;

/// Dynamic adjustment of the AA window `m` (paper §2.2).
///
/// After each iterate, feed the energy-decrease ratio
/// `r = (E^{t-1} − E^t) / (E^{t-2} − E^{t-1})`:
/// `r < ε₁` shrinks `m`, `r > ε₂` grows it (clamped to `[0, m_max]`).
#[derive(Debug, Clone)]
pub struct MController {
    m: usize,
    m_max: usize,
    epsilon1: f64,
    epsilon2: f64,
}

impl MController {
    /// Paper defaults: ε₁ = 0.02, ε₂ = 0.5, m̄ = 30.
    pub fn new(m0: usize, m_max: usize, epsilon1: f64, epsilon2: f64) -> Self {
        assert!(epsilon1 <= epsilon2, "ε₁ must not exceed ε₂");
        Self { m: m0.min(m_max), m_max, epsilon1, epsilon2 }
    }

    /// Current window size.
    pub fn m(&self) -> usize {
        self.m
    }

    /// History cap m̄.
    pub fn m_max(&self) -> usize {
        self.m_max
    }

    /// Shrink threshold ε₁.
    pub fn epsilon1(&self) -> f64 {
        self.epsilon1
    }

    /// Grow threshold ε₂.
    pub fn epsilon2(&self) -> f64 {
        self.epsilon2
    }

    /// Restore the window size from a checkpoint (clamped to m̄) so a
    /// resumed run continues with the exact trust-region state the
    /// snapshot captured.
    pub fn set_m(&mut self, m: usize) {
        self.m = m.min(self.m_max);
    }

    /// Apply Algorithm 1 lines 8–12 given the last two energy decreases.
    /// Non-finite or non-positive denominators (start-up, plateau) leave
    /// `m` unchanged.
    pub fn adjust(&mut self, decrease_now: f64, decrease_prev: f64) {
        if !decrease_prev.is_finite() || decrease_prev <= 0.0 || !decrease_now.is_finite() {
            return;
        }
        let ratio = decrease_now / decrease_prev;
        if ratio < self.epsilon1 {
            self.m = self.m.saturating_sub(1);
        } else if ratio > self.epsilon2 {
            self.m = (self.m + 1).min(self.m_max);
        }
    }
}

/// Stabilized Anderson accelerator over flattened iterates.
///
/// Call [`AndersonAccelerator::propose`] once per iteration with the plain
/// fixed-point output `g_t` and residual `f_t`; it returns the accelerated
/// candidate (equal to `g_t` when no history or `m_use == 0`). The caller
/// decides acceptance and never needs to tell the accelerator — history is
/// built from the `(g_t, f_t)` stream regardless, exactly as Algorithm 1
/// pushes every `(G^t, F^t)` pair.
#[derive(Debug, Clone)]
pub struct AndersonAccelerator {
    ws: AndersonLsWorkspace,
    prev_f: Option<Vec<f64>>,
    prev_g: Option<Vec<f64>>,
    /// Buffers recycled from evicted history columns — once the window is
    /// full, pushing a new difference pair allocates nothing.
    free_cols: Vec<Vec<f64>>,
    /// Scratch for θ* between the solve and the extrapolation.
    theta: Vec<f64>,
    /// Count of propose() calls that actually extrapolated.
    accelerated_steps: u64,
}

impl AndersonAccelerator {
    /// Accelerator for residuals of dimension `dim` keeping up to `m_max`
    /// difference columns.
    pub fn new(m_max: usize, dim: usize) -> Self {
        Self {
            ws: AndersonLsWorkspace::new(m_max.max(1), dim),
            prev_f: None,
            prev_g: None,
            free_cols: Vec::new(),
            theta: Vec::new(),
            accelerated_steps: 0,
        }
    }

    /// Feed this iteration's `(g_t, f_t)` and get the next iterate proposal
    /// using at most `m_use` history columns.
    pub fn propose(&mut self, g_t: &[f64], f_t: &[f64], m_use: usize) -> Vec<f64> {
        let mut out = vec![0.0; g_t.len()];
        self.propose_into(g_t, f_t, m_use, &mut out);
        out
    }

    /// Allocation-free variant of [`AndersonAccelerator::propose`]: writes
    /// the proposal into `out` (length `dim`) and returns whether the
    /// proposal differs from the plain iterate `g_t` (i.e. whether the
    /// caller is looking at an accelerated candidate). At steady state —
    /// full history window, well-conditioned normal equations — this
    /// performs no heap allocation: difference columns are recycled from
    /// evicted history entries and the previous `(f, g)` snapshots are
    /// overwritten in place.
    pub fn propose_into(&mut self, g_t: &[f64], f_t: &[f64], m_use: usize, out: &mut [f64]) -> bool {
        let dim = self.ws.dim();
        debug_assert_eq!(g_t.len(), dim);
        debug_assert_eq!(f_t.len(), dim);
        debug_assert_eq!(out.len(), dim);
        if let (Some(pf), Some(pg)) = (&self.prev_f, &self.prev_g) {
            let mut df = self.free_cols.pop().unwrap_or_else(|| vec![0.0; dim]);
            let mut dg = self.free_cols.pop().unwrap_or_else(|| vec![0.0; dim]);
            crate::linalg::sub(f_t, pf, &mut df);
            crate::linalg::sub(g_t, pg, &mut dg);
            if let Some((ef, eg)) = self.ws.push(df, dg) {
                self.free_cols.push(ef);
                self.free_cols.push(eg);
            }
        }
        // The first snapshots after construction / reset draw from the
        // recycled-column pool too, so a reset accelerator starts its next
        // run without touching the allocator.
        match &mut self.prev_f {
            Some(pf) => pf.copy_from_slice(f_t),
            None => {
                let mut buf = self.free_cols.pop().unwrap_or_else(|| vec![0.0; dim]);
                buf.copy_from_slice(f_t);
                self.prev_f = Some(buf);
            }
        }
        match &mut self.prev_g {
            Some(pg) => pg.copy_from_slice(g_t),
            None => {
                let mut buf = self.free_cols.pop().unwrap_or_else(|| vec![0.0; dim]);
                buf.copy_from_slice(g_t);
                self.prev_g = Some(buf);
            }
        }
        if m_use == 0 || self.ws.is_empty() {
            out.copy_from_slice(g_t);
            return false;
        }
        if self.ws.solve_into(f_t, m_use, &mut self.theta) {
            self.accelerated_steps += 1;
            self.ws.accelerate_into(g_t, &self.theta, out);
            out != g_t
        } else {
            out.copy_from_slice(g_t);
            false
        }
    }

    /// Number of proposals that used extrapolation (vs pass-through).
    pub fn accelerated_steps(&self) -> u64 {
        self.accelerated_steps
    }

    /// Export the accelerator's history for a durable snapshot: the
    /// previous `(f, g)` pair plus the ΔF/ΔG columns oldest-first (the
    /// replay order [`AndersonAccelerator::restore`] needs).
    pub fn snapshot(&self) -> crate::persist::AndersonSnap {
        crate::persist::AndersonSnap {
            prev: match (&self.prev_f, &self.prev_g) {
                (Some(f), Some(g)) => Some((f.clone(), g.clone())),
                _ => None,
            },
            cols: self
                .ws
                .history_oldest_first()
                .map(|(f, g)| (f.to_vec(), g.to_vec()))
                .collect(),
            accelerated_steps: self.accelerated_steps,
        }
    }

    /// Rebuild the history from a snapshot by replaying the same
    /// incremental `push` sequence the original run made — the cached
    /// Gram matrix comes out bit-identical to the uninterrupted run's,
    /// so every subsequent proposal matches it exactly. The snapshot's
    /// columns must have this accelerator's dimension (the resume path
    /// validates shapes before calling).
    pub fn restore(&mut self, snap: &crate::persist::AndersonSnap) {
        let dim = self.ws.dim();
        self.reset();
        let claim = |src: &[f64], free: &mut Vec<Vec<f64>>| -> Vec<f64> {
            assert_eq!(src.len(), dim, "snapshot column dimension mismatch");
            let mut buf = free.pop().unwrap_or_else(|| vec![0.0; dim]);
            buf.copy_from_slice(src);
            buf
        };
        for (df, dg) in &snap.cols {
            let f = claim(df, &mut self.free_cols);
            let g = claim(dg, &mut self.free_cols);
            if let Some((ef, eg)) = self.ws.push(f, g) {
                self.free_cols.push(ef);
                self.free_cols.push(eg);
            }
        }
        if let Some((pf, pg)) = &snap.prev {
            self.prev_f = Some(claim(pf, &mut self.free_cols));
            self.prev_g = Some(claim(pg, &mut self.free_cols));
        }
        self.accelerated_steps = snap.accelerated_steps;
    }

    /// Drop all history (restart). Buffers are recycled into the internal
    /// free pool, so reset-and-reuse on a same-dimension problem performs
    /// no heap allocation.
    pub fn reset(&mut self) {
        self.ws.clear_into(&mut self.free_cols);
        if let Some(pf) = self.prev_f.take() {
            self.free_cols.push(pf);
        }
        if let Some(pg) = self.prev_g.take() {
            self.free_cols.push(pg);
        }
        self.accelerated_steps = 0;
    }
}

/// Outcome of one accelerated fixed-point solve.
#[derive(Debug, Clone)]
pub struct FixedPointReport {
    /// Final iterate.
    pub solution: Vec<f64>,
    /// Energy at the solution.
    pub energy: f64,
    /// Iterations taken.
    pub iterations: usize,
    /// Iterations whose accelerated candidate was accepted.
    pub accepted: usize,
    /// Energy trace (one entry per iteration).
    pub trace: Vec<f64>,
}

/// Generic stabilized-AA driver for any fixed-point map `g` with a merit
/// function `energy` that `g` monotonically decreases (the MM property
/// Lloyd's algorithm has). Demonstrates that the paper's scheme transfers
/// beyond K-Means — and runs on the same safeguarded-Anderson loop as the
/// K-Means solvers ([`crate::accel::FixedPointDriver`], deferred guard):
/// the map is wrapped as a tiny [`crate::accel::Step`] whose iterate
/// converges when the residual `‖G(x) − x‖` drops below `tol`.
///
/// The `controller` supplies the dynamic-`m` parameters (`m`, m̄, ε₁, ε₂);
/// the driver evolves its own copy following Algorithm 1's ordering
/// (adjust from the measured energy, then guard), so the caller's value is
/// read, never mutated. In the returned report, `iterations` counts
/// completed guarded iterations (the terminal residual probe is not
/// counted) and `trace` carries exactly one committed energy per counted
/// iteration — the same accounting as [`crate::kmeans::RunReport`].
///
/// Cost note: the deferred guard measures a proposal with the *next* map
/// application, so a rejected iteration applies `g` twice (once on the
/// rejected proposal, once on the reverted plain iterate). The K-Means
/// solvers avoid this by fusing energy and update into one data pass;
/// a generic map has no such fusion to exploit.
pub fn accelerated_fixed_point(
    x0: &[f64],
    g: impl FnMut(&[f64]) -> Vec<f64>,
    energy: impl FnMut(&[f64]) -> f64,
    controller: &MController,
    max_iters: usize,
    tol: f64,
) -> FixedPointReport {
    use crate::accel::{
        Advance, Budget, DriverConfig, FixedPointDriver, GuardMode, Rejection, Step,
    };
    use crate::config::Acceleration;
    use crate::data::DataMatrix;
    use crate::metrics::{PhaseTimer, Stopwatch};
    use crate::observe::{CancelToken, NoopObserver};

    /// `x` is the current iterate (possibly an unguarded proposal), `g_x`
    /// the retained plain iterate, `g_next` the freshly applied map;
    /// `outstanding` mirrors whether `x` is an unguarded extrapolation.
    struct FnStep<G, E> {
        g: G,
        energy: E,
        x: Vec<f64>,
        g_x: Vec<f64>,
        g_next: Vec<f64>,
        f_t: Vec<f64>,
        tol: f64,
        outstanding: bool,
        shape: DataMatrix,
        phases: PhaseTimer,
    }

    impl<G: FnMut(&[f64]) -> Vec<f64>, E: FnMut(&[f64]) -> f64> Step for FnStep<G, E> {
        fn advance(&mut self) -> Advance {
            let e = (self.energy)(&self.x);
            self.g_next = (self.g)(&self.x);
            crate::linalg::sub(&self.g_next, &self.x, &mut self.f_t);
            let res: f64 = self.f_t.iter().map(|v| v * v).sum::<f64>().sqrt();
            if res < self.tol {
                if self.outstanding {
                    // An unguarded extrapolation may sit near a *worse*
                    // fixed point; fall back to the retained plain
                    // iterate and re-verify, exactly as the solvers'
                    // accelerated-convergence retry does.
                    self.x.copy_from_slice(&self.g_x);
                    self.outstanding = false;
                    return Advance::RetryPlain;
                }
                // The map barely moves this guarded iterate: commit its
                // plain image as the solution.
                self.x.copy_from_slice(&self.g_next);
                return Advance::Converged;
            }
            Advance::Evaluated(Some(e))
        }

        fn reject(&mut self) -> Rejection {
            std::mem::swap(&mut self.x, &mut self.g_x);
            self.outstanding = false;
            let e = (self.energy)(&self.x);
            self.g_next = (self.g)(&self.x);
            Rejection::Reverted(e)
        }

        fn propose(&mut self, acc: &mut AndersonAccelerator, m_use: usize) -> bool {
            std::mem::swap(&mut self.g_x, &mut self.g_next);
            crate::linalg::sub(&self.g_x, &self.x, &mut self.f_t);
            let candidate = acc.propose_into(&self.g_x, &self.f_t, m_use, &mut self.x);
            self.outstanding = candidate;
            candidate
        }

        fn discard_candidate(&mut self) {
            self.x.copy_from_slice(&self.g_x);
            self.outstanding = false;
        }

        fn observe(&self) -> (&DataMatrix, &PhaseTimer) {
            (&self.shape, &self.phases)
        }
    }

    let dim = x0.len();
    let mut acc = AndersonAccelerator::new(controller.m_max().max(1), dim);
    let mut step = FnStep {
        g,
        energy,
        x: x0.to_vec(),
        g_x: vec![0.0; dim],
        g_next: vec![0.0; dim],
        f_t: vec![0.0; dim],
        tol,
        outstanding: false,
        shape: DataMatrix::zeros(1, 1),
        phases: PhaseTimer::new(),
    };
    let sw = Stopwatch::start();
    let cancel = CancelToken::new();
    let driver = FixedPointDriver::new(
        DriverConfig {
            accel: Acceleration::DynamicM(controller.m()),
            m_max: controller.m_max(),
            epsilon1: controller.epsilon1(),
            epsilon2: controller.epsilon2(),
            max_iters,
            record_trace: true,
            trace_m: false,
            guard: GuardMode::Deferred,
            restart_after_rejects: None,
            check_at_top: false,
            checkpoint_every: 0,
        },
        Some(&mut acc),
        Budget::new(&sw, None, &cancel),
        Vec::new(),
        Vec::new(),
    );
    let outcome = driver.run(&mut step, &mut NoopObserver);
    let FnStep { mut energy, x, .. } = step;
    let e_final = energy(&x);
    FixedPointReport {
        solution: x,
        energy: e_final,
        iterations: outcome.iterations,
        accepted: outcome.accepted,
        trace: outcome.energy_trace,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn controller_follows_algorithm1_rules() {
        let mut c = MController::new(2, 30, 0.02, 0.5);
        // Large ratio grows m.
        c.adjust(1.0, 1.0); // ratio 1.0 > 0.5
        assert_eq!(c.m(), 3);
        // Tiny ratio shrinks m.
        c.adjust(0.001, 1.0); // ratio 0.001 < 0.02
        assert_eq!(c.m(), 2);
        // Mid ratio leaves m.
        c.adjust(0.2, 1.0);
        assert_eq!(c.m(), 2);
    }

    #[test]
    fn controller_clamps_to_bounds() {
        let mut c = MController::new(0, 2, 0.02, 0.5);
        c.adjust(0.0001, 1.0);
        assert_eq!(c.m(), 0, "m must not underflow");
        for _ in 0..5 {
            c.adjust(1.0, 1.0);
        }
        assert_eq!(c.m(), 2, "m must cap at m_max");
    }

    #[test]
    fn controller_ignores_degenerate_denominator() {
        let mut c = MController::new(5, 30, 0.02, 0.5);
        c.adjust(1.0, f64::INFINITY); // start-up: E^0 = +inf
        assert_eq!(c.m(), 5);
        c.adjust(1.0, 0.0); // plateau
        assert_eq!(c.m(), 5);
        c.adjust(f64::NAN, 1.0);
        assert_eq!(c.m(), 5);
    }

    #[test]
    fn accelerator_passthrough_without_history() {
        let mut acc = AndersonAccelerator::new(5, 3);
        let g = vec![1.0, 2.0, 3.0];
        let f = vec![0.1, 0.1, 0.1];
        let out = acc.propose(&g, &f, 5);
        assert_eq!(out, g, "first call has no history: pass through");
        assert_eq!(acc.accelerated_steps(), 0);
    }

    #[test]
    fn accelerator_m_zero_is_plain_iteration() {
        let mut acc = AndersonAccelerator::new(5, 2);
        acc.propose(&[1.0, 1.0], &[0.5, 0.5], 5);
        let g2 = vec![1.5, 1.2];
        let out = acc.propose(&g2, &[0.2, 0.3], 0);
        assert_eq!(out, g2);
    }

    /// Snapshot/restore replays the incremental history pushes, so a
    /// restored accelerator's proposals are bit-identical to one that
    /// never stopped — the property the durable-checkpoint parity tests
    /// lean on.
    #[test]
    fn snapshot_restore_is_bit_identical() {
        use crate::rng::{Pcg32, Rng};
        let dim = 12;
        let mut rng = Pcg32::seed_from_u64(77);
        let mut feed = |acc: &mut AndersonAccelerator, out: &mut Vec<f64>, rng: &mut Pcg32| {
            let g: Vec<f64> = (0..dim).map(|_| rng.next_gaussian()).collect();
            let f: Vec<f64> = (0..dim).map(|_| rng.next_gaussian() * 0.1).collect();
            acc.propose_into(&g, &f, 3, out);
        };
        let mut live = AndersonAccelerator::new(4, dim);
        let mut out = vec![0.0; dim];
        for _ in 0..6 {
            feed(&mut live, &mut out, &mut rng);
        }
        let snap = live.snapshot();
        let mut restored = AndersonAccelerator::new(4, dim);
        restored.restore(&snap);
        assert_eq!(restored.accelerated_steps(), live.accelerated_steps());
        // Same future inputs => exactly the same proposals, bit for bit.
        let mut rng_a = rng.clone();
        let mut rng_b = rng;
        let mut out_a = vec![0.0; dim];
        let mut out_b = vec![0.0; dim];
        for step in 0..5 {
            feed(&mut live, &mut out_a, &mut rng_a);
            feed(&mut restored, &mut out_b, &mut rng_b);
            let bits_a: Vec<u64> = out_a.iter().map(|v| v.to_bits()).collect();
            let bits_b: Vec<u64> = out_b.iter().map(|v| v.to_bits()).collect();
            assert_eq!(bits_a, bits_b, "step {step} diverged after restore");
        }
    }

    /// AA solves a linear contraction dramatically faster than plain
    /// iteration — the quasi-Newton property the paper leans on.
    #[test]
    fn accelerates_linear_contraction() {
        let a = [0.9, 0.85, 0.95, 0.8];
        let b = [1.0, -2.0, 0.5, 3.0];
        let fixed: Vec<f64> = (0..4).map(|i| b[i] / (1.0 - a[i])).collect();
        let g = |x: &[f64]| -> Vec<f64> { (0..4).map(|i| a[i] * x[i] + b[i]).collect() };
        let energy = |x: &[f64]| -> f64 {
            x.iter().zip(&fixed).map(|(v, f)| (v - f) * (v - f)).sum()
        };
        // Plain iteration count to tol.
        let mut x = vec![0.0; 4];
        let mut plain_iters = 0;
        while energy(&x) > 1e-16 && plain_iters < 10_000 {
            x = g(&x);
            plain_iters += 1;
        }
        // Accelerated.
        let ctl = MController::new(4, 10, 0.02, 0.5);
        let report = accelerated_fixed_point(&[0.0; 4], g, energy, &ctl, 1000, 1e-10);
        assert!(
            report.iterations * 5 < plain_iters,
            "AA {} iters vs plain {plain_iters}",
            report.iterations
        );
        for i in 0..4 {
            assert!((report.solution[i] - fixed[i]).abs() < 1e-6);
        }
    }

    /// Alternating projections onto two lines through the origin — the
    /// map is nonexpansive and the energy guard must keep AA stable.
    #[test]
    fn alternating_projections_stays_monotone() {
        // Project onto line span{(1,0.2)} then span{(0.2,1)}; intersection
        // is the origin. Energy = squared norm.
        let proj = |u: [f64; 2], x: &[f64]| -> Vec<f64> {
            let nn = u[0] * u[0] + u[1] * u[1];
            let t = (u[0] * x[0] + u[1] * x[1]) / nn;
            vec![t * u[0], t * u[1]]
        };
        let g = move |x: &[f64]| -> Vec<f64> {
            let y = proj([1.0, 0.2], x);
            proj([0.2, 1.0], &y)
        };
        let energy = |x: &[f64]| -> f64 { x[0] * x[0] + x[1] * x[1] };
        let ctl = MController::new(2, 5, 0.02, 0.5);
        let report = accelerated_fixed_point(&[3.0, 4.0], g, energy, &ctl, 200, 1e-12);
        // Trace must be monotonically non-increasing (the guard's contract).
        for w in report.trace.windows(2) {
            assert!(w[1] <= w[0] + 1e-12, "energy increased: {} -> {}", w[0], w[1]);
        }
        assert!(report.energy < 1e-8, "should reach the intersection");
    }
}
