//! [`ClusterRequest`] — the single job description consumed by every layer.
//!
//! One request says everything about one clustering job: where the samples
//! come from ([`DataSource`]), how many clusters, how to seed
//! ([`InitSpec`]), which engine / precision / acceleration to run, the
//! iteration and wall-clock budgets, and the RNG seed. The same value
//! drives the in-process path ([`crate::session::ClusterSession::open`])
//! and the service path ([`crate::coordinator::Coordinator::submit`]), so
//! capabilities can no longer diverge between the two (`Precision` in
//! particular flows end to end).
//!
//! Requests are built — and validated — through
//! [`ClusterRequest::builder`]. Everything data-independent is checked at
//! [`ClusterRequestBuilder::build`]; shape checks against lazily
//! materialized sources happen when the session first touches the data.

use crate::config::{Acceleration, EngineKind, Precision, SolverConfig};
use crate::data::DataMatrix;
use crate::error::{ClusterError, FaultClass};
use crate::init::InitMethod;
use crate::kmeans::WorkspaceSpec;
use crate::persist::CheckpointPolicy;
use crate::stream::{BatchSampling, EnergyGuard};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

/// Where a request's samples come from.
#[derive(Debug, Clone)]
pub enum DataSource {
    /// Caller-provided matrix (shared, zero-copy across queues and runs).
    Inline(Arc<DataMatrix>),
    /// A Table-1 registry dataset, generated at the given scale.
    Registry {
        /// Registry dataset name (see `data::REGISTRY`).
        name: String,
        /// Fraction of the paper's N to generate, in `(0, 1]`.
        scale: f64,
    },
    /// A CSV (anything else) or fvecs (`.fv`) file on disk.
    Path(PathBuf),
    /// A binary shard on disk (the `AAKMFV01` format written by
    /// [`crate::data::ShardWriter`]), streamed chunk-by-chunk through
    /// [`crate::data::MmapShardSource`] by the mini-batch engine — the
    /// out-of-core source. Full-batch engines load it whole.
    Shard(PathBuf),
}

impl DataSource {
    /// Short label for error messages.
    pub fn label(&self) -> String {
        match self {
            Self::Inline(m) => format!("inline {}x{}", m.n(), m.d()),
            Self::Registry { name, scale } => format!("{name}@{scale}"),
            Self::Path(p) => p.display().to_string(),
            Self::Shard(p) => format!("shard {}", p.display()),
        }
    }

    /// Materialize the samples.
    pub fn materialize(&self) -> Result<Arc<DataMatrix>, ClusterError> {
        match self {
            Self::Inline(m) => Ok(Arc::clone(m)),
            Self::Registry { name, scale } => {
                let spec = crate::data::dataset_by_name(name).ok_or_else(|| {
                    ClusterError::Data {
                        source: self.label(),
                        reason: "unknown registry dataset".to_string(),
                    }
                })?;
                Ok(Arc::new(spec.generate_scaled(*scale)))
            }
            Self::Path(p) => {
                let loaded = if p.extension().is_some_and(|e| e == "fv") {
                    crate::data::load_fvecs(p)
                } else {
                    crate::data::load_csv(p)
                };
                loaded.map(Arc::new).map_err(|e| ClusterError::Data {
                    source: self.label(),
                    reason: format!("{e:#}"),
                })
            }
            // Shards share the fvecs layout, so a full-batch materialize
            // is just the batch loader (out-of-core streaming goes through
            // the session's chunk-source path instead).
            Self::Shard(p) => {
                crate::data::load_fvecs(p).map(Arc::new).map_err(|e| ClusterError::Data {
                    source: self.label(),
                    reason: format!("{e:#}"),
                })
            }
        }
    }
}

/// Shape checks that need the materialized data — one implementation
/// shared by [`ClusterRequestBuilder::build`] (inline sources) and the
/// session's first materialization (registry/path sources), so the two
/// validation paths cannot drift.
pub(crate) fn validate_against_data(
    x: &DataMatrix,
    k: usize,
    init: &InitSpec,
    label: &str,
) -> Result<(), ClusterError> {
    if x.n() == 0 || x.d() == 0 {
        return Err(ClusterError::invalid("source", "data must be non-empty"));
    }
    // Admission-time finiteness check: one NaN/∞ sample would otherwise
    // poison every distance, energy and centroid downstream of it.
    for i in 0..x.n() {
        if let Some(j) = x.row(i).iter().position(|v| !v.is_finite()) {
            return Err(ClusterError::InvalidData {
                source: label.to_string(),
                row: i,
                reason: format!("non-finite value at column {j}"),
            });
        }
    }
    if k > x.n() {
        return Err(ClusterError::invalid(
            "k",
            format!("k={k} exceeds the sample count {}", x.n()),
        ));
    }
    if let InitSpec::Centroids(c0) = init {
        if c0.n() != k {
            return Err(ClusterError::invalid(
                "init",
                format!("{} initial centroids for k={k}", c0.n()),
            ));
        }
        if c0.d() != x.d() {
            return Err(ClusterError::invalid(
                "init",
                format!(
                    "initial centroids are {}-dimensional but the data is {}-dimensional",
                    c0.d(),
                    x.d()
                ),
            ));
        }
    }
    Ok(())
}

/// How the initial centroids are produced.
#[derive(Debug, Clone)]
pub enum InitSpec {
    /// Seed with one of the paper's methods, from the request seed.
    Method(InitMethod),
    /// Explicit initial centroids (`k × d`).
    Centroids(Arc<DataMatrix>),
    /// Seed from a registered model's centroids (warm-start re-clustering:
    /// Anderson acceleration near a fixed point is the paper's best case).
    /// The model's k and d are validated against the request when the
    /// session first touches the data.
    WarmStart {
        /// Registry directory holding the model.
        registry: PathBuf,
        /// Model id to seed from.
        model: String,
    },
}

/// What a service job does with the model registry (see
/// [`crate::registry`]): fit-and-register, batch predict, or warm-start
/// refresh.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelJobKind {
    /// Run the fit and register the result under the job's model id.
    Fit,
    /// Load the model and assign the request's samples to it (no solver
    /// run — the request's iteration budget is ignored).
    Predict,
    /// Warm-start from the model, re-fit, and save the result back with a
    /// drift report and a bumped refresh count.
    Refresh,
}

impl ModelJobKind {
    /// Canonical journal / CLI name.
    pub fn name(&self) -> &'static str {
        match self {
            Self::Fit => "fit",
            Self::Predict => "predict",
            Self::Refresh => "refresh",
        }
    }

    /// Parse a canonical name.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "fit" => Some(Self::Fit),
            "predict" => Some(Self::Predict),
            "refresh" => Some(Self::Refresh),
            _ => None,
        }
    }
}

/// A registry action attached to a [`ClusterRequest`], executed by the
/// coordinator when the job runs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelJob {
    /// Registry directory.
    pub registry: PathBuf,
    /// Model id to register / load / refresh.
    pub model: String,
    /// What to do.
    pub kind: ModelJobKind,
}

/// Retry discipline for service jobs that fail with a *transient*
/// [`FaultClass`]: the coordinator re-runs the job up to
/// `max_attempts` times total, sleeping a seeded-deterministic jittered
/// exponential backoff between attempts. Deterministic failures
/// (validation, cancellation) are never retried regardless of policy.
#[derive(Debug, Clone, PartialEq)]
pub struct RetryPolicy {
    /// Total attempts, including the first (must be ≥ 1).
    pub max_attempts: u32,
    /// Base backoff before attempt 2; attempt `a` waits
    /// `backoff · 2^(a-2)`, jittered to 50–100 % of that span by a PRNG
    /// seeded from (request seed, job id, attempt).
    pub backoff: Duration,
    /// Which transient classes are worth re-running.
    pub retry_on: Vec<FaultClass>,
}

impl RetryPolicy {
    /// Retry every transient class (I/O, engine load, worker panic).
    pub fn transient(max_attempts: u32, backoff: Duration) -> Self {
        Self {
            max_attempts,
            backoff,
            retry_on: vec![FaultClass::Io, FaultClass::EngineLoad, FaultClass::Panic],
        }
    }

    /// Whether an error of class `class` qualifies for another attempt.
    pub fn retries(&self, class: Option<FaultClass>) -> bool {
        class.is_some_and(|c| self.retry_on.contains(&c))
    }
}

/// A fully validated clustering job description. Construct through
/// [`ClusterRequest::builder`]; every field has a getter.
#[derive(Debug, Clone)]
pub struct ClusterRequest {
    source: DataSource,
    k: usize,
    init: InitSpec,
    engine: EngineKind,
    precision: Precision,
    accel: Acceleration,
    epsilon1: f64,
    epsilon2: f64,
    m_max: usize,
    max_iters: usize,
    time_limit: Option<Duration>,
    threads: usize,
    record_trace: bool,
    seed: u64,
    artifact_dir: Option<PathBuf>,
    priority: i32,
    chunk_size: usize,
    batches_per_epoch: usize,
    batch_sampling: BatchSampling,
    prefetch: bool,
    guard: EnergyGuard,
    pin_threads: bool,
    client: Option<String>,
    retry: Option<RetryPolicy>,
    cpu_fallback: bool,
    checkpoint: Option<CheckpointPolicy>,
    reseed_empty: bool,
    model_job: Option<ModelJob>,
}

impl ClusterRequest {
    /// Start building a request (paper-default solver parameters).
    pub fn builder() -> ClusterRequestBuilder {
        ClusterRequestBuilder::default()
    }

    /// Data source.
    pub fn source(&self) -> &DataSource {
        &self.source
    }

    /// Number of clusters.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Seeding specification.
    pub fn init(&self) -> &InitSpec {
        &self.init
    }

    /// Assignment engine kind.
    pub fn engine(&self) -> EngineKind {
        self.engine
    }

    /// Kernel sample-storage precision.
    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// Acceleration mode.
    pub fn accel(&self) -> Acceleration {
        self.accel
    }

    /// Iteration budget.
    pub fn max_iters(&self) -> usize {
        self.max_iters
    }

    /// Wall-clock budget, if any.
    pub fn time_limit(&self) -> Option<Duration> {
        self.time_limit
    }

    /// Solver threads (0 = host-sized).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Whether per-iteration traces are recorded into the report.
    pub fn record_trace(&self) -> bool {
        self.record_trace
    }

    /// RNG seed (data generation + seeding).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// PJRT artifact directory override, if any.
    pub fn artifact_dir(&self) -> Option<&PathBuf> {
        self.artifact_dir.as_ref()
    }

    /// Scheduling priority (higher runs first; coordinator workers pick
    /// the highest-priority queued job, FIFO within equal priorities).
    pub fn priority(&self) -> i32 {
        self.priority
    }

    /// Samples per mini-batch chunk (`EngineKind::MiniBatch` only).
    pub fn chunk_size(&self) -> usize {
        self.chunk_size
    }

    /// Mini-batches per epoch; 0 = one full pass over the source.
    pub fn batches_per_epoch(&self) -> usize {
        self.batches_per_epoch
    }

    /// How mini-batch epochs draw their batches
    /// (`EngineKind::MiniBatch` only).
    pub fn batch_sampling(&self) -> BatchSampling {
        self.batch_sampling
    }

    /// Whether chunk reads run through the background prefetch pipeline
    /// (`EngineKind::MiniBatch` streamed sources only).
    pub fn prefetch(&self) -> bool {
        self.prefetch
    }

    /// How mini-batch checkpoint energies are measured
    /// (`EngineKind::MiniBatch` only).
    pub fn guard(&self) -> EnergyGuard {
        self.guard
    }

    /// Whether solver worker lanes (and the prefetcher) are pinned to
    /// fixed CPUs (Linux; no-op elsewhere).
    pub fn pin_threads(&self) -> bool {
        self.pin_threads
    }

    /// Client tag for per-client fair queue pickup (`None` = the shared
    /// anonymous lane).
    pub fn client(&self) -> Option<&str> {
        self.client.as_deref()
    }

    /// Retry policy for transient service-side failures, if any.
    pub fn retry(&self) -> Option<&RetryPolicy> {
        self.retry.as_ref()
    }

    /// Whether a PJRT job whose runtime fails to load may degrade to the
    /// equivalent CPU engine (recorded in `JobOutcome::degraded`).
    pub fn cpu_fallback(&self) -> bool {
        self.cpu_fallback
    }

    /// Durable-snapshot policy, if any: the solver writes crash-safe
    /// checkpoints under the policy's directory and resumes from a
    /// matching snapshot found there (see [`crate::persist`]).
    pub fn checkpoint(&self) -> Option<&CheckpointPolicy> {
        self.checkpoint.as_ref()
    }

    /// Whether clusters that lose every sample are deterministically
    /// re-seeded mid-run (see [`crate::lloyd::reseed_empty_clusters`]).
    pub fn reseed_empty(&self) -> bool {
        self.reseed_empty
    }

    /// The registry action attached to this request, if any.
    pub fn model_job(&self) -> Option<&ModelJob> {
        self.model_job.as_ref()
    }

    /// Re-target the request at a different cluster count (registry
    /// multi-k sweeps). The seeding must be a method — explicit centroids
    /// and warm-start models pin k.
    pub fn with_k(&self, k: usize) -> Result<Self, ClusterError> {
        if k == 0 {
            return Err(ClusterError::invalid("k", "must be at least 1"));
        }
        if !matches!(self.init, InitSpec::Method(_)) {
            return Err(ClusterError::invalid(
                "init",
                "multi-k sweeps need a seeding method, not fixed centroids",
            ));
        }
        let mut req = self.clone();
        req.k = k;
        Ok(req)
    }

    /// Swap in an already-materialized copy of the source (registry sweeps
    /// materialize once and share the matrix — and therefore the kernel's
    /// generation-stamped norm cache — across every k).
    pub(crate) fn with_inline_source(mut self, data: Arc<DataMatrix>) -> Self {
        self.source = DataSource::Inline(data);
        self
    }

    /// Project the streaming mini-batch configuration (used when
    /// [`ClusterRequest::engine`] is `EngineKind::MiniBatch`).
    pub fn minibatch_config(&self) -> crate::stream::MiniBatchConfig {
        crate::stream::MiniBatchConfig {
            solver: self.solver_config(),
            chunk_size: self.chunk_size,
            batches_per_epoch: self.batches_per_epoch,
            sampling: self.batch_sampling,
            seed: self.seed,
            prefetch: self.prefetch,
            guard: self.guard,
            pin_threads: self.pin_threads,
            ..crate::stream::MiniBatchConfig::default()
        }
    }

    /// Project the solver-level configuration.
    pub fn solver_config(&self) -> SolverConfig {
        SolverConfig {
            accel: self.accel,
            engine: self.engine,
            epsilon1: self.epsilon1,
            epsilon2: self.epsilon2,
            m_max: self.m_max,
            max_iters: self.max_iters,
            time_limit: self.time_limit,
            threads: self.threads,
            record_trace: self.record_trace,
            precision: self.precision,
            checkpoint: self.checkpoint.clone(),
            reseed_empty: self.reseed_empty,
            seed: self.seed,
        }
    }

    /// The workspace this request needs.
    pub fn workspace_spec(&self) -> WorkspaceSpec {
        WorkspaceSpec {
            engine: self.engine,
            precision: self.precision,
            threads: self.threads,
            artifact_dir: self.artifact_dir.clone(),
        }
    }

    /// Serialize the request as the coordinator journal's flat `key=value`
    /// payload (one key per line), or `None` when the request cannot be
    /// journaled: inline matrices and explicit initial centroids live only
    /// in the submitting process's memory, so a recovering coordinator
    /// could not reconstruct them.
    ///
    /// `time_limit` is deliberately dropped — it is a deadline measured
    /// from submission, and a recovered job is a new submission.
    pub fn journal_spec(&self) -> Option<String> {
        let source = match &self.source {
            DataSource::Inline(_) => return None,
            DataSource::Registry { name, scale } => format!("registry:{scale}:{name}"),
            DataSource::Path(p) => format!("path:{}", p.display()),
            DataSource::Shard(p) => format!("shard:{}", p.display()),
        };
        let init = match &self.init {
            InitSpec::Method(m) => m.name().to_string(),
            InitSpec::Centroids(_) => return None,
            // Warm-start seeds load from the registry by id, so — unlike
            // explicit centroid matrices — they round-trip through the
            // journal and a recovering coordinator can re-seed them.
            InitSpec::WarmStart { .. } => "warm-start".to_string(),
        };
        let mut kv: Vec<(&str, String)> = vec![
            ("source", source),
            ("k", self.k.to_string()),
            ("init", init),
            ("engine", self.engine.name().to_string()),
            ("precision", self.precision.name().to_string()),
            ("accel", self.accel.label()),
            ("eps1", self.epsilon1.to_string()),
            ("eps2", self.epsilon2.to_string()),
            ("m_max", self.m_max.to_string()),
            ("max_iters", self.max_iters.to_string()),
            ("threads", self.threads.to_string()),
            ("record_trace", self.record_trace.to_string()),
            ("seed", self.seed.to_string()),
            ("priority", self.priority.to_string()),
            ("chunk_size", self.chunk_size.to_string()),
            ("batches_per_epoch", self.batches_per_epoch.to_string()),
            ("sampling", self.batch_sampling.name().to_string()),
            ("prefetch", self.prefetch.to_string()),
            ("guard", self.guard.name()),
            ("pin_threads", self.pin_threads.to_string()),
            ("reseed_empty", self.reseed_empty.to_string()),
            ("cpu_fallback", self.cpu_fallback.to_string()),
        ];
        if let InitSpec::WarmStart { registry, model } = &self.init {
            kv.push(("warm_registry", registry.display().to_string()));
            kv.push(("warm_model", model.clone()));
        }
        if let Some(job) = &self.model_job {
            kv.push(("job", job.kind.name().to_string()));
            kv.push(("job_registry", job.registry.display().to_string()));
            kv.push(("job_model", job.model.clone()));
        }
        if let Some(client) = &self.client {
            kv.push(("client", client.clone()));
        }
        if let Some(dir) = &self.artifact_dir {
            kv.push(("artifact_dir", dir.display().to_string()));
        }
        if let Some(ck) = &self.checkpoint {
            kv.push(("checkpoint_dir", ck.dir.display().to_string()));
            kv.push(("checkpoint_every", ck.every.to_string()));
        }
        if let Some(retry) = &self.retry {
            let classes: Vec<&str> = retry
                .retry_on
                .iter()
                .map(|c| match c {
                    FaultClass::Io => "io",
                    FaultClass::EngineLoad => "engine-load",
                    FaultClass::Panic => "panic",
                })
                .collect();
            kv.push((
                "retry",
                format!("{}:{}:{}", retry.max_attempts, retry.backoff.as_millis(), classes.join(",")),
            ));
        }
        let mut spec = String::new();
        for (key, val) in kv {
            // A newline inside a value (a pathological path or client tag)
            // would shear the line format — such requests don't journal.
            if val.contains('\n') {
                return None;
            }
            spec.push_str(key);
            spec.push('=');
            spec.push_str(&val);
            spec.push('\n');
        }
        Some(spec)
    }

    /// Parse a [`ClusterRequest::journal_spec`] payload back into a
    /// validated request. Unknown keys are rejected: the journal is read
    /// back by the binary that wrote it, so an unrecognized key means a
    /// corrupt record, not version skew to paper over.
    pub fn from_journal_spec(spec: &str) -> Result<Self, ClusterError> {
        fn bad(reason: impl Into<String>) -> ClusterError {
            ClusterError::invalid("journal", reason)
        }
        fn num<T: std::str::FromStr>(key: &str, val: &str) -> Result<T, ClusterError> {
            val.parse().map_err(|_| bad(format!("bad value for {key}: '{val}'")))
        }
        let defaults = SolverConfig::default();
        let mut eps = (defaults.epsilon1, defaults.epsilon2);
        let mut ck_dir: Option<PathBuf> = None;
        let mut ck_every: Option<usize> = None;
        let mut init_warm = false;
        let mut warm_registry: Option<PathBuf> = None;
        let mut warm_model: Option<String> = None;
        let mut job_kind: Option<ModelJobKind> = None;
        let mut job_registry: Option<PathBuf> = None;
        let mut job_model: Option<String> = None;
        let mut b = ClusterRequest::builder();
        for line in spec.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let (key, val) =
                line.split_once('=').ok_or_else(|| bad(format!("malformed line '{line}'")))?;
            b = match key {
                "source" => {
                    let (kind, rest) = val
                        .split_once(':')
                        .ok_or_else(|| bad(format!("malformed source '{val}'")))?;
                    match kind {
                        "registry" => {
                            let (scale, name) = rest
                                .split_once(':')
                                .ok_or_else(|| bad(format!("malformed source '{val}'")))?;
                            b.registry(name, num::<f64>("registry scale", scale)?)
                        }
                        "path" => b.path(rest),
                        "shard" => b.shard(rest),
                        other => return Err(bad(format!("unknown source kind '{other}'"))),
                    }
                }
                "k" => b.k(num("k", val)?),
                "init" if val == "warm-start" => {
                    init_warm = true;
                    b
                }
                "init" => b.init(
                    InitMethod::parse(val).ok_or_else(|| bad(format!("unknown init '{val}'")))?,
                ),
                "warm_registry" => {
                    warm_registry = Some(PathBuf::from(val));
                    b
                }
                "warm_model" => {
                    warm_model = Some(val.to_string());
                    b
                }
                "job" => {
                    job_kind = Some(
                        ModelJobKind::parse(val)
                            .ok_or_else(|| bad(format!("unknown model job '{val}'")))?,
                    );
                    b
                }
                "job_registry" => {
                    job_registry = Some(PathBuf::from(val));
                    b
                }
                "job_model" => {
                    job_model = Some(val.to_string());
                    b
                }
                "engine" => b.engine(
                    EngineKind::parse(val)
                        .ok_or_else(|| bad(format!("unknown engine '{val}'")))?,
                ),
                "precision" => b.precision(
                    Precision::parse(val)
                        .ok_or_else(|| bad(format!("unknown precision '{val}'")))?,
                ),
                "accel" => b.accel(
                    crate::config::parse_accel(val)
                        .ok_or_else(|| bad(format!("unknown accel '{val}'")))?,
                ),
                "eps1" => {
                    eps.0 = num("eps1", val)?;
                    b
                }
                "eps2" => {
                    eps.1 = num("eps2", val)?;
                    b
                }
                "m_max" => b.m_max(num("m_max", val)?),
                "max_iters" => b.max_iters(num("max_iters", val)?),
                "threads" => b.threads(num("threads", val)?),
                "record_trace" => b.record_trace(num("record_trace", val)?),
                "seed" => b.seed(num("seed", val)?),
                "priority" => b.priority(num("priority", val)?),
                "chunk_size" => b.chunk_size(num("chunk_size", val)?),
                "batches_per_epoch" => b.batches_per_epoch(num("batches_per_epoch", val)?),
                "sampling" => b.batch_sampling(
                    BatchSampling::parse(val)
                        .ok_or_else(|| bad(format!("unknown sampling '{val}'")))?,
                ),
                "prefetch" => b.prefetch(num("prefetch", val)?),
                "guard" => b.guard(
                    EnergyGuard::parse(val)
                        .ok_or_else(|| bad(format!("unknown guard '{val}'")))?,
                ),
                "pin_threads" => b.pin_threads(num("pin_threads", val)?),
                "reseed_empty" => b.reseed_empty(num("reseed_empty", val)?),
                "cpu_fallback" => b.cpu_fallback(num("cpu_fallback", val)?),
                "client" => b.client(val),
                "artifact_dir" => b.artifact_dir(val),
                "checkpoint_dir" => {
                    ck_dir = Some(PathBuf::from(val));
                    b
                }
                "checkpoint_every" => {
                    ck_every = Some(num("checkpoint_every", val)?);
                    b
                }
                "retry" => {
                    let mut parts = val.splitn(3, ':');
                    let (Some(max), Some(backoff), Some(classes)) =
                        (parts.next(), parts.next(), parts.next())
                    else {
                        return Err(bad(format!("malformed retry '{val}'")));
                    };
                    let retry_on = classes
                        .split(',')
                        .filter(|c| !c.is_empty())
                        .map(|c| match c {
                            "io" => Ok(FaultClass::Io),
                            "engine-load" => Ok(FaultClass::EngineLoad),
                            "panic" => Ok(FaultClass::Panic),
                            other => Err(bad(format!("unknown fault class '{other}'"))),
                        })
                        .collect::<Result<Vec<_>, _>>()?;
                    b.retry(RetryPolicy {
                        max_attempts: num("retry attempts", max)?,
                        backoff: Duration::from_millis(num("retry backoff", backoff)?),
                        retry_on,
                    })
                }
                other => return Err(bad(format!("unknown key '{other}'"))),
            };
        }
        match (ck_dir, ck_every) {
            (Some(dir), Some(every)) => b = b.checkpoint(CheckpointPolicy { dir, every }),
            (None, None) => {}
            _ => return Err(bad("checkpoint_dir and checkpoint_every must appear together")),
        }
        match (init_warm, warm_registry, warm_model) {
            (true, Some(dir), Some(model)) => b = b.warm_start(dir, model),
            (false, None, None) => {}
            _ => {
                return Err(bad(
                    "warm-start init needs warm_registry and warm_model together",
                ))
            }
        }
        match (job_kind, job_registry, job_model) {
            (Some(kind), Some(registry), Some(model)) => {
                b = b.model_job(ModelJob { registry, model, kind });
            }
            (None, None, None) => {}
            _ => return Err(bad("job, job_registry and job_model must appear together")),
        }
        b.epsilons(eps.0, eps.1).build()
    }

    /// Replace the wall-clock budget with the remaining portion of a
    /// deadline (coordinator-internal: `time_limit` is a per-job deadline
    /// measured from submission, so queue wait is deducted before the
    /// solver starts).
    pub(crate) fn with_time_limit(mut self, limit: Duration) -> Self {
        self.time_limit = Some(limit);
        self
    }

    /// Swap the engine (coordinator-internal: graceful degradation of a
    /// PJRT job to a CPU engine after a runtime-load failure).
    pub(crate) fn with_engine(mut self, engine: EngineKind) -> Self {
        self.engine = engine;
        self
    }

    /// Apply service-side defaults: a zero thread count takes the
    /// coordinator's per-worker thread budget (host-sizing every job would
    /// oversubscribe the workers), and jobs without an explicit artifact
    /// directory use the coordinator's.
    pub(crate) fn with_service_defaults(
        mut self,
        solver_threads: usize,
        artifact_dir: &std::path::Path,
    ) -> Self {
        if self.threads == 0 {
            self.threads = solver_threads.max(1);
        }
        if self.artifact_dir.is_none() {
            self.artifact_dir = Some(artifact_dir.to_path_buf());
        }
        self
    }
}

/// Builder for [`ClusterRequest`]; `build` performs the data-independent
/// validation (and shape validation where the source is inline).
#[derive(Debug, Clone)]
pub struct ClusterRequestBuilder {
    source: Option<DataSource>,
    k: usize,
    init: InitSpec,
    engine: EngineKind,
    precision: Precision,
    accel: Acceleration,
    epsilon1: f64,
    epsilon2: f64,
    m_max: usize,
    max_iters: usize,
    time_limit: Option<Duration>,
    threads: usize,
    record_trace: bool,
    seed: u64,
    artifact_dir: Option<PathBuf>,
    priority: i32,
    chunk_size: usize,
    batches_per_epoch: usize,
    batch_sampling: BatchSampling,
    prefetch: bool,
    guard: EnergyGuard,
    pin_threads: bool,
    client: Option<String>,
    retry: Option<RetryPolicy>,
    cpu_fallback: bool,
    checkpoint: Option<CheckpointPolicy>,
    reseed_empty: bool,
    model_job: Option<ModelJob>,
}

impl Default for ClusterRequestBuilder {
    fn default() -> Self {
        let cfg = SolverConfig::default();
        Self {
            source: None,
            k: 10,
            init: InitSpec::Method(InitMethod::KMeansPlusPlus),
            engine: cfg.engine,
            precision: cfg.precision,
            accel: cfg.accel,
            epsilon1: cfg.epsilon1,
            epsilon2: cfg.epsilon2,
            m_max: cfg.m_max,
            max_iters: cfg.max_iters,
            time_limit: None,
            threads: cfg.threads,
            record_trace: cfg.record_trace,
            seed: 42,
            artifact_dir: None,
            priority: 0,
            chunk_size: 4096,
            batches_per_epoch: 0,
            batch_sampling: BatchSampling::Sequential,
            prefetch: false,
            guard: EnergyGuard::Exact,
            pin_threads: false,
            client: None,
            retry: None,
            cpu_fallback: false,
            checkpoint: None,
            reseed_empty: false,
            model_job: None,
        }
    }
}

impl ClusterRequestBuilder {
    /// Set an arbitrary data source.
    pub fn source(mut self, source: DataSource) -> Self {
        self.source = Some(source);
        self
    }

    /// Cluster caller-provided samples (zero-copy).
    pub fn inline(self, data: Arc<DataMatrix>) -> Self {
        self.source(DataSource::Inline(data))
    }

    /// Cluster a registry dataset at the given scale.
    pub fn registry(self, name: impl Into<String>, scale: f64) -> Self {
        self.source(DataSource::Registry { name: name.into(), scale })
    }

    /// Cluster a CSV / fvecs file.
    pub fn path(self, path: impl Into<PathBuf>) -> Self {
        self.source(DataSource::Path(path.into()))
    }

    /// Cluster a binary shard file (streamed out-of-core by the
    /// mini-batch engine; loaded whole by full-batch engines).
    pub fn shard(self, path: impl Into<PathBuf>) -> Self {
        self.source(DataSource::Shard(path.into()))
    }

    /// Number of clusters.
    pub fn k(mut self, k: usize) -> Self {
        self.k = k;
        self
    }

    /// Seeding method (the default is k-means++).
    pub fn init(mut self, method: InitMethod) -> Self {
        self.init = InitSpec::Method(method);
        self
    }

    /// Explicit initial centroids instead of a seeding method.
    pub fn initial_centroids(mut self, c0: Arc<DataMatrix>) -> Self {
        self.init = InitSpec::Centroids(c0);
        self
    }

    /// Seed from a registered model's centroids (warm-start
    /// re-clustering). The model's shape is validated against the data
    /// when the session first materializes it.
    pub fn warm_start(mut self, registry: impl Into<PathBuf>, model: impl Into<String>) -> Self {
        self.init = InitSpec::WarmStart { registry: registry.into(), model: model.into() };
        self
    }

    /// Attach a raw model job (see the [`ClusterRequestBuilder::fit_into`],
    /// [`ClusterRequestBuilder::predict_with`] and
    /// [`ClusterRequestBuilder::refresh_model`] conveniences).
    pub fn model_job(mut self, job: ModelJob) -> Self {
        self.model_job = Some(job);
        self
    }

    /// Fit and register the result under `model` in `registry`.
    pub fn fit_into(self, registry: impl Into<PathBuf>, model: impl Into<String>) -> Self {
        self.model_job(ModelJob {
            registry: registry.into(),
            model: model.into(),
            kind: ModelJobKind::Fit,
        })
    }

    /// Batch-predict the request's samples against the registered `model`
    /// (no solver run).
    pub fn predict_with(self, registry: impl Into<PathBuf>, model: impl Into<String>) -> Self {
        self.model_job(ModelJob {
            registry: registry.into(),
            model: model.into(),
            kind: ModelJobKind::Predict,
        })
    }

    /// Warm-start from the registered `model`, re-fit, and save the result
    /// back with a drift report (sets both the warm-start seeding and the
    /// refresh job).
    pub fn refresh_model(self, registry: impl Into<PathBuf>, model: impl Into<String>) -> Self {
        let (registry, model) = (registry.into(), model.into());
        self.warm_start(registry.clone(), model.clone()).model_job(ModelJob {
            registry,
            model,
            kind: ModelJobKind::Refresh,
        })
    }

    /// Assignment engine.
    pub fn engine(mut self, engine: EngineKind) -> Self {
        self.engine = engine;
        self
    }

    /// Kernel sample-storage precision.
    pub fn precision(mut self, precision: Precision) -> Self {
        self.precision = precision;
        self
    }

    /// Acceleration mode.
    pub fn accel(mut self, accel: Acceleration) -> Self {
        self.accel = accel;
        self
    }

    /// Algorithm 1's ε₁ / ε₂ thresholds.
    pub fn epsilons(mut self, epsilon1: f64, epsilon2: f64) -> Self {
        self.epsilon1 = epsilon1;
        self.epsilon2 = epsilon2;
        self
    }

    /// History cap m̄.
    pub fn m_max(mut self, m_max: usize) -> Self {
        self.m_max = m_max;
        self
    }

    /// Iteration budget.
    pub fn max_iters(mut self, max_iters: usize) -> Self {
        self.max_iters = max_iters;
        self
    }

    /// Wall-clock budget (checked at iteration boundaries).
    pub fn time_limit(mut self, limit: Duration) -> Self {
        self.time_limit = Some(limit);
        self
    }

    /// Solver threads (0 = host-sized; the coordinator substitutes its
    /// per-worker budget).
    pub fn threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Record per-iteration energy / m traces into the report.
    pub fn record_trace(mut self, record: bool) -> Self {
        self.record_trace = record;
        self
    }

    /// RNG seed for data generation and seeding.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// PJRT artifact directory (only used by `EngineKind::Pjrt`).
    pub fn artifact_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.artifact_dir = Some(dir.into());
        self
    }

    /// Scheduling priority for service submission (higher runs first;
    /// default 0). In-process sessions ignore it.
    pub fn priority(mut self, priority: i32) -> Self {
        self.priority = priority;
        self
    }

    /// Samples per mini-batch chunk (`EngineKind::MiniBatch`; default
    /// 4096 — also the peak resident sample count for streamed sources).
    pub fn chunk_size(mut self, chunk_size: usize) -> Self {
        self.chunk_size = chunk_size;
        self
    }

    /// Mini-batches per epoch. 0 (the default) = one full pass over the
    /// source. A positive cap makes every epoch train on the **first**
    /// `batches` chunks of a pass — deterministic, but the rest of a
    /// bounded source never updates the centroids (it still counts in the
    /// energy checkpoint). Use a positive cap to bound unbounded
    /// generator sources; keep 0 for full coverage of shards and
    /// in-memory data.
    pub fn batches_per_epoch(mut self, batches: usize) -> Self {
        self.batches_per_epoch = batches;
        self
    }

    /// How mini-batch epochs draw their batches (default
    /// [`BatchSampling::Sequential`] — the deterministic pass that keeps
    /// the epoch map AA-friendly). [`BatchSampling::Replacement`] draws
    /// each batch uniformly with replacement (seeded from
    /// [`ClusterRequestBuilder::seed`]) for classic mini-batch gradient
    /// shuffling; it requires a bounded source.
    pub fn batch_sampling(mut self, sampling: BatchSampling) -> Self {
        self.batch_sampling = sampling;
        self
    }

    /// Serve mini-batch chunk reads through the background prefetch
    /// pipeline ([`crate::stream::prefetch::PrefetchSource`]): page-in
    /// and decode of chunk *t+1* overlap the sweep of chunk *t*. Chunk
    /// order is preserved exactly, so results (energy traces, resume)
    /// are bit-identical with the flag on or off. Default off.
    pub fn prefetch(mut self, prefetch: bool) -> Self {
        self.prefetch = prefetch;
        self
    }

    /// How mini-batch checkpoint energies are measured (default
    /// [`EnergyGuard::Exact`] — a full pass per checkpoint).
    /// [`EnergyGuard::Sampled`] estimates them from a seeded fixed
    /// reservoir instead, removing the per-epoch full scans on
    /// out-of-core shards; it changes the trajectory and requires a
    /// bounded source.
    pub fn guard(mut self, guard: EnergyGuard) -> Self {
        self.guard = guard;
        self
    }

    /// Pin the solver's worker lanes (and, with prefetch, the prefetcher
    /// thread) to fixed CPUs — Linux only, a no-op elsewhere. Placement
    /// only; never changes results. Default off.
    pub fn pin_threads(mut self, pin: bool) -> Self {
        self.pin_threads = pin;
        self
    }

    /// Tag service submissions with a client identity: the coordinator's
    /// queue interleaves pickup across clients (round-robin between
    /// lanes, priority-then-FIFO within one), so one client's flood
    /// cannot starve the rest. Untagged requests share one lane.
    pub fn client(mut self, client: impl Into<String>) -> Self {
        self.client = Some(client.into());
        self
    }

    /// Retry transient service-side failures under `policy` (see
    /// [`RetryPolicy`]). In-process sessions ignore it.
    pub fn retry(mut self, policy: RetryPolicy) -> Self {
        self.retry = Some(policy);
        self
    }

    /// Allow a `EngineKind::Pjrt` job whose runtime fails to load to fall
    /// back to the equivalent CPU engine instead of failing (the
    /// degradation is recorded in the job outcome). Default off.
    pub fn cpu_fallback(mut self, allow: bool) -> Self {
        self.cpu_fallback = allow;
        self
    }

    /// Write crash-safe solver snapshots under `policy` and resume from a
    /// matching one if present (see [`crate::persist`]). Default off.
    pub fn checkpoint(mut self, policy: CheckpointPolicy) -> Self {
        self.checkpoint = Some(policy);
        self
    }

    /// Deterministically re-seed clusters that lose every sample instead
    /// of leaving their centroid frozen in place (seeded from the request
    /// seed, so runs stay reproducible). Default off.
    pub fn reseed_empty(mut self, reseed: bool) -> Self {
        self.reseed_empty = reseed;
        self
    }

    /// Validate and produce the request.
    pub fn build(self) -> Result<ClusterRequest, ClusterError> {
        let source = self
            .source
            .ok_or_else(|| ClusterError::invalid("source", "a data source is required"))?;
        if self.k == 0 {
            return Err(ClusterError::invalid("k", "must be at least 1"));
        }
        if self.max_iters == 0 {
            return Err(ClusterError::invalid("max_iters", "must be at least 1"));
        }
        if self.m_max == 0 {
            return Err(ClusterError::invalid("m_max", "must be at least 1"));
        }
        if !(self.epsilon1.is_finite() && self.epsilon2.is_finite() && self.epsilon1 >= 0.0) {
            return Err(ClusterError::invalid("epsilon", "ε₁/ε₂ must be finite and ε₁ ≥ 0"));
        }
        if self.epsilon1 > self.epsilon2 {
            return Err(ClusterError::invalid("epsilon", "ε₁ must not exceed ε₂"));
        }
        if let DataSource::Registry { scale, .. } = &source {
            if !(scale.is_finite() && *scale > 0.0 && *scale <= 1.0) {
                return Err(ClusterError::invalid("source", "registry scale must be in (0, 1]"));
            }
        }
        if self.chunk_size == 0 {
            return Err(ClusterError::invalid("chunk_size", "must be at least 1"));
        }
        if self.guard == (EnergyGuard::Sampled { rows: 0 }) {
            return Err(ClusterError::invalid(
                "guard",
                "the sampled energy guard needs at least one reservoir row (sampled:N, N >= 1)",
            ));
        }
        if let Some(retry) = &self.retry {
            if retry.max_attempts == 0 {
                return Err(ClusterError::invalid("retry", "max_attempts must be at least 1"));
            }
        }
        if let Some(ck) = &self.checkpoint {
            if ck.every == 0 {
                return Err(ClusterError::invalid(
                    "checkpoint",
                    "snapshot cadence must be at least 1",
                ));
            }
        }
        if let InitSpec::WarmStart { model, .. } = &self.init {
            crate::registry::validate_model_id(model)?;
        }
        if let Some(job) = &self.model_job {
            crate::registry::validate_model_id(&job.model)?;
            if job.kind == ModelJobKind::Refresh
                && !matches!(self.init, InitSpec::WarmStart { .. })
            {
                return Err(ClusterError::invalid(
                    "model",
                    "a refresh job must warm-start from its model",
                ));
            }
        }
        // Inline sources get the full shape checks right now; lazy sources
        // get the identical checks (same helper) from the session at first
        // materialization — only the data-independent centroid-count check
        // can run for them here.
        match &source {
            DataSource::Inline(x) => validate_against_data(x, self.k, &self.init, &source.label())?,
            _ => {
                if let InitSpec::Centroids(c0) = &self.init {
                    if c0.n() != self.k {
                        return Err(ClusterError::invalid(
                            "init",
                            format!("{} initial centroids for k={}", c0.n(), self.k),
                        ));
                    }
                }
            }
        }
        Ok(ClusterRequest {
            source,
            k: self.k,
            init: self.init,
            engine: self.engine,
            precision: self.precision,
            accel: self.accel,
            epsilon1: self.epsilon1,
            epsilon2: self.epsilon2,
            m_max: self.m_max,
            max_iters: self.max_iters,
            time_limit: self.time_limit,
            threads: self.threads,
            record_trace: self.record_trace,
            seed: self.seed,
            artifact_dir: self.artifact_dir,
            priority: self.priority,
            chunk_size: self.chunk_size,
            batches_per_epoch: self.batches_per_epoch,
            batch_sampling: self.batch_sampling,
            prefetch: self.prefetch,
            guard: self.guard,
            pin_threads: self.pin_threads,
            client: self.client,
            retry: self.retry,
            cpu_fallback: self.cpu_fallback,
            checkpoint: self.checkpoint,
            reseed_empty: self.reseed_empty,
            model_job: self.model_job,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Arc<DataMatrix> {
        Arc::new(DataMatrix::from_rows(&[&[0.0, 0.0], &[1.0, 0.0], &[0.0, 1.0], &[1.0, 1.0]]))
    }

    #[test]
    fn builder_applies_paper_defaults() {
        let req = ClusterRequest::builder().inline(tiny()).k(2).build().unwrap();
        assert_eq!(req.k(), 2);
        assert_eq!(req.engine(), EngineKind::Hamerly);
        assert_eq!(req.accel(), Acceleration::DynamicM(2));
        assert_eq!(req.precision(), Precision::F64);
        let cfg = req.solver_config();
        assert_eq!(cfg.epsilon1, 0.02);
        assert_eq!(cfg.epsilon2, 0.5);
        assert_eq!(cfg.m_max, 30);
    }

    #[test]
    fn builder_rejects_bad_fields() {
        let no_source = ClusterRequest::builder().k(2).build();
        assert!(matches!(
            no_source,
            Err(ClusterError::InvalidRequest { field: "source", .. })
        ));
        let bad_k = ClusterRequest::builder().inline(tiny()).k(0).build();
        assert!(matches!(bad_k, Err(ClusterError::InvalidRequest { field: "k", .. })));
        let k_over_n = ClusterRequest::builder().inline(tiny()).k(5).build();
        assert!(matches!(k_over_n, Err(ClusterError::InvalidRequest { field: "k", .. })));
        let zero_iters = ClusterRequest::builder().inline(tiny()).k(2).max_iters(0).build();
        assert!(matches!(
            zero_iters,
            Err(ClusterError::InvalidRequest { field: "max_iters", .. })
        ));
        let bad_eps = ClusterRequest::builder().inline(tiny()).k(2).epsilons(0.9, 0.1).build();
        assert!(matches!(
            bad_eps,
            Err(ClusterError::InvalidRequest { field: "epsilon", .. })
        ));
        let bad_scale = ClusterRequest::builder().registry("Birch", 0.0).k(2).build();
        assert!(matches!(
            bad_scale,
            Err(ClusterError::InvalidRequest { field: "source", .. })
        ));
    }

    #[test]
    fn builder_rejects_dimension_mismatched_centroids() {
        let c0 = Arc::new(DataMatrix::from_rows(&[&[0.0, 0.0, 0.0], &[1.0, 1.0, 1.0]]));
        let req = ClusterRequest::builder()
            .inline(tiny())
            .k(2)
            .initial_centroids(c0)
            .build();
        assert!(matches!(req, Err(ClusterError::InvalidRequest { field: "init", .. })));
        let wrong_count =
            Arc::new(DataMatrix::from_rows(&[&[0.0, 0.0], &[1.0, 1.0], &[2.0, 2.0]]));
        let req = ClusterRequest::builder()
            .inline(tiny())
            .k(2)
            .initial_centroids(wrong_count)
            .build();
        assert!(matches!(req, Err(ClusterError::InvalidRequest { field: "init", .. })));
    }

    #[test]
    fn sources_materialize_and_fail_typed() {
        let inline = DataSource::Inline(tiny()).materialize().unwrap();
        assert_eq!(inline.n(), 4);
        let reg = DataSource::Registry { name: "Birch".into(), scale: 0.001 };
        assert_eq!(reg.materialize().unwrap().d(), 2);
        let unknown = DataSource::Registry { name: "nope".into(), scale: 0.5 };
        assert!(matches!(unknown.materialize(), Err(ClusterError::Data { .. })));
        let missing = DataSource::Path(PathBuf::from("/no/such/file.csv"));
        assert!(matches!(missing.materialize(), Err(ClusterError::Data { .. })));
    }

    #[test]
    fn service_defaults_fill_threads_and_artifacts() {
        let req = ClusterRequest::builder().inline(tiny()).k(2).build().unwrap();
        assert_eq!(req.threads(), 0);
        let req = req.with_service_defaults(3, std::path::Path::new("arts"));
        assert_eq!(req.threads(), 3);
        assert_eq!(req.artifact_dir().unwrap(), &PathBuf::from("arts"));
        // Explicit values survive.
        let req2 = ClusterRequest::builder()
            .inline(tiny())
            .k(2)
            .threads(2)
            .artifact_dir("mine")
            .build()
            .unwrap()
            .with_service_defaults(3, std::path::Path::new("arts"));
        assert_eq!(req2.threads(), 2);
        assert_eq!(req2.artifact_dir().unwrap(), &PathBuf::from("mine"));
    }

    #[test]
    fn streaming_fields_default_and_validate() {
        let req = ClusterRequest::builder().inline(tiny()).k(2).build().unwrap();
        assert_eq!(req.priority(), 0);
        assert_eq!(req.chunk_size(), 4096);
        assert_eq!(req.batches_per_epoch(), 0);
        assert_eq!(req.batch_sampling(), BatchSampling::Sequential);
        assert!(!req.prefetch());
        assert_eq!(req.guard(), EnergyGuard::Exact);
        assert!(!req.pin_threads());
        let req = ClusterRequest::builder()
            .inline(tiny())
            .k(2)
            .priority(7)
            .chunk_size(128)
            .batches_per_epoch(3)
            .batch_sampling(BatchSampling::Replacement)
            .prefetch(true)
            .guard(EnergyGuard::Sampled { rows: 64 })
            .pin_threads(true)
            .seed(17)
            .build()
            .unwrap();
        assert_eq!(req.priority(), 7);
        assert_eq!(req.batch_sampling(), BatchSampling::Replacement);
        let mb = req.minibatch_config();
        assert_eq!(mb.chunk_size, 128);
        assert_eq!(mb.batches_per_epoch, 3);
        assert_eq!(mb.sampling, BatchSampling::Replacement);
        assert_eq!(mb.seed, 17, "the draw stream seeds from the request seed");
        assert!(mb.prefetch);
        assert_eq!(mb.guard, EnergyGuard::Sampled { rows: 64 });
        assert!(mb.pin_threads);
        let bad = ClusterRequest::builder().inline(tiny()).k(2).chunk_size(0).build();
        assert!(matches!(
            bad,
            Err(ClusterError::InvalidRequest { field: "chunk_size", .. })
        ));
        let bad = ClusterRequest::builder()
            .inline(tiny())
            .k(2)
            .guard(EnergyGuard::Sampled { rows: 0 })
            .build();
        assert!(matches!(bad, Err(ClusterError::InvalidRequest { field: "guard", .. })));
    }

    #[test]
    fn robustness_fields_default_off_and_validate() {
        let req = ClusterRequest::builder().inline(tiny()).k(2).build().unwrap();
        assert_eq!(req.client(), None);
        assert!(req.retry().is_none());
        assert!(!req.cpu_fallback());
        let req = ClusterRequest::builder()
            .inline(tiny())
            .k(2)
            .client("tenant-a")
            .retry(RetryPolicy::transient(3, Duration::from_millis(5)))
            .cpu_fallback(true)
            .build()
            .unwrap();
        assert_eq!(req.client(), Some("tenant-a"));
        assert!(req.cpu_fallback());
        let policy = req.retry().unwrap();
        assert_eq!(policy.max_attempts, 3);
        assert!(policy.retries(Some(FaultClass::Io)));
        assert!(policy.retries(Some(FaultClass::Panic)));
        assert!(!policy.retries(None), "deterministic failures never retry");
        let bad = ClusterRequest::builder()
            .inline(tiny())
            .k(2)
            .retry(RetryPolicy { max_attempts: 0, backoff: Duration::ZERO, retry_on: vec![] })
            .build();
        assert!(matches!(bad, Err(ClusterError::InvalidRequest { field: "retry", .. })));
    }

    #[test]
    fn checkpoint_and_reseed_fields_default_off_and_validate() {
        let req = ClusterRequest::builder().inline(tiny()).k(2).build().unwrap();
        assert!(req.checkpoint().is_none());
        assert!(!req.reseed_empty());
        let cfg = req.solver_config();
        assert!(cfg.checkpoint.is_none());
        assert!(!cfg.reseed_empty);
        assert_eq!(cfg.seed, 42, "the solver seed defaults with the request seed");

        let policy = CheckpointPolicy::new("ck/dir", 3);
        let req = ClusterRequest::builder()
            .inline(tiny())
            .k(2)
            .checkpoint(policy.clone())
            .reseed_empty(true)
            .seed(9)
            .build()
            .unwrap();
        assert_eq!(req.checkpoint(), Some(&policy));
        assert!(req.reseed_empty());
        let cfg = req.solver_config();
        assert_eq!(cfg.checkpoint, Some(policy));
        assert!(cfg.reseed_empty);
        assert_eq!(cfg.seed, 9, "the snapshot fingerprint seeds from the request seed");

        let bad = ClusterRequest::builder()
            .inline(tiny())
            .k(2)
            .checkpoint(CheckpointPolicy::new("ck/dir", 0))
            .build();
        assert!(matches!(
            bad,
            Err(ClusterError::InvalidRequest { field: "checkpoint", .. })
        ));
    }

    #[test]
    fn journal_spec_roundtrips() {
        let req = ClusterRequest::builder()
            .registry("Birch", 0.001)
            .k(7)
            .init(InitMethod::AfkMc2)
            .engine(EngineKind::MiniBatch)
            .precision(Precision::F32)
            .accel(Acceleration::FixedM(3))
            .epsilons(0.01, 0.4)
            .m_max(12)
            .max_iters(77)
            .threads(2)
            .record_trace(true)
            .seed(1234)
            .priority(-3)
            .chunk_size(256)
            .batches_per_epoch(5)
            .batch_sampling(BatchSampling::Replacement)
            .prefetch(true)
            .guard(EnergyGuard::Sampled { rows: 2048 })
            .pin_threads(true)
            .client("tenant-a")
            .retry(RetryPolicy::transient(3, Duration::from_millis(25)))
            .cpu_fallback(true)
            .checkpoint(CheckpointPolicy::new("ck/dir", 2))
            .reseed_empty(true)
            .build()
            .unwrap();
        let spec = req.journal_spec().expect("registry sources journal");
        let back = ClusterRequest::from_journal_spec(&spec).unwrap();
        match back.source() {
            DataSource::Registry { name, scale } => {
                assert_eq!(name, "Birch");
                assert_eq!(*scale, 0.001);
            }
            other => panic!("expected registry source, got {other:?}"),
        }
        assert_eq!(back.k(), 7);
        assert!(matches!(back.init(), InitSpec::Method(InitMethod::AfkMc2)));
        assert_eq!(back.engine(), EngineKind::MiniBatch);
        assert_eq!(back.precision(), Precision::F32);
        assert_eq!(back.accel(), Acceleration::FixedM(3));
        assert_eq!(back.max_iters(), 77);
        assert_eq!(back.threads(), 2);
        assert!(back.record_trace());
        assert_eq!(back.seed(), 1234);
        assert_eq!(back.priority(), -3);
        assert_eq!(back.chunk_size(), 256);
        assert_eq!(back.batches_per_epoch(), 5);
        assert_eq!(back.batch_sampling(), BatchSampling::Replacement);
        assert!(back.prefetch());
        assert_eq!(back.guard(), EnergyGuard::Sampled { rows: 2048 });
        assert!(back.pin_threads());
        assert_eq!(back.client(), Some("tenant-a"));
        assert_eq!(back.retry(), Some(&RetryPolicy::transient(3, Duration::from_millis(25))));
        assert!(back.cpu_fallback());
        assert_eq!(back.checkpoint(), Some(&CheckpointPolicy::new("ck/dir", 2)));
        assert!(back.reseed_empty());
        let cfg = back.solver_config();
        assert_eq!(cfg.epsilon1, 0.01);
        assert_eq!(cfg.epsilon2, 0.4);
        assert_eq!(cfg.m_max, 12);
    }

    #[test]
    fn warm_start_and_model_job_journal_roundtrip() {
        let req = ClusterRequest::builder()
            .registry("Birch", 0.001)
            .k(5)
            .refresh_model("models/dir", "prod-model")
            .threads(1)
            .build()
            .unwrap();
        let spec = req.journal_spec().expect("warm-start seeds journal by id");
        let back = ClusterRequest::from_journal_spec(&spec).unwrap();
        match back.init() {
            InitSpec::WarmStart { registry, model } => {
                assert_eq!(registry, &PathBuf::from("models/dir"));
                assert_eq!(model, "prod-model");
            }
            other => panic!("expected warm-start init, got {other:?}"),
        }
        let job = back.model_job().unwrap();
        assert_eq!(job.kind, ModelJobKind::Refresh);
        assert_eq!(job.registry, PathBuf::from("models/dir"));
        assert_eq!(job.model, "prod-model");

        // Predict jobs journal too — a recovered predict must re-run as a
        // predict, never as a fit.
        let req = ClusterRequest::builder()
            .registry("Birch", 0.001)
            .k(5)
            .predict_with("models/dir", "prod-model")
            .build()
            .unwrap();
        let back = ClusterRequest::from_journal_spec(&req.journal_spec().unwrap()).unwrap();
        assert_eq!(back.model_job().unwrap().kind, ModelJobKind::Predict);

        // Shorn key pairs are typed corruption, not half-applied state.
        let full = req.journal_spec().unwrap();
        for torn in [
            full.replace("job=predict\n", ""),
            full.replace("job_model=prod-model\n", ""),
        ] {
            assert!(matches!(
                ClusterRequest::from_journal_spec(&torn),
                Err(ClusterError::InvalidRequest { field: "journal", .. })
            ));
        }

        // Model ids are validated at build time.
        let bad = ClusterRequest::builder()
            .registry("Birch", 0.001)
            .k(5)
            .fit_into("models/dir", ".hidden")
            .build();
        assert!(matches!(bad, Err(ClusterError::InvalidRequest { field: "model", .. })));
    }

    #[test]
    fn with_k_retargets_method_seeded_requests_only() {
        let req = ClusterRequest::builder().inline(tiny()).k(2).seed(5).build().unwrap();
        let re = req.with_k(3).unwrap();
        assert_eq!(re.k(), 3);
        assert_eq!(re.seed(), 5);
        assert!(matches!(req.with_k(0), Err(ClusterError::InvalidRequest { field: "k", .. })));
        let c0 = Arc::new(DataMatrix::from_rows(&[&[0.0, 0.0], &[1.0, 1.0]]));
        let pinned = ClusterRequest::builder()
            .inline(tiny())
            .k(2)
            .initial_centroids(c0)
            .build()
            .unwrap();
        assert!(matches!(
            pinned.with_k(3),
            Err(ClusterError::InvalidRequest { field: "init", .. })
        ));
    }

    #[test]
    fn inline_and_explicit_centroid_requests_do_not_journal() {
        let req = ClusterRequest::builder().inline(tiny()).k(2).build().unwrap();
        assert!(req.journal_spec().is_none(), "inline data lives only in memory");
        let c0 = Arc::new(DataMatrix::from_rows(&[&[0.0, 0.0], &[1.0, 1.0]]));
        let req = ClusterRequest::builder()
            .registry("Birch", 0.001)
            .k(2)
            .initial_centroids(c0)
            .build()
            .unwrap();
        assert!(req.journal_spec().is_none(), "explicit centroids live only in memory");
    }

    #[test]
    fn journal_spec_rejects_corruption_typed() {
        let spec = ClusterRequest::builder()
            .shard("/tmp/x.fv")
            .k(3)
            .build()
            .unwrap()
            .journal_spec()
            .unwrap();
        for torn in [
            spec.replace("k=3", "k3"),
            spec.replace("k=3", "k=three"),
            format!("{spec}mystery=1\n"),
            format!("{spec}checkpoint_dir=ck\n"),
            spec.replace("sampling=sequential", "sampling=psychic"),
            spec.replace("guard=exact", "guard=sampled"),
            spec.replace("prefetch=false", "prefetch=maybe"),
        ] {
            assert!(
                matches!(
                    ClusterRequest::from_journal_spec(&torn),
                    Err(ClusterError::InvalidRequest { field: "journal", .. })
                ),
                "accepted corrupt spec:\n{torn}"
            );
        }
    }

    #[test]
    fn non_finite_inline_data_is_rejected_with_row_index() {
        let x = Arc::new(DataMatrix::from_rows(&[
            &[0.0, 0.0],
            &[1.0, 0.0],
            &[0.5, f64::NAN],
            &[1.0, 1.0],
        ]));
        let err = ClusterRequest::builder().inline(x).k(2).build().unwrap_err();
        match err {
            ClusterError::InvalidData { row, ref reason, .. } => {
                assert_eq!(row, 2);
                assert!(reason.contains("column 1"), "{reason}");
            }
            other => panic!("expected InvalidData, got {other:?}"),
        }
        let inf = Arc::new(DataMatrix::from_rows(&[&[f64::INFINITY, 0.0], &[1.0, 1.0]]));
        assert!(matches!(
            ClusterRequest::builder().inline(inf).k(1).build(),
            Err(ClusterError::InvalidData { row: 0, .. })
        ));
    }

    #[test]
    fn shard_source_labels_and_fails_typed() {
        let src = DataSource::Shard(PathBuf::from("/no/such/shard.fv"));
        assert!(src.label().starts_with("shard "));
        assert!(matches!(src.materialize(), Err(ClusterError::Data { .. })));
    }

    #[test]
    fn workspace_spec_projection() {
        let req = ClusterRequest::builder()
            .inline(tiny())
            .k(2)
            .engine(EngineKind::Elkan)
            .precision(Precision::F32)
            .threads(2)
            .build()
            .unwrap();
        let spec = req.workspace_spec();
        assert_eq!(spec.engine, EngineKind::Elkan);
        assert_eq!(spec.precision, Precision::F32);
        assert_eq!(spec.threads, 2);
        assert!(spec.artifact_dir.is_none());
    }
}
