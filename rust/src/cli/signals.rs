//! Graceful SIGINT / SIGTERM handling for the long-running subcommands.
//!
//! The first signal trips a process-wide [`CancelToken`]; every solver
//! and the coordinator's workers stop at their next iteration boundary,
//! which lets a checkpointed run flush one final snapshot (the driver
//! writes it on any interruption) so the command can be re-run to
//! resume. A second signal means "stop now": the handler hard-exits
//! with the conventional `128 + signum` status without unwinding.
//!
//! The handler is declared directly against libc's `signal(2)` — which
//! std always links on unix — so no crate dependency is needed (same
//! idiom as the `mmap` bindings in `data::chunks`). Everything it does
//! is async-signal-safe: one atomic counter bump, one atomic store
//! through the token, or `_exit`.

use crate::observe::CancelToken;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::OnceLock;

/// Signals received so far (0 → none, 1 → graceful stop in progress).
static SIGNALS_SEEN: AtomicU32 = AtomicU32::new(0);

/// The token the handler trips. Installed once per process; read-only
/// from the handler (a `OnceLock` load is a plain atomic read).
static TOKEN: OnceLock<CancelToken> = OnceLock::new();

#[cfg(unix)]
const SIGINT: i32 = 2;
#[cfg(unix)]
const SIGTERM: i32 = 15;

#[cfg(unix)]
unsafe extern "C" {
    fn signal(signum: i32, handler: usize) -> usize;
    fn _exit(status: i32) -> !;
}

#[cfg(unix)]
extern "C" fn on_signal(signum: i32) {
    let prior = SIGNALS_SEEN.fetch_add(1, Ordering::AcqRel);
    if prior == 0 {
        if let Some(token) = TOKEN.get() {
            token.cancel();
        }
    } else {
        // Second signal: the user wants out *now*. `_exit` skips
        // destructors and buffered-IO flushes by design — the snapshot
        // format is torn-write-safe, so an interrupted flush is
        // detected (and the previous snapshot kept) on the next run.
        unsafe { _exit(128 + signum) };
    }
}

/// The process-wide interruption token, installing the SIGINT/SIGTERM
/// handlers on first use. Subsequent calls return the same token. On
/// non-unix targets (or when the handlers cannot be installed) the
/// token is still returned — it simply never trips.
pub fn interrupt_token() -> CancelToken {
    let token = TOKEN.get_or_init(CancelToken::new).clone();
    #[cfg(unix)]
    {
        static INSTALLED: OnceLock<()> = OnceLock::new();
        INSTALLED.get_or_init(|| unsafe {
            signal(SIGINT, on_signal as usize);
            signal(SIGTERM, on_signal as usize);
        });
    }
    token
}

/// Whether a graceful stop is in progress (at least one signal seen).
pub fn interrupted() -> bool {
    SIGNALS_SEEN.load(Ordering::Acquire) > 0
}

#[cfg(test)]
mod tests {
    use super::*;

    // CancelToken is one-way and this token is process-wide, so the test
    // must never actually trip it (it would cancel every other test's
    // runs). Repeated installation being idempotent and the token staying
    // clear is all that can be checked in-process; the end-to-end signal
    // behavior is exercised by the crash-recovery leg of scripts/ci.sh.
    #[test]
    fn token_is_shared_and_initially_clear() {
        let a = interrupt_token();
        let b = interrupt_token();
        assert!(!a.is_cancelled() && !b.is_cancelled());
        assert!(!interrupted());
    }
}
