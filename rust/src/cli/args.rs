//! Tiny flag parser: `--key value` pairs and boolean `--flag`s.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Parsed flags.
#[derive(Debug, Default, Clone)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
}

/// Boolean flags the CLI understands (everything else expects a value).
const BOOL_FLAGS: &[&str] = &[
    "compare",
    "trace",
    "verbose",
    "quiet",
    "center",
    "reseed-empty",
    "cpu-fallback",
    "gc",
    "json",
    "prefetch",
    "pin-threads",
];

impl Args {
    /// Parse an argv slice (after the subcommand).
    pub fn parse(argv: &[&str]) -> Result<Self> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let token = argv[i];
            let Some(name) = token.strip_prefix("--") else {
                bail!("unexpected positional argument '{token}'");
            };
            if name.is_empty() {
                bail!("bare '--' is not supported");
            }
            // --key=value form.
            if let Some((k, v)) = name.split_once('=') {
                out.values.insert(k.to_string(), v.to_string());
                i += 1;
                continue;
            }
            if BOOL_FLAGS.contains(&name) {
                out.flags.push(name.to_string());
                i += 1;
                continue;
            }
            let Some(value) = argv.get(i + 1) else {
                bail!("flag --{name} expects a value");
            };
            out.values.insert(name.to_string(), value.to_string());
            i += 2;
        }
        Ok(out)
    }

    /// Value of `--key value` (or `--key=value`).
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(String::as_str)
    }

    /// Whether a boolean `--flag` was given.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_pairs_and_flags() {
        let a = Args::parse(&["--k", "10", "--compare", "--dataset", "Birch"]).unwrap();
        assert_eq!(a.get("k"), Some("10"));
        assert_eq!(a.get("dataset"), Some("Birch"));
        assert!(a.flag("compare"));
        assert!(!a.flag("trace"));
    }

    #[test]
    fn parses_equals_form() {
        let a = Args::parse(&["--scale=0.5"]).unwrap();
        assert_eq!(a.get("scale"), Some("0.5"));
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&["--k"]).is_err());
    }

    #[test]
    fn positional_is_error() {
        assert!(Args::parse(&["oops"]).is_err());
    }
}
