//! Command-line interface (hand-rolled; no `clap` offline).
//!
//! Subcommands:
//!
//! * `run`     — one clustering experiment (paper method and/or baseline).
//! * `datagen` — materialize a registry dataset to CSV / binary.
//! * `serve`   — run the coordinator service on a synthetic job stream.
//! * `fit`     — fit a model and register it in a model registry.
//! * `predict` — batch-assign a dataset against a registered model.
//! * `refresh` — re-cluster warm-started from a registered model.
//! * `sweep`   — fit a ladder of k values, registering each model.
//! * `models`  — list / delete / gc registered models.
//! * `inspect` — show the AOT artifact manifest.
//! * `telemetry` — dump the metrics registry / validate an event log.
//! * `help`    — usage.
//!
//! Both `run` and `serve` are thin fronts over the same
//! [`ClusterRequest`] / [`ClusterSession`] API the library exposes.

mod args;
pub mod signals;

pub use args::Args;

use crate::config::{Acceleration, EngineKind, ExperimentConfig, Precision};
use crate::coordinator::{Coordinator, CoordinatorConfig};
use crate::data::{self, DataMatrix};
use crate::init::InitMethod;
use crate::observe::NoopObserver;
use crate::persist::CheckpointPolicy;
use crate::request::ClusterRequest;
use crate::session::ClusterSession;
use anyhow::{bail, Context, Result};
use std::sync::Arc;

const USAGE: &str = "\
aakm — Fast K-Means with Anderson Acceleration (Zhang et al. 2018)

USAGE:
    repro <command> [flags]

COMMANDS:
    run      Run one clustering experiment
             --dataset <registry name | csv/fvecs path>   (default Birch)
             --k <clusters>                               (default 10)
             --init <random|k-means++|afk-mc2|bf|clarans> (default k-means++)
             --engine <naive|hamerly|elkan|yinyang|pjrt|minibatch>
                                                          (default hamerly)
             --chunk-size <n>          mini-batch chunk rows (default 4096);
               with --engine minibatch a .fv dataset streams out-of-core
               through a memory-mapped shard, chunk by chunk
             --batches-per-epoch <n>   0 = full pass per epoch (default 0;
               a positive cap trains each epoch on only the FIRST n chunks
               of the source — meant for unbounded generators)
             --sampling <sequential|replacement>   how mini-batch epochs
               draw batches (default sequential — deterministic pass;
               replacement = uniform draws with replacement, seeded)
             --prefetch   overlap chunk reads with the sweep: a background
               thread decodes chunk t+1 while the lanes sweep chunk t
               (minibatch only; bit-identical results, just faster)
             --guard <exact|sampled:N>   mini-batch energy checkpoint:
               exact full pass per epoch (default) or a fixed seeded
               reservoir of N rows — O(N) per epoch instead of O(n)
             --pin-threads   pin sweep lanes (and the prefetcher) to
               distinct CPUs via sched_setaffinity (Linux; no-op elsewhere)
             --accel <none|fixed:M|dynamic:M>             (default dynamic:2;
               with minibatch this is the epoch-level Anderson step)
             --precision <f64|f32>                        (default f64; f32
               stores samples in single precision for a ~2x faster assign
               sweep and auto-enables pre-centering)
             --center     pre-center data (subtract the per-dimension mean;
               reported centroids are mapped back — always safe, distances
               are translation-invariant)
             --checkpoint-dir <dir>    write a crash-safe snapshot of the
               solver state into <dir> as the run progresses; re-running
               the same command resumes bit-identically from it (SIGINT /
               SIGTERM also flush one final snapshot before exiting)
             --checkpoint-every <n>    snapshot cadence in iterations
               (epochs for minibatch; default 1, needs --checkpoint-dir)
             --reseed-empty  deterministically re-seed clusters that go
               empty instead of carrying a dead centroid
             --seed <u64>  --scale <0..1>  --threads <n>
             --config <file.toml>   --compare   --trace
    datagen  Write a registry dataset to disk
             --dataset <name> --scale <0..1> --out <path.{csv,fv}>
    serve    Run the coordinator service demo
             --workers <n> --jobs <n> --k <clusters> --engine <...>
             --precision <f64|f32> --scale <0..1>
             --policy <block|shed|wait:<ms>>   full-queue admission control
               (default block = backpressure; shed fails fast with a typed
               overload error; wait:<ms> bounds the wait, then sheds)
             --retries <n>   total attempts for transiently failing jobs
               (default 1 = no retry; backoff is seeded-deterministic)
             --cpu-fallback  serve pjrt jobs on the CPU engine when the
               runtime fails to load (degradation echoed per job)
             --journal <dir>   write-ahead job journal: every submission
               is recorded before it runs, and on startup incomplete
               jobs from a previous (crashed or interrupted) serve are
               re-enqueued and counted in the final stats line
             --metrics-out <file>   enable the telemetry registry and
               write the Prometheus text exposition there at exit (also
               prints a p50/p99 queue-wait line in the stats)
             --events-out <file.jsonl>   enable the structured event log:
               one JSON object per line (job lifecycle + per-iteration
               events), written by a non-blocking background writer
    fit      Fit a model and register it
             --registry <dir> --model <id>  plus the `run` data/solver
             flags (--dataset --k --engine --precision --accel --seed
             --threads --scale --checkpoint-dir ...)
    predict  Batch-assign a dataset against a registered model (no solver
             run; served on the SIMD distance kernels)
             --registry <dir> --model <id> --dataset <...> [--scale <s>]
             --out <path.csv>   write per-sample `label,distance` rows
    refresh  Re-cluster warm-started from a registered model and save it
             back with a centroid-drift report (--k defaults to the
             model's k); flags as `fit`
    sweep    Fit a ladder of cluster counts over one dataset, sharing the
             warm workspace and sample-norm cache, registering each model
             as <id>-k<K>; prints the elbow table
             --registry <dir> --model <base-id> --ks 2,4,8  plus run flags
    models   List registered models
             --registry <dir> [--delete <id>] [--gc]
    inspect  Print the artifact manifest
             --artifacts <dir>
    telemetry  Observability tooling
             dump [--json]         print this process's metrics registry
               (Prometheus text exposition, or the JSON dump)
             check --events <file.jsonl>   validate an event log against
               the versioned schema; summarizes counts per event kind and
               tolerates a torn final line (crash mid-write)
    help     This message
";

/// CLI entry point (called from `main`).
pub fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    dispatch(&argv.iter().map(String::as_str).collect::<Vec<_>>())
}

/// Dispatch on a parsed argv (separated from `run` for tests).
pub fn dispatch(argv: &[&str]) -> Result<()> {
    let Some((&cmd, rest)) = argv.split_first() else {
        println!("{USAGE}");
        return Ok(());
    };
    // `telemetry` takes a positional action (`dump` / `check`) ahead of
    // its flags, which the strict flag parser would reject.
    if cmd == "telemetry" {
        return cmd_telemetry(rest);
    }
    let args = Args::parse(rest)?;
    match cmd {
        "run" => cmd_run(&args),
        "datagen" => cmd_datagen(&args),
        "serve" => cmd_serve(&args),
        "fit" => cmd_fit(&args, false),
        "refresh" => cmd_fit(&args, true),
        "predict" => cmd_predict(&args),
        "sweep" => cmd_sweep(&args),
        "models" => cmd_models(&args),
        "inspect" => cmd_inspect(&args),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command '{other}' (try `repro help`)"),
    }
}

/// `telemetry dump` — render this process's metrics registry (Prometheus
/// text by default, `--json` for the JSON dump); `telemetry check
/// --events <file>` — validate a JSONL event log against the versioned
/// schema and summarize it per event kind.
fn cmd_telemetry(rest: &[&str]) -> Result<()> {
    let Some((&action, rest)) = rest.split_first() else {
        bail!("telemetry needs an action: dump | check (try `repro help`)");
    };
    let args = Args::parse(rest)?;
    match action {
        "dump" => {
            // Enabling first guarantees every family renders (a disabled
            // registry would still render, but enable() is what a scraper
            // of a live process would see).
            crate::telemetry::enable();
            if args.flag("json") {
                println!("{}", crate::telemetry::json_dump());
            } else {
                print!("{}", crate::telemetry::prometheus_text());
            }
            Ok(())
        }
        "check" => {
            let path = args.get("events").context("--events <file.jsonl> required")?;
            let (events, torn) =
                crate::telemetry::events::read_events(std::path::Path::new(path))
                    .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
            let mut counts: Vec<(String, usize)> = Vec::new();
            for ev in &events {
                match counts.iter_mut().find(|(k, _)| *k == ev.kind) {
                    Some((_, c)) => *c += 1,
                    None => counts.push((ev.kind.clone(), 1)),
                }
            }
            println!(
                "{path}: {} valid event(s){}",
                events.len(),
                if torn { ", torn final line tolerated" } else { "" }
            );
            for (kind, count) in counts {
                println!("  {kind:>8}  {count}");
            }
            Ok(())
        }
        other => bail!("unknown telemetry action '{other}' (dump|check)"),
    }
}

/// Load a dataset: registry name, or a CSV / fvecs path.
pub fn load_dataset(name: &str, scale: f64) -> Result<DataMatrix> {
    if let Some(spec) = data::dataset_by_name(name) {
        return Ok(spec.generate_scaled(scale));
    }
    let path = std::path::Path::new(name);
    if path.exists() {
        return if path.extension().is_some_and(|e| e == "fv") {
            data::load_fvecs(path)
        } else {
            data::load_csv(path)
        };
    }
    bail!("'{name}' is neither a registry dataset nor a readable file");
}

fn experiment_from_args(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => {
            let doc = crate::config::ConfigDoc::parse_file(std::path::Path::new(path))
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            ExperimentConfig::from_doc(&doc).map_err(|e| anyhow::anyhow!("{e}"))?
        }
        None => ExperimentConfig::default(),
    };
    if let Some(v) = args.get("dataset") {
        cfg.dataset = v.to_string();
    }
    if let Some(v) = args.get("k") {
        cfg.k = v.parse().context("--k")?;
    }
    if let Some(v) = args.get("init") {
        cfg.init = InitMethod::parse(v).with_context(|| format!("bad --init {v}"))?;
    }
    if let Some(v) = args.get("engine") {
        cfg.engine = EngineKind::parse(v).with_context(|| format!("bad --engine {v}"))?;
    }
    if let Some(v) = args.get("accel") {
        cfg.accel =
            crate::config::parse_accel(v).with_context(|| format!("bad --accel {v}"))?;
    }
    if let Some(v) = args.get("seed") {
        cfg.seed = v.parse().context("--seed")?;
    }
    if let Some(v) = args.get("scale") {
        cfg.scale = v.parse().context("--scale")?;
    }
    if let Some(v) = args.get("threads") {
        cfg.threads = v.parse().context("--threads")?;
    }
    if let Some(v) = args.get("precision") {
        cfg.precision =
            Precision::parse(v).with_context(|| format!("bad --precision {v}"))?;
    }
    if let Some(v) = args.get("chunk-size") {
        cfg.chunk_size = v.parse().context("--chunk-size")?;
    }
    if let Some(v) = args.get("sampling") {
        cfg.sampling = crate::config::BatchSampling::parse(v)
            .with_context(|| format!("bad --sampling {v} (sequential|replacement)"))?;
    }
    if let Some(v) = args.get("batches-per-epoch") {
        cfg.batches_per_epoch = v.parse().context("--batches-per-epoch")?;
    }
    if args.flag("prefetch") {
        cfg.prefetch = true;
    }
    if let Some(v) = args.get("guard") {
        cfg.guard = crate::config::EnergyGuard::parse(v)
            .with_context(|| format!("bad --guard {v} (exact|sampled:N)"))?;
    }
    if args.flag("pin-threads") {
        cfg.pin_threads = true;
    }
    Ok(cfg)
}

/// Project an [`ExperimentConfig`] + pre-loaded data into a request
/// builder (callers may still attach a model job before building).
fn builder_from_experiment(
    cfg: &ExperimentConfig,
    source: crate::request::DataSource,
    trace: bool,
    artifacts: &str,
    checkpoint: Option<CheckpointPolicy>,
    reseed_empty: bool,
) -> crate::request::ClusterRequestBuilder {
    let mut builder = ClusterRequest::builder()
        .source(source)
        .k(cfg.k)
        .init(cfg.init)
        .engine(cfg.engine)
        .precision(cfg.precision)
        .accel(cfg.accel)
        .epsilons(cfg.epsilon1, cfg.epsilon2)
        .m_max(cfg.m_max)
        .max_iters(cfg.max_iters)
        .threads(cfg.threads)
        .seed(cfg.seed)
        .record_trace(trace)
        .chunk_size(cfg.chunk_size)
        .batches_per_epoch(cfg.batches_per_epoch)
        .batch_sampling(cfg.sampling)
        .prefetch(cfg.prefetch)
        .guard(cfg.guard)
        .pin_threads(cfg.pin_threads)
        .reseed_empty(reseed_empty)
        .artifact_dir(artifacts);
    if let Some(policy) = checkpoint {
        builder = builder.checkpoint(policy);
    }
    builder
}

/// Project an [`ExperimentConfig`] + pre-loaded data into the unified
/// request shape (the single job description every layer consumes).
fn request_from_experiment(
    cfg: &ExperimentConfig,
    source: crate::request::DataSource,
    trace: bool,
    artifacts: &str,
    checkpoint: Option<CheckpointPolicy>,
    reseed_empty: bool,
) -> Result<ClusterRequest> {
    Ok(builder_from_experiment(cfg, source, trace, artifacts, checkpoint, reseed_empty).build()?)
}

/// Parse `--checkpoint-dir` / `--checkpoint-every` into a policy.
fn checkpoint_from_args(args: &Args) -> Result<Option<CheckpointPolicy>> {
    match (args.get("checkpoint-dir"), args.get("checkpoint-every")) {
        (Some(dir), every) => {
            let every: usize = every.unwrap_or("1").parse().context("--checkpoint-every")?;
            if every == 0 {
                bail!("--checkpoint-every must be >= 1");
            }
            Ok(Some(CheckpointPolicy::new(dir, every)))
        }
        (None, Some(_)) => bail!("--checkpoint-every needs --checkpoint-dir"),
        (None, None) => Ok(None),
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    use crate::request::DataSource;
    let cfg = experiment_from_args(args)?;
    let artifacts = args.get("artifacts").unwrap_or("artifacts");
    // A `.fv` dataset under the mini-batch engine streams out-of-core as
    // a memory-mapped shard; every other combination loads in RAM.
    let shard_path = std::path::Path::new(&cfg.dataset);
    let streams_shard = cfg.engine == EngineKind::MiniBatch
        && shard_path.extension().is_some_and(|e| e == "fv")
        && shard_path.exists();
    let trace = args.flag("trace");
    let (source, mean) = if streams_shard {
        // Pre-centering needs the whole dataset in hand; a streamed shard
        // is deliberately never resident. Reject the combination loudly
        // instead of silently changing numerical behavior vs. a RAM run.
        if args.flag("center") || cfg.precision == Precision::F32 {
            bail!(
                "--center / --precision f32 (which auto-centers) cannot be applied while \
                 streaming a shard; pre-center the data when writing the shard \
                 (data::center before ShardWriter), or drop --engine minibatch to load it \
                 in RAM"
            );
        }
        if cfg.scale != 1.0 {
            bail!(
                "--scale only applies to generated registry datasets; a streamed shard is \
                 always clustered whole (write a smaller shard instead)"
            );
        }
        let shard = data::MmapShardSource::open(shard_path)?;
        println!(
            "dataset {} (shard, n={}, d={}), k={}, engine=minibatch, chunk={}, sampling={}, \
             seed={}",
            cfg.dataset,
            shard.n(),
            shard.d(),
            cfg.k,
            cfg.chunk_size,
            cfg.sampling.name(),
            cfg.seed
        );
        (DataSource::Shard(shard_path.to_path_buf()), None)
    } else {
        let mut x = load_dataset(&cfg.dataset, cfg.scale)?;
        // Pre-centering is the f32 mode's accuracy companion (see
        // linalg::kernel): on by default there, opt-in via --center
        // otherwise. Distances are translation-invariant, so the
        // clustering is unchanged; reported centroids are mapped back
        // below.
        let centering = args.flag("center") || cfg.precision == Precision::F32;
        let mean = if centering { Some(data::center(&mut x)) } else { None };
        let sampling = if cfg.engine == EngineKind::MiniBatch {
            format!(", sampling={}", cfg.sampling.name())
        } else {
            String::new()
        };
        println!(
            "dataset {} (n={}, d={}), k={}, init={}, engine={}, precision={}{}{}, seed={}",
            cfg.dataset,
            x.n(),
            x.d(),
            cfg.k,
            cfg.init.name(),
            cfg.engine.name(),
            cfg.precision.name(),
            if centering { ", pre-centered" } else { "" },
            sampling,
            cfg.seed
        );
        (DataSource::Inline(Arc::new(x)), mean)
    };
    let checkpoint = checkpoint_from_args(args)?;
    let reseed_empty = args.flag("reseed-empty");
    let request = request_from_experiment(
        &cfg,
        source.clone(),
        trace,
        artifacts,
        checkpoint.clone(),
        reseed_empty,
    )?;
    let mut session = ClusterSession::open(request)?;
    // First SIGINT/SIGTERM stops the solver at an iteration boundary
    // (flushing a final snapshot when checkpointing); a second hard-exits.
    let cancel = signals::interrupt_token();
    let mut report = session.run_with(&mut NoopObserver, &cancel)?;
    if let Some(mean) = &mean {
        data::uncenter(&mut report.centroids, mean);
    }
    let unit = if cfg.engine == EngineKind::MiniBatch {
        " (iterations = epochs)"
    } else {
        ""
    };
    println!("ours ({:?}): {}{unit}", cfg.accel, report.summary());
    println!("  phases: {}", report.phases.summary());
    if trace {
        println!("  energy trace: {:?}", &report.energy_trace);
        println!("  m trace:      {:?}", &report.m_trace);
    }
    if report.cancelled {
        match &checkpoint {
            Some(ck) => println!(
                "interrupted — final snapshot flushed to {}; re-run the same command to \
                 resume where this left off",
                ck.dir.display()
            ),
            None => println!(
                "interrupted — no --checkpoint-dir was set, so this partial run is not \
                 resumable"
            ),
        }
        return Ok(());
    }
    if args.flag("compare") {
        // The baseline differs only in acceleration, so it can reuse the
        // warm workspace (same engine / precision / threads). Under the
        // mini-batch engine this compares Anderson-on vs Anderson-off
        // epochs on the same stream.
        let mut base_cfg = cfg.clone();
        base_cfg.accel = Acceleration::None;
        // The baseline never checkpoints: its fingerprint differs (accel
        // off), so sharing the directory would only clobber the main
        // run's snapshot.
        let base_req =
            request_from_experiment(&base_cfg, source, false, artifacts, None, reseed_empty)?;
        let mut base_session =
            ClusterSession::with_workspace(base_req, session.into_workspace())?;
        let base = base_session.run()?;
        println!("baseline (no accel): {}", base.summary());
        let speedup = base.seconds / report.seconds.max(1e-12);
        println!(
            "speedup {speedup:.2}x, iteration ratio {:.2}x",
            base.iterations as f64 / report.iterations.max(1) as f64
        );
    }
    Ok(())
}

fn cmd_datagen(args: &Args) -> Result<()> {
    let name = args.get("dataset").context("--dataset required")?;
    let scale: f64 = args.get("scale").unwrap_or("1.0").parse()?;
    let out = args.get("out").context("--out required")?;
    let spec = data::dataset_by_name(name)
        .with_context(|| format!("unknown registry dataset '{name}'"))?;
    let x = spec.generate_scaled(scale);
    let path = std::path::Path::new(out);
    if path.extension().is_some_and(|e| e == "fv") {
        data::save_fvecs(path, &x)?;
    } else {
        data::save_csv(path, &x)?;
    }
    println!("wrote {} (n={}, d={})", out, x.n(), x.d());
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use crate::coordinator::SubmitPolicy;
    use crate::error::ClusterError;
    use crate::request::RetryPolicy;
    let workers: usize = args.get("workers").unwrap_or("2").parse()?;
    let jobs: usize = args.get("jobs").unwrap_or("8").parse()?;
    let k: usize = args.get("k").unwrap_or("10").parse()?;
    let engine = EngineKind::parse(args.get("engine").unwrap_or("hamerly"))
        .context("bad --engine")?;
    let precision = Precision::parse(args.get("precision").unwrap_or("f64"))
        .context("bad --precision (f64|f32)")?;
    let scale: f64 = args.get("scale").unwrap_or("0.05").parse()?;
    let policy = match args.get("policy").unwrap_or("block") {
        "block" => SubmitPolicy::Block,
        "shed" => SubmitPolicy::Shed,
        other => match other.strip_prefix("wait:") {
            Some(ms) => SubmitPolicy::TrySubmitFor(std::time::Duration::from_millis(
                ms.parse().context("--policy wait:<ms>")?,
            )),
            None => bail!("bad --policy '{other}' (block|shed|wait:<ms>)"),
        },
    };
    let retries: u32 = args.get("retries").unwrap_or("1").parse()?;
    if retries == 0 {
        bail!("--retries counts total attempts and must be >= 1");
    }
    let cpu_fallback = args.flag("cpu-fallback");
    let journal = args.get("journal").map(std::path::PathBuf::from);
    // Observability sinks: either flag turns the process-wide metrics
    // registry on; --events-out additionally installs the JSONL event log
    // for the whole serve (job lifecycle + per-iteration events).
    let metrics_out = args.get("metrics-out").map(std::path::PathBuf::from);
    let events_out = args.get("events-out").map(std::path::PathBuf::from);
    if metrics_out.is_some() || events_out.is_some() {
        crate::telemetry::enable();
    }
    let events_guard = match &events_out {
        Some(path) => Some(
            crate::telemetry::events::install(path)
                .map_err(|e| anyhow::anyhow!("--events-out {}: {e}", path.display()))?,
        ),
        None => None,
    };
    let coord = Coordinator::try_start(CoordinatorConfig {
        workers,
        queue_depth: jobs.max(4),
        solver_threads: 1,
        artifact_dir: args.get("artifacts").unwrap_or("artifacts").into(),
        submit_policy: policy,
        journal_dir: journal.clone(),
    })?;
    // Bridge the process-wide signal token to the coordinator: the first
    // SIGINT/SIGTERM cancels every queued and running job, which resolves
    // all handles (incomplete jobs stay journaled for the next serve).
    let sig = signals::interrupt_token();
    let watcher_done = crate::observe::CancelToken::new();
    {
        let (sig, done, coord_cancel) =
            (sig.clone(), watcher_done.clone(), coord.cancel_token());
        std::thread::spawn(move || loop {
            if sig.is_cancelled() {
                coord_cancel.cancel();
                return;
            }
            if done.is_cancelled() {
                return;
            }
            std::thread::sleep(std::time::Duration::from_millis(20));
        });
    }
    let sw = crate::metrics::Stopwatch::start();
    let names = ["HTRU2", "Birch", "Shuttle", "Eb"];
    let mut handles = Vec::new();
    if let Some(dir) = &journal {
        let recovered = coord.recover(dir)?;
        if !recovered.is_empty() {
            println!(
                "recovered {} incomplete job(s) from the journal at {}",
                recovered.len(),
                dir.display()
            );
        }
        handles.extend(recovered);
    }
    for id in 0..jobs as u64 {
        let mut builder = ClusterRequest::builder()
            .registry(names[id as usize % names.len()], scale)
            .k(k)
            .init(InitMethod::KMeansPlusPlus)
            .seed(id)
            .accel(Acceleration::DynamicM(2))
            .engine(engine)
            .precision(precision)
            // Tag alternating clients so the fair queue has lanes to
            // interleave (a demo of per-client fairness, not a real tenant
            // model).
            .client(format!("client-{}", id % 2))
            .cpu_fallback(cpu_fallback);
        if retries > 1 {
            builder = builder.retry(RetryPolicy::transient(
                retries,
                std::time::Duration::from_millis(10),
            ));
        }
        match coord.submit(builder.build()?) {
            Ok(h) => handles.push(h),
            Err(ClusterError::Overloaded) => {
                println!("job {id:>3} SHED: queue full under --policy {policy:?}")
            }
            Err(e) => return Err(e.into()),
        }
    }
    let admitted = handles.len();
    let results = Coordinator::wait_all(handles);
    let total = sw.seconds();
    let mut ok = 0;
    for r in &results {
        match &r.outcome {
            Ok(out) => {
                ok += 1;
                let attempts = if out.attempts > 1 {
                    format!("  ({}x attempts)", out.attempts)
                } else {
                    String::new()
                };
                let degraded = if out.degraded.is_some() {
                    "  [degraded to cpu]"
                } else {
                    ""
                };
                println!(
                    "job {:>3} worker {} wait {:>9.1?} service {:>9.1?}  {} iters  mse {:.4}  [{}/{}]{attempts}{degraded}",
                    r.id,
                    r.worker,
                    r.queue_wait,
                    r.service_time,
                    out.iterations,
                    out.mse,
                    out.engine.name(),
                    out.precision.name()
                );
            }
            Err(e) => println!("job {:>3} FAILED: {e}", r.id),
        }
    }
    let stats = coord.stats();
    println!(
        "served {ok}/{admitted} admitted jobs in {total:.2}s ({:.2} jobs/s)",
        admitted as f64 / total.max(1e-9)
    );
    println!(
        "admission: {} submitted, {} shed, {} recovered; {} retries, {} worker respawns, \
         {} failed, {} degraded",
        stats.submitted,
        stats.shed,
        stats.recovered,
        stats.retries,
        stats.respawns,
        stats.failed,
        stats.degraded
    );
    if crate::telemetry::enabled() {
        let qw = &crate::telemetry::metrics().job_queue_wait;
        if qw.count() > 0 {
            println!(
                "queue wait: p50 {:.1}ms  p99 {:.1}ms over {} pickups",
                qw.quantile(0.5) * 1e3,
                qw.quantile(0.99) * 1e3,
                qw.count()
            );
        }
    }
    watcher_done.cancel();
    coord.shutdown();
    if let Some(guard) = events_guard {
        guard.close();
        println!(
            "events: JSONL log written to {} ({} dropped under backpressure)",
            events_out.as_ref().expect("guard implies path").display(),
            crate::telemetry::events::dropped()
        );
    }
    if let Some(path) = &metrics_out {
        std::fs::write(path, crate::telemetry::prometheus_text())
            .with_context(|| format!("--metrics-out {}", path.display()))?;
        println!("metrics: Prometheus exposition written to {}", path.display());
    }
    if signals::interrupted() {
        match &journal {
            Some(dir) => println!(
                "interrupted — unfinished jobs stay journaled; restart with --journal {} \
                 to re-enqueue them",
                dir.display()
            ),
            None => println!("interrupted — no --journal dir, unfinished jobs are dropped"),
        }
    }
    Ok(())
}

/// Run one model-lifecycle request through a single-worker coordinator —
/// the same dispatch path `serve` uses, so fit/predict/refresh exercise
/// the service plumbing (admission, journal hooks, retry classification)
/// even from the CLI.
fn run_model_request(request: ClusterRequest) -> Result<crate::coordinator::JobOutcome> {
    let coord = Coordinator::try_start(CoordinatorConfig {
        workers: 1,
        queue_depth: 1,
        ..CoordinatorConfig::default()
    })?;
    let handle = coord.submit(request)?;
    let result = handle.wait();
    coord.shutdown();
    Ok(result.outcome?)
}

/// `fit` and `refresh` share one implementation: both run the solver and
/// persist the converged model; refresh additionally warm-starts from the
/// stored centroids and records a drift report.
fn cmd_fit(args: &Args, refresh: bool) -> Result<()> {
    use crate::request::DataSource;
    let registry = args.get("registry").context("--registry required")?;
    let model = args.get("model").context("--model required")?;
    let mut cfg = experiment_from_args(args)?;
    let artifacts = args.get("artifacts").unwrap_or("artifacts");
    if refresh && args.get("k").is_none() {
        // A refresh re-clusters at the model's own k unless overridden.
        let rec = crate::registry::ModelRegistry::open(registry)?.load(model)?;
        cfg.k = rec.centroids.n();
    }
    let x = load_dataset(&cfg.dataset, cfg.scale)?;
    println!(
        "{} model '{model}' on {} (n={}, d={}), k={}, engine={}, precision={}, seed={}",
        if refresh { "refresh" } else { "fit" },
        cfg.dataset,
        x.n(),
        x.d(),
        cfg.k,
        cfg.engine.name(),
        cfg.precision.name(),
        cfg.seed
    );
    let checkpoint = checkpoint_from_args(args)?;
    let builder = builder_from_experiment(
        &cfg,
        DataSource::Inline(Arc::new(x)),
        false,
        artifacts,
        checkpoint,
        args.flag("reseed-empty"),
    );
    let builder = if refresh {
        builder.refresh_model(registry, model)
    } else {
        builder.fit_into(registry, model)
    };
    let out = run_model_request(builder.build()?)?;
    println!(
        "registered '{model}': {} iters ({} accepted), energy {:.6e}, mse {:.6e}, converged={}",
        out.iterations, out.accepted, out.energy, out.mse, out.converged
    );
    if let Some(d) = &out.drift {
        println!(
            "drift vs previous: max displacement {:.4e}, mean {:.4e}, energy {:.6e} -> {:.6e}",
            d.max_displacement, d.mean_displacement, d.energy_before, d.energy_after
        );
    }
    Ok(())
}

fn cmd_predict(args: &Args) -> Result<()> {
    let registry = args.get("registry").context("--registry required")?;
    let model = args.get("model").context("--model required")?;
    let cfg = experiment_from_args(args)?;
    let x = load_dataset(&cfg.dataset, cfg.scale)?;
    let n = x.n();
    // k is irrelevant to serving (the model pins it) but the builder
    // validates it; the naive engine keeps the workspace cheap — predict
    // only uses its thread pool and kernel scratch.
    let request = ClusterRequest::builder()
        .inline(Arc::new(x))
        .k(1)
        .engine(EngineKind::Naive)
        .threads(cfg.threads)
        .predict_with(registry, model)
        .build()?;
    let out = run_model_request(request)?;
    let p = out.prediction.context("predict jobs return a prediction")?;
    println!(
        "predicted {n} samples against '{model}' [{}]: energy {:.6e}, mse {:.6e}",
        out.precision.name(),
        out.energy,
        out.mse
    );
    if let Some(path) = args.get("out") {
        let mut s = String::with_capacity(p.labels.len() * 12 + 16);
        s.push_str("label,distance\n");
        for (l, d) in p.labels.iter().zip(&p.distances) {
            s.push_str(&format!("{l},{d:.17e}\n"));
        }
        std::fs::write(path, s).with_context(|| format!("writing {path}"))?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    use crate::request::DataSource;
    let registry = args.get("registry").context("--registry required")?;
    let base_id = args.get("model").context("--model required")?;
    let ks: Vec<usize> = args
        .get("ks")
        .context("--ks required (comma-separated cluster counts, e.g. 2,4,8)")?
        .split(',')
        .map(|s| s.trim().parse::<usize>())
        .collect::<std::result::Result<_, _>>()
        .context("--ks")?;
    let cfg = experiment_from_args(args)?;
    let artifacts = args.get("artifacts").unwrap_or("artifacts");
    let x = load_dataset(&cfg.dataset, cfg.scale)?;
    println!(
        "sweep '{base_id}' on {} (n={}, d={}) over k in {ks:?}, engine={}, seed={}",
        cfg.dataset,
        x.n(),
        x.d(),
        cfg.engine.name(),
        cfg.seed
    );
    let base = builder_from_experiment(
        &cfg,
        DataSource::Inline(Arc::new(x)),
        false,
        artifacts,
        None,
        args.flag("reseed-empty"),
    )
    .build()?;
    let reg = crate::registry::ModelRegistry::open(registry)?;
    let report = crate::registry::sweep(&reg, &base, &ks, base_id)?;
    print!("{}", report.table());
    Ok(())
}

fn cmd_models(args: &Args) -> Result<()> {
    let dir = args.get("registry").context("--registry required")?;
    let reg = crate::registry::ModelRegistry::open(dir)?;
    if let Some(id) = args.get("delete") {
        println!(
            "{}",
            if reg.delete(id)? { "deleted" } else { "no such model" }
        );
        return Ok(());
    }
    if args.flag("gc") {
        let removed = reg.gc()?;
        println!("gc removed {} file(s)", removed.len());
        for f in &removed {
            println!("  {f}");
        }
        return Ok(());
    }
    let models = reg.list()?;
    if models.is_empty() {
        println!("no models registered in {dir}");
        return Ok(());
    }
    println!(
        "{:<24} {:>5} {:>4}  {:<9} {:<5} {:>9}  energy",
        "model", "k", "d", "engine", "prec", "refreshes"
    );
    for m in &models {
        println!(
            "{:<24} {:>5} {:>4}  {:<9} {:<5} {:>9}  {:.6e}",
            m.id,
            m.k,
            m.d,
            m.engine,
            m.precision.name(),
            m.refreshes,
            m.energy
        );
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let dir = args.get("artifacts").unwrap_or("artifacts");
    let manifest = crate::runtime::Manifest::load(std::path::Path::new(dir))?;
    println!(
        "artifact dir {} (jax {}, tile_n {})",
        manifest.dir.display(),
        manifest.jax_version,
        manifest.tile_n
    );
    for s in &manifest.specs {
        println!(
            "  {:<28} kind={:<12} n={:<6} d={:<3} k={:<3} {}",
            s.name, s.kind, s.n, s.d, s.k, s.file
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_command_errors() {
        assert!(dispatch(&["frobnicate"]).is_err());
    }

    #[test]
    fn help_is_ok() {
        assert!(dispatch(&["help"]).is_ok());
        assert!(dispatch(&[]).is_ok());
    }

    #[test]
    fn load_dataset_registry_and_missing() {
        let x = load_dataset("Birch", 0.001).unwrap();
        assert_eq!(x.d(), 2);
        assert!(load_dataset("no-such-thing", 1.0).is_err());
    }

    #[test]
    fn run_on_tiny_registry_dataset() {
        // End-to-end CLI run (smoke): tiny scale to stay fast.
        assert!(dispatch(&[
            "run", "--dataset", "HTRU2", "--scale", "0.01", "--k", "4", "--threads", "1",
            "--compare"
        ])
        .is_ok());
    }

    #[test]
    fn run_f32_precision_and_centering() {
        // The f32 sample-storage path end-to-end (auto-centers), plus the
        // explicit --center flag on the f64 path.
        assert!(dispatch(&[
            "run", "--dataset", "HTRU2", "--scale", "0.01", "--k", "4", "--threads", "1",
            "--precision", "f32"
        ])
        .is_ok());
        assert!(dispatch(&[
            "run", "--dataset", "HTRU2", "--scale", "0.01", "--k", "4", "--threads", "1",
            "--center"
        ])
        .is_ok());
        assert!(dispatch(&["run", "--precision", "f16"]).is_err());
    }

    #[test]
    fn run_minibatch_engine_inline_and_shard() {
        // In-memory mini-batch run (registry dataset), with the AA-on vs
        // AA-off comparison path.
        assert!(dispatch(&[
            "run", "--dataset", "HTRU2", "--scale", "0.01", "--k", "4", "--threads", "1",
            "--engine", "minibatch", "--chunk-size", "128", "--compare"
        ])
        .is_ok());
        // Out-of-core: write a .fv shard, then stream it chunk by chunk.
        let dir = std::env::temp_dir().join("aakm_cli_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("stream.fv");
        dispatch(&[
            "datagen", "--dataset", "Birch", "--scale", "0.005", "--out",
            out.to_str().unwrap(),
        ])
        .unwrap();
        assert!(dispatch(&[
            "run", "--dataset", out.to_str().unwrap(), "--k", "3", "--threads", "1",
            "--engine", "minibatch", "--chunk-size", "64"
        ])
        .is_ok());
        // The saturation knobs end-to-end on the same shard: pipelined
        // prefetch + sampled energy guard + pinned lanes.
        assert!(dispatch(&[
            "run", "--dataset", out.to_str().unwrap(), "--k", "3", "--threads", "1",
            "--engine", "minibatch", "--chunk-size", "64", "--prefetch",
            "--guard", "sampled:200", "--pin-threads"
        ])
        .is_ok());
        assert!(dispatch(&[
            "run", "--dataset", out.to_str().unwrap(), "--k", "3",
            "--engine", "minibatch", "--guard", "approx"
        ])
        .is_err());
        // Pre-centering cannot be applied to a streamed shard: loud error
        // instead of silently un-centered f32 numerics.
        assert!(dispatch(&[
            "run", "--dataset", out.to_str().unwrap(), "--k", "3", "--threads", "1",
            "--engine", "minibatch", "--precision", "f32"
        ])
        .is_err());
        assert!(dispatch(&[
            "run", "--dataset", out.to_str().unwrap(), "--k", "3", "--threads", "1",
            "--engine", "minibatch", "--center"
        ])
        .is_err());
    }

    #[test]
    fn serve_smoke_with_precision() {
        // The service mode end-to-end at smoke scale, f32 jobs included —
        // Precision flows request → worker → result metadata.
        assert!(dispatch(&[
            "serve", "--workers", "1", "--jobs", "2", "--k", "3", "--scale", "0.005",
            "--precision", "f32"
        ])
        .is_ok());
        assert!(dispatch(&["serve", "--jobs", "1", "--precision", "f16"]).is_err());
    }

    #[test]
    fn serve_smoke_with_admission_and_retry_flags() {
        // Shed admission + retry budget + CPU fallback, end-to-end at
        // smoke scale (no PJRT jobs here, so fallback stays dormant).
        assert!(dispatch(&[
            "serve", "--workers", "1", "--jobs", "3", "--k", "3", "--scale", "0.005",
            "--policy", "shed", "--retries", "2", "--cpu-fallback"
        ])
        .is_ok());
        assert!(dispatch(&[
            "serve", "--workers", "1", "--jobs", "2", "--k", "3", "--scale", "0.005",
            "--policy", "wait:50"
        ])
        .is_ok());
        assert!(dispatch(&["serve", "--jobs", "1", "--policy", "sometimes"]).is_err());
        assert!(dispatch(&["serve", "--jobs", "1", "--retries", "0"]).is_err());
    }

    #[test]
    fn serve_writes_telemetry_sinks_and_telemetry_subcommand_reads_them() {
        let dir = std::env::temp_dir().join("aakm_cli_tests").join("telemetry");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let metrics = dir.join("metrics.prom");
        let events = dir.join("events.jsonl");
        assert!(dispatch(&[
            "serve", "--workers", "1", "--jobs", "2", "--k", "3", "--scale", "0.005",
            "--metrics-out", metrics.to_str().unwrap(),
            "--events-out", events.to_str().unwrap(),
        ])
        .is_ok());
        let exposition = std::fs::read_to_string(&metrics).unwrap();
        assert!(exposition.contains("aakm_jobs_submitted_total"));
        assert!(exposition.contains("aakm_job_queue_wait_seconds_bucket"));
        let (parsed, torn) = crate::telemetry::events::read_events(&events).unwrap();
        assert!(!torn, "a drained serve closes its event log cleanly");
        assert!(parsed.iter().filter(|e| e.kind == "outcome").count() >= 2);
        // The read-side subcommand validates the same artifacts.
        assert!(dispatch(&["telemetry", "check", "--events", events.to_str().unwrap()]).is_ok());
        assert!(dispatch(&["telemetry", "dump"]).is_ok());
        assert!(dispatch(&["telemetry", "dump", "--json"]).is_ok());
        assert!(dispatch(&["telemetry", "check"]).is_err(), "check requires --events");
        assert!(dispatch(&["telemetry", "bogus"]).is_err(), "unknown action is loud");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn run_with_checkpoint_flags() {
        let dir = std::env::temp_dir().join("aakm_cli_tests").join("ck_run");
        let _ = std::fs::remove_dir_all(&dir);
        let d = dir.to_str().unwrap();
        assert!(dispatch(&[
            "run", "--dataset", "HTRU2", "--scale", "0.01", "--k", "4", "--threads", "1",
            "--checkpoint-dir", d, "--checkpoint-every", "2", "--reseed-empty"
        ])
        .is_ok());
        // A converged run consumes its snapshot: nothing stale is left to
        // confuse a later run with the same flags.
        assert!(!crate::persist::snapshot_path(&dir).exists());
        // Flag validation: cadence without a directory, and a zero cadence.
        assert!(dispatch(&["run", "--checkpoint-every", "3"]).is_err());
        assert!(dispatch(&[
            "run", "--checkpoint-dir", d, "--checkpoint-every", "0"
        ])
        .is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn serve_with_journal_leaves_no_incomplete_jobs() {
        let dir = std::env::temp_dir().join("aakm_cli_tests").join("serve_journal");
        let _ = std::fs::remove_dir_all(&dir);
        assert!(dispatch(&[
            "serve", "--workers", "1", "--jobs", "2", "--k", "3", "--scale", "0.005",
            "--journal", dir.to_str().unwrap(),
        ])
        .is_ok());
        // A clean drain closes every journaled record; a restart over the
        // same journal therefore recovers nothing (the crashed-serve case
        // is exercised end-to-end in tests/recovery.rs).
        let events = crate::persist::read_journal(&dir).unwrap();
        assert!(!events.is_empty(), "serve must have journaled its jobs");
        assert!(crate::persist::incomplete_jobs(&events).is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn model_lifecycle_fit_predict_refresh_sweep_models() {
        let dir = std::env::temp_dir().join("aakm_cli_tests").join("registry");
        let _ = std::fs::remove_dir_all(&dir);
        let reg = dir.to_str().unwrap();
        assert!(dispatch(&[
            "fit", "--registry", reg, "--model", "m1", "--dataset", "Birch", "--scale",
            "0.005", "--k", "4", "--threads", "1", "--seed", "7"
        ])
        .is_ok());
        let out = dir.join("pred.csv");
        assert!(dispatch(&[
            "predict", "--registry", reg, "--model", "m1", "--dataset", "Birch", "--scale",
            "0.005", "--out", out.to_str().unwrap()
        ])
        .is_ok());
        let text = std::fs::read_to_string(&out).unwrap();
        assert!(text.starts_with("label,distance\n"));
        assert!(text.lines().count() > 1, "one row per sample");
        // Refresh without --k re-clusters at the model's own k.
        assert!(dispatch(&[
            "refresh", "--registry", reg, "--model", "m1", "--dataset", "Birch", "--scale",
            "0.005", "--threads", "1", "--seed", "7"
        ])
        .is_ok());
        assert!(dispatch(&[
            "sweep", "--registry", reg, "--model", "lad", "--ks", "2,3", "--dataset",
            "Birch", "--scale", "0.005", "--threads", "1"
        ])
        .is_ok());
        assert!(dispatch(&["models", "--registry", reg]).is_ok());
        assert!(dispatch(&["models", "--registry", reg, "--delete", "lad-k2"]).is_ok());
        assert!(dispatch(&["models", "--registry", reg, "--gc"]).is_ok());
        // Missing / bad inputs are loud, typed errors.
        assert!(dispatch(&["fit", "--model", "x"]).is_err());
        assert!(dispatch(&[
            "predict", "--registry", reg, "--model", "absent", "--dataset", "Birch",
            "--scale", "0.005"
        ])
        .is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn datagen_roundtrip() {
        let dir = std::env::temp_dir().join("aakm_cli_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("birch.csv");
        dispatch(&[
            "datagen", "--dataset", "Birch", "--scale", "0.001", "--out",
            out.to_str().unwrap(),
        ])
        .unwrap();
        let x = crate::data::load_csv(&out).unwrap();
        assert_eq!(x.d(), 2);
    }

    #[test]
    fn experiment_from_args_overrides() {
        let args = Args::parse(&["--k", "25", "--accel", "fixed:7", "--init", "clarans"]).unwrap();
        let cfg = experiment_from_args(&args).unwrap();
        assert_eq!(cfg.k, 25);
        assert_eq!(cfg.accel, Acceleration::FixedM(7));
        assert_eq!(cfg.init, InitMethod::Clarans);
    }

    #[test]
    fn experiment_from_args_streaming_knobs() {
        use crate::config::EnergyGuard;
        let args =
            Args::parse(&["--prefetch", "--guard", "sampled:4096", "--pin-threads"]).unwrap();
        let cfg = experiment_from_args(&args).unwrap();
        assert!(cfg.prefetch);
        assert_eq!(cfg.guard, EnergyGuard::Sampled { rows: 4096 });
        assert!(cfg.pin_threads);
        let cfg = experiment_from_args(&Args::parse(&[]).unwrap()).unwrap();
        assert!(!cfg.prefetch);
        assert_eq!(cfg.guard, EnergyGuard::Exact);
        assert!(!cfg.pin_threads);
        assert!(experiment_from_args(&Args::parse(&["--guard", "sampled:"]).unwrap()).is_err());
    }
}
