//! Typed errors for the public clustering API.
//!
//! Everything the request/session surface can fail with is enumerated here,
//! so library callers match on variants instead of parsing `anyhow` strings.
//! Internal plumbing (PJRT artifact loading, dataset IO) still uses `anyhow`
//! for context-rich messages; those are folded into the typed variants at
//! the API boundary with their full context chain preserved in `reason`.

/// Error type of the `ClusterRequest` / `ClusterSession` / coordinator API.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ClusterError {
    /// A request field failed validation (builder or run-time shape check).
    InvalidRequest {
        /// Which field was rejected (`"k"`, `"source"`, `"init"`, ...).
        field: &'static str,
        /// Human-readable explanation.
        reason: String,
    },
    /// The data source could not be materialized.
    Data {
        /// Label of the offending source (registry name, path, ...).
        source: String,
        /// Human-readable explanation.
        reason: String,
    },
    /// The data source materialized, but a sample value is unusable
    /// (non-finite): admission-time validation rejects it before NaN can
    /// poison energies and assignments.
    InvalidData {
        /// Label of the offending source (registry name, path, ...).
        source: String,
        /// Zero-based row index of the first offending sample.
        row: usize,
        /// Human-readable explanation.
        reason: String,
    },
    /// An assignment engine could not be constructed or failed fatally.
    Engine {
        /// Canonical engine name (`"pjrt"`, ...).
        engine: &'static str,
        /// Human-readable explanation.
        reason: String,
    },
    /// The run was cancelled through a [`crate::observe::CancelToken`].
    Cancelled,
    /// The coordinator no longer accepts jobs.
    Shutdown,
    /// The coordinator's admission policy shed the submission because the
    /// queue was full (see `SubmitPolicy::Shed` / `SubmitPolicy::TrySubmitFor`).
    Overloaded,
    /// The job's result was already taken by an earlier `wait` on the
    /// same handle.
    ResultTaken,
    /// A checkpoint snapshot or job-journal file could not be written,
    /// or an existing one was rejected on load (torn write, corruption,
    /// fingerprint mismatch with the resuming request).
    Snapshot {
        /// Path of the offending snapshot / journal file.
        path: String,
        /// Human-readable explanation.
        reason: String,
    },
    /// A worker failed unexpectedly (panic isolated per job).
    Internal(String),
}

/// Coarse classification of transient failures, used by
/// `RetryPolicy::retry_on` to decide which errors are worth re-running.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultClass {
    /// Data-source I/O faults (mmap page-in, registry materialization).
    Io,
    /// Engine construction / runtime-artifact load faults (PJRT manifest,
    /// client bring-up).
    EngineLoad,
    /// A worker panic isolated into a typed result.
    Panic,
}

impl std::fmt::Display for ClusterError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::InvalidRequest { field, reason } => {
                write!(f, "invalid request: {field}: {reason}")
            }
            Self::Data { source, reason } => {
                write!(f, "data source '{source}': {reason}")
            }
            Self::Engine { engine, reason } => {
                write!(f, "engine '{engine}': {reason}")
            }
            Self::InvalidData { source, row, reason } => {
                write!(f, "invalid data in '{source}' at row {row}: {reason}")
            }
            Self::Cancelled => write!(f, "run cancelled"),
            Self::Shutdown => write!(f, "coordinator is shut down"),
            Self::Overloaded => write!(f, "coordinator overloaded: submission shed"),
            Self::ResultTaken => write!(f, "job result already taken by an earlier wait"),
            Self::Snapshot { path, reason } => {
                write!(f, "snapshot '{path}': {reason}")
            }
            Self::Internal(reason) => write!(f, "internal failure: {reason}"),
        }
    }
}

impl std::error::Error for ClusterError {}

impl ClusterError {
    /// Shorthand for a validation failure.
    pub(crate) fn invalid(field: &'static str, reason: impl Into<String>) -> Self {
        Self::InvalidRequest { field, reason: reason.into() }
    }

    /// True for [`ClusterError::Cancelled`].
    pub fn is_cancelled(&self) -> bool {
        matches!(self, Self::Cancelled)
    }

    /// Transient-fault classification for retry decisions. `None` means
    /// the failure is deterministic (validation, cancellation, shutdown)
    /// and re-running the job cannot help.
    pub fn fault_class(&self) -> Option<FaultClass> {
        match self {
            Self::Data { .. } | Self::Snapshot { .. } => Some(FaultClass::Io),
            Self::Engine { .. } => Some(FaultClass::EngineLoad),
            Self::Internal(_) => Some(FaultClass::Panic),
            Self::InvalidRequest { .. }
            | Self::InvalidData { .. }
            | Self::Cancelled
            | Self::Shutdown
            | Self::Overloaded
            | Self::ResultTaken => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_names_the_field() {
        let e = ClusterError::invalid("k", "must be at least 1");
        assert_eq!(e.to_string(), "invalid request: k: must be at least 1");
        assert!(!e.is_cancelled());
        assert!(ClusterError::Cancelled.is_cancelled());
    }

    #[test]
    fn converts_into_anyhow() {
        let e: anyhow::Error = ClusterError::Shutdown.into();
        assert!(e.to_string().contains("shut down"));
    }

    #[test]
    fn fault_classes_split_transient_from_deterministic() {
        let io = ClusterError::Data { source: "s".into(), reason: "mmap".into() };
        assert_eq!(io.fault_class(), Some(FaultClass::Io));
        let load = ClusterError::Engine { engine: "pjrt", reason: "no manifest".into() };
        assert_eq!(load.fault_class(), Some(FaultClass::EngineLoad));
        assert_eq!(ClusterError::Internal("boom".into()).fault_class(), Some(FaultClass::Panic));
        assert_eq!(ClusterError::Overloaded.fault_class(), None);
        assert_eq!(ClusterError::Cancelled.fault_class(), None);
        let bad = ClusterError::InvalidData { source: "s".into(), row: 3, reason: "NaN".into() };
        assert_eq!(bad.fault_class(), None);
        assert!(bad.to_string().contains("row 3"));
    }
}
