//! Typed errors for the public clustering API.
//!
//! Everything the request/session surface can fail with is enumerated here,
//! so library callers match on variants instead of parsing `anyhow` strings.
//! Internal plumbing (PJRT artifact loading, dataset IO) still uses `anyhow`
//! for context-rich messages; those are folded into the typed variants at
//! the API boundary with their full context chain preserved in `reason`.

/// Error type of the `ClusterRequest` / `ClusterSession` / coordinator API.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ClusterError {
    /// A request field failed validation (builder or run-time shape check).
    InvalidRequest {
        /// Which field was rejected (`"k"`, `"source"`, `"init"`, ...).
        field: &'static str,
        /// Human-readable explanation.
        reason: String,
    },
    /// The data source could not be materialized.
    Data {
        /// Label of the offending source (registry name, path, ...).
        source: String,
        /// Human-readable explanation.
        reason: String,
    },
    /// An assignment engine could not be constructed or failed fatally.
    Engine {
        /// Canonical engine name (`"pjrt"`, ...).
        engine: &'static str,
        /// Human-readable explanation.
        reason: String,
    },
    /// The run was cancelled through a [`crate::observe::CancelToken`].
    Cancelled,
    /// The coordinator no longer accepts jobs.
    Shutdown,
    /// A worker failed unexpectedly (panic isolated per job).
    Internal(String),
}

impl std::fmt::Display for ClusterError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::InvalidRequest { field, reason } => {
                write!(f, "invalid request: {field}: {reason}")
            }
            Self::Data { source, reason } => {
                write!(f, "data source '{source}': {reason}")
            }
            Self::Engine { engine, reason } => {
                write!(f, "engine '{engine}': {reason}")
            }
            Self::Cancelled => write!(f, "run cancelled"),
            Self::Shutdown => write!(f, "coordinator is shut down"),
            Self::Internal(reason) => write!(f, "internal failure: {reason}"),
        }
    }
}

impl std::error::Error for ClusterError {}

impl ClusterError {
    /// Shorthand for a validation failure.
    pub(crate) fn invalid(field: &'static str, reason: impl Into<String>) -> Self {
        Self::InvalidRequest { field, reason: reason.into() }
    }

    /// True for [`ClusterError::Cancelled`].
    pub fn is_cancelled(&self) -> bool {
        matches!(self, Self::Cancelled)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_names_the_field() {
        let e = ClusterError::invalid("k", "must be at least 1");
        assert_eq!(e.to_string(), "invalid request: k: must be at least 1");
        assert!(!e.is_cancelled());
        assert!(ClusterError::Cancelled.is_cancelled());
    }

    #[test]
    fn converts_into_anyhow() {
        let e: anyhow::Error = ClusterError::Shutdown.into();
        assert!(e.to_string().contains("shut down"));
    }
}
