//! The O(NK) reference assignment engine: every sample against every
//! centroid, parallelized over samples. No bound state between calls — but
//! the distances themselves run on the blocked norm-decomposed
//! [`DistanceKernel`], so this is the fastest *exhaustive* sweep the crate
//! has (and the baseline the bound engines are judged against).

use super::{Assignment, AssignmentEngine};
use crate::data::DataMatrix;
use crate::linalg::DistanceKernel;
use crate::par::{SyncSliceMut, ThreadPool};
use std::sync::atomic::{AtomicU64, Ordering};

/// Brute-force nearest-centroid assignment over the blocked kernel.
#[derive(Debug, Default)]
pub struct NaiveEngine {
    kernel: DistanceKernel,
    dist_evals: AtomicU64,
}

impl NaiveEngine {
    pub fn new() -> Self {
        Self::default()
    }

    /// Engine whose kernel stores samples at the given precision.
    pub fn with_precision(precision: crate::linalg::Precision) -> Self {
        Self { kernel: DistanceKernel::with_precision(precision), ..Self::default() }
    }
}

impl AssignmentEngine for NaiveEngine {
    fn name(&self) -> &'static str {
        "naive"
    }

    fn assign(&mut self, x: &DataMatrix, c: &DataMatrix, pool: &ThreadPool, out: &mut Assignment) {
        let (n, k) = (x.n(), c.n());
        out.resize(n, 0);
        self.kernel.prepare(x, c, pool);
        let kernel = &self.kernel;
        let shared = SyncSliceMut::new(out.as_mut_slice());
        let evals = AtomicU64::new(0);
        pool.parallel_for(n, 256, |range| {
            let local = (range.len() * k) as u64;
            kernel.argmin2_range(x, c, range, |i, b| {
                *shared.at(i) = b.best;
            });
            evals.fetch_add(local, Ordering::Relaxed);
        });
        self.dist_evals.fetch_add(evals.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    fn reset(&mut self) {
        // The kernel's sample-norm cache is keyed on the data's
        // generation stamp, so it survives the reset: a same-data rerun
        // (different k, warm-start refresh) skips the O(N·d) norm pass.
    }

    fn distance_evals(&self) -> u64 {
        self.dist_evals.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lloyd::test_support::engine_matches_brute_force;

    #[test]
    fn matches_brute_force() {
        engine_matches_brute_force(&mut NaiveEngine::new());
    }

    #[test]
    fn norm_cache_survives_reset_on_same_data() {
        let mut e = NaiveEngine::new();
        let pool = ThreadPool::new(1);
        let x = DataMatrix::from_rows(&[&[0.0, 1.0], &[1.0, 0.0], &[2.0, 2.0]]);
        let c = DataMatrix::from_rows(&[&[0.0, 0.0], &[2.0, 2.0]]);
        let mut out = Assignment::new();
        e.assign(&x, &c, &pool, &mut out);
        assert_eq!(e.kernel.norm_builds(), 1);
        e.reset();
        // Same data (same generation stamp) after a reset: the cached
        // sample norms are still keyed correctly and must not rebuild.
        e.assign(&x, &c, &pool, &mut out);
        assert_eq!(e.kernel.norm_builds(), 1);
        // Different data forces a rebuild.
        let y = DataMatrix::from_rows(&[&[5.0, 5.0], &[6.0, 6.0]]);
        e.assign(&y, &c, &pool, &mut out);
        assert_eq!(e.kernel.norm_builds(), 2);
    }

    #[test]
    fn counts_distance_evals() {
        let mut e = NaiveEngine::new();
        let pool = ThreadPool::new(1);
        let x = DataMatrix::from_rows(&[&[0.0], &[1.0], &[2.0]]);
        let c = DataMatrix::from_rows(&[&[0.0], &[5.0]]);
        let mut out = Assignment::new();
        e.assign(&x, &c, &pool, &mut out);
        assert_eq!(e.distance_evals(), 6); // 3 samples × 2 centroids
        assert_eq!(out, vec![0, 0, 0]);
    }
}
