//! The O(NK) reference assignment engine: every sample against every
//! centroid, parallelized over samples. No state between calls.

use super::{Assignment, AssignmentEngine};
use crate::data::DataMatrix;
use crate::linalg::dist_sq;
use crate::par::{SyncSliceMut, ThreadPool};
use std::sync::atomic::{AtomicU64, Ordering};

/// Brute-force nearest-centroid assignment.
#[derive(Debug, Default)]
pub struct NaiveEngine {
    dist_evals: AtomicU64,
}

impl NaiveEngine {
    pub fn new() -> Self {
        Self::default()
    }
}

impl AssignmentEngine for NaiveEngine {
    fn name(&self) -> &'static str {
        "naive"
    }

    fn assign(&mut self, x: &DataMatrix, c: &DataMatrix, pool: &ThreadPool, out: &mut Assignment) {
        let (n, k) = (x.n(), c.n());
        out.resize(n, 0);
        let shared = SyncSliceMut::new(out.as_mut_slice());
        let evals = AtomicU64::new(0);
        pool.parallel_for(n, 256, |range| {
            let mut local_evals = 0u64;
            for i in range {
                let row = x.row(i);
                let mut best = 0u32;
                let mut best_d = f64::INFINITY;
                for j in 0..k {
                    let dsq = dist_sq(row, c.row(j));
                    if dsq < best_d {
                        best_d = dsq;
                        best = j as u32;
                    }
                }
                local_evals += k as u64;
                *shared.at(i) = best;
            }
            evals.fetch_add(local_evals, Ordering::Relaxed);
        });
        self.dist_evals.fetch_add(evals.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    fn reset(&mut self) {}

    fn distance_evals(&self) -> u64 {
        self.dist_evals.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lloyd::test_support::engine_matches_brute_force;

    #[test]
    fn matches_brute_force() {
        engine_matches_brute_force(&mut NaiveEngine::new());
    }

    #[test]
    fn counts_distance_evals() {
        let mut e = NaiveEngine::new();
        let pool = ThreadPool::new(1);
        let x = DataMatrix::from_rows(&[&[0.0], &[1.0], &[2.0]]);
        let c = DataMatrix::from_rows(&[&[0.0], &[5.0]]);
        let mut out = Assignment::new();
        e.assign(&x, &c, &pool, &mut out);
        assert_eq!(e.distance_evals(), 6); // 3 samples × 2 centroids
        assert_eq!(out, vec![0, 0, 0]);
    }
}
