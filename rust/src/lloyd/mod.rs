//! Lloyd's algorithm building blocks: assignment engines, the update step,
//! and energy evaluation.
//!
//! The paper implements its *Assignment-Step* with Hamerly's bounds
//! (Hamerly 2010) and notes that faster engines (Ding et al. 2015, Newling
//! & Fleuret 2016) would not change the iteration-count reduction. We
//! provide four CPU engines behind one trait —
//! [`NaiveEngine`] (O(NK) reference), [`HamerlyEngine`] (the paper's
//! choice), [`ElkanEngine`] (Elkan 2003) and [`YinyangEngine`] (Ding et
//! al. 2015, for the large-K columns) — plus the PJRT engine in
//! [`crate::runtime`] that executes the AOT-compiled JAX G-step.

mod bounds;
mod elkan;
mod hamerly;
mod naive;
mod yinyang;

pub use bounds::SavedBounds;
pub use elkan::ElkanEngine;
pub use hamerly::HamerlyEngine;
pub use naive::NaiveEngine;
pub use yinyang::YinyangEngine;

use crate::data::DataMatrix;
use crate::linalg::dist_sq;
use crate::par::ThreadPool;

/// Cluster assignment for every sample.
pub type Assignment = Vec<u32>;

/// An assignment-step implementation. Engines may keep per-sample bound
/// state between calls (Hamerly, Elkan); [`AssignmentEngine::reset`] drops
/// it (used when the centroid set is replaced wholesale, e.g. a new run).
///
/// Deliberately not `Send`: the PJRT engine wraps non-`Send` PJRT handles.
/// The coordinator gives each worker thread its own engine via a factory.
pub trait AssignmentEngine {
    /// Engine name for reports.
    fn name(&self) -> &'static str;

    /// Assign every sample in `x` to its nearest centroid in `c`, writing
    /// into `out` (resized as needed). Implementations may exploit bound
    /// state from the previous call *with arbitrary new centroids* — both
    /// Hamerly and Elkan bounds stay valid under any centroid motion, which
    /// is what lets the paper reuse them for accelerated iterates.
    fn assign(&mut self, x: &DataMatrix, c: &DataMatrix, pool: &ThreadPool, out: &mut Assignment);

    /// Forget all cached bound state. Norm caches keyed by the data's
    /// generation stamp ([`crate::linalg::DistanceKernel`]) survive a
    /// reset — the stamp already proves their validity — so a same-data
    /// rerun at a different `k` (multi-k sweeps, warm-start refreshes)
    /// skips the O(N·d) sample-norm pass.
    fn reset(&mut self);

    /// Number of full point–centroid distance evaluations since creation
    /// (the classic efficiency metric for accelerated K-Means engines).
    fn distance_evals(&self) -> u64;

    /// Save the current bound state. Called by the accelerated solver right
    /// before it jumps to an Anderson candidate, so that a rejected jump can
    /// [`AssignmentEngine::rollback`] instead of drifting the bounds by two
    /// large motions (candidate there-and-back). Default: unsupported no-op.
    fn checkpoint(&mut self) {}

    /// Restore the state saved by [`AssignmentEngine::checkpoint`]; returns
    /// `false` when unsupported (callers then proceed with drifted bounds —
    /// correctness is unaffected either way, this is purely a prune-quality
    /// optimization; see EXPERIMENTS.md §Perf L3 iteration 2).
    fn rollback(&mut self) -> bool {
        false
    }
}

/// Build an engine by kind with the default `f64` kernel precision. The
/// `Pjrt` kind is constructed by the runtime module (it needs artifacts) —
/// asking for it here panics; prefer [`try_make_engine`].
pub fn make_engine(kind: crate::config::EngineKind) -> Box<dyn AssignmentEngine> {
    make_engine_with(kind, crate::config::Precision::F64)
}

/// Build an engine by kind with an explicit kernel storage precision (the
/// solver threads [`crate::config::SolverConfig::precision`] through here).
/// Panics on `EngineKind::Pjrt`; prefer [`try_make_engine`].
pub fn make_engine_with(
    kind: crate::config::EngineKind,
    precision: crate::config::Precision,
) -> Box<dyn AssignmentEngine> {
    try_make_engine(kind, precision)
        .unwrap_or_else(|e| panic!("{e} (use lloyd::try_make_engine or ClusterSession::open)"))
}

/// Fallible engine factory: every CPU engine kind succeeds; the `Pjrt`
/// kind returns a typed error because it needs AOT artifacts — construct
/// it through [`crate::kmeans::Workspace::open`] (which knows the artifact
/// directory) or wrap a `runtime::PjrtEngine` yourself.
///
/// `EngineKind::MiniBatch` maps to the dense [`NaiveEngine`]: the
/// mini-batch solver ([`crate::stream::MiniBatchSolver`]) assigns each
/// fresh chunk exactly once, so bound state never survives a call and one
/// exhaustive blocked-kernel sweep per chunk is the optimal strategy.
pub fn try_make_engine(
    kind: crate::config::EngineKind,
    precision: crate::config::Precision,
) -> Result<Box<dyn AssignmentEngine>, crate::error::ClusterError> {
    use crate::config::EngineKind;
    Ok(match kind {
        EngineKind::Naive | EngineKind::MiniBatch => {
            Box::new(NaiveEngine::with_precision(precision))
        }
        EngineKind::Hamerly => Box::new(HamerlyEngine::with_precision(precision)),
        EngineKind::Elkan => Box::new(ElkanEngine::with_precision(precision)),
        EngineKind::Yinyang => Box::new(YinyangEngine::with_precision(precision)),
        EngineKind::Pjrt => {
            return Err(crate::error::ClusterError::Engine {
                engine: "pjrt",
                reason: "needs AOT artifacts; open it via Workspace::open / \
                         ClusterSession::open (artifact_dir) or wrap a \
                         runtime::PjrtEngine with Solver::with_engine"
                    .to_string(),
            })
        }
    })
}

/// The update step (paper Eq. 4): each centroid moves to the mean of its
/// assigned samples. Empty clusters keep their previous position (the
/// conventional choice; the paper does not treat empty clusters specially).
/// Returns the per-cluster sample counts.
pub fn update_step(
    x: &DataMatrix,
    assign: &Assignment,
    prev_c: &DataMatrix,
    out_c: &mut DataMatrix,
    pool: &ThreadPool,
) -> Vec<usize> {
    let (n, d) = (x.n(), x.d());
    let k = prev_c.n();
    debug_assert_eq!(assign.len(), n);
    debug_assert_eq!(out_c.n(), k);
    // Parallel partial sums per lane, combined at the end. Each partial is
    // (k*d sums, k counts).
    let (sums, counts) = pool.map_reduce(
        n,
        512,
        || (vec![0.0f64; k * d], vec![0usize; k]),
        |acc, range| {
            let (sums, counts) = acc;
            for i in range {
                let j = assign[i] as usize;
                debug_assert!(j < k, "assignment out of range");
                counts[j] += 1;
                let row = x.row(i);
                let dst = &mut sums[j * d..(j + 1) * d];
                for (s, &v) in dst.iter_mut().zip(row) {
                    *s += v;
                }
            }
        },
        |(mut s1, mut c1), (s2, c2)| {
            for (a, b) in s1.iter_mut().zip(&s2) {
                *a += b;
            }
            for (a, b) in c1.iter_mut().zip(&c2) {
                *a += b;
            }
            (s1, c1)
        },
    );
    for j in 0..k {
        let dst = out_c.row_mut(j);
        if counts[j] == 0 {
            dst.copy_from_slice(prev_c.row(j));
        } else {
            let inv = 1.0 / counts[j] as f64;
            for (o, &s) in dst.iter_mut().zip(&sums[j * d..(j + 1) * d]) {
                *o = s * inv;
            }
        }
    }
    counts
}

/// Fused update + energy: one parallel pass over the samples computes the
/// per-cluster sums/counts (the update step) *and* the clustering energy at
/// the **input** centroids `E(P, C^t)` — the quantity Algorithm 1 line 7
/// needs for the acceptance guard. Fusing the two O(N·d) sweeps makes the
/// accelerated solver's per-iteration memory traffic identical to plain
/// Lloyd's (see EXPERIMENTS.md §Perf, L3 iteration 1).
pub fn update_and_energy(
    x: &DataMatrix,
    assign: &Assignment,
    c_t: &DataMatrix,
    out_c: &mut DataMatrix,
    pool: &ThreadPool,
) -> (Vec<usize>, f64) {
    let (n, d) = (x.n(), x.d());
    let k = c_t.n();
    debug_assert_eq!(assign.len(), n);
    debug_assert_eq!(out_c.n(), k);
    let (sums, counts, energy) = pool.map_reduce(
        n,
        512,
        || (vec![0.0f64; k * d], vec![0usize; k], 0.0f64),
        |acc, range| {
            let (sums, counts, energy) = acc;
            for i in range {
                let j = assign[i] as usize;
                debug_assert!(j < k);
                counts[j] += 1;
                let row = x.row(i);
                let cj = c_t.row(j);
                let dst = &mut sums[j * d..(j + 1) * d];
                let mut e = 0.0;
                for t in 0..d {
                    let v = row[t];
                    dst[t] += v;
                    let diff = v - cj[t];
                    e += diff * diff;
                }
                *energy += e;
            }
        },
        |(mut s1, mut c1, e1), (s2, c2, e2)| {
            for (a, b) in s1.iter_mut().zip(&s2) {
                *a += b;
            }
            for (a, b) in c1.iter_mut().zip(&c2) {
                *a += b;
            }
            (s1, c1, e1 + e2)
        },
    );
    for j in 0..k {
        let dst = out_c.row_mut(j);
        if counts[j] == 0 {
            dst.copy_from_slice(c_t.row(j));
        } else {
            let inv = 1.0 / counts[j] as f64;
            for (o, &s) in dst.iter_mut().zip(&sums[j * d..(j + 1) * d]) {
                *o = s * inv;
            }
        }
    }
    (counts, energy)
}

/// Reusable per-lane accumulators for [`update_step_with`] /
/// [`update_and_energy_with`]: `(per-cluster sums, per-cluster counts,
/// energy)` per pool lane. Owned by the solver workspace so warm
/// iterations run the update reduce without touching the allocator (the
/// per-iteration reduce identities were the last warm-run transients —
/// see `tests/alloc_reuse.rs`).
#[derive(Default)]
pub struct UpdateScratch {
    lanes: crate::par::LaneScratch<(Vec<f64>, Vec<usize>, f64)>,
}

/// Shared core of the allocation-free update reduces: one parallel pass
/// accumulates per-cluster sums/counts (and, when `with_energy`, the
/// clustering energy at the input centroids) into `scratch`'s lane
/// accumulators, then writes the means into `out_c` and returns the energy.
fn update_reduce_with(
    x: &DataMatrix,
    assign: &Assignment,
    c_ref: &DataMatrix,
    out_c: &mut DataMatrix,
    pool: &ThreadPool,
    scratch: &mut UpdateScratch,
    with_energy: bool,
) -> f64 {
    let (n, d) = (x.n(), x.d());
    let k = c_ref.n();
    debug_assert_eq!(assign.len(), n);
    debug_assert_eq!(out_c.n(), k);
    pool.map_reduce_with(
        &mut scratch.lanes,
        n,
        512,
        || (vec![0.0f64; k * d], vec![0usize; k], 0.0f64),
        |acc| {
            let (sums, counts, energy) = acc;
            sums.clear();
            sums.resize(k * d, 0.0);
            counts.clear();
            counts.resize(k, 0);
            *energy = 0.0;
        },
        |acc, range| {
            let (sums, counts, energy) = acc;
            for i in range {
                let j = assign[i] as usize;
                debug_assert!(j < k, "assignment out of range");
                counts[j] += 1;
                let row = x.row(i);
                let dst = &mut sums[j * d..(j + 1) * d];
                if with_energy {
                    let cj = c_ref.row(j);
                    let mut e = 0.0;
                    for t in 0..d {
                        let v = row[t];
                        dst[t] += v;
                        let diff = v - cj[t];
                        e += diff * diff;
                    }
                    *energy += e;
                } else {
                    for (s, &v) in dst.iter_mut().zip(row) {
                        *s += v;
                    }
                }
            }
        },
        |a, b| {
            for (s, &v) in a.0.iter_mut().zip(&b.0) {
                *s += v;
            }
            for (s, &v) in a.1.iter_mut().zip(&b.1) {
                *s += v;
            }
            a.2 += b.2;
        },
        |acc| {
            let (sums, counts, energy) = acc;
            for j in 0..k {
                let dst = out_c.row_mut(j);
                if counts[j] == 0 {
                    dst.copy_from_slice(c_ref.row(j));
                } else {
                    let inv = 1.0 / counts[j] as f64;
                    for (o, &s) in dst.iter_mut().zip(&sums[j * d..(j + 1) * d]) {
                        *o = s * inv;
                    }
                }
            }
            *energy
        },
    )
}

/// Allocation-free [`update_step`]: identical means, but the reduce
/// accumulators persist in the caller-owned [`UpdateScratch`], so warm
/// solver iterations perform no heap allocation here.
pub fn update_step_with(
    x: &DataMatrix,
    assign: &Assignment,
    prev_c: &DataMatrix,
    out_c: &mut DataMatrix,
    pool: &ThreadPool,
    scratch: &mut UpdateScratch,
) {
    let _ = update_reduce_with(x, assign, prev_c, out_c, pool, scratch, false);
}

/// Allocation-free [`update_and_energy`]: returns `E(P, C^t)` (the energy
/// at the **input** centroids) while writing the update-step means into
/// `out_c`, with all reduce accumulators drawn from `scratch`.
pub fn update_and_energy_with(
    x: &DataMatrix,
    assign: &Assignment,
    c_t: &DataMatrix,
    out_c: &mut DataMatrix,
    pool: &ThreadPool,
    scratch: &mut UpdateScratch,
) -> f64 {
    update_reduce_with(x, assign, c_t, out_c, pool, scratch, true)
}

/// Deterministic empty-cluster re-seeding: split the highest-energy cluster.
///
/// The default policy everywhere in the crate (and the paper's implicit
/// choice) is that an empty cluster keeps its previous centroid. Opting in
/// via `SolverConfig::reseed_empty` instead moves each empty centroid onto a
/// member of the current *highest-energy* donor cluster, which converts a
/// dead centroid into an immediate energy reduction on the next assignment
/// pass. The policy is deliberately engine-agnostic and runs after the
/// update step on the freshly updated centroids.
///
/// Determinism: member selection draws from a [`Pcg32`] seeded by
/// `seed ^ iteration·φ64`, and every scan is a serial pass in sample order,
/// so the result is bit-identical across thread counts and across a
/// checkpoint/resume boundary (the caller passes the committed iteration
/// counter). Donor ties break toward the lowest cluster index.
///
/// Returns the number of centroids that were re-seeded (0 when no cluster
/// is empty, which is the common case and costs one O(N) counting pass).
pub fn reseed_empty_clusters(
    x: &DataMatrix,
    assign: &Assignment,
    c: &mut DataMatrix,
    seed: u64,
    iteration: u64,
) -> usize {
    use crate::rng::{Pcg32, Rng};
    let n = x.n();
    let k = c.n();
    debug_assert_eq!(assign.len(), n);
    let mut counts = vec![0usize; k];
    for &j in assign {
        counts[j as usize] += 1;
    }
    if counts.iter().all(|&cnt| cnt > 0) {
        return 0;
    }
    // Per-cluster energy at the current centroids. Empty clusters contribute
    // nothing, so mutating their rows below never invalidates donor energies.
    let mut e = vec![0.0f64; k];
    for i in 0..n {
        let j = assign[i] as usize;
        e[j] += dist_sq(x.row(i), c.row(j));
    }
    let mut taken = vec![false; n];
    let mut rng = Pcg32::seed_from_u64(seed ^ iteration.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let mut reseeded = 0usize;
    for j in 0..k {
        if counts[j] != 0 {
            continue;
        }
        // Donor: highest-energy cluster that can spare a member.
        let mut donor = usize::MAX;
        for cand in 0..k {
            if counts[cand] >= 2 && (donor == usize::MAX || e[cand] > e[donor]) {
                donor = cand;
            }
        }
        if donor == usize::MAX {
            break; // fewer samples than clusters; leave the rest in place
        }
        let r = rng.next_u32() as usize % counts[donor];
        let mut seen = 0usize;
        let mut pick = usize::MAX;
        for i in 0..n {
            if assign[i] as usize == donor && !taken[i] {
                if seen == r {
                    pick = i;
                    break;
                }
                seen += 1;
            }
        }
        debug_assert_ne!(pick, usize::MAX, "donor count out of sync");
        if pick == usize::MAX {
            break;
        }
        taken[pick] = true;
        e[donor] -= dist_sq(x.row(pick), c.row(donor));
        counts[donor] -= 1;
        counts[j] = 1;
        e[j] = 0.0;
        c.row_mut(j).copy_from_slice(x.row(pick));
        reseeded += 1;
    }
    reseeded
}

/// Clustering energy (paper Eq. 1) with a precomputed assignment —
/// `E(P, C)` in Algorithm 1. O(N·d).
pub fn energy(x: &DataMatrix, c: &DataMatrix, assign: &Assignment, pool: &ThreadPool) -> f64 {
    let n = x.n();
    debug_assert_eq!(assign.len(), n);
    pool.map_reduce(
        n,
        1024,
        || 0.0f64,
        |acc, range| {
            let mut s = 0.0;
            for i in range {
                s += dist_sq(x.row(i), c.row(assign[i] as usize));
            }
            *acc += s;
        },
        |a, b| a + b,
    )
}

/// Mean squared error — the paper's reported MSE column: `E / N`.
pub fn mse(x: &DataMatrix, c: &DataMatrix, assign: &Assignment, pool: &ThreadPool) -> f64 {
    energy(x, c, assign, pool) / x.n().max(1) as f64
}

/// Reference brute-force assignment used in tests to validate engines.
pub fn brute_force_assign(x: &DataMatrix, c: &DataMatrix) -> Assignment {
    (0..x.n())
        .map(|i| {
            let mut best = 0u32;
            let mut best_d = f64::INFINITY;
            for j in 0..c.n() {
                let dsq = dist_sq(x.row(i), c.row(j));
                if dsq < best_d {
                    best_d = dsq;
                    best = j as u32;
                }
            }
            best
        })
        .collect()
}

#[cfg(test)]
pub(crate) mod test_support {
    use super::*;
    use crate::data::synth;
    use crate::rng::Pcg32;

    /// A deterministic small problem for engine tests.
    pub fn small_problem(seed: u64, n: usize, d: usize, k: usize) -> (DataMatrix, DataMatrix) {
        let mut rng = Pcg32::seed_from_u64(seed);
        let x = synth::gaussian_blobs(&mut rng, n, d, k, 2.0, 0.3);
        let c = x.gather_rows(&crate::rng::sample_indices(n, k, &mut rng));
        (x, c)
    }

    /// Property test for the shared [`SavedBounds`] machinery: for a bound
    /// engine, `checkpoint → assign(perturbed centroids) → rollback →
    /// assign(original)` must reproduce bit-identical assignments *and*
    /// bounds versus a fresh engine that never jumped, across several
    /// random problems and perturbations — and stay bit-identical through
    /// a subsequent Lloyd step.
    pub fn checkpoint_rollback_matches_fresh<E: AssignmentEngine>(
        mut engine: E,
        mut fresh: E,
        state: impl Fn(&E) -> (Vec<f64>, Vec<f64>, Vec<u32>),
    ) {
        use crate::rng::Rng;
        let pool = ThreadPool::new(1);
        let mut rng = Pcg32::seed_from_u64(0xB0B5);
        for round in 0..4u64 {
            let (x, c) = small_problem(600 + round, 400, 4, 12);
            engine.reset();
            fresh.reset();
            let mut out = Assignment::new();
            let mut out_fresh = Assignment::new();
            engine.assign(&x, &c, &pool, &mut out);
            fresh.assign(&x, &c, &pool, &mut out_fresh);
            engine.checkpoint();
            // Jump to a random perturbation (an accelerated candidate)...
            let mut c_jump = c.clone();
            for j in 0..c_jump.n() {
                for t in 0..c_jump.d() {
                    c_jump[(j, t)] += 0.5 * rng.next_gaussian();
                }
            }
            engine.assign(&x, &c_jump, &pool, &mut out);
            // ...and roll back, as the solver does on a rejected jump.
            assert!(engine.rollback(), "round {round}: rollback must restore");
            // Re-assigning the original centroids must look exactly like a
            // fresh engine re-assigning them (zero drift both ways).
            engine.assign(&x, &c, &pool, &mut out);
            fresh.assign(&x, &c, &pool, &mut out_fresh);
            assert_eq!(out, out_fresh, "round {round}: assignments diverged after rollback");
            assert_bound_state_eq(&state(&engine), &state(&fresh), round, "post-rollback");
            // One real Lloyd step keeps the two engines in lock-step.
            let mut c_next = c.clone();
            update_step(&x, &out_fresh, &c, &mut c_next, &pool);
            engine.assign(&x, &c_next, &pool, &mut out);
            fresh.assign(&x, &c_next, &pool, &mut out_fresh);
            assert_eq!(out, out_fresh, "round {round}: assignments diverged after update");
            assert_bound_state_eq(&state(&engine), &state(&fresh), round, "post-update");
        }
    }

    fn assert_bound_state_eq(
        got: &(Vec<f64>, Vec<f64>, Vec<u32>),
        want: &(Vec<f64>, Vec<f64>, Vec<u32>),
        round: u64,
        stage: &str,
    ) {
        assert_eq!(got.2, want.2, "round {round} {stage}: stored assignments diverged");
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(
            bits(&got.0),
            bits(&want.0),
            "round {round} {stage}: upper bounds diverged"
        );
        assert_eq!(
            bits(&got.1),
            bits(&want.1),
            "round {round} {stage}: lower bounds diverged"
        );
    }

    /// Assert an engine agrees with brute force across several rounds of
    /// centroid motion (including non-Lloyd "accelerated" jumps).
    pub fn engine_matches_brute_force(engine: &mut dyn AssignmentEngine) {
        let pool = ThreadPool::new(2);
        let (x, mut c) = small_problem(404, 600, 5, 8);
        let mut rng = Pcg32::seed_from_u64(505);
        let mut out = Assignment::new();
        for round in 0..6 {
            engine.assign(&x, &c, &pool, &mut out);
            let expect = brute_force_assign(&x, &c);
            // Ties can differ between engines; compare distances instead of ids.
            for i in 0..x.n() {
                let got_d = dist_sq(x.row(i), c.row(out[i] as usize));
                let exp_d = dist_sq(x.row(i), c.row(expect[i] as usize));
                assert!(
                    (got_d - exp_d).abs() < 1e-9,
                    "{}: round {round} sample {i}: {got_d} vs {exp_d}",
                    engine.name()
                );
            }
            // Move centroids: alternate Lloyd-like small steps and random
            // jumps (mimicking accepted accelerated iterates).
            if round % 2 == 0 {
                let mut next = c.clone();
                update_step(&x, &out, &c, &mut next, &pool);
                c = next;
            } else {
                use crate::rng::Rng;
                for j in 0..c.n() {
                    for t in 0..c.d() {
                        c[(j, t)] += 0.2 * rng.next_gaussian();
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::rng::Pcg32;

    #[test]
    fn update_step_computes_means() {
        let x = DataMatrix::from_rows(&[&[0.0, 0.0], &[2.0, 0.0], &[10.0, 10.0]]);
        let prev = DataMatrix::from_rows(&[&[0.0, 0.0], &[9.0, 9.0], &[-5.0, -5.0]]);
        let assign = vec![0, 0, 1];
        let mut out = DataMatrix::zeros(3, 2);
        let pool = ThreadPool::new(1);
        let counts = update_step(&x, &assign, &prev, &mut out, &pool);
        assert_eq!(counts, vec![2, 1, 0]);
        assert_eq!(out.row(0), &[1.0, 0.0]);
        assert_eq!(out.row(1), &[10.0, 10.0]);
        // Empty cluster 2 keeps its previous position.
        assert_eq!(out.row(2), &[-5.0, -5.0]);
    }

    #[test]
    fn energy_matches_manual() {
        let x = DataMatrix::from_rows(&[&[0.0], &[4.0]]);
        let c = DataMatrix::from_rows(&[&[1.0]]);
        let assign = vec![0, 0];
        let pool = ThreadPool::new(1);
        // (0-1)^2 + (4-1)^2 = 1 + 9
        assert_eq!(energy(&x, &c, &assign, &pool), 10.0);
        assert_eq!(mse(&x, &c, &assign, &pool), 5.0);
    }

    #[test]
    fn update_parallel_equals_serial() {
        let mut rng = Pcg32::seed_from_u64(77);
        let x = synth::gaussian_blobs(&mut rng, 3000, 6, 5, 2.0, 0.4);
        let c0 = x.gather_rows(&[0, 100, 200, 300, 400]);
        let assign = brute_force_assign(&x, &c0);
        let pool1 = ThreadPool::new(1);
        let pool4 = ThreadPool::new(4);
        let mut out1 = DataMatrix::zeros(5, 6);
        let mut out4 = DataMatrix::zeros(5, 6);
        let c1 = update_step(&x, &assign, &c0, &mut out1, &pool1);
        let c4 = update_step(&x, &assign, &c0, &mut out4, &pool4);
        assert_eq!(c1, c4);
        for j in 0..5 {
            for t in 0..6 {
                assert!((out1[(j, t)] - out4[(j, t)]).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn update_with_matches_allocating_variants() {
        let mut rng = Pcg32::seed_from_u64(91);
        let x = synth::gaussian_blobs(&mut rng, 1500, 5, 6, 2.0, 0.4);
        let c0 = x.gather_rows(&[0, 200, 400, 600, 800, 1000]);
        let assign = brute_force_assign(&x, &c0);
        for threads in [1usize, 4] {
            let pool = ThreadPool::new(threads);
            let mut scratch = UpdateScratch::default();
            let mut ref_c = DataMatrix::zeros(6, 5);
            let (_, ref_e) = update_and_energy(&x, &assign, &c0, &mut ref_c, &pool);
            // Repeated calls reuse the same lane accumulators.
            for round in 0..3 {
                let mut got_c = DataMatrix::zeros(6, 5);
                let e = update_and_energy_with(&x, &assign, &c0, &mut got_c, &pool, &mut scratch);
                assert!(
                    (e - ref_e).abs() <= 1e-9 * ref_e.max(1.0),
                    "threads={threads} round={round}: {e} vs {ref_e}"
                );
                let mut step_c = DataMatrix::zeros(6, 5);
                update_step_with(&x, &assign, &c0, &mut step_c, &pool, &mut scratch);
                for j in 0..6 {
                    for t in 0..5 {
                        assert!((got_c[(j, t)] - ref_c[(j, t)]).abs() < 1e-9);
                        assert!((step_c[(j, t)] - ref_c[(j, t)]).abs() < 1e-9);
                    }
                }
            }
        }
    }

    #[test]
    fn reseed_fills_empties_deterministically() {
        // Adversarial init: sample two tight blobs, then park three of the
        // five centroids far outside the data so they capture nothing. The
        // two live anchors sit 1e-6 off their seed samples so no data point
        // ever coincides with a surviving centroid (ties break by index and
        // would otherwise starve a reseeded cluster of its own seed sample).
        let mut rng = Pcg32::seed_from_u64(1234);
        let x = synth::gaussian_blobs(&mut rng, 400, 3, 2, 3.0, 0.2);
        let mut c = DataMatrix::zeros(5, 3);
        c.row_mut(0).copy_from_slice(x.row(0));
        c.row_mut(1).copy_from_slice(x.row(200));
        for j in 0..2 {
            for v in c.row_mut(j) {
                *v += 1e-6;
            }
        }
        for j in 2..5 {
            for v in c.row_mut(j) {
                *v = 1.0e6 + j as f64;
            }
        }
        let assign = brute_force_assign(&x, &c);
        let mut counts = vec![0usize; 5];
        for &a in &assign {
            counts[a as usize] += 1;
        }
        assert_eq!(&counts[2..], &[0, 0, 0], "init must leave clusters 2..5 empty");

        let mut c_a = c.clone();
        let got = reseed_empty_clusters(&x, &assign, &mut c_a, 42, 7);
        assert_eq!(got, 3);
        // Same seed/iteration → bit-identical outcome.
        let mut c_b = c.clone();
        reseed_empty_clusters(&x, &assign, &mut c_b, 42, 7);
        for j in 0..5 {
            let bits = |r: &[f64]| r.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(c_a.row(j)), bits(c_b.row(j)), "cluster {j} not deterministic");
        }
        // Non-empty clusters are untouched; each reseeded centroid sits on a
        // distinct data sample, so the next assignment pass gives everyone
        // at least one member.
        for j in 0..2 {
            assert_eq!(c_a.row(j), c.row(j));
        }
        let re = brute_force_assign(&x, &c_a);
        let mut re_counts = vec![0usize; 5];
        for &a in &re {
            re_counts[a as usize] += 1;
        }
        assert!(re_counts.iter().all(|&cnt| cnt > 0), "still empty: {re_counts:?}");
        // And the split strictly reduced energy.
        let pool = ThreadPool::new(1);
        let before = energy(&x, &c, &assign, &pool);
        let after = energy(&x, &c_a, &re, &pool);
        assert!(after < before, "reseed must reduce energy: {after} vs {before}");
        // No-op when nothing is empty.
        let mut c_c = c_a.clone();
        assert_eq!(reseed_empty_clusters(&x, &re, &mut c_c, 42, 8), 0);
        for j in 0..5 {
            assert_eq!(c_c.row(j), c_a.row(j));
        }
    }

    #[test]
    fn reseed_property_random_adversarial_inits() {
        // Property sweep: random problems with deliberately colliding
        // centroids (duplicates guarantee empties under min-distance
        // tie-breaking). After reseed + reassign, no cluster may be empty
        // as long as there are enough samples, and repeated invocation is
        // stable (idempotent once nothing is empty).
        for trial in 0..6u64 {
            let mut rng = Pcg32::seed_from_u64(900 + trial);
            let x = synth::gaussian_blobs(&mut rng, 300, 4, 3, 2.5, 0.3);
            let k = 6usize;
            // All centroids start on the same off-sample point (1e-6 past a
            // sample, so no data point ties with a surviving centroid):
            // index-order tie-breaking sends every sample to cluster 0 and
            // leaves the other k-1 clusters empty.
            let mut c = DataMatrix::zeros(k, 4);
            for j in 0..k {
                c.row_mut(j).copy_from_slice(x.row(5));
                for v in c.row_mut(j) {
                    *v += 1e-6;
                }
            }
            let assign = brute_force_assign(&x, &c);
            let reseeded = reseed_empty_clusters(&x, &assign, &mut c, trial, trial * 3);
            assert_eq!(reseeded, k - 1, "trial {trial}");
            let re = brute_force_assign(&x, &c);
            let mut counts = vec![0usize; k];
            for &a in &re {
                counts[a as usize] += 1;
            }
            assert!(
                counts.iter().all(|&cnt| cnt > 0),
                "trial {trial}: empties survived reseed: {counts:?}"
            );
            assert_eq!(reseed_empty_clusters(&x, &re, &mut c, trial, trial * 3 + 1), 0);
        }
    }

    #[test]
    fn reseed_leaves_surplus_empties_when_samples_run_out() {
        // Fewer samples than clusters: the policy reseeds what it can and
        // leaves the rest untouched rather than duplicating points.
        let x = DataMatrix::from_rows(&[&[0.0, 0.0], &[1.0, 0.0]]);
        let mut c = DataMatrix::from_rows(&[&[0.4, 0.0], &[9.0, 9.0], &[8.0, 8.0], &[7.0, 7.0]]);
        let assign = vec![0u32, 0];
        let reseeded = reseed_empty_clusters(&x, &assign, &mut c, 1, 1);
        assert_eq!(reseeded, 1, "only one member can be donated");
        assert_eq!(c.row(2), &[8.0, 8.0]);
        assert_eq!(c.row(3), &[7.0, 7.0]);
    }

    #[test]
    fn lloyd_iteration_decreases_energy() {
        let (x, mut c) = test_support::small_problem(9, 500, 4, 6);
        let pool = ThreadPool::new(1);
        let mut prev_energy = f64::INFINITY;
        for _ in 0..20 {
            let assign = brute_force_assign(&x, &c);
            let e = energy(&x, &c, &assign, &pool);
            assert!(
                e <= prev_energy + 1e-9,
                "Lloyd iteration must not increase energy: {e} > {prev_energy}"
            );
            prev_energy = e;
            let mut next = c.clone();
            update_step(&x, &assign, &c, &mut next, &pool);
            c = next;
        }
    }
}
