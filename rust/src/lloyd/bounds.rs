//! Shared checkpoint/rollback store for bound-based assignment engines.
//!
//! Hamerly, Elkan and Yinyang all keep the same restorable state shape —
//! the previous centroid set, a per-sample upper-bound vector, a flat
//! lower-bound vector (per sample, per sample×centroid and per
//! sample×group respectively) and the current assignment — and all three
//! grew structurally identical save/restore implementations for the
//! accelerated solver's rejected-jump rollback. [`SavedBounds`] is that
//! machinery extracted once: engines call [`SavedBounds::checkpoint`]
//! with borrows of their live state and [`SavedBounds::rollback_into`]
//! to restore it, so the next bounds-state fix (or a new bound engine)
//! lands in one place.
//!
//! The retained buffers are overwritten in place whenever the shapes
//! match, so checkpoints on warm same-shape runs allocate nothing —
//! exactly the contract the per-engine copies enforced (see
//! `tests/alloc_reuse.rs`).

use crate::data::DataMatrix;

/// Saved `(prev_c, upper, lower, assign)` engine state plus a validity
/// flag. The buffers persist (and are reused) across checkpoints and
/// runs; `valid` marks whether they currently hold a restorable state.
#[derive(Debug, Default)]
pub struct SavedBounds {
    saved: Option<(DataMatrix, Vec<f64>, Vec<f64>, Vec<u32>)>,
    valid: bool,
}

impl SavedBounds {
    /// Mark any held state as non-restorable (engine `reset`). The
    /// buffers keep their capacity for the next checkpoint.
    pub fn invalidate(&mut self) {
        self.valid = false;
    }

    /// Whether a rollback would currently restore state.
    pub fn is_valid(&self) -> bool {
        self.valid
    }

    /// Save the engine's live state. Reuses the retained buffers in
    /// place when every shape matches; reallocates otherwise (first
    /// checkpoint, or a problem-shape change).
    pub fn checkpoint(
        &mut self,
        prev_c: &DataMatrix,
        upper: &[f64],
        lower: &[f64],
        assign: &[u32],
    ) {
        match &mut self.saved {
            Some((sc, su, sl, sa))
                if sc.n() == prev_c.n()
                    && sc.d() == prev_c.d()
                    && su.len() == upper.len()
                    && sl.len() == lower.len() =>
            {
                sc.as_mut_slice().copy_from_slice(prev_c.as_slice());
                su.copy_from_slice(upper);
                sl.copy_from_slice(lower);
                sa.copy_from_slice(assign);
            }
            _ => {
                self.saved =
                    Some((prev_c.clone(), upper.to_vec(), lower.to_vec(), assign.to_vec()));
            }
        }
        self.valid = true;
    }

    /// Restore the saved state into the engine's live buffers, consuming
    /// the validity flag. Returns `false` (leaving the live state
    /// untouched) when no restorable state is held — callers then
    /// proceed with drifted bounds, which is correct but prunes less.
    pub fn rollback_into(
        &mut self,
        prev_c: &mut Option<DataMatrix>,
        upper: &mut Vec<f64>,
        lower: &mut Vec<f64>,
        assign: &mut Vec<u32>,
    ) -> bool {
        if !self.valid {
            return false;
        }
        self.valid = false;
        let Some((sc, su, sl, sa)) = &self.saved else { return false };
        match prev_c {
            Some(p) if p.n() == sc.n() && p.d() == sc.d() => {
                p.as_mut_slice().copy_from_slice(sc.as_slice());
            }
            _ => *prev_c = Some(sc.clone()),
        }
        upper.clear();
        upper.extend_from_slice(su);
        lower.clear();
        lower.extend_from_slice(sl);
        assign.clear();
        assign.extend_from_slice(sa);
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkpoint_then_rollback_roundtrips() {
        let mut sb = SavedBounds::default();
        let c = DataMatrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let upper = vec![0.5, 0.25, 0.75];
        let lower = vec![1.5, 1.25, 1.75];
        let assign = vec![0u32, 1, 1];
        assert!(!sb.is_valid());
        sb.checkpoint(&c, &upper, &lower, &assign);
        assert!(sb.is_valid());

        let mut prev_c = Some(DataMatrix::zeros(2, 2));
        let mut u = vec![9.0; 3];
        let mut l = vec![9.0; 3];
        let mut a = vec![7u32; 3];
        assert!(sb.rollback_into(&mut prev_c, &mut u, &mut l, &mut a));
        assert_eq!(prev_c.as_ref().unwrap().as_slice(), c.as_slice());
        assert_eq!(u, upper);
        assert_eq!(l, lower);
        assert_eq!(a, assign);
        // The flag is consumed: a second rollback is a no-op.
        u[0] = -1.0;
        assert!(!sb.rollback_into(&mut prev_c, &mut u, &mut l, &mut a));
        assert_eq!(u[0], -1.0);
    }

    #[test]
    fn invalidate_blocks_rollback_but_keeps_buffers() {
        let mut sb = SavedBounds::default();
        let c = DataMatrix::zeros(2, 3);
        sb.checkpoint(&c, &[1.0, 2.0], &[3.0, 4.0], &[0, 1]);
        sb.invalidate();
        let mut prev_c = None;
        let (mut u, mut l, mut a) = (Vec::new(), Vec::new(), Vec::new());
        assert!(!sb.rollback_into(&mut prev_c, &mut u, &mut l, &mut a));
        assert!(prev_c.is_none());
        // A fresh checkpoint revalidates without reallocating shape-matched
        // buffers.
        sb.checkpoint(&c, &[5.0, 6.0], &[7.0, 8.0], &[1, 0]);
        assert!(sb.rollback_into(&mut prev_c, &mut u, &mut l, &mut a));
        assert_eq!(u, vec![5.0, 6.0]);
        assert_eq!(a, vec![1, 0]);
    }

    #[test]
    fn shape_change_reallocates() {
        let mut sb = SavedBounds::default();
        sb.checkpoint(&DataMatrix::zeros(2, 2), &[1.0], &[2.0], &[0]);
        // Different shapes force the fallback path.
        sb.checkpoint(&DataMatrix::zeros(3, 2), &[1.0, 2.0], &[3.0, 4.0], &[0, 1]);
        let mut prev_c = None;
        let (mut u, mut l, mut a) = (Vec::new(), Vec::new(), Vec::new());
        assert!(sb.rollback_into(&mut prev_c, &mut u, &mut l, &mut a));
        assert_eq!(prev_c.unwrap().n(), 3);
        assert_eq!(u.len(), 2);
    }
}
