//! Hamerly's assignment algorithm (Hamerly 2010) — one upper bound on the
//! distance to the assigned centroid and one lower bound on the distance to
//! the second-closest centroid per sample, invalidated by centroid motion.
//!
//! This is the assignment engine the paper builds Algorithm 1 on. Crucially,
//! the bounds stay valid under *arbitrary* centroid motion (the update rule
//! only needs how far each centroid moved), so they survive accelerated
//! iterates and the occasional revert-to-`C_AU` fall-back.

use super::{Assignment, AssignmentEngine};
use crate::data::DataMatrix;
use crate::linalg::{dist_sq, DistanceKernel};
use crate::par::{SyncSliceMut, ThreadPool};
use std::sync::atomic::{AtomicU64, Ordering};

/// Hamerly-bounds assignment engine.
#[derive(Debug, Default)]
pub struct HamerlyEngine {
    /// Blocked norm-decomposed distance kernel (per-engine cache).
    kernel: DistanceKernel,
    /// Centroids seen at the previous call.
    prev_c: Option<DataMatrix>,
    /// Upper bound: d(x_i, c_{a_i}).
    upper: Vec<f64>,
    /// Lower bound: d(x_i, second-closest centroid).
    lower: Vec<f64>,
    /// Current assignment.
    assign: Vec<u32>,
    /// Saved state for [`AssignmentEngine::rollback`] after rejected
    /// accelerated jumps: `(prev_c, upper, lower, assign)`.
    saved: Option<(DataMatrix, Vec<f64>, Vec<f64>, Vec<u32>)>,
    dist_evals: AtomicU64,
}

impl HamerlyEngine {
    pub fn new() -> Self {
        Self::default()
    }

    /// Engine whose kernel stores samples at the given precision.
    pub fn with_precision(precision: crate::linalg::Precision) -> Self {
        Self { kernel: DistanceKernel::with_precision(precision), ..Self::default() }
    }

    /// Full O(NK) initialization of bounds + assignment.
    fn initialize(&mut self, x: &DataMatrix, c: &DataMatrix, pool: &ThreadPool) {
        let (n, k) = (x.n(), c.n());
        self.upper.resize(n, 0.0);
        self.lower.resize(n, 0.0);
        self.assign.resize(n, 0);
        let upper = SyncSliceMut::new(&mut self.upper);
        let lower = SyncSliceMut::new(&mut self.lower);
        let assign = SyncSliceMut::new(&mut self.assign);
        let kernel = &self.kernel;
        let evals = AtomicU64::new(0);
        pool.parallel_for(n, 256, |range| {
            // One fused kernel sweep yields both bounds per sample.
            let local = (range.len() * k) as u64;
            kernel.argmin2_range(x, c, range, |i, b| {
                *upper.at(i) = b.best_d.sqrt();
                *lower.at(i) = b.second_d.sqrt();
                *assign.at(i) = b.best;
            });
            evals.fetch_add(local, Ordering::Relaxed);
        });
        self.dist_evals.fetch_add(evals.load(Ordering::Relaxed), Ordering::Relaxed);
    }
}

impl AssignmentEngine for HamerlyEngine {
    fn name(&self) -> &'static str {
        "hamerly"
    }

    fn assign(&mut self, x: &DataMatrix, c: &DataMatrix, pool: &ThreadPool, out: &mut Assignment) {
        let (n, k, d) = (x.n(), c.n(), x.d());
        self.kernel.prepare(x, c, pool);
        let stale = match &self.prev_c {
            Some(prev) => prev.n() != k || prev.d() != d || self.assign.len() != n,
            None => true,
        };
        if stale {
            self.initialize(x, c, pool);
            self.prev_c = Some(c.clone());
            out.clear();
            out.extend_from_slice(&self.assign);
            return;
        }
        let prev = self.prev_c.as_ref().unwrap();
        // Per-centroid movement; track the largest and second largest so a
        // sample assigned to the arg-max centroid uses the runner-up.
        let mut moved = vec![0.0f64; k];
        let (mut max1, mut max2, mut argmax) = (0.0f64, 0.0f64, usize::MAX);
        for j in 0..k {
            let m = dist_sq(prev.row(j), c.row(j)).sqrt();
            moved[j] = m;
            if m > max1 {
                max2 = max1;
                max1 = m;
                argmax = j;
            } else if m > max2 {
                max2 = m;
            }
        }
        // Half distance from each centroid to its nearest other centroid.
        let mut s = vec![f64::INFINITY; k];
        for j in 0..k {
            for j2 in (j + 1)..k {
                let d_jj = dist_sq(c.row(j), c.row(j2)).sqrt();
                if d_jj < s[j] {
                    s[j] = d_jj;
                }
                if d_jj < s[j2] {
                    s[j2] = d_jj;
                }
            }
        }
        for v in s.iter_mut() {
            *v *= 0.5;
        }

        let upper = SyncSliceMut::new(&mut self.upper);
        let lower = SyncSliceMut::new(&mut self.lower);
        let assign = SyncSliceMut::new(&mut self.assign);
        let kernel = &self.kernel;
        let evals = AtomicU64::new(0);
        pool.parallel_for(n, 256, |range| {
            let mut local = 0u64;
            for i in range {
                let a = *assign.at(i) as usize;
                // Drift the bounds by centroid motion.
                let u = *upper.at(i) + moved[a];
                let loosen = if a == argmax { max2 } else { max1 };
                let l = *lower.at(i) - loosen;
                *upper.at(i) = u;
                *lower.at(i) = l;
                let threshold = s[a].max(l);
                if u <= threshold {
                    continue; // bound test passed, assignment unchanged
                }
                // Tighten the upper bound with one real distance.
                let tight = kernel.dist_sq(x, c, i, a).sqrt();
                local += 1;
                *upper.at(i) = tight;
                if tight <= threshold {
                    continue;
                }
                // Full scan through the fused blocked kernel: one sweep
                // refreshes both bounds.
                let b = kernel.argmin2_row(x, c, i);
                local += k as u64;
                *upper.at(i) = b.best_d.sqrt();
                *lower.at(i) = b.second_d.sqrt();
                *assign.at(i) = b.best;
            }
            evals.fetch_add(local, Ordering::Relaxed);
        });
        self.dist_evals.fetch_add(evals.load(Ordering::Relaxed), Ordering::Relaxed);
        self.prev_c = Some(c.clone());
        out.clear();
        out.extend_from_slice(&self.assign);
    }

    fn reset(&mut self) {
        self.kernel.invalidate();
        self.prev_c = None;
        self.upper.clear();
        self.lower.clear();
        self.assign.clear();
        self.saved = None;
    }

    fn distance_evals(&self) -> u64 {
        self.dist_evals.load(Ordering::Relaxed)
    }

    fn checkpoint(&mut self) {
        if let Some(prev) = &self.prev_c {
            self.saved =
                Some((prev.clone(), self.upper.clone(), self.lower.clone(), self.assign.clone()));
        }
    }

    fn rollback(&mut self) -> bool {
        match self.saved.take() {
            Some((prev, upper, lower, assign)) => {
                self.prev_c = Some(prev);
                self.upper = upper;
                self.lower = lower;
                self.assign = assign;
                true
            }
            None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lloyd::test_support::engine_matches_brute_force;
    use crate::lloyd::{brute_force_assign, update_step};

    #[test]
    fn matches_brute_force_over_rounds() {
        engine_matches_brute_force(&mut HamerlyEngine::new());
    }

    #[test]
    fn saves_distance_evals_vs_naive() {
        // Over a converging Lloyd run, Hamerly must do far fewer distance
        // evaluations than N*K per iteration.
        let (x, mut c) = crate::lloyd::test_support::small_problem(42, 2000, 4, 10);
        let pool = ThreadPool::new(1);
        let mut engine = HamerlyEngine::new();
        let mut out = Assignment::new();
        let mut iters = 0;
        loop {
            let before = engine.distance_evals();
            engine.assign(&x, &c, &pool, &mut out);
            let evals = engine.distance_evals() - before;
            if iters > 2 {
                assert!(
                    evals < (x.n() * c.n()) as u64 / 2,
                    "iter {iters}: {evals} evals is not better than half of naive"
                );
            }
            let mut next = c.clone();
            update_step(&x, &out, &c, &mut next, &pool);
            if next.frob_dist(&c) < 1e-12 || iters > 60 {
                break;
            }
            c = next;
            iters += 1;
        }
        assert!(iters > 3, "problem should take a few iterations");
    }

    #[test]
    fn single_cluster_works() {
        let x = DataMatrix::from_rows(&[&[0.0, 0.0], &[1.0, 1.0]]);
        let c = DataMatrix::from_rows(&[&[0.5, 0.5]]);
        let pool = ThreadPool::new(1);
        let mut engine = HamerlyEngine::new();
        let mut out = Assignment::new();
        engine.assign(&x, &c, &pool, &mut out);
        assert_eq!(out, vec![0, 0]);
        // Second call with moved centroid still works.
        let c2 = DataMatrix::from_rows(&[&[5.0, 5.0]]);
        engine.assign(&x, &c2, &pool, &mut out);
        assert_eq!(out, vec![0, 0]);
    }

    #[test]
    fn reset_reinitializes() {
        let (x, c) = crate::lloyd::test_support::small_problem(7, 100, 3, 4);
        let pool = ThreadPool::new(1);
        let mut engine = HamerlyEngine::new();
        let mut out = Assignment::new();
        engine.assign(&x, &c, &pool, &mut out);
        engine.reset();
        engine.assign(&x, &c, &pool, &mut out);
        let expect = brute_force_assign(&x, &c);
        for i in 0..x.n() {
            let got_d = dist_sq(x.row(i), c.row(out[i] as usize));
            let exp_d = dist_sq(x.row(i), c.row(expect[i] as usize));
            assert!((got_d - exp_d).abs() < 1e-9);
        }
    }

    #[test]
    fn k_change_triggers_reinit() {
        let (x, c) = crate::lloyd::test_support::small_problem(8, 120, 3, 4);
        let pool = ThreadPool::new(1);
        let mut engine = HamerlyEngine::new();
        let mut out = Assignment::new();
        engine.assign(&x, &c, &pool, &mut out);
        // Different K: engine must not panic and must stay correct.
        let c2 = c.gather_rows(&[0, 1]);
        engine.assign(&x, &c2, &pool, &mut out);
        let expect = brute_force_assign(&x, &c2);
        for i in 0..x.n() {
            let got_d = dist_sq(x.row(i), c2.row(out[i] as usize));
            let exp_d = dist_sq(x.row(i), c2.row(expect[i] as usize));
            assert!((got_d - exp_d).abs() < 1e-9);
        }
    }
}
