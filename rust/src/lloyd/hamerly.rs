//! Hamerly's assignment algorithm (Hamerly 2010) — one upper bound on the
//! distance to the assigned centroid and one lower bound on the distance to
//! the second-closest centroid per sample, invalidated by centroid motion.
//!
//! This is the assignment engine the paper builds Algorithm 1 on. Crucially,
//! the bounds stay valid under *arbitrary* centroid motion (the update rule
//! only needs how far each centroid moved), so they survive accelerated
//! iterates and the occasional revert-to-`C_AU` fall-back.

use super::{Assignment, AssignmentEngine, SavedBounds};
use crate::data::DataMatrix;
use crate::linalg::{dist_sq, DistanceKernel};
use crate::par::{SyncSliceMut, ThreadPool};
use std::sync::atomic::{AtomicU64, Ordering};

/// Hamerly-bounds assignment engine.
#[derive(Debug, Default)]
pub struct HamerlyEngine {
    /// Blocked norm-decomposed distance kernel (per-engine cache).
    kernel: DistanceKernel,
    /// Centroids seen at the previous call. The buffer survives `reset`
    /// (only `prev_valid` drops) so warm same-shape runs never reallocate.
    prev_c: Option<DataMatrix>,
    prev_valid: bool,
    /// Upper bound: d(x_i, c_{a_i}).
    upper: Vec<f64>,
    /// Lower bound: d(x_i, second-closest centroid).
    lower: Vec<f64>,
    /// Current assignment.
    assign: Vec<u32>,
    /// Saved state for [`AssignmentEngine::rollback`] after rejected
    /// accelerated jumps (shared store/checkpoint/rollback machinery —
    /// see [`SavedBounds`]).
    saved: SavedBounds,
    /// Per-call scratch (per-centroid motion and half nearest-centroid
    /// distances), persistent so warm calls stay allocation-free.
    moved: Vec<f64>,
    s_half: Vec<f64>,
    dist_evals: AtomicU64,
}

impl HamerlyEngine {
    pub fn new() -> Self {
        Self::default()
    }

    /// Engine whose kernel stores samples at the given precision.
    pub fn with_precision(precision: crate::linalg::Precision) -> Self {
        Self { kernel: DistanceKernel::with_precision(precision), ..Self::default() }
    }

    /// Remember `c` as the previous centroid set, reusing the existing
    /// buffer when the shape matches (no allocation on warm calls).
    fn store_prev(&mut self, c: &DataMatrix) {
        match &mut self.prev_c {
            Some(p) if p.n() == c.n() && p.d() == c.d() => {
                p.as_mut_slice().copy_from_slice(c.as_slice());
            }
            _ => self.prev_c = Some(c.clone()),
        }
        self.prev_valid = true;
    }

    /// Live bound state (bounds + assignment) for the checkpoint/rollback
    /// property tests.
    #[cfg(test)]
    pub(crate) fn bound_state(&self) -> (Vec<f64>, Vec<f64>, Vec<u32>) {
        (self.upper.clone(), self.lower.clone(), self.assign.clone())
    }

    /// Full O(NK) initialization of bounds + assignment.
    fn initialize(&mut self, x: &DataMatrix, c: &DataMatrix, pool: &ThreadPool) {
        let (n, k) = (x.n(), c.n());
        self.upper.resize(n, 0.0);
        self.lower.resize(n, 0.0);
        self.assign.resize(n, 0);
        let upper = SyncSliceMut::new(&mut self.upper);
        let lower = SyncSliceMut::new(&mut self.lower);
        let assign = SyncSliceMut::new(&mut self.assign);
        let kernel = &self.kernel;
        let evals = AtomicU64::new(0);
        pool.parallel_for(n, 256, |range| {
            // One fused kernel sweep yields both bounds per sample.
            let local = (range.len() * k) as u64;
            kernel.argmin2_range(x, c, range, |i, b| {
                *upper.at(i) = b.best_d.sqrt();
                *lower.at(i) = b.second_d.sqrt();
                *assign.at(i) = b.best;
            });
            evals.fetch_add(local, Ordering::Relaxed);
        });
        self.dist_evals.fetch_add(evals.load(Ordering::Relaxed), Ordering::Relaxed);
    }
}

impl AssignmentEngine for HamerlyEngine {
    fn name(&self) -> &'static str {
        "hamerly"
    }

    fn assign(&mut self, x: &DataMatrix, c: &DataMatrix, pool: &ThreadPool, out: &mut Assignment) {
        let (n, k, d) = (x.n(), c.n(), x.d());
        self.kernel.prepare(x, c, pool);
        let stale = !self.prev_valid
            || match &self.prev_c {
                Some(prev) => prev.n() != k || prev.d() != d || self.assign.len() != n,
                None => true,
            };
        if stale {
            self.initialize(x, c, pool);
            self.store_prev(c);
            out.clear();
            out.extend_from_slice(&self.assign);
            return;
        }
        // Per-centroid movement; track the largest and second largest so a
        // sample assigned to the arg-max centroid uses the runner-up.
        self.moved.clear();
        self.moved.resize(k, 0.0);
        let (mut max1, mut max2, mut argmax) = (0.0f64, 0.0f64, usize::MAX);
        {
            let prev = self.prev_c.as_ref().unwrap();
            for j in 0..k {
                let m = dist_sq(prev.row(j), c.row(j)).sqrt();
                self.moved[j] = m;
                if m > max1 {
                    max2 = max1;
                    max1 = m;
                    argmax = j;
                } else if m > max2 {
                    max2 = m;
                }
            }
        }
        // Half distance from each centroid to its nearest other centroid.
        self.s_half.clear();
        self.s_half.resize(k, f64::INFINITY);
        for j in 0..k {
            for j2 in (j + 1)..k {
                let d_jj = dist_sq(c.row(j), c.row(j2)).sqrt();
                if d_jj < self.s_half[j] {
                    self.s_half[j] = d_jj;
                }
                if d_jj < self.s_half[j2] {
                    self.s_half[j2] = d_jj;
                }
            }
        }
        for v in self.s_half.iter_mut() {
            *v *= 0.5;
        }

        let moved: &[f64] = &self.moved;
        let s: &[f64] = &self.s_half;
        let upper = SyncSliceMut::new(&mut self.upper);
        let lower = SyncSliceMut::new(&mut self.lower);
        let assign = SyncSliceMut::new(&mut self.assign);
        let kernel = &self.kernel;
        let evals = AtomicU64::new(0);
        pool.parallel_for(n, 256, |range| {
            let mut local = 0u64;
            for i in range {
                let a = *assign.at(i) as usize;
                // Drift the bounds by centroid motion.
                let u = *upper.at(i) + moved[a];
                let loosen = if a == argmax { max2 } else { max1 };
                let l = *lower.at(i) - loosen;
                *upper.at(i) = u;
                *lower.at(i) = l;
                let threshold = s[a].max(l);
                if u <= threshold {
                    continue; // bound test passed, assignment unchanged
                }
                // Tighten the upper bound with one real distance.
                let tight = kernel.dist_sq(x, c, i, a).sqrt();
                local += 1;
                *upper.at(i) = tight;
                if tight <= threshold {
                    continue;
                }
                // Full scan through the fused blocked kernel: one sweep
                // refreshes both bounds.
                let b = kernel.argmin2_row(x, c, i);
                local += k as u64;
                *upper.at(i) = b.best_d.sqrt();
                *lower.at(i) = b.second_d.sqrt();
                *assign.at(i) = b.best;
            }
            evals.fetch_add(local, Ordering::Relaxed);
        });
        self.dist_evals.fetch_add(evals.load(Ordering::Relaxed), Ordering::Relaxed);
        self.store_prev(c);
        out.clear();
        out.extend_from_slice(&self.assign);
    }

    fn reset(&mut self) {
        // Keep the buffers (capacity) but mark the bound state unusable.
        // The kernel's sample-norm cache stays: it is keyed on the data's
        // generation stamp, so same-data reruns skip the norm pass.
        self.prev_valid = false;
        self.upper.clear();
        self.lower.clear();
        self.assign.clear();
        self.saved.invalidate();
    }

    fn distance_evals(&self) -> u64 {
        self.dist_evals.load(Ordering::Relaxed)
    }

    fn checkpoint(&mut self) {
        if !self.prev_valid {
            return;
        }
        let Some(prev) = &self.prev_c else { return };
        self.saved.checkpoint(prev, &self.upper, &self.lower, &self.assign);
    }

    fn rollback(&mut self) -> bool {
        self.saved.rollback_into(
            &mut self.prev_c,
            &mut self.upper,
            &mut self.lower,
            &mut self.assign,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lloyd::test_support::engine_matches_brute_force;
    use crate::lloyd::{brute_force_assign, update_step};

    #[test]
    fn matches_brute_force_over_rounds() {
        engine_matches_brute_force(&mut HamerlyEngine::new());
    }

    #[test]
    fn checkpoint_rollback_reproduces_fresh_engine_state() {
        crate::lloyd::test_support::checkpoint_rollback_matches_fresh(
            HamerlyEngine::new(),
            HamerlyEngine::new(),
            HamerlyEngine::bound_state,
        );
    }

    #[test]
    fn saves_distance_evals_vs_naive() {
        // Over a converging Lloyd run, Hamerly must do far fewer distance
        // evaluations than N*K per iteration.
        let (x, mut c) = crate::lloyd::test_support::small_problem(42, 2000, 4, 10);
        let pool = ThreadPool::new(1);
        let mut engine = HamerlyEngine::new();
        let mut out = Assignment::new();
        let mut iters = 0;
        loop {
            let before = engine.distance_evals();
            engine.assign(&x, &c, &pool, &mut out);
            let evals = engine.distance_evals() - before;
            if iters > 2 {
                assert!(
                    evals < (x.n() * c.n()) as u64 / 2,
                    "iter {iters}: {evals} evals is not better than half of naive"
                );
            }
            let mut next = c.clone();
            update_step(&x, &out, &c, &mut next, &pool);
            if next.frob_dist(&c) < 1e-12 || iters > 60 {
                break;
            }
            c = next;
            iters += 1;
        }
        assert!(iters > 3, "problem should take a few iterations");
    }

    #[test]
    fn single_cluster_works() {
        let x = DataMatrix::from_rows(&[&[0.0, 0.0], &[1.0, 1.0]]);
        let c = DataMatrix::from_rows(&[&[0.5, 0.5]]);
        let pool = ThreadPool::new(1);
        let mut engine = HamerlyEngine::new();
        let mut out = Assignment::new();
        engine.assign(&x, &c, &pool, &mut out);
        assert_eq!(out, vec![0, 0]);
        // Second call with moved centroid still works.
        let c2 = DataMatrix::from_rows(&[&[5.0, 5.0]]);
        engine.assign(&x, &c2, &pool, &mut out);
        assert_eq!(out, vec![0, 0]);
    }

    #[test]
    fn reset_reinitializes() {
        let (x, c) = crate::lloyd::test_support::small_problem(7, 100, 3, 4);
        let pool = ThreadPool::new(1);
        let mut engine = HamerlyEngine::new();
        let mut out = Assignment::new();
        engine.assign(&x, &c, &pool, &mut out);
        engine.reset();
        engine.assign(&x, &c, &pool, &mut out);
        let expect = brute_force_assign(&x, &c);
        for i in 0..x.n() {
            let got_d = dist_sq(x.row(i), c.row(out[i] as usize));
            let exp_d = dist_sq(x.row(i), c.row(expect[i] as usize));
            assert!((got_d - exp_d).abs() < 1e-9);
        }
    }

    #[test]
    fn k_change_triggers_reinit() {
        let (x, c) = crate::lloyd::test_support::small_problem(8, 120, 3, 4);
        let pool = ThreadPool::new(1);
        let mut engine = HamerlyEngine::new();
        let mut out = Assignment::new();
        engine.assign(&x, &c, &pool, &mut out);
        // Different K: engine must not panic and must stay correct.
        let c2 = c.gather_rows(&[0, 1]);
        engine.assign(&x, &c2, &pool, &mut out);
        let expect = brute_force_assign(&x, &c2);
        for i in 0..x.n() {
            let got_d = dist_sq(x.row(i), c2.row(out[i] as usize));
            let exp_d = dist_sq(x.row(i), c2.row(expect[i] as usize));
            assert!((got_d - exp_d).abs() < 1e-9);
        }
    }
}
