//! Yinyang K-Means assignment (Ding et al., ICML 2015) — the "drop-in
//! faster assignment" the paper cites as compatible with its acceleration.
//!
//! Centroids are clustered into `G ≈ K/10` groups once at initialization;
//! each sample keeps one upper bound (distance to its assigned centroid)
//! and one lower bound **per group** (min distance to that group's
//! centroids). Group-level bounds survive centroid motion much better than
//! Hamerly's single global lower bound when only a few centroids move far —
//! which is exactly what an accepted Anderson jump looks like — and they
//! scale to the paper's K=100 / K=1000 columns.

use super::{Assignment, AssignmentEngine, SavedBounds};
use crate::data::DataMatrix;
use crate::linalg::{dist_sq, DistanceKernel};
use crate::par::{SyncSliceMut, ThreadPool};
use std::sync::atomic::{AtomicU64, Ordering};

/// Target number of centroids per group (Ding et al. use K/10).
const GROUP_SIZE: usize = 10;
/// Lloyd rounds used to cluster the centroids into groups.
const GROUPING_ROUNDS: usize = 5;

/// Yinyang group-bounds assignment engine.
#[derive(Debug, Default)]
pub struct YinyangEngine {
    /// Blocked norm-decomposed distance kernel (per-engine cache).
    kernel: DistanceKernel,
    /// Centroids seen at the previous call. The buffer survives `reset`
    /// (only `prev_valid` drops) so warm same-shape runs never reallocate.
    prev_c: Option<DataMatrix>,
    prev_valid: bool,
    /// Group id per centroid.
    group_of: Vec<usize>,
    n_groups: usize,
    /// Upper bound d(x_i, c_{a_i}).
    upper: Vec<f64>,
    /// Lower bounds per sample per group, row-major N×G: min distance to
    /// any centroid of the group **other than the assigned centroid**.
    lower: Vec<f64>,
    assign: Vec<u32>,
    /// Saved state for [`AssignmentEngine::rollback`] (shared
    /// store/checkpoint/rollback machinery — see [`SavedBounds`]).
    saved: SavedBounds,
    /// Per-call scratch (per-centroid and per-group motion, plus the
    /// group-Lloyd buffers of `build_groups`), persistent so warm calls
    /// stay allocation-free.
    moved: Vec<f64>,
    group_moved: Vec<f64>,
    group_centers: Vec<f64>,
    group_sums: Vec<f64>,
    group_counts: Vec<usize>,
    dist_evals: AtomicU64,
}

impl YinyangEngine {
    pub fn new() -> Self {
        Self::default()
    }

    /// Engine whose kernel stores samples at the given precision.
    pub fn with_precision(precision: crate::linalg::Precision) -> Self {
        Self { kernel: DistanceKernel::with_precision(precision), ..Self::default() }
    }

    /// Remember `c` as the previous centroid set, reusing the existing
    /// buffer when the shape matches (no allocation on warm calls).
    fn store_prev(&mut self, c: &DataMatrix) {
        match &mut self.prev_c {
            Some(p) if p.n() == c.n() && p.d() == c.d() => {
                p.as_mut_slice().copy_from_slice(c.as_slice());
            }
            _ => self.prev_c = Some(c.clone()),
        }
        self.prev_valid = true;
    }

    /// Live bound state (bounds + assignment) for the checkpoint/rollback
    /// property tests.
    #[cfg(test)]
    pub(crate) fn bound_state(&self) -> (Vec<f64>, Vec<f64>, Vec<u32>) {
        (self.upper.clone(), self.lower.clone(), self.assign.clone())
    }

    /// Cluster the centroids into groups with a few Lloyd rounds (groups
    /// are fixed afterwards, as in the original algorithm). All buffers
    /// are persistent fields, so regrouping at the start of a warm run
    /// does not touch the allocator.
    fn build_groups(&mut self, c: &DataMatrix) {
        let k = c.n();
        let d = c.d();
        let g = k.div_ceil(GROUP_SIZE).max(1);
        self.n_groups = g;
        self.group_of.clear();
        self.group_of.resize(k, 0);
        if g == 1 {
            return;
        }
        // Seed group centers with a strided pick, then Lloyd on centroids.
        self.group_centers.clear();
        self.group_centers.resize(g * d, 0.0);
        for gi in 0..g {
            let src = c.row(gi * k / g);
            self.group_centers[gi * d..(gi + 1) * d].copy_from_slice(src);
        }
        for _ in 0..GROUPING_ROUNDS {
            for j in 0..k {
                let (mut best, mut best_d) = (0usize, f64::INFINITY);
                for gi in 0..g {
                    let ctr = &self.group_centers[gi * d..(gi + 1) * d];
                    let dist = dist_sq(c.row(j), ctr);
                    if dist < best_d {
                        best_d = dist;
                        best = gi;
                    }
                }
                self.group_of[j] = best;
            }
            // Means (empty groups keep their center).
            self.group_sums.clear();
            self.group_sums.resize(g * d, 0.0);
            self.group_counts.clear();
            self.group_counts.resize(g, 0);
            for j in 0..k {
                let gi = self.group_of[j];
                self.group_counts[gi] += 1;
                let dst = &mut self.group_sums[gi * d..(gi + 1) * d];
                for (s, &v) in dst.iter_mut().zip(c.row(j)) {
                    *s += v;
                }
            }
            for gi in 0..g {
                if self.group_counts[gi] > 0 {
                    let inv = 1.0 / self.group_counts[gi] as f64;
                    let sums = &self.group_sums[gi * d..(gi + 1) * d];
                    let dst = &mut self.group_centers[gi * d..(gi + 1) * d];
                    for (ctr, &s) in dst.iter_mut().zip(sums) {
                        *ctr = s * inv;
                    }
                }
            }
        }
    }

    /// Full O(NK) pass establishing assignment + bounds.
    fn initialize(&mut self, x: &DataMatrix, c: &DataMatrix, pool: &ThreadPool) {
        let (n, k, g) = (x.n(), c.n(), self.n_groups);
        self.upper.resize(n, 0.0);
        self.lower.resize(n * g, 0.0);
        self.assign.resize(n, 0);
        let upper = SyncSliceMut::new(&mut self.upper);
        let lower = SyncSliceMut::new(&mut self.lower);
        let assign = SyncSliceMut::new(&mut self.assign);
        let group_of = &self.group_of;
        let kernel = &self.kernel;
        let evals = AtomicU64::new(0);
        pool.parallel_for(n, 128, |range| {
            let mut local = 0u64;
            let mut glb = vec![f64::INFINITY; g];
            // The init needs every distance: dense blocked kernel rows.
            let mut dists = vec![0.0f64; k];
            for i in range {
                kernel.dists_row(x, c, i, &mut dists);
                glb.iter_mut().for_each(|v| *v = f64::INFINITY);
                let (mut d1, mut best) = (f64::INFINITY, 0usize);
                for (j, &dsq) in dists.iter().enumerate() {
                    let dj = dsq.sqrt();
                    let gj = group_of[j];
                    if dj < d1 {
                        // The old best drops into its group's lower bound.
                        if d1 < glb[group_of[best]] {
                            glb[group_of[best]] = d1;
                        }
                        d1 = dj;
                        best = j;
                    } else if dj < glb[gj] {
                        glb[gj] = dj;
                    }
                }
                local += k as u64;
                *upper.at(i) = d1;
                *assign.at(i) = best as u32;
                for (gi, &v) in glb.iter().enumerate() {
                    *lower.at(i * g + gi) = v;
                }
            }
            evals.fetch_add(local, Ordering::Relaxed);
        });
        self.dist_evals.fetch_add(evals.load(Ordering::Relaxed), Ordering::Relaxed);
    }
}

impl AssignmentEngine for YinyangEngine {
    fn name(&self) -> &'static str {
        "yinyang"
    }

    fn assign(&mut self, x: &DataMatrix, c: &DataMatrix, pool: &ThreadPool, out: &mut Assignment) {
        let (n, k, d) = (x.n(), c.n(), x.d());
        self.kernel.prepare(x, c, pool);
        let stale = !self.prev_valid
            || match &self.prev_c {
                Some(prev) => prev.n() != k || prev.d() != d || self.assign.len() != n,
                None => true,
            };
        if stale {
            self.build_groups(c);
            self.initialize(x, c, pool);
            self.store_prev(c);
            out.clear();
            out.extend_from_slice(&self.assign);
            return;
        }
        let g = self.n_groups;
        // Per-centroid and per-group max movement (persistent scratch:
        // warm calls allocate nothing here).
        self.moved.clear();
        self.moved.resize(k, 0.0);
        self.group_moved.clear();
        self.group_moved.resize(g, 0.0);
        {
            let prev = self.prev_c.as_ref().unwrap();
            for j in 0..k {
                let m = dist_sq(prev.row(j), c.row(j)).sqrt();
                self.moved[j] = m;
                let gj = self.group_of[j];
                if m > self.group_moved[gj] {
                    self.group_moved[gj] = m;
                }
            }
        }
        let moved: &[f64] = &self.moved;
        let group_moved: &[f64] = &self.group_moved;
        let upper = SyncSliceMut::new(&mut self.upper);
        let lower = SyncSliceMut::new(&mut self.lower);
        let assign = SyncSliceMut::new(&mut self.assign);
        let group_of = &self.group_of;
        let kernel = &self.kernel;
        let evals = AtomicU64::new(0);
        pool.parallel_for(n, 128, |range| {
            let mut local = 0u64;
            // Flat scan buffers, shared by every sample this lane
            // processes in this range (hoisted out of the per-sample loop
            // so warm assignment sweeps stay allocation-light).
            let mut scanned_groups: Vec<usize> = Vec::new();
            let mut group_start: Vec<usize> = Vec::new();
            let mut scan_j: Vec<u32> = Vec::new();
            let mut scan_d: Vec<f64> = Vec::new();
            for i in range {
                let a = *assign.at(i) as usize;
                let mut u = *upper.at(i) + moved[a];
                // Drift group lower bounds; find the global minimum.
                let mut glb_min = f64::INFINITY;
                for gi in 0..g {
                    let lb = lower.at(i * g + gi);
                    *lb = (*lb - group_moved[gi]).max(0.0);
                    if *lb < glb_min {
                        glb_min = *lb;
                    }
                }
                if u <= glb_min {
                    *upper.at(i) = u;
                    continue;
                }
                // Tighten the upper bound once.
                u = kernel.dist_sq(x, c, i, a).sqrt();
                local += 1;
                if u <= glb_min {
                    *upper.at(i) = u;
                    continue;
                }
                // Scan only the groups whose bound fails the test. Cache the
                // distances so the exact per-group lower bounds (min over
                // members excluding the final assigned centroid) come free.
                let mut best = a;
                let mut d1 = u;
                scanned_groups.clear();
                group_start.clear();
                scan_j.clear();
                scan_d.clear();
                for gi in 0..g {
                    if *lower.at(i * g + gi) >= d1 {
                        continue; // group cannot contain a closer centroid
                    }
                    scanned_groups.push(gi);
                    group_start.push(scan_j.len());
                    for j in 0..k {
                        if group_of[j] != gi || j == a {
                            continue;
                        }
                        let dj = kernel.dist_sq(x, c, i, j).sqrt();
                        local += 1;
                        scan_j.push(j as u32);
                        scan_d.push(dj);
                        if dj < d1 {
                            d1 = dj;
                            best = j;
                        }
                    }
                }
                group_start.push(scan_j.len());
                // Exact lower bounds for scanned groups. The previously
                // assigned centroid `a` (distance u) belongs to some group
                // and is no longer the assignment if best != a.
                for (idx, &gi) in scanned_groups.iter().enumerate() {
                    let (lo, hi) = (group_start[idx], group_start[idx + 1]);
                    let mut exact = f64::INFINITY;
                    for t in lo..hi {
                        if scan_j[t] as usize != best && scan_d[t] < exact {
                            exact = scan_d[t];
                        }
                    }
                    if group_of[a] == gi && a != best && u < exact {
                        exact = u;
                    }
                    *lower.at(i * g + gi) = exact;
                }
                // If `a` moved groups... it cannot — but if `a`'s group was
                // NOT scanned and the assignment changed, its drifted bound
                // may now exceed the true min (which includes `a`): shrink.
                if best != a {
                    let ga = group_of[a];
                    let lb = lower.at(i * g + ga);
                    if u < *lb {
                        *lb = u;
                    }
                }
                *upper.at(i) = d1;
                *assign.at(i) = best as u32;
            }
            evals.fetch_add(local, Ordering::Relaxed);
        });
        self.dist_evals.fetch_add(evals.load(Ordering::Relaxed), Ordering::Relaxed);
        self.store_prev(c);
        out.clear();
        out.extend_from_slice(&self.assign);
    }

    fn reset(&mut self) {
        // Keep the buffers (capacity) but mark the bound state unusable.
        // The kernel's sample-norm cache stays: it is keyed on the data's
        // generation stamp, so same-data reruns skip the norm pass.
        self.prev_valid = false;
        self.upper.clear();
        self.lower.clear();
        self.assign.clear();
        self.group_of.clear();
        self.saved.invalidate();
    }

    fn distance_evals(&self) -> u64 {
        self.dist_evals.load(Ordering::Relaxed)
    }

    fn checkpoint(&mut self) {
        if !self.prev_valid {
            return;
        }
        let Some(prev) = &self.prev_c else { return };
        self.saved.checkpoint(prev, &self.upper, &self.lower, &self.assign);
    }

    fn rollback(&mut self) -> bool {
        self.saved.rollback_into(
            &mut self.prev_c,
            &mut self.upper,
            &mut self.lower,
            &mut self.assign,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lloyd::test_support::engine_matches_brute_force;
    use crate::lloyd::update_step;

    #[test]
    fn matches_brute_force_over_rounds() {
        engine_matches_brute_force(&mut YinyangEngine::new());
    }

    #[test]
    fn checkpoint_rollback_reproduces_fresh_engine_state() {
        crate::lloyd::test_support::checkpoint_rollback_matches_fresh(
            YinyangEngine::new(),
            YinyangEngine::new(),
            YinyangEngine::bound_state,
        );
    }

    #[test]
    fn matches_brute_force_large_k() {
        // The regime yinyang exists for: K larger than GROUP_SIZE so the
        // engine actually maintains several groups.
        let pool = ThreadPool::new(1);
        let (x, mut c) = crate::lloyd::test_support::small_problem(77, 800, 4, 40);
        let mut engine = YinyangEngine::new();
        let mut out = Assignment::new();
        for round in 0..5 {
            engine.assign(&x, &c, &pool, &mut out);
            let expect = crate::lloyd::brute_force_assign(&x, &c);
            for i in 0..x.n() {
                let got = dist_sq(x.row(i), c.row(out[i] as usize));
                let exp = dist_sq(x.row(i), c.row(expect[i] as usize));
                assert!((got - exp).abs() < 1e-9, "round {round} sample {i}");
            }
            let mut next = c.clone();
            update_step(&x, &out, &c, &mut next, &pool);
            c = next;
        }
        assert!(engine.n_groups >= 2, "expected multiple groups for K=40");
    }

    #[test]
    fn saves_evals_at_large_k() {
        let pool = ThreadPool::new(1);
        let (x, mut c) = crate::lloyd::test_support::small_problem(78, 3000, 6, 50);
        let mut engine = YinyangEngine::new();
        let mut out = Assignment::new();
        let mut total_after_init = 0u64;
        for iter in 0..12 {
            let before = engine.distance_evals();
            engine.assign(&x, &c, &pool, &mut out);
            let evals = engine.distance_evals() - before;
            if iter > 2 {
                total_after_init += evals;
                assert!(
                    evals < (x.n() * c.n()) as u64 / 2,
                    "iter {iter}: {evals} evals (naive would be {})",
                    x.n() * c.n()
                );
            }
            let mut next = c.clone();
            update_step(&x, &out, &c, &mut next, &pool);
            if next.frob_dist(&c) < 1e-12 {
                break;
            }
            c = next;
        }
        assert!(total_after_init > 0);
    }

    #[test]
    fn rollback_roundtrip() {
        let pool = ThreadPool::new(1);
        let (x, c) = crate::lloyd::test_support::small_problem(79, 300, 3, 25);
        let mut engine = YinyangEngine::new();
        let mut out = Assignment::new();
        engine.assign(&x, &c, &pool, &mut out);
        engine.checkpoint();
        let saved_assign = engine.assign.clone();
        // Jump far away and back.
        let mut c_jump = c.clone();
        for j in 0..c_jump.n() {
            c_jump[(j, 0)] += 3.0;
        }
        engine.assign(&x, &c_jump, &pool, &mut out);
        assert!(engine.rollback());
        assert_eq!(engine.assign, saved_assign);
        // Next assign from restored state stays correct.
        engine.assign(&x, &c, &pool, &mut out);
        let expect = crate::lloyd::brute_force_assign(&x, &c);
        for i in 0..x.n() {
            let got = dist_sq(x.row(i), c.row(out[i] as usize));
            let exp = dist_sq(x.row(i), c.row(expect[i] as usize));
            assert!((got - exp).abs() < 1e-9);
        }
    }
}
