//! Elkan's assignment algorithm (Elkan 2003): per-sample upper bound plus a
//! full `N×K` matrix of lower bounds, pruned with the triangle inequality
//! over centroid–centroid distances. More memory than Hamerly, fewer
//! distance evaluations for large `K` — provided as the paper's suggested
//! "even faster assignment" extension point.

use super::{Assignment, AssignmentEngine};
use crate::data::DataMatrix;
use crate::linalg::{dist_sq, DistanceKernel};
use crate::par::{SyncSliceMut, ThreadPool};
use std::sync::atomic::{AtomicU64, Ordering};

/// Elkan triangle-inequality assignment engine.
#[derive(Debug, Default)]
pub struct ElkanEngine {
    /// Blocked norm-decomposed distance kernel (per-engine cache).
    kernel: DistanceKernel,
    prev_c: Option<DataMatrix>,
    /// Upper bound d(x_i, c_{a_i}).
    upper: Vec<f64>,
    /// Lower bounds d(x_i, c_j), row-major N×K.
    lower: Vec<f64>,
    assign: Vec<u32>,
    /// Saved state for rollback after rejected accelerated jumps.
    saved: Option<(DataMatrix, Vec<f64>, Vec<f64>, Vec<u32>)>,
    dist_evals: AtomicU64,
}

impl ElkanEngine {
    pub fn new() -> Self {
        Self::default()
    }

    /// Engine whose kernel stores samples at the given precision.
    pub fn with_precision(precision: crate::linalg::Precision) -> Self {
        Self { kernel: DistanceKernel::with_precision(precision), ..Self::default() }
    }

    fn initialize(&mut self, x: &DataMatrix, c: &DataMatrix, pool: &ThreadPool) {
        let (n, k) = (x.n(), c.n());
        self.upper.resize(n, 0.0);
        self.lower.resize(n * k, 0.0);
        self.assign.resize(n, 0);
        let upper = SyncSliceMut::new(&mut self.upper);
        let lower = SyncSliceMut::new(&mut self.lower);
        let assign = SyncSliceMut::new(&mut self.assign);
        let kernel = &self.kernel;
        let evals = AtomicU64::new(0);
        pool.parallel_for(n, 128, |range| {
            let mut local = 0u64;
            // Elkan's init needs every distance anyway: one dense blocked
            // kernel row per sample fills the whole lower-bound row.
            let mut dists = vec![0.0f64; k];
            for i in range {
                kernel.dists_row(x, c, i, &mut dists);
                let (mut d1, mut best) = (f64::INFINITY, 0u32);
                for (j, &dsq) in dists.iter().enumerate() {
                    let dj = dsq.sqrt();
                    *lower.at(i * k + j) = dj;
                    if dj < d1 {
                        d1 = dj;
                        best = j as u32;
                    }
                }
                local += k as u64;
                *upper.at(i) = d1;
                *assign.at(i) = best;
            }
            evals.fetch_add(local, Ordering::Relaxed);
        });
        self.dist_evals.fetch_add(evals.load(Ordering::Relaxed), Ordering::Relaxed);
    }
}

impl AssignmentEngine for ElkanEngine {
    fn name(&self) -> &'static str {
        "elkan"
    }

    fn assign(&mut self, x: &DataMatrix, c: &DataMatrix, pool: &ThreadPool, out: &mut Assignment) {
        let (n, k, d) = (x.n(), c.n(), x.d());
        self.kernel.prepare(x, c, pool);
        let stale = match &self.prev_c {
            Some(prev) => prev.n() != k || prev.d() != d || self.assign.len() != n,
            None => true,
        };
        if stale {
            self.initialize(x, c, pool);
            self.prev_c = Some(c.clone());
            out.clear();
            out.extend_from_slice(&self.assign);
            return;
        }
        let prev = self.prev_c.as_ref().unwrap();
        // Centroid motion drifts all bounds.
        let mut moved = vec![0.0f64; k];
        for j in 0..k {
            moved[j] = dist_sq(prev.row(j), c.row(j)).sqrt();
        }
        // Centroid–centroid half-distances s[j] = ½ min_{j'≠j} d(c_j, c_j')
        // and the full pairwise matrix for the per-centroid prune.
        let mut cc = vec![0.0f64; k * k];
        let mut s = vec![f64::INFINITY; k];
        for j in 0..k {
            for j2 in (j + 1)..k {
                let djj = dist_sq(c.row(j), c.row(j2)).sqrt();
                cc[j * k + j2] = djj;
                cc[j2 * k + j] = djj;
                if djj < s[j] {
                    s[j] = djj;
                }
                if djj < s[j2] {
                    s[j2] = djj;
                }
            }
        }
        for v in s.iter_mut() {
            *v *= 0.5;
        }

        let upper = SyncSliceMut::new(&mut self.upper);
        let lower = SyncSliceMut::new(&mut self.lower);
        let assign = SyncSliceMut::new(&mut self.assign);
        let kernel = &self.kernel;
        let evals = AtomicU64::new(0);
        pool.parallel_for(n, 128, |range| {
            let mut local = 0u64;
            for i in range {
                // Drift bounds.
                let a0 = *assign.at(i) as usize;
                let mut u = *upper.at(i) + moved[a0];
                for j in 0..k {
                    let lb = lower.at(i * k + j);
                    *lb = (*lb - moved[j]).max(0.0);
                }
                let mut a = a0;
                if u <= s[a] {
                    *upper.at(i) = u;
                    continue; // global prune: nothing can be closer
                }
                let mut u_tight = false;
                for j in 0..k {
                    if j == a {
                        continue;
                    }
                    let lb = *lower.at(i * k + j);
                    // Candidate j survives both the lower-bound and the
                    // inter-centroid prune?
                    if u > lb && u > 0.5 * cc[a * k + j] {
                        if !u_tight {
                            u = kernel.dist_sq(x, c, i, a).sqrt();
                            local += 1;
                            *lower.at(i * k + a) = u;
                            u_tight = true;
                            if u <= lb && u <= 0.5 * cc[a * k + j] {
                                continue;
                            }
                        }
                        let dj = kernel.dist_sq(x, c, i, j).sqrt();
                        local += 1;
                        *lower.at(i * k + j) = dj;
                        if dj < u {
                            u = dj;
                            a = j;
                        }
                    }
                }
                *upper.at(i) = u;
                *assign.at(i) = a as u32;
            }
            evals.fetch_add(local, Ordering::Relaxed);
        });
        self.dist_evals.fetch_add(evals.load(Ordering::Relaxed), Ordering::Relaxed);
        self.prev_c = Some(c.clone());
        out.clear();
        out.extend_from_slice(&self.assign);
    }

    fn reset(&mut self) {
        self.kernel.invalidate();
        self.prev_c = None;
        self.upper.clear();
        self.lower.clear();
        self.assign.clear();
        self.saved = None;
    }

    fn distance_evals(&self) -> u64 {
        self.dist_evals.load(Ordering::Relaxed)
    }

    fn checkpoint(&mut self) {
        if let Some(prev) = &self.prev_c {
            self.saved =
                Some((prev.clone(), self.upper.clone(), self.lower.clone(), self.assign.clone()));
        }
    }

    fn rollback(&mut self) -> bool {
        match self.saved.take() {
            Some((prev, upper, lower, assign)) => {
                self.prev_c = Some(prev);
                self.upper = upper;
                self.lower = lower;
                self.assign = assign;
                true
            }
            None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lloyd::test_support::engine_matches_brute_force;
    use crate::lloyd::update_step;

    #[test]
    fn matches_brute_force_over_rounds() {
        engine_matches_brute_force(&mut ElkanEngine::new());
    }

    #[test]
    fn fewer_evals_than_naive_on_converging_run() {
        let (x, mut c) = crate::lloyd::test_support::small_problem(43, 1500, 6, 12);
        let pool = ThreadPool::new(1);
        let mut engine = ElkanEngine::new();
        let mut out = Assignment::new();
        for iter in 0..25 {
            let before = engine.distance_evals();
            engine.assign(&x, &c, &pool, &mut out);
            let evals = engine.distance_evals() - before;
            if iter > 2 {
                assert!(
                    evals < (x.n() * c.n()) as u64 / 2,
                    "iter {iter}: {evals} evals"
                );
            }
            let mut next = c.clone();
            update_step(&x, &out, &c, &mut next, &pool);
            if next.frob_dist(&c) < 1e-12 {
                break;
            }
            c = next;
        }
    }

    #[test]
    fn handles_identical_centroids() {
        // Duplicate centroids give zero inter-centroid distance — bounds
        // must not mis-prune.
        let x = DataMatrix::from_rows(&[&[0.0], &[1.0], &[3.0]]);
        let c = DataMatrix::from_rows(&[&[1.0], &[1.0], &[3.0]]);
        let pool = ThreadPool::new(1);
        let mut engine = ElkanEngine::new();
        let mut out = Assignment::new();
        engine.assign(&x, &c, &pool, &mut out);
        // Samples 0,1 near centroid 0/1 (tie), sample 2 at centroid 2.
        assert_eq!(out[2], 2);
        let d0 = dist_sq(x.row(0), c.row(out[0] as usize));
        assert!((d0 - 1.0).abs() < 1e-12);
    }
}
