//! Elkan's assignment algorithm (Elkan 2003): per-sample upper bound plus a
//! full `N×K` matrix of lower bounds, pruned with the triangle inequality
//! over centroid–centroid distances. More memory than Hamerly, fewer
//! distance evaluations for large `K` — provided as the paper's suggested
//! "even faster assignment" extension point.

use super::{Assignment, AssignmentEngine, SavedBounds};
use crate::data::DataMatrix;
use crate::linalg::{dist_sq, DistanceKernel};
use crate::par::{SyncSliceMut, ThreadPool};
use std::sync::atomic::{AtomicU64, Ordering};

/// Elkan triangle-inequality assignment engine.
#[derive(Debug, Default)]
pub struct ElkanEngine {
    /// Blocked norm-decomposed distance kernel (per-engine cache).
    kernel: DistanceKernel,
    /// Centroids seen at the previous call. The buffer survives `reset`
    /// (only `prev_valid` drops) so warm same-shape runs never reallocate.
    prev_c: Option<DataMatrix>,
    prev_valid: bool,
    /// Upper bound d(x_i, c_{a_i}).
    upper: Vec<f64>,
    /// Lower bounds d(x_i, c_j), row-major N×K.
    lower: Vec<f64>,
    assign: Vec<u32>,
    /// Saved state for [`AssignmentEngine::rollback`] after rejected
    /// accelerated jumps (shared store/checkpoint/rollback machinery —
    /// see [`SavedBounds`]).
    saved: SavedBounds,
    /// Per-call scratch (per-centroid motion, the K×K centroid-centroid
    /// distances and the half nearest-centroid distances), persistent so
    /// warm calls stay allocation-free.
    moved: Vec<f64>,
    cc: Vec<f64>,
    s_half: Vec<f64>,
    dist_evals: AtomicU64,
}

impl ElkanEngine {
    pub fn new() -> Self {
        Self::default()
    }

    /// Engine whose kernel stores samples at the given precision.
    pub fn with_precision(precision: crate::linalg::Precision) -> Self {
        Self { kernel: DistanceKernel::with_precision(precision), ..Self::default() }
    }

    /// Remember `c` as the previous centroid set, reusing the existing
    /// buffer when the shape matches (no allocation on warm calls).
    fn store_prev(&mut self, c: &DataMatrix) {
        match &mut self.prev_c {
            Some(p) if p.n() == c.n() && p.d() == c.d() => {
                p.as_mut_slice().copy_from_slice(c.as_slice());
            }
            _ => self.prev_c = Some(c.clone()),
        }
        self.prev_valid = true;
    }

    /// Live bound state (bounds + assignment) for the checkpoint/rollback
    /// property tests.
    #[cfg(test)]
    pub(crate) fn bound_state(&self) -> (Vec<f64>, Vec<f64>, Vec<u32>) {
        (self.upper.clone(), self.lower.clone(), self.assign.clone())
    }

    fn initialize(&mut self, x: &DataMatrix, c: &DataMatrix, pool: &ThreadPool) {
        let (n, k) = (x.n(), c.n());
        self.upper.resize(n, 0.0);
        self.lower.resize(n * k, 0.0);
        self.assign.resize(n, 0);
        let upper = SyncSliceMut::new(&mut self.upper);
        let lower = SyncSliceMut::new(&mut self.lower);
        let assign = SyncSliceMut::new(&mut self.assign);
        let kernel = &self.kernel;
        let evals = AtomicU64::new(0);
        pool.parallel_for(n, 128, |range| {
            let mut local = 0u64;
            // Elkan's init needs every distance anyway: one dense blocked
            // kernel row per sample fills the whole lower-bound row.
            let mut dists = vec![0.0f64; k];
            for i in range {
                kernel.dists_row(x, c, i, &mut dists);
                let (mut d1, mut best) = (f64::INFINITY, 0u32);
                for (j, &dsq) in dists.iter().enumerate() {
                    let dj = dsq.sqrt();
                    *lower.at(i * k + j) = dj;
                    if dj < d1 {
                        d1 = dj;
                        best = j as u32;
                    }
                }
                local += k as u64;
                *upper.at(i) = d1;
                *assign.at(i) = best;
            }
            evals.fetch_add(local, Ordering::Relaxed);
        });
        self.dist_evals.fetch_add(evals.load(Ordering::Relaxed), Ordering::Relaxed);
    }
}

impl AssignmentEngine for ElkanEngine {
    fn name(&self) -> &'static str {
        "elkan"
    }

    fn assign(&mut self, x: &DataMatrix, c: &DataMatrix, pool: &ThreadPool, out: &mut Assignment) {
        let (n, k, d) = (x.n(), c.n(), x.d());
        self.kernel.prepare(x, c, pool);
        let stale = !self.prev_valid
            || match &self.prev_c {
                Some(prev) => prev.n() != k || prev.d() != d || self.assign.len() != n,
                None => true,
            };
        if stale {
            self.initialize(x, c, pool);
            self.store_prev(c);
            out.clear();
            out.extend_from_slice(&self.assign);
            return;
        }
        // Centroid motion drifts all bounds (persistent scratch: warm
        // calls allocate nothing here).
        self.moved.clear();
        self.moved.resize(k, 0.0);
        {
            let prev = self.prev_c.as_ref().unwrap();
            for j in 0..k {
                self.moved[j] = dist_sq(prev.row(j), c.row(j)).sqrt();
            }
        }
        // Centroid–centroid half-distances s[j] = ½ min_{j'≠j} d(c_j, c_j')
        // and the full pairwise matrix for the per-centroid prune.
        self.cc.clear();
        self.cc.resize(k * k, 0.0);
        self.s_half.clear();
        self.s_half.resize(k, f64::INFINITY);
        for j in 0..k {
            for j2 in (j + 1)..k {
                let djj = dist_sq(c.row(j), c.row(j2)).sqrt();
                self.cc[j * k + j2] = djj;
                self.cc[j2 * k + j] = djj;
                if djj < self.s_half[j] {
                    self.s_half[j] = djj;
                }
                if djj < self.s_half[j2] {
                    self.s_half[j2] = djj;
                }
            }
        }
        for v in self.s_half.iter_mut() {
            *v *= 0.5;
        }

        let moved: &[f64] = &self.moved;
        let cc: &[f64] = &self.cc;
        let s: &[f64] = &self.s_half;
        let upper = SyncSliceMut::new(&mut self.upper);
        let lower = SyncSliceMut::new(&mut self.lower);
        let assign = SyncSliceMut::new(&mut self.assign);
        let kernel = &self.kernel;
        let evals = AtomicU64::new(0);
        pool.parallel_for(n, 128, |range| {
            let mut local = 0u64;
            for i in range {
                // Drift bounds.
                let a0 = *assign.at(i) as usize;
                let mut u = *upper.at(i) + moved[a0];
                for j in 0..k {
                    let lb = lower.at(i * k + j);
                    *lb = (*lb - moved[j]).max(0.0);
                }
                let mut a = a0;
                if u <= s[a] {
                    *upper.at(i) = u;
                    continue; // global prune: nothing can be closer
                }
                let mut u_tight = false;
                for j in 0..k {
                    if j == a {
                        continue;
                    }
                    let lb = *lower.at(i * k + j);
                    // Candidate j survives both the lower-bound and the
                    // inter-centroid prune?
                    if u > lb && u > 0.5 * cc[a * k + j] {
                        if !u_tight {
                            u = kernel.dist_sq(x, c, i, a).sqrt();
                            local += 1;
                            *lower.at(i * k + a) = u;
                            u_tight = true;
                            if u <= lb && u <= 0.5 * cc[a * k + j] {
                                continue;
                            }
                        }
                        let dj = kernel.dist_sq(x, c, i, j).sqrt();
                        local += 1;
                        *lower.at(i * k + j) = dj;
                        if dj < u {
                            u = dj;
                            a = j;
                        }
                    }
                }
                *upper.at(i) = u;
                *assign.at(i) = a as u32;
            }
            evals.fetch_add(local, Ordering::Relaxed);
        });
        self.dist_evals.fetch_add(evals.load(Ordering::Relaxed), Ordering::Relaxed);
        self.store_prev(c);
        out.clear();
        out.extend_from_slice(&self.assign);
    }

    fn reset(&mut self) {
        // Keep the buffers (capacity) but mark the bound state unusable.
        // The kernel's sample-norm cache stays: it is keyed on the data's
        // generation stamp, so same-data reruns skip the norm pass.
        self.prev_valid = false;
        self.upper.clear();
        self.lower.clear();
        self.assign.clear();
        self.saved.invalidate();
    }

    fn distance_evals(&self) -> u64 {
        self.dist_evals.load(Ordering::Relaxed)
    }

    fn checkpoint(&mut self) {
        if !self.prev_valid {
            return;
        }
        let Some(prev) = &self.prev_c else { return };
        self.saved.checkpoint(prev, &self.upper, &self.lower, &self.assign);
    }

    fn rollback(&mut self) -> bool {
        self.saved.rollback_into(
            &mut self.prev_c,
            &mut self.upper,
            &mut self.lower,
            &mut self.assign,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lloyd::test_support::engine_matches_brute_force;
    use crate::lloyd::update_step;

    #[test]
    fn matches_brute_force_over_rounds() {
        engine_matches_brute_force(&mut ElkanEngine::new());
    }

    #[test]
    fn checkpoint_rollback_reproduces_fresh_engine_state() {
        crate::lloyd::test_support::checkpoint_rollback_matches_fresh(
            ElkanEngine::new(),
            ElkanEngine::new(),
            ElkanEngine::bound_state,
        );
    }

    #[test]
    fn fewer_evals_than_naive_on_converging_run() {
        let (x, mut c) = crate::lloyd::test_support::small_problem(43, 1500, 6, 12);
        let pool = ThreadPool::new(1);
        let mut engine = ElkanEngine::new();
        let mut out = Assignment::new();
        for iter in 0..25 {
            let before = engine.distance_evals();
            engine.assign(&x, &c, &pool, &mut out);
            let evals = engine.distance_evals() - before;
            if iter > 2 {
                assert!(
                    evals < (x.n() * c.n()) as u64 / 2,
                    "iter {iter}: {evals} evals"
                );
            }
            let mut next = c.clone();
            update_step(&x, &out, &c, &mut next, &pool);
            if next.frob_dist(&c) < 1e-12 {
                break;
            }
            c = next;
        }
    }

    #[test]
    fn handles_identical_centroids() {
        // Duplicate centroids give zero inter-centroid distance — bounds
        // must not mis-prune.
        let x = DataMatrix::from_rows(&[&[0.0], &[1.0], &[3.0]]);
        let c = DataMatrix::from_rows(&[&[1.0], &[1.0], &[3.0]]);
        let pool = ThreadPool::new(1);
        let mut engine = ElkanEngine::new();
        let mut out = Assignment::new();
        engine.assign(&x, &c, &pool, &mut out);
        // Samples 0,1 near centroid 0/1 (tie), sample 2 at centroid 2.
        assert_eq!(out[2], 2);
        let d0 = dist_sq(x.row(0), c.row(out[0] as usize));
        assert!((d0 - 1.0).abs() < 1e-12);
    }
}
