//! Synthetic dataset generators.
//!
//! These generators stand in for the paper's UCI datasets (see DESIGN.md §3
//! for the substitution argument) and provide the workloads of the examples
//! and benches. All of them are deterministic from the supplied RNG.

use super::DataMatrix;
use crate::rng::{shuffle, Pcg32, Rng};

/// Isotropic Gaussian mixture ("blobs"): `clusters` centers uniform in
/// `[-spread, spread]^d`, each sample drawn from one center with the given
/// `noise` standard deviation plus a `background` fraction of uniform noise.
pub fn gaussian_blobs<R: Rng>(
    rng: &mut R,
    n: usize,
    d: usize,
    clusters: usize,
    spread: f64,
    noise: f64,
) -> DataMatrix {
    gaussian_blobs_ex(rng, n, d, clusters, spread, noise, 0.0, 1.0)
}

/// Full-control blob generator.
///
/// * `spread` — half-width of the box the cluster centers are drawn from.
/// * `noise` — per-cluster standard deviation.
/// * `background` — fraction of samples replaced by uniform box noise
///   (models the unstructured mass real UCI tables carry).
/// * `anisotropy` — per-dimension sigma is scaled by a factor drawn from
///   `[1/anisotropy, anisotropy]`; `1.0` keeps clusters isotropic.
pub fn gaussian_blobs_ex<R: Rng>(
    rng: &mut R,
    n: usize,
    d: usize,
    clusters: usize,
    spread: f64,
    noise: f64,
    background: f64,
    anisotropy: f64,
) -> DataMatrix {
    assert!(clusters >= 1 && d >= 1);
    let mut centers = DataMatrix::zeros(clusters, d);
    for c in 0..clusters {
        for j in 0..d {
            centers[(c, j)] = rng.next_range(-spread, spread);
        }
    }
    // Per-cluster, per-dimension sigmas.
    let mut sigmas = vec![0.0; clusters * d];
    for c in 0..clusters {
        for j in 0..d {
            let factor = if anisotropy > 1.0 {
                let lo = 1.0 / anisotropy;
                rng.next_range(lo, anisotropy)
            } else {
                1.0
            };
            sigmas[c * d + j] = noise * factor;
        }
    }
    // Random (but non-degenerate) cluster weights.
    let mut weights = vec![0.0; clusters];
    for w in weights.iter_mut() {
        *w = 0.2 + rng.next_f64();
    }
    let mut x = DataMatrix::zeros(n, d);
    for i in 0..n {
        if background > 0.0 && rng.next_f64() < background {
            for j in 0..d {
                x[(i, j)] = rng.next_range(-1.5 * spread, 1.5 * spread);
            }
            continue;
        }
        let c = crate::rng::choose_weighted(&weights, rng);
        for j in 0..d {
            x[(i, j)] = centers[(c, j)] + sigmas[c * d + j] * rng.next_gaussian();
        }
    }
    x
}

/// The Birch1-style synthetic set (Zhang et al. 1997, as used by the paper):
/// a regular `side × side` grid of Gaussian clusters in 2-D. The paper's
/// instance is `side = 10`, `n = 100 000`.
pub fn birch_grid<R: Rng>(rng: &mut R, n: usize, side: usize, sigma: f64) -> DataMatrix {
    assert!(side >= 1);
    let clusters = side * side;
    let mut x = DataMatrix::zeros(n, 2);
    for i in 0..n {
        let c = rng.next_below(clusters);
        let (gx, gy) = ((c % side) as f64, (c / side) as f64);
        x[(i, 0)] = gx + sigma * rng.next_gaussian();
        x[(i, 1)] = gy + sigma * rng.next_gaussian();
    }
    x
}

/// Uniform box noise — the worst case for AA (no cluster structure).
pub fn uniform_box<R: Rng>(rng: &mut R, n: usize, d: usize, half_width: f64) -> DataMatrix {
    let mut x = DataMatrix::zeros(n, d);
    for i in 0..n {
        for j in 0..d {
            x[(i, j)] = rng.next_range(-half_width, half_width);
        }
    }
    x
}

/// Low-dimensional manifold embedded in `d` dimensions: samples on a noisy
/// 1-D curve. Exercises the "samples not separated into clusters" regime the
/// paper identifies as the slow-convergence case for Lloyd's.
pub fn noisy_curve<R: Rng>(rng: &mut R, n: usize, d: usize, noise: f64) -> DataMatrix {
    assert!(d >= 2);
    let mut x = DataMatrix::zeros(n, d);
    for i in 0..n {
        let t = rng.next_f64() * std::f64::consts::TAU;
        x[(i, 0)] = t.cos() * 3.0 + noise * rng.next_gaussian();
        x[(i, 1)] = t.sin() * 3.0 + noise * rng.next_gaussian();
        for j in 2..d {
            // Harmonics keep the intrinsic dimension low but fill all axes.
            x[(i, j)] = (t * (j as f64)).sin() + noise * rng.next_gaussian();
        }
    }
    x
}

/// Random sinusoidal embedding of a low-dimensional latent into `R^d` —
/// continuous, curved, strongly-correlated features.
///
/// This is the stand-in for sensor / trajectory / physics UCI tables
/// (power readings, localization traces, particle features): their
/// intrinsic dimension is far below `d`, K-Means centroids crawl along the
/// manifold (the slow-but-smooth Lloyd regime), and that is exactly the
/// landscape where the paper reports its largest accelerations.
pub fn sin_manifold<R: Rng>(
    rng: &mut R,
    n: usize,
    d: usize,
    intrinsic: usize,
    freq: f64,
    noise: f64,
) -> DataMatrix {
    assert!(intrinsic >= 1 && d >= 1);
    let mut w = vec![0.0; d * intrinsic];
    let mut phase = vec![0.0; d];
    for v in w.iter_mut() {
        *v = freq * rng.next_gaussian();
    }
    for v in phase.iter_mut() {
        *v = rng.next_range(0.0, std::f64::consts::TAU);
    }
    let mut x = DataMatrix::zeros(n, d);
    let mut t = vec![0.0; intrinsic];
    for i in 0..n {
        for tv in t.iter_mut() {
            *tv = rng.next_f64();
        }
        for j in 0..d {
            let mut arg = phase[j];
            for l in 0..intrinsic {
                arg += w[j * intrinsic + l] * t[l];
            }
            x[(i, j)] = arg.sin() + noise * rng.next_gaussian();
        }
    }
    x
}

/// A synthetic RGB-like image as an `(n_pixels × 3)` sample matrix composed
/// of a few dominant color regions plus gradient noise. Used by the color
/// quantization example (the paper's data-compression motivation).
pub fn synthetic_image<R: Rng>(rng: &mut R, width: usize, height: usize) -> DataMatrix {
    let palette: [[f64; 3]; 6] = [
        [0.85, 0.10, 0.10], // red
        [0.10, 0.60, 0.15], // green
        [0.15, 0.20, 0.80], // blue
        [0.95, 0.85, 0.20], // yellow
        [0.50, 0.50, 0.50], // gray
        [0.95, 0.95, 0.95], // white
    ];
    let mut x = DataMatrix::zeros(width * height, 3);
    for py in 0..height {
        for px in 0..width {
            let i = py * width + px;
            // Blocky regions with a diagonal gradient and sensor noise.
            let region = ((px * 3 / width) + (py * 2 / height) * 3) % palette.len();
            let grad = 0.15 * (px as f64 / width as f64);
            for ch in 0..3 {
                let v = palette[region][ch] + grad + 0.02 * rng.next_gaussian();
                x[(i, ch)] = v.clamp(0.0, 1.0);
            }
        }
    }
    x
}

/// Heavy-tailed mixture: Gaussian clusters whose sigma is drawn from a
/// log-uniform range, mimicking the scale disparity of real UCI features.
pub fn heavy_tail_blobs<R: Rng>(
    rng: &mut R,
    n: usize,
    d: usize,
    clusters: usize,
    spread: f64,
) -> DataMatrix {
    let mut x = gaussian_blobs_ex(rng, n, d, clusters, spread, 0.1 * spread, 0.02, 4.0);
    // Inject a few far outliers (heavy tails).
    let n_out = (n / 200).max(1);
    let mut pcg = Pcg32::seed_from_u64(rng.next_u64());
    let mut idx: Vec<usize> = (0..n).collect();
    shuffle(&mut idx, &mut pcg);
    for &i in idx.iter().take(n_out) {
        for j in 0..d {
            x[(i, j)] = pcg.next_range(-8.0 * spread, 8.0 * spread);
        }
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    #[test]
    fn blobs_shape_and_determinism() {
        let a = gaussian_blobs(&mut Pcg32::seed_from_u64(1), 500, 4, 5, 2.0, 0.1);
        let b = gaussian_blobs(&mut Pcg32::seed_from_u64(1), 500, 4, 5, 2.0, 0.1);
        assert_eq!(a.n(), 500);
        assert_eq!(a.d(), 4);
        assert_eq!(a, b, "same seed must give identical data");
    }

    #[test]
    fn blobs_cluster_structure_exists() {
        // With tiny noise the pairwise spread within a cluster is far below
        // the spread between cluster centers: variance check.
        let x = gaussian_blobs(&mut Pcg32::seed_from_u64(2), 2000, 2, 4, 5.0, 0.01);
        let b = x.bounds();
        assert!(b[0].1 - b[0].0 > 1.0, "data should span the center box");
    }

    #[test]
    fn birch_grid_bounds() {
        let x = birch_grid(&mut Pcg32::seed_from_u64(3), 5000, 10, 0.05);
        let b = x.bounds();
        for j in 0..2 {
            assert!(b[j].0 > -1.0 && b[j].1 < 10.0, "grid range violated: {:?}", b[j]);
        }
    }

    #[test]
    fn uniform_box_respects_half_width() {
        let x = uniform_box(&mut Pcg32::seed_from_u64(4), 1000, 3, 2.5);
        for (lo, hi) in x.bounds() {
            assert!(lo >= -2.5 && hi < 2.5);
        }
    }

    #[test]
    fn synthetic_image_rgb_range() {
        let x = synthetic_image(&mut Pcg32::seed_from_u64(5), 32, 24);
        assert_eq!(x.n(), 32 * 24);
        assert_eq!(x.d(), 3);
        for (lo, hi) in x.bounds() {
            assert!(lo >= 0.0 && hi <= 1.0);
        }
    }

    #[test]
    fn sin_manifold_bounded_and_deterministic() {
        let a = sin_manifold(&mut Pcg32::seed_from_u64(8), 400, 6, 2, 4.0, 0.05);
        let b = sin_manifold(&mut Pcg32::seed_from_u64(8), 400, 6, 2, 4.0, 0.05);
        assert_eq!(a, b);
        for (lo, hi) in a.bounds() {
            assert!(lo > -2.0 && hi < 2.0, "sin+noise stays near [-1,1]");
        }
    }

    #[test]
    fn noisy_curve_shape() {
        let x = noisy_curve(&mut Pcg32::seed_from_u64(6), 300, 5, 0.05);
        assert_eq!((x.n(), x.d()), (300, 5));
    }

    #[test]
    fn heavy_tail_has_outliers() {
        let x = heavy_tail_blobs(&mut Pcg32::seed_from_u64(7), 2000, 3, 5, 1.0);
        let b = x.bounds();
        let wide = b.iter().any(|(lo, hi)| hi - lo > 6.0);
        assert!(wide, "outlier injection should widen the bounding box");
    }
}
