//! Registry reproducing the paper's Table 1 — the 20 evaluation datasets.
//!
//! The 19 UCI tables are not redistributable inside this offline image, so
//! each entry is generated synthetically with the **exact `N` and `d` of
//! Table 1** and a cluster structure chosen to be plausible for the source
//! data (see DESIGN.md §3 for why this preserves the paper's observable
//! behaviour). Dataset #13 (Birch) is the real construction from Zhang et
//! al. 1997: a 10×10 grid of Gaussian clusters.
//!
//! Generation is deterministic: dataset `k` always uses seed `0xDA7A_0000 + k`.

use super::synth;
use super::DataMatrix;
use crate::rng::Pcg32;

/// The shape of synthetic structure standing in for a source dataset.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Structure {
    /// Gaussian mixture: (clusters, spread, noise, background, anisotropy).
    Blobs { clusters: usize, spread: f64, noise: f64, background: f64, anisotropy: f64 },
    /// The Birch regular grid: (side, sigma).
    BirchGrid { side: usize, sigma: f64 },
    /// Noisy low-dimensional curve (poorly separated regime).
    Curve { noise: f64 },
    /// Heavy-tailed mixture with outliers: (clusters, spread).
    HeavyTail { clusters: usize, spread: f64 },
    /// Sinusoidal manifold embedding: (intrinsic dim, frequency, noise) —
    /// the stand-in for strongly-correlated sensor/trajectory tables.
    Manifold { intrinsic: usize, freq: f64, noise: f64 },
}

/// One Table-1 dataset: paper row number, name, paper N, d, and the
/// synthetic structure used to generate it.
#[derive(Debug, Clone, Copy)]
pub struct DatasetSpec {
    pub number: usize,
    pub name: &'static str,
    pub n: usize,
    pub d: usize,
    pub structure: Structure,
}

impl DatasetSpec {
    /// Generate the dataset at full paper size.
    pub fn generate(&self) -> DataMatrix {
        self.generate_scaled(1.0)
    }

    /// Generate with `scale ∈ (0, 1]` of the paper's sample count (bench
    /// smoke mode uses small scales; structure parameters are unchanged, so
    /// the relative behaviour of solvers is preserved).
    pub fn generate_scaled(&self, scale: f64) -> DataMatrix {
        assert!(scale > 0.0 && scale <= 1.0, "scale must be in (0,1]");
        let n = ((self.n as f64 * scale) as usize).max(64);
        let mut rng = Pcg32::seed_from_u64(0xDA7A_0000 + self.number as u64);
        match self.structure {
            Structure::Blobs { clusters, spread, noise, background, anisotropy } => {
                synth::gaussian_blobs_ex(
                    &mut rng, n, self.d, clusters, spread, noise, background, anisotropy,
                )
            }
            Structure::BirchGrid { side, sigma } => synth::birch_grid(&mut rng, n, side, sigma),
            Structure::Curve { noise } => synth::noisy_curve(&mut rng, n, self.d, noise),
            Structure::HeavyTail { clusters, spread } => {
                synth::heavy_tail_blobs(&mut rng, n, self.d, clusters, spread)
            }
            Structure::Manifold { intrinsic, freq, noise } => {
                synth::sin_manifold(&mut rng, n, self.d, intrinsic, freq, noise)
            }
        }
    }
}

/// Shorthand for blob entries.
const fn blobs(
    clusters: usize,
    spread: f64,
    noise: f64,
    background: f64,
    anisotropy: f64,
) -> Structure {
    Structure::Blobs { clusters, spread, noise, background, anisotropy }
}

/// Table 1 of the paper, in paper order. `N`/`d` match the paper exactly;
/// the structure column encodes how separated / noisy the stand-in is.
/// Shorthand for manifold entries.
const fn mani(intrinsic: usize, freq: f64, noise: f64) -> Structure {
    Structure::Manifold { intrinsic, freq, noise }
}

pub const REGISTRY: [DatasetSpec; 20] = [
    // Structure notes: sensor / trajectory / histogram tables are modelled
    // as low-intrinsic-dimension manifolds (their features are strongly
    // correlated — e.g. #2 is CT-slice features indexed by axial position,
    // #6 is a power time series, #12 is localization traces); categorical /
    // multi-class tables as Gaussian mixtures; #13 is the real Birch grid.
    // `freq` is calibrated so Lloyd's iteration count at K=10 lands near
    // the paper's Table 3 values.
    DatasetSpec { number: 1, name: "UCIHARDataXtrain", n: 7352, d: 561, structure: mani(2, 3.0, 0.10) },
    DatasetSpec { number: 2, name: "Slicelocalization", n: 53500, d: 385, structure: mani(1, 6.0, 0.05) },
    DatasetSpec { number: 3, name: "RelationNetwork", n: 53413, d: 22, structure: blobs(14, 1.2, 0.50, 0.10, 3.0) },
    DatasetSpec { number: 4, name: "Letterrecognition", n: 20000, d: 16, structure: blobs(26, 1.5, 0.55, 0.05, 2.0) },
    DatasetSpec { number: 5, name: "HTRU2", n: 17898, d: 8, structure: blobs(2, 1.5, 0.60, 0.15, 3.0) },
    DatasetSpec { number: 6, name: "Household", n: 2_049_280, d: 6, structure: mani(1, 10.0, 0.04) },
    DatasetSpec { number: 7, name: "FrogsMFCCs", n: 7195, d: 21, structure: blobs(10, 1.3, 0.45, 0.05, 2.0) },
    DatasetSpec { number: 8, name: "Eb", n: 45781, d: 2, structure: Structure::Curve { noise: 0.25 } },
    DatasetSpec { number: 9, name: "AllUsers", n: 78095, d: 8, structure: mani(1, 8.0, 0.06) },
    DatasetSpec { number: 10, name: "MiniBoone", n: 130_064, d: 50, structure: mani(2, 6.0, 0.08) },
    DatasetSpec { number: 11, name: "Colorment", n: 68040, d: 9, structure: blobs(16, 1.0, 0.60, 0.15, 2.0) },
    DatasetSpec { number: 12, name: "Conflongdemo", n: 164_860, d: 3, structure: mani(1, 6.0, 0.08) },
    DatasetSpec { number: 13, name: "Birch", n: 100_000, d: 2, structure: Structure::BirchGrid { side: 10, sigma: 0.08 } },
    DatasetSpec { number: 14, name: "Shuttle", n: 43500, d: 9, structure: blobs(7, 1.6, 0.35, 0.03, 3.0) },
    DatasetSpec { number: 15, name: "Covtype", n: 581_012, d: 55, structure: mani(2, 4.0, 0.10) },
    DatasetSpec { number: 16, name: "SkinNonSkin", n: 245_057, d: 4, structure: mani(2, 2.0, 0.05) },
    DatasetSpec { number: 17, name: "Finalgeneral", n: 10104, d: 72, structure: blobs(9, 1.1, 0.45, 0.05, 2.0) },
    DatasetSpec { number: 18, name: "ColorHistogram", n: 68040, d: 32, structure: mani(2, 5.0, 0.08) },
    DatasetSpec { number: 19, name: "USCensus1990", n: 2_458_285, d: 69, structure: blobs(18, 1.0, 0.50, 0.10, 2.0) },
    DatasetSpec { number: 20, name: "Kddcup99", n: 4_898_431, d: 37, structure: Structure::HeavyTail { clusters: 5, spread: 1.5 } },
];

/// Look up a registry entry by paper row number (1-based).
pub fn dataset_by_number(number: usize) -> Option<&'static DatasetSpec> {
    REGISTRY.iter().find(|s| s.number == number)
}

/// Look up a registry entry by (case-insensitive) name.
pub fn dataset_by_name(name: &str) -> Option<&'static DatasetSpec> {
    REGISTRY.iter().find(|s| s.name.eq_ignore_ascii_case(name))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_matches_paper_inventory() {
        assert_eq!(REGISTRY.len(), 20);
        // Spot-check the N/d pairs against Table 1.
        let expect = [
            (1, 7352, 561),
            (6, 2_049_280, 6),
            (13, 100_000, 2),
            (19, 2_458_285, 69),
            (20, 4_898_431, 37),
        ];
        for (num, n, d) in expect {
            let s = dataset_by_number(num).unwrap();
            assert_eq!((s.n, s.d), (n, d), "dataset #{num}");
        }
    }

    #[test]
    fn numbers_are_sequential() {
        for (i, s) in REGISTRY.iter().enumerate() {
            assert_eq!(s.number, i + 1);
        }
    }

    #[test]
    fn generation_is_deterministic_and_shaped() {
        let s = dataset_by_number(5).unwrap();
        let a = s.generate_scaled(0.05);
        let b = s.generate_scaled(0.05);
        assert_eq!(a, b);
        assert_eq!(a.d(), 8);
        assert!(a.n() >= 64);
    }

    #[test]
    fn lookup_by_name_case_insensitive() {
        assert_eq!(dataset_by_name("birch").unwrap().number, 13);
        assert_eq!(dataset_by_name("KDDCUP99").unwrap().number, 20);
        assert!(dataset_by_name("nope").is_none());
    }

    #[test]
    fn scaled_generation_caps_floor() {
        let s = dataset_by_number(1).unwrap();
        let tiny = s.generate_scaled(0.000001);
        assert_eq!(tiny.n(), 64, "floor at 64 samples");
    }

    #[test]
    fn birch_is_a_grid() {
        let s = dataset_by_number(13).unwrap();
        let x = s.generate_scaled(0.02);
        let b = x.bounds();
        assert!(b[0].1 <= 10.0 && b[0].0 >= -1.0);
    }
}
