//! Dataset IO: CSV (human-friendly, interoperable) and `fvecs`-style binary
//! (fast reload of the large registry datasets between bench runs).

use super::DataMatrix;
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufWriter, Read, Write};
use std::path::Path;

/// Load a headerless (or single-header) CSV of floats into a matrix.
/// Lines starting with `#` and blank lines are skipped; a first line that
/// fails to parse entirely is treated as a header.
pub fn load_csv(path: &Path) -> Result<DataMatrix> {
    let file = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let reader = std::io::BufReader::new(file);
    let mut data = Vec::new();
    let mut d = None;
    let mut first_data_line = true;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let trimmed = line.trim();
        if trimmed.is_empty() || trimmed.starts_with('#') {
            continue;
        }
        let values: std::result::Result<Vec<f64>, _> =
            trimmed.split(',').map(|f| f.trim().parse::<f64>()).collect();
        match values {
            Ok(row) => {
                match d {
                    None => d = Some(row.len()),
                    Some(expect) if expect != row.len() => {
                        bail!("ragged CSV at line {}: {} vs {} fields", lineno + 1, row.len(), expect)
                    }
                    _ => {}
                }
                data.extend_from_slice(&row);
                first_data_line = false;
            }
            Err(e) => {
                if first_data_line {
                    continue; // header line
                }
                bail!("bad float at line {}: {e}", lineno + 1);
            }
        }
    }
    let d = d.context("empty CSV")?;
    let n = data.len() / d;
    Ok(DataMatrix::from_vec(data, n, d))
}

/// Write a matrix as a plain CSV.
pub fn save_csv(path: &Path, x: &DataMatrix) -> Result<()> {
    let file = std::fs::File::create(path)?;
    let mut w = BufWriter::new(file);
    for i in 0..x.n() {
        let row = x.row(i);
        for (j, v) in row.iter().enumerate() {
            if j > 0 {
                w.write_all(b",")?;
            }
            write!(w, "{v}")?;
        }
        w.write_all(b"\n")?;
    }
    Ok(())
}

/// Shared binary-shard magic: the streaming layer's `MmapShardSource` /
/// `ShardWriter` (see [`super::chunks`]) speak the same format, so a shard
/// written chunk-by-chunk loads through [`load_fvecs`] and vice versa.
pub(crate) const FVECS_MAGIC: &[u8; 8] = b"AAKMFV01";

/// Save in a simple binary format: magic, u64 n, u64 d, then n·d f64 LE.
pub fn save_fvecs(path: &Path, x: &DataMatrix) -> Result<()> {
    let file = std::fs::File::create(path)?;
    let mut w = BufWriter::new(file);
    w.write_all(FVECS_MAGIC)?;
    w.write_all(&(x.n() as u64).to_le_bytes())?;
    w.write_all(&(x.d() as u64).to_le_bytes())?;
    for &v in x.as_slice() {
        w.write_all(&v.to_le_bytes())?;
    }
    Ok(())
}

/// Load the binary format written by [`save_fvecs`].
pub fn load_fvecs(path: &Path) -> Result<DataMatrix> {
    let mut file = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?,
    );
    let mut magic = [0u8; 8];
    file.read_exact(&mut magic)?;
    if &magic != FVECS_MAGIC {
        bail!("{} is not an aakm fvecs file", path.display());
    }
    let mut u64buf = [0u8; 8];
    file.read_exact(&mut u64buf)?;
    let n = u64::from_le_bytes(u64buf) as usize;
    file.read_exact(&mut u64buf)?;
    let d = u64::from_le_bytes(u64buf) as usize;
    let total = n.checked_mul(d).context("overflow in header")?;
    let mut raw = vec![0u8; total * 8];
    file.read_exact(&mut raw)?;
    let mut data = Vec::with_capacity(total);
    for chunk in raw.chunks_exact(8) {
        data.push(f64::from_le_bytes(chunk.try_into().unwrap()));
    }
    Ok(DataMatrix::from_vec(data, n, d))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("aakm_io_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn csv_roundtrip() {
        let x = DataMatrix::from_rows(&[&[1.5, -2.0], &[0.0, 3.25]]);
        let p = tmp("roundtrip.csv");
        save_csv(&p, &x).unwrap();
        let y = load_csv(&p).unwrap();
        assert_eq!(x, y);
    }

    #[test]
    fn csv_skips_header_and_comments() {
        let p = tmp("header.csv");
        std::fs::write(&p, "colA,colB\n# comment\n1.0,2.0\n\n3.0,4.0\n").unwrap();
        let x = load_csv(&p).unwrap();
        assert_eq!(x.n(), 2);
        assert_eq!(x.row(1), &[3.0, 4.0]);
    }

    #[test]
    fn csv_rejects_ragged() {
        let p = tmp("ragged.csv");
        std::fs::write(&p, "1.0,2.0\n3.0\n").unwrap();
        assert!(load_csv(&p).is_err());
    }

    #[test]
    fn fvecs_roundtrip() {
        let x = DataMatrix::from_rows(&[&[1.0, 2.0, 3.0], &[-4.0, 5.5, 6.0]]);
        let p = tmp("roundtrip.fv");
        save_fvecs(&p, &x).unwrap();
        let y = load_fvecs(&p).unwrap();
        assert_eq!(x, y);
    }

    #[test]
    fn fvecs_rejects_bad_magic() {
        let p = tmp("bad.fv");
        std::fs::write(&p, b"NOTMAGIC\x00\x00").unwrap();
        assert!(load_fvecs(&p).is_err());
    }
}
