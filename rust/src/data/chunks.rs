//! Chunked data sources — the streaming substrate of the mini-batch
//! engine.
//!
//! A [`ChunkSource`] yields fixed-size sample chunks into a caller-owned
//! buffer, so datasets larger than RAM flow through the SIMD assign
//! kernels one chunk at a time with peak residency bounded by the chunk
//! size. Three implementations cover the workloads:
//!
//! * [`InMemoryChunks`] — streams an existing [`DataMatrix`] (zero-copy
//!   source, chunk-copy into the buffer); chunking is exactly row slicing,
//!   which the property tests pin down.
//! * [`SynthChunks`] — an on-the-fly Gaussian-mixture generator with a
//!   fixed mixture and a rewindable sample stream: every epoch pass
//!   replays the identical samples, so the stream behaves like a dataset
//!   that never materializes.
//! * [`MmapShardSource`] — a memory-mapped binary shard on disk (the same
//!   `AAKMFV01` format as [`super::save_fvecs`]); pages are faulted in as
//!   chunks are copied out, so resident sample memory stays at one chunk.
//!
//! [`ShardWriter`] is the producer side: it streams chunks to disk without
//! ever holding the full dataset, patching the row count on `finish` — the
//! out-of-core pipeline of `examples/streaming.rs`.

use super::io::FVECS_MAGIC;
use super::DataMatrix;
use crate::error::ClusterError;
use crate::rng::{choose_weighted, Pcg32, Rng};
use std::io::{BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// A restartable stream of fixed-size sample chunks.
///
/// Sources are pull-driven: the consumer hands in a reusable
/// [`DataMatrix`] buffer and the source resizes it to the rows it
/// produced, so a warm consumer loop performs no per-chunk allocation.
/// [`ChunkSource::rewind`] restarts the stream; deterministic sources
/// (all three provided here) replay identical chunks after a rewind,
/// which is what lets the mini-batch solver treat one pass as one
/// deterministic epoch map.
pub trait ChunkSource {
    /// Dimensionality of every sample.
    fn d(&self) -> usize;

    /// Samples per pass, when known (`None` only for custom unbounded
    /// sources; all built-ins are bounded).
    fn len(&self) -> Option<usize>;

    /// Whether a pass over this source yields no samples (unknown-length
    /// sources report `false`).
    fn is_empty(&self) -> bool {
        self.len() == Some(0)
    }

    /// Fill `out` with the next `≤ max_rows` samples (resizing it to the
    /// produced row count) and return that count; `0` means the pass is
    /// exhausted. `out` must already have this source's dimensionality
    /// (resizing only changes the row count) — implementations panic on a
    /// mismatch rather than fill a misaligned buffer.
    fn next_chunk(
        &mut self,
        max_rows: usize,
        out: &mut DataMatrix,
    ) -> Result<usize, ClusterError>;

    /// Restart the stream from the beginning of the pass.
    fn rewind(&mut self);

    /// Fill `out` with the rows at `indices` (ascending order required;
    /// duplicates allowed — the shape sampling-with-replacement batches
    /// draw), resizing `out` to `indices.len()`. The default
    /// implementation streams one rewound pass and copies requested rows
    /// as their chunks go by, which works for any rewindable source;
    /// random-access sources override it with direct row reads. The
    /// stream cursor afterwards is unspecified — callers rewind before
    /// the next sequential use.
    ///
    /// Cost note: the default re-streams from the start (up to the
    /// largest requested row) and allocates a transient decode buffer on
    /// every call — fine for seeding and tests, but a per-batch hot loop
    /// (replacement-sampling epochs) should prefer a source with a
    /// random-access override (in-memory, mmap shard) over a pure
    /// generator, where each epoch costs roughly one extra generator
    /// pass per batch.
    fn gather_rows(
        &mut self,
        indices: &[usize],
        out: &mut DataMatrix,
    ) -> Result<(), ClusterError> {
        debug_assert!(
            indices.windows(2).all(|w| w[0] <= w[1]),
            "gather_rows indices must be ascending"
        );
        let d = self.d();
        assert_eq!(out.d(), d, "chunk buffer dimensionality mismatch");
        out.resize_rows(indices.len());
        if indices.is_empty() {
            return Ok(());
        }
        self.rewind();
        let mut buf = DataMatrix::zeros(0, d);
        // Absolute index of `buf`'s first row, and its row count.
        let mut row0 = 0usize;
        let mut got = 0usize;
        for (slot, &want) in indices.iter().enumerate() {
            while want >= row0 + got {
                row0 += got;
                got = self.next_chunk(1024, &mut buf)?;
                if got == 0 {
                    return Err(ClusterError::invalid(
                        "sampling",
                        format!("row {want} is beyond the source ({row0} rows streamed)"),
                    ));
                }
            }
            out.row_mut(slot).copy_from_slice(buf.row(want - row0));
        }
        Ok(())
    }
}

/// Stream an in-memory matrix chunk by chunk — the bridge that runs the
/// mini-batch engine on RAM-resident data (and the reference the chunking
/// property tests compare the out-of-core sources against).
pub struct InMemoryChunks {
    data: Arc<DataMatrix>,
    cursor: usize,
}

impl InMemoryChunks {
    /// Source over shared samples (zero-copy; chunks are copied out).
    pub fn new(data: Arc<DataMatrix>) -> Self {
        Self { data, cursor: 0 }
    }
}

impl ChunkSource for InMemoryChunks {
    fn d(&self) -> usize {
        self.data.d()
    }

    fn len(&self) -> Option<usize> {
        Some(self.data.n())
    }

    fn next_chunk(
        &mut self,
        max_rows: usize,
        out: &mut DataMatrix,
    ) -> Result<usize, ClusterError> {
        assert_eq!(out.d(), self.data.d(), "chunk buffer dimensionality mismatch");
        // Fault-injection point: inert unless a `FaultPlan` arms the
        // chunk-read site (robustness tests).
        crate::fault::check(crate::fault::FaultSite::ChunkRead)?;
        let remaining = self.data.n().saturating_sub(self.cursor);
        let rows = remaining.min(max_rows.max(1));
        out.resize_rows(rows);
        if rows == 0 {
            return Ok(0);
        }
        let d = self.data.d();
        let src = &self.data.as_slice()[self.cursor * d..(self.cursor + rows) * d];
        out.as_mut_slice().copy_from_slice(src);
        self.cursor += rows;
        Ok(rows)
    }

    fn rewind(&mut self) {
        self.cursor = 0;
    }

    fn gather_rows(
        &mut self,
        indices: &[usize],
        out: &mut DataMatrix,
    ) -> Result<(), ClusterError> {
        assert_eq!(out.d(), self.data.d(), "chunk buffer dimensionality mismatch");
        out.resize_rows(indices.len());
        for (slot, &i) in indices.iter().enumerate() {
            if i >= self.data.n() {
                return Err(ClusterError::invalid(
                    "sampling",
                    format!("row {i} is beyond the source ({} rows)", self.data.n()),
                ));
            }
            out.row_mut(slot).copy_from_slice(self.data.row(i));
        }
        Ok(())
    }
}

/// Deterministic Gaussian-mixture generator source: the mixture (centers,
/// per-cluster sigmas, weights) is drawn once at construction, and every
/// pass replays the same `epoch_len` samples from the same seed — an
/// arbitrarily large dataset that costs no memory and no disk.
pub struct SynthChunks {
    centers: DataMatrix,
    sigmas: Vec<f64>,
    weights: Vec<f64>,
    d: usize,
    epoch_len: usize,
    seed: u64,
    rng: Pcg32,
    produced: usize,
}

impl SynthChunks {
    /// Mixture of `clusters` isotropic Gaussians (centers uniform in
    /// `[-spread, spread]^d`, standard deviation `noise`), streaming
    /// `epoch_len` samples per pass.
    pub fn new(
        seed: u64,
        epoch_len: usize,
        d: usize,
        clusters: usize,
        spread: f64,
        noise: f64,
    ) -> Self {
        assert!(d >= 1 && clusters >= 1 && epoch_len >= 1);
        // The mixture comes from a separate stream so the sample stream
        // below starts identically on every rewind.
        let mut mix_rng = Pcg32::seed_from_u64(seed ^ 0x5EED_C0DE);
        let mut centers = DataMatrix::zeros(clusters, d);
        for c in 0..clusters {
            for j in 0..d {
                centers[(c, j)] = mix_rng.next_range(-spread, spread);
            }
        }
        let sigmas = vec![noise; clusters];
        let mut weights = vec![0.0; clusters];
        for w in weights.iter_mut() {
            *w = 0.2 + mix_rng.next_f64();
        }
        Self {
            centers,
            sigmas,
            weights,
            d,
            epoch_len,
            seed,
            rng: Pcg32::seed_from_u64(seed),
            produced: 0,
        }
    }

    /// The mixture's true centers (for inspection in examples/tests).
    pub fn centers(&self) -> &DataMatrix {
        &self.centers
    }
}

impl ChunkSource for SynthChunks {
    fn d(&self) -> usize {
        self.d
    }

    fn len(&self) -> Option<usize> {
        Some(self.epoch_len)
    }

    fn next_chunk(
        &mut self,
        max_rows: usize,
        out: &mut DataMatrix,
    ) -> Result<usize, ClusterError> {
        assert_eq!(out.d(), self.d, "chunk buffer dimensionality mismatch");
        let remaining = self.epoch_len.saturating_sub(self.produced);
        let rows = remaining.min(max_rows.max(1));
        out.resize_rows(rows);
        for i in 0..rows {
            let c = choose_weighted(&self.weights, &mut self.rng);
            let sigma = self.sigmas[c];
            let center = self.centers.row(c);
            for j in 0..self.d {
                out[(i, j)] = center[j] + sigma * self.rng.next_gaussian();
            }
        }
        self.produced += rows;
        Ok(rows)
    }

    fn rewind(&mut self) {
        self.produced = 0;
        self.rng = Pcg32::seed_from_u64(self.seed);
    }
}

/// Incremental writer for binary shards in the `AAKMFV01` format: chunks
/// are appended as they are produced (peak memory = one chunk) and the
/// header's row count is patched in on [`ShardWriter::finish`]. The
/// resulting file is readable by both [`MmapShardSource`] (streaming) and
/// [`super::load_fvecs`] (full load).
pub struct ShardWriter {
    w: BufWriter<std::fs::File>,
    d: usize,
    rows: u64,
}

impl ShardWriter {
    /// Create (truncate) a shard for `d`-dimensional samples.
    pub fn create(path: &Path, d: usize) -> crate::Result<Self> {
        assert!(d >= 1);
        let file = std::fs::File::create(path)?;
        let mut w = BufWriter::new(file);
        w.write_all(FVECS_MAGIC)?;
        w.write_all(&0u64.to_le_bytes())?; // row count, patched by finish()
        w.write_all(&(d as u64).to_le_bytes())?;
        Ok(Self { w, d, rows: 0 })
    }

    /// Rows written so far.
    pub fn rows(&self) -> u64 {
        self.rows
    }

    /// Append every row of `chunk` (must match the shard dimensionality).
    pub fn append(&mut self, chunk: &DataMatrix) -> crate::Result<()> {
        anyhow::ensure!(
            chunk.d() == self.d,
            "chunk is {}-dimensional but the shard holds d={}",
            chunk.d(),
            self.d
        );
        for &v in chunk.as_slice() {
            self.w.write_all(&v.to_le_bytes())?;
        }
        self.rows += chunk.n() as u64;
        Ok(())
    }

    /// Patch the header row count, flush, and return the total rows.
    pub fn finish(mut self) -> crate::Result<u64> {
        self.w.seek(SeekFrom::Start(FVECS_MAGIC.len() as u64))?;
        self.w.write_all(&self.rows.to_le_bytes())?;
        self.w.flush()?;
        Ok(self.rows)
    }
}

/// Read-only memory map of a whole file (unix `mmap(2)`; declared
/// directly against libc — which std always links on unix — so no crate
/// dependency is needed). Pages fault in lazily as the consumer copies
/// chunks out, which is what keeps resident sample memory at one chunk
/// for shards far larger than RAM.
#[cfg(unix)]
struct Mmap {
    ptr: *mut core::ffi::c_void,
    len: usize,
}

// `unsafe extern` keeps this block valid under edition 2024 (where bare
// `extern` blocks are rejected) as well as older editions on current
// toolchains.
#[cfg(unix)]
unsafe extern "C" {
    fn mmap(
        addr: *mut core::ffi::c_void,
        len: usize,
        prot: i32,
        flags: i32,
        fd: i32,
        offset: i64,
    ) -> *mut core::ffi::c_void;
    fn munmap(addr: *mut core::ffi::c_void, len: usize) -> i32;
    fn madvise(addr: *mut core::ffi::c_void, len: usize, advice: i32) -> i32;
}

#[cfg(unix)]
impl Mmap {
    const PROT_READ: i32 = 1;
    const MAP_PRIVATE: i32 = 2;
    // Same numeric values on Linux and the BSD family (incl. macOS).
    const MADV_SEQUENTIAL: i32 = 2;
    const MADV_WILLNEED: i32 = 3;

    fn map(file: &std::fs::File, len: usize) -> std::io::Result<Self> {
        use std::os::unix::io::AsRawFd;
        assert!(len > 0, "cannot map an empty file");
        // SAFETY: a fresh private read-only mapping of `len` bytes backed
        // by an open fd; the pointer is checked against MAP_FAILED below
        // and unmapped in Drop.
        let ptr = unsafe {
            mmap(
                std::ptr::null_mut(),
                len,
                Self::PROT_READ,
                Self::MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr as isize == -1 || ptr.is_null() {
            return Err(std::io::Error::last_os_error());
        }
        Ok(Self { ptr, len })
    }

    fn as_bytes(&self) -> &[u8] {
        // SAFETY: the mapping is valid for `len` bytes until Drop, and the
        // underlying shard file is treated as immutable while sourced.
        unsafe { std::slice::from_raw_parts(self.ptr as *const u8, self.len) }
    }

    /// Best-effort `madvise` over the whole mapping. Advice is a paging
    /// hint, never correctness: a kernel that rejects it simply pages on
    /// demand, so the result is ignored.
    fn advise_all(&self, advice: i32) {
        // SAFETY: exactly the region returned by mmap in `map`.
        unsafe {
            let _ = madvise(self.ptr, self.len, advice);
        }
    }

    /// Best-effort `madvise` over a byte window of the mapping. The start
    /// is rounded down to a 64 KiB boundary — page-aligned for every page
    /// size in practical use, which `madvise` requires — and the window
    /// is clamped to the mapping.
    fn advise_window(&self, offset: usize, len: usize, advice: i32) {
        const ALIGN: usize = 64 * 1024;
        let start = offset.min(self.len) & !(ALIGN - 1);
        let end = offset.saturating_add(len).min(self.len);
        if end <= start {
            return;
        }
        // SAFETY: `start..end` lies within the mapping and `start` is
        // aligned; a rejected hint is ignored.
        unsafe {
            let _ = madvise(
                (self.ptr as *mut u8).add(start) as *mut core::ffi::c_void,
                end - start,
                advice,
            );
        }
    }
}

#[cfg(unix)]
impl Drop for Mmap {
    fn drop(&mut self) {
        // SAFETY: exactly the region returned by mmap in `map`.
        let rc = unsafe { munmap(self.ptr, self.len) };
        // A failed munmap leaks the mapping, which is survivable; what it
        // must never do is panic inside Drop on an unwind path — shard
        // sources are dropped by the prefetcher thread while *it* is
        // panicking under fault injection, and a double panic would abort
        // the process instead of surfacing a typed error.
        debug_assert!(rc == 0 || std::thread::panicking(), "munmap failed");
    }
}

// SAFETY: the mapping is read-only and the raw pointer is never aliased
// mutably; sending it between threads is sound.
#[cfg(unix)]
unsafe impl Send for Mmap {}

/// Streaming source over an on-disk binary shard (`AAKMFV01`: magic,
/// `u64` rows, `u64` d, then row-major `f64` little-endian). On unix the
/// file is memory-mapped and chunks are decoded straight out of the
/// mapping; elsewhere a buffered reader seeks through the file. Either
/// way, resident sample memory is one chunk.
pub struct MmapShardSource {
    path: PathBuf,
    n: usize,
    d: usize,
    cursor: usize,
    #[cfg(unix)]
    map: Mmap,
    #[cfg(not(unix))]
    file: std::io::BufReader<std::fs::File>,
}

/// Byte offset of the first sample (magic + two u64 header words).
const SHARD_HEADER_BYTES: usize = 24;

impl MmapShardSource {
    /// Open a shard, validating magic and shape against the file length.
    ///
    /// Every rejection — missing file, foreign magic, empty or overflowing
    /// declared shape, truncation, trailing bytes past the declared rows —
    /// surfaces as a typed [`ClusterError::Data`], so the coordinator's
    /// retry classifier sees shard corruption as an I/O-class fault.
    pub fn open(path: &Path) -> Result<Self, ClusterError> {
        let fail = |reason: String| ClusterError::Data {
            source: format!("shard {}", path.display()),
            reason,
        };
        let mut file =
            std::fs::File::open(path).map_err(|e| fail(format!("open: {e}")))?;
        let mut header = [0u8; SHARD_HEADER_BYTES];
        file.read_exact(&mut header)
            .map_err(|e| fail(format!("read header: {e}")))?;
        if &header[..8] != FVECS_MAGIC {
            return Err(fail("not an AAKMFV01 shard (bad magic)".into()));
        }
        let n = u64::from_le_bytes(header[8..16].try_into().unwrap()) as usize;
        let d = u64::from_le_bytes(header[16..24].try_into().unwrap()) as usize;
        if n == 0 || d == 0 {
            return Err(fail(format!("declares an empty {n}x{d} shard")));
        }
        let need = (n as u64)
            .checked_mul(d as u64)
            .and_then(|v| v.checked_mul(8))
            .and_then(|v| v.checked_add(SHARD_HEADER_BYTES as u64))
            .ok_or_else(|| fail(format!("{n}x{d} shape overflows the file length")))?;
        let actual = file.metadata().map_err(|e| fail(format!("stat: {e}")))?.len();
        // Strict equality: a short file means truncated rows, a long one
        // means the header's row count disagrees with the payload stride —
        // both are corruption, not data to silently read past.
        if actual != need {
            let what = if actual < need { "truncated" } else { "has trailing bytes" };
            return Err(fail(format!(
                "{what}: {actual} bytes for a {n}x{d} shard ({need} expected)"
            )));
        }
        #[cfg(unix)]
        {
            let map =
                Mmap::map(&file, need as usize).map_err(|e| fail(format!("mmap: {e}")))?;
            // The dominant access pattern is the epoch loop's forward
            // scan: tell the kernel so read-ahead widens and behind-pages
            // drop early, instead of the default mixed-access heuristics.
            map.advise_all(Mmap::MADV_SEQUENTIAL);
            Ok(Self { path: path.to_path_buf(), n, d, cursor: 0, map })
        }
        #[cfg(not(unix))]
        {
            file.seek(SeekFrom::Start(SHARD_HEADER_BYTES as u64))
                .map_err(|e| fail(format!("seek: {e}")))?;
            let file = std::io::BufReader::new(file);
            Ok(Self { path: path.to_path_buf(), n, d, cursor: 0, file })
        }
    }

    /// Shard path (for labels and error messages).
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Total rows in the shard.
    pub fn n(&self) -> usize {
        self.n
    }

    #[cfg(not(unix))]
    fn data_error(&self, reason: String) -> ClusterError {
        ClusterError::Data { source: self.path.display().to_string(), reason }
    }

    /// Decode row `i` (caller-validated) into `dst` — the single site
    /// that knows the `AAKMFV01` row layout, shared by the sequential
    /// chunk reader and the random-access gather.
    fn read_row(&mut self, i: usize, dst: &mut [f64]) -> Result<(), ClusterError> {
        debug_assert!(i < self.n);
        debug_assert_eq!(dst.len(), self.d);
        #[cfg(unix)]
        {
            let start = SHARD_HEADER_BYTES + i * self.d * 8;
            let bytes = &self.map.as_bytes()[start..start + self.d * 8];
            for (v, raw) in dst.iter_mut().zip(bytes.chunks_exact(8)) {
                *v = f64::from_le_bytes(raw.try_into().expect("chunks_exact(8)"));
            }
        }
        #[cfg(not(unix))]
        {
            let start = SHARD_HEADER_BYTES as u64 + (i * self.d * 8) as u64;
            self.file
                .seek(SeekFrom::Start(start))
                .map_err(|e| self.data_error(format!("seek: {e}")))?;
            let mut raw = [0u8; 8];
            for v in dst.iter_mut() {
                self.file
                    .read_exact(&mut raw)
                    .map_err(|e| self.data_error(format!("read: {e}")))?;
                *v = f64::from_le_bytes(raw);
            }
        }
        // A corrupt shard can hold any bit pattern; rejecting non-finite
        // values here — the single decode site — covers the sequential and
        // random-access paths alike, with the offending row in the error.
        if let Some(j) = dst.iter().position(|v| !v.is_finite()) {
            return Err(ClusterError::InvalidData {
                source: format!("shard {}", self.path.display()),
                row: i,
                reason: format!("non-finite value at column {j}"),
            });
        }
        Ok(())
    }
}

impl ChunkSource for MmapShardSource {
    fn d(&self) -> usize {
        self.d
    }

    fn len(&self) -> Option<usize> {
        Some(self.n)
    }

    fn next_chunk(
        &mut self,
        max_rows: usize,
        out: &mut DataMatrix,
    ) -> Result<usize, ClusterError> {
        assert_eq!(out.d(), self.d, "chunk buffer dimensionality mismatch");
        // Fault-injection point, mirroring `InMemoryChunks::next_chunk`.
        crate::fault::check(crate::fault::FaultSite::ChunkRead)?;
        let remaining = self.n.saturating_sub(self.cursor);
        let rows = remaining.min(max_rows.max(1));
        out.resize_rows(rows);
        if rows == 0 {
            return Ok(0);
        }
        #[cfg(unix)]
        {
            // Prefetch-window touch: ask for the *next* chunk's pages
            // while this one decodes, so the page-in overlaps the copy
            // even without the prefetcher thread (and feeds it when the
            // thread is running ahead).
            let row_bytes = self.d * 8;
            self.map.advise_window(
                SHARD_HEADER_BYTES + (self.cursor + rows) * row_bytes,
                rows * row_bytes,
                Mmap::MADV_WILLNEED,
            );
        }
        for r in 0..rows {
            let row = self.cursor + r;
            self.read_row(row, out.row_mut(r))?;
        }
        self.cursor += rows;
        Ok(rows)
    }

    fn rewind(&mut self) {
        self.cursor = 0;
    }

    fn gather_rows(
        &mut self,
        indices: &[usize],
        out: &mut DataMatrix,
    ) -> Result<(), ClusterError> {
        assert_eq!(out.d(), self.d, "chunk buffer dimensionality mismatch");
        out.resize_rows(indices.len());
        for (slot, &i) in indices.iter().enumerate() {
            if i >= self.n {
                return Err(ClusterError::invalid(
                    "sampling",
                    format!("row {i} is beyond the shard ({} rows)", self.n),
                ));
            }
            self.read_row(i, out.row_mut(slot))?;
        }
        Ok(())
    }
}

/// Collect an entire source into one matrix (bounded sources only —
/// intended for seeding buffers and tests, not for out-of-core data).
pub fn collect_source(
    source: &mut dyn ChunkSource,
    chunk_rows: usize,
    max_rows: usize,
) -> Result<DataMatrix, ClusterError> {
    let d = source.d();
    let mut out = DataMatrix::zeros(0, d);
    let mut chunk = DataMatrix::zeros(0, d);
    while out.n() < max_rows {
        let want = chunk_rows.min(max_rows - out.n());
        let got = source.next_chunk(want, &mut chunk)?;
        if got == 0 {
            break;
        }
        out.append(&chunk);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("aakm_chunk_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn in_memory_chunks_match_direct_slicing() {
        let mut rng = Pcg32::seed_from_u64(11);
        let x = Arc::new(synth::gaussian_blobs(&mut rng, 257, 3, 4, 2.0, 0.3));
        for chunk_rows in [1usize, 7, 64, 256, 257, 1000] {
            let mut src = InMemoryChunks::new(Arc::clone(&x));
            let mut buf = DataMatrix::zeros(0, 3);
            let mut row = 0usize;
            loop {
                let got = src.next_chunk(chunk_rows, &mut buf).unwrap();
                if got == 0 {
                    break;
                }
                assert!(got <= chunk_rows);
                for i in 0..got {
                    assert_eq!(buf.row(i), x.row(row + i), "chunk_rows={chunk_rows}");
                }
                row += got;
            }
            assert_eq!(row, x.n(), "chunking must cover every row exactly once");
        }
    }

    #[test]
    fn synth_chunks_replay_identically_after_rewind() {
        let mut src = SynthChunks::new(5, 500, 4, 3, 2.0, 0.2);
        let first = collect_source(&mut src, 128, usize::MAX).unwrap();
        assert_eq!(first.n(), 500);
        src.rewind();
        let second = collect_source(&mut src, 97, usize::MAX).unwrap();
        assert_eq!(first, second, "rewound pass must replay the same samples");
        // A different seed gives a different stream.
        let mut other = SynthChunks::new(6, 500, 4, 3, 2.0, 0.2);
        let third = collect_source(&mut other, 128, usize::MAX).unwrap();
        assert_ne!(first, third);
    }

    #[test]
    fn shard_roundtrip_through_writer_and_mmap() {
        let mut rng = Pcg32::seed_from_u64(21);
        let x = synth::gaussian_blobs(&mut rng, 301, 5, 4, 2.0, 0.3);
        let path = tmp("roundtrip.fv");
        let mut w = ShardWriter::create(&path, 5).unwrap();
        // Write in uneven chunks to exercise the append path.
        let mut src = InMemoryChunks::new(Arc::new(x.clone()));
        let mut buf = DataMatrix::zeros(0, 5);
        while src.next_chunk(77, &mut buf).unwrap() > 0 {
            w.append(&buf).unwrap();
        }
        assert_eq!(w.finish().unwrap(), 301);
        // Streaming read reproduces the matrix...
        let mut shard = MmapShardSource::open(&path).unwrap();
        assert_eq!(shard.n(), 301);
        assert_eq!(shard.d(), 5);
        let back = collect_source(&mut shard, 64, usize::MAX).unwrap();
        assert_eq!(back, x);
        // ...and rewinding replays it.
        shard.rewind();
        let again = collect_source(&mut shard, 300, usize::MAX).unwrap();
        assert_eq!(again, x);
        // The format is plain fvecs: the batch loader agrees.
        let full = crate::data::load_fvecs(&path).unwrap();
        assert_eq!(full, x);
    }

    #[test]
    fn shard_rejects_bad_magic_and_truncation() {
        let bad = tmp("bad_magic.fv");
        std::fs::write(&bad, b"NOTMAGIC\x01\x00\x00\x00\x00\x00\x00\x00").unwrap();
        assert!(MmapShardSource::open(&bad).is_err());

        let trunc = tmp("trunc.fv");
        let mut w = ShardWriter::create(&trunc, 2).unwrap();
        w.append(&DataMatrix::zeros(3, 2)).unwrap();
        w.finish().unwrap();
        // Chop off the last row's bytes.
        let bytes = std::fs::read(&trunc).unwrap();
        std::fs::write(&trunc, &bytes[..bytes.len() - 8]).unwrap();
        assert!(MmapShardSource::open(&trunc).is_err());
    }

    #[test]
    fn shard_open_rejects_trailing_bytes_typed() {
        let path = tmp("trailing.fv");
        let mut w = ShardWriter::create(&path, 2).unwrap();
        w.append(&DataMatrix::zeros(3, 2)).unwrap();
        w.finish().unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.extend_from_slice(&[0u8; 4]);
        std::fs::write(&path, &bytes).unwrap();
        let err = MmapShardSource::open(&path).unwrap_err();
        assert!(matches!(err, ClusterError::Data { .. }), "{err}");
        assert!(err.to_string().contains("trailing"), "{err}");
    }

    #[test]
    fn shard_rows_with_non_finite_values_fail_typed() {
        let path = tmp("nonfinite.fv");
        let mut w = ShardWriter::create(&path, 2).unwrap();
        let mut chunk = DataMatrix::zeros(3, 2);
        chunk[(1, 1)] = f64::NAN;
        w.append(&chunk).unwrap();
        w.finish().unwrap();
        let mut shard = MmapShardSource::open(&path).unwrap();
        let mut buf = DataMatrix::zeros(0, 2);
        match shard.next_chunk(16, &mut buf).unwrap_err() {
            ClusterError::InvalidData { row, .. } => assert_eq!(row, 1),
            other => panic!("expected InvalidData, got {other}"),
        }
    }

    #[test]
    fn injected_chunk_read_faults_fire_on_schedule() {
        use crate::fault::{FaultKind, FaultPlan, FaultSite};
        let x = Arc::new(DataMatrix::zeros(8, 2));
        let mut src = InMemoryChunks::new(x);
        let mut buf = DataMatrix::zeros(0, 2);
        let _guard = FaultPlan::new()
            .fail_next(FaultSite::ChunkRead, FaultKind::Error, 1)
            .install_for_current_thread();
        let err = src.next_chunk(4, &mut buf).unwrap_err();
        assert_eq!(err.fault_class(), Some(crate::error::FaultClass::Io));
        // The single-shot budget is spent: the next read succeeds.
        assert_eq!(src.next_chunk(4, &mut buf).unwrap(), 4);
    }

    #[test]
    #[should_panic(expected = "chunk buffer dimensionality mismatch")]
    fn next_chunk_rejects_mismatched_buffer() {
        let x = Arc::new(DataMatrix::zeros(4, 3));
        let mut src = InMemoryChunks::new(x);
        let mut buf = DataMatrix::zeros(0, 2);
        let _ = src.next_chunk(2, &mut buf);
    }

    #[test]
    fn shard_writer_rejects_dimension_mismatch() {
        let path = tmp("dmismatch.fv");
        let mut w = ShardWriter::create(&path, 3).unwrap();
        assert!(w.append(&DataMatrix::zeros(2, 2)).is_err());
    }

    #[test]
    fn gather_rows_agrees_across_sources_and_with_streaming_default() {
        // The overridden random-access gathers (in-memory, mmap shard)
        // and the streaming default (exercised via SynthChunks) must all
        // return exactly the rows a full collect yields.
        let mut synth = SynthChunks::new(31, 400, 3, 4, 2.0, 0.25);
        let full = collect_source(&mut synth, 128, usize::MAX).unwrap();
        let indices = vec![0usize, 0, 7, 7, 7, 128, 129, 255, 399, 399];

        let mut expect = DataMatrix::zeros(0, 3);
        for &i in &indices {
            expect.append(&full.gather_rows(&[i]));
        }

        let mut out = DataMatrix::zeros(0, 3);
        synth.gather_rows(&indices, &mut out).unwrap();
        assert_eq!(out, expect, "streaming default gather");

        let mut in_mem = InMemoryChunks::new(Arc::new(full.clone()));
        synth.rewind();
        in_mem.gather_rows(&indices, &mut out).unwrap();
        assert_eq!(out, expect, "in-memory gather");

        let path = tmp("gather.fv");
        let mut w = ShardWriter::create(&path, 3).unwrap();
        w.append(&full).unwrap();
        w.finish().unwrap();
        let mut shard = MmapShardSource::open(&path).unwrap();
        shard.gather_rows(&indices, &mut out).unwrap();
        assert_eq!(out, expect, "mmap shard gather");

        // Out-of-range rows fail typed on every implementation.
        let bad = vec![0usize, 400];
        assert!(in_mem.gather_rows(&bad, &mut out).is_err());
        assert!(shard.gather_rows(&bad, &mut out).is_err());
        let mut synth2 = SynthChunks::new(31, 400, 3, 4, 2.0, 0.25);
        assert!(synth2.gather_rows(&bad, &mut out).is_err());
    }
}
