//! Data substrate: the sample matrix, synthetic dataset generators, CSV and
//! binary IO, normalization, and the registry reproducing the paper's
//! Table 1 inventory (20 datasets) as synthetic equivalents.

mod io;
mod matrix;
pub mod registry;
pub mod synth;

pub use io::{load_csv, load_fvecs, save_csv, save_fvecs};
pub use matrix::DataMatrix;
pub use registry::{dataset_by_name, dataset_by_number, DatasetSpec, REGISTRY};

/// Scale every column to zero mean / unit variance (columns with zero
/// variance are left centered). Returns per-column (mean, std) so callers
/// can de-normalize centroids.
pub fn standardize(x: &mut DataMatrix) -> Vec<(f64, f64)> {
    let (n, d) = (x.n(), x.d());
    let mut stats = vec![(0.0, 0.0); d];
    if n == 0 {
        return stats;
    }
    for j in 0..d {
        let mut mean = 0.0;
        for i in 0..n {
            mean += x[(i, j)];
        }
        mean /= n as f64;
        let mut var = 0.0;
        for i in 0..n {
            let c = x[(i, j)] - mean;
            var += c * c;
        }
        var /= n as f64;
        let std = var.sqrt();
        let denom = if std > 0.0 { std } else { 1.0 };
        for i in 0..n {
            x[(i, j)] = (x[(i, j)] - mean) / denom;
        }
        stats[j] = (mean, std);
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standardize_zero_mean_unit_var() {
        let mut x = DataMatrix::from_vec(vec![1.0, 10.0, 2.0, 20.0, 3.0, 30.0], 3, 2);
        let stats = standardize(&mut x);
        for j in 0..2 {
            let mean: f64 = (0..3).map(|i| x[(i, j)]).sum::<f64>() / 3.0;
            let var: f64 = (0..3).map(|i| x[(i, j)] * x[(i, j)]).sum::<f64>() / 3.0;
            assert!(mean.abs() < 1e-12);
            assert!((var - 1.0).abs() < 1e-12);
            assert!(stats[j].1 > 0.0);
        }
    }

    #[test]
    fn standardize_constant_column() {
        let mut x = DataMatrix::from_vec(vec![5.0, 5.0, 5.0], 3, 1);
        standardize(&mut x);
        for i in 0..3 {
            assert_eq!(x[(i, 0)], 0.0);
        }
    }
}
