//! Data substrate: the sample matrix, synthetic dataset generators, CSV and
//! binary IO, normalization, chunked streaming sources (in-memory /
//! generator / memory-mapped shard — see [`chunks`]), and the registry
//! reproducing the paper's Table 1 inventory (20 datasets) as synthetic
//! equivalents.

pub mod chunks;
mod io;
mod matrix;
pub mod registry;
pub mod synth;

pub use chunks::{ChunkSource, InMemoryChunks, MmapShardSource, ShardWriter, SynthChunks};
pub use io::{load_csv, load_fvecs, save_csv, save_fvecs};
pub use matrix::DataMatrix;
pub use registry::{dataset_by_name, dataset_by_number, DatasetSpec, REGISTRY};

/// Scale every column to zero mean / unit variance (columns with zero
/// variance are left centered). Returns per-column (mean, std) so callers
/// can de-normalize centroids.
pub fn standardize(x: &mut DataMatrix) -> Vec<(f64, f64)> {
    let (n, d) = (x.n(), x.d());
    let mut stats = vec![(0.0, 0.0); d];
    if n == 0 {
        return stats;
    }
    for j in 0..d {
        let mut mean = 0.0;
        for i in 0..n {
            mean += x[(i, j)];
        }
        mean /= n as f64;
        let mut var = 0.0;
        for i in 0..n {
            let c = x[(i, j)] - mean;
            var += c * c;
        }
        var /= n as f64;
        let std = var.sqrt();
        let denom = if std > 0.0 { std } else { 1.0 };
        for i in 0..n {
            x[(i, j)] = (x[(i, j)] - mean) / denom;
        }
        stats[j] = (mean, std);
    }
    stats
}

/// Pre-centering transform: subtract the per-dimension mean from every
/// sample, returning the mean vector so callers can [`uncenter`] reported
/// centroids afterwards.
///
/// Squared Euclidean distances — and therefore assignments, energies and
/// the whole Lloyd/Anderson iteration — are translation-invariant, so
/// centering never changes the clustering. What it buys is numerical
/// headroom: the norm-decomposed kernel's cancellation error scales with
/// `‖x‖² + ‖c‖²` (see [`crate::linalg::kernel`]), and centering minimizes
/// the sample norms. It is the recommended (and CLI-default) companion of
/// the `f32` sample-storage mode, where the error budget is `f32`-sized.
pub fn center(x: &mut DataMatrix) -> Vec<f64> {
    let (n, d) = (x.n(), x.d());
    let mut mean = vec![0.0f64; d];
    if n == 0 {
        return mean;
    }
    for i in 0..n {
        let row = x.row(i);
        for (m, &v) in mean.iter_mut().zip(row) {
            *m += v;
        }
    }
    let inv = 1.0 / n as f64;
    for m in mean.iter_mut() {
        *m *= inv;
    }
    for i in 0..n {
        let row = x.row_mut(i);
        for (v, &m) in row.iter_mut().zip(&mean) {
            *v -= m;
        }
    }
    mean
}

/// Undo [`center`]: add the per-dimension mean back to every row (used to
/// report centroids in the original coordinate frame).
pub fn uncenter(c: &mut DataMatrix, mean: &[f64]) {
    assert_eq!(c.d(), mean.len(), "mean dimension mismatch");
    for i in 0..c.n() {
        let row = c.row_mut(i);
        for (v, &m) in row.iter_mut().zip(mean) {
            *v += m;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standardize_zero_mean_unit_var() {
        let mut x = DataMatrix::from_vec(vec![1.0, 10.0, 2.0, 20.0, 3.0, 30.0], 3, 2);
        let stats = standardize(&mut x);
        for j in 0..2 {
            let mean: f64 = (0..3).map(|i| x[(i, j)]).sum::<f64>() / 3.0;
            let var: f64 = (0..3).map(|i| x[(i, j)] * x[(i, j)]).sum::<f64>() / 3.0;
            assert!(mean.abs() < 1e-12);
            assert!((var - 1.0).abs() < 1e-12);
            assert!(stats[j].1 > 0.0);
        }
    }

    #[test]
    fn standardize_constant_column() {
        let mut x = DataMatrix::from_vec(vec![5.0, 5.0, 5.0], 3, 1);
        standardize(&mut x);
        for i in 0..3 {
            assert_eq!(x[(i, 0)], 0.0);
        }
    }

    #[test]
    fn center_uncenter_round_trip() {
        let orig = DataMatrix::from_vec(vec![1.0, 10.0, 3.0, 30.0, 5.0, 20.0], 3, 2);
        let mut x = orig.clone();
        let mean = center(&mut x);
        assert!((mean[0] - 3.0).abs() < 1e-12);
        assert!((mean[1] - 20.0).abs() < 1e-12);
        for j in 0..2 {
            let col: f64 = (0..3).map(|i| x[(i, j)]).sum();
            assert!(col.abs() < 1e-12, "column {j} not centered");
        }
        uncenter(&mut x, &mean);
        for i in 0..3 {
            for j in 0..2 {
                assert!((x[(i, j)] - orig[(i, j)]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn center_preserves_pairwise_distances() {
        let a = DataMatrix::from_rows(&[&[100.0, -7.0], &[103.0, -3.0], &[90.0, 2.0]]);
        let mut b = a.clone();
        center(&mut b);
        for i in 0..a.n() {
            for j in 0..a.n() {
                let da = crate::linalg::dist_sq(a.row(i), a.row(j));
                let db = crate::linalg::dist_sq(b.row(i), b.row(j));
                assert!((da - db).abs() < 1e-9, "pair ({i},{j}): {da} vs {db}");
            }
        }
    }

    #[test]
    fn center_empty_matrix_is_noop() {
        let mut x = DataMatrix::zeros(0, 3);
        let mean = center(&mut x);
        assert_eq!(mean, vec![0.0; 3]);
    }
}
