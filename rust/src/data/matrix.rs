//! Row-major `f64` sample matrix — the core container for datasets and
//! centroid sets alike (a centroid set is just a `K×d` matrix).

/// Row-major matrix of `n` samples × `d` features.
#[derive(Debug, Clone, PartialEq)]
pub struct DataMatrix {
    data: Vec<f64>,
    n: usize,
    d: usize,
}

impl DataMatrix {
    /// Zero-filled `n × d` matrix.
    pub fn zeros(n: usize, d: usize) -> Self {
        Self { data: vec![0.0; n * d], n, d }
    }

    /// Take ownership of a row-major buffer.
    pub fn from_vec(data: Vec<f64>, n: usize, d: usize) -> Self {
        assert_eq!(data.len(), n * d, "buffer is {} not {}×{}", data.len(), n, d);
        Self { data, n, d }
    }

    /// Build from row slices.
    pub fn from_rows(rows: &[&[f64]]) -> Self {
        assert!(!rows.is_empty());
        let d = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * d);
        for r in rows {
            assert_eq!(r.len(), d, "ragged rows");
            data.extend_from_slice(r);
        }
        Self { data, n: rows.len(), d }
    }

    /// Number of samples (rows).
    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Dimensionality (columns).
    #[inline]
    pub fn d(&self) -> usize {
        self.d
    }

    /// Borrow row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.d..(i + 1) * self.d]
    }

    /// Mutably borrow row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.d..(i + 1) * self.d]
    }

    /// Whole backing buffer (row-major).
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Mutable backing buffer (row-major).
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Consume into the backing buffer.
    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }

    /// Copy the given rows into a new matrix (used for seeding from sample
    /// indices and for sub-sampling).
    pub fn gather_rows(&self, indices: &[usize]) -> DataMatrix {
        let mut out = DataMatrix::zeros(indices.len(), self.d);
        for (dst, &src) in indices.iter().enumerate() {
            out.row_mut(dst).copy_from_slice(self.row(src));
        }
        out
    }

    /// Append all rows of `other` (must have the same `d`).
    pub fn append(&mut self, other: &DataMatrix) {
        assert_eq!(self.d, other.d);
        self.data.extend_from_slice(&other.data);
        self.n += other.n;
    }

    /// Per-dimension bounding box `(min, max)` of all samples.
    pub fn bounds(&self) -> Vec<(f64, f64)> {
        let mut b = vec![(f64::INFINITY, f64::NEG_INFINITY); self.d];
        for i in 0..self.n {
            let r = self.row(i);
            for j in 0..self.d {
                if r[j] < b[j].0 {
                    b[j].0 = r[j];
                }
                if r[j] > b[j].1 {
                    b[j].1 = r[j];
                }
            }
        }
        b
    }

    /// Convert to `f32` (row-major) — the PJRT artifacts run in `f32`.
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&v| v as f32).collect()
    }

    /// Frobenius-norm distance to another same-shape matrix.
    pub fn frob_dist(&self, other: &DataMatrix) -> f64 {
        assert_eq!(self.n, other.n);
        assert_eq!(self.d, other.d);
        crate::linalg::dist_sq(&self.data, &other.data).sqrt()
    }
}

impl std::ops::Index<(usize, usize)> for DataMatrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.n && j < self.d);
        &self.data[i * self.d + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for DataMatrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.n && j < self.d);
        &mut self.data[i * self.d + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_rows() {
        let m = DataMatrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0], &[5.0, 6.0]]);
        assert_eq!(m.n(), 3);
        assert_eq!(m.d(), 2);
        assert_eq!(m.row(1), &[3.0, 4.0]);
        assert_eq!(m[(2, 1)], 6.0);
    }

    #[test]
    fn gather_rows_selects() {
        let m = DataMatrix::from_rows(&[&[0.0], &[1.0], &[2.0], &[3.0]]);
        let g = m.gather_rows(&[3, 0, 3]);
        assert_eq!(g.as_slice(), &[3.0, 0.0, 3.0]);
    }

    #[test]
    fn bounds_cover_extremes() {
        let m = DataMatrix::from_rows(&[&[-1.0, 5.0], &[2.0, -3.0]]);
        assert_eq!(m.bounds(), vec![(-1.0, 2.0), (-3.0, 5.0)]);
    }

    #[test]
    fn append_grows() {
        let mut a = DataMatrix::from_rows(&[&[1.0], &[2.0]]);
        let b = DataMatrix::from_rows(&[&[3.0]]);
        a.append(&b);
        assert_eq!(a.n(), 3);
        assert_eq!(a.row(2), &[3.0]);
    }

    #[test]
    #[should_panic(expected = "buffer is")]
    fn from_vec_shape_mismatch_panics() {
        DataMatrix::from_vec(vec![1.0, 2.0, 3.0], 2, 2);
    }

    #[test]
    fn frob_dist_zero_for_identical() {
        let a = DataMatrix::from_rows(&[&[1.0, 2.0]]);
        assert_eq!(a.frob_dist(&a.clone()), 0.0);
    }
}
