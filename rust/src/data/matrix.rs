//! Row-major `f64` sample matrix — the core container for datasets and
//! centroid sets alike (a centroid set is just a `K×d` matrix).

use std::sync::atomic::{AtomicU64, Ordering};

/// Global identity counter: every matrix *construction* (including clones)
/// draws a fresh identity, so `(ident, version, n, d)` uniquely identifies
/// matrix contents — unlike a buffer pointer, an identity is never reused
/// after free/realloc, which is what makes the stamp safe as a norm-cache
/// key (see [`crate::linalg::DistanceKernel`]). Mutations only bump the
/// per-matrix `version` (a plain increment — `&mut self` proves exclusive
/// access), keeping element-wise write loops free of atomic traffic.
static IDENT: AtomicU64 = AtomicU64::new(1);

fn next_ident() -> u64 {
    IDENT.fetch_add(1, Ordering::Relaxed)
}

/// Row-major matrix of `n` samples × `d` features.
#[derive(Debug)]
pub struct DataMatrix {
    data: Vec<f64>,
    n: usize,
    d: usize,
    /// Globally unique construction identity (never copied by `clone`).
    ident: u64,
    /// Mutation count; bumped by every `&mut` accessor.
    version: u64,
}

/// Clones take a fresh identity: two clones that diverge through later
/// mutation must never share a content stamp, which copied
/// `(ident, version)` pairs could.
impl Clone for DataMatrix {
    fn clone(&self) -> Self {
        Self {
            data: self.data.clone(),
            n: self.n,
            d: self.d,
            ident: next_ident(),
            version: 0,
        }
    }
}

/// Equality is by shape and contents; the content stamp is identity
/// metadata and deliberately excluded.
impl PartialEq for DataMatrix {
    fn eq(&self, other: &Self) -> bool {
        self.n == other.n && self.d == other.d && self.data == other.data
    }
}

impl DataMatrix {
    /// Zero-filled `n × d` matrix.
    pub fn zeros(n: usize, d: usize) -> Self {
        Self { data: vec![0.0; n * d], n, d, ident: next_ident(), version: 0 }
    }

    /// Take ownership of a row-major buffer.
    pub fn from_vec(data: Vec<f64>, n: usize, d: usize) -> Self {
        assert_eq!(data.len(), n * d, "buffer is {} not {}×{}", data.len(), n, d);
        Self { data, n, d, ident: next_ident(), version: 0 }
    }

    /// Build from row slices.
    pub fn from_rows(rows: &[&[f64]]) -> Self {
        assert!(!rows.is_empty());
        let d = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * d);
        for r in rows {
            assert_eq!(r.len(), d, "ragged rows");
            data.extend_from_slice(r);
        }
        Self { data, n: rows.len(), d, ident: next_ident(), version: 0 }
    }

    /// Content stamp `(ident, version)`. Two reads returning the same pair
    /// guarantee the contents did not change in between (every mutable
    /// access bumps `version`), and no two differently-built matrices —
    /// including clones that later diverge — ever share a stamp.
    #[inline]
    pub fn generation(&self) -> (u64, u64) {
        (self.ident, self.version)
    }

    /// Number of samples (rows).
    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    /// Dimensionality (columns).
    #[inline]
    pub fn d(&self) -> usize {
        self.d
    }

    /// Borrow row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.d..(i + 1) * self.d]
    }

    /// Mutably borrow row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        self.version += 1;
        &mut self.data[i * self.d..(i + 1) * self.d]
    }

    /// Whole backing buffer (row-major).
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Mutable backing buffer (row-major).
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        self.version += 1;
        &mut self.data
    }

    /// Consume into the backing buffer.
    pub fn into_vec(self) -> Vec<f64> {
        self.data
    }

    /// Copy the given rows into a new matrix (used for seeding from sample
    /// indices and for sub-sampling).
    pub fn gather_rows(&self, indices: &[usize]) -> DataMatrix {
        let mut out = DataMatrix::zeros(indices.len(), self.d);
        for (dst, &src) in indices.iter().enumerate() {
            out.row_mut(dst).copy_from_slice(self.row(src));
        }
        out
    }

    /// Resize to `n` rows (dimensionality unchanged), reusing the backing
    /// allocation; rows added beyond the current count are zero-filled.
    /// This is the chunk-buffer primitive of the streaming layer: one
    /// matrix is refilled chunk after chunk, shrinking for the final
    /// partial chunk without releasing capacity.
    pub fn resize_rows(&mut self, n: usize) {
        self.data.resize(n * self.d, 0.0);
        self.n = n;
        self.version += 1;
    }

    /// Append all rows of `other` (must have the same `d`).
    pub fn append(&mut self, other: &DataMatrix) {
        assert_eq!(self.d, other.d);
        self.data.extend_from_slice(&other.data);
        self.n += other.n;
        self.version += 1;
    }

    /// Per-dimension bounding box `(min, max)` of all samples.
    pub fn bounds(&self) -> Vec<(f64, f64)> {
        let mut b = vec![(f64::INFINITY, f64::NEG_INFINITY); self.d];
        for i in 0..self.n {
            let r = self.row(i);
            for j in 0..self.d {
                if r[j] < b[j].0 {
                    b[j].0 = r[j];
                }
                if r[j] > b[j].1 {
                    b[j].1 = r[j];
                }
            }
        }
        b
    }

    /// Convert to `f32` (row-major). The single `f64→f32` narrowing point
    /// in the crate: both the PJRT padding path and the distance kernel's
    /// f32 sample-storage mirror go through here / [`DataMatrix::write_f32_into`].
    pub fn to_f32(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.data.len()];
        self.write_f32_into(&mut out);
        out
    }

    /// Write the row-major `f32` narrowing of this matrix into `out`
    /// (which must hold exactly `n·d` values). Allocation-free variant of
    /// [`DataMatrix::to_f32`] for callers that own padded or reused buffers.
    pub fn write_f32_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.data.len(), "f32 destination shape mismatch");
        for (o, &v) in out.iter_mut().zip(&self.data) {
            *o = v as f32;
        }
    }

    /// Frobenius-norm distance to another same-shape matrix.
    pub fn frob_dist(&self, other: &DataMatrix) -> f64 {
        assert_eq!(self.n, other.n);
        assert_eq!(self.d, other.d);
        crate::linalg::dist_sq(&self.data, &other.data).sqrt()
    }
}

impl std::ops::Index<(usize, usize)> for DataMatrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.n && j < self.d);
        &self.data[i * self.d + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for DataMatrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.n && j < self.d);
        self.version += 1;
        &mut self.data[i * self.d + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_rows() {
        let m = DataMatrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0], &[5.0, 6.0]]);
        assert_eq!(m.n(), 3);
        assert_eq!(m.d(), 2);
        assert_eq!(m.row(1), &[3.0, 4.0]);
        assert_eq!(m[(2, 1)], 6.0);
    }

    #[test]
    fn gather_rows_selects() {
        let m = DataMatrix::from_rows(&[&[0.0], &[1.0], &[2.0], &[3.0]]);
        let g = m.gather_rows(&[3, 0, 3]);
        assert_eq!(g.as_slice(), &[3.0, 0.0, 3.0]);
    }

    #[test]
    fn bounds_cover_extremes() {
        let m = DataMatrix::from_rows(&[&[-1.0, 5.0], &[2.0, -3.0]]);
        assert_eq!(m.bounds(), vec![(-1.0, 2.0), (-3.0, 5.0)]);
    }

    #[test]
    fn resize_rows_keeps_prefix_and_zero_fills() {
        let mut m = DataMatrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0], &[5.0, 6.0]]);
        let g0 = m.generation();
        m.resize_rows(2);
        assert_eq!((m.n(), m.d()), (2, 2));
        assert_eq!(m.as_slice(), &[1.0, 2.0, 3.0, 4.0]);
        assert_ne!(m.generation(), g0, "resize must bump the content stamp");
        m.resize_rows(3);
        assert_eq!(m.row(2), &[0.0, 0.0], "regrown rows are zero-filled");
    }

    #[test]
    fn append_grows() {
        let mut a = DataMatrix::from_rows(&[&[1.0], &[2.0]]);
        let b = DataMatrix::from_rows(&[&[3.0]]);
        a.append(&b);
        assert_eq!(a.n(), 3);
        assert_eq!(a.row(2), &[3.0]);
    }

    #[test]
    #[should_panic(expected = "buffer is")]
    fn from_vec_shape_mismatch_panics() {
        DataMatrix::from_vec(vec![1.0, 2.0, 3.0], 2, 2);
    }

    #[test]
    fn frob_dist_zero_for_identical() {
        let a = DataMatrix::from_rows(&[&[1.0, 2.0]]);
        assert_eq!(a.frob_dist(&a.clone()), 0.0);
    }

    #[test]
    fn generation_bumps_on_every_mut_accessor() {
        let mut m = DataMatrix::zeros(2, 2);
        let g0 = m.generation();
        m.row_mut(0)[0] = 1.0;
        let g1 = m.generation();
        assert_ne!(g1, g0, "row_mut must bump the stamp");
        m.as_mut_slice()[1] = 2.0;
        let g2 = m.generation();
        assert_ne!(g2, g1, "as_mut_slice must bump the stamp");
        m[(1, 1)] = 3.0;
        let g3 = m.generation();
        assert_ne!(g3, g2, "index_mut must bump the stamp");
        m.append(&DataMatrix::zeros(1, 2));
        assert_ne!(m.generation(), g3, "append must bump the stamp");
        // Read-only access leaves the stamp alone.
        let g4 = m.generation();
        let _ = m.row(0);
        let _ = m.as_slice();
        let _ = m[(0, 0)];
        assert_eq!(m.generation(), g4);
    }

    #[test]
    fn generations_are_unique_across_matrices() {
        // Two freshly built matrices never share a stamp, even with the
        // same shape and contents — the property the norm-cache key needs
        // that a buffer pointer cannot provide after free/realloc.
        let a = DataMatrix::zeros(3, 2);
        let b = DataMatrix::zeros(3, 2);
        assert_ne!(a.generation(), b.generation());
        assert_eq!(a, b, "equality still compares contents only");
    }

    #[test]
    fn diverging_clones_never_share_a_stamp() {
        // A clone takes a fresh identity, so mutating original and clone
        // the same number of times still yields distinct stamps (a copied
        // identity with per-matrix version counters would collide here).
        let mut a = DataMatrix::zeros(2, 2);
        a.row_mut(0)[0] = 1.0;
        let mut b = a.clone();
        assert_ne!(a.generation(), b.generation());
        a.row_mut(0)[0] = 2.0;
        b.row_mut(0)[0] = 3.0;
        assert_ne!(a.generation(), b.generation());
        assert_ne!(a, b);
    }

    #[test]
    fn to_f32_round_trip_accuracy() {
        // Values representable in f32 survive the round trip exactly; the
        // rest stay within half-ULP relative error (~6e-8).
        let exact = DataMatrix::from_rows(&[&[1.0, -2.5, 0.0], &[1024.0, 0.125, -0.75]]);
        for (&w, &v) in exact.to_f32().iter().zip(exact.as_slice()) {
            assert_eq!(w as f64, v);
        }
        let vals: Vec<f64> = (0..64).map(|i| (i as f64 * 0.7130711).sin() * 1e3).collect();
        let m = DataMatrix::from_vec(vals, 8, 8);
        let narrowed = m.to_f32();
        assert_eq!(narrowed.len(), 64);
        for (&w, &v) in narrowed.iter().zip(m.as_slice()) {
            let rel = ((w as f64) - v).abs() / v.abs().max(1e-30);
            assert!(rel < 6.0e-8, "{v} -> {w}: rel err {rel}");
        }
        // write_f32_into is the same conversion, no allocation.
        let mut buf = vec![0.0f32; 64];
        m.write_f32_into(&mut buf);
        assert_eq!(buf, narrowed);
    }

    #[test]
    #[should_panic(expected = "f32 destination shape mismatch")]
    fn write_f32_into_checks_shape() {
        let m = DataMatrix::zeros(2, 2);
        let mut buf = vec![0.0f32; 3];
        m.write_f32_into(&mut buf);
    }
}
