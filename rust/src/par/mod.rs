//! Parallelism substrate — the OpenMP substitute.
//!
//! The paper's C++ implementation parallelizes the assignment loop with
//! OpenMP. The offline crate set has no `rayon`, so this module provides a
//! persistent [`ThreadPool`] with a chunked, work-stealing `parallel_for`
//! over index ranges, plus a `map_reduce` built on top of it.
//!
//! Design: workers park on a condvar; a job dispatch installs a per-lane
//! closure, wakes everyone, participates itself, and returns once the
//! done-counter reaches the worker count. Closures are borrowed from the
//! caller's stack — safe because the call does not return until every
//! worker has finished the job (enforced by the completion latch),
//! mirroring rayon's scoped model. Each lane has a stable id (`0` is the
//! caller, `1..threads` the workers), which `map_reduce` uses to fold into
//! exactly one accumulator per lane — chunks are claimed lock-free from a
//! shared cursor, and the only synchronization is the single per-lane
//! publish at the end, not a lock per chunk.

mod slice;

pub use slice::SyncSliceMut;

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Type-erased job: a closure invoked once per lane with the lane id.
struct Job {
    /// Pointer to the caller's `&(dyn Fn(usize) + Sync)`, type-erased to
    /// `'static`. Valid only while the issuing dispatch is blocked.
    func: *const (dyn Fn(usize) + Sync),
}

// SAFETY: `func` points into the stack frame of the dispatching caller,
// which blocks until the job is fully drained; the pointee is `Sync`.
unsafe impl Send for Job {}

struct Shared {
    state: Mutex<State>,
    work_ready: Condvar,
    work_done: Condvar,
}

struct State {
    /// Current job, if any. Replaced wholesale per dispatch.
    job: Option<Job>,
    /// Monotonic id so sleeping workers can tell a fresh job from a stale one.
    epoch: u64,
    /// Workers still running the current epoch's job.
    active: usize,
    shutdown: bool,
}

/// A persistent pool of worker threads executing chunked index loops.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
}

impl ThreadPool {
    /// Pool with `threads` total lanes (including the caller's). `threads`
    /// is clamped to ≥ 1; `ThreadPool::new(1)` runs everything inline.
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(State { job: None, epoch: 0, active: 0, shutdown: false }),
            work_ready: Condvar::new(),
            work_done: Condvar::new(),
        });
        // The caller participates as lane 0, so spawn threads-1 workers
        // with lane ids 1..threads.
        let workers = (1..threads)
            .map(|lane| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(&shared, lane))
            })
            .collect();
        Self { shared, workers, threads }
    }

    /// Pool sized to the machine (`available_parallelism`).
    pub fn host_sized() -> Self {
        let n = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
        Self::new(n)
    }

    /// Number of lanes (caller + workers).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(lane)` once on every lane (caller = lane 0, workers =
    /// lanes `1..threads`). Blocks until every lane has returned.
    fn dispatch(&self, f: &(dyn Fn(usize) + Sync)) {
        if self.threads == 1 {
            f(0);
            return;
        }
        // SAFETY: see `Job.func` — we block below until the job drains.
        let func: *const (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(f) };
        {
            let mut st = self.shared.state.lock().unwrap();
            debug_assert!(st.job.is_none(), "pool jobs are not reentrant");
            st.job = Some(Job { func });
            st.epoch += 1;
            st.active = self.workers.len();
            self.shared.work_ready.notify_all();
        }
        // The caller participates in the same job as lane 0.
        f(0);
        // Wait until all workers have finished.
        let mut st = self.shared.state.lock().unwrap();
        while st.active > 0 {
            st = self.shared.work_done.wait(st).unwrap();
        }
        st.job = None;
    }

    /// Run `f` over `0..n` in chunks of at least `min_chunk`, in parallel.
    /// Blocks until every chunk has been processed.
    pub fn parallel_for<F>(&self, n: usize, min_chunk: usize, f: F)
    where
        F: Fn(Range<usize>) + Sync,
    {
        if n == 0 {
            return;
        }
        let min_chunk = min_chunk.max(1);
        // Inline when there is nothing to parallelize.
        if self.threads == 1 || n <= min_chunk {
            f(0..n);
            return;
        }
        // Aim for ~4 chunks per lane to smooth imbalance, floor at min_chunk.
        let chunk = (n / (self.threads * 4)).max(min_chunk);
        let cursor = AtomicUsize::new(0);
        self.dispatch(&|_lane| run_chunks(&f, &cursor, n, chunk));
    }

    /// Parallel map-reduce over `0..n`: each lane folds every chunk it
    /// claims into one thread-local accumulator (created lazily from
    /// `init()`), and the per-lane partials — at most `threads` of them,
    /// regardless of chunk count — are combined with `combine` at the end.
    pub fn map_reduce<T, FInit, FFold, FComb>(
        &self,
        n: usize,
        min_chunk: usize,
        init: FInit,
        fold: FFold,
        combine: FComb,
    ) -> T
    where
        T: Send,
        FInit: Fn() -> T + Sync,
        FFold: Fn(&mut T, Range<usize>) + Sync,
        FComb: Fn(T, T) -> T,
    {
        let min_chunk = min_chunk.max(1);
        if self.threads == 1 || n <= min_chunk {
            let mut acc = init();
            if n > 0 {
                fold(&mut acc, 0..n);
            }
            return acc;
        }
        let chunk = (n / (self.threads * 4)).max(min_chunk);
        let cursor = AtomicUsize::new(0);
        // One slot per lane; a lane that claims no chunk publishes nothing.
        let slots: Vec<Mutex<Option<T>>> = (0..self.threads).map(|_| Mutex::new(None)).collect();
        self.dispatch(&|lane| {
            let mut acc: Option<T> = None;
            loop {
                let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                fold(acc.get_or_insert_with(&init), start..(start + chunk).min(n));
            }
            if acc.is_some() {
                *slots[lane].lock().unwrap() = acc;
            }
        });
        let mut partials = slots.into_iter().filter_map(|s| s.into_inner().unwrap());
        let first = partials.next().unwrap_or_else(&init);
        partials.fold(first, &combine)
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work_ready.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: &Shared, lane: usize) {
    let mut last_epoch = 0u64;
    loop {
        let func = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != last_epoch {
                    if let Some(job) = &st.job {
                        last_epoch = st.epoch;
                        break job.func;
                    }
                }
                st = shared.work_ready.wait(st).unwrap();
            }
        };
        // SAFETY: the issuing dispatch blocks until `active` hits zero,
        // keeping the closure alive for the duration of this call.
        let f: &(dyn Fn(usize) + Sync) = unsafe { &*func };
        f(lane);
        let mut st = shared.state.lock().unwrap();
        st.active -= 1;
        if st.active == 0 {
            shared.work_done.notify_all();
        }
    }
}

/// Claim chunks from the shared cursor until the range is exhausted.
fn run_chunks(
    f: &(dyn Fn(Range<usize>) + Sync),
    cursor: &AtomicUsize,
    n: usize,
    chunk: usize,
) {
    loop {
        let start = cursor.fetch_add(chunk, Ordering::Relaxed);
        if start >= n {
            return;
        }
        f(start..(start + chunk).min(n));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn covers_every_index_exactly_once() {
        for threads in [1, 2, 4] {
            let pool = ThreadPool::new(threads);
            let n = 10_007;
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            pool.parallel_for(n, 16, |range| {
                for i in range {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                }
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "threads={threads}: some index not covered exactly once"
            );
        }
    }

    #[test]
    fn zero_length_is_noop() {
        let pool = ThreadPool::new(4);
        pool.parallel_for(0, 1, |_| panic!("must not be called"));
    }

    #[test]
    fn sequential_reuse_of_pool() {
        let pool = ThreadPool::new(3);
        for round in 0..50 {
            let total = AtomicU64::new(0);
            pool.parallel_for(1000, 8, |range| {
                let s: u64 = range.map(|i| i as u64).sum();
                total.fetch_add(s, Ordering::Relaxed);
            });
            assert_eq!(total.load(Ordering::Relaxed), 999 * 1000 / 2, "round {round}");
        }
    }

    #[test]
    fn map_reduce_sums() {
        let pool = ThreadPool::new(4);
        let sum = pool.map_reduce(
            100_000,
            64,
            || 0u64,
            |acc, range| *acc += range.map(|i| i as u64).sum::<u64>(),
            |a, b| a + b,
        );
        assert_eq!(sum, 99_999u64 * 100_000 / 2);
    }

    #[test]
    fn map_reduce_empty_returns_init() {
        let pool = ThreadPool::new(2);
        let v = pool.map_reduce(0, 1, || 42u32, |_, _| panic!(), |a, _| a);
        assert_eq!(v, 42);
    }

    #[test]
    fn map_reduce_combines_lane_count_partials() {
        // The per-lane fold must create at most one accumulator per lane —
        // not one per chunk — no matter how many chunks the job splits into.
        let threads = 4;
        let pool = ThreadPool::new(threads);
        let n = 100_000;
        let inits = AtomicUsize::new(0);
        let combines = AtomicUsize::new(0);
        // min_chunk 8 → chunk = n / (threads*4) = 6250 → 16 chunks > lanes.
        let sum = pool.map_reduce(
            n,
            8,
            || {
                inits.fetch_add(1, Ordering::Relaxed);
                0u64
            },
            |acc, range| *acc += range.map(|i| i as u64).sum::<u64>(),
            |a, b| {
                combines.fetch_add(1, Ordering::Relaxed);
                a + b
            },
        );
        assert_eq!(sum, (n as u64 - 1) * n as u64 / 2);
        let inits = inits.load(Ordering::Relaxed);
        let combines = combines.load(Ordering::Relaxed);
        assert!(inits <= threads, "{inits} accumulators for {threads} lanes");
        assert!(combines < threads, "{combines} combines for {threads} lanes");
        assert!(inits >= 1 && combines == inits - 1);
    }

    #[test]
    fn single_thread_pool_runs_inline() {
        let pool = ThreadPool::new(1);
        assert_eq!(pool.threads(), 1);
        let caller = std::thread::current().id();
        pool.parallel_for(100, 1, |_range| {
            assert_eq!(std::thread::current().id(), caller);
        });
    }
}
