//! Parallelism substrate — the OpenMP substitute.
//!
//! The paper's C++ implementation parallelizes the assignment loop with
//! OpenMP. The offline crate set has no `rayon`, so this module provides a
//! persistent [`ThreadPool`] with a chunked, work-stealing `parallel_for`
//! over index ranges, plus a `map_reduce` built on top of it.
//!
//! Design: workers park on a condvar; a `parallel_for` call installs a job
//! (closure + atomic chunk cursor), wakes everyone, participates itself,
//! and returns once the done-counter reaches the worker count. Closures are
//! borrowed from the caller's stack — safe because the call does not return
//! until every worker has finished the job (enforced by the completion
//! latch), mirroring rayon's scoped model.

mod slice;

pub use slice::SyncSliceMut;

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Type-erased job: a closure over an index range plus its chunk cursor.
struct Job {
    /// Pointer to the caller's `&(dyn Fn(Range<usize>) + Sync)`, type-erased
    /// to `'static`. Valid only while the issuing `parallel_for` is blocked.
    func: *const (dyn Fn(Range<usize>) + Sync),
    cursor: Arc<AtomicUsize>,
    n: usize,
    chunk: usize,
}

// SAFETY: `func` points into the stack frame of the `parallel_for` caller,
// which blocks until the job is fully drained; the pointee is `Sync`.
unsafe impl Send for Job {}

struct Shared {
    state: Mutex<State>,
    work_ready: Condvar,
    work_done: Condvar,
}

struct State {
    /// Current job, if any. Replaced wholesale per `parallel_for`.
    job: Option<Job>,
    /// Monotonic id so sleeping workers can tell a fresh job from a stale one.
    epoch: u64,
    /// Workers still running the current epoch's job.
    active: usize,
    shutdown: bool,
}

/// A persistent pool of worker threads executing chunked index loops.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
}

impl ThreadPool {
    /// Pool with `threads` total lanes (including the caller's). `threads`
    /// is clamped to ≥ 1; `ThreadPool::new(1)` runs everything inline.
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(State { job: None, epoch: 0, active: 0, shutdown: false }),
            work_ready: Condvar::new(),
            work_done: Condvar::new(),
        });
        // The caller participates, so spawn threads-1 workers.
        let workers = (1..threads)
            .map(|_| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(&shared))
            })
            .collect();
        Self { shared, workers, threads }
    }

    /// Pool sized to the machine (`available_parallelism`).
    pub fn host_sized() -> Self {
        let n = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
        Self::new(n)
    }

    /// Number of lanes (caller + workers).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f` over `0..n` in chunks of at least `min_chunk`, in parallel.
    /// Blocks until every chunk has been processed.
    pub fn parallel_for<F>(&self, n: usize, min_chunk: usize, f: F)
    where
        F: Fn(Range<usize>) + Sync,
    {
        if n == 0 {
            return;
        }
        let min_chunk = min_chunk.max(1);
        // Inline when there is nothing to parallelize.
        if self.threads == 1 || n <= min_chunk {
            f(0..n);
            return;
        }
        // Aim for ~4 chunks per lane to smooth imbalance, floor at min_chunk.
        let chunk = (n / (self.threads * 4)).max(min_chunk);
        let cursor = Arc::new(AtomicUsize::new(0));
        let f_ref: &(dyn Fn(Range<usize>) + Sync) = &f;
        // SAFETY: see `Job.func` — we block below until the job drains.
        let func: *const (dyn Fn(Range<usize>) + Sync) =
            unsafe { std::mem::transmute(f_ref) };
        {
            let mut st = self.shared.state.lock().unwrap();
            debug_assert!(st.job.is_none(), "parallel_for is not reentrant");
            st.job = Some(Job { func, cursor: Arc::clone(&cursor), n, chunk });
            st.epoch += 1;
            st.active = self.workers.len();
            self.shared.work_ready.notify_all();
        }
        // The caller participates in the same job.
        run_chunks(&f, &cursor, n, chunk);
        // Wait until all workers have finished their last chunk.
        let mut st = self.shared.state.lock().unwrap();
        while st.active > 0 {
            st = self.shared.work_done.wait(st).unwrap();
        }
        st.job = None;
    }

    /// Parallel map-reduce over `0..n`: each lane folds its chunks with
    /// `fold`, starting from `init()`; partials are combined with `combine`.
    pub fn map_reduce<T, FInit, FFold, FComb>(
        &self,
        n: usize,
        min_chunk: usize,
        init: FInit,
        fold: FFold,
        combine: FComb,
    ) -> T
    where
        T: Send,
        FInit: Fn() -> T + Sync,
        FFold: Fn(&mut T, Range<usize>) + Sync,
        FComb: Fn(T, T) -> T,
    {
        let partials = Mutex::new(Vec::<T>::new());
        self.parallel_for(n, min_chunk, |range| {
            // One partial per chunk; cheap relative to chunk work.
            let mut acc = init();
            fold(&mut acc, range);
            partials.lock().unwrap().push(acc);
        });
        let partials = partials.into_inner().unwrap();
        let mut it = partials.into_iter();
        let first = it.next().unwrap_or_else(&init);
        it.fold(first, &combine)
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work_ready.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: &Shared) {
    let mut last_epoch = 0u64;
    loop {
        let (func, cursor, n, chunk) = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != last_epoch {
                    if let Some(job) = &st.job {
                        last_epoch = st.epoch;
                        break (job.func, Arc::clone(&job.cursor), job.n, job.chunk);
                    }
                }
                st = shared.work_ready.wait(st).unwrap();
            }
        };
        // SAFETY: the issuing parallel_for blocks until `active` hits zero,
        // keeping the closure alive for the duration of this call.
        let f: &(dyn Fn(Range<usize>) + Sync) = unsafe { &*func };
        run_chunks(f, &cursor, n, chunk);
        let mut st = shared.state.lock().unwrap();
        st.active -= 1;
        if st.active == 0 {
            shared.work_done.notify_all();
        }
    }
}

/// Claim chunks from the shared cursor until the range is exhausted.
fn run_chunks(f: &(dyn Fn(Range<usize>) + Sync), cursor: &AtomicUsize, n: usize, chunk: usize) {
    loop {
        let start = cursor.fetch_add(chunk, Ordering::Relaxed);
        if start >= n {
            return;
        }
        f(start..(start + chunk).min(n));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn covers_every_index_exactly_once() {
        for threads in [1, 2, 4] {
            let pool = ThreadPool::new(threads);
            let n = 10_007;
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            pool.parallel_for(n, 16, |range| {
                for i in range {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                }
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "threads={threads}: some index not covered exactly once"
            );
        }
    }

    #[test]
    fn zero_length_is_noop() {
        let pool = ThreadPool::new(4);
        pool.parallel_for(0, 1, |_| panic!("must not be called"));
    }

    #[test]
    fn sequential_reuse_of_pool() {
        let pool = ThreadPool::new(3);
        for round in 0..50 {
            let total = AtomicU64::new(0);
            pool.parallel_for(1000, 8, |range| {
                let s: u64 = range.map(|i| i as u64).sum();
                total.fetch_add(s, Ordering::Relaxed);
            });
            assert_eq!(total.load(Ordering::Relaxed), 999 * 1000 / 2, "round {round}");
        }
    }

    #[test]
    fn map_reduce_sums() {
        let pool = ThreadPool::new(4);
        let sum = pool.map_reduce(
            100_000,
            64,
            || 0u64,
            |acc, range| *acc += range.map(|i| i as u64).sum::<u64>(),
            |a, b| a + b,
        );
        assert_eq!(sum, 99_999u64 * 100_000 / 2);
    }

    #[test]
    fn map_reduce_empty_returns_init() {
        let pool = ThreadPool::new(2);
        let v = pool.map_reduce(0, 1, || 42u32, |_, _| panic!(), |a, _| a);
        assert_eq!(v, 42);
    }

    #[test]
    fn single_thread_pool_runs_inline() {
        let pool = ThreadPool::new(1);
        assert_eq!(pool.threads(), 1);
        let caller = std::thread::current().id();
        pool.parallel_for(100, 1, |_range| {
            assert_eq!(std::thread::current().id(), caller);
        });
    }
}
