//! Parallelism substrate — the OpenMP substitute.
//!
//! The paper's C++ implementation parallelizes the assignment loop with
//! OpenMP. The offline crate set has no `rayon`, so this module provides a
//! persistent [`ThreadPool`] with a chunked, work-stealing `parallel_for`
//! over index ranges, plus a `map_reduce` built on top of it.
//!
//! Design: workers park on a condvar; a job dispatch installs a per-lane
//! closure, wakes everyone, participates itself, and returns once the
//! done-counter reaches the worker count. Closures are borrowed from the
//! caller's stack — safe because the call does not return until every
//! worker has finished the job (enforced by the completion latch),
//! mirroring rayon's scoped model. Each lane has a stable id (`0` is the
//! caller, `1..threads` the workers), which `map_reduce` uses to fold into
//! exactly one accumulator per lane — chunks are claimed lock-free from a
//! shared cursor, and the only synchronization is the single per-lane
//! publish at the end, not a lock per chunk.

mod slice;

pub use slice::SyncSliceMut;

use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Widest CPU id representable in the affinity mask handed to the kernel
/// (16 × 64 bits — matches glibc's default `cpu_set_t`).
const MAX_PIN_CPUS: usize = 16 * 64;

/// Pin the calling thread to `cpu` (taken modulo [`MAX_PIN_CPUS`]).
///
/// Linux only — a raw `sched_setaffinity(0, …)` on the calling thread,
/// bound here like the `mmap` binding in `data/chunks.rs` because the
/// offline crate set has no `libc`. Everywhere else this is a no-op, and
/// failures are deliberately ignored: affinity is a placement hint, never
/// correctness — a restricted cpuset (containers) simply leaves the
/// thread where the scheduler put it.
#[cfg(target_os = "linux")]
pub fn pin_current_thread(cpu: usize) {
    unsafe extern "C" {
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    }
    let mut mask = [0u64; MAX_PIN_CPUS / 64];
    let cpu = cpu % MAX_PIN_CPUS;
    mask[cpu / 64] = 1u64 << (cpu % 64);
    // SAFETY: pid 0 targets the calling thread; the mask outlives the call.
    unsafe {
        let _ = sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr());
    }
}

/// Non-Linux stub: thread affinity is not portable; stay a no-op.
#[cfg(not(target_os = "linux"))]
pub fn pin_current_thread(_cpu: usize) {}

/// Type-erased job: a closure invoked once per lane with the lane id.
struct Job {
    /// Pointer to the caller's `&(dyn Fn(usize) + Sync)`, type-erased to
    /// `'static`. Valid only while the issuing dispatch is blocked.
    func: *const (dyn Fn(usize) + Sync),
}

// SAFETY: `func` points into the stack frame of the dispatching caller,
// which blocks until the job is fully drained; the pointee is `Sync`.
unsafe impl Send for Job {}

struct Shared {
    state: Mutex<State>,
    work_ready: Condvar,
    work_done: Condvar,
}

struct State {
    /// Current job, if any. Replaced wholesale per dispatch.
    job: Option<Job>,
    /// Monotonic id so sleeping workers can tell a fresh job from a stale one.
    epoch: u64,
    /// Workers still running the current epoch's job.
    active: usize,
    shutdown: bool,
}

/// A persistent pool of worker threads executing chunked index loops.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
    /// Whether `pin_lanes` already ran (it is idempotent per pool).
    pinned: AtomicBool,
}

impl ThreadPool {
    /// Pool with `threads` total lanes (including the caller's). `threads`
    /// is clamped to ≥ 1; `ThreadPool::new(1)` runs everything inline.
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(State { job: None, epoch: 0, active: 0, shutdown: false }),
            work_ready: Condvar::new(),
            work_done: Condvar::new(),
        });
        // The caller participates as lane 0, so spawn threads-1 workers
        // with lane ids 1..threads.
        let workers = (1..threads)
            .map(|lane| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || worker_loop(&shared, lane))
            })
            .collect();
        Self { shared, workers, threads, pinned: AtomicBool::new(false) }
    }

    /// Pin every *worker* lane to a fixed CPU (`lane % cores`) so the
    /// sweep lanes stop migrating across cores mid-run — Linux only, a
    /// no-op elsewhere (see [`pin_current_thread`]). Lane 0 is the
    /// caller's thread and is never pinned: the pool does not own it, and
    /// hijacking the embedder's affinity would leak policy outward.
    /// Idempotent per pool, and placement-only — pinning can never change
    /// a result bit.
    pub fn pin_lanes(&self) {
        if self.threads == 1 || self.pinned.swap(true, Ordering::Relaxed) {
            return;
        }
        let cores = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
        self.dispatch(&move |lane| {
            if lane > 0 {
                pin_current_thread(lane % cores);
            }
        });
    }

    /// Pool sized to the machine (`available_parallelism`).
    pub fn host_sized() -> Self {
        let n = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
        Self::new(n)
    }

    /// Number of lanes (caller + workers).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(lane)` once on every lane (caller = lane 0, workers =
    /// lanes `1..threads`). Blocks until every lane has returned.
    fn dispatch(&self, f: &(dyn Fn(usize) + Sync)) {
        if self.threads == 1 {
            f(0);
            return;
        }
        // SAFETY: see `Job.func` — we block below until the job drains.
        let func: *const (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(f) };
        {
            let mut st = self.shared.state.lock().unwrap();
            debug_assert!(st.job.is_none(), "pool jobs are not reentrant");
            st.job = Some(Job { func });
            st.epoch += 1;
            st.active = self.workers.len();
            self.shared.work_ready.notify_all();
        }
        // The caller participates in the same job as lane 0.
        f(0);
        // Wait until all workers have finished.
        let mut st = self.shared.state.lock().unwrap();
        while st.active > 0 {
            st = self.shared.work_done.wait(st).unwrap();
        }
        st.job = None;
    }

    /// Run `f` over `0..n` in chunks of at least `min_chunk`, in parallel.
    /// Blocks until every chunk has been processed.
    pub fn parallel_for<F>(&self, n: usize, min_chunk: usize, f: F)
    where
        F: Fn(Range<usize>) + Sync,
    {
        if n == 0 {
            return;
        }
        let min_chunk = min_chunk.max(1);
        // Inline when there is nothing to parallelize.
        if self.threads == 1 || n <= min_chunk {
            f(0..n);
            return;
        }
        // Aim for ~4 chunks per lane to smooth imbalance, floor at min_chunk.
        let chunk = (n / (self.threads * 4)).max(min_chunk);
        let cursor = AtomicUsize::new(0);
        self.dispatch(&|_lane| run_chunks(&f, &cursor, n, chunk));
    }

    /// Parallel map-reduce over `0..n`: each lane folds every chunk it
    /// claims into one thread-local accumulator (created lazily from
    /// `init()`), and the per-lane partials — at most `threads` of them,
    /// regardless of chunk count — are combined with `combine` at the end.
    ///
    /// Allocating accumulators (e.g. per-cluster sum vectors) are rebuilt
    /// by `init()` on every call; hot loops that run a reduce per iteration
    /// should use [`ThreadPool::map_reduce_with`], which keeps the per-lane
    /// accumulators alive in a caller-owned [`LaneScratch`].
    pub fn map_reduce<T, FInit, FFold, FComb>(
        &self,
        n: usize,
        min_chunk: usize,
        init: FInit,
        fold: FFold,
        combine: FComb,
    ) -> T
    where
        T: Send,
        FInit: Fn() -> T + Sync,
        FFold: Fn(&mut T, Range<usize>) + Sync,
        FComb: Fn(T, T) -> T,
    {
        let min_chunk = min_chunk.max(1);
        if self.threads == 1 || n <= min_chunk {
            let mut acc = init();
            if n > 0 {
                fold(&mut acc, 0..n);
            }
            return acc;
        }
        let chunk = (n / (self.threads * 4)).max(min_chunk);
        let cursor = AtomicUsize::new(0);
        // One slot per lane; a lane that claims no chunk publishes nothing.
        let slots: Vec<Mutex<Option<T>>> = (0..self.threads).map(|_| Mutex::new(None)).collect();
        self.dispatch(&|lane| {
            let mut acc: Option<T> = None;
            loop {
                let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                fold(acc.get_or_insert_with(&init), start..(start + chunk).min(n));
            }
            if acc.is_some() {
                *slots[lane].lock().unwrap() = acc;
            }
        });
        let mut partials = slots.into_iter().filter_map(|s| s.into_inner().unwrap());
        let first = partials.next().unwrap_or_else(&init);
        partials.fold(first, &combine)
    }

    /// [`ThreadPool::map_reduce`] with caller-owned per-lane accumulators:
    /// the lane that claims a chunk takes the accumulator slot matching its
    /// lane id from `scratch` — `init()` builds it on first use, `reset`
    /// clears it on reuse — so a reduce that runs once per solver iteration
    /// touches the allocator only on its very first call. The per-lane
    /// partials are merged in place with `combine(dst, src)` and the merged
    /// accumulator is handed to `finish`, whose return value is the call's
    /// result (copy scalars out / write into caller buffers there; the
    /// accumulator itself stays in `scratch` for the next call).
    #[allow(clippy::too_many_arguments)]
    pub fn map_reduce_with<T, R, FInit, FReset, FFold, FComb, FFinish>(
        &self,
        scratch: &mut LaneScratch<T>,
        n: usize,
        min_chunk: usize,
        init: FInit,
        reset: FReset,
        fold: FFold,
        combine: FComb,
        finish: FFinish,
    ) -> R
    where
        T: Send,
        FInit: Fn() -> T + Sync,
        FReset: Fn(&mut T) + Sync,
        FFold: Fn(&mut T, Range<usize>) + Sync,
        FComb: Fn(&mut T, &T),
        FFinish: FnOnce(&mut T) -> R,
    {
        let min_chunk = min_chunk.max(1);
        if scratch.slots.len() < self.threads {
            scratch.slots.resize_with(self.threads, || None);
            scratch.touched.resize(self.threads, false);
        }
        for t in scratch.touched.iter_mut() {
            *t = false;
        }
        // Inline path: everything folds into lane 0's slot.
        if self.threads == 1 || n <= min_chunk {
            let slot = &mut scratch.slots[0];
            match slot {
                Some(acc) => reset(acc),
                None => *slot = Some(init()),
            }
            let acc = slot.as_mut().expect("slot 0 was just filled");
            if n > 0 {
                fold(acc, 0..n);
            }
            return finish(acc);
        }
        let chunk = (n / (self.threads * 4)).max(min_chunk);
        let cursor = AtomicUsize::new(0);
        {
            // SAFETY contract of SyncSliceMut: each lane touches only its
            // own slot index, so the writes are disjoint by construction.
            let slots = SyncSliceMut::new(&mut scratch.slots);
            let touched = SyncSliceMut::new(&mut scratch.touched);
            self.dispatch(&|lane| {
                let mut claimed = false;
                loop {
                    let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                    if start >= n {
                        break;
                    }
                    if !claimed {
                        claimed = true;
                        *touched.at(lane) = true;
                        let slot = slots.at(lane);
                        match slot {
                            Some(acc) => reset(acc),
                            None => *slot = Some(init()),
                        }
                    }
                    let acc = slots.at(lane).as_mut().expect("claimed lane has an accumulator");
                    fold(acc, start..(start + chunk).min(n));
                }
            });
        }
        // Serial in-place merge into the first touched lane's accumulator.
        let mut result_lane = None;
        for lane in 0..self.threads {
            if !scratch.touched[lane] {
                continue;
            }
            match result_lane {
                None => result_lane = Some(lane),
                Some(dst) => {
                    let (left, right) = scratch.slots.split_at_mut(lane);
                    let dst_acc = left[dst].as_mut().expect("touched lane has an accumulator");
                    let src_acc = right[0].as_ref().expect("touched lane has an accumulator");
                    combine(dst_acc, src_acc);
                }
            }
        }
        let lane = match result_lane {
            Some(lane) => lane,
            // n > 0 and chunk claims cover 0..n, so some lane always claims
            // work; this arm only defends against future refactors.
            None => {
                let slot = &mut scratch.slots[0];
                match slot {
                    Some(acc) => reset(acc),
                    None => *slot = Some(init()),
                }
                0
            }
        };
        finish(scratch.slots[lane].as_mut().expect("result lane has an accumulator"))
    }
}

/// Caller-owned per-lane accumulator slots for
/// [`ThreadPool::map_reduce_with`]. One scratch serves one accumulator
/// type; keep it alive (e.g. in a solver workspace) across calls so warm
/// iterations reuse the lane accumulators instead of reallocating them.
pub struct LaneScratch<T> {
    /// One slot per lane; `None` until that lane first claims work.
    slots: Vec<Option<T>>,
    /// Which lanes claimed work during the current call.
    touched: Vec<bool>,
}

impl<T> LaneScratch<T> {
    /// Empty scratch; slots are sized lazily to the pool that uses it.
    pub fn new() -> Self {
        Self { slots: Vec::new(), touched: Vec::new() }
    }
}

impl<T> Default for LaneScratch<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work_ready.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: &Shared, lane: usize) {
    let mut last_epoch = 0u64;
    loop {
        let func = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != last_epoch {
                    if let Some(job) = &st.job {
                        last_epoch = st.epoch;
                        break job.func;
                    }
                }
                st = shared.work_ready.wait(st).unwrap();
            }
        };
        // SAFETY: the issuing dispatch blocks until `active` hits zero,
        // keeping the closure alive for the duration of this call.
        let f: &(dyn Fn(usize) + Sync) = unsafe { &*func };
        f(lane);
        let mut st = shared.state.lock().unwrap();
        st.active -= 1;
        if st.active == 0 {
            shared.work_done.notify_all();
        }
    }
}

/// Claim chunks from the shared cursor until the range is exhausted.
fn run_chunks(
    f: &(dyn Fn(Range<usize>) + Sync),
    cursor: &AtomicUsize,
    n: usize,
    chunk: usize,
) {
    loop {
        let start = cursor.fetch_add(chunk, Ordering::Relaxed);
        if start >= n {
            return;
        }
        f(start..(start + chunk).min(n));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn covers_every_index_exactly_once() {
        for threads in [1, 2, 4] {
            let pool = ThreadPool::new(threads);
            let n = 10_007;
            let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            pool.parallel_for(n, 16, |range| {
                for i in range {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                }
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "threads={threads}: some index not covered exactly once"
            );
        }
    }

    #[test]
    fn zero_length_is_noop() {
        let pool = ThreadPool::new(4);
        pool.parallel_for(0, 1, |_| panic!("must not be called"));
    }

    #[test]
    fn sequential_reuse_of_pool() {
        let pool = ThreadPool::new(3);
        for round in 0..50 {
            let total = AtomicU64::new(0);
            pool.parallel_for(1000, 8, |range| {
                let s: u64 = range.map(|i| i as u64).sum();
                total.fetch_add(s, Ordering::Relaxed);
            });
            assert_eq!(total.load(Ordering::Relaxed), 999 * 1000 / 2, "round {round}");
        }
    }

    #[test]
    fn map_reduce_sums() {
        let pool = ThreadPool::new(4);
        let sum = pool.map_reduce(
            100_000,
            64,
            || 0u64,
            |acc, range| *acc += range.map(|i| i as u64).sum::<u64>(),
            |a, b| a + b,
        );
        assert_eq!(sum, 99_999u64 * 100_000 / 2);
    }

    #[test]
    fn map_reduce_empty_returns_init() {
        let pool = ThreadPool::new(2);
        let v = pool.map_reduce(0, 1, || 42u32, |_, _| panic!(), |a, _| a);
        assert_eq!(v, 42);
    }

    #[test]
    fn map_reduce_combines_lane_count_partials() {
        // The per-lane fold must create at most one accumulator per lane —
        // not one per chunk — no matter how many chunks the job splits into.
        let threads = 4;
        let pool = ThreadPool::new(threads);
        let n = 100_000;
        let inits = AtomicUsize::new(0);
        let combines = AtomicUsize::new(0);
        // min_chunk 8 → chunk = n / (threads*4) = 6250 → 16 chunks > lanes.
        let sum = pool.map_reduce(
            n,
            8,
            || {
                inits.fetch_add(1, Ordering::Relaxed);
                0u64
            },
            |acc, range| *acc += range.map(|i| i as u64).sum::<u64>(),
            |a, b| {
                combines.fetch_add(1, Ordering::Relaxed);
                a + b
            },
        );
        assert_eq!(sum, (n as u64 - 1) * n as u64 / 2);
        let inits = inits.load(Ordering::Relaxed);
        let combines = combines.load(Ordering::Relaxed);
        assert!(inits <= threads, "{inits} accumulators for {threads} lanes");
        assert!(combines < threads, "{combines} combines for {threads} lanes");
        assert!(inits >= 1 && combines == inits - 1);
    }

    #[test]
    fn map_reduce_with_matches_map_reduce() {
        for threads in [1, 2, 4] {
            let pool = ThreadPool::new(threads);
            let mut scratch = LaneScratch::new();
            let n = 50_000;
            let expect: u64 = (n as u64 - 1) * n as u64 / 2;
            for round in 0..3 {
                let sum = pool.map_reduce_with(
                    &mut scratch,
                    n,
                    64,
                    || vec![0u64; 1],
                    |acc| acc[0] = 0,
                    |acc, range| acc[0] += range.map(|i| i as u64).sum::<u64>(),
                    |a, b| a[0] += b[0],
                    |acc| acc[0],
                );
                assert_eq!(sum, expect, "threads={threads} round={round}");
            }
        }
    }

    #[test]
    fn map_reduce_with_reuses_lane_accumulators() {
        // After a warm-up call, further same-shape calls must never invoke
        // `init` again — the lane accumulators live in the scratch.
        let pool = ThreadPool::new(4);
        let mut scratch = LaneScratch::new();
        let inits = AtomicUsize::new(0);
        for _ in 0..5 {
            let _ = pool.map_reduce_with(
                &mut scratch,
                10_000,
                8,
                || {
                    inits.fetch_add(1, Ordering::Relaxed);
                    0u64
                },
                |acc| *acc = 0,
                |acc, range| *acc += range.len() as u64,
                |a, b| *a += *b,
                |acc| *acc,
            );
        }
        assert!(
            inits.load(Ordering::Relaxed) <= 4,
            "init ran {} times for a 4-lane pool across 5 calls",
            inits.load(Ordering::Relaxed)
        );
    }

    #[test]
    fn map_reduce_with_empty_input_returns_reset_accumulator() {
        let pool = ThreadPool::new(2);
        let mut scratch = LaneScratch::new();
        let v = pool.map_reduce_with(
            &mut scratch,
            0,
            1,
            || 7u32,
            |acc| *acc = 7,
            |_, _| panic!("no chunks on empty input"),
            |_, _| panic!("nothing to combine"),
            |acc| *acc,
        );
        assert_eq!(v, 7);
    }

    #[test]
    fn pinned_pool_still_computes_correctly_and_is_idempotent() {
        for threads in [1, 3] {
            let pool = ThreadPool::new(threads);
            pool.pin_lanes();
            pool.pin_lanes(); // second call must be a no-op, not a deadlock
            let total = AtomicU64::new(0);
            pool.parallel_for(1000, 8, |range| {
                let s: u64 = range.map(|i| i as u64).sum();
                total.fetch_add(s, Ordering::Relaxed);
            });
            assert_eq!(total.load(Ordering::Relaxed), 999 * 1000 / 2, "threads={threads}");
        }
        // Pinning an arbitrary thread is safe even with an oversized id.
        pin_current_thread(MAX_PIN_CPUS + 3);
    }

    #[test]
    fn single_thread_pool_runs_inline() {
        let pool = ThreadPool::new(1);
        assert_eq!(pool.threads(), 1);
        let caller = std::thread::current().id();
        pool.parallel_for(100, 1, |_range| {
            assert_eq!(std::thread::current().id(), caller);
        });
    }
}
