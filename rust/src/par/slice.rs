//! Unsafe-but-contained helper for disjoint parallel writes.
//!
//! `parallel_for` hands each lane a disjoint index range; per-sample state
//! (assignments, bounds) is naturally partitioned by that range. Rust can't
//! prove the disjointness through a `Fn(Range)` closure, so [`SyncSliceMut`]
//! wraps a raw pointer and exposes unchecked per-index access. All callers
//! in this crate index strictly inside the range their lane was given.

use std::marker::PhantomData;

/// A `&mut [T]` that can be shared across the pool's lanes for writes to
/// disjoint indices.
pub struct SyncSliceMut<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: PhantomData<&'a mut [T]>,
}

// SAFETY: access is only valid for disjoint indices; enforced by callers
// indexing within their assigned chunk.
unsafe impl<T: Send> Send for SyncSliceMut<'_, T> {}
unsafe impl<T: Send> Sync for SyncSliceMut<'_, T> {}

impl<'a, T> SyncSliceMut<'a, T> {
    /// Wrap a mutable slice.
    pub fn new(slice: &'a mut [T]) -> Self {
        Self { ptr: slice.as_mut_ptr(), len: slice.len(), _marker: PhantomData }
    }

    /// Length of the underlying slice.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Write `value` at `i`.
    ///
    /// # Safety contract (checked by debug assert only)
    /// `i` must be inside the caller's disjoint chunk.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub fn at(&self, i: usize) -> &mut T {
        debug_assert!(i < self.len);
        // SAFETY: disjointness is guaranteed by the chunked parallel_for
        // contract documented on this type.
        unsafe { &mut *self.ptr.add(i) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::par::ThreadPool;

    #[test]
    fn disjoint_parallel_writes_land() {
        let pool = ThreadPool::new(4);
        let n = 5000;
        let mut data = vec![0usize; n];
        {
            let shared = SyncSliceMut::new(&mut data);
            pool.parallel_for(n, 32, |range| {
                for i in range {
                    *shared.at(i) = i * 2;
                }
            });
        }
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, i * 2);
        }
    }

    #[test]
    fn len_matches() {
        let mut v = vec![1, 2, 3];
        let s = SyncSliceMut::new(&mut v);
        assert_eq!(s.len(), 3);
        assert!(!s.is_empty());
    }
}
