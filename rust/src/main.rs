fn main() -> aakm::Result<()> {
    aakm::cli::run()
}
