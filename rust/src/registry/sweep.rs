//! Multi-k sweep: fit a ladder of cluster counts over one source, register
//! every model, report an elbow table.
//!
//! The sweep materializes the source **once** and re-targets the same
//! in-memory matrix at every k, so the kernel's generation-stamped
//! sample-norm cache — which survives engine `reset()` — is computed for
//! the first fit and shared by all the rest; the warm [`Workspace`] is
//! chained from fit to fit the same way the coordinator chains it from job
//! to job. Every fitted model lands in the registry as `<base>-k<K>`.

use super::{cluster_counts, request_fingerprint, validate_model_id};
use super::{ModelMetrics, ModelRecord, ModelRegistry};
use crate::error::ClusterError;
use crate::kmeans::Workspace;
use crate::request::ClusterRequest;
use crate::session::ClusterSession;

/// One fitted k of a sweep.
#[derive(Debug, Clone)]
pub struct ElbowRow {
    /// Cluster count.
    pub k: usize,
    /// Registered model id (`<base>-k<K>`).
    pub model_id: String,
    /// Final energy at this k.
    pub energy: f64,
    /// Energy per sample.
    pub mse: f64,
    /// Iterations to converge.
    pub iterations: usize,
    /// Fitting wall time in seconds.
    pub seconds: f64,
}

/// Result of [`sweep`]: one row per k, in the requested order.
#[derive(Debug, Clone)]
pub struct SweepReport {
    /// Elbow table rows.
    pub rows: Vec<ElbowRow>,
}

impl SweepReport {
    /// Render the elbow table as aligned text.
    pub fn table(&self) -> String {
        let mut out = String::from("k      model                    iters  energy           mse\n");
        for r in &self.rows {
            out.push_str(&format!(
                "{:<6} {:<24} {:<6} {:<16.6e} {:.6e}\n",
                r.k, r.model_id, r.iterations, r.energy, r.mse
            ));
        }
        out
    }
}

/// Fit `base` at every k in `ks`, registering each fitted model into
/// `registry` as `<base_id>-k<K>`. The source is materialized once and
/// shared (same data generation) across fits, and the workspace — engine,
/// thread pool, kernel caches, solver scratch — is recycled from k to k.
pub fn sweep(
    registry: &ModelRegistry,
    base: &ClusterRequest,
    ks: &[usize],
    base_id: &str,
) -> Result<SweepReport, ClusterError> {
    validate_model_id(base_id)?;
    if ks.is_empty() {
        return Err(ClusterError::invalid("sweep", "at least one k is required"));
    }
    // One materialization for the whole ladder: every per-k request holds
    // the same Arc'd matrix, so the generation-stamped norm cache built by
    // the first fit serves all of them.
    let x = base.source().materialize()?;
    let mut ws: Option<Workspace> = None;
    let mut rows = Vec::with_capacity(ks.len());
    for &k in ks {
        let req = base.with_k(k)?.with_inline_source(std::sync::Arc::clone(&x));
        let ws_for_run = match ws.take() {
            Some(w) if w.matches(&req.workspace_spec()) => w,
            _ => Workspace::open(&req.workspace_spec())?,
        };
        let mut session = ClusterSession::with_workspace(req.clone(), ws_for_run)?;
        let report = session.run()?;
        let model_id = format!("{base_id}-k{k}");
        let record = ModelRecord {
            id: model_id.clone(),
            fingerprint: request_fingerprint(&req, report.centroids.d()),
            engine: session.workspace().engine_name().to_string(),
            precision: req.precision(),
            seed: req.seed(),
            refreshes: 0,
            centroids: report.centroids.clone(),
            metrics: ModelMetrics {
                energy: report.energy,
                mse: report.mse,
                iterations: report.iterations as u64,
                accepted: report.accepted as u64,
                seconds: report.seconds,
                cluster_counts: cluster_counts(&report.assignment, k),
            },
            drift: None,
        };
        registry.save(&record)?;
        rows.push(ElbowRow {
            k,
            model_id,
            energy: report.energy,
            mse: report.mse,
            iterations: report.iterations,
            seconds: report.seconds,
        });
        session.recycle(report);
        ws = Some(session.into_workspace());
    }
    Ok(SweepReport { rows })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synth, DataMatrix};
    use crate::rng::Pcg32;
    use std::sync::Arc;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("aakm_registry_sweep").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn blobs(seed: u64, n: usize) -> Arc<DataMatrix> {
        let mut rng = Pcg32::seed_from_u64(seed);
        Arc::new(synth::gaussian_blobs(&mut rng, n, 4, 8, 2.5, 0.3))
    }

    #[test]
    fn sweep_registers_every_k_and_energy_is_monotone() {
        let reg = ModelRegistry::open(tmp("ladder")).unwrap();
        let base = ClusterRequest::builder()
            .inline(blobs(11, 1500))
            .k(2)
            .threads(1)
            .seed(3)
            .build()
            .unwrap();
        let ks = [2usize, 4, 8];
        let report = sweep(&reg, &base, &ks, "elbow").unwrap();
        assert_eq!(report.rows.len(), 3);
        for (row, &k) in report.rows.iter().zip(&ks) {
            assert_eq!(row.k, k);
            assert_eq!(row.model_id, format!("elbow-k{k}"));
            let rec = reg.load(&row.model_id).unwrap();
            assert_eq!(rec.centroids.n(), k);
            assert_eq!(rec.metrics.energy.to_bits(), row.energy.to_bits());
            assert_eq!(rec.metrics.cluster_counts.len(), k);
            assert_eq!(
                rec.metrics.cluster_counts.iter().sum::<u64>(),
                1500,
                "counts cover every sample"
            );
        }
        // More clusters never increase the optimal-assignment energy.
        for pair in report.rows.windows(2) {
            assert!(
                pair[1].energy <= pair[0].energy + 1e-9,
                "k={} energy {} > k={} energy {}",
                pair[1].k,
                pair[1].energy,
                pair[0].k,
                pair[0].energy
            );
        }
        assert!(report.table().contains("elbow-k4"));
    }

    #[test]
    fn sweep_rejects_empty_ladder_and_fixed_centroids() {
        let reg = ModelRegistry::open(tmp("reject")).unwrap();
        let base = ClusterRequest::builder()
            .inline(blobs(1, 100))
            .k(2)
            .threads(1)
            .build()
            .unwrap();
        assert!(matches!(
            sweep(&reg, &base, &[], "x"),
            Err(ClusterError::InvalidRequest { field: "sweep", .. })
        ));
        let data = blobs(2, 100);
        let c0 = Arc::new(data.gather_rows(&[0, 50]));
        let pinned = ClusterRequest::builder()
            .inline(data)
            .k(2)
            .initial_centroids(c0)
            .threads(1)
            .build()
            .unwrap();
        assert!(matches!(
            sweep(&reg, &pinned, &[2, 3], "x"),
            Err(ClusterError::InvalidRequest { field: "init", .. })
        ));
    }
}
