//! Batch nearest-centroid inference on a registered model.
//!
//! Serving is an assignment sweep with the centroids pinned: one
//! [`crate::linalg::DistanceKernel::prepare`] then a parallel fused-argmin
//! pass over the batch. The kernel, label and distance buffers all come
//! from the [`Workspace`] scratch pools, so a warm same-shape rerun —
//! after the caller returns the previous [`Prediction`]'s buffers via
//! [`Workspace::recycle_prediction`] — touches the allocator not at all
//! (the contract test lives in `tests/alloc_reuse.rs`). Because the
//! kernel's sample-norm cache is keyed on the data's generation stamp,
//! repeated predicts over the same batch also skip the O(N·d) norm pass.

use super::ModelRecord;
use crate::data::DataMatrix;
use crate::error::ClusterError;
use crate::kmeans::Workspace;
use crate::lloyd::Assignment;
use crate::par::SyncSliceMut;

/// Labels + per-sample squared distances for one predicted batch.
#[derive(Debug, Clone, PartialEq)]
pub struct Prediction {
    /// Nearest-centroid index per sample.
    pub labels: Assignment,
    /// Squared Euclidean distance to that centroid, per sample.
    pub distances: Vec<f64>,
}

impl Prediction {
    /// Sum of the per-sample squared distances — the clustering energy of
    /// the batch under the model.
    pub fn energy(&self) -> f64 {
        self.distances.iter().sum()
    }
}

/// Assign every row of `x` to its nearest centroid of `record`, at the
/// model's stored precision. Buffers are drawn from (and the kernel is
/// returned to) `ws`; hand the finished [`Prediction`]'s buffers back via
/// [`Workspace::recycle_prediction`] to make the next same-shape call
/// allocation-free.
pub fn predict(
    record: &ModelRecord,
    x: &DataMatrix,
    ws: &mut Workspace,
) -> Result<Prediction, ClusterError> {
    let c = &record.centroids;
    if x.d() != c.d() {
        return Err(ClusterError::invalid(
            "predict",
            format!(
                "batch is {}-dimensional but model '{}' is {}-dimensional",
                x.d(),
                record.id,
                c.d()
            ),
        ));
    }
    if x.n() == 0 {
        return Err(ClusterError::invalid("predict", "no samples to assign"));
    }
    let n = x.n();
    let mut kernel = ws.scratch.take_predict_kernel(record.precision);
    kernel.prepare(x, c, &ws.pool);
    let mut labels = ws.scratch.take_assign();
    labels.resize(n, 0);
    let mut distances = ws.scratch.take_trace_f64();
    distances.resize(n, 0.0);
    {
        let labels_s = SyncSliceMut::new(labels.as_mut_slice());
        let dist_s = SyncSliceMut::new(distances.as_mut_slice());
        let kernel = &kernel;
        ws.pool.parallel_for(n, 512, |range| {
            kernel.argmin2_range(x, c, range, |i, b| {
                *labels_s.at(i) = b.best;
                *dist_s.at(i) = b.best_d;
            });
        });
    }
    ws.scratch.put_predict_kernel(record.precision, kernel);
    Ok(Prediction { labels, distances })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{EngineKind, Precision};
    use crate::kmeans::WorkspaceSpec;
    use crate::registry::{ModelMetrics, ModelRecord};

    fn model(c: DataMatrix, precision: Precision) -> ModelRecord {
        ModelRecord {
            id: "m".to_string(),
            fingerprint: String::new(),
            engine: "naive".to_string(),
            precision,
            seed: 0,
            refreshes: 0,
            centroids: c,
            metrics: ModelMetrics {
                energy: 0.0,
                mse: 0.0,
                iterations: 0,
                accepted: 0,
                seconds: 0.0,
                cluster_counts: Vec::new(),
            },
            drift: None,
        }
    }

    fn workspace() -> Workspace {
        Workspace::open(&WorkspaceSpec {
            engine: EngineKind::Naive,
            precision: Precision::F64,
            threads: 1,
            artifact_dir: None,
        })
        .unwrap()
    }

    #[test]
    fn assigns_nearest_centroid_with_distances() {
        let c = DataMatrix::from_rows(&[&[0.0, 0.0], &[10.0, 0.0]]);
        let x = DataMatrix::from_rows(&[&[1.0, 0.0], &[9.0, 0.0], &[4.0, 3.0]]);
        let mut ws = workspace();
        let p = predict(&model(c, Precision::F64), &x, &mut ws).unwrap();
        assert_eq!(p.labels, vec![0, 1, 0]);
        assert!((p.distances[0] - 1.0).abs() < 1e-9);
        assert!((p.distances[1] - 1.0).abs() < 1e-9);
        assert!((p.distances[2] - 25.0).abs() < 1e-9);
        assert!((p.energy() - 27.0).abs() < 1e-9);
    }

    #[test]
    fn dimension_mismatch_is_typed() {
        let c = DataMatrix::from_rows(&[&[0.0, 0.0, 0.0]]);
        let x = DataMatrix::from_rows(&[&[1.0, 0.0]]);
        let mut ws = workspace();
        match predict(&model(c, Precision::F64), &x, &mut ws) {
            Err(ClusterError::InvalidRequest { field: "predict", .. }) => {}
            other => panic!("expected typed mismatch, got ok={}", other.is_ok()),
        }
    }

    #[test]
    fn f32_model_predicts_and_reuses_its_kernel() {
        let c = DataMatrix::from_rows(&[&[0.0], &[100.0]]);
        let x = DataMatrix::from_rows(&[&[1.0], &[99.0], &[49.0]]);
        let mut ws = workspace();
        let m = model(c, Precision::F32);
        let p1 = predict(&m, &x, &mut ws).unwrap();
        assert_eq!(p1.labels, vec![0, 1, 0]);
        let (labels, distances) = (p1.labels.clone(), p1.distances.clone());
        ws.recycle_prediction(p1.labels, p1.distances);
        let p2 = predict(&m, &x, &mut ws).unwrap();
        assert_eq!(p2.labels, labels, "warm rerun is deterministic");
        assert_eq!(p2.distances, distances);
    }
}
