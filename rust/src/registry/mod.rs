//! Model registry — fitted k-means models as durable, servable artifacts.
//!
//! A clustering run's product is its centroid set, but until now that
//! product evaporated with the process: serving assignments or warm-starting
//! a re-fit meant re-running the solver. The registry closes the
//! fit/serve/refresh lifecycle the paper's warm-start observation begs for
//! (Anderson acceleration is at its best when seeded near a fixed point):
//!
//! - [`ModelRegistry`] persists fitted models in the versioned `AAKMMR01`
//!   format — centroids, precision, request fingerprint, seed and quality
//!   metrics (final energy, iterations, wall time, per-cluster counts) —
//!   addressable by model id with list / get / delete / gc. Writes reuse the
//!   checkpoint discipline of [`crate::persist`]: temp file, fsync, atomic
//!   rename, CRC-framed records — a crash (or an injected
//!   [`crate::fault::FaultSite::RegistryWrite`] fault) at any point leaves
//!   the previously registered model intact.
//! - [`predict`] assigns a batch of samples to a loaded model's nearest
//!   centroids on the SIMD fused-argmin kernel — zero allocations on warm
//!   [`crate::kmeans::Workspace`] reruns — returning labels plus per-sample
//!   squared distances.
//! - `InitSpec::WarmStart` (see [`crate::request::InitSpec`]) seeds any
//!   engine from registry centroids; a refresh records a [`DriftReport`]
//!   (energy delta, centroid displacement) back onto the model.
//! - [`sweep`] fits a ladder of k values over one materialized source,
//!   sharing the sample-norm cache and the workspace across fits, registers
//!   every model and reports an elbow table.
//!
//! Corruption never panics and never yields a silently wrong model: every
//! record is CRC-framed, decode is strict (duplicate / missing / misshapen
//! records are typed errors), and a loaded record must name the id it was
//! requested by — a renamed or misplaced file is rejected as stale.

mod predict;
mod sweep;

pub use predict::{predict, Prediction};
pub use sweep::{sweep, ElbowRow, SweepReport};

use crate::config::Precision;
use crate::data::DataMatrix;
use crate::error::ClusterError;
use crate::persist::{parse_records, push_record, Dec, Enc};
use crate::request::ClusterRequest;
use std::path::{Path, PathBuf};

/// Magic prefix of a registry model file (format version 01).
pub const MODEL_MAGIC: &[u8; 8] = b"AAKMMR01";

/// File suffix of a registered model.
const MODEL_EXT: &str = "aakm";

const TAG_META: u32 = 1;
const TAG_CENTROIDS: u32 = 2;
const TAG_METRICS: u32 = 3;
const TAG_DRIFT: u32 = 4;
const TAG_END: u32 = 0xFFFF_FFFF;

/// Quality metrics captured when a model is fitted (or refreshed).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelMetrics {
    /// Final clustering energy (sum of squared distances).
    pub energy: f64,
    /// Energy normalized per sample.
    pub mse: f64,
    /// Solver iterations of the fitting run.
    pub iterations: u64,
    /// Accepted (non-rejected) Anderson steps.
    pub accepted: u64,
    /// Fitting wall time in seconds.
    pub seconds: f64,
    /// Samples per cluster at convergence; empty when the fitting run
    /// carried no resident assignment (streamed mini-batch sources).
    pub cluster_counts: Vec<u64>,
}

/// What a refresh did to a model: recorded on the record so `models` can
/// show how far a re-fit moved from the previous centroids.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DriftReport {
    /// Energy of the model before the refresh.
    pub energy_before: f64,
    /// Energy after the refresh.
    pub energy_after: f64,
    /// Largest per-centroid displacement (Euclidean).
    pub max_displacement: f64,
    /// Mean per-centroid displacement.
    pub mean_displacement: f64,
}

/// One fitted model: everything needed to serve predictions or warm-start
/// a re-fit.
#[derive(Debug, Clone)]
pub struct ModelRecord {
    /// Registry-unique id (see [`validate_model_id`]).
    pub id: String,
    /// Fingerprint of the fitting request (see [`request_fingerprint`]).
    pub fingerprint: String,
    /// Engine that fitted the model (canonical name).
    pub engine: String,
    /// Kernel precision the model was fitted at.
    pub precision: Precision,
    /// RNG seed of the fitting request.
    pub seed: u64,
    /// How many refreshes this model has absorbed.
    pub refreshes: u64,
    /// The `k × d` centroid set.
    pub centroids: DataMatrix,
    /// Quality metrics of the most recent fit/refresh.
    pub metrics: ModelMetrics,
    /// Drift of the most recent refresh, if any.
    pub drift: Option<DriftReport>,
}

/// One row of [`ModelRegistry::list`].
#[derive(Debug, Clone)]
pub struct ModelSummary {
    /// Model id.
    pub id: String,
    /// Cluster count.
    pub k: usize,
    /// Dimensionality.
    pub d: usize,
    /// Fitting engine name.
    pub engine: String,
    /// Kernel precision.
    pub precision: Precision,
    /// Final energy.
    pub energy: f64,
    /// Refresh count.
    pub refreshes: u64,
}

/// Validate a model id: non-empty, at most 128 characters, ASCII
/// alphanumerics plus `-`/`_`/`.`, not starting with a dot (ids double as
/// file stems, so a leading dot would hide the model from `list`).
pub fn validate_model_id(id: &str) -> Result<(), ClusterError> {
    if id.is_empty() {
        return Err(ClusterError::invalid("model", "model id must be non-empty"));
    }
    if id.len() > 128 {
        return Err(ClusterError::invalid(
            "model",
            format!("model id is {} characters (max 128)", id.len()),
        ));
    }
    if id.starts_with('.') {
        return Err(ClusterError::invalid("model", "model id must not start with '.'"));
    }
    if let Some(c) =
        id.chars().find(|c| !(c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.')))
    {
        return Err(ClusterError::invalid(
            "model",
            format!("model id contains '{c}' (allowed: alphanumerics, '-', '_', '.')"),
        ));
    }
    Ok(())
}

/// The fingerprint a fitted model records: the request facts that define
/// what the centroids *are* (shape, seed, engine, precision, acceleration)
/// — budgets and init are excluded, since two runs differing only there
/// still describe the same model family.
pub fn request_fingerprint(req: &ClusterRequest, d: usize) -> String {
    format!(
        "aakm-model-v1 k={} d={} seed={} engine={} precision={} accel={}",
        req.k(),
        d,
        req.seed(),
        req.engine().name(),
        req.precision().name(),
        req.accel().label()
    )
}

/// Per-cluster sample counts from a resident assignment (empty in, empty
/// out — streamed runs carry no assignment). Out-of-range labels are
/// ignored rather than panicking: the counts are metrics, not invariants.
pub(crate) fn cluster_counts(assignment: &[u32], k: usize) -> Vec<u64> {
    if assignment.is_empty() {
        return Vec::new();
    }
    let mut counts = vec![0u64; k];
    for &a in assignment {
        if let Some(c) = counts.get_mut(a as usize) {
            *c += 1;
        }
    }
    counts
}

/// Drift between two same-shape centroid sets (`None` on shape mismatch —
/// a refresh that changed k has no per-centroid correspondence).
pub fn drift_between(
    before: &DataMatrix,
    after: &DataMatrix,
    energy_before: f64,
    energy_after: f64,
) -> Option<DriftReport> {
    if before.n() != after.n() || before.d() != after.d() || before.n() == 0 {
        return None;
    }
    let mut max_displacement = 0.0f64;
    let mut sum = 0.0f64;
    for j in 0..before.n() {
        let dj = crate::linalg::dist_sq(before.row(j), after.row(j)).sqrt();
        max_displacement = max_displacement.max(dj);
        sum += dj;
    }
    Some(DriftReport {
        energy_before,
        energy_after,
        max_displacement,
        mean_displacement: sum / before.n() as f64,
    })
}

/// A directory of fitted models, one `<id>.aakm` file per model.
#[derive(Debug, Clone)]
pub struct ModelRegistry {
    dir: PathBuf,
}

impl ModelRegistry {
    /// Open (creating if needed) the registry at `dir`.
    pub fn open(dir: impl Into<PathBuf>) -> Result<Self, ClusterError> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir).map_err(|e| ClusterError::Snapshot {
            path: dir.display().to_string(),
            reason: format!("create registry dir: {e}"),
        })?;
        Ok(Self { dir })
    }

    /// The registry directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Where a model id lives on disk.
    pub fn model_path(&self, id: &str) -> PathBuf {
        self.dir.join(format!("{id}.{MODEL_EXT}"))
    }

    /// Persist `record` durably: serialize, write to a temp file, fsync,
    /// atomically rename over any previous version of the model. A crash
    /// (or an injected [`crate::fault::FaultSite::RegistryWrite`] fault) at
    /// any point leaves either the old complete record or the new complete
    /// record on disk — never a torn one.
    pub fn save(&self, record: &ModelRecord) -> Result<PathBuf, ClusterError> {
        let sw = crate::metrics::Stopwatch::start();
        validate_model_id(&record.id)?;
        let path = self.model_path(&record.id);
        let fail = |reason: String| ClusterError::Snapshot {
            path: path.display().to_string(),
            reason,
        };
        // Fault window 1: a clean write failure before any bytes land.
        crate::fault::check(crate::fault::FaultSite::RegistryWrite)
            .map_err(|e| fail(format!("write failed: {e}")))?;
        let bytes = encode_model(record);
        let tmp = self.dir.join(format!("{}.{MODEL_EXT}.tmp", record.id));
        {
            use std::io::Write;
            let mut f =
                std::fs::File::create(&tmp).map_err(|e| fail(format!("create temp: {e}")))?;
            f.write_all(&bytes).map_err(|e| fail(format!("write temp: {e}")))?;
            f.sync_all().map_err(|e| fail(format!("sync temp: {e}")))?;
        }
        // Fault window 2: between the write and the rename. An injected
        // error truncates the temp file to a torn prefix (what a real crash
        // mid-write leaves) and keeps the previous record in place; an
        // injected kill unwinds with the rename never performed.
        if let Err(e) = crate::fault::check(crate::fault::FaultSite::RegistryWrite) {
            let _ = std::fs::File::options()
                .write(true)
                .open(&tmp)
                .and_then(|f| f.set_len(bytes.len() as u64 / 2));
            return Err(fail(format!("write failed before rename: {e}")));
        }
        std::fs::rename(&tmp, &path).map_err(|e| fail(format!("rename: {e}")))?;
        // Make the rename itself durable (best-effort: not all platforms
        // support fsync on directories).
        if let Ok(d) = std::fs::File::open(&self.dir) {
            let _ = d.sync_all();
        }
        if crate::telemetry::enabled() {
            let t = crate::telemetry::metrics();
            t.model_writes.inc();
            t.model_bytes.add(bytes.len() as u64);
            t.model_write_seconds.observe(sw.seconds());
        }
        Ok(path)
    }

    /// Load a model by id. A missing model is a deterministic
    /// [`ClusterError::InvalidRequest`] (never retried); a corrupt file is
    /// a typed [`ClusterError::Snapshot`]. A file whose decoded id differs
    /// from the requested one (a renamed or misplaced copy) is rejected —
    /// serving a stale model silently is the one failure mode this layer
    /// must never have.
    pub fn load(&self, id: &str) -> Result<ModelRecord, ClusterError> {
        validate_model_id(id)?;
        let path = self.model_path(id);
        let bytes = match std::fs::read(&path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Err(ClusterError::invalid(
                    "model",
                    format!("no model '{id}' in {}", self.dir.display()),
                ));
            }
            Err(e) => {
                return Err(ClusterError::Snapshot {
                    path: path.display().to_string(),
                    reason: format!("read: {e}"),
                });
            }
        };
        let record = decode_model(&bytes).map_err(|reason| ClusterError::Snapshot {
            path: path.display().to_string(),
            reason,
        })?;
        if record.id != id {
            return Err(ClusterError::Snapshot {
                path: path.display().to_string(),
                reason: format!(
                    "model file names itself '{}' — stale or misplaced copy",
                    record.id
                ),
            });
        }
        Ok(record)
    }

    /// Summaries of every readable model, sorted by id. Corrupt files are
    /// skipped (use [`ModelRegistry::gc`] to remove them); a listing must
    /// not fail because one artifact is damaged.
    pub fn list(&self) -> Result<Vec<ModelSummary>, ClusterError> {
        let mut out = Vec::new();
        for id in self.model_ids()? {
            if let Ok(r) = self.load(&id) {
                out.push(ModelSummary {
                    id: r.id,
                    k: r.centroids.n(),
                    d: r.centroids.d(),
                    engine: r.engine,
                    precision: r.precision,
                    energy: r.metrics.energy,
                    refreshes: r.refreshes,
                });
            }
        }
        out.sort_by(|a, b| a.id.cmp(&b.id));
        Ok(out)
    }

    /// Delete a model; `Ok(false)` when it did not exist.
    pub fn delete(&self, id: &str) -> Result<bool, ClusterError> {
        validate_model_id(id)?;
        match std::fs::remove_file(self.model_path(id)) {
            Ok(()) => Ok(true),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(false),
            Err(e) => Err(ClusterError::Snapshot {
                path: self.model_path(id).display().to_string(),
                reason: format!("delete: {e}"),
            }),
        }
    }

    /// Remove unreadable model files and stray temp files left by crashed
    /// writes; returns the removed file names.
    pub fn gc(&self) -> Result<Vec<String>, ClusterError> {
        let mut removed = Vec::new();
        let entries = std::fs::read_dir(&self.dir).map_err(|e| ClusterError::Snapshot {
            path: self.dir.display().to_string(),
            reason: format!("read dir: {e}"),
        })?;
        for entry in entries.flatten() {
            let path = entry.path();
            let Some(name) = path.file_name().and_then(|n| n.to_str()).map(String::from)
            else {
                continue;
            };
            let stale_tmp = name.ends_with(".tmp");
            let corrupt = path.extension().is_some_and(|e| e == MODEL_EXT)
                && path
                    .file_stem()
                    .and_then(|s| s.to_str())
                    .is_none_or(|id| self.load(id).is_err());
            if stale_tmp || corrupt {
                if std::fs::remove_file(&path).is_ok() {
                    removed.push(name);
                }
            }
        }
        removed.sort();
        Ok(removed)
    }

    /// Ids of every `.aakm` file present (readable or not), sorted.
    fn model_ids(&self) -> Result<Vec<String>, ClusterError> {
        let entries = std::fs::read_dir(&self.dir).map_err(|e| ClusterError::Snapshot {
            path: self.dir.display().to_string(),
            reason: format!("read dir: {e}"),
        })?;
        let mut ids: Vec<String> = entries
            .flatten()
            .filter_map(|e| {
                let path = e.path();
                if path.extension().is_some_and(|x| x == MODEL_EXT) {
                    path.file_stem().and_then(|s| s.to_str()).map(String::from)
                } else {
                    None
                }
            })
            .collect();
        ids.sort();
        Ok(ids)
    }
}

/// Serialize a record into the `AAKMMR01` byte format.
fn encode_model(r: &ModelRecord) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(MODEL_MAGIC);
    {
        let mut e = Enc::default();
        e.str(&r.id);
        e.str(&r.fingerprint);
        e.str(&r.engine);
        e.str(r.precision.name());
        e.u64(r.seed);
        e.u64(r.refreshes);
        push_record(&mut out, TAG_META, &e.buf);
    }
    {
        let mut e = Enc::default();
        e.u64(r.centroids.n() as u64);
        e.u64(r.centroids.d() as u64);
        e.f64s(r.centroids.as_slice());
        push_record(&mut out, TAG_CENTROIDS, &e.buf);
    }
    {
        let mut e = Enc::default();
        e.f64(r.metrics.energy);
        e.f64(r.metrics.mse);
        e.u64(r.metrics.iterations);
        e.u64(r.metrics.accepted);
        e.f64(r.metrics.seconds);
        e.u64s(&r.metrics.cluster_counts);
        push_record(&mut out, TAG_METRICS, &e.buf);
    }
    if let Some(d) = &r.drift {
        let mut e = Enc::default();
        e.f64(d.energy_before);
        e.f64(d.energy_after);
        e.f64(d.max_displacement);
        e.f64(d.mean_displacement);
        push_record(&mut out, TAG_DRIFT, &e.buf);
    }
    push_record(&mut out, TAG_END, &[]);
    out
}

/// Decode and validate a model byte stream. Every structural defect —
/// foreign magic, truncation, CRC mismatch, duplicate / missing /
/// misshapen records — is a typed error, never a panic and never a
/// silently wrong model.
fn decode_model(bytes: &[u8]) -> Result<ModelRecord, String> {
    if bytes.len() < MODEL_MAGIC.len() || &bytes[..8] != MODEL_MAGIC {
        return Err("not an AAKMMR01 model (bad magic)".to_string());
    }
    let records = parse_records(&bytes[8..], true)?;
    if records.last().map(|(t, _)| *t) != Some(TAG_END) {
        return Err("missing end record (torn write)".to_string());
    }
    let dup = |what: &str| format!("duplicate {what} record");

    let mut meta: Option<(String, String, String, Precision, u64, u64)> = None;
    let mut centroids: Option<DataMatrix> = None;
    let mut metrics: Option<ModelMetrics> = None;
    let mut drift: Option<DriftReport> = None;
    for &(tag, payload) in &records[..records.len() - 1] {
        let mut d = Dec::new(payload);
        match tag {
            TAG_META => {
                let id = d.str()?;
                let fingerprint = d.str()?;
                let engine = d.str()?;
                let precision = d.str()?;
                let precision = Precision::parse(&precision)
                    .ok_or_else(|| format!("unknown precision '{precision}'"))?;
                let seed = d.u64()?;
                let refreshes = d.u64()?;
                if meta.replace((id, fingerprint, engine, precision, seed, refreshes)).is_some()
                {
                    return Err(dup("meta"));
                }
            }
            TAG_CENTROIDS => {
                let k = d.u64()? as usize;
                let dim = d.u64()? as usize;
                let vals = d.f64s()?;
                if k == 0 || dim == 0 {
                    return Err(format!("degenerate centroid shape {k}×{dim}"));
                }
                if vals.len() != k * dim {
                    return Err(format!(
                        "centroid payload holds {} values for a {k}×{dim} model",
                        vals.len()
                    ));
                }
                if centroids.replace(DataMatrix::from_vec(vals, k, dim)).is_some() {
                    return Err(dup("centroids"));
                }
            }
            TAG_METRICS => {
                let m = ModelMetrics {
                    energy: d.f64()?,
                    mse: d.f64()?,
                    iterations: d.u64()?,
                    accepted: d.u64()?,
                    seconds: d.f64()?,
                    cluster_counts: d.u64s()?,
                };
                if metrics.replace(m).is_some() {
                    return Err(dup("metrics"));
                }
            }
            TAG_DRIFT => {
                let r = DriftReport {
                    energy_before: d.f64()?,
                    energy_after: d.f64()?,
                    max_displacement: d.f64()?,
                    mean_displacement: d.f64()?,
                };
                if drift.replace(r).is_some() {
                    return Err(dup("drift"));
                }
            }
            TAG_END => return Err("end record before the end of the file".to_string()),
            other => return Err(format!("unknown record tag {other} (newer format?)")),
        }
        d.done()?;
    }
    let (id, fingerprint, engine, precision, seed, refreshes) =
        meta.ok_or("missing meta record")?;
    let centroids = centroids.ok_or("missing centroids record")?;
    let metrics = metrics.ok_or("missing metrics record")?;
    if !metrics.cluster_counts.is_empty() && metrics.cluster_counts.len() != centroids.n() {
        return Err(format!(
            "{} cluster counts for a k={} model",
            metrics.cluster_counts.len(),
            centroids.n()
        ));
    }
    Ok(ModelRecord {
        id,
        fingerprint,
        engine,
        precision,
        seed,
        refreshes,
        centroids,
        metrics,
        drift,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("aakm_registry_unit").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn sample_record(id: &str) -> ModelRecord {
        ModelRecord {
            id: id.to_string(),
            fingerprint: "aakm-model-v1 k=2 d=2 seed=7 engine=hamerly precision=f64 \
                          accel=dynamic:2"
                .to_string(),
            engine: "hamerly".to_string(),
            precision: Precision::F64,
            seed: 7,
            refreshes: 1,
            centroids: DataMatrix::from_rows(&[&[0.25, -1.5], &[3.0, 4.0]]),
            metrics: ModelMetrics {
                energy: 12.5,
                mse: 0.125,
                iterations: 9,
                accepted: 4,
                seconds: 0.031,
                cluster_counts: vec![60, 40],
            },
            drift: Some(DriftReport {
                energy_before: 13.0,
                energy_after: 12.5,
                max_displacement: 0.4,
                mean_displacement: 0.2,
            }),
        }
    }

    #[test]
    fn save_load_roundtrip_is_exact() {
        let reg = ModelRegistry::open(tmp("roundtrip")).unwrap();
        let rec = sample_record("m1");
        reg.save(&rec).unwrap();
        let back = reg.load("m1").unwrap();
        assert_eq!(back.id, rec.id);
        assert_eq!(back.fingerprint, rec.fingerprint);
        assert_eq!(back.engine, rec.engine);
        assert_eq!(back.precision, rec.precision);
        assert_eq!(back.seed, rec.seed);
        assert_eq!(back.refreshes, rec.refreshes);
        assert_eq!(back.centroids, rec.centroids);
        assert_eq!(back.metrics, rec.metrics);
        assert_eq!(back.drift, rec.drift);
    }

    #[test]
    fn missing_model_is_a_deterministic_typed_error() {
        let reg = ModelRegistry::open(tmp("missing")).unwrap();
        match reg.load("nope") {
            Err(ClusterError::InvalidRequest { field: "model", .. }) => {}
            other => panic!("expected InvalidRequest, got ok={}", other.is_ok()),
        }
    }

    #[test]
    fn bad_ids_are_rejected() {
        for bad in ["", ".hidden", "a/b", "a b", "a\nb", &"x".repeat(200)] {
            assert!(
                matches!(
                    validate_model_id(bad),
                    Err(ClusterError::InvalidRequest { field: "model", .. })
                ),
                "accepted bad id {bad:?}"
            );
        }
        for good in ["m1", "model-2.v3", "A_B.c-d"] {
            validate_model_id(good).unwrap();
        }
    }

    #[test]
    fn renamed_file_is_rejected_as_stale() {
        let reg = ModelRegistry::open(tmp("stale")).unwrap();
        reg.save(&sample_record("original")).unwrap();
        std::fs::rename(reg.model_path("original"), reg.model_path("imposter")).unwrap();
        match reg.load("imposter") {
            Err(ClusterError::Snapshot { reason, .. }) => {
                assert!(reason.contains("original"), "{reason}");
            }
            other => panic!("expected Snapshot error, got ok={}", other.is_ok()),
        }
    }

    #[test]
    fn list_skips_corrupt_and_gc_removes_it() {
        let reg = ModelRegistry::open(tmp("gc")).unwrap();
        reg.save(&sample_record("good")).unwrap();
        std::fs::write(reg.model_path("broken"), b"AAKMMR01 then garbage").unwrap();
        std::fs::write(reg.dir().join("crashed.aakm.tmp"), b"torn").unwrap();
        let listing = reg.list().unwrap();
        assert_eq!(listing.len(), 1);
        assert_eq!(listing[0].id, "good");
        assert_eq!(listing[0].k, 2);
        let removed = reg.gc().unwrap();
        assert_eq!(removed, vec!["broken.aakm".to_string(), "crashed.aakm.tmp".to_string()]);
        assert_eq!(reg.list().unwrap().len(), 1, "gc must keep readable models");
        assert!(reg.delete("good").unwrap());
        assert!(!reg.delete("good").unwrap(), "second delete reports absence");
    }

    #[test]
    fn every_single_byte_flip_is_caught() {
        let rec = sample_record("fuzz");
        let bytes = encode_model(&rec);
        decode_model(&bytes).unwrap();
        for i in 0..bytes.len() {
            let mut flipped = bytes.clone();
            flipped[i] ^= 0x01;
            assert!(
                decode_model(&flipped).is_err(),
                "flip at byte {i} of {} decoded successfully",
                bytes.len()
            );
        }
    }

    #[test]
    fn truncation_prefixes_never_decode() {
        let bytes = encode_model(&sample_record("trunc"));
        for cut in 0..bytes.len() {
            assert!(decode_model(&bytes[..cut]).is_err(), "prefix of {cut} bytes decoded");
        }
    }

    #[test]
    fn cluster_counts_and_drift_helpers() {
        assert!(cluster_counts(&[], 4).is_empty());
        assert_eq!(cluster_counts(&[0, 1, 1, 3, 9], 4), vec![1, 2, 0, 1]);
        let a = DataMatrix::from_rows(&[&[0.0, 0.0], &[1.0, 0.0]]);
        let b = DataMatrix::from_rows(&[&[0.0, 3.0], &[1.0, 1.0]]);
        let d = drift_between(&a, &b, 10.0, 8.0).unwrap();
        assert_eq!(d.max_displacement, 3.0);
        assert_eq!(d.mean_displacement, 2.0);
        assert_eq!(d.energy_before, 10.0);
        let c = DataMatrix::from_rows(&[&[0.0, 0.0]]);
        assert!(drift_between(&a, &c, 1.0, 1.0).is_none(), "shape mismatch has no drift");
    }
}
