//! # aakm — Fast K-Means Clustering with Anderson Acceleration
//!
//! A production reproduction of *Zhang, Yao, Peng, Yu, Deng — "Fast K-Means
//! Clustering with Anderson Acceleration" (2018)* as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the Anderson-accelerated Lloyd solver with
//!   dynamic-`m` adjustment (the paper's Algorithm 1), the baselines it is
//!   compared against (Lloyd with naive / Hamerly / Elkan assignment), the
//!   four seeding methods from the evaluation (k-means++, afk-mc²,
//!   Bradley–Fayyad, CLARANS), and a clustering service coordinator.
//! * **Layer 2 (JAX, build time)** — the fixed-point map
//!   `G(C) = Update(Assign(X, C))` lowered AOT to HLO text.
//! * **Layer 1 (Pallas, build time)** — the tiled distance + argmin kernel
//!   inside the L2 map.
//!
//! The [`runtime`] module loads the AOT artifacts via PJRT (`xla` crate) so
//! the Rust hot path can execute the JAX-defined G-step without Python.
//!
//! ## Quickstart
//!
//! ```no_run
//! use aakm::data::synth;
//! use aakm::kmeans::{Solver, SolverConfig};
//! use aakm::init::{seed_centroids, InitMethod};
//! use aakm::rng::Pcg32;
//!
//! let mut rng = Pcg32::seed_from_u64(7);
//! let x = synth::gaussian_blobs(&mut rng, 10_000, 8, 10, 1.0, 0.05);
//! let c0 = seed_centroids(&x, 10, InitMethod::KMeansPlusPlus, &mut rng);
//! let report = Solver::new(SolverConfig::default()).run(&x, c0);
//! println!("converged in {} iterations, mse {:.4}",
//!          report.iterations, report.mse);
//! ```

pub mod anderson;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod init;
pub mod kmeans;
pub mod linalg;
pub mod lloyd;
pub mod metrics;
pub mod par;
pub mod rng;
pub mod runtime;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;

/// Version string reported by the CLI and service endpoints.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
