//! # aakm — Fast K-Means Clustering with Anderson Acceleration
//!
//! A production reproduction of *Zhang, Yao, Peng, Yu, Deng — "Fast K-Means
//! Clustering with Anderson Acceleration" (2018)* as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the Anderson-accelerated Lloyd solver with
//!   dynamic-`m` adjustment (the paper's Algorithm 1), the baselines it is
//!   compared against (Lloyd with naive / Hamerly / Elkan assignment), the
//!   four seeding methods from the evaluation (k-means++, afk-mc²,
//!   Bradley–Fayyad, CLARANS), and a clustering service coordinator.
//! * **Layer 2 (JAX, build time)** — the fixed-point map
//!   `G(C) = Update(Assign(X, C))` lowered AOT to HLO text.
//! * **Layer 1 (Pallas, build time)** — the tiled distance + argmin kernel
//!   inside the L2 map.
//!
//! The [`runtime`] module loads the AOT artifacts via PJRT (`xla` crate) so
//! the Rust hot path can execute the JAX-defined G-step without Python.
//!
//! Every solver loop in the crate — the accelerated full-batch path, the
//! Lloyd baseline and the streaming mini-batch epochs — drives the single
//! safeguarded-Anderson implementation in [`accel`]
//! ([`accel::FixedPointDriver`] over the [`accel::Step`] trait), so the
//! paper's accept/reject scheme exists exactly once.
//!
//! ## Quickstart
//!
//! Every layer consumes one job description, [`ClusterRequest`]; opening it
//! as a [`ClusterSession`] owns a warm workspace (engine, thread pool,
//! kernel caches, solver scratch) that repeated runs reuse:
//!
//! ```no_run
//! use aakm::data::synth;
//! use aakm::rng::Pcg32;
//! use aakm::{ClusterRequest, ClusterSession};
//! use std::sync::Arc;
//!
//! fn main() -> Result<(), aakm::ClusterError> {
//!     let mut rng = Pcg32::seed_from_u64(7);
//!     let x = Arc::new(synth::gaussian_blobs(&mut rng, 10_000, 8, 10, 1.0, 0.05));
//!     let request = ClusterRequest::builder().inline(x).k(10).seed(7).build()?;
//!     let mut session = ClusterSession::open(request)?;
//!     let report = session.run()?;
//!     println!("converged in {} iterations, mse {:.4}", report.iterations, report.mse);
//!     session.recycle(report); // next same-shape run is allocation-free
//!     Ok(())
//! }
//! ```
//!
//! Mid-run observability and cancellation live in [`observe`]
//! ([`Observer`], [`CancelToken`]); the service coordinator
//! ([`coordinator::Coordinator`]) accepts the same requests and returns
//! [`coordinator::JobHandle`]s with poll / wait / cancel — worker pickup
//! honors [`ClusterRequest`] priorities and interleaves clients fairly.
//! The coordinator's fault-tolerance layer (admission policies with
//! load-shedding, retry-with-backoff, worker supervision, graceful
//! PJRT→CPU degradation) is exercised by the deterministic
//! fault-injection harness in [`fault`].
//!
//! Datasets larger than RAM run through the streaming engine: a request
//! with `EngineKind::MiniBatch` (and, for out-of-core files, a
//! `DataSource::Shard`) streams chunks from a [`data::ChunkSource`]
//! through the mini-batch solver in [`stream`], with Anderson acceleration
//! applied to the per-epoch centroid sequence.
//!
//! Long runs survive process death through the durable-checkpoint layer in
//! [`persist`]: a [`persist::CheckpointPolicy`] on the request makes the
//! solver write crash-safe `AAKMCK01` snapshots it can resume from
//! bit-identically, and a journaled coordinator replays its write-ahead
//! job log on restart to re-enqueue incomplete jobs.
//!
//! Fitted clusterings persist as *models* in a [`registry::ModelRegistry`]:
//! `fit` registers the converged centroids (with quality metrics and a
//! request fingerprint), `predict` batch-assigns new samples against a
//! registered model on the SIMD distance kernels, and `refresh` re-clusters
//! drifted data warm-started from the stored centroids — the paper's
//! best-case regime for Anderson acceleration, since the iterate starts
//! near the fixed point — recording a centroid-drift report on the model.
//!
//! Runtime observability lives in [`telemetry`]: an opt-in process-wide
//! metrics registry (Prometheus text exposition + JSON dump) fed by the
//! solver driver, coordinator, streaming engine and durability layers; a
//! bounded non-blocking JSONL event log ([`telemetry::events`]); and live
//! per-iteration progress streamed out of the coordinator via
//! [`coordinator::JobHandle::subscribe`].

// Kernel-style numeric code throughout this crate indexes several parallel
// arrays per loop; rewriting those loops as iterator chains would obscure
// the arithmetic the paper specifies, so this one pedantic lint stays off
// crate-wide (the remaining clippy set runs with -D warnings in CI).
#![allow(clippy::needless_range_loop)]

pub mod accel;
pub mod anderson;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod fault;
pub mod init;
pub mod kmeans;
pub mod linalg;
pub mod lloyd;
pub mod metrics;
pub mod observe;
pub mod par;
pub mod persist;
pub mod registry;
pub mod request;
pub mod rng;
pub mod runtime;
pub mod session;
pub mod stream;
pub mod telemetry;

pub use error::ClusterError;
pub use observe::{CancelToken, Observer};
pub use registry::ModelRegistry;
pub use request::{ClusterRequest, DataSource, InitSpec, ModelJob, ModelJobKind};
pub use session::ClusterSession;

/// Crate-wide result alias (internal plumbing; the public request/session
/// API returns [`ClusterError`] instead).
pub type Result<T> = anyhow::Result<T>;

/// Version string reported by the CLI and service endpoints.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
