//! Streaming mini-batch K-Means with epoch-level Anderson acceleration.
//!
//! [`MiniBatchSolver`] runs Sculley-style mini-batch K-Means (per-batch
//! assign + per-centroid decaying learning rates) over any
//! [`ChunkSource`], so only one chunk of samples is resident at a time —
//! datasets far larger than RAM stream through the same SIMD assign
//! kernels the full-batch engines use. On top of the batch loop it applies
//! the paper's machinery at *epoch* granularity: one pass over the source
//! is one application of a deterministic fixed-point map `C_e = G(C_{e-1})`
//! (all built-in sources replay identical chunks after a rewind), and the
//! smoothed per-epoch centroid sequence is Anderson-extrapolated with the
//! dynamic-`m` safeguard from [`crate::anderson`]. Every epoch ends with a
//! full-energy checkpoint over the source; the checkpoint guards AA
//! proposals (reject on non-decrease, Algorithm 1 lines 13–15), drives the
//! dynamic-`m` controller, restarts the AA history after repeated
//! rejections, and decides convergence.
//!
//! The solver runs on the same reusable [`Workspace`] as the full-batch
//! path — chunk buffer, assignment buffer, Anderson history and the
//! per-centroid counters are all drawn from (and returned to) the
//! workspace scratch, so warm reruns allocate nothing. The higher-level
//! entry point is a [`crate::ClusterRequest`] with
//! `EngineKind::MiniBatch`, which routes [`crate::ClusterSession`] (and
//! therefore the coordinator) through this module.

use crate::anderson::{AndersonAccelerator, MController};
use crate::config::{Acceleration, SolverConfig};
use crate::data::chunks::ChunkSource;
use crate::data::DataMatrix;
use crate::error::ClusterError;
use crate::kmeans::{over_budget, RunReport, Workspace, WorkspaceSpec};
use crate::lloyd;
use crate::metrics::{PhaseTimer, Stopwatch};
use crate::observe::{CancelToken, IterationInfo, NoopObserver, Observer, ObserverControl};

/// Batch cap per epoch for custom unbounded sources that neither report a
/// length nor run out (all built-in sources are bounded per pass).
const UNBOUNDED_EPOCH_BATCHES: usize = 64;

/// Consecutive rejected Anderson proposals after which the history is
/// dropped (restart): epoch-level residuals are noisier than full-batch
/// ones, and a stale history that keeps proposing uphill extrapolations
/// is worse than starting fresh.
const RESTART_AFTER_REJECTS: u32 = 2;

/// Configuration of one streaming mini-batch run.
#[derive(Debug, Clone)]
pub struct MiniBatchConfig {
    /// Solver-level knobs reused from the full-batch path: `accel` /
    /// `epsilon1` / `epsilon2` / `m_max` drive the epoch-level Anderson
    /// step, `max_iters` caps *epochs*, `time_limit` is checked at batch
    /// boundaries, `threads` / `precision` size the workspace.
    pub solver: SolverConfig,
    /// Samples per mini-batch chunk (peak resident sample count).
    pub chunk_size: usize,
    /// Mini-batches per epoch; 0 = one full pass over the source. With a
    /// positive cap each epoch streams the first `batches_per_epoch`
    /// chunks of a pass, keeping the epoch map deterministic.
    pub batches_per_epoch: usize,
    /// Relative epoch-energy change below which the run converges.
    pub convergence_tol: f64,
}

impl Default for MiniBatchConfig {
    fn default() -> Self {
        Self {
            solver: SolverConfig {
                engine: crate::config::EngineKind::MiniBatch,
                ..SolverConfig::default()
            },
            chunk_size: 4096,
            batches_per_epoch: 0,
            convergence_tol: 1e-4,
        }
    }
}

/// Anderson-accelerated mini-batch solver over a reusable [`Workspace`].
pub struct MiniBatchSolver {
    cfg: MiniBatchConfig,
    ws: Workspace,
}

impl MiniBatchSolver {
    /// Build a solver (and a fresh workspace) for `cfg`.
    pub fn try_new(cfg: MiniBatchConfig) -> Result<Self, ClusterError> {
        let ws = Workspace::open(&WorkspaceSpec::from_config(&cfg.solver))?;
        Ok(Self { cfg, ws })
    }

    /// Build a solver over an existing (warm) workspace.
    pub fn from_workspace(cfg: MiniBatchConfig, ws: Workspace) -> Self {
        Self { cfg, ws }
    }

    /// Configuration in use.
    pub fn config(&self) -> &MiniBatchConfig {
        &self.cfg
    }

    /// The workspace backing this solver.
    pub fn workspace(&self) -> &Workspace {
        &self.ws
    }

    /// Release the workspace for reuse.
    pub fn into_workspace(self) -> Workspace {
        self.ws
    }

    /// Run mini-batch epochs over `source` from the initial centroids
    /// `c0` until the epoch energy plateaus (convergence), the epoch cap
    /// (`solver.max_iters`) or the time budget is reached.
    pub fn run(
        &mut self,
        source: &mut dyn ChunkSource,
        c0: &DataMatrix,
    ) -> Result<RunReport, ClusterError> {
        self.run_observed(source, c0, &mut NoopObserver, &CancelToken::new())
    }

    /// [`MiniBatchSolver::run`] with an [`Observer`] called once per
    /// *epoch* (the iteration granularity of this solver; `energy` is the
    /// epoch's full checkpoint energy) and a [`CancelToken`] checked at
    /// every batch boundary. In the returned report, `iterations` counts
    /// epochs, `accepted` counts epochs whose Anderson proposal passed the
    /// energy guard, and `assignment` is empty — a streamed dataset has no
    /// resident assignment vector. Because the full dataset is never
    /// resident, `Observer::on_start` receives the initial centroids as
    /// its data argument.
    pub fn run_observed(
        &mut self,
        source: &mut dyn ChunkSource,
        c0: &DataMatrix,
        observer: &mut dyn Observer,
        cancel: &CancelToken,
    ) -> Result<RunReport, ClusterError> {
        run_on_workspace(&self.cfg, &mut self.ws, source, c0, observer, cancel)
    }
}

/// One full-energy checkpoint pass: rewind the source and accumulate the
/// exact clustering energy of `c` over up to `max_batches` chunks (every
/// chunk for bounded sources). Returns `Some((energy, samples))`, or
/// `None` when the cancel token trips or the time budget expires mid-pass
/// — like the training pass, the checkpoint yields at batch boundaries so
/// cancellation latency on out-of-core data is one chunk, not one full
/// dataset scan.
#[allow(clippy::too_many_arguments)]
fn checkpoint_energy(
    ws: &mut Workspace,
    source: &mut dyn ChunkSource,
    c: &DataMatrix,
    chunk: &mut DataMatrix,
    assign: &mut lloyd::Assignment,
    chunk_rows: usize,
    max_batches: usize,
    phases: &mut PhaseTimer,
    cancel: &CancelToken,
    sw: &Stopwatch,
    limit: Option<std::time::Duration>,
) -> Result<Option<(f64, u64)>, ClusterError> {
    source.rewind();
    let mut energy = 0.0;
    let mut samples = 0u64;
    let mut batches = 0usize;
    while batches < max_batches {
        if cancel.is_cancelled() || over_budget(sw, limit) {
            return Ok(None);
        }
        let got = source.next_chunk(chunk_rows, chunk)?;
        if got == 0 {
            break;
        }
        // Per-chunk reset, as in the training pass: never let bound state
        // from one chunk's samples prune another's.
        ws.engine.reset();
        phases.time("energy", || {
            ws.engine.assign(chunk, c, &ws.pool, assign);
            energy += lloyd::energy(chunk, c, assign, &ws.pool);
        });
        samples += got as u64;
        batches += 1;
    }
    Ok(Some((energy, samples)))
}

/// The mini-batch epoch loop, shared by [`MiniBatchSolver`] and the
/// session/coordinator path (which hands in the session's warm workspace).
pub(crate) fn run_on_workspace(
    cfg: &MiniBatchConfig,
    ws: &mut Workspace,
    source: &mut dyn ChunkSource,
    c0: &DataMatrix,
    observer: &mut dyn Observer,
    cancel: &CancelToken,
) -> Result<RunReport, ClusterError> {
    // Typed validation, not asserts: MiniBatchSolver::run is a public
    // entry point with the same fallible-API contract as ClusterSession.
    if c0.d() != source.d() {
        return Err(ClusterError::invalid(
            "init",
            format!(
                "initial centroids are {}-dimensional but the source is {}-dimensional",
                c0.d(),
                source.d()
            ),
        ));
    }
    if c0.n() == 0 {
        return Err(ClusterError::invalid("k", "at least one centroid is required"));
    }
    let sw = Stopwatch::start();
    let mut phases = PhaseTimer::new();
    let (k, d) = (c0.n(), c0.d());
    let dim = k * d;
    let chunk_rows = cfg.chunk_size.max(1);
    let (use_aa, m0, dynamic) = match cfg.solver.accel {
        Acceleration::None => (false, 0, false),
        Acceleration::FixedM(m) => (true, m, false),
        Acceleration::DynamicM(m) => (true, m, true),
    };
    // Epoch batch budget: an explicit cap, a full pass for bounded
    // sources, or the defensive cap for custom unbounded generators.
    let epoch_batches = if cfg.batches_per_epoch > 0 {
        cfg.batches_per_epoch
    } else if source.len().is_some() {
        usize::MAX
    } else {
        UNBOUNDED_EPOCH_BATCHES
    };
    let eval_batches = if source.len().is_some() {
        usize::MAX
    } else {
        epoch_batches
    };

    ws.scratch.begin_run();
    ws.engine.reset();
    let evals0 = ws.engine.distance_evals();
    observer.on_start(c0, c0);

    // Every buffer below comes from the workspace scratch: warm reruns of
    // the same shape perform no allocation in the epoch loop.
    let mut c = ws.scratch.take_output_mat(k, d);
    c.as_mut_slice().copy_from_slice(c0.as_slice());
    // Take order mirrors the put order below (LIFO pool): the chunk
    // buffer keeps its large allocation across runs instead of rotating
    // into a centroid-sized slot.
    let mut chunk = ws.scratch.take_mat(chunk_rows, d);
    let mut c_prev = ws.scratch.take_mat(k, d);
    let mut c_prop = ws.scratch.take_mat(k, d);
    let mut assign = ws.scratch.take_assign();
    // Anderson state only exists for accelerated runs: a plain mini-batch
    // run neither allocates the m̄ history columns nor the residual.
    let mut aa_state: Option<(AndersonAccelerator, Vec<f64>)> = if use_aa {
        let acc = ws.scratch.take_accelerator(cfg.solver.m_max.max(1), dim);
        Some((acc, ws.scratch.take_f_t(dim)))
    } else {
        None
    };
    let mut counts = ws.scratch.take_trace_f64();
    counts.clear();
    counts.resize(k, 0.0);
    let mut trace = if cfg.solver.record_trace {
        ws.scratch.take_trace_f64()
    } else {
        Vec::new()
    };
    let mut m_trace = if cfg.solver.record_trace {
        ws.scratch.take_trace_usize()
    } else {
        Vec::new()
    };
    let mut controller = MController::new(
        m0.min(cfg.solver.m_max),
        cfg.solver.m_max,
        cfg.solver.epsilon1,
        cfg.solver.epsilon2,
    );

    let mut e_prev = f64::INFINITY;
    let mut decrease_prev = f64::INFINITY;
    let mut epochs = 0usize;
    let mut accepted = 0usize;
    let mut rejects = 0u32;
    let mut eval_samples = 0u64;
    let mut converged = false;
    let mut cancelled = false;
    let mut stopped_early = false;
    let mut mid_epoch_break = false;
    // Source failures abort the run but must still flow past the buffer
    // put-backs below (a transient IO error must not strip the workspace
    // of its warm scratch), so they are carried out of the loop instead
    // of early-returned.
    let mut stream_error: Option<ClusterError> = None;

    'epochs: for _epoch in 1..=cfg.solver.max_iters {
        if cancel.is_cancelled() || over_budget(&sw, cfg.solver.time_limit) {
            cancelled = cancel.is_cancelled();
            stopped_early = !cancelled;
            break;
        }
        // ---- Mini-batch pass: one application of the epoch map G.
        c_prev.as_mut_slice().copy_from_slice(c.as_slice());
        source.rewind();
        let mut batches = 0usize;
        while batches < epoch_batches {
            let got = match source.next_chunk(chunk_rows, &mut chunk) {
                Ok(got) => got,
                Err(e) => {
                    stream_error = Some(e);
                    break 'epochs;
                }
            };
            if got == 0 {
                break;
            }
            // Every chunk is a fresh sample set: drop any per-sample bound
            // state first. The default mini-batch engine (Naive) keeps no
            // state and only re-derives small per-chunk norm caches, but a
            // caller-configured bound engine (Hamerly/Elkan/Yinyang) would
            // otherwise prune the new chunk with the previous chunk's
            // bounds — same shapes, different samples — and silently
            // mis-assign.
            ws.engine.reset();
            phases.time("assign", || ws.engine.assign(&chunk, &c, &ws.pool, &mut assign));
            phases.time("update", || {
                for i in 0..got {
                    let j = assign[i] as usize;
                    debug_assert!(j < k, "assignment out of range");
                    counts[j] += 1.0;
                    let eta = 1.0 / counts[j];
                    let row = chunk.row(i);
                    let dst = c.row_mut(j);
                    for t in 0..d {
                        dst[t] += eta * (row[t] - dst[t]);
                    }
                }
            });
            batches += 1;
            // Batch boundary: cancellation and budgets land within one
            // chunk. The partial epoch is discarded below so the returned
            // state is always an epoch-boundary iterate with an exact
            // checkpoint energy.
            if cancel.is_cancelled() || over_budget(&sw, cfg.solver.time_limit) {
                cancelled = cancel.is_cancelled();
                stopped_early = !cancelled;
                mid_epoch_break = true;
                break 'epochs;
            }
        }
        if batches == 0 {
            // Empty source: the initial centroids are already the answer.
            converged = true;
            break;
        }
        // ---- Full-energy checkpoint at the smoothed iterate G_e (it
        // yields at batch boundaries exactly like the training pass).
        let (e_g, n_eval) = match checkpoint_energy(
            ws,
            source,
            &c,
            &mut chunk,
            &mut assign,
            chunk_rows,
            eval_batches,
            &mut phases,
            cancel,
            &sw,
            cfg.solver.time_limit,
        ) {
            Ok(Some(measured)) => measured,
            Ok(None) => {
                // Interrupted before this epoch's energy was measured: the
                // epoch is discarded like any other mid-pass break.
                cancelled = cancel.is_cancelled();
                stopped_early = !cancelled;
                mid_epoch_break = true;
                break;
            }
            Err(e) => {
                stream_error = Some(e);
                break;
            }
        };
        epochs += 1;
        eval_samples = n_eval;
        let mut e = e_g;
        // Dynamic-m safeguard on the epoch-energy decrease ratio.
        if dynamic {
            controller.adjust(e_prev - e_g, decrease_prev);
        }
        // ---- Anderson step on the epoch sequence, guarded by the
        // checkpoint energy (reject ⇒ keep the plain mini-batch iterate).
        let mut candidate = false;
        let mut accepted_this = false;
        if let Some((acc, f_t)) = aa_state.as_mut() {
            candidate = phases.time("anderson", || {
                crate::linalg::sub(c.as_slice(), c_prev.as_slice(), f_t);
                acc.propose_into(c.as_slice(), f_t, controller.m(), c_prop.as_mut_slice())
            });
            if candidate {
                match checkpoint_energy(
                    ws,
                    source,
                    &c_prop,
                    &mut chunk,
                    &mut assign,
                    chunk_rows,
                    eval_batches,
                    &mut phases,
                    cancel,
                    &sw,
                    cfg.solver.time_limit,
                ) {
                    Ok(Some((e_p, _))) if e_p < e_g => {
                        c.as_mut_slice().copy_from_slice(c_prop.as_slice());
                        e = e_p;
                        accepted += 1;
                        accepted_this = true;
                        rejects = 0;
                    }
                    Ok(Some(_)) => {
                        rejects += 1;
                        if rejects >= RESTART_AFTER_REJECTS {
                            acc.reset();
                            rejects = 0;
                        }
                    }
                    // Interrupted mid-guard: keep the plain iterate (its
                    // energy e_g is exact); the next epoch-top check ends
                    // the run before any further work.
                    Ok(None) => {}
                    Err(e) => {
                        stream_error = Some(e);
                        break;
                    }
                }
            }
        }
        if cfg.solver.record_trace {
            trace.push(e);
            m_trace.push(controller.m());
        }
        let plateaued = e_prev.is_finite()
            && (e_prev - e).abs() <= cfg.convergence_tol * e_prev.abs().max(f64::MIN_POSITIVE);
        decrease_prev = e_prev - e;
        e_prev = e;
        let control = observer.on_iteration(&IterationInfo {
            iteration: epochs,
            energy: Some(e),
            m: controller.m(),
            accelerated_candidate: candidate,
            accepted: accepted_this,
            centroids: &c,
            phases: &phases,
        });
        if control == ObserverControl::Stop {
            stopped_early = true;
            break;
        }
        if plateaued {
            converged = true;
            break;
        }
    }

    // An interrupted epoch is discarded: revert to the last epoch-boundary
    // iterate, whose checkpoint energy (`e_prev`) is exact.
    if mid_epoch_break {
        c.as_mut_slice().copy_from_slice(c_prev.as_slice());
    }
    let (energy, n_eval) = if stream_error.is_some() {
        (f64::INFINITY, 1)
    } else if epochs > 0 {
        (e_prev, eval_samples.max(1))
    } else if cancelled {
        // Fast cancel before the first checkpoint: no energy measured.
        (f64::INFINITY, 1)
    } else {
        // No epoch completed (empty source / immediate stop): measure the
        // returned centroids once — unless the budget is already gone, in
        // which case the interruptible pass bails on its first batch.
        match checkpoint_energy(
            ws,
            source,
            &c,
            &mut chunk,
            &mut assign,
            chunk_rows,
            eval_batches,
            &mut phases,
            cancel,
            &sw,
            cfg.solver.time_limit,
        ) {
            Ok(Some((e0, n0))) => (e0, n0.max(1)),
            Ok(None) => (f64::INFINITY, 1),
            Err(e) => {
                stream_error = Some(e);
                (f64::INFINITY, 1)
            }
        }
    };

    ws.scratch.put_mat(c_prop);
    ws.scratch.put_mat(c_prev);
    ws.scratch.put_mat(chunk);
    ws.scratch.put_assign(assign);
    if let Some((acc, f_t)) = aa_state {
        ws.scratch.put_f_t(f_t);
        ws.scratch.put_accelerator(acc);
    }
    ws.scratch.put_trace_f64(counts);
    // Buffers are home; only now may a carried source failure surface.
    if let Some(e) = stream_error {
        return Err(e);
    }
    let report = RunReport {
        iterations: epochs,
        accepted,
        seconds: sw.seconds(),
        energy,
        mse: energy / n_eval as f64,
        converged,
        cancelled,
        stopped_early,
        energy_trace: trace,
        m_trace,
        dist_evals: ws.engine.distance_evals() - evals0,
        phases,
        centroids: c,
        assignment: lloyd::Assignment::new(),
    };
    observer.on_finish(&report);
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::chunks::{InMemoryChunks, SynthChunks};
    use crate::data::synth;
    use crate::init::{seed_centroids, InitMethod};
    use crate::lloyd::brute_force_assign;
    use crate::par::ThreadPool;
    use crate::rng::Pcg32;
    use std::sync::Arc;

    fn cfg(accel: Acceleration, chunk: usize) -> MiniBatchConfig {
        MiniBatchConfig {
            solver: SolverConfig {
                engine: crate::config::EngineKind::MiniBatch,
                accel,
                threads: 1,
                max_iters: 60,
                record_trace: true,
                ..SolverConfig::default()
            },
            chunk_size: chunk,
            batches_per_epoch: 0,
            convergence_tol: 1e-5,
        }
    }

    #[test]
    fn clusters_in_memory_blobs_to_good_energy() {
        let mut rng = Pcg32::seed_from_u64(42);
        let x = Arc::new(synth::gaussian_blobs(&mut rng, 4000, 4, 5, 3.0, 0.2));
        let mut srng = Pcg32::seed_from_u64(7);
        let c0 = seed_centroids(&x, 5, InitMethod::KMeansPlusPlus, &mut srng);
        let mut solver = MiniBatchSolver::try_new(cfg(Acceleration::DynamicM(2), 512)).unwrap();
        let mut source = InMemoryChunks::new(Arc::clone(&x));
        let report = solver.run(&mut source, &c0).unwrap();
        assert!(report.iterations >= 1);
        assert!(report.energy.is_finite() && report.energy > 0.0);
        assert_eq!(report.centroids.n(), 5);
        assert!(report.assignment.is_empty(), "streamed runs carry no assignment");
        // The reported energy is exact for the reported centroids.
        let pool = ThreadPool::new(1);
        let assign = brute_force_assign(&x, &report.centroids);
        let exact = lloyd::energy(&x, &report.centroids, &assign, &pool);
        assert!(
            (exact - report.energy).abs() <= 1e-6 * exact.max(1.0),
            "checkpoint energy {} vs exact {exact}",
            report.energy
        );
    }

    #[test]
    fn epoch_trace_has_one_entry_per_epoch() {
        let mut source = SynthChunks::new(9, 3000, 3, 4, 2.0, 0.3);
        let seed_buf =
            crate::data::chunks::collect_source(&mut source, 512, 1024).unwrap();
        let mut srng = Pcg32::seed_from_u64(3);
        let c0 = seed_centroids(&seed_buf, 4, InitMethod::KMeansPlusPlus, &mut srng);
        let mut solver = MiniBatchSolver::try_new(cfg(Acceleration::DynamicM(2), 500)).unwrap();
        let report = solver.run(&mut source, &c0).unwrap();
        assert_eq!(report.energy_trace.len(), report.iterations);
        assert_eq!(report.m_trace.len(), report.iterations);
        assert!(report.accepted <= report.iterations);
    }

    #[test]
    fn warm_reruns_reuse_workspace_and_are_deterministic() {
        let mut rng = Pcg32::seed_from_u64(5);
        let x = Arc::new(synth::gaussian_blobs(&mut rng, 2000, 3, 4, 2.5, 0.25));
        let mut srng = Pcg32::seed_from_u64(5);
        let c0 = seed_centroids(&x, 4, InitMethod::KMeansPlusPlus, &mut srng);
        let mut solver = MiniBatchSolver::try_new(cfg(Acceleration::DynamicM(2), 256)).unwrap();
        let mut source = InMemoryChunks::new(Arc::clone(&x));
        let r1 = solver.run(&mut source, &c0).unwrap();
        assert!(solver.workspace().last_run_rebuilt_scratch());
        let (it1, e1) = (r1.iterations, r1.energy);
        solver.ws.recycle(r1);
        source.rewind();
        let r2 = solver.run(&mut source, &c0).unwrap();
        assert!(
            !solver.workspace().last_run_rebuilt_scratch(),
            "second same-shape run must reuse the workspace scratch"
        );
        assert_eq!(r2.iterations, it1, "deterministic source ⇒ identical reruns");
        assert_eq!(r2.energy.to_bits(), e1.to_bits());
    }

    #[test]
    fn cancel_before_first_epoch_reports_cancelled() {
        let mut rng = Pcg32::seed_from_u64(6);
        let x = Arc::new(synth::gaussian_blobs(&mut rng, 1000, 3, 4, 2.0, 0.3));
        let c0 = x.gather_rows(&[0, 1, 2, 3]);
        let mut solver = MiniBatchSolver::try_new(cfg(Acceleration::None, 128)).unwrap();
        let mut source = InMemoryChunks::new(x);
        let token = CancelToken::new();
        token.cancel();
        let report =
            solver.run_observed(&mut source, &c0, &mut NoopObserver, &token).unwrap();
        assert!(report.cancelled);
        assert_eq!(report.iterations, 0);
        assert_eq!(report.centroids.as_slice(), c0.as_slice(), "state reverts to c0");
    }

    #[test]
    fn plain_minibatch_matches_sculley_reference() {
        // One epoch of the solver with Acceleration::None equals a direct
        // transcription of Sculley's update on the same chunk order.
        let mut rng = Pcg32::seed_from_u64(8);
        let x = Arc::new(synth::gaussian_blobs(&mut rng, 700, 2, 3, 2.0, 0.3));
        let c0 = x.gather_rows(&[0, 300, 600]);
        let mut config = cfg(Acceleration::None, 100);
        config.solver.max_iters = 1;
        let mut solver = MiniBatchSolver::try_new(config).unwrap();
        let mut source = InMemoryChunks::new(Arc::clone(&x));
        let report = solver.run(&mut source, &c0).unwrap();

        // Reference implementation.
        let mut c = c0.clone();
        let mut counts = vec![0.0f64; 3];
        for start in (0..x.n()).step_by(100) {
            let idx: Vec<usize> = (start..(start + 100).min(x.n())).collect();
            let chunk = x.gather_rows(&idx);
            let assign = brute_force_assign(&chunk, &c);
            for i in 0..chunk.n() {
                let j = assign[i] as usize;
                counts[j] += 1.0;
                let eta = 1.0 / counts[j];
                for t in 0..2 {
                    c[(j, t)] += eta * (chunk[(i, t)] - c[(j, t)]);
                }
            }
        }
        for j in 0..3 {
            for t in 0..2 {
                assert!(
                    (report.centroids[(j, t)] - c[(j, t)]).abs() < 1e-9,
                    "centroid {j} dim {t}: {} vs reference {}",
                    report.centroids[(j, t)],
                    c[(j, t)]
                );
            }
        }
    }
}
