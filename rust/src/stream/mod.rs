//! Streaming mini-batch K-Means with epoch-level Anderson acceleration.
//!
//! [`MiniBatchSolver`] runs Sculley-style mini-batch K-Means (per-batch
//! assign + per-centroid decaying learning rates) over any
//! [`ChunkSource`], so only one chunk of samples is resident at a time —
//! datasets far larger than RAM stream through the same SIMD assign
//! kernels the full-batch engines use. On top of the batch loop it applies
//! the paper's machinery at *epoch* granularity: one pass over the source
//! is one application of a fixed-point map `C_e = G(C_{e-1})` (exactly
//! deterministic for the default [`BatchSampling::Sequential`] — all
//! built-in sources replay identical chunks after a rewind), and the
//! smoothed per-epoch centroid sequence is Anderson-extrapolated with the
//! dynamic-`m` safeguard from [`crate::anderson`]. Every epoch ends with a
//! full-energy checkpoint over the source; the checkpoint guards AA
//! proposals (reject on non-decrease, Algorithm 1 lines 13–15), drives the
//! dynamic-`m` controller, restarts the AA history after repeated
//! rejections, and decides convergence.
//!
//! The epoch loop itself is a private `EpochStep` driven by the shared
//! safeguarded-Anderson [`crate::accel::FixedPointDriver`] (immediate
//! guard: an epoch is a full pass over the data, far too expensive to
//! spend on an unguarded extrapolation) — the same audited accept/reject
//! implementation the full-batch solver uses.
//!
//! [`BatchSampling::Replacement`] switches the per-epoch batches from the
//! deterministic sequential pass to sampling-with-replacement draws (the
//! classic mini-batch regime: gradient shuffling at the cost of an
//! epoch map that is no longer the same map every epoch). The checkpoint
//! energies stay exact full passes, so the guard, the dynamic-`m` rule
//! and the convergence test are unaffected; the draw stream is seeded
//! from the request, so reruns stay reproducible.
//!
//! The solver runs on the same reusable [`Workspace`] as the full-batch
//! path — chunk buffer, assignment buffer, Anderson history and the
//! per-centroid counters are all drawn from (and returned to) the
//! workspace scratch, so warm reruns allocate nothing. The higher-level
//! entry point is a [`crate::ClusterRequest`] with
//! `EngineKind::MiniBatch`, which routes [`crate::ClusterSession`] (and
//! therefore the coordinator) through this module.

pub mod prefetch;

use crate::accel::{Advance, Budget, DriverConfig, FixedPointDriver, GuardMode, Step};
use crate::anderson::AndersonAccelerator;
use crate::config::{Acceleration, SolverConfig};
use crate::data::chunks::ChunkSource;
use crate::data::DataMatrix;
use crate::error::ClusterError;
use crate::kmeans::{RunReport, Workspace, WorkspaceSpec};
use crate::lloyd;
use crate::metrics::{PhaseTimer, Stopwatch};
use crate::observe::{CancelToken, NoopObserver, Observer};
use crate::persist::{self, DriverSnap, SolverSnapshot, StreamSnap};
use crate::rng::{Pcg32, Rng};

/// Batch cap per epoch for custom unbounded sources that neither report a
/// length nor run out (all built-in sources are bounded per pass).
const UNBOUNDED_EPOCH_BATCHES: usize = 64;

/// Consecutive rejected Anderson proposals after which the history is
/// dropped (restart): epoch-level residuals are noisier than full-batch
/// ones, and a stale history that keeps proposing uphill extrapolations
/// is worse than starting fresh.
const RESTART_AFTER_REJECTS: u32 = 2;

/// How each epoch draws its mini-batches from the source.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BatchSampling {
    /// One deterministic pass: the epoch streams the source's chunks in
    /// order. The epoch map is the same map every epoch, which is the
    /// friendliest regime for the epoch-level Anderson step — the
    /// default, and the pre-knob behavior.
    #[default]
    Sequential,
    /// Each batch draws `chunk_size` rows uniformly with replacement
    /// (seeded from the request): Sculley's i.i.d. mini-batch regime,
    /// trading epoch-map determinism for gradient shuffling. Requires a
    /// bounded source ([`ChunkSource::len`] = `Some`); prefer sources
    /// with random-access [`ChunkSource::gather_rows`] overrides
    /// (in-memory, mmap shards) — generator sources fall back to a
    /// re-streaming gather that costs roughly one extra pass per batch.
    Replacement,
}

impl BatchSampling {
    /// Parse from a config / CLI string.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "sequential" => Some(Self::Sequential),
            "replacement" => Some(Self::Replacement),
            _ => None,
        }
    }

    /// Canonical name.
    pub fn name(&self) -> &'static str {
        match self {
            Self::Sequential => "sequential",
            Self::Replacement => "replacement",
        }
    }
}

/// Seed salt for the sampled-guard reservoir draw: the reservoir must be
/// decorrelated from the replacement-sampling draw stream, which is seeded
/// from the same request seed.
const GUARD_RESERVOIR_SALT: u64 = 0x5eed_9a7d_0f3b_c4e1;

/// How the epoch-level energy checkpoints — the measurements behind the
/// AA guard, the dynamic-`m` controller and the convergence test — are
/// computed.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EnergyGuard {
    /// One exact full pass over the source per checkpoint: the default,
    /// and the pre-knob behavior. Two checkpoints per epoch under the
    /// immediate guard — on out-of-core shards that is two extra scans of
    /// the whole dataset per epoch.
    #[default]
    Exact,
    /// Estimate every checkpoint from a fixed reservoir of `rows`
    /// distinct samples, drawn once per run from the request seed
    /// (Floyd's algorithm). The *same* reservoir scores the committed
    /// iterate and each Anderson candidate, so the guard's accept/reject
    /// comparisons see a common, unbiased estimator rather than fresh
    /// noise per measurement. Requires a bounded source; `rows >= n`
    /// degenerates to scoring every sample (bit-identical energies to
    /// [`EnergyGuard::Exact`], in reservoir order).
    Sampled {
        /// Reservoir size in samples.
        rows: usize,
    },
}

impl EnergyGuard {
    /// Parse from a config / CLI string: `exact` or `sampled:N`.
    pub fn parse(s: &str) -> Option<Self> {
        let s = s.to_ascii_lowercase();
        if s == "exact" {
            return Some(Self::Exact);
        }
        if let Some(rows) = s.strip_prefix("sampled:") {
            return rows.parse::<usize>().ok().map(|rows| Self::Sampled { rows });
        }
        None
    }

    /// Canonical name (round-trips through [`EnergyGuard::parse`]).
    pub fn name(&self) -> String {
        match self {
            Self::Exact => "exact".to_string(),
            Self::Sampled { rows } => format!("sampled:{rows}"),
        }
    }
}

/// Configuration of one streaming mini-batch run.
#[derive(Debug, Clone)]
pub struct MiniBatchConfig {
    /// Solver-level knobs reused from the full-batch path: `accel` /
    /// `epsilon1` / `epsilon2` / `m_max` drive the epoch-level Anderson
    /// step, `max_iters` caps *epochs*, `time_limit` is checked at batch
    /// boundaries, `threads` / `precision` size the workspace.
    pub solver: SolverConfig,
    /// Samples per mini-batch chunk (peak resident sample count).
    pub chunk_size: usize,
    /// Mini-batches per epoch; 0 = one full pass over the source. With a
    /// positive cap each epoch streams the first `batches_per_epoch`
    /// chunks of a pass, keeping the epoch map deterministic.
    pub batches_per_epoch: usize,
    /// Relative epoch-energy change below which the run converges.
    pub convergence_tol: f64,
    /// How each epoch draws its batches (see [`BatchSampling`]).
    pub sampling: BatchSampling,
    /// Seed for the replacement-sampling draw stream (ignored by
    /// [`BatchSampling::Sequential`]); re-seeded per run so warm reruns
    /// stay deterministic.
    pub seed: u64,
    /// Serve chunks through the background prefetch pipeline
    /// ([`prefetch::PrefetchSource`]). Consumed by the owners of the
    /// source — the session path wraps its shard / in-memory source when
    /// set; [`MiniBatchSolver::run`] borrows the source and leaves
    /// wrapping to the caller. Off by default. Chunk order is preserved
    /// exactly, so this knob never changes a trajectory.
    pub prefetch: bool,
    /// How checkpoint energies are measured (see [`EnergyGuard`]).
    /// [`EnergyGuard::Sampled`] changes the trajectory and is baked into
    /// the snapshot fingerprint; the default stays exact.
    pub guard: EnergyGuard,
    /// Pin the pool's worker lanes (and the prefetcher thread, when both
    /// knobs are set) to distinct CPUs — Linux only, a no-op elsewhere.
    /// Placement-only: never changes a trajectory.
    pub pin_threads: bool,
}

impl Default for MiniBatchConfig {
    fn default() -> Self {
        Self {
            solver: SolverConfig {
                engine: crate::config::EngineKind::MiniBatch,
                ..SolverConfig::default()
            },
            chunk_size: 4096,
            batches_per_epoch: 0,
            convergence_tol: 1e-4,
            sampling: BatchSampling::Sequential,
            seed: 42,
            prefetch: false,
            guard: EnergyGuard::Exact,
            pin_threads: false,
        }
    }
}

/// Where the epoch loop writes its durable snapshots (resolved from
/// [`crate::persist::CheckpointPolicy`] once per run).
struct StreamCkpt {
    dir: std::path::PathBuf,
    every: usize,
    fingerprint: String,
}

/// Identity string baked into mini-batch snapshots. Excludes `max_iters`
/// (a capped run may be resumed with a larger epoch budget) and the trace
/// knobs; everything that shapes the epoch trajectory — including the
/// batch layout and the seeded draw stream — is included, so a snapshot
/// resumed under the same fingerprint replays the exact batch sequence.
fn stream_fingerprint(cfg: &MiniBatchConfig, k: usize, d: usize) -> String {
    let mut fp = format!(
        "aakm-stream-v1 k={k} d={d} seed={} precision={} accel={} m_max={} eps1={} \
         eps2={} chunk={} bpe={} tol={} sampling={} reseed={}",
        cfg.seed,
        cfg.solver.precision.name(),
        cfg.solver.accel.label(),
        cfg.solver.m_max,
        cfg.solver.epsilon1,
        cfg.solver.epsilon2,
        cfg.chunk_size,
        cfg.batches_per_epoch,
        cfg.convergence_tol,
        cfg.sampling.name(),
        cfg.solver.reseed_empty,
    );
    // The sampled guard changes the trajectory, so it must fence resume;
    // the exact default appends nothing, keeping pre-knob snapshots
    // loadable. Prefetch and pinning are deliberately excluded — neither
    // affects a single bit of the trajectory, so a run may resume with
    // either toggled.
    if let EnergyGuard::Sampled { rows } = cfg.guard {
        use std::fmt::Write;
        let _ = write!(fp, " guard=sampled:{rows}");
    }
    fp
}

/// Anderson-accelerated mini-batch solver over a reusable [`Workspace`].
pub struct MiniBatchSolver {
    cfg: MiniBatchConfig,
    ws: Workspace,
}

impl MiniBatchSolver {
    /// Build a solver (and a fresh workspace) for `cfg`.
    pub fn try_new(cfg: MiniBatchConfig) -> Result<Self, ClusterError> {
        let ws = Workspace::open(&WorkspaceSpec::from_config(&cfg.solver))?;
        Ok(Self { cfg, ws })
    }

    /// Build a solver over an existing (warm) workspace.
    pub fn from_workspace(cfg: MiniBatchConfig, ws: Workspace) -> Self {
        Self { cfg, ws }
    }

    /// Configuration in use.
    pub fn config(&self) -> &MiniBatchConfig {
        &self.cfg
    }

    /// The workspace backing this solver.
    pub fn workspace(&self) -> &Workspace {
        &self.ws
    }

    /// Release the workspace for reuse.
    pub fn into_workspace(self) -> Workspace {
        self.ws
    }

    /// Run mini-batch epochs over `source` from the initial centroids
    /// `c0` until the epoch energy plateaus (convergence), the epoch cap
    /// (`solver.max_iters`) or the time budget is reached.
    pub fn run(
        &mut self,
        source: &mut dyn ChunkSource,
        c0: &DataMatrix,
    ) -> Result<RunReport, ClusterError> {
        self.run_observed(source, c0, &mut NoopObserver, &CancelToken::new())
    }

    /// [`MiniBatchSolver::run`] with an [`Observer`] called once per
    /// *epoch* (the iteration granularity of this solver; `energy` is the
    /// epoch's full checkpoint energy) and a [`CancelToken`] checked at
    /// every batch boundary. In the returned report, `iterations` counts
    /// epochs, `accepted` counts epochs whose Anderson proposal passed the
    /// energy guard, and `assignment` is empty — a streamed dataset has no
    /// resident assignment vector. Because the full dataset is never
    /// resident, `Observer::on_start` receives the initial centroids as
    /// its data argument.
    pub fn run_observed(
        &mut self,
        source: &mut dyn ChunkSource,
        c0: &DataMatrix,
        observer: &mut dyn Observer,
        cancel: &CancelToken,
    ) -> Result<RunReport, ClusterError> {
        run_on_workspace(&self.cfg, &mut self.ws, source, c0, observer, cancel)
    }
}

/// One epoch of the mini-batch map plus its exact-energy checkpoint, as a
/// [`Step`] for the shared safeguarded-Anderson driver (immediate guard).
struct EpochStep<'a> {
    ws: &'a mut Workspace,
    source: &'a mut dyn ChunkSource,
    budget: Budget<'a>,
    phases: PhaseTimer,
    /// Committed iterate (mutated in place by the mini-batch pass).
    c: DataMatrix,
    /// Iterate at the top of the current epoch (partial-epoch revert
    /// target, and the Anderson residual's base point).
    c_prev: DataMatrix,
    /// Staged Anderson proposal awaiting the immediate guard.
    c_prop: DataMatrix,
    chunk: DataMatrix,
    assign: lloyd::Assignment,
    /// Anderson residual buffer (`None` for un-accelerated runs, which
    /// never allocate AA state).
    f_t: Option<Vec<f64>>,
    /// Per-centroid assigned-sample counts (learning-rate denominators).
    counts: Vec<f64>,
    chunk_rows: usize,
    epoch_batches: usize,
    eval_batches: usize,
    /// Samples covered by the last epoch checkpoint (for the report MSE).
    eval_samples: u64,
    convergence_tol: f64,
    sampling: BatchSampling,
    /// Draw stream + index scratch for [`BatchSampling::Replacement`].
    sample_rng: Pcg32,
    sample_idx: Vec<usize>,
    source_len: Option<usize>,
    /// Epoch-start copies of the learning-rate counters and the draw
    /// stream: a mid-epoch interrupt reverts to them (alongside `c_prev`)
    /// so the committed state is always an exact epoch boundary — which
    /// is also what makes a resumed run replay the same batch sequence.
    counts_prev: Vec<f64>,
    rng_prev: (u64, u64),
    /// How checkpoint energies are measured.
    guard: EnergyGuard,
    /// Sorted reservoir indices scored by the sampled guard (empty under
    /// the exact guard). Drawn once per run; both the committed iterate
    /// and every Anderson candidate are scored on exactly these rows.
    eval_idx: Vec<usize>,
    /// Durable-snapshot destination (`None` = checkpointing off).
    ckpt: Option<StreamCkpt>,
    /// `Some(seed)` turns on the streaming empty-cluster re-seed policy.
    reseed_seed: Option<u64>,
}

impl EpochStep<'_> {
    /// Produce the next training batch into the chunk buffer: the next
    /// sequential chunk, or a sorted with-replacement draw.
    fn next_train_chunk(&mut self) -> Result<usize, ClusterError> {
        let got = match self.sampling {
            BatchSampling::Sequential => self.source.next_chunk(self.chunk_rows, &mut self.chunk)?,
            BatchSampling::Replacement => {
                let n = self.source_len.expect("replacement sampling requires a bounded source");
                if n == 0 {
                    // An empty source has nothing to draw from; report an
                    // exhausted pass so the epoch converges on the initial
                    // centroids, exactly like the sequential path.
                    return Ok(0);
                }
                self.sample_idx.clear();
                for _ in 0..self.chunk_rows {
                    let i = self.sample_rng.next_below(n);
                    self.sample_idx.push(i);
                }
                // Ascending order lets every source gather in one forward
                // sweep; the multiset of drawn rows is what matters to the
                // update, not their order.
                self.sample_idx.sort_unstable();
                self.source.gather_rows(&self.sample_idx, &mut self.chunk)?;
                self.chunk_rows
            }
        };
        if got > 0 && crate::telemetry::enabled() {
            let t = crate::telemetry::metrics();
            t.stream_chunks.inc();
            t.stream_rows.add(got as u64);
        }
        Ok(got)
    }

    /// One energy checkpoint of the committed iterate (or, for the
    /// immediate guard, the staged candidate): an exact full pass or the
    /// fixed-reservoir estimate, per the configured [`EnergyGuard`].
    /// Returns `Ok(None)` when the budget trips mid-pass — like the
    /// training pass, the checkpoint yields at batch boundaries so
    /// cancellation latency on out-of-core data is one chunk, not one
    /// full dataset scan.
    fn checkpoint_pass(&mut self, of_candidate: bool) -> Result<Option<(f64, u64)>, ClusterError> {
        match self.guard {
            EnergyGuard::Exact => self.checkpoint_exact(of_candidate),
            EnergyGuard::Sampled { .. } => self.checkpoint_sampled(of_candidate),
        }
    }

    /// The exact checkpoint: rewind the source and accumulate the
    /// clustering energy over up to `eval_batches` chunks.
    fn checkpoint_exact(&mut self, of_candidate: bool) -> Result<Option<(f64, u64)>, ClusterError> {
        let Self {
            ws,
            source,
            budget,
            phases,
            chunk,
            assign,
            c,
            c_prop,
            chunk_rows,
            eval_batches,
            ..
        } = self;
        let target: &DataMatrix = if of_candidate { c_prop } else { c };
        source.rewind();
        let mut energy = 0.0;
        let mut samples = 0u64;
        let mut batches = 0usize;
        while batches < *eval_batches {
            if budget.interrupted().is_some() {
                return Ok(None);
            }
            let got = source.next_chunk(*chunk_rows, chunk)?;
            if got == 0 {
                break;
            }
            if crate::telemetry::enabled() {
                let t = crate::telemetry::metrics();
                t.stream_chunks.inc();
                t.stream_rows.add(got as u64);
            }
            // Per-chunk reset, as in the training pass: never let bound
            // state from one chunk's samples prune another's.
            ws.engine.reset();
            phases.time("energy", || {
                ws.engine.assign(chunk, target, &ws.pool, assign);
                energy += lloyd::energy(chunk, target, assign, &ws.pool);
            });
            samples += got as u64;
            batches += 1;
        }
        Ok(Some((energy, samples)))
    }

    /// The sampled checkpoint: score the fixed reservoir in chunk-sized
    /// gathers instead of rescanning the whole source. This is the cost
    /// [`EnergyGuard::Sampled`] removes — on a 10×-RAM shard the exact
    /// guard's two checkpoint scans per epoch dominate wall-clock.
    fn checkpoint_sampled(
        &mut self,
        of_candidate: bool,
    ) -> Result<Option<(f64, u64)>, ClusterError> {
        let Self {
            ws,
            source,
            budget,
            phases,
            chunk,
            assign,
            c,
            c_prop,
            chunk_rows,
            eval_idx,
            ..
        } = self;
        let target: &DataMatrix = if of_candidate { c_prop } else { c };
        let mut energy = 0.0;
        let mut samples = 0u64;
        let mut start = 0usize;
        while start < eval_idx.len() {
            if budget.interrupted().is_some() {
                return Ok(None);
            }
            let end = (start + *chunk_rows).min(eval_idx.len());
            source.gather_rows(&eval_idx[start..end], chunk)?;
            let got = end - start;
            if crate::telemetry::enabled() {
                let t = crate::telemetry::metrics();
                t.stream_chunks.inc();
                t.stream_rows.add(got as u64);
            }
            ws.engine.reset();
            phases.time("energy", || {
                ws.engine.assign(chunk, target, &ws.pool, assign);
                energy += lloyd::energy(chunk, target, assign, &ws.pool);
            });
            samples += got as u64;
            start = end;
        }
        Ok(Some((energy, samples)))
    }

    /// Throw away a partial epoch: centroids, learning-rate counters and
    /// the draw stream all return to their epoch-start values.
    fn revert_epoch(&mut self) {
        self.c.as_mut_slice().copy_from_slice(self.c_prev.as_slice());
        self.counts.copy_from_slice(&self.counts_prev);
        self.sample_rng = Pcg32::from_parts(self.rng_prev.0, self.rng_prev.1);
    }

    /// Streaming variant of the empty-cluster re-seed policy: a centroid
    /// that has absorbed no samples is moved next to the heaviest donor
    /// centroid with a small deterministic jitter, and the donor's mass is
    /// split between the two. The full dataset is never resident here, so
    /// unlike [`crate::lloyd::reseed_empty_clusters`] the new centroid
    /// adopts a perturbed donor *position* rather than a member sample —
    /// the jitter stream is seeded from the run seed and the current
    /// centroid bits, so reruns and checkpoint-resumed runs make the same
    /// choice.
    fn reseed_empty(&mut self) {
        let Some(seed) = self.reseed_seed else { return };
        if self.counts.iter().all(|&cnt| cnt > 0.0) {
            return;
        }
        let (k, d) = (self.c.n(), self.c.d());
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for &v in self.c.as_slice() {
            h = (h ^ v.to_bits()).wrapping_mul(0x0000_0100_0000_01B3);
        }
        let mut rng = Pcg32::seed_from_u64(seed ^ h);
        for j in 0..k {
            if self.counts[j] > 0.0 {
                continue;
            }
            let mut donor = j;
            for cand in 0..k {
                if self.counts[cand] > self.counts[donor] {
                    donor = cand;
                }
            }
            if self.counts[donor] < 2.0 {
                // Nothing heavy enough to split; later epochs may feed it.
                break;
            }
            for t in 0..d {
                let u = rng.next_u32() as f64 / u32::MAX as f64 - 0.5;
                let v = self.c[(donor, t)];
                self.c[(j, t)] = v + v.abs().max(1.0) * u * 1e-6;
            }
            let half = self.counts[donor] / 2.0;
            self.counts[donor] = half;
            self.counts[j] = half;
        }
    }
}

impl Step for EpochStep<'_> {
    fn advance(&mut self) -> Advance {
        let (k, d) = (self.c.n(), self.c.d());
        // ---- Mini-batch pass: one application of the epoch map G.
        // Everything a mid-epoch interrupt must revert is saved first:
        // the iterate, the learning-rate counters and the draw stream.
        self.c_prev.as_mut_slice().copy_from_slice(self.c.as_slice());
        self.counts_prev.copy_from_slice(&self.counts);
        self.rng_prev = self.sample_rng.state_parts();
        self.source.rewind();
        let mut batches = 0usize;
        while batches < self.epoch_batches {
            let got = match self.next_train_chunk() {
                Ok(got) => got,
                // Source failures abort the run but are carried out so
                // the caller restores the workspace buffers first (a
                // transient IO error must not strip the warm scratch).
                Err(e) => return Advance::Failed(e),
            };
            if got == 0 {
                break;
            }
            // Every chunk is a fresh sample set: drop any per-sample
            // bound state first. The default mini-batch engine (Naive)
            // keeps no state and only re-derives small per-chunk norm
            // caches, but a caller-configured bound engine
            // (Hamerly/Elkan/Yinyang) would otherwise prune the new chunk
            // with the previous chunk's bounds — same shapes, different
            // samples — and silently mis-assign.
            self.ws.engine.reset();
            let Self { ws, phases, chunk, c, assign, counts, .. } = self;
            phases.time("assign", || ws.engine.assign(chunk, c, &ws.pool, assign));
            phases.time("update", || {
                for i in 0..got {
                    let j = assign[i] as usize;
                    debug_assert!(j < k, "assignment out of range");
                    counts[j] += 1.0;
                    let eta = 1.0 / counts[j];
                    let row = chunk.row(i);
                    let dst = c.row_mut(j);
                    for t in 0..d {
                        dst[t] += eta * (row[t] - dst[t]);
                    }
                }
            });
            batches += 1;
            // Batch boundary: cancellation and budgets land within one
            // chunk. The partial epoch is discarded so the returned state
            // is always an epoch-boundary iterate with an exact
            // checkpoint energy.
            if let Some(cancelled) = self.budget.interrupted() {
                self.revert_epoch();
                return Advance::Interrupted { cancelled };
            }
        }
        if batches == 0 {
            // Empty source: the initial centroids are already the answer.
            return Advance::Converged;
        }
        // Opt-in recovery for centroids that have never absorbed a sample
        // (reverted with the rest of the epoch if the checkpoint below is
        // interrupted).
        self.reseed_empty();
        // ---- Full-energy checkpoint at the smoothed iterate G_e (it
        // yields at batch boundaries exactly like the training pass).
        match self.checkpoint_pass(false) {
            Ok(Some((e_g, n_eval))) => {
                self.eval_samples = n_eval;
                Advance::Evaluated(Some(e_g))
            }
            Ok(None) => {
                // Interrupted before this epoch's energy was measured:
                // the epoch is discarded like any other mid-pass break.
                self.revert_epoch();
                Advance::Interrupted { cancelled: self.budget.is_cancelled() }
            }
            Err(e) => Advance::Failed(e),
        }
    }

    fn propose(&mut self, acc: &mut AndersonAccelerator, m_use: usize) -> bool {
        let Self { phases, c, c_prev, c_prop, f_t, .. } = self;
        let f_t = f_t.as_mut().expect("accelerated runs carry the residual buffer");
        // Anderson step on the epoch sequence: residual against the
        // epoch's starting point, proposal staged for the immediate
        // guard.
        phases.time("anderson", || {
            crate::linalg::sub(c.as_slice(), c_prev.as_slice(), f_t);
            acc.propose_into(c.as_slice(), f_t, m_use, c_prop.as_mut_slice())
        })
    }

    fn evaluate_candidate(&mut self) -> Result<Option<f64>, ClusterError> {
        // The guard's measurement is a full checkpoint pass over the
        // staged candidate; its sample count is discarded (the epoch
        // checkpoint already set `eval_samples`).
        self.checkpoint_pass(true).map(|r| r.map(|(e, _)| e))
    }

    fn accept_candidate(&mut self) {
        self.c.as_mut_slice().copy_from_slice(self.c_prop.as_slice());
    }

    fn plateaued(&self, e_prev: f64, e: f64) -> bool {
        e_prev.is_finite()
            && (e_prev - e).abs() <= self.convergence_tol * e_prev.abs().max(f64::MIN_POSITIVE)
    }

    fn observe(&self) -> (&DataMatrix, &PhaseTimer) {
        (&self.c, &self.phases)
    }

    fn save_checkpoint(
        &mut self,
        driver: &DriverSnap,
        acc: Option<&AndersonAccelerator>,
    ) -> Result<(), ClusterError> {
        let Some(ck) = &self.ckpt else { return Ok(()) };
        // Epoch boundaries are the only snapshot points (the immediate
        // guard resolves every proposal within its epoch, so there is
        // never an outstanding candidate): the committed iterate, the
        // learning-rate counters and the draw stream pin the trajectory.
        let (rng_state, rng_inc) = self.sample_rng.state_parts();
        let snap = SolverSnapshot {
            fingerprint: ck.fingerprint.clone(),
            driver: driver.clone(),
            k: self.c.n(),
            d: self.c.d(),
            centroids: self.c.as_slice().to_vec(),
            anderson: acc.map(|a| a.snapshot()),
            full_batch: None,
            stream: Some(StreamSnap {
                counts: self.counts.clone(),
                rng_state,
                rng_inc,
                eval_samples: self.eval_samples,
            }),
        };
        persist::write_snapshot(&ck.dir, &snap).map(|_| ())
    }
}

/// The mini-batch epoch loop, shared by [`MiniBatchSolver`] and the
/// session/coordinator path (which hands in the session's warm workspace):
/// buffer setup from the workspace scratch, an [`EpochStep`] over the
/// shared driver, and report assembly.
pub(crate) fn run_on_workspace(
    cfg: &MiniBatchConfig,
    ws: &mut Workspace,
    source: &mut dyn ChunkSource,
    c0: &DataMatrix,
    observer: &mut dyn Observer,
    cancel: &CancelToken,
) -> Result<RunReport, ClusterError> {
    // Typed validation, not asserts: MiniBatchSolver::run is a public
    // entry point with the same fallible-API contract as ClusterSession.
    if c0.d() != source.d() {
        return Err(ClusterError::invalid(
            "init",
            format!(
                "initial centroids are {}-dimensional but the source is {}-dimensional",
                c0.d(),
                source.d()
            ),
        ));
    }
    if c0.n() == 0 {
        return Err(ClusterError::invalid("k", "at least one centroid is required"));
    }
    let source_len = source.len();
    if cfg.sampling == BatchSampling::Replacement && source_len.is_none() {
        return Err(ClusterError::invalid(
            "sampling",
            "sampling-with-replacement requires a bounded source (ChunkSource::len = Some)",
        ));
    }
    if let EnergyGuard::Sampled { rows } = cfg.guard {
        if rows == 0 {
            return Err(ClusterError::invalid(
                "guard",
                "the sampled energy guard needs at least one reservoir row (sampled:N, N >= 1)",
            ));
        }
        if source_len.is_none() {
            return Err(ClusterError::invalid(
                "guard",
                "the sampled energy guard requires a bounded source (ChunkSource::len = Some)",
            ));
        }
    }
    if cfg.pin_threads {
        ws.pool.pin_lanes();
    }
    let sw = Stopwatch::start();
    let (k, d) = (c0.n(), c0.d());
    let dim = k * d;
    let chunk_rows = cfg.chunk_size.max(1);
    let use_aa = !matches!(cfg.solver.accel, Acceleration::None);
    // Epoch batch budget: an explicit cap, a full pass for bounded
    // sources (one forward pass sequentially; the same number of draws
    // under replacement sampling), or the defensive cap for custom
    // unbounded generators.
    let epoch_batches = if cfg.batches_per_epoch > 0 {
        cfg.batches_per_epoch
    } else {
        match (cfg.sampling, source_len) {
            (BatchSampling::Sequential, Some(_)) => usize::MAX,
            (BatchSampling::Replacement, Some(n)) => n.div_ceil(chunk_rows).max(1),
            _ => UNBOUNDED_EPOCH_BATCHES,
        }
    };
    let eval_batches = if source_len.is_some() { usize::MAX } else { epoch_batches };

    // Durable checkpointing: resolve the policy and load + validate any
    // existing snapshot before touching the workspace. A corrupt, torn or
    // mismatched snapshot is a typed error, never a silent fresh start.
    let mut ckpt: Option<StreamCkpt> = None;
    let mut resume: Option<SolverSnapshot> = None;
    if let Some(policy) = cfg.solver.checkpoint.clone() {
        let fingerprint = stream_fingerprint(cfg, k, d);
        if let Some(snap) = persist::load_snapshot(&policy.dir)? {
            snap.check_fingerprint(&fingerprint, &policy.dir)?;
            if snap.stream.is_none() {
                return Err(ClusterError::Snapshot {
                    path: persist::snapshot_path(&policy.dir).display().to_string(),
                    reason: "snapshot carries no mini-batch solver state".into(),
                });
            }
            resume = Some(snap);
        }
        ckpt = Some(StreamCkpt { dir: policy.dir, every: policy.every, fingerprint });
    }
    let checkpoint_every = ckpt.as_ref().map_or(0, |c| c.every);
    let ck_dir = ckpt.as_ref().map(|c| c.dir.clone());

    ws.scratch.begin_run();
    ws.engine.reset();
    let evals0 = ws.engine.distance_evals();
    observer.on_start(c0, c0);

    // Every buffer below comes from the workspace scratch: warm reruns of
    // the same shape perform no allocation in the epoch loop.
    let mut c = ws.scratch.take_output_mat(k, d);
    c.as_mut_slice().copy_from_slice(c0.as_slice());
    // Take order mirrors the put order below (LIFO pool): the chunk
    // buffer keeps its large allocation across runs instead of rotating
    // into a centroid-sized slot.
    let chunk = ws.scratch.take_mat(chunk_rows, d);
    let c_prev = ws.scratch.take_mat(k, d);
    let c_prop = ws.scratch.take_mat(k, d);
    let assign = ws.scratch.take_assign();
    // Anderson state only exists for accelerated runs: a plain mini-batch
    // run neither allocates the m̄ history columns nor the residual.
    let mut acc: Option<AndersonAccelerator> = None;
    let f_t = if use_aa {
        acc = Some(ws.scratch.take_accelerator(cfg.solver.m_max.max(1), dim));
        Some(ws.scratch.take_f_t(dim))
    } else {
        None
    };
    let mut counts = ws.scratch.take_trace_f64();
    counts.clear();
    counts.resize(k, 0.0);
    let mut counts_prev = ws.scratch.take_trace_f64();
    counts_prev.clear();
    counts_prev.resize(k, 0.0);
    let trace = if cfg.solver.record_trace {
        ws.scratch.take_trace_f64()
    } else {
        Vec::new()
    };
    let m_trace = if cfg.solver.record_trace {
        ws.scratch.take_trace_usize()
    } else {
        Vec::new()
    };
    let sample_idx = if cfg.sampling == BatchSampling::Replacement {
        ws.scratch.take_trace_usize()
    } else {
        Vec::new()
    };
    // The sampled guard's reservoir: `rows` distinct indices drawn once
    // per run by Floyd's algorithm, kept sorted so every source gathers
    // in one forward sweep. Seeded from the request (salted away from the
    // replacement draw stream), so reruns and resumes score the exact
    // same rows.
    let mut eval_idx = if matches!(cfg.guard, EnergyGuard::Sampled { .. }) {
        ws.scratch.take_trace_usize()
    } else {
        Vec::new()
    };
    if let EnergyGuard::Sampled { rows } = cfg.guard {
        let n = source_len.expect("validated above");
        eval_idx.clear();
        if rows >= n {
            eval_idx.extend(0..n);
        } else {
            let mut rng = Pcg32::seed_from_u64(cfg.seed ^ GUARD_RESERVOIR_SALT);
            for j in (n - rows)..n {
                let t = rng.next_below(j + 1);
                match eval_idx.binary_search(&t) {
                    // `t` already drawn: Floyd inserts `j` instead, which
                    // exceeds every element drawn so far.
                    Ok(_) => eval_idx.push(j),
                    Err(pos) => eval_idx.insert(pos, t),
                }
            }
        }
    }

    // Mid-trajectory restore: the committed iterate, the learning-rate
    // counters and the draw stream come back byte-for-byte, and the
    // Anderson history is replayed into the freshly-taken (and therefore
    // reset) accelerator — the resumed run replays the exact batch
    // sequence the interrupted one would have seen.
    let mut sample_rng = Pcg32::seed_from_u64(cfg.seed);
    let mut eval_samples = 0u64;
    let mut resume_driver = None;
    if let Some(snap) = resume {
        c.as_mut_slice().copy_from_slice(&snap.centroids);
        let st = snap.stream.expect("validated above");
        counts.copy_from_slice(&st.counts);
        sample_rng = Pcg32::from_parts(st.rng_state, st.rng_inc);
        eval_samples = st.eval_samples;
        if let (Some(aa), Some(acc)) = (&snap.anderson, acc.as_mut()) {
            acc.restore(aa);
        }
        resume_driver = Some(snap.driver);
    }
    let rng_prev = sample_rng.state_parts();

    let budget = Budget::new(&sw, cfg.solver.time_limit, cancel);
    let mut step = EpochStep {
        ws,
        source,
        budget,
        phases: PhaseTimer::new(),
        c,
        c_prev,
        c_prop,
        chunk,
        assign,
        f_t,
        counts,
        chunk_rows,
        epoch_batches,
        eval_batches,
        eval_samples,
        convergence_tol: cfg.convergence_tol,
        sampling: cfg.sampling,
        sample_rng,
        sample_idx,
        source_len,
        counts_prev,
        rng_prev,
        guard: cfg.guard,
        eval_idx,
        ckpt,
        reseed_seed: cfg.solver.reseed_empty.then_some(cfg.seed),
    };
    let mut driver = FixedPointDriver::new(
        DriverConfig {
            accel: cfg.solver.accel,
            m_max: cfg.solver.m_max,
            epsilon1: cfg.solver.epsilon1,
            epsilon2: cfg.solver.epsilon2,
            max_iters: cfg.solver.max_iters,
            record_trace: cfg.solver.record_trace,
            trace_m: true,
            guard: GuardMode::Immediate,
            restart_after_rejects: Some(RESTART_AFTER_REJECTS),
            check_at_top: true,
            checkpoint_every,
        },
        acc.as_mut(),
        budget,
        trace,
        m_trace,
    );
    if let Some(ds) = resume_driver {
        driver.resume_from(ds);
    }
    let outcome = driver.run(&mut step, observer);
    if let Some(dir) = ck_dir.filter(|_| outcome.converged) {
        // A converged run needs no resume point; interrupted, errored or
        // capped runs keep theirs.
        persist::remove_snapshot(&dir);
    }

    // The final energy is the last epoch's exact checkpoint; runs that
    // never completed an epoch measure the returned centroids once —
    // unless the budget is already gone, in which case the interruptible
    // pass bails on its first batch. Source failures are carried past the
    // buffer put-backs below.
    let mut stream_error = outcome.error;
    let (energy, n_eval) = if stream_error.is_some() {
        (f64::INFINITY, 1)
    } else if outcome.iterations > 0 {
        (outcome.last_energy, step.eval_samples.max(1))
    } else if outcome.cancelled {
        // Fast cancel before the first checkpoint: no energy measured.
        (f64::INFINITY, 1)
    } else {
        match step.checkpoint_pass(false) {
            Ok(Some((e0, n0))) => (e0, n0.max(1)),
            Ok(None) => (f64::INFINITY, 1),
            Err(e) => {
                stream_error = Some(e);
                (f64::INFINITY, 1)
            }
        }
    };

    let EpochStep {
        ws,
        phases,
        c,
        c_prev,
        c_prop,
        chunk,
        assign,
        f_t,
        counts,
        counts_prev,
        sample_idx,
        eval_idx,
        ..
    } = step;
    ws.scratch.put_mat(c_prop);
    ws.scratch.put_mat(c_prev);
    ws.scratch.put_mat(chunk);
    ws.scratch.put_assign(assign);
    if let Some(f_t) = f_t {
        ws.scratch.put_f_t(f_t);
    }
    if let Some(acc) = acc {
        ws.scratch.put_accelerator(acc);
    }
    ws.scratch.put_trace_f64(counts_prev);
    ws.scratch.put_trace_f64(counts);
    if eval_idx.capacity() > 0 {
        ws.scratch.put_trace_usize(eval_idx);
    }
    if sample_idx.capacity() > 0 {
        ws.scratch.put_trace_usize(sample_idx);
    }
    // Buffers are home; only now may a carried source failure surface.
    if let Some(e) = stream_error {
        return Err(e);
    }
    let report = RunReport {
        iterations: outcome.iterations,
        accepted: outcome.accepted,
        seconds: sw.seconds(),
        energy,
        mse: energy / n_eval as f64,
        converged: outcome.converged,
        cancelled: outcome.cancelled,
        stopped_early: outcome.stopped_early,
        // A carried stream error already surfaced above, typed.
        error: None,
        energy_trace: outcome.energy_trace,
        m_trace: outcome.m_trace,
        dist_evals: ws.engine.distance_evals() - evals0,
        phases,
        centroids: c,
        assignment: lloyd::Assignment::new(),
    };
    observer.on_finish(&report);
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::chunks::{InMemoryChunks, SynthChunks};
    use crate::data::synth;
    use crate::init::{seed_centroids, InitMethod};
    use crate::lloyd::brute_force_assign;
    use crate::par::ThreadPool;
    use crate::rng::Pcg32;
    use std::sync::Arc;

    fn cfg(accel: Acceleration, chunk: usize) -> MiniBatchConfig {
        MiniBatchConfig {
            solver: SolverConfig {
                engine: crate::config::EngineKind::MiniBatch,
                accel,
                threads: 1,
                max_iters: 60,
                record_trace: true,
                ..SolverConfig::default()
            },
            chunk_size: chunk,
            batches_per_epoch: 0,
            convergence_tol: 1e-5,
            sampling: BatchSampling::Sequential,
            seed: 42,
            ..MiniBatchConfig::default()
        }
    }

    #[test]
    fn clusters_in_memory_blobs_to_good_energy() {
        let mut rng = Pcg32::seed_from_u64(42);
        let x = Arc::new(synth::gaussian_blobs(&mut rng, 4000, 4, 5, 3.0, 0.2));
        let mut srng = Pcg32::seed_from_u64(7);
        let c0 = seed_centroids(&x, 5, InitMethod::KMeansPlusPlus, &mut srng);
        let mut solver = MiniBatchSolver::try_new(cfg(Acceleration::DynamicM(2), 512)).unwrap();
        let mut source = InMemoryChunks::new(Arc::clone(&x));
        let report = solver.run(&mut source, &c0).unwrap();
        assert!(report.iterations >= 1);
        assert!(report.energy.is_finite() && report.energy > 0.0);
        assert_eq!(report.centroids.n(), 5);
        assert!(report.assignment.is_empty(), "streamed runs carry no assignment");
        // The reported energy is exact for the reported centroids.
        let pool = ThreadPool::new(1);
        let assign = brute_force_assign(&x, &report.centroids);
        let exact = lloyd::energy(&x, &report.centroids, &assign, &pool);
        assert!(
            (exact - report.energy).abs() <= 1e-6 * exact.max(1.0),
            "checkpoint energy {} vs exact {exact}",
            report.energy
        );
    }

    #[test]
    fn epoch_trace_has_one_entry_per_epoch() {
        let mut source = SynthChunks::new(9, 3000, 3, 4, 2.0, 0.3);
        let seed_buf =
            crate::data::chunks::collect_source(&mut source, 512, 1024).unwrap();
        let mut srng = Pcg32::seed_from_u64(3);
        let c0 = seed_centroids(&seed_buf, 4, InitMethod::KMeansPlusPlus, &mut srng);
        let mut solver = MiniBatchSolver::try_new(cfg(Acceleration::DynamicM(2), 500)).unwrap();
        let report = solver.run(&mut source, &c0).unwrap();
        assert_eq!(report.energy_trace.len(), report.iterations);
        assert_eq!(report.m_trace.len(), report.iterations);
        assert!(report.accepted <= report.iterations);
    }

    #[test]
    fn warm_reruns_reuse_workspace_and_are_deterministic() {
        let mut rng = Pcg32::seed_from_u64(5);
        let x = Arc::new(synth::gaussian_blobs(&mut rng, 2000, 3, 4, 2.5, 0.25));
        let mut srng = Pcg32::seed_from_u64(5);
        let c0 = seed_centroids(&x, 4, InitMethod::KMeansPlusPlus, &mut srng);
        let mut solver = MiniBatchSolver::try_new(cfg(Acceleration::DynamicM(2), 256)).unwrap();
        let mut source = InMemoryChunks::new(Arc::clone(&x));
        let r1 = solver.run(&mut source, &c0).unwrap();
        assert!(solver.workspace().last_run_rebuilt_scratch());
        let (it1, e1) = (r1.iterations, r1.energy);
        solver.ws.recycle(r1);
        source.rewind();
        let r2 = solver.run(&mut source, &c0).unwrap();
        assert!(
            !solver.workspace().last_run_rebuilt_scratch(),
            "second same-shape run must reuse the workspace scratch"
        );
        assert_eq!(r2.iterations, it1, "deterministic source ⇒ identical reruns");
        assert_eq!(r2.energy.to_bits(), e1.to_bits());
    }

    #[test]
    fn cancel_before_first_epoch_reports_cancelled() {
        let mut rng = Pcg32::seed_from_u64(6);
        let x = Arc::new(synth::gaussian_blobs(&mut rng, 1000, 3, 4, 2.0, 0.3));
        let c0 = x.gather_rows(&[0, 1, 2, 3]);
        let mut solver = MiniBatchSolver::try_new(cfg(Acceleration::None, 128)).unwrap();
        let mut source = InMemoryChunks::new(x);
        let token = CancelToken::new();
        token.cancel();
        let report =
            solver.run_observed(&mut source, &c0, &mut NoopObserver, &token).unwrap();
        assert!(report.cancelled);
        assert_eq!(report.iterations, 0);
        assert_eq!(report.centroids.as_slice(), c0.as_slice(), "state reverts to c0");
    }

    #[test]
    fn plain_minibatch_matches_sculley_reference() {
        // One epoch of the solver with Acceleration::None equals a direct
        // transcription of Sculley's update on the same chunk order.
        let mut rng = Pcg32::seed_from_u64(8);
        let x = Arc::new(synth::gaussian_blobs(&mut rng, 700, 2, 3, 2.0, 0.3));
        let c0 = x.gather_rows(&[0, 300, 600]);
        let mut config = cfg(Acceleration::None, 100);
        config.solver.max_iters = 1;
        let mut solver = MiniBatchSolver::try_new(config).unwrap();
        let mut source = InMemoryChunks::new(Arc::clone(&x));
        let report = solver.run(&mut source, &c0).unwrap();

        // Reference implementation.
        let mut c = c0.clone();
        let mut counts = vec![0.0f64; 3];
        for start in (0..x.n()).step_by(100) {
            let idx: Vec<usize> = (start..(start + 100).min(x.n())).collect();
            let chunk = x.gather_rows(&idx);
            let assign = brute_force_assign(&chunk, &c);
            for i in 0..chunk.n() {
                let j = assign[i] as usize;
                counts[j] += 1.0;
                let eta = 1.0 / counts[j];
                for t in 0..2 {
                    c[(j, t)] += eta * (chunk[(i, t)] - c[(j, t)]);
                }
            }
        }
        for j in 0..3 {
            for t in 0..2 {
                assert!(
                    (report.centroids[(j, t)] - c[(j, t)]).abs() < 1e-9,
                    "centroid {j} dim {t}: {} vs reference {}",
                    report.centroids[(j, t)],
                    c[(j, t)]
                );
            }
        }
    }

    #[test]
    fn replacement_sampling_matches_manual_draws() {
        // One epoch with replacement sampling equals a hand transcription
        // drawing the same seeded index stream.
        let mut rng = Pcg32::seed_from_u64(14);
        let x = Arc::new(synth::gaussian_blobs(&mut rng, 500, 2, 3, 2.0, 0.3));
        let c0 = x.gather_rows(&[0, 200, 400]);
        let mut config = cfg(Acceleration::None, 100);
        config.solver.max_iters = 1;
        config.sampling = BatchSampling::Replacement;
        config.seed = 99;
        let mut solver = MiniBatchSolver::try_new(config).unwrap();
        let mut source = InMemoryChunks::new(Arc::clone(&x));
        let report = solver.run(&mut source, &c0).unwrap();

        // Reference: 500 / 100 = 5 batches of 100 sorted draws each.
        let mut draw_rng = Pcg32::seed_from_u64(99);
        let mut c = c0.clone();
        let mut counts = vec![0.0f64; 3];
        for _batch in 0..5 {
            let mut idx: Vec<usize> = (0..100).map(|_| draw_rng.next_below(500)).collect();
            idx.sort_unstable();
            let chunk = x.gather_rows(&idx);
            let assign = brute_force_assign(&chunk, &c);
            for i in 0..chunk.n() {
                let j = assign[i] as usize;
                counts[j] += 1.0;
                let eta = 1.0 / counts[j];
                for t in 0..2 {
                    c[(j, t)] += eta * (chunk[(i, t)] - c[(j, t)]);
                }
            }
        }
        for j in 0..3 {
            for t in 0..2 {
                assert!(
                    (report.centroids[(j, t)] - c[(j, t)]).abs() < 1e-9,
                    "centroid {j} dim {t}: {} vs reference {}",
                    report.centroids[(j, t)],
                    c[(j, t)]
                );
            }
        }
    }

    #[test]
    fn replacement_sampling_reruns_deterministically() {
        let mut rng = Pcg32::seed_from_u64(15);
        let x = Arc::new(synth::gaussian_blobs(&mut rng, 1500, 3, 4, 2.5, 0.25));
        let mut srng = Pcg32::seed_from_u64(15);
        let c0 = seed_centroids(&x, 4, InitMethod::KMeansPlusPlus, &mut srng);
        let mut config = cfg(Acceleration::DynamicM(2), 256);
        config.sampling = BatchSampling::Replacement;
        let mut solver = MiniBatchSolver::try_new(config).unwrap();
        let mut source = InMemoryChunks::new(Arc::clone(&x));
        let r1 = solver.run(&mut source, &c0).unwrap();
        assert!(r1.energy.is_finite() && r1.iterations >= 1);
        let (it1, e1) = (r1.iterations, r1.energy);
        solver.ws.recycle(r1);
        source.rewind();
        let r2 = solver.run(&mut source, &c0).unwrap();
        assert_eq!(r2.iterations, it1, "seeded draw stream ⇒ identical reruns");
        assert_eq!(r2.energy.to_bits(), e1.to_bits());
    }

    #[test]
    fn empty_source_converges_with_initial_centroids_in_both_sampling_modes() {
        let x = Arc::new(DataMatrix::zeros(0, 2));
        let c0 = DataMatrix::from_rows(&[&[0.5, -0.5]]);
        for sampling in [BatchSampling::Sequential, BatchSampling::Replacement] {
            let mut config = cfg(Acceleration::DynamicM(2), 8);
            config.sampling = sampling;
            let mut solver = MiniBatchSolver::try_new(config).unwrap();
            let mut source = InMemoryChunks::new(Arc::clone(&x));
            let report = solver.run(&mut source, &c0).unwrap();
            assert!(report.converged, "{sampling:?}: empty source must converge");
            assert_eq!(report.iterations, 0, "{sampling:?}");
            assert_eq!(
                report.centroids.as_slice(),
                c0.as_slice(),
                "{sampling:?}: the initial centroids are already the answer"
            );
        }
    }

    #[test]
    fn replacement_sampling_rejects_unbounded_sources() {
        /// A source that never reports a length.
        struct Endless;
        impl ChunkSource for Endless {
            fn d(&self) -> usize {
                2
            }
            fn len(&self) -> Option<usize> {
                None
            }
            fn next_chunk(
                &mut self,
                max_rows: usize,
                out: &mut DataMatrix,
            ) -> Result<usize, ClusterError> {
                out.resize_rows(max_rows.max(1));
                Ok(max_rows.max(1))
            }
            fn rewind(&mut self) {}
        }
        let c0 = DataMatrix::zeros(2, 2);
        let mut config = cfg(Acceleration::None, 16);
        config.sampling = BatchSampling::Replacement;
        let mut solver = MiniBatchSolver::try_new(config).unwrap();
        match solver.run(&mut Endless, &c0) {
            Err(ClusterError::InvalidRequest { field: "sampling", .. }) => {}
            other => panic!("expected a typed sampling error, got ok={}", other.is_ok()),
        }
    }

    #[test]
    fn checkpointed_minibatch_run_resumes_bit_identical() {
        let dir = std::env::temp_dir().join("aakm_stream_tests").join("resume_parity");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let mut rng = Pcg32::seed_from_u64(31);
        let x = Arc::new(synth::gaussian_blobs(&mut rng, 3000, 3, 5, 2.5, 0.3));
        let mut srng = Pcg32::seed_from_u64(31);
        let c0 = seed_centroids(&x, 5, InitMethod::KMeansPlusPlus, &mut srng);
        // Replacement sampling so the resumed draw stream is exercised too.
        let mut config = cfg(Acceleration::DynamicM(2), 512);
        config.sampling = BatchSampling::Replacement;
        // Reference: one uninterrupted run.
        let mut solver = MiniBatchSolver::try_new(config.clone()).unwrap();
        let mut source = InMemoryChunks::new(Arc::clone(&x));
        let full = solver.run(&mut source, &c0).unwrap();
        assert!(full.converged, "reference must converge");
        assert!(full.iterations >= 2, "need room to truncate: {}", full.iterations);
        // Truncated run: checkpoint every epoch, cap halfway through.
        let policy = crate::persist::CheckpointPolicy::new(&dir, 1);
        let mut tcfg = config.clone();
        tcfg.solver.max_iters = full.iterations / 2;
        tcfg.solver.checkpoint = Some(policy.clone());
        let mut solver = MiniBatchSolver::try_new(tcfg).unwrap();
        let mut source = InMemoryChunks::new(Arc::clone(&x));
        let first = solver.run(&mut source, &c0).unwrap();
        assert!(!first.converged);
        assert!(
            crate::persist::load_snapshot(&dir).unwrap().is_some(),
            "a capped run must leave its snapshot behind"
        );
        // Resume with the full epoch budget: stitched trajectory must
        // land on the same bits as the uninterrupted run.
        let mut rcfg = config;
        rcfg.solver.checkpoint = Some(policy);
        let mut solver = MiniBatchSolver::try_new(rcfg).unwrap();
        let mut source = InMemoryChunks::new(Arc::clone(&x));
        let resumed = solver.run(&mut source, &c0).unwrap();
        assert!(resumed.converged);
        assert_eq!(resumed.iterations, full.iterations, "epoch count carries across resume");
        assert_eq!(resumed.energy.to_bits(), full.energy.to_bits());
        assert_eq!(resumed.centroids.as_slice(), full.centroids.as_slice());
        let mut stitched = first.energy_trace.clone();
        stitched.extend_from_slice(&resumed.energy_trace);
        assert_eq!(stitched.len(), full.energy_trace.len());
        for (i, (a, b)) in stitched.iter().zip(&full.energy_trace).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "trace diverges at epoch {i}");
        }
        assert!(
            crate::persist::load_snapshot(&dir).unwrap().is_none(),
            "a converged run drops its snapshot"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn streaming_reseed_revives_never_fed_centroids() {
        let mut rng = Pcg32::seed_from_u64(44);
        let x = Arc::new(synth::gaussian_blobs(&mut rng, 1000, 2, 3, 3.0, 0.2));
        // Three centroids on the data, one far outside it: the far one
        // never absorbs a sample and stays put without the policy.
        let far = [1e6, 1e6];
        let c0 = DataMatrix::from_rows(&[x.row(0), x.row(400), x.row(800), &far]);
        let mut config = cfg(Acceleration::None, 256);
        config.solver.reseed_empty = true;
        let mut solver = MiniBatchSolver::try_new(config.clone()).unwrap();
        let mut source = InMemoryChunks::new(Arc::clone(&x));
        let report = solver.run(&mut source, &c0).unwrap();
        for j in 0..4 {
            for t in 0..2 {
                assert!(
                    report.centroids[(j, t)].abs() < 1e5,
                    "centroid {j} dim {t} still at the far seed: {}",
                    report.centroids[(j, t)]
                );
            }
        }
        // The policy is deterministic: a rerun lands on the same bits.
        let mut solver = MiniBatchSolver::try_new(config).unwrap();
        let mut source = InMemoryChunks::new(Arc::clone(&x));
        let again = solver.run(&mut source, &c0).unwrap();
        assert_eq!(report.centroids.as_slice(), again.centroids.as_slice());
        assert_eq!(report.energy.to_bits(), again.energy.to_bits());
    }

    #[test]
    fn full_reservoir_sampled_guard_matches_exact_bit_for_bit() {
        // rows >= n degenerates to scoring every sample in index order —
        // the same accumulation order as the exact sequential scan, so
        // the whole trajectory must match to the bit.
        let mut rng = Pcg32::seed_from_u64(21);
        let x = Arc::new(synth::gaussian_blobs(&mut rng, 1200, 3, 4, 2.5, 0.25));
        let mut srng = Pcg32::seed_from_u64(21);
        let c0 = seed_centroids(&x, 4, InitMethod::KMeansPlusPlus, &mut srng);
        let exact = {
            let mut solver = MiniBatchSolver::try_new(cfg(Acceleration::DynamicM(2), 256)).unwrap();
            solver.run(&mut InMemoryChunks::new(Arc::clone(&x)), &c0).unwrap()
        };
        let mut config = cfg(Acceleration::DynamicM(2), 256);
        config.guard = EnergyGuard::Sampled { rows: 5000 };
        let sampled = {
            let mut solver = MiniBatchSolver::try_new(config).unwrap();
            solver.run(&mut InMemoryChunks::new(Arc::clone(&x)), &c0).unwrap()
        };
        assert_eq!(sampled.iterations, exact.iterations);
        assert_eq!(sampled.energy.to_bits(), exact.energy.to_bits());
        assert_eq!(sampled.centroids.as_slice(), exact.centroids.as_slice());
        for (a, b) in sampled.energy_trace.iter().zip(&exact.energy_trace) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn sampled_guard_runs_converge_and_rerun_deterministically() {
        let mut rng = Pcg32::seed_from_u64(27);
        let x = Arc::new(synth::gaussian_blobs(&mut rng, 3000, 4, 5, 3.0, 0.25));
        let mut srng = Pcg32::seed_from_u64(27);
        let c0 = seed_centroids(&x, 5, InitMethod::KMeansPlusPlus, &mut srng);
        let mut config = cfg(Acceleration::DynamicM(2), 512);
        config.guard = EnergyGuard::Sampled { rows: 600 };
        let mut solver = MiniBatchSolver::try_new(config).unwrap();
        let mut source = InMemoryChunks::new(Arc::clone(&x));
        let r1 = solver.run(&mut source, &c0).unwrap();
        assert!(r1.energy.is_finite() && r1.iterations >= 1);
        let (it1, e1, c1) = (r1.iterations, r1.energy, r1.centroids.as_slice().to_vec());
        solver.ws.recycle(r1);
        source.rewind();
        let r2 = solver.run(&mut source, &c0).unwrap();
        assert!(
            !solver.workspace().last_run_rebuilt_scratch(),
            "sampled-guard reruns must reuse the workspace scratch (incl. the reservoir buffer)"
        );
        assert_eq!(r2.iterations, it1, "fixed seeded reservoir ⇒ identical reruns");
        assert_eq!(r2.energy.to_bits(), e1.to_bits());
        assert_eq!(r2.centroids.as_slice(), c1.as_slice());
    }

    #[test]
    fn sampled_guard_rejects_bad_configs() {
        let c0 = DataMatrix::zeros(2, 2);
        let mut config = cfg(Acceleration::None, 16);
        config.guard = EnergyGuard::Sampled { rows: 0 };
        let mut solver = MiniBatchSolver::try_new(config).unwrap();
        let x = Arc::new(DataMatrix::zeros(64, 2));
        match solver.run(&mut InMemoryChunks::new(x), &c0) {
            Err(ClusterError::InvalidRequest { field: "guard", .. }) => {}
            other => panic!("rows=0 must fail typed, got ok={}", other.is_ok()),
        }

        /// A source that never reports a length.
        struct Endless;
        impl ChunkSource for Endless {
            fn d(&self) -> usize {
                2
            }
            fn len(&self) -> Option<usize> {
                None
            }
            fn next_chunk(
                &mut self,
                max_rows: usize,
                out: &mut DataMatrix,
            ) -> Result<usize, ClusterError> {
                out.resize_rows(max_rows.max(1));
                Ok(max_rows.max(1))
            }
            fn rewind(&mut self) {}
        }
        let mut config = cfg(Acceleration::None, 16);
        config.guard = EnergyGuard::Sampled { rows: 32 };
        let mut solver = MiniBatchSolver::try_new(config).unwrap();
        match solver.run(&mut Endless, &c0) {
            Err(ClusterError::InvalidRequest { field: "guard", .. }) => {}
            other => panic!("unbounded source must fail typed, got ok={}", other.is_ok()),
        }
    }

    #[test]
    fn guard_reservoir_is_a_sorted_distinct_uniform_sample() {
        // Drive the Floyd draw through a tiny run and check the invariant
        // indirectly: a sampled run over a delta dataset (all rows equal)
        // must measure zero energy regardless of which rows the reservoir
        // picked, proving every index was in range.
        let x = Arc::new(DataMatrix::from_vec(vec![1.5; 101 * 2], 101, 2));
        let c0 = DataMatrix::from_rows(&[&[1.5, 1.5]]);
        let mut config = cfg(Acceleration::None, 7);
        config.guard = EnergyGuard::Sampled { rows: 37 };
        config.solver.max_iters = 2;
        let mut solver = MiniBatchSolver::try_new(config).unwrap();
        let report = solver.run(&mut InMemoryChunks::new(x), &c0).unwrap();
        assert_eq!(report.energy, 0.0);
        // And the estimator's denominator is the reservoir size.
        assert_eq!(report.mse, 0.0);
    }

    #[test]
    fn energy_guard_parses_and_names() {
        assert_eq!(EnergyGuard::parse("exact"), Some(EnergyGuard::Exact));
        assert_eq!(EnergyGuard::parse("Sampled:4096"), Some(EnergyGuard::Sampled { rows: 4096 }));
        assert_eq!(EnergyGuard::parse("sampled:"), None);
        assert_eq!(EnergyGuard::parse("sampled"), None);
        assert_eq!(EnergyGuard::parse("approx"), None);
        assert_eq!(EnergyGuard::default(), EnergyGuard::Exact);
        assert_eq!(EnergyGuard::Exact.name(), "exact");
        assert_eq!(EnergyGuard::Sampled { rows: 512 }.name(), "sampled:512");
        for s in ["exact", "sampled:512"] {
            assert_eq!(EnergyGuard::parse(s).unwrap().name(), s, "round-trip");
        }
    }

    #[test]
    fn sampled_guard_fingerprint_fences_resume_but_exact_is_unchanged() {
        let base = cfg(Acceleration::DynamicM(2), 256);
        let exact_fp = stream_fingerprint(&base, 4, 3);
        assert!(
            !exact_fp.contains("guard="),
            "the exact default must keep the pre-knob fingerprint: {exact_fp}"
        );
        let mut sampled = base.clone();
        sampled.guard = EnergyGuard::Sampled { rows: 128 };
        let sampled_fp = stream_fingerprint(&sampled, 4, 3);
        assert!(sampled_fp.ends_with(" guard=sampled:128"), "{sampled_fp}");
        assert_ne!(exact_fp, sampled_fp);
        // Prefetch and pinning never change a trajectory, so they must
        // not fence resume.
        let mut pipelined = base.clone();
        pipelined.prefetch = true;
        pipelined.pin_threads = true;
        assert_eq!(stream_fingerprint(&pipelined, 4, 3), exact_fp);
    }

    #[test]
    fn batch_sampling_parses_and_names() {
        assert_eq!(BatchSampling::parse("sequential"), Some(BatchSampling::Sequential));
        assert_eq!(BatchSampling::parse("Replacement"), Some(BatchSampling::Replacement));
        assert_eq!(BatchSampling::parse("iid"), None);
        assert_eq!(BatchSampling::default(), BatchSampling::Sequential);
        assert_eq!(BatchSampling::Replacement.name(), "replacement");
    }
}
