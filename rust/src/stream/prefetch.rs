//! Pipelined chunk prefetch — the I/O half of the streaming engine.
//!
//! [`PrefetchSource`] wraps any `Send` [`ChunkSource`] behind a background
//! prefetcher thread and a **bounded two-slot buffer exchange**: while the
//! consumer sweeps chunk *t*, the thread is already paging in and decoding
//! chunk *t+1* into the second buffer. Exactly two chunk buffers ping-pong
//! between the two threads for the lifetime of the source — the steady
//! state allocates nothing (asserted by `tests/alloc_reuse.rs`) — and
//! chunks are served in exactly the order the inner source produces them,
//! so a prefetched run is bit-identical to a direct one (energy traces,
//! checkpoints and resume included; `tests/integration_stream.rs` pins
//! this down per sampling mode).
//!
//! The exchange is a hand-rolled `Mutex` + `Condvar` rendezvous rather
//! than a channel: a channel send allocates queue nodes on the hot path,
//! and the protocol here never needs more than one outstanding request.
//! Faults inside the prefetcher — an injected [`FaultSite::ChunkRead`]
//! error, a decode failure, even a panic — surface on the consumer side
//! as typed [`ClusterError`]s (a dead thread is detected through the
//! exchange, never waited on forever), and the thread is joined on drop.
//!
//! [`FaultSite::ChunkRead`]: crate::fault::FaultSite::ChunkRead

use crate::data::chunks::ChunkSource;
use crate::data::DataMatrix;
use crate::error::ClusterError;
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::time::Instant;

/// Consumer → prefetcher: the one outstanding operation.
enum Request {
    /// Read the next sequential chunk into `buf`.
    Fill { max_rows: usize, buf: DataMatrix },
    /// Random-access gather of `idx` into `buf` (replacement sampling and
    /// the sampled energy guard).
    Gather { idx: Vec<usize>, buf: DataMatrix },
    /// Restart the inner stream.
    Rewind,
}

/// Prefetcher → consumer: the operation's result, buffers returned.
enum Reply {
    Filled { buf: DataMatrix, res: Result<usize, ClusterError> },
    Gathered { idx: Vec<usize>, buf: DataMatrix, res: Result<(), ClusterError> },
    Rewound,
}

/// The two-slot exchange: at most one request and one reply in flight.
struct Exchange {
    state: Mutex<ExchangeState>,
    cond: Condvar,
}

#[derive(Default)]
struct ExchangeState {
    request: Option<Request>,
    reply: Option<Reply>,
    /// Consumer asks the thread to exit.
    shutdown: bool,
    /// Set when the prefetcher thread exits for any reason (clean shutdown
    /// or panic) so the consumer can never block on a reply that will not
    /// come.
    dead: bool,
}

impl Exchange {
    fn lock(&self) -> MutexGuard<'_, ExchangeState> {
        self.state.lock().unwrap_or_else(PoisonError::into_inner)
    }
}

/// Marks the exchange dead when the prefetcher thread unwinds (or exits
/// cleanly) — the consumer-side waits key off this instead of hanging.
struct DeadGuard(Arc<Exchange>);

impl Drop for DeadGuard {
    fn drop(&mut self) {
        let mut st = self.0.lock();
        st.dead = true;
        self.0.cond.notify_all();
    }
}

/// The prefetcher thread: serve requests in order until shutdown.
fn prefetch_loop(exchange: &Exchange, inner: &mut (dyn ChunkSource + Send)) {
    loop {
        let req = {
            let mut st = exchange.lock();
            loop {
                if st.shutdown {
                    return;
                }
                if let Some(req) = st.request.take() {
                    break req;
                }
                st = exchange.cond.wait(st).unwrap_or_else(PoisonError::into_inner);
            }
        };
        // Execute outside the lock: the read (mmap page-in + decode) is
        // the work this thread exists to overlap with the sweep.
        let reply = match req {
            Request::Fill { max_rows, mut buf } => {
                let res = inner.next_chunk(max_rows, &mut buf);
                Reply::Filled { buf, res }
            }
            Request::Gather { idx, mut buf } => {
                let res = inner.gather_rows(&idx, &mut buf);
                Reply::Gathered { idx, buf, res }
            }
            Request::Rewind => {
                inner.rewind();
                Reply::Rewound
            }
        };
        let mut st = exchange.lock();
        debug_assert!(st.reply.is_none(), "two-slot exchange: one reply at a time");
        st.reply = Some(reply);
        exchange.cond.notify_all();
    }
}

/// A [`ChunkSource`] that double-buffers reads from an inner source on a
/// background thread. See the module docs for the pipeline contract.
///
/// The pipeline speculates at a fixed chunk size (the `chunk_rows` it was
/// spawned with): `next_chunk` panics on any other `max_rows`, matching
/// the streaming engine's constant-chunk discipline. `gather_rows` and
/// `rewind` are synchronous round-trips through the same thread, so the
/// inner source never sees interleaved access.
pub struct PrefetchSource {
    d: usize,
    len: Option<usize>,
    chunk_rows: usize,
    exchange: Arc<Exchange>,
    thread: Option<std::thread::JoinHandle<Box<dyn ChunkSource + Send>>>,
    /// Buffers currently on the consumer side (2 - in-flight).
    spares: Vec<DataMatrix>,
    /// Whether a speculative fill is outstanding.
    inflight: bool,
    /// Recycled index buffer for gather round-trips.
    idx_buf: Vec<usize>,
}

impl PrefetchSource {
    /// Spawn a prefetcher over `inner`, allocating the two pipeline
    /// buffers (`chunk_rows × d`). Callers with a warm buffer pool should
    /// prefer [`PrefetchSource::with_buffers`].
    pub fn spawn(inner: Box<dyn ChunkSource + Send>, chunk_rows: usize) -> Self {
        let d = inner.d();
        let chunk_rows = chunk_rows.max(1);
        let b0 = DataMatrix::zeros(chunk_rows, d);
        let b1 = DataMatrix::zeros(chunk_rows, d);
        Self::with_buffers(inner, chunk_rows, b0, b1, None)
    }

    /// Spawn a prefetcher reusing two caller-provided buffers (recycled
    /// from the workspace scratch on the session path — warm reruns then
    /// allocate no chunk storage). Buffers of the wrong shape are resized
    /// in place, reusing their allocation where capacity allows.
    /// `pin_cpu` pins the prefetcher thread to that CPU on Linux (no-op
    /// elsewhere) so it stops migrating across the sweep lanes' cores.
    pub fn with_buffers(
        inner: Box<dyn ChunkSource + Send>,
        chunk_rows: usize,
        b0: DataMatrix,
        b1: DataMatrix,
        pin_cpu: Option<usize>,
    ) -> Self {
        let d = inner.d();
        let len = inner.len();
        let chunk_rows = chunk_rows.max(1);
        let fit = |m: DataMatrix| -> DataMatrix {
            if m.d() == d {
                return m;
            }
            let mut v = m.into_vec();
            v.clear();
            v.resize(chunk_rows * d, 0.0);
            DataMatrix::from_vec(v, chunk_rows, d)
        };
        let exchange = Arc::new(Exchange {
            state: Mutex::new(ExchangeState::default()),
            cond: Condvar::new(),
        });
        let thread_exchange = Arc::clone(&exchange);
        let mut inner = inner;
        let thread = std::thread::Builder::new()
            .name("aakm-prefetch".into())
            .spawn(move || {
                if let Some(cpu) = pin_cpu {
                    crate::par::pin_current_thread(cpu);
                }
                let _dead = DeadGuard(Arc::clone(&thread_exchange));
                prefetch_loop(&thread_exchange, inner.as_mut());
                inner
            })
            .expect("spawning the prefetcher thread");
        Self {
            d,
            len,
            chunk_rows,
            exchange,
            thread: Some(thread),
            spares: vec![fit(b0), fit(b1)],
            inflight: false,
            idx_buf: Vec::new(),
        }
    }

    /// The typed error a request gets when the prefetcher thread died
    /// (e.g. an injected panic): classed as I/O like any other source
    /// failure, so the coordinator's retry classifier treats it as
    /// transient.
    fn dead_error(&self) -> ClusterError {
        ClusterError::Data {
            source: "prefetch".to_string(),
            reason: "prefetcher thread died before serving the request".to_string(),
        }
    }

    /// Hand a request to the thread (the request slot is empty by the
    /// one-outstanding-operation invariant).
    fn post(&self, req: Request) {
        let mut st = self.exchange.lock();
        debug_assert!(st.request.is_none(), "two-slot exchange: one request at a time");
        st.request = Some(req);
        self.exchange.cond.notify_all();
    }

    /// Block until the thread posts its reply (or dies). `account` adds
    /// the wait to the prefetch hit/stall telemetry — set only for the
    /// chunk-serving path, so rewind/gather round-trips don't skew the
    /// pipeline's hit rate.
    fn wait_reply(&self, account: bool) -> Result<Reply, ClusterError> {
        let mut st = self.exchange.lock();
        let telemetry = account && crate::telemetry::enabled();
        if st.reply.is_none() && !st.dead {
            if telemetry {
                crate::telemetry::metrics().stream_prefetch_stalls.inc();
            }
            let t0 = Instant::now();
            while st.reply.is_none() && !st.dead {
                st = self.exchange.cond.wait(st).unwrap_or_else(PoisonError::into_inner);
            }
            if telemetry {
                crate::telemetry::metrics()
                    .stream_prefetch_stall_seconds
                    .observe_duration(t0.elapsed());
            }
        } else if telemetry && st.reply.is_some() {
            crate::telemetry::metrics().stream_prefetch_hits.inc();
        }
        st.reply.take().ok_or_else(|| self.dead_error())
    }

    /// Launch the next speculative fill (requires a spare buffer).
    fn arm(&mut self) {
        let buf = self.spares.pop().expect("pipeline invariant: a spare buffer exists");
        self.post(Request::Fill { max_rows: self.chunk_rows, buf });
        self.inflight = true;
    }

    /// Absorb an outstanding speculative fill before a non-sequential
    /// operation, reclaiming its buffer. The speculative result — data or
    /// error — is discarded: the chunk was never requested, and a
    /// persistent failure resurfaces on the next consumed read.
    fn drain(&mut self) {
        if !self.inflight {
            return;
        }
        self.inflight = false;
        match self.wait_reply(false) {
            Ok(Reply::Filled { buf, .. }) | Ok(Reply::Gathered { buf, .. }) => {
                self.spares.push(buf);
            }
            Ok(Reply::Rewound) | Err(_) => {}
        }
    }

    /// Tear the pipeline down explicitly, returning the inner source
    /// (`None` if the thread panicked) and the surviving chunk buffers —
    /// the session path feeds these back into the workspace scratch so
    /// warm reruns reuse them.
    pub fn shutdown(mut self) -> (Option<Box<dyn ChunkSource + Send>>, Vec<DataMatrix>) {
        self.drain();
        let inner = self.join();
        (inner, std::mem::take(&mut self.spares))
    }

    /// Signal shutdown and join the thread (idempotent).
    fn join(&mut self) -> Option<Box<dyn ChunkSource + Send>> {
        let handle = self.thread.take()?;
        {
            let mut st = self.exchange.lock();
            st.shutdown = true;
            self.exchange.cond.notify_all();
        }
        handle.join().ok()
    }
}

impl Drop for PrefetchSource {
    fn drop(&mut self) {
        let _ = self.join();
    }
}

impl ChunkSource for PrefetchSource {
    fn d(&self) -> usize {
        self.d
    }

    fn len(&self) -> Option<usize> {
        self.len
    }

    fn next_chunk(
        &mut self,
        max_rows: usize,
        out: &mut DataMatrix,
    ) -> Result<usize, ClusterError> {
        assert_eq!(out.d(), self.d, "chunk buffer dimensionality mismatch");
        assert_eq!(
            max_rows.max(1),
            self.chunk_rows,
            "PrefetchSource streams fixed-size chunks (spawned for {} rows)",
            self.chunk_rows
        );
        if !self.inflight {
            // Cold start (first read, or the read after an exhausted pass
            // or a surfaced error): nothing to overlap yet.
            self.arm();
        }
        self.inflight = false;
        match self.wait_reply(true)? {
            Reply::Filled { buf, res } => match res {
                Ok(0) => {
                    // Pass exhausted: stop speculating — the consumer's
                    // next move is a rewind (which re-arms) or teardown.
                    self.spares.push(buf);
                    out.resize_rows(0);
                    Ok(0)
                }
                Ok(got) => {
                    // Re-arm with the other buffer *before* copying out,
                    // so the next page-in/decode overlaps this chunk's
                    // sweep — the pipeline.
                    self.arm();
                    out.resize_rows(got);
                    out.as_mut_slice().copy_from_slice(buf.as_slice());
                    self.spares.push(buf);
                    if crate::telemetry::enabled() {
                        crate::telemetry::metrics()
                            .stream_prefetch_bytes
                            .add((got * self.d * 8) as u64);
                    }
                    Ok(got)
                }
                Err(e) => {
                    self.spares.push(buf);
                    Err(e)
                }
            },
            _ => Err(self.dead_error()),
        }
    }

    fn rewind(&mut self) {
        self.drain();
        self.post(Request::Rewind);
        match self.wait_reply(false) {
            Ok(Reply::Rewound) => {
                // The pass restarts at chunk 0 — speculate it immediately
                // so even the first chunk of a sequential pass is a hit.
                self.arm();
            }
            // A dead thread surfaces on the next read; buffers of any
            // other (impossible) reply shape are reclaimed defensively.
            Ok(Reply::Filled { buf, .. }) | Ok(Reply::Gathered { buf, .. }) => {
                self.spares.push(buf);
            }
            Err(_) => {}
        }
    }

    fn gather_rows(
        &mut self,
        indices: &[usize],
        out: &mut DataMatrix,
    ) -> Result<(), ClusterError> {
        assert_eq!(out.d(), self.d, "chunk buffer dimensionality mismatch");
        self.drain();
        let mut idx = std::mem::take(&mut self.idx_buf);
        idx.clear();
        idx.extend_from_slice(indices);
        let Some(buf) = self.spares.pop() else {
            return Err(self.dead_error());
        };
        self.post(Request::Gather { idx, buf });
        match self.wait_reply(false)? {
            Reply::Gathered { idx, buf, res } => {
                self.idx_buf = idx;
                res?;
                out.resize_rows(buf.n());
                out.as_mut_slice().copy_from_slice(buf.as_slice());
                self.spares.push(buf);
                Ok(())
            }
            _ => Err(self.dead_error()),
        }
    }
}

#[cfg(test)]
mod tests {
    // Injected-fault behavior (chunk-read errors and panics on the
    // prefetcher thread) lives in `tests/fault_injection.rs`: those plans
    // are process-scoped, and that binary's every-test-holds-a-plan
    // convention is what keeps them from robbing parallel tests.
    use super::*;
    use crate::data::chunks::{collect_source, InMemoryChunks, SynthChunks};
    use crate::data::synth;
    use crate::rng::Pcg32;
    use std::sync::Arc;

    #[test]
    fn prefetched_chunks_match_the_inner_source_exactly() {
        let mut rng = Pcg32::seed_from_u64(17);
        let x = Arc::new(synth::gaussian_blobs(&mut rng, 513, 3, 4, 2.0, 0.3));
        for chunk_rows in [1usize, 7, 128, 513, 600] {
            let mut direct = InMemoryChunks::new(Arc::clone(&x));
            let mut pf =
                PrefetchSource::spawn(Box::new(InMemoryChunks::new(Arc::clone(&x))), chunk_rows);
            let mut a = DataMatrix::zeros(0, 3);
            let mut b = DataMatrix::zeros(0, 3);
            // Two passes: the second exercises rewind + the re-armed
            // pipeline.
            for pass in 0..2 {
                loop {
                    let got_d = direct.next_chunk(chunk_rows, &mut a).unwrap();
                    let got_p = pf.next_chunk(chunk_rows, &mut b).unwrap();
                    assert_eq!(got_d, got_p, "chunk_rows={chunk_rows} pass={pass}");
                    assert_eq!(a.as_slice(), b.as_slice());
                    if got_d == 0 {
                        break;
                    }
                }
                direct.rewind();
                pf.rewind();
            }
            let (inner, bufs) = pf.shutdown();
            assert!(inner.is_some(), "clean shutdown returns the inner source");
            assert_eq!(bufs.len(), 2, "both pipeline buffers survive");
        }
    }

    #[test]
    fn gather_and_len_pass_through() {
        let mut synth_direct = SynthChunks::new(23, 300, 3, 4, 2.0, 0.25);
        let full = collect_source(&mut synth_direct, 64, usize::MAX).unwrap();
        let mut pf =
            PrefetchSource::spawn(Box::new(SynthChunks::new(23, 300, 3, 4, 2.0, 0.25)), 64);
        assert_eq!(pf.len(), Some(300));
        assert_eq!(pf.d(), 3);
        let indices = [0usize, 5, 5, 64, 128, 299];
        let mut out = DataMatrix::zeros(0, 3);
        pf.gather_rows(&indices, &mut out).unwrap();
        for (slot, &i) in indices.iter().enumerate() {
            assert_eq!(out.row(slot), full.row(i));
        }
        // Gathers interleave with sequential reads: a rewind restores the
        // sequential pass exactly.
        pf.rewind();
        let replay = collect_source(&mut pf, 64, usize::MAX).unwrap();
        assert_eq!(replay, full);
        // Out-of-range gathers fail typed, pipeline still usable.
        assert!(pf.gather_rows(&[0, 300], &mut out).is_err());
        pf.rewind();
        let again = collect_source(&mut pf, 64, usize::MAX).unwrap();
        assert_eq!(again, full);
    }

}
