//! Shape-bucket padding: HLO artifacts are shape-static, so jobs are padded
//! up to the nearest lowered bucket before execution.
//!
//! Contract (mirrored by `python/compile/model.py` and property-tested in
//! `python/tests/test_model.py` + `rust/tests/integration_runtime.rs`):
//!
//! * sample rows beyond the real count are zero and masked out (`mask = 0`);
//! * centroid rows beyond the real count are parked at the sentinel, far
//!   outside any standardized dataset, so no real sample selects them.

use crate::data::DataMatrix;

/// Where padding centroids live (must match `model.PAD_CENTROID_SENTINEL`).
pub const PAD_CENTROID_SENTINEL: f32 = 1.0e6;

/// Key identifying a shape bucket.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BucketKey {
    pub n: usize,
    pub d: usize,
    pub k: usize,
}

/// A problem padded into a bucket, in the f32 row-major layout PJRT takes.
#[derive(Debug, Clone)]
pub struct PaddedProblem {
    /// (bucket_n × d) samples, zero-padded.
    pub x: Vec<f32>,
    /// (bucket_k × d) centroids, sentinel-padded.
    pub c: Vec<f32>,
    /// (bucket_n,) 1.0 for real rows, 0.0 for padding.
    pub mask: Vec<f32>,
    /// Real sample count.
    pub real_n: usize,
    /// Real cluster count.
    pub real_k: usize,
}

/// Pad `(x, c)` into an `(bucket_n, bucket_k)` bucket.
///
/// Panics if the bucket is too small (callers select buckets through
/// [`crate::runtime::Manifest::find_bucket`], which guarantees fit).
pub fn pad_problem(x: &DataMatrix, c: &DataMatrix, bucket_n: usize, bucket_k: usize) -> PaddedProblem {
    let (n, d, k) = (x.n(), x.d(), c.n());
    assert!(bucket_n >= n, "bucket n {bucket_n} < {n}");
    assert!(bucket_k >= k, "bucket k {bucket_k} < {k}");
    assert_eq!(c.d(), d);
    // The narrowing itself is DataMatrix::write_f32_into — the crate's one
    // f64→f32 conversion point — written into the real-row prefix of each
    // padded buffer.
    let mut xf = vec![0.0f32; bucket_n * d];
    x.write_f32_into(&mut xf[..n * d]);
    let mut cf = vec![PAD_CENTROID_SENTINEL; bucket_k * d];
    c.write_f32_into(&mut cf[..k * d]);
    let mut mask = vec![0.0f32; bucket_n];
    for m in mask.iter_mut().take(n) {
        *m = 1.0;
    }
    PaddedProblem { x: xf, c: cf, mask, real_n: n, real_k: k }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pads_samples_and_mask() {
        let x = DataMatrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let c = DataMatrix::from_rows(&[&[0.0, 0.0]]);
        let p = pad_problem(&x, &c, 4, 2);
        assert_eq!(p.x.len(), 8);
        assert_eq!(&p.x[..4], &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(&p.x[4..], &[0.0; 4]);
        assert_eq!(p.mask, vec![1.0, 1.0, 0.0, 0.0]);
        assert_eq!(p.c.len(), 4);
        assert_eq!(&p.c[..2], &[0.0, 0.0]);
        assert_eq!(&p.c[2..], &[PAD_CENTROID_SENTINEL; 2]);
        assert_eq!((p.real_n, p.real_k), (2, 1));
    }

    #[test]
    fn exact_fit_no_padding() {
        let x = DataMatrix::from_rows(&[&[1.0], &[2.0]]);
        let c = DataMatrix::from_rows(&[&[0.5], &[1.5]]);
        let p = pad_problem(&x, &c, 2, 2);
        assert_eq!(p.mask, vec![1.0, 1.0]);
        assert_eq!(p.c, vec![0.5, 1.5]);
    }

    #[test]
    #[should_panic(expected = "bucket n")]
    fn too_small_bucket_panics() {
        let x = DataMatrix::from_rows(&[&[1.0], &[2.0], &[3.0]]);
        let c = DataMatrix::from_rows(&[&[0.0]]);
        pad_problem(&x, &c, 2, 1);
    }
}
