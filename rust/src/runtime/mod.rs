//! PJRT runtime — executes the AOT-compiled JAX/Pallas artifacts from Rust.
//!
//! This is the bridge that makes the three-layer architecture real: the
//! Python side (`python/compile/aot.py`) lowers the L2 G-step once to HLO
//! text per shape bucket; this module loads those files through the `xla`
//! crate (`PjRtClient::cpu` → `HloModuleProto::from_text_file` → `compile`
//! → `execute`) so the request path never touches Python.
//!
//! * [`Manifest`] — parses `artifacts/manifest.txt` (TOML subset).
//! * [`bucket`] — shape-bucket selection and the padding contract
//!   (zero-padded samples + mask, sentinel-padded centroids).
//! * [`PjrtRuntime`] — compiled-executable cache + typed `g_step` /
//!   `energy_step` entry points.
//! * [`PjrtEngine`] — an [`crate::lloyd::AssignmentEngine`] backed by the
//!   AOT `energy_step`, so the Algorithm-1 solver can run its assignment
//!   hot path on the compiled artifact.
//!
//! PJRT handles hold `Rc` internals (not `Send`): callers that want one
//! runtime per worker thread construct it *inside* the thread (see
//! [`crate::coordinator`]).

pub mod bucket;
mod manifest;

pub use bucket::{pad_problem, BucketKey, PaddedProblem, PAD_CENTROID_SENTINEL};
pub use manifest::{ArtifactSpec, Manifest};

use crate::data::DataMatrix;
use crate::lloyd::{Assignment, AssignmentEngine};
use crate::par::ThreadPool;
use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;

/// Output of one compiled G-step execution (already unpadded).
#[derive(Debug, Clone)]
pub struct GStepOutput {
    /// Updated centroids (k × d).
    pub centroids: DataMatrix,
    /// Per-sample assignment.
    pub assignment: Assignment,
    /// Masked clustering energy at the *input* centroids.
    pub energy: f64,
    /// Per-cluster sample counts.
    pub counts: Vec<f64>,
}

/// PJRT-backed executor over the artifact set.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    manifest: Manifest,
    /// Executable cache keyed by artifact name. Compilation happens lazily
    /// on first use of a bucket and is then amortized across the run.
    cache: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
    /// Distance-evaluation accounting (one full sweep = n·k).
    dist_evals: std::cell::Cell<u64>,
}

impl PjrtRuntime {
    /// Open the artifact directory (expects `manifest.txt` inside).
    pub fn open(artifact_dir: &Path) -> Result<Self> {
        // Fault-injection point: inert unless a `FaultPlan` arms the
        // runtime-load site (robustness tests).
        crate::fault::check(crate::fault::FaultSite::PjrtOpen)?;
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(Self {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
            dist_evals: std::cell::Cell::new(0),
        })
    }

    /// The manifest in use.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (e.g. `"cpu"`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Total point–centroid distance evaluations implied by the executed
    /// sweeps (the dense kernel always computes n·k distances per call).
    pub fn dist_evals(&self) -> u64 {
        self.dist_evals.get()
    }

    fn executable(&self, spec: &ArtifactSpec) -> Result<()> {
        if self.cache.borrow().contains_key(&spec.name) {
            return Ok(());
        }
        let path = self.manifest.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("PJRT compile {}", spec.name))?;
        self.cache.borrow_mut().insert(spec.name.clone(), exe);
        Ok(())
    }

    /// Execute the compiled artifact `spec` on an already-padded problem.
    fn execute_padded(
        &self,
        spec: &ArtifactSpec,
        padded: &PaddedProblem,
    ) -> Result<xla::Literal> {
        self.executable(spec)?;
        let cache = self.cache.borrow();
        let exe = cache.get(&spec.name).expect("just inserted");
        let x_lit = xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::F32,
            &[spec.n, spec.d],
            bytes_of(&padded.x),
        )?;
        let c_lit = xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::F32,
            &[spec.k, spec.d],
            bytes_of(&padded.c),
        )?;
        let m_lit = xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::F32,
            &[spec.n],
            bytes_of(&padded.mask),
        )?;
        let result = exe.execute::<xla::Literal>(&[x_lit, c_lit, m_lit])?[0][0]
            .to_literal_sync()?;
        self.dist_evals.set(self.dist_evals.get() + (spec.n * spec.k) as u64);
        Ok(result)
    }

    /// Run one full fixed-point step `G(C)` (assignment + update + energy)
    /// on the AOT artifact, transparently padding to the bucket.
    pub fn g_step(&self, x: &DataMatrix, c: &DataMatrix) -> Result<GStepOutput> {
        let spec = self
            .manifest
            .find_bucket("g_step", x.n(), x.d(), c.n())
            .with_context(|| {
                format!(
                    "no g_step bucket for n={} d={} k={} (available: {})",
                    x.n(),
                    x.d(),
                    c.n(),
                    self.manifest.bucket_summary("g_step")
                )
            })?
            .clone();
        let padded = pad_problem(x, c, spec.n, spec.k);
        let result = self.execute_padded(&spec, &padded)?;
        let (c_new, assign, energy, counts) = result.to_tuple4()?;
        // Unpad.
        let c_f32 = c_new.to_vec::<f32>()?;
        let mut centroids = DataMatrix::zeros(c.n(), c.d());
        for j in 0..c.n() {
            for t in 0..c.d() {
                centroids[(j, t)] = c_f32[j * spec.d + t] as f64;
            }
        }
        let assign_i32 = assign.to_vec::<i32>()?;
        let assignment: Assignment = assign_i32[..x.n()].iter().map(|&v| v as u32).collect();
        if assignment.iter().any(|&a| a as usize >= c.n()) {
            bail!("artifact returned an assignment to a padding centroid");
        }
        let energy_v = energy.to_vec::<f32>()?;
        let counts_v: Vec<f64> =
            counts.to_vec::<f32>()?[..c.n()].iter().map(|&v| v as f64).collect();
        Ok(GStepOutput {
            centroids,
            assignment,
            energy: energy_v[0] as f64,
            counts: counts_v,
        })
    }

    /// Run assignment + energy only (`energy_step` artifact).
    pub fn energy_step(&self, x: &DataMatrix, c: &DataMatrix) -> Result<(Assignment, f64)> {
        let spec = self
            .manifest
            .find_bucket("energy_step", x.n(), x.d(), c.n())
            .with_context(|| {
                format!(
                    "no energy_step bucket for n={} d={} k={} (available: {})",
                    x.n(),
                    x.d(),
                    c.n(),
                    self.manifest.bucket_summary("energy_step")
                )
            })?
            .clone();
        let padded = pad_problem(x, c, spec.n, spec.k);
        let result = self.execute_padded(&spec, &padded)?;
        let (assign, energy) = result.to_tuple2()?;
        let assign_i32 = assign.to_vec::<i32>()?;
        let assignment: Assignment = assign_i32[..x.n()].iter().map(|&v| v as u32).collect();
        let energy_v = energy.to_vec::<f32>()?;
        Ok((assignment, energy_v[0] as f64))
    }
}

/// View a `f32` slice as bytes (little-endian host layout, what PJRT wants).
fn bytes_of(v: &[f32]) -> &[u8] {
    // SAFETY: f32 has no invalid bit patterns and we only reinterpret for
    // reading; alignment of u8 is 1.
    unsafe { std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4) }
}

/// An [`AssignmentEngine`] running the assignment step through the AOT
/// artifact (the `energy_step` kind). This is how `EngineKind::Pjrt` plugs
/// into the Algorithm-1 solver: Rust drives the outer loop, PJRT executes
/// the JAX/Pallas compute.
pub struct PjrtEngine {
    runtime: std::rc::Rc<PjrtRuntime>,
}

impl PjrtEngine {
    /// Wrap a shared runtime.
    pub fn new(runtime: std::rc::Rc<PjrtRuntime>) -> Self {
        Self { runtime }
    }

    /// Convenience: open the artifact dir and wrap.
    pub fn open(artifact_dir: &Path) -> Result<Self> {
        Ok(Self::new(std::rc::Rc::new(PjrtRuntime::open(artifact_dir)?)))
    }
}

impl AssignmentEngine for PjrtEngine {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn assign(&mut self, x: &DataMatrix, c: &DataMatrix, _pool: &ThreadPool, out: &mut Assignment) {
        let (assignment, _energy) = self
            .runtime
            .energy_step(x, c)
            .expect("PJRT energy_step failed (missing bucket or artifact)");
        *out = assignment;
    }

    fn reset(&mut self) {}

    fn distance_evals(&self) -> u64 {
        self.runtime.dist_evals()
    }
}

/// Default artifact directory: `$AAKM_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> std::path::PathBuf {
    std::env::var_os("AAKM_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_of_roundtrip() {
        let v = [1.0f32, -2.5, 3.25];
        let b = bytes_of(&v);
        assert_eq!(b.len(), 12);
        let back = f32::from_le_bytes([b[4], b[5], b[6], b[7]]);
        assert_eq!(back, -2.5);
    }

    #[test]
    fn default_dir_env_override() {
        // Note: env-var mutation is process-global; keep the assert local.
        std::env::set_var("AAKM_ARTIFACTS", "/tmp/aakm_custom");
        assert_eq!(default_artifact_dir(), std::path::PathBuf::from("/tmp/aakm_custom"));
        std::env::remove_var("AAKM_ARTIFACTS");
        assert_eq!(default_artifact_dir(), std::path::PathBuf::from("artifacts"));
    }
}
