//! Artifact manifest: the TOML-subset file `aot.py` writes next to the
//! HLO artifacts, describing each shape bucket.

use crate::config::ConfigDoc;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// One lowered artifact (a `kind` at a concrete shape bucket).
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// Section name, e.g. `g_step_n1024_d8_k16`.
    pub name: String,
    /// `g_step` or `energy_step`.
    pub kind: String,
    /// Bucket sample count.
    pub n: usize,
    /// Bucket dimensionality (must match exactly).
    pub d: usize,
    /// Bucket cluster capacity.
    pub k: usize,
    /// File name inside the artifact dir.
    pub file: String,
}

/// Parsed manifest + artifact directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub specs: Vec<ArtifactSpec>,
    /// The Pallas tile size the artifacts were lowered with.
    pub tile_n: usize,
    /// jax version recorded at lowering time (for diagnostics).
    pub jax_version: String,
}

impl Manifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.txt");
        let doc = ConfigDoc::parse_file(&path)
            .map_err(|e| anyhow::anyhow!("{e}"))
            .with_context(|| format!("load manifest {}", path.display()))?;
        Self::from_doc(&doc, dir)
    }

    /// Build from a parsed document (exposed for tests).
    pub fn from_doc(doc: &ConfigDoc, dir: &Path) -> Result<Self> {
        let tile_n = doc
            .get("", "tile_n")
            .and_then(|v| v.as_int().ok())
            .unwrap_or(256) as usize;
        let jax_version = doc
            .get("", "jax_version")
            .and_then(|v| v.as_str().ok().map(str::to_string))
            .unwrap_or_default();
        let mut sections: Vec<String> = Vec::new();
        for (section, _) in doc.keys() {
            if !section.is_empty() && !sections.iter().any(|s| s == section) {
                sections.push(section.to_string());
            }
        }
        let mut specs = Vec::new();
        for name in sections {
            let get = |key: &str| {
                doc.get(&name, key)
                    .with_context(|| format!("manifest section [{name}] missing `{key}`"))
            };
            let kind = get("kind")?.as_str().map_err(|e| anyhow::anyhow!("{e}"))?.to_string();
            let n = get("n")?.as_int().map_err(|e| anyhow::anyhow!("{e}"))? as usize;
            let d = get("d")?.as_int().map_err(|e| anyhow::anyhow!("{e}"))? as usize;
            let k = get("k")?.as_int().map_err(|e| anyhow::anyhow!("{e}"))? as usize;
            let file = get("file")?.as_str().map_err(|e| anyhow::anyhow!("{e}"))?.to_string();
            specs.push(ArtifactSpec { name, kind, n, d, k, file });
        }
        anyhow::ensure!(!specs.is_empty(), "manifest lists no artifacts");
        Ok(Self { dir: dir.to_path_buf(), specs, tile_n, jax_version })
    }

    /// Smallest bucket of `kind` that fits `(n, d, k)`: `d` must match
    /// exactly (HLO is shape-static in every dim; padding the feature axis
    /// would change distances), `n`/`k` round up.
    pub fn find_bucket(&self, kind: &str, n: usize, d: usize, k: usize) -> Option<&ArtifactSpec> {
        self.specs
            .iter()
            .filter(|s| s.kind == kind && s.d == d && s.n >= n && s.k >= k)
            .min_by_key(|s| (s.n, s.k))
    }

    /// Human list of available buckets for one kind (error messages).
    pub fn bucket_summary(&self, kind: &str) -> String {
        let mut v: Vec<String> = self
            .specs
            .iter()
            .filter(|s| s.kind == kind)
            .map(|s| format!("n{}d{}k{}", s.n, s.d, s.k))
            .collect();
        v.sort();
        if v.is_empty() {
            "none".to_string()
        } else {
            v.join(", ")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
jax_version = "0.8.2"
format = "hlo-text"
tile_n = 256
[g_step_n1024_d8_k16]
kind = "g_step"
n = 1024
d = 8
k = 16
file = "g_step_n1024_d8_k16.hlo.txt"
[g_step_n4096_d8_k16]
kind = "g_step"
n = 4096
d = 8
k = 16
file = "g_step_n4096_d8_k16.hlo.txt"
[g_step_n1024_d2_k16]
kind = "g_step"
n = 1024
d = 2
k = 16
file = "g_step_n1024_d2_k16.hlo.txt"
"#;

    fn manifest() -> Manifest {
        let doc = ConfigDoc::parse(SAMPLE).unwrap();
        Manifest::from_doc(&doc, Path::new("/tmp/a")).unwrap()
    }

    #[test]
    fn parses_specs() {
        let m = manifest();
        assert_eq!(m.specs.len(), 3);
        assert_eq!(m.tile_n, 256);
        assert_eq!(m.jax_version, "0.8.2");
    }

    #[test]
    fn bucket_selection_rounds_up() {
        let m = manifest();
        let s = m.find_bucket("g_step", 900, 8, 10).unwrap();
        assert_eq!(s.n, 1024);
        let s = m.find_bucket("g_step", 1025, 8, 10).unwrap();
        assert_eq!(s.n, 4096);
    }

    #[test]
    fn bucket_requires_exact_d() {
        let m = manifest();
        assert!(m.find_bucket("g_step", 100, 3, 10).is_none());
        assert!(m.find_bucket("g_step", 100, 2, 10).is_some());
    }

    #[test]
    fn bucket_none_when_too_large() {
        let m = manifest();
        assert!(m.find_bucket("g_step", 100_000, 8, 10).is_none());
        assert!(m.find_bucket("g_step", 100, 8, 32).is_none());
    }

    #[test]
    fn summary_lists_buckets() {
        let m = manifest();
        let s = m.bucket_summary("g_step");
        assert!(s.contains("n1024d8k16"));
        assert_eq!(m.bucket_summary("nope"), "none");
    }

    #[test]
    fn empty_manifest_rejected() {
        let doc = ConfigDoc::parse("tile_n = 256\n").unwrap();
        assert!(Manifest::from_doc(&doc, Path::new("/tmp")).is_err());
    }
}
