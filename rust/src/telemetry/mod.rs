//! Process-wide telemetry: a lock-cheap metrics registry plus a
//! structured JSONL event log ([`events`]).
//!
//! The registry holds atomic [`Counter`]s, [`Gauge`]s and fixed-bucket
//! [`Histogram`]s covering the solver driver (iterations, AA
//! accept/reject, restarts, per-phase time), the coordinator (queue
//! depth per client lane, queue-wait/run-time distributions, admission
//! and supervision counters), the streaming engine (chunks, rows),
//! durability (snapshot/model write latency + bytes) and fault
//! injection. [`prometheus_text`] renders the whole registry in the
//! Prometheus text exposition format; [`json_dump`] renders the same
//! data as one JSON object.
//!
//! Collection is **off by default** and gated on a single relaxed
//! atomic load ([`enabled`]): every mutation primitive early-returns
//! when disabled, and the solver hot loop additionally batches its
//! counts in locals and flushes once per run, so un-instrumented runs
//! pay nothing (asserted by `benches/perf_observe.rs` and the counting
//! allocator in `tests/alloc_reuse.rs`). Enabling is process-wide
//! ([`enable`]) — the CLI does it for `serve --metrics-out` and
//! `telemetry dump`.

pub mod events;

use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Duration;

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Turn metric collection on, process-wide. Forces registry
/// initialization so later recording never allocates.
pub fn enable() {
    let _ = metrics();
    ENABLED.store(true, Ordering::SeqCst);
}

/// Turn metric collection off again (recorded values are kept).
pub fn disable() {
    ENABLED.store(false, Ordering::SeqCst);
}

/// Whether metric collection is on. One relaxed load — cheap enough
/// for per-iteration checks; hot loops still batch in locals.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Monotonically increasing event count. All mutations are relaxed
/// atomics: concurrent increments never lose counts (asserted by the
/// registry concurrency test).
#[derive(Debug, Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add 1 (no-op while telemetry is disabled).
    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    /// Add `n` (no-op while telemetry is disabled).
    #[inline]
    pub fn add(&self, n: u64) {
        if enabled() {
            self.value.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Point-in-time signed value (queue depths, in-flight jobs, last
/// dynamic-m window).
#[derive(Debug, Default)]
pub struct Gauge {
    value: AtomicI64,
}

impl Gauge {
    pub fn new() -> Self {
        Self::default()
    }

    /// Set to `v` (no-op while telemetry is disabled).
    #[inline]
    pub fn set(&self, v: i64) {
        if enabled() {
            self.value.store(v, Ordering::Relaxed);
        }
    }

    /// Add a signed delta (no-op while telemetry is disabled).
    #[inline]
    pub fn add(&self, delta: i64) {
        if enabled() {
            self.value.fetch_add(delta, Ordering::Relaxed);
        }
    }

    /// Current value.
    pub fn get(&self) -> i64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// Latency bucket upper bounds in seconds (log-spaced 100µs..30s).
pub const LATENCY_BOUNDS: &[f64] = &[
    0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5,
    5.0, 10.0, 30.0,
];

/// Iteration-count bucket upper bounds (powers of two).
pub const ITERATION_BOUNDS: &[f64] =
    &[1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0, 512.0, 1024.0];

/// Fixed-bucket histogram: `bounds.len() + 1` atomic buckets (the last
/// is the `+Inf` overflow), an atomic micro-unit sum and a count. All
/// recording is lock- and allocation-free; the bucket bounds are static
/// so a registry entry is built exactly once.
#[derive(Debug)]
pub struct Histogram {
    bounds: &'static [f64],
    buckets: Box<[AtomicU64]>,
    sum_micro: AtomicU64,
    count: AtomicU64,
}

impl Histogram {
    /// Histogram over the given ascending upper bounds.
    pub fn with_bounds(bounds: &'static [f64]) -> Self {
        let buckets: Box<[AtomicU64]> =
            (0..=bounds.len()).map(|_| AtomicU64::new(0)).collect::<Vec<_>>().into_boxed_slice();
        Self { bounds, buckets, sum_micro: AtomicU64::new(0), count: AtomicU64::new(0) }
    }

    /// Record one sample (no-op while telemetry is disabled).
    #[inline]
    pub fn observe(&self, value: f64) {
        if !enabled() {
            return;
        }
        let v = if value.is_finite() && value > 0.0 { value } else { 0.0 };
        let idx = self.bounds.iter().position(|b| v <= *b).unwrap_or(self.bounds.len());
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.sum_micro.fetch_add((v * 1e6) as u64, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a duration, in seconds.
    #[inline]
    pub fn observe_duration(&self, d: Duration) {
        self.observe(d.as_secs_f64());
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of recorded samples (micro-unit resolution).
    pub fn sum(&self) -> f64 {
        self.sum_micro.load(Ordering::Relaxed) as f64 / 1e6
    }

    /// Bucket upper bounds.
    pub fn bounds(&self) -> &'static [f64] {
        self.bounds
    }

    /// Per-bucket counts, `bounds().len() + 1` entries (last = overflow).
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    /// Estimated q-quantile (`0.0..=1.0`) by linear interpolation within
    /// the bucket containing the target rank. Returns 0 with no samples;
    /// samples in the overflow bucket report the last finite bound.
    pub fn quantile(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            let c = b.load(Ordering::Relaxed);
            cum += c;
            if c > 0 && cum >= target {
                let last = self.bounds.last().copied().unwrap_or(0.0);
                let hi = self.bounds.get(i).copied().unwrap_or(last);
                let lo = if i == 0 { 0.0 } else { self.bounds[i - 1] };
                let into = (target - (cum - c)) as f64 / c as f64;
                return lo + (hi - lo) * into;
            }
        }
        self.bounds.last().copied().unwrap_or(0.0)
    }
}

/// Small labelled family of signed gauges (per-client queue-lane
/// depth). Mutations take a mutex but only ever run on queue push/pop —
/// never inside the solver loop — and allocate only on first sight of a
/// label.
#[derive(Debug, Default)]
pub struct LabeledGauges {
    inner: Mutex<Vec<(String, i64)>>,
}

impl LabeledGauges {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `delta` to the gauge for `label` (no-op while disabled).
    pub fn add(&self, label: &str, delta: i64) {
        if !enabled() {
            return;
        }
        let mut g = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        if let Some(entry) = g.iter_mut().find(|(name, _)| name == label) {
            entry.1 += delta;
        } else {
            g.push((label.to_string(), delta));
        }
    }

    /// Snapshot of `(label, value)` pairs in first-seen order.
    pub fn snapshot(&self) -> Vec<(String, i64)> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner()).clone()
    }
}

/// Small labelled family of counters in micro-units (per-phase solver
/// time). Flushed once per run, not per iteration.
#[derive(Debug, Default)]
pub struct LabeledCounters {
    inner: Mutex<Vec<(String, u64)>>,
}

impl LabeledCounters {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `v` to the counter for `label` (no-op while disabled).
    pub fn add(&self, label: &str, v: u64) {
        if !enabled() {
            return;
        }
        let mut g = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        if let Some(entry) = g.iter_mut().find(|(name, _)| name == label) {
            entry.1 += v;
        } else {
            g.push((label.to_string(), v));
        }
    }

    /// Snapshot of `(label, value)` pairs in first-seen order.
    pub fn snapshot(&self) -> Vec<(String, u64)> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner()).clone()
    }
}

/// The process-wide registry. Every metric is pre-registered here as a
/// struct field, so recording never takes a registry lock or allocates.
#[derive(Debug)]
pub struct Metrics {
    // Solver driver (accel::FixedPointDriver).
    pub solver_runs: Counter,
    pub solver_iterations: Counter,
    pub aa_proposals: Counter,
    pub aa_accepted: Counter,
    pub aa_rejected: Counter,
    pub aa_restarts: Counter,
    pub solver_m: Gauge,
    pub solver_run_iterations: Histogram,
    pub solver_phase_micros: LabeledCounters,
    // Coordinator.
    pub jobs_submitted: Counter,
    pub jobs_shed: Counter,
    pub jobs_completed: Counter,
    pub jobs_failed: Counter,
    pub job_retries: Counter,
    pub worker_respawns: Counter,
    pub jobs_recovered: Counter,
    pub jobs_degraded: Counter,
    pub jobs_inflight: Gauge,
    pub queue_depth: Gauge,
    pub queue_lane_depth: LabeledGauges,
    pub job_queue_wait: Histogram,
    pub job_run: Histogram,
    // Streaming engine.
    pub stream_chunks: Counter,
    pub stream_rows: Counter,
    pub stream_prefetch_hits: Counter,
    pub stream_prefetch_stalls: Counter,
    pub stream_prefetch_bytes: Counter,
    pub stream_prefetch_stall_seconds: Histogram,
    // Durability.
    pub snapshot_writes: Counter,
    pub snapshot_bytes: Counter,
    pub snapshot_write_seconds: Histogram,
    pub model_writes: Counter,
    pub model_bytes: Counter,
    pub model_write_seconds: Histogram,
    // Fault injection + telemetry self-accounting.
    pub fault_injections: Counter,
    pub events_dropped: Counter,
    pub progress_dropped: Counter,
}

impl Metrics {
    fn new() -> Self {
        Self {
            solver_runs: Counter::new(),
            solver_iterations: Counter::new(),
            aa_proposals: Counter::new(),
            aa_accepted: Counter::new(),
            aa_rejected: Counter::new(),
            aa_restarts: Counter::new(),
            solver_m: Gauge::new(),
            solver_run_iterations: Histogram::with_bounds(ITERATION_BOUNDS),
            solver_phase_micros: LabeledCounters::new(),
            jobs_submitted: Counter::new(),
            jobs_shed: Counter::new(),
            jobs_completed: Counter::new(),
            jobs_failed: Counter::new(),
            job_retries: Counter::new(),
            worker_respawns: Counter::new(),
            jobs_recovered: Counter::new(),
            jobs_degraded: Counter::new(),
            jobs_inflight: Gauge::new(),
            queue_depth: Gauge::new(),
            queue_lane_depth: LabeledGauges::new(),
            job_queue_wait: Histogram::with_bounds(LATENCY_BOUNDS),
            job_run: Histogram::with_bounds(LATENCY_BOUNDS),
            stream_chunks: Counter::new(),
            stream_rows: Counter::new(),
            stream_prefetch_hits: Counter::new(),
            stream_prefetch_stalls: Counter::new(),
            stream_prefetch_bytes: Counter::new(),
            stream_prefetch_stall_seconds: Histogram::with_bounds(LATENCY_BOUNDS),
            snapshot_writes: Counter::new(),
            snapshot_bytes: Counter::new(),
            snapshot_write_seconds: Histogram::with_bounds(LATENCY_BOUNDS),
            model_writes: Counter::new(),
            model_bytes: Counter::new(),
            model_write_seconds: Histogram::with_bounds(LATENCY_BOUNDS),
            fault_injections: Counter::new(),
            events_dropped: Counter::new(),
            progress_dropped: Counter::new(),
        }
    }

    fn counters(&self) -> [(&'static str, &'static str, &Counter); 19] {
        [
            ("aakm_solver_runs_total", "Completed solver driver runs", &self.solver_runs),
            ("aakm_solver_iterations_total", "Productive iterations", &self.solver_iterations),
            ("aakm_aa_proposals_total", "Anderson candidates proposed", &self.aa_proposals),
            ("aakm_aa_accepted_total", "Anderson candidates accepted", &self.aa_accepted),
            ("aakm_aa_rejected_total", "Anderson candidates rejected", &self.aa_rejected),
            ("aakm_aa_restarts_total", "Anderson history restarts", &self.aa_restarts),
            ("aakm_jobs_submitted_total", "Jobs admitted to the queue", &self.jobs_submitted),
            ("aakm_jobs_shed_total", "Jobs shed by admission control", &self.jobs_shed),
            ("aakm_jobs_completed_total", "Jobs finished successfully", &self.jobs_completed),
            ("aakm_jobs_failed_total", "Jobs finished with an error", &self.jobs_failed),
            ("aakm_job_retries_total", "Job attempts retried", &self.job_retries),
            ("aakm_worker_respawns_total", "Workers respawned", &self.worker_respawns),
            ("aakm_jobs_recovered_total", "Jobs re-submitted on recovery", &self.jobs_recovered),
            ("aakm_jobs_degraded_total", "Jobs degraded to a fallback engine", &self.jobs_degraded),
            ("aakm_stream_chunks_total", "Streaming chunks read", &self.stream_chunks),
            ("aakm_stream_rows_total", "Streaming rows read", &self.stream_rows),
            ("aakm_snapshot_writes_total", "Checkpoint snapshots written", &self.snapshot_writes),
            ("aakm_snapshot_bytes_total", "Snapshot bytes written", &self.snapshot_bytes),
            ("aakm_model_writes_total", "Registry model records written", &self.model_writes),
        ]
    }

    fn counters2(&self) -> [(&'static str, &'static str, &Counter); 7] {
        [
            ("aakm_model_bytes_total", "Registry model bytes written", &self.model_bytes),
            (
                "aakm_stream_prefetch_hits_total",
                "Prefetched chunks ready on arrival",
                &self.stream_prefetch_hits,
            ),
            (
                "aakm_stream_prefetch_stalls_total",
                "Chunk requests that waited on the prefetcher",
                &self.stream_prefetch_stalls,
            ),
            (
                "aakm_stream_prefetch_bytes_total",
                "Sample bytes served through the prefetch pipeline",
                &self.stream_prefetch_bytes,
            ),
            ("aakm_fault_injections_total", "Injected faults fired", &self.fault_injections),
            ("aakm_events_dropped_total", "Event lines dropped", &self.events_dropped),
            ("aakm_progress_dropped_total", "Progress records dropped", &self.progress_dropped),
        ]
    }

    fn gauges(&self) -> [(&'static str, &'static str, &Gauge); 3] {
        [
            ("aakm_solver_m", "Anderson window m after the latest run", &self.solver_m),
            ("aakm_jobs_inflight", "Jobs being executed by workers", &self.jobs_inflight),
            ("aakm_queue_depth", "Jobs waiting in the coordinator queue", &self.queue_depth),
        ]
    }

    fn histograms(&self) -> [(&'static str, &'static str, &Histogram); 6] {
        [
            ("aakm_solver_run_iterations", "Iterations per run", &self.solver_run_iterations),
            (
                "aakm_stream_prefetch_stall_seconds",
                "Consumer wait on a prefetched chunk",
                &self.stream_prefetch_stall_seconds,
            ),
            ("aakm_job_queue_wait_seconds", "Submit-to-pickup wait", &self.job_queue_wait),
            ("aakm_job_run_seconds", "Solver run time per successful attempt", &self.job_run),
            (
                "aakm_snapshot_write_seconds",
                "Checkpoint snapshot write latency",
                &self.snapshot_write_seconds,
            ),
            ("aakm_model_write_seconds", "Registry model write latency", &self.model_write_seconds),
        ]
    }

    /// Prometheus text exposition of every registered metric.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::with_capacity(4096);
        let all: Vec<_> =
            self.counters().iter().chain(self.counters2().iter()).cloned().collect();
        for (name, help, c) in all {
            out.push_str(&format!(
                "# HELP {name} {help}\n# TYPE {name} counter\n{name} {}\n",
                c.get()
            ));
        }
        for (name, help, g) in self.gauges() {
            out.push_str(&format!(
                "# HELP {name} {help}\n# TYPE {name} gauge\n{name} {}\n",
                g.get()
            ));
        }
        {
            let name = "aakm_queue_lane_depth";
            out.push_str(&format!(
                "# HELP {name} Jobs waiting per client lane\n# TYPE {name} gauge\n"
            ));
            for (label, v) in self.queue_lane_depth.snapshot() {
                out.push_str(&format!("{name}{{client=\"{}\"}} {v}\n", escape_label(&label)));
            }
        }
        {
            let name = "aakm_solver_phase_seconds_total";
            out.push_str(&format!(
                "# HELP {name} Cumulative solver time per phase\n# TYPE {name} counter\n"
            ));
            for (label, micros) in self.solver_phase_micros.snapshot() {
                out.push_str(&format!(
                    "{name}{{phase=\"{}\"}} {}\n",
                    escape_label(&label),
                    micros as f64 / 1e6
                ));
            }
        }
        for (name, help, h) in self.histograms() {
            out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} histogram\n"));
            let counts = h.bucket_counts();
            let mut cum = 0u64;
            for (i, c) in counts.iter().enumerate() {
                cum += c;
                match h.bounds().get(i) {
                    Some(le) => {
                        out.push_str(&format!("{name}_bucket{{le=\"{le}\"}} {cum}\n"));
                    }
                    None => out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {cum}\n")),
                }
            }
            out.push_str(&format!("{name}_sum {}\n{name}_count {}\n", h.sum(), h.count()));
        }
        out
    }

    /// The same registry as one JSON object.
    pub fn render_json(&self) -> String {
        let mut out = String::with_capacity(4096);
        out.push('{');
        let mut first = true;
        let mut field = |out: &mut String, key: &str, value: String| {
            if !first {
                out.push(',');
            }
            first = false;
            out.push_str(&format!("\"{key}\":{value}"));
        };
        for (name, _, c) in self.counters().iter().chain(self.counters2().iter()) {
            field(&mut out, name, c.get().to_string());
        }
        for (name, _, g) in self.gauges() {
            field(&mut out, name, g.get().to_string());
        }
        {
            let lanes = self
                .queue_lane_depth
                .snapshot()
                .iter()
                .map(|(l, v)| format!("\"{}\":{v}", events::escape_json(l)))
                .collect::<Vec<_>>()
                .join(",");
            field(&mut out, "aakm_queue_lane_depth", format!("{{{lanes}}}"));
        }
        {
            let phases = self
                .solver_phase_micros
                .snapshot()
                .iter()
                .map(|(l, v)| format!("\"{}\":{}", events::escape_json(l), *v as f64 / 1e6))
                .collect::<Vec<_>>()
                .join(",");
            field(&mut out, "aakm_solver_phase_seconds_total", format!("{{{phases}}}"));
        }
        for (name, _, h) in self.histograms() {
            let counts = h.bucket_counts();
            let buckets = counts
                .iter()
                .enumerate()
                .map(|(i, c)| match h.bounds().get(i) {
                    Some(le) => format!("[{le},{c}]"),
                    None => format!("[null,{c}]"),
                })
                .collect::<Vec<_>>()
                .join(",");
            field(
                &mut out,
                name,
                format!(
                    "{{\"sum\":{},\"count\":{},\"buckets\":[{buckets}]}}",
                    h.sum(),
                    h.count()
                ),
            );
        }
        out.push('}');
        out
    }
}

fn escape_label(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

static REGISTRY: OnceLock<Metrics> = OnceLock::new();

/// The process-wide metrics registry (initialized on first use).
pub fn metrics() -> &'static Metrics {
    REGISTRY.get_or_init(Metrics::new)
}

/// Prometheus text exposition of the whole registry.
pub fn prometheus_text() -> String {
    metrics().render_prometheus()
}

/// JSON dump of the whole registry.
pub fn json_dump() -> String {
    metrics().render_json()
}

#[cfg(test)]
mod tests {
    use super::*;

    // The enable flag is process-global and the crate's unit tests run
    // in parallel threads, so every test that toggles it serializes here.
    static GATE: Mutex<()> = Mutex::new(());

    fn with_enabled<T>(f: impl FnOnce() -> T) -> T {
        enable();
        let out = f();
        disable();
        out
    }

    #[test]
    fn counter_and_gauge_gate_on_enable() {
        let _gate = GATE.lock().unwrap_or_else(|p| p.into_inner());
        let c = Counter::new();
        let g = Gauge::new();
        disable();
        c.inc();
        g.set(5);
        assert_eq!(c.get(), 0, "disabled counter must not move");
        assert_eq!(g.get(), 0, "disabled gauge must not move");
        with_enabled(|| {
            c.add(3);
            g.set(5);
            g.add(-2);
        });
        assert_eq!(c.get(), 3);
        assert_eq!(g.get(), 3);
    }

    #[test]
    fn histogram_buckets_sum_and_quantiles() {
        let _gate = GATE.lock().unwrap_or_else(|p| p.into_inner());
        let h = Histogram::with_bounds(&[0.001, 0.01, 0.1, 1.0]);
        with_enabled(|| {
            for _ in 0..90 {
                h.observe(0.005); // bucket le=0.01
            }
            for _ in 0..10 {
                h.observe(0.5); // bucket le=1.0
            }
            h.observe(99.0); // overflow
        });
        assert_eq!(h.count(), 101);
        assert_eq!(h.bucket_counts(), vec![0, 90, 0, 10, 1]);
        let p50 = h.quantile(0.5);
        assert!(p50 > 0.001 && p50 <= 0.01, "p50 {p50} must fall in the 0.01 bucket");
        let p99 = h.quantile(0.99);
        assert!(p99 > 0.1 && p99 <= 1.0, "p99 {p99} must fall in the 1.0 bucket");
        // Overflow samples report the last finite bound.
        assert_eq!(h.quantile(1.0), 1.0);
        // Empty histogram: 0.
        assert_eq!(Histogram::with_bounds(LATENCY_BOUNDS).quantile(0.5), 0.0);
    }

    #[test]
    fn labelled_families_accumulate_per_label() {
        let _gate = GATE.lock().unwrap_or_else(|p| p.into_inner());
        let lanes = LabeledGauges::new();
        let phases = LabeledCounters::new();
        with_enabled(|| {
            lanes.add("a", 2);
            lanes.add("b", 1);
            lanes.add("a", -1);
            phases.add("assign", 100);
            phases.add("assign", 50);
        });
        assert_eq!(lanes.snapshot(), vec![("a".into(), 1), ("b".into(), 1)]);
        assert_eq!(phases.snapshot(), vec![("assign".into(), 150)]);
    }

    #[test]
    fn prometheus_and_json_render_every_family() {
        let _gate = GATE.lock().unwrap_or_else(|p| p.into_inner());
        with_enabled(|| {
            metrics().solver_runs.inc();
            metrics().queue_lane_depth.add("c0", 1);
            metrics().solver_phase_micros.add("assign", 1_000_000);
            metrics().job_queue_wait.observe(0.002);
            metrics().queue_lane_depth.add("c0", -1);
        });
        let text = prometheus_text();
        for family in [
            "aakm_solver_runs_total",
            "aakm_solver_iterations_total",
            "aakm_jobs_submitted_total",
            "aakm_queue_depth",
            "aakm_queue_lane_depth",
            "aakm_solver_phase_seconds_total",
            "aakm_job_queue_wait_seconds_bucket",
            "aakm_job_queue_wait_seconds_count",
            "aakm_fault_injections_total",
        ] {
            assert!(text.contains(family), "exposition missing {family}:\n{text}");
        }
        // Every non-comment line is `name[{labels}] value` with a numeric value.
        for line in text.lines().filter(|l| !l.starts_with('#') && !l.is_empty()) {
            let (_, value) = line.rsplit_once(' ').expect("metric line has a value");
            value.parse::<f64>().unwrap_or_else(|_| panic!("non-numeric value in '{line}'"));
        }
        let json = json_dump();
        assert!(json.starts_with('{') && json.ends_with('}'));
        assert!(json.contains("\"aakm_solver_runs_total\":"));
        assert!(json.contains("\"aakm_job_queue_wait_seconds\":{\"sum\":"));
    }
}
