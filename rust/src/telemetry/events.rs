//! Structured JSONL event log: one flat JSON object per line with a
//! versioned schema (`"v"`), a wall-clock microsecond timestamp
//! (`"ts_us"`) and a `"kind"` tag, covering the coordinator job
//! lifecycle (submit → pickup → attempt → outcome, plus shed / retry /
//! respawn / degraded) and per-iteration solver progress.
//!
//! The writer is **bounded and non-blocking**: [`emit`] hands the
//! rendered line to a background thread over a bounded channel with
//! `try_send`, and on a full buffer the line is dropped and counted
//! ([`dropped`], `aakm_events_dropped_total`) instead of ever stalling
//! the solver or a coordinator worker. [`read_events`] parses a log
//! back with the persist idiom for durability files: strict on
//! interior lines, lenient on a torn tail (a crash mid-append loses at
//! most the final partial line).

use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, SyncSender, TrySendError};
use std::sync::Mutex;
use std::thread::JoinHandle;

/// Version stamped into (and required of) every event line.
pub const SCHEMA_VERSION: u64 = 1;

/// Default bounded-buffer capacity (lines) for the background writer.
pub const DEFAULT_BUFFER: usize = 4096;

static EVENTS_ON: AtomicBool = AtomicBool::new(false);
static DROPPED: AtomicU64 = AtomicU64::new(0);
static SINK: Mutex<Option<SyncSender<String>>> = Mutex::new(None);

/// Whether an event log is installed (one relaxed load).
#[inline(always)]
pub fn events_enabled() -> bool {
    EVENTS_ON.load(Ordering::Relaxed)
}

/// Lines dropped because the bounded buffer was full (process-wide,
/// monotone — counted even when the metrics registry is disabled).
pub fn dropped() -> u64 {
    DROPPED.load(Ordering::Relaxed)
}

/// One telemetry event. Rendering is hand-rolled (flat objects only)
/// so the hot path never needs an external serializer.
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    /// Job admitted into the queue.
    Submit { job: u64, client: String },
    /// Job rejected by admission control.
    Shed { client: String },
    /// Worker picked the job off the queue.
    Pickup { job: u64, worker: u64, queue_wait_us: u64 },
    /// One execution attempt started.
    Attempt { job: u64, attempt: u64 },
    /// Attempt failed with a retryable fault; the job will re-run.
    Retry { job: u64, attempt: u64, error: String },
    /// Job degraded to a fallback engine after an engine-load fault.
    Degraded { job: u64, engine: String },
    /// Terminal outcome of a job.
    Outcome { job: u64, ok: bool, error: String, iterations: u64, energy: f64, service_us: u64 },
    /// Supervisor replaced a dead worker.
    Respawn { worker: u64 },
    /// One productive solver iteration of a coordinator job.
    Iteration { job: u64, iteration: u64, energy: f64, m: u64, accelerated: bool, accepted: bool },
}

impl Event {
    /// The `"kind"` tag of this event.
    pub fn kind(&self) -> &'static str {
        match self {
            Event::Submit { .. } => "submit",
            Event::Shed { .. } => "shed",
            Event::Pickup { .. } => "pickup",
            Event::Attempt { .. } => "attempt",
            Event::Retry { .. } => "retry",
            Event::Degraded { .. } => "degraded",
            Event::Outcome { .. } => "outcome",
            Event::Respawn { .. } => "respawn",
            Event::Iteration { .. } => "iter",
        }
    }

    /// Render as one schema-versioned JSONL line (no trailing newline).
    pub fn to_line(&self, ts_us: u64) -> String {
        let mut w = LineWriter::new(self.kind(), ts_us);
        match self {
            Event::Submit { job, client } => {
                w.unum("job", *job);
                w.str("client", client);
            }
            Event::Shed { client } => w.str("client", client),
            Event::Pickup { job, worker, queue_wait_us } => {
                w.unum("job", *job);
                w.unum("worker", *worker);
                w.unum("queue_wait_us", *queue_wait_us);
            }
            Event::Attempt { job, attempt } => {
                w.unum("job", *job);
                w.unum("attempt", *attempt);
            }
            Event::Retry { job, attempt, error } => {
                w.unum("job", *job);
                w.unum("attempt", *attempt);
                w.str("error", error);
            }
            Event::Degraded { job, engine } => {
                w.unum("job", *job);
                w.str("engine", engine);
            }
            Event::Outcome { job, ok, error, iterations, energy, service_us } => {
                w.unum("job", *job);
                w.boolean("ok", *ok);
                w.str("error", error);
                w.unum("iterations", *iterations);
                w.fnum("energy", *energy);
                w.unum("service_us", *service_us);
            }
            Event::Respawn { worker } => w.unum("worker", *worker),
            Event::Iteration { job, iteration, energy, m, accelerated, accepted } => {
                w.unum("job", *job);
                w.unum("iteration", *iteration);
                w.fnum("energy", *energy);
                w.unum("m", *m);
                w.boolean("accelerated", *accelerated);
                w.boolean("accepted", *accepted);
            }
        }
        w.finish()
    }
}

/// Required non-header keys per kind, used by the schema validator.
fn required_keys(kind: &str) -> Option<&'static [&'static str]> {
    Some(match kind {
        "submit" => &["job", "client"],
        "shed" => &["client"],
        "pickup" => &["job", "worker", "queue_wait_us"],
        "attempt" => &["job", "attempt"],
        "retry" => &["job", "attempt", "error"],
        "degraded" => &["job", "engine"],
        "outcome" => &["job", "ok", "error", "iterations", "energy", "service_us"],
        "respawn" => &["worker"],
        "iter" => &["job", "iteration", "energy", "m", "accelerated", "accepted"],
        _ => return None,
    })
}

struct LineWriter {
    buf: String,
}

impl LineWriter {
    fn new(kind: &str, ts_us: u64) -> Self {
        Self { buf: format!("{{\"v\":{SCHEMA_VERSION},\"ts_us\":{ts_us},\"kind\":\"{kind}\"") }
    }

    fn unum(&mut self, key: &str, v: u64) {
        self.buf.push_str(&format!(",\"{key}\":{v}"));
    }

    /// Finite floats render as numbers; NaN/inf (a mini-batch trace
    /// without an energy sample) render as `null`.
    fn fnum(&mut self, key: &str, v: f64) {
        if v.is_finite() {
            self.buf.push_str(&format!(",\"{key}\":{v:?}"));
        } else {
            self.buf.push_str(&format!(",\"{key}\":null"));
        }
    }

    fn boolean(&mut self, key: &str, v: bool) {
        self.buf.push_str(&format!(",\"{key}\":{v}"));
    }

    fn str(&mut self, key: &str, v: &str) {
        self.buf.push_str(&format!(",\"{key}\":\"{}\"", escape_json(v)));
    }

    fn finish(mut self) -> String {
        self.buf.push('}');
        self.buf
    }
}

/// Minimal JSON string escaping (quotes, backslashes, control bytes).
pub fn escape_json(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Owns the background writer. Dropping it (or calling
/// [`EventLogGuard::close`]) disables [`emit`], flushes buffered lines
/// and joins the writer thread.
#[derive(Debug)]
pub struct EventLogGuard {
    path: PathBuf,
    thread: Option<JoinHandle<()>>,
}

impl EventLogGuard {
    /// Where the log is being written.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Disable emission, flush and join the writer.
    pub fn close(mut self) {
        self.shutdown();
    }

    fn shutdown(&mut self) {
        EVENTS_ON.store(false, Ordering::SeqCst);
        // Dropping the sender closes the channel; the writer drains
        // whatever is buffered, flushes and exits.
        *SINK.lock().unwrap_or_else(|p| p.into_inner()) = None;
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for EventLogGuard {
    fn drop(&mut self) {
        if self.thread.is_some() {
            self.shutdown();
        }
    }
}

/// Install the process-wide event log writing to `path` (truncating),
/// with the default buffer capacity.
pub fn install(path: &Path) -> std::io::Result<EventLogGuard> {
    install_with_capacity(path, DEFAULT_BUFFER)
}

/// Install the process-wide event log with an explicit bounded-buffer
/// capacity. Errors if a log is already installed.
pub fn install_with_capacity(path: &Path, capacity: usize) -> std::io::Result<EventLogGuard> {
    let mut sink = SINK.lock().unwrap_or_else(|p| p.into_inner());
    if sink.is_some() {
        return Err(std::io::Error::new(
            std::io::ErrorKind::AlreadyExists,
            "telemetry event log already installed",
        ));
    }
    let file = std::fs::File::create(path)?;
    let (tx, rx) = sync_channel::<String>(capacity.max(1));
    let thread = std::thread::Builder::new().name("aakm-events".into()).spawn(move || {
        let mut w = std::io::BufWriter::new(file);
        while let Ok(line) = rx.recv() {
            let _ = w.write_all(line.as_bytes());
            let _ = w.write_all(b"\n");
            // Drain opportunistically, then flush once the buffer is
            // empty: batching under load, prompt lines when idle.
            while let Ok(next) = rx.try_recv() {
                let _ = w.write_all(next.as_bytes());
                let _ = w.write_all(b"\n");
            }
            let _ = w.flush();
        }
        let _ = w.flush();
    })?;
    *sink = Some(tx);
    drop(sink);
    EVENTS_ON.store(true, Ordering::SeqCst);
    Ok(EventLogGuard { path: path.to_path_buf(), thread: Some(thread) })
}

/// Emit one event. Never blocks: with no log installed this is one
/// relaxed load; with a full buffer the line is dropped and counted.
pub fn emit(ev: &Event) {
    if !events_enabled() {
        return;
    }
    let line = ev.to_line(unix_micros());
    let sink = SINK.lock().unwrap_or_else(|p| p.into_inner());
    let Some(tx) = sink.as_ref() else {
        return;
    };
    match tx.try_send(line) {
        Ok(()) => {}
        Err(TrySendError::Full(_)) | Err(TrySendError::Disconnected(_)) => {
            DROPPED.fetch_add(1, Ordering::Relaxed);
            super::metrics().events_dropped.inc();
        }
    }
}

fn unix_micros() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::SystemTime::UNIX_EPOCH)
        .map(|d| d.as_micros() as u64)
        .unwrap_or(0)
}

/// A parsed event line: the schema header plus every other field in
/// line order.
#[derive(Debug, Clone, PartialEq)]
pub struct ParsedEvent {
    pub v: u64,
    pub ts_us: u64,
    pub kind: String,
    pub fields: Vec<(String, FieldValue)>,
}

impl ParsedEvent {
    /// Numeric field by key.
    pub fn num(&self, key: &str) -> Option<f64> {
        self.fields.iter().find(|(k, _)| k == key).and_then(|(_, v)| match v {
            FieldValue::Num(n) => Some(*n),
            _ => None,
        })
    }

    /// String field by key.
    pub fn text(&self, key: &str) -> Option<&str> {
        self.fields.iter().find(|(k, _)| k == key).and_then(|(_, v)| match v {
            FieldValue::Str(s) => Some(s.as_str()),
            _ => None,
        })
    }

    /// Boolean field by key.
    pub fn boolean(&self, key: &str) -> Option<bool> {
        self.fields.iter().find(|(k, _)| k == key).and_then(|(_, v)| match v {
            FieldValue::Bool(b) => Some(*b),
            _ => None,
        })
    }

    /// Whether the field exists and is JSON `null`.
    pub fn is_null(&self, key: &str) -> bool {
        matches!(
            self.fields.iter().find(|(k, _)| k == key),
            Some((_, FieldValue::Null))
        )
    }
}

/// A flat JSON value as found in event lines.
#[derive(Debug, Clone, PartialEq)]
pub enum FieldValue {
    Num(f64),
    Str(String),
    Bool(bool),
    Null,
}

/// Parse and schema-validate one event line: well-formed flat JSON,
/// `v == 1`, a known `kind`, and that kind's required fields present.
pub fn parse_line(line: &str) -> Result<ParsedEvent, String> {
    let mut fields = parse_flat_object(line)?;
    fn take_header_num(fields: &mut Vec<(String, FieldValue)>, key: &str) -> Result<u64, String> {
        let idx = fields
            .iter()
            .position(|(k, _)| k == key)
            .ok_or_else(|| format!("missing '{key}' header"))?;
        match fields.remove(idx).1 {
            FieldValue::Num(n) if n >= 0.0 && n.fract() == 0.0 => Ok(n as u64),
            other => Err(format!("'{key}' must be a non-negative integer, got {other:?}")),
        }
    }
    let v = take_header_num(&mut fields, "v")?;
    if v != SCHEMA_VERSION {
        return Err(format!("unsupported event schema version {v} (want {SCHEMA_VERSION})"));
    }
    let ts_us = take_header_num(&mut fields, "ts_us")?;
    let kind_idx =
        fields.iter().position(|(k, _)| k == "kind").ok_or("missing 'kind' header")?;
    let kind = match fields.remove(kind_idx).1 {
        FieldValue::Str(s) => s,
        other => return Err(format!("'kind' must be a string, got {other:?}")),
    };
    let required =
        required_keys(&kind).ok_or_else(|| format!("unknown event kind '{kind}'"))?;
    for key in required {
        if !fields.iter().any(|(k, _)| k == key) {
            return Err(format!("event kind '{kind}' is missing required field '{key}'"));
        }
    }
    Ok(ParsedEvent { v, ts_us, kind, fields })
}

/// Read a JSONL event log with torn-tail tolerance: every complete
/// line must parse (an interior corruption is an error naming the line
/// number), while a final line without a trailing newline — a torn
/// append — is ignored and reported via the returned flag.
pub fn read_events(path: &Path) -> Result<(Vec<ParsedEvent>, bool), String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("read {path:?}: {e}"))?;
    let torn = !text.is_empty() && !text.ends_with('\n');
    let mut complete: Vec<&str> = text.lines().collect();
    if torn {
        complete.pop();
    }
    let mut out = Vec::with_capacity(complete.len());
    for (i, line) in complete.iter().enumerate() {
        if line.is_empty() {
            continue;
        }
        let ev = parse_line(line).map_err(|e| format!("line {}: {e}", i + 1))?;
        out.push(ev);
    }
    Ok((out, torn))
}

// ---- flat JSON object parsing ------------------------------------------

fn parse_flat_object(s: &str) -> Result<Vec<(String, FieldValue)>, String> {
    let mut p = Parser { bytes: s.trim().as_bytes(), i: 0 };
    p.expect(b'{')?;
    let mut out = Vec::new();
    p.skip_ws();
    if p.peek() == Some(b'}') {
        p.i += 1;
    } else {
        loop {
            p.skip_ws();
            let key = p.string()?;
            p.skip_ws();
            p.expect(b':')?;
            p.skip_ws();
            let value = p.value()?;
            out.push((key, value));
            p.skip_ws();
            match p.next_byte() {
                Some(b',') => continue,
                Some(b'}') => break,
                other => return Err(format!("expected ',' or '}}', got {other:?}")),
            }
        }
    }
    p.skip_ws();
    if p.i != p.bytes.len() {
        return Err("trailing bytes after object".into());
    }
    Ok(out)
}

struct Parser<'a> {
    bytes: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.i).copied()
    }

    fn next_byte(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.i += 1;
        }
        b
    }

    fn expect(&mut self, want: u8) -> Result<(), String> {
        match self.next_byte() {
            Some(b) if b == want => Ok(()),
            other => Err(format!("expected '{}', got {other:?}", want as char)),
        }
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\r' | b'\n')) {
            self.i += 1;
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.next_byte() {
                None => return Err("unterminated string".into()),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.next_byte() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.next_byte().ok_or("truncated \\u escape")?;
                            code = code * 16
                                + (d as char).to_digit(16).ok_or("bad \\u escape digit")?;
                        }
                        out.push(char::from_u32(code).ok_or("bad \\u code point")?);
                    }
                    other => return Err(format!("bad escape {other:?}")),
                },
                Some(b) if b < 0x20 => return Err("raw control byte in string".into()),
                Some(b) => {
                    // Re-assemble multi-byte UTF-8 sequences byte-wise.
                    let start = self.i - 1;
                    let width = utf8_width(b)?;
                    let end = start + width;
                    if end > self.bytes.len() {
                        return Err("truncated UTF-8 sequence".into());
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| "invalid UTF-8 in string")?;
                    out.push_str(chunk);
                    self.i = end;
                }
            }
        }
    }

    fn value(&mut self) -> Result<FieldValue, String> {
        match self.peek() {
            Some(b'"') => Ok(FieldValue::Str(self.string()?)),
            Some(b't') => self.literal("true").map(|()| FieldValue::Bool(true)),
            Some(b'f') => self.literal("false").map(|()| FieldValue::Bool(false)),
            Some(b'n') => self.literal("null").map(|()| FieldValue::Null),
            Some(b'-' | b'0'..=b'9') => {
                let start = self.i;
                while matches!(self.peek(), Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')) {
                    self.i += 1;
                }
                let text = std::str::from_utf8(&self.bytes[start..self.i]).unwrap_or("");
                text.parse::<f64>()
                    .map(FieldValue::Num)
                    .map_err(|_| format!("bad number '{text}'"))
            }
            other => Err(format!(
                "unexpected value start {other:?} (not part of the flat event schema)"
            )),
        }
    }

    fn literal(&mut self, word: &str) -> Result<(), String> {
        for b in word.bytes() {
            self.expect(b)?;
        }
        Ok(())
    }
}

fn utf8_width(b: u8) -> Result<usize, String> {
    match b {
        0x00..=0x7f => Ok(1),
        0xc0..=0xdf => Ok(2),
        0xe0..=0xef => Ok(3),
        0xf0..=0xf7 => Ok(4),
        _ => Err("invalid UTF-8 lead byte".into()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_kind_round_trips_through_the_parser() {
        let events = vec![
            Event::Submit { job: 7, client: "tenant-a".into() },
            Event::Shed { client: "tenant-\"b\"".into() },
            Event::Pickup { job: 7, worker: 2, queue_wait_us: 1500 },
            Event::Attempt { job: 7, attempt: 1 },
            Event::Retry { job: 7, attempt: 1, error: "chunk read: injected\nfault".into() },
            Event::Degraded { job: 7, engine: "naive".into() },
            Event::Outcome {
                job: 7,
                ok: true,
                error: String::new(),
                iterations: 42,
                energy: 1234.5,
                service_us: 99_000,
            },
            Event::Respawn { worker: 2 },
            Event::Iteration {
                job: 7,
                iteration: 3,
                energy: f64::NAN,
                m: 2,
                accelerated: true,
                accepted: false,
            },
        ];
        for ev in &events {
            let line = ev.to_line(123_456);
            let parsed = parse_line(&line).unwrap_or_else(|e| panic!("{line}: {e}"));
            assert_eq!(parsed.v, SCHEMA_VERSION);
            assert_eq!(parsed.ts_us, 123_456);
            assert_eq!(parsed.kind, ev.kind());
        }
        // Spot-check field fidelity, including escapes and NaN → null.
        let retry = parse_line(&events[4].to_line(1)).unwrap();
        assert_eq!(retry.text("error"), Some("chunk read: injected\nfault"));
        assert_eq!(retry.num("attempt"), Some(1.0));
        let iter = parse_line(&events[8].to_line(1)).unwrap();
        assert!(iter.is_null("energy"), "NaN energy must serialize as null");
        assert_eq!(iter.boolean("accelerated"), Some(true));
        assert_eq!(iter.boolean("accepted"), Some(false));
        let shed = parse_line(&events[1].to_line(1)).unwrap();
        assert_eq!(shed.text("client"), Some("tenant-\"b\""));
    }

    #[test]
    fn parser_rejects_malformed_and_off_schema_lines() {
        for bad in [
            "",
            "{",
            "not json",
            "{\"v\":1}",                                              // missing headers
            "{\"v\":2,\"ts_us\":1,\"kind\":\"submit\",\"job\":1,\"client\":\"c\"}", // bad version
            "{\"v\":1,\"ts_us\":1,\"kind\":\"mystery\"}",             // unknown kind
            "{\"v\":1,\"ts_us\":1,\"kind\":\"submit\",\"job\":1}",    // missing required field
            "{\"v\":1,\"ts_us\":1,\"kind\":\"submit\",\"job\":1,\"client\":\"c\"}x", // trailing
            "{\"v\":1,\"ts_us\":1,\"kind\":\"submit\",\"job\":{},\"client\":\"c\"}", // nested
        ] {
            assert!(parse_line(bad).is_err(), "must reject: {bad}");
        }
    }
}
