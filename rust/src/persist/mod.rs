//! Durable checkpoint/resume: crash-safe solver snapshots and the
//! coordinator's write-ahead job journal.
//!
//! Two on-disk artifacts live here, both following the validation
//! discipline the `AAKMFV01` shard format established (magic, explicit
//! shape, strict length accounting, typed rejection of anything torn):
//!
//! * **`AAKMCK01` snapshots** — one file per run
//!   ([`SNAPSHOT_FILE`] inside the [`CheckpointPolicy::dir`]) holding
//!   everything the safeguarded-Anderson driver needs to resume a run
//!   mid-trajectory *bit-identically*: the committed centroids, the
//!   driver's energy/counter state, the Anderson ΔF/ΔG history (stored
//!   oldest-first so the Gram matrix is rebuilt by replaying the same
//!   incremental pushes), and the solver-shape extras (retained plain
//!   iterate + assignments for the full-batch path; Sculley counts,
//!   sampler RNG raw state and evaluation totals for the mini-batch
//!   path). The payload is framed as tagged records, each carrying its
//!   own CRC-32, and every write goes to a temp file that is atomically
//!   renamed over the previous snapshot — a crash at any instant leaves
//!   either the old complete snapshot or the new complete snapshot,
//!   never a torn one. Torn, truncated, bit-flipped or
//!   wrong-fingerprint files are rejected with
//!   [`ClusterError::Snapshot`], never a panic or a silent wrong read.
//! * **`AAKMJL01` job journals** — an append-only record stream
//!   ([`JOURNAL_FILE`]) of coordinator job lifecycle events
//!   (submitted / started / completed). Each record is CRC-framed; a
//!   torn tail (the crash case an append-only log is designed for) is
//!   silently dropped on read, while a corrupt header or foreign magic
//!   is rejected typed. `Coordinator::recover` replays the journal and
//!   re-enqueues every job that was submitted but never completed,
//!   pointing it at its per-job snapshot directory so the re-run
//!   resumes from the last durable iterate instead of from scratch.
//!
//! Fault injection: [`crate::fault::FaultSite::CheckpointWrite`] is
//! checked twice inside [`write_snapshot`] — before the temp file is
//! written (a clean failure: no new bytes on disk) and between the
//! write and the rename (an injected error truncates the temp file to
//! a torn prefix and leaves it behind; a worker kill dies with the
//! rename never performed). In every case the previous snapshot stays
//! intact, which is exactly the property `tests/recovery.rs` sweeps.

use crate::error::ClusterError;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

/// Magic prefix of a snapshot file.
pub const SNAPSHOT_MAGIC: &[u8; 8] = b"AAKMCK01";
/// Magic prefix of a job-journal file.
pub const JOURNAL_MAGIC: &[u8; 8] = b"AAKMJL01";
/// Snapshot file name inside a checkpoint directory (one live snapshot
/// per run; every write atomically replaces the previous one, so "the
/// latest snapshot" is simply this file).
pub const SNAPSHOT_FILE: &str = "snapshot.ck";
/// Journal file name inside a coordinator journal directory.
pub const JOURNAL_FILE: &str = "journal.wal";

/// Where and how often a run writes durable snapshots.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CheckpointPolicy {
    /// Directory holding the run's [`SNAPSHOT_FILE`] (created on the
    /// first write). A run whose directory already holds a snapshot
    /// with a matching fingerprint resumes from it.
    pub dir: PathBuf,
    /// Snapshot every `every` productive iterations (epochs for the
    /// mini-batch engine). Must be ≥ 1.
    pub every: usize,
}

impl CheckpointPolicy {
    /// Policy snapshotting into `dir` every `every` iterations.
    pub fn new(dir: impl Into<PathBuf>, every: usize) -> Self {
        Self { dir: dir.into(), every }
    }
}

/// Path of the (single, latest) snapshot inside a checkpoint directory.
pub fn snapshot_path(dir: &Path) -> PathBuf {
    dir.join(SNAPSHOT_FILE)
}

// ---------------------------------------------------------------------
// CRC-32 (IEEE 802.3, the ubiquitous zlib polynomial), table-driven and
// dependency-free. Snapshots are small (centroids + m history columns);
// the table keeps even the n-sized assignment records cheap.
// ---------------------------------------------------------------------

const CRC_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut j = 0;
        while j < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            j += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// CRC-32 over a concatenation of byte slices (streamed, no joining).
pub(crate) fn crc32_parts(parts: &[&[u8]]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for part in parts {
        for &b in *part {
            crc = CRC_TABLE[((crc ^ b as u32) & 0xFF) as usize] ^ (crc >> 8);
        }
    }
    !crc
}

// ---------------------------------------------------------------------
// Record framing: [u32 tag][u64 len][payload][u32 crc], where the CRC
// covers tag, length and payload — a bit flip anywhere in the record
// (including its header) fails verification.
// ---------------------------------------------------------------------

const TAG_END: u32 = 0xFFFF_FFFF;
const TAG_FINGERPRINT: u32 = 1;
const TAG_DRIVER: u32 = 2;
const TAG_CENTROIDS: u32 = 3;
const TAG_ANDERSON: u32 = 4;
const TAG_FULL_BATCH: u32 = 5;
const TAG_STREAM: u32 = 6;
// Journal record tags share the framing but live in their own file.
const TAG_JOB_SUBMITTED: u32 = 0x10;
const TAG_JOB_STARTED: u32 = 0x11;
const TAG_JOB_COMPLETED: u32 = 0x12;

pub(crate) fn push_record(out: &mut Vec<u8>, tag: u32, payload: &[u8]) {
    let tag_b = tag.to_le_bytes();
    let len_b = (payload.len() as u64).to_le_bytes();
    let crc = crc32_parts(&[&tag_b, &len_b, payload]);
    out.extend_from_slice(&tag_b);
    out.extend_from_slice(&len_b);
    out.extend_from_slice(payload);
    out.extend_from_slice(&crc.to_le_bytes());
}

/// One parsed `(tag, payload)` record.
pub(crate) type RawRecord<'a> = (u32, &'a [u8]);

/// Parse the record stream after the magic. `strict` (snapshots)
/// rejects any malformed byte; lenient mode (journals) stops at the
/// first malformed record and returns the valid prefix — the torn tail
/// an append-only log accumulates when the process dies mid-append.
pub(crate) fn parse_records<'a>(
    mut bytes: &'a [u8],
    strict: bool,
) -> Result<Vec<RawRecord<'a>>, String> {
    let mut records = Vec::new();
    while !bytes.is_empty() {
        if bytes.len() < 12 {
            if strict {
                return Err(format!("truncated record header ({} trailing bytes)", bytes.len()));
            }
            break;
        }
        let tag = u32::from_le_bytes(bytes[0..4].try_into().expect("4 bytes"));
        let len = u64::from_le_bytes(bytes[4..12].try_into().expect("8 bytes"));
        let Ok(len) = usize::try_from(len) else {
            if strict {
                return Err(format!("record length {len} overflows"));
            }
            break;
        };
        let total = match len.checked_add(16) {
            Some(t) if t <= bytes.len() => t,
            _ => {
                if strict {
                    return Err(format!(
                        "record (tag {tag}) declares {len} payload bytes but only {} remain",
                        bytes.len().saturating_sub(16)
                    ));
                }
                break;
            }
        };
        let payload = &bytes[12..12 + len];
        let stored = u32::from_le_bytes(bytes[12 + len..total].try_into().expect("4 bytes"));
        let computed = crc32_parts(&[&bytes[0..12], payload]);
        if stored != computed {
            if strict {
                return Err(format!(
                    "record (tag {tag}) CRC mismatch: stored {stored:#010x}, computed {computed:#010x}"
                ));
            }
            break;
        }
        records.push((tag, payload));
        bytes = &bytes[total..];
        if strict && tag == TAG_END && !bytes.is_empty() {
            return Err(format!("{} bytes after the end record", bytes.len()));
        }
    }
    Ok(records)
}

// ---------------------------------------------------------------------
// Little-endian payload encoding/decoding helpers.
// ---------------------------------------------------------------------

#[derive(Default)]
pub(crate) struct Enc {
    pub(crate) buf: Vec<u8>,
}

impl Enc {
    pub(crate) fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub(crate) fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub(crate) fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    pub(crate) fn boolean(&mut self, v: bool) {
        self.buf.push(v as u8);
    }

    pub(crate) fn f64s(&mut self, v: &[f64]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.f64(x);
        }
    }

    pub(crate) fn u32s(&mut self, v: &[u32]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.u32(x);
        }
    }

    pub(crate) fn u64s(&mut self, v: &[u64]) {
        self.u64(v.len() as u64);
        for &x in v {
            self.u64(x);
        }
    }

    pub(crate) fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.buf.extend_from_slice(s.as_bytes());
    }
}

pub(crate) struct Dec<'a> {
    buf: &'a [u8],
}

impl<'a> Dec<'a> {
    pub(crate) fn new(buf: &'a [u8]) -> Self {
        Self { buf }
    }

    pub(crate) fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.buf.len() < n {
            return Err(format!("payload truncated: wanted {n} bytes, {} left", self.buf.len()));
        }
        let (head, rest) = self.buf.split_at(n);
        self.buf = rest;
        Ok(head)
    }

    pub(crate) fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    pub(crate) fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }

    pub(crate) fn f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_bits(self.u64()?))
    }

    pub(crate) fn boolean(&mut self) -> Result<bool, String> {
        match self.take(1)?[0] {
            0 => Ok(false),
            1 => Ok(true),
            other => Err(format!("bad boolean byte {other:#04x}")),
        }
    }

    /// Length-prefixed `f64` vector; the declared length is bounded by
    /// the remaining payload before allocating, so a corrupt length
    /// cannot request an absurd allocation.
    pub(crate) fn f64s(&mut self) -> Result<Vec<f64>, String> {
        let len = self.u64()? as usize;
        if len.checked_mul(8).is_none_or(|b| b > self.buf.len()) {
            return Err(format!("f64 vector declares {len} items past the payload end"));
        }
        (0..len).map(|_| self.f64()).collect()
    }

    pub(crate) fn u32s(&mut self) -> Result<Vec<u32>, String> {
        let len = self.u64()? as usize;
        if len.checked_mul(4).is_none_or(|b| b > self.buf.len()) {
            return Err(format!("u32 vector declares {len} items past the payload end"));
        }
        (0..len).map(|_| self.u32()).collect()
    }

    /// Length-prefixed `u64` vector, with the same declared-length bound
    /// as [`Dec::f64s`] so a corrupt length cannot request an absurd
    /// allocation (used by the model registry's per-cluster counts).
    pub(crate) fn u64s(&mut self) -> Result<Vec<u64>, String> {
        let len = self.u64()? as usize;
        if len.checked_mul(8).is_none_or(|b| b > self.buf.len()) {
            return Err(format!("u64 vector declares {len} items past the payload end"));
        }
        (0..len).map(|_| self.u64()).collect()
    }

    pub(crate) fn str(&mut self) -> Result<String, String> {
        let len = self.u64()? as usize;
        if len > self.buf.len() {
            return Err(format!("string declares {len} bytes past the payload end"));
        }
        String::from_utf8(self.take(len)?.to_vec()).map_err(|e| format!("bad utf-8: {e}"))
    }

    pub(crate) fn done(&self) -> Result<(), String> {
        if self.buf.is_empty() {
            Ok(())
        } else {
            Err(format!("{} unconsumed payload bytes", self.buf.len()))
        }
    }
}

// ---------------------------------------------------------------------
// Snapshot contents.
// ---------------------------------------------------------------------

/// The fixed-point driver's loop state at a committed iteration
/// boundary — everything [`crate::accel::FixedPointDriver`] needs to
/// continue a trajectory exactly where the snapshot left it.
#[derive(Debug, Clone, PartialEq)]
pub struct DriverSnap {
    /// Productive iterations completed (epochs for the streaming step).
    pub iterations: u64,
    /// Iterations whose accelerated candidate passed the energy guard.
    pub accepted: u64,
    /// The committed iterate's energy (`e_prev` in the driver loop).
    pub energy: f64,
    /// Energy decrease of the previous iteration (`E^{t-2} − E^{t-1}`),
    /// which the dynamic-`m` controller's next adjustment consumes.
    pub decrease_prev: f64,
    /// Consecutive immediate-guard rejections toward the restart cap.
    pub rejects: u32,
    /// The dynamic-`m` controller's current window size.
    pub m: u64,
    /// Deferred guard: whether the current iterate is an unguarded
    /// accelerated proposal awaiting the next pass's measurement.
    pub outstanding: bool,
}

/// The Anderson accelerator's history: the previous `(f, g)` pair plus
/// the ΔF/ΔG difference columns **oldest-first**. Restoring replays the
/// same incremental `push` calls the original run made, so the Gram
/// matrix is rebuilt bit-identically rather than deserialized.
#[derive(Debug, Clone, PartialEq)]
pub struct AndersonSnap {
    /// The last `(f_t, g_t)` pair fed to the accelerator, if any.
    pub prev: Option<(Vec<f64>, Vec<f64>)>,
    /// `(ΔF, ΔG)` history columns, oldest first.
    pub cols: Vec<(Vec<f64>, Vec<f64>)>,
    /// Lifetime accelerated-proposal count (reporting only).
    pub accelerated_steps: u64,
}

/// Full-batch solver extras: the retained plain iterate and the
/// assignment pair the deferred guard compares. Engine bound caches are
/// deliberately *not* stored — a resumed run re-assigns once from
/// scratch, and since bounds only prune (they never change an
/// assignment), the trajectory stays bit-identical.
#[derive(Debug, Clone, PartialEq)]
pub struct FullBatchSnap {
    /// The retained plain iterate `C_AU` (reverted to on rejection).
    pub c_au: Vec<f64>,
    /// Scratch assignment buffer (the previous iteration's assignment).
    pub assign: Vec<u32>,
    /// The latest committed assignment.
    pub prev_assign: Vec<u32>,
    /// Whether the current iterate came from an accelerated proposal.
    pub candidate_was_accel: bool,
}

/// Mini-batch solver extras: the Sculley per-cluster counts and the raw
/// sampler RNG state, so a resumed run replays the exact batch sequence
/// the uninterrupted run would have drawn.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamSnap {
    /// Per-cluster Sculley update counts (learning-rate denominators).
    pub counts: Vec<f64>,
    /// Raw PCG state of the batch sampler (`Pcg32::state_parts`).
    pub rng_state: u64,
    /// Raw PCG increment of the batch sampler.
    pub rng_inc: u64,
    /// Samples behind the last checkpoint energy (MSE denominator).
    pub eval_samples: u64,
}

/// A complete solver snapshot: request fingerprint, driver state,
/// committed centroids, and the optional per-solver-shape extras.
#[derive(Debug, Clone, PartialEq)]
pub struct SolverSnapshot {
    /// Human-readable digest of the request shape (k, d, seed, engine,
    /// acceleration, sampling, ...). A resuming run must present the
    /// identical fingerprint; anything else is a stale snapshot and is
    /// rejected typed.
    pub fingerprint: String,
    /// Driver loop state at the snapshot boundary.
    pub driver: DriverSnap,
    /// Number of centroids.
    pub k: usize,
    /// Dimensionality.
    pub d: usize,
    /// Committed centroids, row-major `k × d`.
    pub centroids: Vec<f64>,
    /// Anderson history (accelerated runs only).
    pub anderson: Option<AndersonSnap>,
    /// Full-batch solver extras.
    pub full_batch: Option<FullBatchSnap>,
    /// Mini-batch solver extras.
    pub stream: Option<StreamSnap>,
}

impl SolverSnapshot {
    /// Reject this snapshot unless its fingerprint matches the resuming
    /// request's — the typed "stale snapshot" rejection.
    pub fn check_fingerprint(&self, expected: &str, dir: &Path) -> Result<(), ClusterError> {
        if self.fingerprint == expected {
            Ok(())
        } else {
            Err(ClusterError::Snapshot {
                path: snapshot_path(dir).display().to_string(),
                reason: format!(
                    "fingerprint mismatch: snapshot was written by [{}], this request is [{expected}]",
                    self.fingerprint
                ),
            })
        }
    }
}

/// Serialize a snapshot to its on-disk byte layout (exposed for the
/// corruption fuzz tests; production callers use [`write_snapshot`]).
pub fn encode_snapshot(s: &SolverSnapshot) -> Vec<u8> {
    let mut out = SNAPSHOT_MAGIC.to_vec();
    let mut e = Enc::default();
    e.str(&s.fingerprint);
    push_record(&mut out, TAG_FINGERPRINT, &e.buf);

    let mut e = Enc::default();
    e.u64(s.driver.iterations);
    e.u64(s.driver.accepted);
    e.f64(s.driver.energy);
    e.f64(s.driver.decrease_prev);
    e.u32(s.driver.rejects);
    e.u64(s.driver.m);
    e.boolean(s.driver.outstanding);
    push_record(&mut out, TAG_DRIVER, &e.buf);

    let mut e = Enc::default();
    e.u64(s.k as u64);
    e.u64(s.d as u64);
    e.f64s(&s.centroids);
    push_record(&mut out, TAG_CENTROIDS, &e.buf);

    if let Some(aa) = &s.anderson {
        let mut e = Enc::default();
        e.boolean(aa.prev.is_some());
        if let Some((f, g)) = &aa.prev {
            e.f64s(f);
            e.f64s(g);
        }
        e.u64(aa.cols.len() as u64);
        for (df, dg) in &aa.cols {
            e.f64s(df);
            e.f64s(dg);
        }
        e.u64(aa.accelerated_steps);
        push_record(&mut out, TAG_ANDERSON, &e.buf);
    }

    if let Some(fb) = &s.full_batch {
        let mut e = Enc::default();
        e.f64s(&fb.c_au);
        e.u32s(&fb.assign);
        e.u32s(&fb.prev_assign);
        e.boolean(fb.candidate_was_accel);
        push_record(&mut out, TAG_FULL_BATCH, &e.buf);
    }

    if let Some(st) = &s.stream {
        let mut e = Enc::default();
        e.f64s(&st.counts);
        e.u64(st.rng_state);
        e.u64(st.rng_inc);
        e.u64(st.eval_samples);
        push_record(&mut out, TAG_STREAM, &e.buf);
    }

    push_record(&mut out, TAG_END, &[]);
    out
}

/// Decode and validate a snapshot byte stream. Every structural defect
/// — foreign magic, truncation, CRC mismatch, shape inconsistencies,
/// missing or duplicate records, unknown tags — is a typed error.
pub fn decode_snapshot(bytes: &[u8], path: &Path) -> Result<SolverSnapshot, ClusterError> {
    let fail = |reason: String| ClusterError::Snapshot {
        path: path.display().to_string(),
        reason,
    };
    if bytes.len() < SNAPSHOT_MAGIC.len() || &bytes[..8] != SNAPSHOT_MAGIC {
        return Err(fail("not an AAKMCK01 snapshot (bad magic)".into()));
    }
    let records = parse_records(&bytes[8..], true).map_err(&fail)?;
    if records.last().map(|(t, _)| *t) != Some(TAG_END) {
        return Err(fail("missing end record (torn write)".into()));
    }

    let mut fingerprint = None;
    let mut driver = None;
    let mut shape = None;
    let mut anderson = None;
    let mut full_batch = None;
    let mut stream = None;
    for &(tag, payload) in &records[..records.len() - 1] {
        let mut d = Dec::new(payload);
        let dup = |name: &str| fail(format!("duplicate {name} record"));
        match tag {
            TAG_FINGERPRINT => {
                if fingerprint.replace(d.str().map_err(&fail)?).is_some() {
                    return Err(dup("fingerprint"));
                }
            }
            TAG_DRIVER => {
                let snap = DriverSnap {
                    iterations: d.u64().map_err(&fail)?,
                    accepted: d.u64().map_err(&fail)?,
                    energy: d.f64().map_err(&fail)?,
                    decrease_prev: d.f64().map_err(&fail)?,
                    rejects: d.u32().map_err(&fail)?,
                    m: d.u64().map_err(&fail)?,
                    outstanding: d.boolean().map_err(&fail)?,
                };
                if driver.replace(snap).is_some() {
                    return Err(dup("driver"));
                }
            }
            TAG_CENTROIDS => {
                let k = d.u64().map_err(&fail)? as usize;
                let dim = d.u64().map_err(&fail)? as usize;
                let c = d.f64s().map_err(&fail)?;
                if k.checked_mul(dim) != Some(c.len()) {
                    return Err(fail(format!(
                        "centroid record declares {k}x{dim} but holds {} values",
                        c.len()
                    )));
                }
                if shape.replace((k, dim, c)).is_some() {
                    return Err(dup("centroid"));
                }
            }
            TAG_ANDERSON => {
                let prev = if d.boolean().map_err(&fail)? {
                    Some((d.f64s().map_err(&fail)?, d.f64s().map_err(&fail)?))
                } else {
                    None
                };
                let ncols = d.u64().map_err(&fail)? as usize;
                let mut cols = Vec::new();
                for _ in 0..ncols {
                    cols.push((d.f64s().map_err(&fail)?, d.f64s().map_err(&fail)?));
                }
                let snap = AndersonSnap {
                    prev,
                    cols,
                    accelerated_steps: d.u64().map_err(&fail)?,
                };
                if anderson.replace(snap).is_some() {
                    return Err(dup("anderson"));
                }
            }
            TAG_FULL_BATCH => {
                let snap = FullBatchSnap {
                    c_au: d.f64s().map_err(&fail)?,
                    assign: d.u32s().map_err(&fail)?,
                    prev_assign: d.u32s().map_err(&fail)?,
                    candidate_was_accel: d.boolean().map_err(&fail)?,
                };
                if full_batch.replace(snap).is_some() {
                    return Err(dup("full-batch"));
                }
            }
            TAG_STREAM => {
                let snap = StreamSnap {
                    counts: d.f64s().map_err(&fail)?,
                    rng_state: d.u64().map_err(&fail)?,
                    rng_inc: d.u64().map_err(&fail)?,
                    eval_samples: d.u64().map_err(&fail)?,
                };
                if stream.replace(snap).is_some() {
                    return Err(dup("stream"));
                }
            }
            TAG_END => return Err(fail("end record before the end of the file".into())),
            other => return Err(fail(format!("unknown record tag {other} (newer format?)"))),
        }
        d.done().map_err(&fail)?;
    }

    let fingerprint = fingerprint.ok_or_else(|| fail("missing fingerprint record".into()))?;
    let driver = driver.ok_or_else(|| fail("missing driver record".into()))?;
    let (k, d, centroids) = shape.ok_or_else(|| fail("missing centroid record".into()))?;
    let dim = k * d;
    if let Some(aa) = &anderson {
        let col_ok = |v: &Vec<f64>| v.len() == dim;
        let prev_ok = aa.prev.as_ref().is_none_or(|(f, g)| col_ok(f) && col_ok(g));
        if !prev_ok || !aa.cols.iter().all(|(f, g)| col_ok(f) && col_ok(g)) {
            return Err(fail(format!("anderson history columns disagree with k*d = {dim}")));
        }
    }
    if let Some(fb) = &full_batch {
        if fb.c_au.len() != dim {
            return Err(fail(format!(
                "plain-iterate record holds {} values, expected k*d = {dim}",
                fb.c_au.len()
            )));
        }
        if fb.assign.len() != fb.prev_assign.len() {
            return Err(fail(format!(
                "assignment records disagree: {} vs {} rows",
                fb.assign.len(),
                fb.prev_assign.len()
            )));
        }
    }
    if let Some(st) = &stream {
        if st.counts.len() != k {
            return Err(fail(format!(
                "stream counts record holds {} clusters, expected k = {k}",
                st.counts.len()
            )));
        }
    }
    Ok(SolverSnapshot { fingerprint, driver, k, d, centroids, anderson, full_batch, stream })
}

/// Write a snapshot durably: serialize, write to a temp file, fsync,
/// then atomically rename over the previous snapshot. A crash (or an
/// injected [`crate::fault::FaultSite::CheckpointWrite`] fault) at any
/// point leaves either the old complete snapshot or the new complete
/// snapshot on disk — never a torn one.
pub fn write_snapshot(dir: &Path, snap: &SolverSnapshot) -> Result<PathBuf, ClusterError> {
    let sw = crate::metrics::Stopwatch::start();
    let path = snapshot_path(dir);
    let fail = |reason: String| ClusterError::Snapshot {
        path: path.display().to_string(),
        reason,
    };
    // Fault window 1: a clean write failure before any bytes land.
    crate::fault::check(crate::fault::FaultSite::CheckpointWrite)
        .map_err(|e| fail(format!("write failed: {e}")))?;
    std::fs::create_dir_all(dir).map_err(|e| fail(format!("create dir: {e}")))?;
    let bytes = encode_snapshot(snap);
    let tmp = dir.join(format!("{SNAPSHOT_FILE}.tmp"));
    {
        let mut f = std::fs::File::create(&tmp).map_err(|e| fail(format!("create temp: {e}")))?;
        f.write_all(&bytes).map_err(|e| fail(format!("write temp: {e}")))?;
        f.sync_all().map_err(|e| fail(format!("sync temp: {e}")))?;
    }
    // Fault window 2: between the write and the rename. An injected
    // error truncates the temp file to a torn prefix (what a real crash
    // mid-write leaves) and keeps the previous snapshot in place; an
    // injected kill unwinds with the rename never performed.
    if let Err(e) = crate::fault::check(crate::fault::FaultSite::CheckpointWrite) {
        let _ = std::fs::File::options()
            .write(true)
            .open(&tmp)
            .and_then(|f| f.set_len(bytes.len() as u64 / 2));
        return Err(fail(format!("write failed before rename: {e}")));
    }
    std::fs::rename(&tmp, &path).map_err(|e| fail(format!("rename: {e}")))?;
    // Best-effort directory sync so the rename itself is durable.
    if let Ok(d) = std::fs::File::open(dir) {
        let _ = d.sync_all();
    }
    if crate::telemetry::enabled() {
        let t = crate::telemetry::metrics();
        t.snapshot_writes.inc();
        t.snapshot_bytes.add(bytes.len() as u64);
        t.snapshot_write_seconds.observe(sw.seconds());
    }
    Ok(path)
}

/// Load the latest snapshot from a checkpoint directory. `Ok(None)`
/// when no snapshot exists (a fresh run); typed errors for anything
/// unreadable or corrupt.
pub fn load_snapshot(dir: &Path) -> Result<Option<SolverSnapshot>, ClusterError> {
    let path = snapshot_path(dir);
    let bytes = match std::fs::read(&path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
        Err(e) => {
            return Err(ClusterError::Snapshot {
                path: path.display().to_string(),
                reason: format!("read: {e}"),
            })
        }
    };
    decode_snapshot(&bytes, &path).map(Some)
}

/// Remove a run's snapshot (called when the run completes, so "a
/// snapshot exists" always means "this run is resumable"). Missing
/// files and removal failures are ignored — a stale snapshot is
/// rejected by its fingerprint or replaced by the next write.
pub fn remove_snapshot(dir: &Path) {
    let _ = std::fs::remove_file(snapshot_path(dir));
}

// ---------------------------------------------------------------------
// The coordinator's write-ahead job journal.
// ---------------------------------------------------------------------

/// One job-lifecycle event in the coordinator journal.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum JournalEvent {
    /// A job was admitted. `spec` is the re-submittable request
    /// description (`ClusterRequest::journal_spec`); `None` for
    /// requests that cannot be reconstructed after a restart (inline
    /// data, explicit centroid inits), which recovery skips.
    Submitted {
        /// Coordinator job id.
        job: u64,
        /// Serialized request spec, when recoverable.
        spec: Option<String>,
    },
    /// A worker picked the job up (attempt numbers count retries).
    Started {
        /// Coordinator job id.
        job: u64,
        /// 1-based attempt number.
        attempt: u32,
    },
    /// The job resolved (success, typed failure, or cancellation) —
    /// recovery has nothing left to do for it.
    Completed {
        /// Coordinator job id.
        job: u64,
    },
}

/// Path of the journal inside a journal directory.
pub fn journal_path(dir: &Path) -> PathBuf {
    dir.join(JOURNAL_FILE)
}

/// Append-only journal writer. Every append is CRC-framed and flushed,
/// so the journal never loses more than the record being written when
/// the process dies.
pub struct JournalWriter {
    path: PathBuf,
    file: std::fs::File,
}

impl JournalWriter {
    /// Open (or create) the journal in `dir`, validating the magic of
    /// an existing file before appending to it.
    pub fn open(dir: &Path) -> Result<Self, ClusterError> {
        let path = journal_path(dir);
        let fail = |reason: String| ClusterError::Snapshot {
            path: path.display().to_string(),
            reason,
        };
        std::fs::create_dir_all(dir).map_err(|e| fail(format!("create dir: {e}")))?;
        let mut file = std::fs::File::options()
            .create(true)
            .read(true)
            .append(true)
            .open(&path)
            .map_err(|e| fail(format!("open: {e}")))?;
        let len = file.metadata().map_err(|e| fail(format!("stat: {e}")))?.len();
        if len == 0 {
            file.write_all(JOURNAL_MAGIC).map_err(|e| fail(format!("write magic: {e}")))?;
            file.sync_all().map_err(|e| fail(format!("sync: {e}")))?;
        } else {
            let mut magic = [0u8; 8];
            use std::io::Seek;
            file.seek(std::io::SeekFrom::Start(0)).map_err(|e| fail(format!("seek: {e}")))?;
            let ok = file.read_exact(&mut magic).is_ok() && &magic == JOURNAL_MAGIC;
            if !ok {
                return Err(fail("not an AAKMJL01 journal (bad magic)".into()));
            }
        }
        Ok(Self { path, file })
    }

    /// Append one event durably (framed, CRC'd, flushed to disk).
    pub fn append(&mut self, ev: &JournalEvent) -> Result<(), ClusterError> {
        let fail = |reason: String| ClusterError::Snapshot {
            path: self.path.display().to_string(),
            reason,
        };
        let mut e = Enc::default();
        let tag = match ev {
            JournalEvent::Submitted { job, spec } => {
                e.u64(*job);
                e.boolean(spec.is_some());
                if let Some(s) = spec {
                    e.str(s);
                }
                TAG_JOB_SUBMITTED
            }
            JournalEvent::Started { job, attempt } => {
                e.u64(*job);
                e.u32(*attempt);
                TAG_JOB_STARTED
            }
            JournalEvent::Completed { job } => {
                e.u64(*job);
                TAG_JOB_COMPLETED
            }
        };
        let mut rec = Vec::new();
        push_record(&mut rec, tag, &e.buf);
        self.file.write_all(&rec).map_err(|err| fail(format!("append: {err}")))?;
        self.file.sync_data().map_err(|err| fail(format!("sync: {err}")))?;
        Ok(())
    }
}

/// Read every valid event from a journal. A missing file is an empty
/// journal; a torn tail (the crash-mid-append case) is dropped
/// silently; foreign magic is rejected typed.
pub fn read_journal(dir: &Path) -> Result<Vec<JournalEvent>, ClusterError> {
    let path = journal_path(dir);
    let fail = |reason: String| ClusterError::Snapshot {
        path: path.display().to_string(),
        reason,
    };
    let bytes = match std::fs::read(&path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
        Err(e) => return Err(fail(format!("read: {e}"))),
    };
    if bytes.len() < 8 || &bytes[..8] != JOURNAL_MAGIC {
        return Err(fail("not an AAKMJL01 journal (bad magic)".into()));
    }
    let records = parse_records(&bytes[8..], false).map_err(&fail)?;
    let mut events = Vec::new();
    for (tag, payload) in records {
        let mut d = Dec::new(payload);
        let ev = match tag {
            TAG_JOB_SUBMITTED => {
                let job = d.u64().map_err(&fail)?;
                let spec = if d.boolean().map_err(&fail)? {
                    Some(d.str().map_err(&fail)?)
                } else {
                    None
                };
                JournalEvent::Submitted { job, spec }
            }
            TAG_JOB_STARTED => JournalEvent::Started {
                job: d.u64().map_err(&fail)?,
                attempt: d.u32().map_err(&fail)?,
            },
            TAG_JOB_COMPLETED => JournalEvent::Completed { job: d.u64().map_err(&fail)? },
            // A valid-CRC record with an unknown tag is a newer writer;
            // recovery stops at the first record it cannot interpret.
            _ => break,
        };
        d.done().map_err(&fail)?;
        events.push(ev);
    }
    Ok(events)
}

/// A journaled job that was submitted but never completed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct IncompleteJob {
    /// The original coordinator job id.
    pub job: u64,
    /// Serialized request spec, when the job is re-submittable.
    pub spec: Option<String>,
    /// How many worker attempts the journal recorded.
    pub attempts: u32,
}

/// Fold a journal into its incomplete jobs, in submission order.
pub fn incomplete_jobs(events: &[JournalEvent]) -> Vec<IncompleteJob> {
    let mut open: Vec<IncompleteJob> = Vec::new();
    for ev in events {
        match ev {
            JournalEvent::Submitted { job, spec } => {
                open.push(IncompleteJob { job: *job, spec: spec.clone(), attempts: 0 });
            }
            JournalEvent::Started { job, attempt } => {
                if let Some(j) = open.iter_mut().find(|j| j.job == *job) {
                    j.attempts = j.attempts.max(*attempt);
                }
            }
            JournalEvent::Completed { job } => {
                open.retain(|j| j.job != *job);
            }
        }
    }
    open
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("aakm_persist_tests").join(name);
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn sample_snapshot() -> SolverSnapshot {
        SolverSnapshot {
            fingerprint: "k=2 d=3 seed=42 engine=hamerly".into(),
            driver: DriverSnap {
                iterations: 7,
                accepted: 3,
                energy: 12.5,
                decrease_prev: 0.25,
                rejects: 1,
                m: 4,
                outstanding: true,
            },
            k: 2,
            d: 3,
            centroids: vec![1.0, 2.0, 3.0, -1.0, -2.0, -3.0],
            anderson: Some(AndersonSnap {
                prev: Some((vec![0.5; 6], vec![0.25; 6])),
                cols: vec![(vec![1.0; 6], vec![2.0; 6]), (vec![3.0; 6], vec![4.0; 6])],
                accelerated_steps: 5,
            }),
            full_batch: Some(FullBatchSnap {
                c_au: vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0],
                assign: vec![0, 1, 1, 0],
                prev_assign: vec![0, 1, 0, 0],
                candidate_was_accel: true,
            }),
            stream: None,
        }
    }

    #[test]
    fn snapshot_roundtrips_bit_exact() {
        let dir = tmp("roundtrip");
        let snap = sample_snapshot();
        let path = write_snapshot(&dir, &snap).unwrap();
        assert_eq!(path, snapshot_path(&dir));
        let back = load_snapshot(&dir).unwrap().expect("snapshot exists");
        assert_eq!(back, snap);
        // NaN-safe energies roundtrip through bits too.
        let mut with_inf = snap.clone();
        with_inf.driver.energy = f64::INFINITY;
        with_inf.driver.decrease_prev = f64::INFINITY;
        write_snapshot(&dir, &with_inf).unwrap();
        let back = load_snapshot(&dir).unwrap().unwrap();
        assert_eq!(back.driver.energy, f64::INFINITY);
    }

    #[test]
    fn missing_snapshot_is_none_not_error() {
        let dir = tmp("missing");
        assert_eq!(load_snapshot(&dir).unwrap(), None);
        remove_snapshot(&dir); // no-op on nothing
    }

    #[test]
    fn writes_replace_atomically_and_fingerprint_gates_resume() {
        let dir = tmp("replace");
        let snap = sample_snapshot();
        write_snapshot(&dir, &snap).unwrap();
        let mut newer = snap.clone();
        newer.driver.iterations = 99;
        write_snapshot(&dir, &newer).unwrap();
        let back = load_snapshot(&dir).unwrap().unwrap();
        assert_eq!(back.driver.iterations, 99);
        assert!(back.check_fingerprint("k=2 d=3 seed=42 engine=hamerly", &dir).is_ok());
        let err = back.check_fingerprint("k=9 d=3 seed=42 engine=hamerly", &dir).unwrap_err();
        assert!(matches!(err, ClusterError::Snapshot { .. }), "{err}");
        assert!(err.to_string().contains("fingerprint mismatch"), "{err}");
    }

    #[test]
    fn every_bit_flip_and_truncation_is_rejected_typed() {
        let snap = sample_snapshot();
        let bytes = encode_snapshot(&snap);
        let path = Path::new("fuzz.ck");
        assert!(decode_snapshot(&bytes, path).is_ok());
        // Bit flips across the whole file (every byte, one bit each).
        for i in 0..bytes.len() {
            let mut mutated = bytes.clone();
            mutated[i] ^= 1 << (i % 8);
            match decode_snapshot(&mutated, path) {
                Err(ClusterError::Snapshot { .. }) => {}
                Err(other) => panic!("byte {i}: wrong error type {other}"),
                Ok(_) => panic!("byte {i}: bit flip accepted silently"),
            }
        }
        // Truncations at every prefix length.
        for cut in 0..bytes.len() {
            match decode_snapshot(&bytes[..cut], path) {
                Err(ClusterError::Snapshot { .. }) => {}
                Err(other) => panic!("cut {cut}: wrong error type {other}"),
                Ok(_) => panic!("cut {cut}: truncation accepted silently"),
            }
        }
    }

    #[test]
    fn injected_faults_never_corrupt_the_previous_snapshot() {
        use crate::fault::{FaultKind, FaultPlan, FaultSite};
        let dir = tmp("faulted");
        let snap = sample_snapshot();
        write_snapshot(&dir, &snap).unwrap();

        // Window 1: clean failure before the write.
        {
            let _guard = FaultPlan::new()
                .fail_next(FaultSite::CheckpointWrite, FaultKind::Error, 1)
                .install_for_current_thread();
            let mut newer = snap.clone();
            newer.driver.iterations = 100;
            let err = write_snapshot(&dir, &newer).unwrap_err();
            assert!(matches!(err, ClusterError::Snapshot { .. }), "{err}");
        }
        assert_eq!(load_snapshot(&dir).unwrap().unwrap().driver.iterations, 7);

        // Window 2: torn write between the temp write and the rename.
        {
            let _guard = FaultPlan::new()
                .fail_after(FaultSite::CheckpointWrite, FaultKind::Error, 1, 1)
                .install_for_current_thread();
            let mut newer = snap.clone();
            newer.driver.iterations = 101;
            let err = write_snapshot(&dir, &newer).unwrap_err();
            assert!(err.to_string().contains("before rename"), "{err}");
        }
        // The torn temp file exists, but the live snapshot is intact.
        assert!(dir.join(format!("{SNAPSHOT_FILE}.tmp")).exists());
        assert_eq!(load_snapshot(&dir).unwrap().unwrap().driver.iterations, 7);

        // And a clean retry replaces it wholesale.
        let mut newer = snap.clone();
        newer.driver.iterations = 102;
        write_snapshot(&dir, &newer).unwrap();
        assert_eq!(load_snapshot(&dir).unwrap().unwrap().driver.iterations, 102);
    }

    #[test]
    fn journal_roundtrips_and_folds_incomplete_jobs() {
        let dir = tmp("journal");
        let mut w = JournalWriter::open(&dir).unwrap();
        w.append(&JournalEvent::Submitted { job: 1, spec: Some("k=3".into()) }).unwrap();
        w.append(&JournalEvent::Submitted { job: 2, spec: None }).unwrap();
        w.append(&JournalEvent::Started { job: 1, attempt: 1 }).unwrap();
        w.append(&JournalEvent::Completed { job: 1 }).unwrap();
        w.append(&JournalEvent::Started { job: 2, attempt: 1 }).unwrap();
        w.append(&JournalEvent::Started { job: 2, attempt: 2 }).unwrap();
        drop(w);
        // Reopen and keep appending (restart-append path).
        let mut w = JournalWriter::open(&dir).unwrap();
        w.append(&JournalEvent::Submitted { job: 3, spec: Some("k=4".into()) }).unwrap();
        drop(w);

        let events = read_journal(&dir).unwrap();
        assert_eq!(events.len(), 7);
        let open = incomplete_jobs(&events);
        assert_eq!(open.len(), 2);
        assert_eq!(open[0], IncompleteJob { job: 2, spec: None, attempts: 2 });
        assert_eq!(open[1], IncompleteJob { job: 3, spec: Some("k=4".into()), attempts: 0 });
    }

    #[test]
    fn journal_tolerates_a_torn_tail_but_rejects_bad_magic() {
        let dir = tmp("torn");
        let mut w = JournalWriter::open(&dir).unwrap();
        w.append(&JournalEvent::Submitted { job: 1, spec: None }).unwrap();
        w.append(&JournalEvent::Completed { job: 1 }).unwrap();
        drop(w);
        // Tear the last record mid-way: the valid prefix still reads.
        let path = journal_path(&dir);
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 5]).unwrap();
        let events = read_journal(&dir).unwrap();
        assert_eq!(events, vec![JournalEvent::Submitted { job: 1, spec: None }]);
        assert_eq!(incomplete_jobs(&events).len(), 1);
        // Foreign magic is not a journal.
        std::fs::write(&path, b"NOTAMAGICFILE").unwrap();
        assert!(read_journal(&dir).is_err());
        // An empty dir is an empty journal.
        let empty = tmp("torn_empty");
        assert_eq!(read_journal(&empty).unwrap(), Vec::new());
    }
}
