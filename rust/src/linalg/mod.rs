//! Dense linear-algebra substrate.
//!
//! The Anderson-acceleration step (paper Eq. 7) is a tiny least-squares
//! problem — `m ≤ 30` unknowns over `(K·d)`-dimensional residual columns —
//! so no BLAS is needed: we implement the vector kernels, an SPD Cholesky
//! solve, a Householder-QR least squares (used for cross-validation of the
//! normal-equations path in tests), and the regularized normal-equation
//! solver the solver's hot loop uses (same scheme as Peng et al. 2018).
//!
//! The assignment hot path lives in [`kernel`]: blocked, norm-decomposed,
//! precision-generic distance kernels (f64 / f32 sample storage, explicit
//! AVX2+FMA lanes with a runtime-dispatched scalar fallback) with a fused
//! (best, second-best) argmin that all four CPU engines run on.

mod dense;
pub mod kernel;
mod lstsq;

pub use dense::{cholesky_solve_in_place, householder_lstsq, Mat};
pub use kernel::{Best2, DistanceKernel, Precision, Scalar, SimdLevel};
pub use lstsq::{solve_anderson_weights, AndersonLsWorkspace};

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // 4-lane unrolling: the compiler auto-vectorizes this reliably.
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for i in 0..chunks {
        let j = i * 4;
        s0 += a[j] * b[j];
        s1 += a[j + 1] * b[j + 1];
        s2 += a[j + 2] * b[j + 2];
        s3 += a[j + 3] * b[j + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        s += a[i] * b[i];
    }
    s
}

/// Squared Euclidean norm.
#[inline]
pub fn norm_sq(a: &[f64]) -> f64 {
    dot(a, a)
}

/// Squared Euclidean distance between two equal-length slices.
#[inline]
pub fn dist_sq(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for i in 0..a.len() {
        let diff = a[i] - b[i];
        s += diff * diff;
    }
    s
}

/// `y ← y + alpha * x`.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

/// Elementwise `out = a - b`.
#[inline]
pub fn sub(a: &[f64], b: &[f64], out: &mut [f64]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    for i in 0..a.len() {
        out[i] = a[i] - b[i];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f64> = (0..37).map(|i| i as f64 * 0.5).collect();
        let b: Vec<f64> = (0..37).map(|i| (i as f64).sin()).collect();
        let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-9);
    }

    #[test]
    fn dist_sq_basic() {
        assert_eq!(dist_sq(&[0.0, 0.0], &[3.0, 4.0]), 25.0);
        assert_eq!(dist_sq(&[1.0], &[1.0]), 0.0);
    }

    #[test]
    fn axpy_basic() {
        let x = [1.0, 2.0, 3.0];
        let mut y = [10.0, 10.0, 10.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 14.0, 16.0]);
    }

    #[test]
    fn sub_basic() {
        let mut out = [0.0; 3];
        sub(&[5.0, 6.0, 7.0], &[1.0, 2.0, 3.0], &mut out);
        assert_eq!(out, [4.0, 4.0, 4.0]);
    }

    #[test]
    fn norm_sq_matches_dot() {
        let a: Vec<f64> = (0..13).map(|i| i as f64 - 6.0).collect();
        assert!((norm_sq(&a) - dot(&a, &a)).abs() < 1e-12);
    }
}
