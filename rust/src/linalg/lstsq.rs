//! The Anderson-acceleration least-squares subproblem (paper Eq. 7–8).
//!
//! Given the residual history `F^t, F^{t-1}, …` of the fixed-point map, each
//! iteration solves
//!
//! ```text
//! θ* = argmin ‖ F^t − Σ_{j=1..m} θ_j (F^{t-j+1} − F^{t-j}) ‖²
//! ```
//!
//! and extrapolates `C^{t+1} = G^t − Σ_j θ*_j (G^{t-j+1} − G^{t-j})`.
//! (Algorithm 1 line 19 of the paper; the `+` in its Eq. 8 is a sign typo —
//! the Walker–Ni form and the paper's own pseudocode both subtract.)
//!
//! The normal matrix `ΔFᵀΔF` is maintained **incrementally**: pushing a new
//! column costs `m` inner products of length `dim` (exactly the per-iteration
//! overhead the paper quotes), not a full `m²` Gram rebuild. The `m×m` system
//! is solved by Cholesky with escalating Tikhonov regularization, falling
//! back to Householder QR on the raw columns if the normal equations stay
//! indefinite (Peng et al. 2018 use the same regularized scheme).

use super::dense::{cholesky_solve_in_place, householder_lstsq, Mat};
use super::dot;

/// Relative Tikhonov regularization added to the normal matrix diagonal.
const BASE_REG: f64 = 1e-10;
/// Escalation factor when Cholesky fails.
const REG_ESCALATION: f64 = 1e4;
/// Give up after this many escalations and use QR instead.
const MAX_REG_ROUNDS: usize = 3;
/// Reject a solution whose coefficients exceed this magnitude: a nearly
/// rank-deficient history (duplicated iterates, stalled map) produces
/// exploding mixing weights that extrapolate garbage even when every
/// entry is technically finite. The solve then retries with the oldest
/// columns dropped (see [`AndersonLsWorkspace::solve_into`]).
const THETA_MAX: f64 = 1e8;

/// Reusable workspace holding the ΔF/ΔG column history and the cached Gram
/// matrix. Columns are indexed by recency: index 0 is `F^t − F^{t-1}`.
#[derive(Debug, Clone)]
pub struct AndersonLsWorkspace {
    max_m: usize,
    dim: usize,
    /// ΔF columns, newest first. Length ≤ max_m.
    delta_f: std::collections::VecDeque<Vec<f64>>,
    /// ΔG columns, newest first, aligned with `delta_f`.
    delta_g: std::collections::VecDeque<Vec<f64>>,
    /// Gram matrix of `delta_f` with the same recency indexing, row-major
    /// `max_m × max_m` (only the top-left `len×len` block is valid).
    gram: Vec<f64>,
    /// Scratch for the regularized normal matrix.
    scratch_a: Vec<f64>,
    /// Scratch for the RHS.
    scratch_b: Vec<f64>,
    /// Scratch for the Cholesky solution (the RHS is preserved across
    /// regularization retries).
    scratch_x: Vec<f64>,
}

impl AndersonLsWorkspace {
    /// Workspace for up to `max_m` history columns of dimension `dim`.
    pub fn new(max_m: usize, dim: usize) -> Self {
        assert!(max_m > 0, "max_m must be positive");
        Self {
            max_m,
            dim,
            delta_f: std::collections::VecDeque::with_capacity(max_m + 1),
            delta_g: std::collections::VecDeque::with_capacity(max_m + 1),
            gram: vec![0.0; max_m * max_m],
            scratch_a: vec![0.0; max_m * max_m],
            scratch_b: vec![0.0; max_m],
            scratch_x: vec![0.0; max_m],
        }
    }

    /// Number of stored history columns.
    pub fn len(&self) -> usize {
        self.delta_f.len()
    }

    /// True when no history is stored.
    pub fn is_empty(&self) -> bool {
        self.delta_f.is_empty()
    }

    /// Residual dimension this workspace was sized for.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Drop all history (used when the solver restarts after a rejection
    /// cascade or a dataset switch).
    pub fn clear(&mut self) {
        self.delta_f.clear();
        self.delta_g.clear();
    }

    /// [`AndersonLsWorkspace::clear`], but hands the evicted column buffers
    /// to the caller for recycling — clearing between same-shape runs then
    /// costs no allocator traffic (the warm-workspace contract of
    /// [`crate::kmeans::Workspace`]).
    pub fn clear_into(&mut self, free: &mut Vec<Vec<f64>>) {
        free.extend(self.delta_f.drain(..));
        free.extend(self.delta_g.drain(..));
    }

    /// The stored `(ΔF, ΔG)` column pairs **oldest first** — the order a
    /// checkpoint restore must re-[`push`](AndersonLsWorkspace::push)
    /// them so the incremental Gram cache is rebuilt bit-identically to
    /// the uninterrupted run's.
    pub fn history_oldest_first(&self) -> impl Iterator<Item = (&[f64], &[f64])> + '_ {
        self.delta_f
            .iter()
            .rev()
            .zip(self.delta_g.iter().rev())
            .map(|(f, g)| (f.as_slice(), g.as_slice()))
    }

    /// Push the newest difference columns `ΔF = f_new − f_old`,
    /// `ΔG = g_new − g_old`. Updates the Gram cache with `len` inner
    /// products (the paper's stated per-iteration cost). When the history
    /// is at capacity the evicted column pair is returned so callers can
    /// recycle the buffers (the solver's zero-alloc steady state).
    pub fn push(
        &mut self,
        delta_f: Vec<f64>,
        delta_g: Vec<f64>,
    ) -> Option<(Vec<f64>, Vec<f64>)> {
        assert_eq!(delta_f.len(), self.dim);
        assert_eq!(delta_g.len(), self.dim);
        // Shift the valid Gram block down-right by one (newest slot is 0,0).
        let old_len = self.delta_f.len().min(self.max_m - 1);
        for i in (0..old_len).rev() {
            for j in (0..old_len).rev() {
                self.gram[(i + 1) * self.max_m + (j + 1)] = self.gram[i * self.max_m + j];
            }
        }
        let evicted = if self.delta_f.len() == self.max_m {
            let ef = self.delta_f.pop_back().expect("len == max_m > 0");
            let eg = self.delta_g.pop_back().expect("aligned with delta_f");
            Some((ef, eg))
        } else {
            None
        };
        self.delta_f.push_front(delta_f);
        self.delta_g.push_front(delta_g);
        // New inner products for row/column 0.
        let newest = &self.delta_f[0];
        for j in 0..self.delta_f.len() {
            let v = dot(newest, &self.delta_f[j]);
            self.gram[j] = v; // row 0
            self.gram[j * self.max_m] = v; // column 0
        }
        evicted
    }

    /// Solve Eq. (7) for the `m_use` most recent columns against residual
    /// `f_t`, returning `θ*`. `None` when there is no usable history.
    pub fn solve(&mut self, f_t: &[f64], m_use: usize) -> Option<Vec<f64>> {
        let mut theta = Vec::new();
        self.solve_into(f_t, m_use, &mut theta).then_some(theta)
    }

    /// Allocation-free variant of [`AndersonLsWorkspace::solve`]: writes
    /// `θ*` into `theta_out` (cleared first) and returns whether a finite,
    /// bounded solution was found. The Cholesky path reuses internal
    /// scratch; only the rare ill-conditioned QR fall-back allocates.
    ///
    /// Rank-deficiency guard: when the history is ill-conditioned enough
    /// that the weights come out non-finite or larger than [`THETA_MAX`]
    /// in magnitude (duplicated iterates make ΔF columns collinear), the
    /// solve retries with the window shrunk by one — dropping the oldest
    /// columns, which are the stalest directions — until a usable
    /// solution appears or the history is exhausted. The caller then
    /// falls through to the plain iterate instead of extrapolating NaNs.
    pub fn solve_into(&mut self, f_t: &[f64], m_use: usize, theta_out: &mut Vec<f64>) -> bool {
        assert_eq!(f_t.len(), self.dim);
        theta_out.clear();
        let mut m = m_use.min(self.delta_f.len());
        while m > 0 {
            if self.solve_window(f_t, m, theta_out) {
                return true;
            }
            m -= 1;
        }
        false
    }

    /// One solve attempt over exactly the `m` most recent columns.
    fn solve_window(&mut self, f_t: &[f64], m: usize, theta_out: &mut Vec<f64>) -> bool {
        let usable = |v: &f64| v.is_finite() && v.abs() <= THETA_MAX;
        // RHS: b_j = <ΔF_j, F^t>.
        for j in 0..m {
            self.scratch_b[j] = dot(&self.delta_f[j], f_t);
        }
        // Mean diagonal magnitude sets the regularization scale.
        let mut trace = 0.0;
        for i in 0..m {
            trace += self.gram[i * self.max_m + i];
        }
        let scale = (trace / m as f64).max(f64::MIN_POSITIVE);

        let mut reg = BASE_REG;
        for _round in 0..MAX_REG_ROUNDS {
            for i in 0..m {
                for j in 0..m {
                    self.scratch_a[i * m + j] = self.gram[i * self.max_m + j];
                }
                self.scratch_a[i * m + i] += reg * scale;
            }
            let (rhs, sol) = (&self.scratch_b[..m], &mut self.scratch_x[..m]);
            sol.copy_from_slice(rhs);
            if cholesky_solve_in_place(&mut self.scratch_a[..m * m], sol, m)
                && sol.iter().all(usable)
            {
                theta_out.extend_from_slice(sol);
                return true;
            }
            reg *= REG_ESCALATION;
        }
        // Last resort: QR on the explicit (dim × m) column matrix.
        let mut cols = vec![0.0; self.dim * m];
        for (j, col) in self.delta_f.iter().take(m).enumerate() {
            for i in 0..self.dim {
                cols[i * m + j] = col[i];
            }
        }
        let a = Mat::from_rows(self.dim, m, &cols);
        let theta = householder_lstsq(&a, f_t);
        if theta.iter().all(usable) {
            theta_out.extend_from_slice(&theta);
            true
        } else {
            false
        }
    }

    /// Apply the extrapolation of Algorithm 1 line 19:
    /// `out = g_t − Σ_j θ_j ΔG_j`.
    pub fn accelerate(&self, g_t: &[f64], theta: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.dim];
        self.accelerate_into(g_t, theta, &mut out);
        out
    }

    /// Allocation-free variant of [`AndersonLsWorkspace::accelerate`].
    pub fn accelerate_into(&self, g_t: &[f64], theta: &[f64], out: &mut [f64]) {
        assert_eq!(g_t.len(), self.dim);
        assert_eq!(out.len(), self.dim);
        assert!(theta.len() <= self.delta_g.len());
        out.copy_from_slice(g_t);
        for (j, &th) in theta.iter().enumerate() {
            super::axpy(-th, &self.delta_g[j], out);
        }
    }
}

/// One-shot convenience wrapper: build a workspace from explicit histories
/// and solve. Used by tests and by callers that do not keep a workspace.
///
/// `f_hist` / `g_hist` are newest-first sequences `[F^t, F^{t-1}, …]`.
pub fn solve_anderson_weights(
    f_hist: &[Vec<f64>],
    g_hist: &[Vec<f64>],
    m_use: usize,
) -> Option<(Vec<f64>, Vec<f64>)> {
    if f_hist.len() < 2 {
        return None;
    }
    let dim = f_hist[0].len();
    let m = m_use.min(f_hist.len() - 1);
    let mut ws = AndersonLsWorkspace::new(m.max(1), dim);
    // Push oldest differences first so index 0 ends up newest.
    for j in (0..m).rev() {
        let mut df = vec![0.0; dim];
        let mut dg = vec![0.0; dim];
        super::sub(&f_hist[j], &f_hist[j + 1], &mut df);
        super::sub(&g_hist[j], &g_hist[j + 1], &mut dg);
        let _ = ws.push(df, dg);
    }
    let theta = ws.solve(&f_hist[0], m)?;
    let accel = ws.accelerate(&g_hist[0], &theta);
    Some((theta, accel))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Brute-force reference: materialize ΔF and solve with QR.
    fn reference_theta(f_hist: &[Vec<f64>], m: usize) -> Vec<f64> {
        let dim = f_hist[0].len();
        let mut cols = vec![0.0; dim * m];
        for j in 0..m {
            for i in 0..dim {
                cols[i * m + j] = f_hist[j][i] - f_hist[j + 1][i];
            }
        }
        let a = Mat::from_rows(dim, m, &cols);
        householder_lstsq(&a, &f_hist[0])
    }

    fn fake_history(dim: usize, steps: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<Vec<f64>>) {
        use crate::rng::{Pcg32, Rng};
        let mut rng = Pcg32::seed_from_u64(seed);
        let f: Vec<Vec<f64>> =
            (0..steps).map(|_| (0..dim).map(|_| rng.next_gaussian()).collect()).collect();
        let g: Vec<Vec<f64>> =
            (0..steps).map(|_| (0..dim).map(|_| rng.next_gaussian()).collect()).collect();
        (f, g)
    }

    #[test]
    fn workspace_matches_qr_reference() {
        let (f, g) = fake_history(40, 6, 21);
        for m in 1..=5 {
            let (theta, _) = solve_anderson_weights(&f, &g, m).unwrap();
            let reference = reference_theta(&f, m);
            for j in 0..m {
                assert!(
                    (theta[j] - reference[j]).abs() < 1e-6,
                    "m={m} j={j}: {} vs {}",
                    theta[j],
                    reference[j]
                );
            }
        }
    }

    #[test]
    fn incremental_gram_equals_fresh_gram() {
        let (f, g) = fake_history(25, 8, 22);
        let dim = 25;
        let mut ws = AndersonLsWorkspace::new(4, dim);
        for t in (0..7).rev() {
            let mut df = vec![0.0; dim];
            let mut dg = vec![0.0; dim];
            crate::linalg::sub(&f[t], &f[t + 1], &mut df);
            crate::linalg::sub(&g[t], &g[t + 1], &mut dg);
            let _ = ws.push(df, dg);
        }
        // After 7 pushes into capacity 4, columns are ΔF_0..ΔF_3.
        assert_eq!(ws.len(), 4);
        for i in 0..4 {
            for j in 0..4 {
                let expect = dot(&ws.delta_f[i], &ws.delta_f[j]);
                let got = ws.gram[i * ws.max_m + j];
                assert!((expect - got).abs() < 1e-9, "gram[{i}][{j}]");
            }
        }
    }

    #[test]
    fn acceleration_is_exact_for_linear_map() {
        // For a linear fixed-point map G(x) = A x + b with fixed point x*,
        // AA with m = dim recovers x* in one extrapolation from generic
        // iterates (the quasi-Newton property on linear problems).
        let a_diag = [0.5, -0.25, 0.8];
        let b = [1.0, 2.0, -1.0];
        let x_star: Vec<f64> = (0..3).map(|i| b[i] / (1.0 - a_diag[i])).collect();
        let g = |x: &[f64]| -> Vec<f64> {
            (0..3).map(|i| a_diag[i] * x[i] + b[i]).collect()
        };
        // Build 4 iterates (newest first at the end).
        let mut xs = vec![vec![0.0, 0.0, 0.0]];
        for t in 0..3 {
            let next = g(&xs[t]);
            xs.push(next);
        }
        // Histories newest-first: F^t = G(x^t) − x^t, G^t = G(x^t).
        let mut f_hist = Vec::new();
        let mut g_hist = Vec::new();
        for x in xs.iter().rev() {
            let gx = g(x);
            f_hist.push((0..3).map(|i| gx[i] - x[i]).collect());
            g_hist.push(gx);
        }
        let (_, accel) = solve_anderson_weights(&f_hist, &g_hist, 3).unwrap();
        for i in 0..3 {
            // Tolerance is bounded below by the Tikhonov regularization the
            // production solver always applies (BASE_REG ≈ 1e-10 relative).
            assert!(
                (accel[i] - x_star[i]).abs() < 1e-6,
                "accel[{i}]={} vs x*={}",
                accel[i],
                x_star[i]
            );
        }
    }

    #[test]
    fn solve_handles_duplicate_columns() {
        // Identical ΔF columns make the Gram singular; regularization (or
        // the QR fall-back) must still return finite weights.
        let dim = 10;
        let col: Vec<f64> = (0..dim).map(|i| i as f64).collect();
        let mut ws = AndersonLsWorkspace::new(3, dim);
        for _ in 0..3 {
            let _ = ws.push(col.clone(), col.clone());
        }
        let f_t: Vec<f64> = (0..dim).map(|i| (i as f64).cos()).collect();
        let theta = ws.solve(&f_t, 3).expect("should solve with regularization");
        assert!(theta.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn degenerate_duplicated_iterates_never_explode() {
        // A stalled map repeats its iterate: ΔF columns are tiny exact
        // duplicates while the residual stays O(1). The unregularizable
        // normal equations then produce coefficients ~1/‖ΔF‖ ≈ 1e9 —
        // finite, but garbage to extrapolate with. The bounded-θ guard
        // must refuse (pass-through), not hand back exploding weights.
        let dim = 6;
        let base: Vec<f64> = (0..dim).map(|i| (i as f64 * 0.7).sin() + 1.5).collect();
        let tiny: Vec<f64> = base.iter().map(|v| v * 1e-9).collect();
        let mut ws = AndersonLsWorkspace::new(3, dim);
        for _ in 0..3 {
            let _ = ws.push(tiny.clone(), tiny.clone());
        }
        assert!(
            ws.solve(&base, 3).is_none(),
            "degenerate history must be refused, not extrapolated"
        );
        // End to end: the accelerator passes the plain iterate through.
        let mut acc = crate::anderson::AndersonAccelerator::new(3, dim);
        let g1: Vec<f64> = base.clone();
        acc.propose(&g1, &base, 3);
        // Second call pushes a near-zero ΔF column (duplicated iterate).
        let f2: Vec<f64> = base.iter().map(|v| v + 1e-12).collect();
        let out = acc.propose(&g1, &f2, 3);
        assert!(out.iter().all(|v| v.is_finite()), "proposal must stay finite");
    }

    #[test]
    fn non_finite_oldest_column_is_dropped() {
        // A NaN-poisoned oldest column defeats Cholesky and QR at m = 2;
        // the window-shrinking retry must fall back to the healthy newest
        // column and match the single-column reference solve.
        let dim = 5;
        let healthy: Vec<f64> = (0..dim).map(|i| 1.0 + i as f64).collect();
        let poisoned = vec![f64::NAN; dim];
        let mut ws = AndersonLsWorkspace::new(2, dim);
        let _ = ws.push(poisoned.clone(), poisoned);
        let _ = ws.push(healthy.clone(), healthy.clone());
        let f_t: Vec<f64> = (0..dim).map(|i| (i as f64).cos()).collect();
        let theta = ws.solve(&f_t, 2).expect("healthy newest column should solve");
        assert_eq!(theta.len(), 1, "the poisoned oldest column must be dropped");
        assert!(theta[0].is_finite() && theta[0].abs() <= THETA_MAX);

        let mut reference = AndersonLsWorkspace::new(1, dim);
        let _ = reference.push(healthy.clone(), healthy);
        let expect = reference.solve(&f_t, 1).unwrap();
        assert!((theta[0] - expect[0]).abs() < 1e-12);
    }

    #[test]
    fn history_export_is_oldest_first() {
        let dim = 3;
        let mut ws = AndersonLsWorkspace::new(2, dim);
        for v in 1..=3 {
            let _ = ws.push(vec![v as f64; dim], vec![-(v as f64); dim]);
        }
        let cols: Vec<(Vec<f64>, Vec<f64>)> =
            ws.history_oldest_first().map(|(f, g)| (f.to_vec(), g.to_vec())).collect();
        assert_eq!(cols.len(), 2);
        assert_eq!(cols[0].0, vec![2.0; dim], "first exported column is the oldest kept");
        assert_eq!(cols[1].0, vec![3.0; dim]);
        assert_eq!(cols[1].1, vec![-3.0; dim]);
    }

    #[test]
    fn empty_history_returns_none() {
        let mut ws = AndersonLsWorkspace::new(5, 8);
        let f = vec![1.0; 8];
        assert!(ws.solve(&f, 5).is_none());
        assert!(solve_anderson_weights(&[f.clone()], &[f], 3).is_none());
    }

    #[test]
    fn ring_buffer_evicts_oldest() {
        let dim = 4;
        let mut ws = AndersonLsWorkspace::new(2, dim);
        for v in 1..=5 {
            let _ = ws.push(vec![v as f64; dim], vec![v as f64; dim]);
        }
        assert_eq!(ws.len(), 2);
        assert_eq!(ws.delta_f[0], vec![5.0; dim]);
        assert_eq!(ws.delta_f[1], vec![4.0; dim]);
    }
}
