//! Blocked, norm-decomposed distance kernels — the compute core every
//! assignment engine runs on.
//!
//! # Decomposition
//!
//! The squared Euclidean distance is evaluated as
//!
//! ```text
//! ‖x − c‖² = ‖x‖² − 2·x·c + ‖c‖²
//! ```
//!
//! with `‖x‖²` cached once per dataset (samples never move during a run)
//! and `‖c‖²` refreshed once per centroid motion (i.e. per [`DistanceKernel::prepare`]
//! call). That turns the inner loop from 3 flops/element (subtract, square,
//! add) into a pure 2 flops/element dot product, which the register-blocked
//! micro-kernel below evaluates for four centroids at a time so each sample
//! element is loaded once per block instead of once per centroid.
//!
//! # Blocking
//!
//! [`DistanceKernel::argmin2_range`] sweeps cache-sized *sample tiles* ×
//! *centroid blocks*: the centroid block (sized to stay resident in L1) is
//! reused across every sample of the tile, and within a block the
//! [`dot_x4`] micro-kernel keeps four independent accumulator chains alive
//! so the auto-vectorizer can emit wide FMA lanes. The sweep is *fused*
//! with the argmin: it returns both the best and second-best distance per
//! sample in one pass, which is exactly what bound-based engines (Hamerly,
//! Elkan, Yinyang) need to refresh their upper *and* lower bounds from a
//! single sweep.
//!
//! # Accuracy tradeoff
//!
//! The norm-decomposed form loses bits to cancellation when `‖x‖² + ‖c‖²`
//! is much larger than the true distance (a point sitting almost on a
//! centroid): the absolute error is `O(ε · (‖x‖² + ‖c‖²))` with
//! `ε ≈ 2.2e−16`, versus `O(ε · ‖x − c‖²)` for the subtract-square form.
//! Results are clamped at zero (the decomposition can go slightly
//! negative), and downstream comparisons must use *distance* equality (the
//! crate-wide `1e-9` tolerance), never assignment-id equality — ties can
//! legitimately resolve either way. For data with coordinates up to ~1e4
//! the error stays below ~1e-12, far inside the tolerance; callers with
//! extreme dynamic range should pre-center their data (see ROADMAP).

use crate::data::DataMatrix;
use crate::par::{SyncSliceMut, ThreadPool};
use std::ops::Range;

/// Samples per tile of the blocked sweep. A tile's running best/second
/// state lives in stack arrays of this size.
const SAMPLE_TILE: usize = 32;
/// Centroids per micro-kernel pass (the register-blocking width).
const CENTROID_BLOCK: usize = 4;
/// Target bytes of centroid data kept hot per block sweep (~half of a
/// typical 32 KiB L1d).
const CENTROID_TILE_BYTES: usize = 16 * 1024;

/// Result of the fused argmin sweep for one sample: squared distances to
/// the best and second-best centroid. `second_d` is `+∞` when `K == 1`.
#[derive(Debug, Clone, Copy)]
pub struct Best2 {
    /// Index of the nearest centroid.
    pub best: u32,
    /// Squared distance to the nearest centroid (clamped ≥ 0).
    pub best_d: f64,
    /// Squared distance to the second-nearest centroid (clamped ≥ 0).
    pub second_d: f64,
}

/// Per-engine cache of the norm decomposition: sample norms are computed
/// once per dataset (keyed on the buffer pointer + shape, dropped by
/// [`DistanceKernel::invalidate`]), centroid norms once per
/// [`DistanceKernel::prepare`] call — i.e. once per centroid motion.
#[derive(Debug, Clone, Default)]
pub struct DistanceKernel {
    /// `(buffer ptr, n, d)` of the sample matrix the cached norms belong
    /// to. Engines call [`DistanceKernel::invalidate`] on reset so a new
    /// run never trusts a stale pointer match.
    x_key: Option<(usize, usize, usize)>,
    x_norms: Vec<f64>,
    c_norms: Vec<f64>,
}

impl DistanceKernel {
    /// Fresh kernel with no cached state.
    pub fn new() -> Self {
        Self::default()
    }

    /// Refresh the cached norms for `(x, c)`. Sample norms are recomputed
    /// only when `x` changed identity or shape (one parallel O(N·d) pass);
    /// centroid norms are recomputed every call (O(K·d), negligible).
    pub fn prepare(&mut self, x: &DataMatrix, c: &DataMatrix, pool: &ThreadPool) {
        let key = (x.as_slice().as_ptr() as usize, x.n(), x.d());
        if self.x_key != Some(key) {
            self.x_norms.clear();
            self.x_norms.resize(x.n(), 0.0);
            let norms = SyncSliceMut::new(&mut self.x_norms);
            pool.parallel_for(x.n(), 512, |range| {
                for i in range {
                    *norms.at(i) = super::norm_sq(x.row(i));
                }
            });
            self.x_key = Some(key);
        }
        self.c_norms.clear();
        self.c_norms.resize(c.n(), 0.0);
        for j in 0..c.n() {
            self.c_norms[j] = super::norm_sq(c.row(j));
        }
    }

    /// Drop the cached sample norms (engines call this from `reset`).
    pub fn invalidate(&mut self) {
        self.x_key = None;
    }

    /// Centroid rows per cache tile: as many as fit the L1 budget, rounded
    /// to the register-block width, never below one block.
    fn centroid_tile(&self, d: usize) -> usize {
        let rows = CENTROID_TILE_BYTES / (8 * d.max(1));
        (rows.max(CENTROID_BLOCK) / CENTROID_BLOCK) * CENTROID_BLOCK
    }

    /// Fused (best, second-best) argmin over all centroids for every
    /// sample in `rows`, evaluated in sample tiles × centroid blocks.
    /// `emit(i, best2)` is called once per sample in ascending order.
    ///
    /// Requires a matching [`DistanceKernel::prepare`] call. Safe to call
    /// concurrently from pool lanes over disjoint ranges (`&self` only).
    pub fn argmin2_range(
        &self,
        x: &DataMatrix,
        c: &DataMatrix,
        rows: Range<usize>,
        mut emit: impl FnMut(usize, Best2),
    ) {
        debug_assert_eq!(self.x_norms.len(), x.n(), "prepare() not called for x");
        debug_assert_eq!(self.c_norms.len(), c.n(), "prepare() not called for c");
        let k = c.n();
        let ctile = self.centroid_tile(x.d());
        let mut start = rows.start;
        while start < rows.end {
            let tile = (rows.end - start).min(SAMPLE_TILE);
            // Running partials p = ‖c‖² − 2·x·c; the constant ‖x‖² is added
            // at emit time (it does not affect the argmin).
            let mut best = [0u32; SAMPLE_TILE];
            let mut best_p = [f64::INFINITY; SAMPLE_TILE];
            let mut second_p = [f64::INFINITY; SAMPLE_TILE];
            let mut cb = 0;
            while cb < k {
                let cend = (cb + ctile).min(k);
                for ti in 0..tile {
                    self.scan_block(
                        x.row(start + ti),
                        c,
                        cb,
                        cend,
                        &mut best[ti],
                        &mut best_p[ti],
                        &mut second_p[ti],
                    );
                }
                cb = cend;
            }
            for ti in 0..tile {
                let xn = self.x_norms[start + ti];
                emit(
                    start + ti,
                    Best2 {
                        best: best[ti],
                        best_d: (xn + best_p[ti]).max(0.0),
                        second_d: (xn + second_p[ti]).max(0.0),
                    },
                );
            }
            start += tile;
        }
    }

    /// Fused best/second-best for a single sample (the bound engines' full
    /// re-scan path).
    pub fn argmin2_row(&self, x: &DataMatrix, c: &DataMatrix, i: usize) -> Best2 {
        let mut out = Best2 { best: 0, best_d: f64::INFINITY, second_d: f64::INFINITY };
        self.argmin2_range(x, c, i..i + 1, |_, b| out = b);
        out
    }

    /// All `K` squared distances for sample `i` written into `out`
    /// (the dense initialization path of Elkan / Yinyang).
    pub fn dists_row(&self, x: &DataMatrix, c: &DataMatrix, i: usize, out: &mut [f64]) {
        let k = c.n();
        debug_assert_eq!(out.len(), k);
        debug_assert_eq!(self.c_norms.len(), k, "prepare() not called for c");
        let row = x.row(i);
        let xn = self.x_norms[i];
        let mut j = 0;
        while j + CENTROID_BLOCK <= k {
            let dots = dot_x4(row, c.row(j), c.row(j + 1), c.row(j + 2), c.row(j + 3));
            for (lane, &dj) in dots.iter().enumerate() {
                out[j + lane] = (xn - 2.0 * dj + self.c_norms[j + lane]).max(0.0);
            }
            j += CENTROID_BLOCK;
        }
        while j < k {
            out[j] = (xn - 2.0 * super::dot(row, c.row(j)) + self.c_norms[j]).max(0.0);
            j += 1;
        }
    }

    /// Single-pair squared distance via the cached norms (the sparse
    /// bound-tightening path).
    pub fn dist_sq(&self, x: &DataMatrix, c: &DataMatrix, i: usize, j: usize) -> f64 {
        (self.x_norms[i] - 2.0 * super::dot(x.row(i), c.row(j)) + self.c_norms[j]).max(0.0)
    }

    /// Scan centroids `[cb, cend)` for one sample, updating the running
    /// best/second partials. Full blocks go through the 4-wide micro-kernel.
    #[inline]
    fn scan_block(
        &self,
        row: &[f64],
        c: &DataMatrix,
        cb: usize,
        cend: usize,
        best: &mut u32,
        best_p: &mut f64,
        second_p: &mut f64,
    ) {
        let mut j = cb;
        while j + CENTROID_BLOCK <= cend {
            let dots = dot_x4(row, c.row(j), c.row(j + 1), c.row(j + 2), c.row(j + 3));
            for (lane, &dj) in dots.iter().enumerate() {
                let p = self.c_norms[j + lane] - 2.0 * dj;
                update2(best, best_p, second_p, (j + lane) as u32, p);
            }
            j += CENTROID_BLOCK;
        }
        while j < cend {
            let p = self.c_norms[j] - 2.0 * super::dot(row, c.row(j));
            update2(best, best_p, second_p, j as u32, p);
            j += 1;
        }
    }
}

/// Track the two smallest partials seen so far. Strict `<` keeps the
/// lowest centroid index on exact ties, matching the brute-force scan.
#[inline(always)]
fn update2(best: &mut u32, best_p: &mut f64, second_p: &mut f64, j: u32, p: f64) {
    if p < *best_p {
        *second_p = *best_p;
        *best_p = p;
        *best = j;
    } else if p < *second_p {
        *second_p = p;
    }
}

/// Dot products of one sample row against four centroid rows at once —
/// the register-blocked micro-kernel. Four independent accumulator chains
/// let the auto-vectorizer emit wide FMA lanes while each sample element
/// is loaded once per block instead of once per centroid.
#[inline(always)]
fn dot_x4(x: &[f64], c0: &[f64], c1: &[f64], c2: &[f64], c3: &[f64]) -> [f64; 4] {
    let d = x.len();
    let (c0, c1, c2, c3) = (&c0[..d], &c1[..d], &c2[..d], &c3[..d]);
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for t in 0..d {
        let v = x[t];
        s0 += v * c0[t];
        s1 += v * c1[t];
        s2 += v * c2[t];
        s3 += v * c3[t];
    }
    [s0, s1, s2, s3]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::linalg;
    use crate::lloyd::brute_force_assign;
    use crate::rng::Pcg32;

    /// Exact distances for one sample, for cross-checking.
    fn exact_dists(x: &DataMatrix, c: &DataMatrix, i: usize) -> Vec<f64> {
        (0..c.n()).map(|j| linalg::dist_sq(x.row(i), c.row(j))).collect()
    }

    fn check_matches_brute(x: &DataMatrix, c: &DataMatrix, ctx: &str) {
        let pool = ThreadPool::new(2);
        let mut kernel = DistanceKernel::new();
        kernel.prepare(x, c, &pool);
        let expect = brute_force_assign(x, c);
        let k = c.n();
        let mut seen = 0usize;
        kernel.argmin2_range(x, c, 0..x.n(), |i, b| {
            seen += 1;
            let mut exact = exact_dists(x, c, i);
            // The kernel's pick must be distance-equal to the brute-force
            // pick (ids may differ on ties — see module docs).
            let got = exact[b.best as usize];
            let best = exact[expect[i] as usize];
            assert!((got - best).abs() < 1e-9, "{ctx}: sample {i}: {got} vs {best}");
            assert!((b.best_d - got).abs() < 1e-9, "{ctx}: sample {i} best_d");
            assert!(b.best_d >= 0.0 && b.second_d >= 0.0, "{ctx}: negative distance");
            exact.sort_by(|a, b| a.partial_cmp(b).unwrap());
            if k >= 2 {
                assert!(
                    (b.second_d - exact[1]).abs() < 1e-9,
                    "{ctx}: sample {i} second_d {} vs {}",
                    b.second_d,
                    exact[1]
                );
            } else {
                assert!(b.second_d.is_infinite(), "{ctx}: K=1 second bound");
            }
            // dists_row and dist_sq agree with the exact form too.
            let mut dense = vec![0.0; k];
            kernel.dists_row(x, c, i, &mut dense);
            for j in 0..k {
                let e = linalg::dist_sq(x.row(i), c.row(j));
                assert!((dense[j] - e).abs() < 1e-9, "{ctx}: dists_row[{i}][{j}]");
            }
            let one = kernel.dist_sq(x, c, i, b.best as usize);
            assert!((one - got).abs() < 1e-9, "{ctx}: dist_sq one-pair");
        });
        assert_eq!(seen, x.n(), "{ctx}: emit must cover every sample once");
    }

    /// Satellite property test: tiled/norm-decomposed assignment matches
    /// brute force across the full d × K grid, with duplicate points and
    /// tie distances (duplicated centroids, centroids placed exactly on
    /// samples so clamping at zero is exercised).
    #[test]
    fn property_matches_brute_force_across_shapes() {
        let mut rng = Pcg32::seed_from_u64(0xD15E);
        for &d in &[1usize, 2, 3, 7, 8, 16, 100] {
            for &k in &[1usize, 7, 64] {
                let n = 160.max(2 * k);
                let blobs = k.clamp(1, 8);
                let mut x = synth::gaussian_blobs(&mut rng, n, d, blobs, 2.0, 0.3);
                // Duplicate points: rows 1 and 2 become copies of row 0.
                let r0 = x.row(0).to_vec();
                x.row_mut(1).copy_from_slice(&r0);
                x.row_mut(2).copy_from_slice(&r0);
                // Centroids sit exactly on samples (zero distances).
                let idx: Vec<usize> = (0..k).map(|j| (j * 7) % n).collect();
                let mut c = x.gather_rows(&idx);
                if k >= 2 {
                    // Tie distances: centroid 1 duplicates centroid 0.
                    let c0 = c.row(0).to_vec();
                    c.row_mut(1).copy_from_slice(&c0);
                }
                check_matches_brute(&x, &c, &format!("d={d} k={k}"));
            }
        }
    }

    #[test]
    fn prepare_tracks_centroid_motion() {
        let mut rng = Pcg32::seed_from_u64(7);
        let x = synth::gaussian_blobs(&mut rng, 200, 5, 3, 2.0, 0.4);
        let mut c = x.gather_rows(&[0, 50, 100]);
        let pool = ThreadPool::new(1);
        let mut kernel = DistanceKernel::new();
        for round in 0..4 {
            kernel.prepare(&x, &c, &pool);
            check_round(&kernel, &x, &c, round);
            for j in 0..c.n() {
                for t in 0..c.d() {
                    c[(j, t)] += 0.1 * (j + t + 1) as f64;
                }
            }
        }

        fn check_round(kernel: &DistanceKernel, x: &DataMatrix, c: &DataMatrix, round: usize) {
            for i in (0..x.n()).step_by(17) {
                for j in 0..c.n() {
                    let e = linalg::dist_sq(x.row(i), c.row(j));
                    let g = kernel.dist_sq(x, c, i, j);
                    assert!((g - e).abs() < 1e-9, "round {round} pair ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn invalidate_recomputes_sample_norms() {
        let pool = ThreadPool::new(1);
        let mut kernel = DistanceKernel::new();
        let x1 = DataMatrix::from_rows(&[&[1.0, 0.0], &[0.0, 2.0]]);
        let c = DataMatrix::from_rows(&[&[0.0, 0.0]]);
        kernel.prepare(&x1, &c, &pool);
        assert!((kernel.dist_sq(&x1, &c, 1, 0) - 4.0).abs() < 1e-12);
        kernel.invalidate();
        let x2 = DataMatrix::from_rows(&[&[3.0, 0.0], &[0.0, 5.0]]);
        kernel.prepare(&x2, &c, &pool);
        assert!((kernel.dist_sq(&x2, &c, 1, 0) - 25.0).abs() < 1e-12);
    }
}
