//! Small dense matrices (row-major), Cholesky SPD solve, Householder-QR
//! least squares. Sized for the AA subproblem (`m ≤ 30`).

/// Row-major dense matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Build from a row-major slice.
    pub fn from_rows(rows: usize, cols: usize, data: &[f64]) -> Self {
        assert_eq!(data.len(), rows * cols);
        Self { rows, cols, data: data.to_vec() }
    }

    /// Identity.
    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Matrix–vector product.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0; self.rows];
        for i in 0..self.rows {
            y[i] = super::dot(&self.data[i * self.cols..(i + 1) * self.cols], x);
        }
        y
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

/// Solve `A x = b` for symmetric positive-definite `A` (in-place Cholesky;
/// `a` is the packed row-major `n×n` matrix, destroyed; `b` becomes `x`).
///
/// Returns `false` when the factorization hits a non-positive pivot (matrix
/// not SPD within tolerance) — callers are expected to re-regularize.
pub fn cholesky_solve_in_place(a: &mut [f64], b: &mut [f64], n: usize) -> bool {
    debug_assert_eq!(a.len(), n * n);
    debug_assert_eq!(b.len(), n);
    // Factor A = L Lᵀ, L stored in the lower triangle of `a`.
    for j in 0..n {
        let mut diag = a[j * n + j];
        for k in 0..j {
            diag -= a[j * n + k] * a[j * n + k];
        }
        if diag <= 0.0 || !diag.is_finite() {
            return false;
        }
        let ljj = diag.sqrt();
        a[j * n + j] = ljj;
        for i in (j + 1)..n {
            let mut v = a[i * n + j];
            for k in 0..j {
                v -= a[i * n + k] * a[j * n + k];
            }
            a[i * n + j] = v / ljj;
        }
    }
    // Forward solve L y = b.
    for i in 0..n {
        let mut v = b[i];
        for k in 0..i {
            v -= a[i * n + k] * b[k];
        }
        b[i] = v / a[i * n + i];
    }
    // Back solve Lᵀ x = y.
    for i in (0..n).rev() {
        let mut v = b[i];
        for k in (i + 1)..n {
            v -= a[k * n + i] * b[k];
        }
        b[i] = v / a[i * n + i];
    }
    true
}

/// Least squares `min ‖A x − b‖₂` via Householder QR with column norms as a
/// rank guard. `a` is `rows×cols` row-major with `rows ≥ cols`. Used as the
/// reference solver in tests and as the fall-back when normal equations are
/// too ill-conditioned.
pub fn householder_lstsq(a: &Mat, b: &[f64]) -> Vec<f64> {
    let (m, n) = (a.rows, a.cols);
    assert!(m >= n, "householder_lstsq needs rows >= cols");
    assert_eq!(b.len(), m);
    let mut r = a.data.clone();
    let mut y = b.to_vec();
    for k in 0..n {
        // Householder vector for column k below the diagonal.
        let mut alpha = 0.0;
        for i in k..m {
            alpha += r[i * n + k] * r[i * n + k];
        }
        let alpha = alpha.sqrt();
        if alpha == 0.0 {
            continue; // zero column: leave x_k = 0 via zero pivot handling
        }
        let sign = if r[k * n + k] >= 0.0 { 1.0 } else { -1.0 };
        let mut v = vec![0.0; m - k];
        v[0] = r[k * n + k] + sign * alpha;
        for i in (k + 1)..m {
            v[i - k] = r[i * n + k];
        }
        let vnorm_sq: f64 = v.iter().map(|x| x * x).sum();
        if vnorm_sq == 0.0 {
            continue;
        }
        // Apply H = I - 2 v vᵀ / (vᵀv) to R[k.., k..] and y[k..].
        for j in k..n {
            let mut proj = 0.0;
            for i in k..m {
                proj += v[i - k] * r[i * n + j];
            }
            let scale = 2.0 * proj / vnorm_sq;
            for i in k..m {
                r[i * n + j] -= scale * v[i - k];
            }
        }
        let mut proj = 0.0;
        for i in k..m {
            proj += v[i - k] * y[i];
        }
        let scale = 2.0 * proj / vnorm_sq;
        for i in k..m {
            y[i] -= scale * v[i - k];
        }
    }
    // Back substitution on the upper-triangular R.
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut v = y[i];
        for j in (i + 1)..n {
            v -= r[i * n + j] * x[j];
        }
        let pivot = r[i * n + i];
        x[i] = if pivot.abs() > 1e-12 { v / pivot } else { 0.0 };
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cholesky_solves_spd_system() {
        // A = Bᵀ B + I is SPD.
        let n = 4;
        let bmat = [
            1.0, 2.0, 0.0, 1.0, //
            0.0, 1.0, 3.0, 0.0, //
            2.0, 0.0, 1.0, 1.0, //
            1.0, 1.0, 1.0, 2.0,
        ];
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut s = if i == j { 1.0 } else { 0.0 };
                for k in 0..n {
                    s += bmat[k * n + i] * bmat[k * n + j];
                }
                a[i * n + j] = s;
            }
        }
        let x_true = [1.0, -2.0, 0.5, 3.0];
        let mut rhs = vec![0.0; n];
        for i in 0..n {
            for j in 0..n {
                rhs[i] += a[i * n + j] * x_true[j];
            }
        }
        let mut a_work = a.clone();
        assert!(cholesky_solve_in_place(&mut a_work, &mut rhs, n));
        for i in 0..n {
            assert!((rhs[i] - x_true[i]).abs() < 1e-9, "x[{i}]={}", rhs[i]);
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let mut a = vec![1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        let mut b = vec![1.0, 1.0];
        assert!(!cholesky_solve_in_place(&mut a, &mut b, 2));
    }

    #[test]
    fn qr_recovers_exact_solution_square() {
        let a = Mat::from_rows(3, 3, &[2.0, 1.0, 0.0, 1.0, 3.0, 1.0, 0.0, 1.0, 4.0]);
        let x_true = [1.0, -1.0, 2.0];
        let b = a.matvec(&x_true);
        let x = householder_lstsq(&a, &b);
        for i in 0..3 {
            assert!((x[i] - x_true[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn qr_overdetermined_matches_normal_equations() {
        // Fit a line y = 2x + 1 through noisy-free samples: exact recovery.
        let xs = [0.0, 1.0, 2.0, 3.0, 4.0];
        let mut data = Vec::new();
        let mut b = Vec::new();
        for &x in &xs {
            data.extend_from_slice(&[x, 1.0]);
            b.push(2.0 * x + 1.0);
        }
        let a = Mat::from_rows(5, 2, &data);
        let sol = householder_lstsq(&a, &b);
        assert!((sol[0] - 2.0).abs() < 1e-9);
        assert!((sol[1] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn qr_rank_deficient_returns_finite() {
        // Second column is 2× the first: rank 1. Solver must not blow up.
        let a = Mat::from_rows(4, 2, &[1.0, 2.0, 2.0, 4.0, 3.0, 6.0, 4.0, 8.0]);
        let b = [1.0, 2.0, 3.0, 4.0];
        let x = householder_lstsq(&a, &b);
        assert!(x.iter().all(|v| v.is_finite()));
        // Residual should still be (near) minimal: b is in the column space.
        let pred = a.matvec(&x);
        let res: f64 = pred.iter().zip(&b).map(|(p, t)| (p - t) * (p - t)).sum();
        assert!(res < 1e-9, "residual {res}");
    }

    #[test]
    fn mat_eye_and_matvec() {
        let i3 = Mat::eye(3);
        assert_eq!(i3.matvec(&[4.0, 5.0, 6.0]), vec![4.0, 5.0, 6.0]);
    }
}
