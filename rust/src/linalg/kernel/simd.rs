//! Runtime-dispatched explicit SIMD lanes for the distance micro-kernels.
//!
//! The portable scalar code in [`super::scalar`] autovectorizes well, but
//! only the explicit AVX2+FMA paths here guarantee the 4-wide (f64) /
//! 8-wide (f32) FMA lanes regardless of compiler mood. Dispatch is decided
//! **once per kernel construction** via [`detect`] (backed by
//! `is_x86_feature_detected!`) and stored as a [`SimdLevel`]; the hot loop
//! then takes a single well-predicted branch per micro-kernel call instead
//! of re-querying CPUID.
//!
//! On non-x86_64 targets this module compiles down to the [`SimdLevel`]
//! enum and a [`detect`] that always answers [`SimdLevel::Scalar`], so the
//! portable fallback is exercised by construction — there is no
//! conditionally-absent API surface.

/// Which micro-kernel implementation the [`super::DistanceKernel`] runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdLevel {
    /// Portable autovectorized code (any target; forced via
    /// [`super::DistanceKernel::with_options`] for baselines and tests).
    Scalar,
    /// Explicit AVX2+FMA intrinsics (x86_64 with runtime support only).
    Avx2Fma,
}

impl SimdLevel {
    /// Canonical name for benches and reports.
    pub fn name(&self) -> &'static str {
        match self {
            Self::Scalar => "scalar",
            Self::Avx2Fma => "avx2+fma",
        }
    }
}

/// Detect the best level the running CPU supports. Callers cache the
/// answer (one CPUID probe per kernel construction, never per sweep).
pub fn detect() -> SimdLevel {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            return SimdLevel::Avx2Fma;
        }
    }
    SimdLevel::Scalar
}

#[cfg(target_arch = "x86_64")]
pub use x86::{dot_f32_avx2, dot_f64_avx2, dot_x4_f32_avx2, dot_x4_f64_avx2};

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::*;

    // Note: every body below is wrapped in an explicit `unsafe { }` block
    // so the module compiles unchanged under `unsafe_op_in_unsafe_fn`
    // (edition-2024 default) as well as older editions.

    /// Horizontal sum of a 4-lane f64 accumulator.
    #[inline]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn hsum_pd(v: __m256d) -> f64 {
        unsafe {
            let lo = _mm256_castpd256_pd128(v);
            let hi = _mm256_extractf128_pd(v, 1);
            let s = _mm_add_pd(lo, hi);
            let swapped = _mm_unpackhi_pd(s, s);
            _mm_cvtsd_f64(_mm_add_sd(s, swapped))
        }
    }

    /// Horizontal sum of an 8-lane f32 accumulator.
    #[inline]
    #[target_feature(enable = "avx2,fma")]
    unsafe fn hsum_ps(v: __m256) -> f32 {
        unsafe {
            let lo = _mm256_castps256_ps128(v);
            let hi = _mm256_extractf128_ps(v, 1);
            let s = _mm_add_ps(lo, hi);
            let shuf = _mm_movehdup_ps(s);
            let sums = _mm_add_ps(s, shuf);
            let high = _mm_movehl_ps(shuf, sums);
            _mm_cvtss_f32(_mm_add_ss(sums, high))
        }
    }

    /// AVX2+FMA dot product, f64 lanes.
    ///
    /// # Safety
    /// The CPU must support AVX2 and FMA (call only after [`super::detect`]
    /// answered [`super::SimdLevel::Avx2Fma`]). Slices must share a length.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot_f64_avx2(a: &[f64], b: &[f64]) -> f64 {
        debug_assert_eq!(a.len(), b.len());
        let d = a.len();
        unsafe {
            let mut acc = _mm256_setzero_pd();
            let mut t = 0;
            while t + 4 <= d {
                let va = _mm256_loadu_pd(a.as_ptr().add(t));
                let vb = _mm256_loadu_pd(b.as_ptr().add(t));
                acc = _mm256_fmadd_pd(va, vb, acc);
                t += 4;
            }
            let mut s = hsum_pd(acc);
            while t < d {
                s += a[t] * b[t];
                t += 1;
            }
            s
        }
    }

    /// AVX2+FMA dot product, f32 lanes, widened to f64 at the end.
    ///
    /// # Safety
    /// Same contract as [`dot_f64_avx2`].
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot_f32_avx2(a: &[f32], b: &[f32]) -> f64 {
        debug_assert_eq!(a.len(), b.len());
        let d = a.len();
        unsafe {
            let mut acc = _mm256_setzero_ps();
            let mut t = 0;
            while t + 8 <= d {
                let va = _mm256_loadu_ps(a.as_ptr().add(t));
                let vb = _mm256_loadu_ps(b.as_ptr().add(t));
                acc = _mm256_fmadd_ps(va, vb, acc);
                t += 8;
            }
            let mut s = hsum_ps(acc);
            while t < d {
                s += a[t] * b[t];
                t += 1;
            }
            s as f64
        }
    }

    /// One sample row against four centroid rows, f64 AVX2+FMA lanes —
    /// the register-blocked micro-kernel: each 4-wide load of `x` feeds
    /// four independent FMA accumulator chains, so every sample element
    /// is loaded once per centroid *block* instead of once per centroid.
    ///
    /// # Safety
    /// Same contract as [`dot_f64_avx2`]; all five slices share a length.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot_x4_f64_avx2(
        x: &[f64],
        c0: &[f64],
        c1: &[f64],
        c2: &[f64],
        c3: &[f64],
    ) -> [f64; 4] {
        let d = x.len();
        debug_assert!(c0.len() == d && c1.len() == d && c2.len() == d && c3.len() == d);
        unsafe {
            let mut s0 = _mm256_setzero_pd();
            let mut s1 = _mm256_setzero_pd();
            let mut s2 = _mm256_setzero_pd();
            let mut s3 = _mm256_setzero_pd();
            let mut t = 0;
            while t + 4 <= d {
                let v = _mm256_loadu_pd(x.as_ptr().add(t));
                s0 = _mm256_fmadd_pd(v, _mm256_loadu_pd(c0.as_ptr().add(t)), s0);
                s1 = _mm256_fmadd_pd(v, _mm256_loadu_pd(c1.as_ptr().add(t)), s1);
                s2 = _mm256_fmadd_pd(v, _mm256_loadu_pd(c2.as_ptr().add(t)), s2);
                s3 = _mm256_fmadd_pd(v, _mm256_loadu_pd(c3.as_ptr().add(t)), s3);
                t += 4;
            }
            let mut out = [hsum_pd(s0), hsum_pd(s1), hsum_pd(s2), hsum_pd(s3)];
            while t < d {
                let v = x[t];
                out[0] += v * c0[t];
                out[1] += v * c1[t];
                out[2] += v * c2[t];
                out[3] += v * c3[t];
                t += 1;
            }
            out
        }
    }

    /// One sample row against four centroid rows, f32 AVX2+FMA lanes
    /// (8 elements per load — the 2× bandwidth the f32 storage mode buys).
    ///
    /// # Safety
    /// Same contract as [`dot_x4_f64_avx2`].
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot_x4_f32_avx2(
        x: &[f32],
        c0: &[f32],
        c1: &[f32],
        c2: &[f32],
        c3: &[f32],
    ) -> [f64; 4] {
        let d = x.len();
        debug_assert!(c0.len() == d && c1.len() == d && c2.len() == d && c3.len() == d);
        unsafe {
            let mut s0 = _mm256_setzero_ps();
            let mut s1 = _mm256_setzero_ps();
            let mut s2 = _mm256_setzero_ps();
            let mut s3 = _mm256_setzero_ps();
            let mut t = 0;
            while t + 8 <= d {
                let v = _mm256_loadu_ps(x.as_ptr().add(t));
                s0 = _mm256_fmadd_ps(v, _mm256_loadu_ps(c0.as_ptr().add(t)), s0);
                s1 = _mm256_fmadd_ps(v, _mm256_loadu_ps(c1.as_ptr().add(t)), s1);
                s2 = _mm256_fmadd_ps(v, _mm256_loadu_ps(c2.as_ptr().add(t)), s2);
                s3 = _mm256_fmadd_ps(v, _mm256_loadu_ps(c3.as_ptr().add(t)), s3);
                t += 8;
            }
            let mut out = [hsum_ps(s0), hsum_ps(s1), hsum_ps(s2), hsum_ps(s3)];
            while t < d {
                let v = x[t];
                out[0] += v * c0[t];
                out[1] += v * c1[t];
                out[2] += v * c2[t];
                out[3] += v * c3[t];
                t += 1;
            }
            [out[0] as f64, out[1] as f64, out[2] as f64, out[3] as f64]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn detect_is_stable_and_sane() {
        let a = detect();
        let b = detect();
        assert_eq!(a, b, "detection must be deterministic");
        // On non-x86_64 builds the only possible answer is the fallback —
        // this is the cfg-based dispatch check the CI fallback leg relies on.
        #[cfg(not(target_arch = "x86_64"))]
        assert_eq!(a, SimdLevel::Scalar);
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_dots_match_scalar_reference() {
        if detect() != SimdLevel::Avx2Fma {
            eprintln!("avx2+fma unavailable; skipping intrinsics test");
            return;
        }
        // Lengths straddling the vector widths exercise the tails.
        for d in [1usize, 3, 4, 5, 7, 8, 9, 16, 31, 100] {
            let a64: Vec<f64> = (0..d).map(|i| (i as f64 * 0.37).sin()).collect();
            let b64: Vec<f64> = (0..d).map(|i| (i as f64 * 0.91).cos()).collect();
            let exact: f64 = a64.iter().zip(&b64).map(|(x, y)| x * y).sum();
            let got = unsafe { dot_f64_avx2(&a64, &b64) };
            assert!((got - exact).abs() < 1e-12, "d={d}: f64 {got} vs {exact}");

            let a32: Vec<f32> = a64.iter().map(|&v| v as f32).collect();
            let b32: Vec<f32> = b64.iter().map(|&v| v as f32).collect();
            let got32 = unsafe { dot_f32_avx2(&a32, &b32) };
            assert!(
                (got32 - exact).abs() < 1e-4 * (d as f64),
                "d={d}: f32 {got32} vs {exact}"
            );

            let x4 = unsafe { dot_x4_f64_avx2(&a64, &b64, &a64, &b64, &a64) };
            let naa: f64 = a64.iter().map(|v| v * v).sum();
            assert!((x4[0] - exact).abs() < 1e-12);
            assert!((x4[1] - naa).abs() < 1e-12);
            assert!((x4[2] - exact).abs() < 1e-12);
            assert!((x4[3] - naa).abs() < 1e-12);

            let x4s = unsafe { dot_x4_f32_avx2(&a32, &b32, &a32, &b32, &a32) };
            assert!((x4s[0] - exact).abs() < 1e-4 * (d as f64));
            assert!((x4s[1] - naa).abs() < 1e-4 * (d as f64));
        }
    }
}
