//! The precision abstraction and the portable (autovectorized) micro-kernels.
//!
//! [`Scalar`] is the trait the whole distance subsystem is generic over:
//! it carries the element type of the *storage* (`f64`, or the `f32`
//! mirror of the sample matrix) and routes every dot product through the
//! dispatch level chosen at kernel construction. Accumulation happens in
//! the storage precision — that is the point of the f32 mode: half the
//! memory traffic *and* twice the lanes per FMA — and results are widened
//! to `f64` at the micro-kernel boundary, so norms, partials, bounds and
//! energies stay `f64` everywhere above this file.

use super::simd::SimdLevel;

/// Element type of a distance-kernel storage buffer (`f64` or `f32`).
///
/// The methods take the [`SimdLevel`] the owning kernel resolved once at
/// construction and pick between the explicit AVX2+FMA lanes and the
/// autovectorized fallback below; both return `f64`. (Narrowing *into*
/// the storage type is not part of this trait — the f32 mirror is filled
/// by [`crate::data::DataMatrix::write_f32_into`], the crate's single
/// conversion point.)
pub trait Scalar:
    Copy
    + Send
    + Sync
    + Default
    + std::ops::Mul<Output = Self>
    + std::ops::AddAssign
    + Into<f64>
    + 'static
{
    /// Dot product of two equal-length slices under `simd`.
    fn dot(simd: SimdLevel, a: &[Self], b: &[Self]) -> f64;

    /// Register-blocked micro-kernel: one sample row against four centroid
    /// rows at once, under `simd`.
    fn dot_x4(
        simd: SimdLevel,
        x: &[Self],
        c0: &[Self],
        c1: &[Self],
        c2: &[Self],
        c3: &[Self],
    ) -> [f64; 4];
}

/// Portable dot product with four independent accumulator chains — the
/// shape the auto-vectorizer reliably turns into wide FMA lanes.
pub fn dot_autovec<T: Scalar>(a: &[T], b: &[T]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) =
        (T::default(), T::default(), T::default(), T::default());
    for i in 0..chunks {
        let j = i * 4;
        s0 += a[j] * b[j];
        s1 += a[j + 1] * b[j + 1];
        s2 += a[j + 2] * b[j + 2];
        s3 += a[j + 3] * b[j + 3];
    }
    let mut s = s0;
    s += s1;
    s += s2;
    s += s3;
    for i in chunks * 4..n {
        s += a[i] * b[i];
    }
    s.into()
}

/// Portable 4-wide register-blocked micro-kernel: four accumulator chains,
/// one per centroid, each sample element loaded once per block.
pub fn dot_x4_autovec<T: Scalar>(x: &[T], c0: &[T], c1: &[T], c2: &[T], c3: &[T]) -> [f64; 4] {
    let d = x.len();
    let (c0, c1, c2, c3) = (&c0[..d], &c1[..d], &c2[..d], &c3[..d]);
    let (mut s0, mut s1, mut s2, mut s3) =
        (T::default(), T::default(), T::default(), T::default());
    for t in 0..d {
        let v = x[t];
        s0 += v * c0[t];
        s1 += v * c1[t];
        s2 += v * c2[t];
        s3 += v * c3[t];
    }
    [s0.into(), s1.into(), s2.into(), s3.into()]
}

impl Scalar for f64 {
    #[inline]
    fn dot(simd: SimdLevel, a: &[Self], b: &[Self]) -> f64 {
        #[cfg(target_arch = "x86_64")]
        if simd == SimdLevel::Avx2Fma {
            // SAFETY: Avx2Fma is only ever constructed after runtime
            // detection (see `simd::detect` / `DistanceKernel::with_options`).
            return unsafe { super::simd::dot_f64_avx2(a, b) };
        }
        let _ = simd;
        dot_autovec(a, b)
    }

    #[inline]
    fn dot_x4(
        simd: SimdLevel,
        x: &[Self],
        c0: &[Self],
        c1: &[Self],
        c2: &[Self],
        c3: &[Self],
    ) -> [f64; 4] {
        #[cfg(target_arch = "x86_64")]
        if simd == SimdLevel::Avx2Fma {
            // SAFETY: as in `dot` above.
            return unsafe { super::simd::dot_x4_f64_avx2(x, c0, c1, c2, c3) };
        }
        let _ = simd;
        dot_x4_autovec(x, c0, c1, c2, c3)
    }
}

impl Scalar for f32 {
    #[inline]
    fn dot(simd: SimdLevel, a: &[Self], b: &[Self]) -> f64 {
        #[cfg(target_arch = "x86_64")]
        if simd == SimdLevel::Avx2Fma {
            // SAFETY: as in the f64 impl.
            return unsafe { super::simd::dot_f32_avx2(a, b) };
        }
        let _ = simd;
        dot_autovec(a, b)
    }

    #[inline]
    fn dot_x4(
        simd: SimdLevel,
        x: &[Self],
        c0: &[Self],
        c1: &[Self],
        c2: &[Self],
        c3: &[Self],
    ) -> [f64; 4] {
        #[cfg(target_arch = "x86_64")]
        if simd == SimdLevel::Avx2Fma {
            // SAFETY: as in the f64 impl.
            return unsafe { super::simd::dot_x4_f32_avx2(x, c0, c1, c2, c3) };
        }
        let _ = simd;
        dot_x4_autovec(x, c0, c1, c2, c3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn autovec_dot_matches_naive_for_both_precisions() {
        let a64: Vec<f64> = (0..37).map(|i| (i as f64 * 0.5).sin()).collect();
        let b64: Vec<f64> = (0..37).map(|i| (i as f64 * 0.2).cos()).collect();
        let naive: f64 = a64.iter().zip(&b64).map(|(x, y)| x * y).sum();
        assert!((dot_autovec(&a64, &b64) - naive).abs() < 1e-12);

        let a32: Vec<f32> = a64.iter().map(|&v| v as f32).collect();
        let b32: Vec<f32> = b64.iter().map(|&v| v as f32).collect();
        assert!((dot_autovec(&a32, &b32) - naive).abs() < 1e-4);
    }

    #[test]
    fn dot_x4_autovec_matches_four_dots() {
        let rows: Vec<Vec<f64>> = (0..5)
            .map(|r| (0..13).map(|i| ((r * 13 + i) as f64 * 0.73).sin()).collect())
            .collect();
        let got = dot_x4_autovec(&rows[0], &rows[1], &rows[2], &rows[3], &rows[4]);
        for lane in 0..4 {
            let exact: f64 =
                rows[0].iter().zip(&rows[lane + 1]).map(|(x, y)| x * y).sum();
            assert!((got[lane] - exact).abs() < 1e-12, "lane {lane}");
        }
    }

    #[test]
    fn trait_dispatch_agrees_across_levels() {
        // Whatever level `detect` picks must agree with the forced-scalar
        // fallback — the unit-level version of the argmin parity property.
        let level = super::super::simd::detect();
        for d in [1usize, 4, 7, 8, 12, 33] {
            let a: Vec<f64> = (0..d).map(|i| (i as f64 * 1.3).sin() * 3.0).collect();
            let b: Vec<f64> = (0..d).map(|i| (i as f64 * 0.7).cos() * 2.0).collect();
            let scalar = f64::dot(SimdLevel::Scalar, &a, &b);
            let best = f64::dot(level, &a, &b);
            assert!((scalar - best).abs() < 1e-10, "d={d}: {scalar} vs {best}");

            let a32: Vec<f32> = a.iter().map(|&v| v as f32).collect();
            let b32: Vec<f32> = b.iter().map(|&v| v as f32).collect();
            let scalar32 = f32::dot(SimdLevel::Scalar, &a32, &b32);
            let best32 = f32::dot(level, &a32, &b32);
            assert!(
                (scalar32 - best32).abs() < 1e-4,
                "d={d}: f32 {scalar32} vs {best32}"
            );
        }
    }
}
