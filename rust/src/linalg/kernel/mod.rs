//! Blocked, norm-decomposed distance kernels — the compute core every
//! assignment engine runs on. Precision-generic since PR 2: the same tile
//! sweep runs on `f64` storage or on an `f32` sample mirror, over explicit
//! AVX2+FMA lanes or the portable autovectorized fallback, selected once
//! per kernel at construction.
//!
//! # Decomposition
//!
//! The squared Euclidean distance is evaluated as
//!
//! ```text
//! ‖x − c‖² = ‖x‖² − 2·x·c + ‖c‖²
//! ```
//!
//! with `‖x‖²` cached once per dataset (samples never move during a run)
//! and `‖c‖²` refreshed once per centroid motion (i.e. per [`DistanceKernel::prepare`]
//! call). That turns the inner loop from 3 flops/element (subtract, square,
//! add) into a pure 2 flops/element dot product, which the register-blocked
//! micro-kernel evaluates for four centroids at a time so each sample
//! element is loaded once per block instead of once per centroid.
//!
//! # Layers
//!
//! * [`scalar`] defines the [`Scalar`] trait (`f64` / `f32` storage) and the
//!   portable 4-chain micro-kernels the auto-vectorizer handles well.
//! * [`simd`] holds the explicit `std::arch` AVX2+FMA micro-kernels
//!   (`_mm256_fmadd_pd` 4-wide / `_mm256_fmadd_ps` 8-wide) and the one-shot
//!   [`simd::detect`] runtime dispatch; on non-x86_64 targets it degrades to
//!   the enum plus a detector that always answers [`SimdLevel::Scalar`].
//! * This module owns the caches and the blocked sweep, generic over both.
//!
//! # Blocking
//!
//! [`DistanceKernel::argmin2_range`] sweeps cache-sized *sample tiles* ×
//! *centroid blocks*: the centroid block (sized to stay resident in L1) is
//! reused across every sample of the tile, and within a block the 4-wide
//! micro-kernel keeps four independent accumulator chains alive. The sweep
//! is *fused* with the argmin: it returns both the best and second-best
//! distance per sample in one pass, which is exactly what bound-based
//! engines (Hamerly, Elkan, Yinyang) need to refresh their upper *and*
//! lower bounds from a single sweep.
//!
//! # Accuracy tradeoff
//!
//! The norm-decomposed form loses bits to cancellation when `‖x‖² + ‖c‖²`
//! is much larger than the true distance (a point sitting almost on a
//! centroid): the absolute error is `O(ε · (‖x‖² + ‖c‖²))`, versus
//! `O(ε · ‖x − c‖²)` for the subtract-square form.
//!
//! * **f64 storage** (`ε ≈ 2.2e−16`): for data with coordinates up to ~1e4
//!   the error stays below ~1e-12, far inside the crate-wide `1e-9`
//!   tolerance. The AVX2 path changes only the summation *order* (4-wide
//!   FMA trees), never the precision — scalar-f64 and simd-f64 agree to
//!   the same `1e-9` tolerance, which the parity property test pins down.
//! * **f32 sample storage** (`ε ≈ 1.2e−7`): samples are mirrored once into
//!   an `f32` buffer for 2× memory bandwidth and 8-wide FMA lanes, while
//!   centroids, norms, bounds and energies stay `f64` (the mirror of the
//!   centroid block is refreshed per [`DistanceKernel::prepare`], an
//!   O(K·d) cost). Distances now carry `O(ε₃₂ · (‖x‖² + ‖c‖²))` error, so
//!   the mode is meant to be paired with the [`crate::data::center`]
//!   pre-centering transform, which minimizes sample norms and keeps the
//!   error near `ε₃₂ ·` (cluster spread)² — ties may resolve differently,
//!   but every returned distance stays within that envelope of the exact
//!   one. The CLI applies pre-centering automatically in f32 mode.
//!
//! Results are clamped at zero (the decomposition can go slightly
//! negative), and downstream comparisons must use *distance* equality,
//! never assignment-id equality — ties can legitimately resolve either way.
//!
//! # Cache identity
//!
//! Sample norms (and the f32 mirror) are keyed on
//! `(DataMatrix::generation, n, d)`. The stamp is an
//! `(identity, mutation-count)` pair — identities are globally unique and
//! never copied by `clone`, and every `&mut` accessor bumps the count — so,
//! unlike the buffer pointer this cache used to key on, a
//! freed-and-reallocated matrix at the same address, or an in-place
//! mutation, can never alias a stale cache entry. Because the stamp alone
//! proves validity, engine `reset()` keeps the cache alive across runs:
//! a same-data rerun at a different `k` (a multi-k sweep, a warm-start
//! refresh) skips the O(N·d) norm pass entirely.
//! [`DistanceKernel::invalidate`] remains for explicit cold starts.

pub mod scalar;
pub mod simd;

pub use scalar::Scalar;
pub use simd::SimdLevel;

use crate::data::DataMatrix;
use crate::par::{SyncSliceMut, ThreadPool};
use std::ops::Range;

/// Samples per tile of the blocked sweep. A tile's running best/second
/// state lives in stack arrays of this size.
const SAMPLE_TILE: usize = 32;
/// Centroids per micro-kernel pass (the register-blocking width).
const CENTROID_BLOCK: usize = 4;
/// Target bytes of centroid data kept hot per block sweep (~half of a
/// typical 32 KiB L1d).
const CENTROID_TILE_BYTES: usize = 16 * 1024;

/// Storage precision of a [`DistanceKernel`]'s sample data.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Precision {
    /// Full-precision `f64` storage (the default).
    #[default]
    F64,
    /// `f32` sample-storage mode: samples mirrored once into `f32` for 2×
    /// assign-sweep bandwidth; centroids, bounds and energy stay `f64`.
    F32,
}

impl Precision {
    /// Parse from a config / CLI string.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "f64" | "double" => Some(Self::F64),
            "f32" | "single" | "float" => Some(Self::F32),
            _ => None,
        }
    }

    /// Canonical name.
    pub fn name(&self) -> &'static str {
        match self {
            Self::F64 => "f64",
            Self::F32 => "f32",
        }
    }

    /// Bytes per stored sample element (drives the L1 tile sizing).
    fn elem_bytes(&self) -> usize {
        match self {
            Self::F64 => 8,
            Self::F32 => 4,
        }
    }
}

/// Result of the fused argmin sweep for one sample: squared distances to
/// the best and second-best centroid. `second_d` is `+∞` when `K == 1`.
#[derive(Debug, Clone, Copy)]
pub struct Best2 {
    /// Index of the nearest centroid.
    pub best: u32,
    /// Squared distance to the nearest centroid (clamped ≥ 0).
    pub best_d: f64,
    /// Squared distance to the second-nearest centroid (clamped ≥ 0).
    pub second_d: f64,
}

/// Per-engine cache of the norm decomposition: sample norms (plus, in f32
/// mode, the sample mirror) are computed once per dataset — keyed on the
/// matrix generation stamp and shape, dropped by
/// [`DistanceKernel::invalidate`] — and centroid norms once per
/// [`DistanceKernel::prepare`] call, i.e. once per centroid motion.
#[derive(Debug, Clone)]
pub struct DistanceKernel {
    precision: Precision,
    simd: SimdLevel,
    /// `(generation stamp, n, d)` of the sample matrix the cached norms
    /// (and the f32 mirror) belong to. The stamp is never reused, so a
    /// matching key proves the contents are the ones we prepared for.
    x_key: Option<((u64, u64), usize, usize)>,
    x_norms: Vec<f64>,
    c_norms: Vec<f64>,
    /// f32 sample mirror (F32 precision only; cached under `x_key`).
    x32: Vec<f32>,
    /// f32 centroid mirror (F32 precision only; refreshed per `prepare`).
    c32: Vec<f32>,
    /// How many times the sample-norm pass (the O(N·d) side of `prepare`)
    /// actually ran — the observable for "same-data reruns reuse the
    /// cache" regression tests.
    norm_builds: u64,
}

impl Default for DistanceKernel {
    fn default() -> Self {
        Self::new()
    }
}

impl DistanceKernel {
    /// Fresh `f64` kernel with runtime-detected SIMD dispatch.
    pub fn new() -> Self {
        Self::with_precision(Precision::F64)
    }

    /// Fresh kernel at the given storage precision, runtime-detected SIMD.
    pub fn with_precision(precision: Precision) -> Self {
        Self::with_options(precision, simd::detect())
    }

    /// Fully explicit construction — benches and tests use this to force
    /// the portable fallback. A requested [`SimdLevel::Avx2Fma`] is
    /// silently downgraded when the running CPU lacks AVX2+FMA, so a
    /// constructed kernel is always safe to run.
    pub fn with_options(precision: Precision, simd: SimdLevel) -> Self {
        let simd = match simd {
            SimdLevel::Avx2Fma if simd::detect() != SimdLevel::Avx2Fma => SimdLevel::Scalar,
            other => other,
        };
        Self {
            precision,
            simd,
            x_key: None,
            x_norms: Vec::new(),
            c_norms: Vec::new(),
            x32: Vec::new(),
            c32: Vec::new(),
            norm_builds: 0,
        }
    }

    /// Storage precision this kernel runs at.
    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// SIMD dispatch level resolved at construction.
    pub fn simd_level(&self) -> SimdLevel {
        self.simd
    }

    /// Refresh the cached norms for `(x, c)`. Sample norms — and the f32
    /// sample mirror in F32 mode — are recomputed only when `x` changed
    /// generation or shape (one parallel O(N·d) pass); centroid norms (and
    /// the f32 centroid mirror) are recomputed every call (O(K·d),
    /// negligible next to the sweep).
    pub fn prepare(&mut self, x: &DataMatrix, c: &DataMatrix, pool: &ThreadPool) {
        let key = (x.generation(), x.n(), x.d());
        if self.x_key != Some(key) {
            self.norm_builds += 1;
            let d = x.d();
            self.x_norms.clear();
            self.x_norms.resize(x.n(), 0.0);
            match self.precision {
                Precision::F64 => {
                    let simd = self.simd;
                    let norms = SyncSliceMut::new(&mut self.x_norms);
                    pool.parallel_for(x.n(), 512, |range| {
                        for i in range {
                            let row = x.row(i);
                            *norms.at(i) = f64::dot(simd, row, row);
                        }
                    });
                }
                Precision::F32 => {
                    self.x32.clear();
                    self.x32.resize(x.n() * d, 0.0);
                    x.write_f32_into(&mut self.x32);
                    let simd = self.simd;
                    let x32: &[f32] = &self.x32;
                    let norms = SyncSliceMut::new(&mut self.x_norms);
                    pool.parallel_for(x.n(), 512, |range| {
                        for i in range {
                            let row = &x32[i * d..(i + 1) * d];
                            *norms.at(i) = f32::dot(simd, row, row);
                        }
                    });
                }
            }
            self.x_key = Some(key);
        }
        self.c_norms.clear();
        self.c_norms.resize(c.n(), 0.0);
        match self.precision {
            Precision::F64 => {
                for j in 0..c.n() {
                    let row = c.row(j);
                    self.c_norms[j] = f64::dot(self.simd, row, row);
                }
            }
            Precision::F32 => {
                let d = c.d();
                self.c32.clear();
                self.c32.resize(c.n() * d, 0.0);
                c.write_f32_into(&mut self.c32);
                for j in 0..c.n() {
                    let row = &self.c32[j * d..(j + 1) * d];
                    self.c_norms[j] = f32::dot(self.simd, row, row);
                }
            }
        }
    }

    /// Drop the cached sample norms. Engines no longer call this from
    /// `reset` — the generation-stamp key already proves cache validity,
    /// so same-data reruns (a different `k`, a multi-k sweep, a warm
    /// re-clustering) skip the O(N·d) norm pass — but it remains for
    /// callers that want an explicit cold start.
    pub fn invalidate(&mut self) {
        self.x_key = None;
    }

    /// How many times the O(N·d) sample-norm pass has run over this
    /// kernel's lifetime. A warm same-data rerun must not grow this.
    pub fn norm_builds(&self) -> u64 {
        self.norm_builds
    }

    /// Centroid rows per cache tile: as many as fit the L1 budget, rounded
    /// to the register-block width, never below one block.
    fn centroid_tile(&self, d: usize) -> usize {
        let rows = CENTROID_TILE_BYTES / (self.precision.elem_bytes() * d.max(1));
        (rows.max(CENTROID_BLOCK) / CENTROID_BLOCK) * CENTROID_BLOCK
    }

    /// Fused (best, second-best) argmin over all centroids for every
    /// sample in `rows`, evaluated in sample tiles × centroid blocks.
    /// `emit(i, best2)` is called once per sample in ascending order.
    ///
    /// Requires a matching [`DistanceKernel::prepare`] call. Safe to call
    /// concurrently from pool lanes over disjoint ranges (`&self` only).
    pub fn argmin2_range(
        &self,
        x: &DataMatrix,
        c: &DataMatrix,
        rows: Range<usize>,
        mut emit: impl FnMut(usize, Best2),
    ) {
        debug_assert_eq!(self.x_norms.len(), x.n(), "prepare() not called for x");
        debug_assert_eq!(self.c_norms.len(), c.n(), "prepare() not called for c");
        match self.precision {
            Precision::F64 => self.argmin2_range_t::<f64>(
                x.as_slice(),
                c.as_slice(),
                x.d(),
                c.n(),
                rows,
                &mut emit,
            ),
            Precision::F32 => {
                debug_assert_eq!(self.x32.len(), x.n() * x.d(), "f32 mirror stale for x");
                debug_assert_eq!(self.c32.len(), c.n() * c.d(), "f32 mirror stale for c");
                self.argmin2_range_t::<f32>(&self.x32, &self.c32, x.d(), c.n(), rows, &mut emit)
            }
        }
    }

    /// The precision-generic tile sweep behind [`DistanceKernel::argmin2_range`].
    fn argmin2_range_t<T: Scalar>(
        &self,
        xdata: &[T],
        cdata: &[T],
        d: usize,
        k: usize,
        rows: Range<usize>,
        emit: &mut dyn FnMut(usize, Best2),
    ) {
        let ctile = self.centroid_tile(d);
        let mut start = rows.start;
        while start < rows.end {
            let tile = (rows.end - start).min(SAMPLE_TILE);
            // Running partials p = ‖c‖² − 2·x·c; the constant ‖x‖² is added
            // at emit time (it does not affect the argmin).
            let mut best = [0u32; SAMPLE_TILE];
            let mut best_p = [f64::INFINITY; SAMPLE_TILE];
            let mut second_p = [f64::INFINITY; SAMPLE_TILE];
            let mut cb = 0;
            while cb < k {
                let cend = (cb + ctile).min(k);
                for ti in 0..tile {
                    let i = start + ti;
                    scan_block(
                        self.simd,
                        &xdata[i * d..(i + 1) * d],
                        cdata,
                        d,
                        &self.c_norms,
                        cb,
                        cend,
                        &mut best[ti],
                        &mut best_p[ti],
                        &mut second_p[ti],
                    );
                }
                cb = cend;
            }
            for ti in 0..tile {
                let xn = self.x_norms[start + ti];
                emit(
                    start + ti,
                    Best2 {
                        best: best[ti],
                        best_d: (xn + best_p[ti]).max(0.0),
                        second_d: (xn + second_p[ti]).max(0.0),
                    },
                );
            }
            start += tile;
        }
    }

    /// Fused best/second-best for a single sample (the bound engines' full
    /// re-scan path).
    pub fn argmin2_row(&self, x: &DataMatrix, c: &DataMatrix, i: usize) -> Best2 {
        let mut out = Best2 { best: 0, best_d: f64::INFINITY, second_d: f64::INFINITY };
        self.argmin2_range(x, c, i..i + 1, |_, b| out = b);
        out
    }

    /// All `K` squared distances for sample `i` written into `out`
    /// (the dense initialization path of Elkan / Yinyang).
    pub fn dists_row(&self, x: &DataMatrix, c: &DataMatrix, i: usize, out: &mut [f64]) {
        debug_assert_eq!(out.len(), c.n());
        debug_assert_eq!(self.c_norms.len(), c.n(), "prepare() not called for c");
        match self.precision {
            Precision::F64 => {
                self.dists_row_t::<f64>(x.as_slice(), c.as_slice(), x.d(), c.n(), i, out)
            }
            Precision::F32 => self.dists_row_t::<f32>(&self.x32, &self.c32, x.d(), c.n(), i, out),
        }
    }

    fn dists_row_t<T: Scalar>(
        &self,
        xdata: &[T],
        cdata: &[T],
        d: usize,
        k: usize,
        i: usize,
        out: &mut [f64],
    ) {
        let row = &xdata[i * d..(i + 1) * d];
        let xn = self.x_norms[i];
        let mut j = 0;
        while j + CENTROID_BLOCK <= k {
            let dots = T::dot_x4(
                self.simd,
                row,
                &cdata[j * d..(j + 1) * d],
                &cdata[(j + 1) * d..(j + 2) * d],
                &cdata[(j + 2) * d..(j + 3) * d],
                &cdata[(j + 3) * d..(j + 4) * d],
            );
            for (lane, &dj) in dots.iter().enumerate() {
                out[j + lane] = (xn - 2.0 * dj + self.c_norms[j + lane]).max(0.0);
            }
            j += CENTROID_BLOCK;
        }
        while j < k {
            let dj = T::dot(self.simd, row, &cdata[j * d..(j + 1) * d]);
            out[j] = (xn - 2.0 * dj + self.c_norms[j]).max(0.0);
            j += 1;
        }
    }

    /// Single-pair squared distance via the cached norms (the sparse
    /// bound-tightening path).
    pub fn dist_sq(&self, x: &DataMatrix, c: &DataMatrix, i: usize, j: usize) -> f64 {
        let d = x.d();
        let dot = match self.precision {
            Precision::F64 => f64::dot(self.simd, x.row(i), c.row(j)),
            Precision::F32 => f32::dot(
                self.simd,
                &self.x32[i * d..(i + 1) * d],
                &self.c32[j * d..(j + 1) * d],
            ),
        };
        (self.x_norms[i] - 2.0 * dot + self.c_norms[j]).max(0.0)
    }
}

/// Scan centroids `[cb, cend)` for one sample, updating the running
/// best/second partials. Full blocks go through the 4-wide micro-kernel.
#[inline]
#[allow(clippy::too_many_arguments)]
fn scan_block<T: Scalar>(
    simd: SimdLevel,
    row: &[T],
    cdata: &[T],
    d: usize,
    c_norms: &[f64],
    cb: usize,
    cend: usize,
    best: &mut u32,
    best_p: &mut f64,
    second_p: &mut f64,
) {
    let mut j = cb;
    while j + CENTROID_BLOCK <= cend {
        let dots = T::dot_x4(
            simd,
            row,
            &cdata[j * d..(j + 1) * d],
            &cdata[(j + 1) * d..(j + 2) * d],
            &cdata[(j + 2) * d..(j + 3) * d],
            &cdata[(j + 3) * d..(j + 4) * d],
        );
        for (lane, &dj) in dots.iter().enumerate() {
            let p = c_norms[j + lane] - 2.0 * dj;
            update2(best, best_p, second_p, (j + lane) as u32, p);
        }
        j += CENTROID_BLOCK;
    }
    while j < cend {
        let p = c_norms[j] - 2.0 * T::dot(simd, row, &cdata[j * d..(j + 1) * d]);
        update2(best, best_p, second_p, j as u32, p);
        j += 1;
    }
}

/// Track the two smallest partials seen so far. Strict `<` keeps the
/// lowest centroid index on exact ties, matching the brute-force scan.
#[inline(always)]
fn update2(best: &mut u32, best_p: &mut f64, second_p: &mut f64, j: u32, p: f64) {
    if p < *best_p {
        *second_p = *best_p;
        *best_p = p;
        *best = j;
    } else if p < *second_p {
        *second_p = p;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::linalg;
    use crate::lloyd::brute_force_assign;
    use crate::rng::Pcg32;

    /// Exact distances for one sample, for cross-checking.
    fn exact_dists(x: &DataMatrix, c: &DataMatrix, i: usize) -> Vec<f64> {
        (0..c.n()).map(|j| linalg::dist_sq(x.row(i), c.row(j))).collect()
    }

    fn check_matches_brute(kernel: &mut DistanceKernel, x: &DataMatrix, c: &DataMatrix, ctx: &str) {
        let pool = ThreadPool::new(2);
        kernel.prepare(x, c, &pool);
        let expect = brute_force_assign(x, c);
        let k = c.n();
        let mut seen = 0usize;
        kernel.argmin2_range(x, c, 0..x.n(), |i, b| {
            seen += 1;
            let mut exact = exact_dists(x, c, i);
            // The kernel's pick must be distance-equal to the brute-force
            // pick (ids may differ on ties — see module docs).
            let got = exact[b.best as usize];
            let best = exact[expect[i] as usize];
            assert!((got - best).abs() < 1e-9, "{ctx}: sample {i}: {got} vs {best}");
            assert!((b.best_d - got).abs() < 1e-9, "{ctx}: sample {i} best_d");
            assert!(b.best_d >= 0.0 && b.second_d >= 0.0, "{ctx}: negative distance");
            exact.sort_by(|a, b| a.partial_cmp(b).unwrap());
            if k >= 2 {
                assert!(
                    (b.second_d - exact[1]).abs() < 1e-9,
                    "{ctx}: sample {i} second_d {} vs {}",
                    b.second_d,
                    exact[1]
                );
            } else {
                assert!(b.second_d.is_infinite(), "{ctx}: K=1 second bound");
            }
            // dists_row and dist_sq agree with the exact form too.
            let mut dense = vec![0.0; k];
            kernel.dists_row(x, c, i, &mut dense);
            for j in 0..k {
                let e = linalg::dist_sq(x.row(i), c.row(j));
                assert!((dense[j] - e).abs() < 1e-9, "{ctx}: dists_row[{i}][{j}]");
            }
            let one = kernel.dist_sq(x, c, i, b.best as usize);
            assert!((one - got).abs() < 1e-9, "{ctx}: dist_sq one-pair");
        });
        assert_eq!(seen, x.n(), "{ctx}: emit must cover every sample once");
    }

    /// Grid problem with duplicate points, tie distances, and centroids
    /// sitting exactly on samples (so clamping at zero is exercised).
    fn grid_problem(rng: &mut Pcg32, d: usize, k: usize) -> (DataMatrix, DataMatrix) {
        let n = 160.max(2 * k);
        let blobs = k.clamp(1, 8);
        let mut x = synth::gaussian_blobs(rng, n, d, blobs, 2.0, 0.3);
        // Duplicate points: rows 1 and 2 become copies of row 0.
        let r0 = x.row(0).to_vec();
        x.row_mut(1).copy_from_slice(&r0);
        x.row_mut(2).copy_from_slice(&r0);
        // Centroids sit exactly on samples (zero distances).
        let idx: Vec<usize> = (0..k).map(|j| (j * 7) % n).collect();
        let mut c = x.gather_rows(&idx);
        if k >= 2 {
            // Tie distances: centroid 1 duplicates centroid 0.
            let c0 = c.row(0).to_vec();
            c.row_mut(1).copy_from_slice(&c0);
        }
        (x, c)
    }

    /// Property test: tiled/norm-decomposed assignment matches brute force
    /// across the full d × K grid — for the auto-dispatched f64 kernel AND
    /// the forced-scalar fallback (the runtime-dispatch degradation path).
    #[test]
    fn property_matches_brute_force_across_shapes() {
        let mut rng = Pcg32::seed_from_u64(0xD15E);
        for &d in &[1usize, 2, 3, 7, 8, 16, 100] {
            for &k in &[1usize, 7, 64] {
                let (x, c) = grid_problem(&mut rng, d, k);
                let mut auto = DistanceKernel::new();
                check_matches_brute(&mut auto, &x, &c, &format!("auto d={d} k={k}"));
                let mut scalar = DistanceKernel::with_options(Precision::F64, SimdLevel::Scalar);
                check_matches_brute(&mut scalar, &x, &c, &format!("scalar d={d} k={k}"));
            }
        }
    }

    /// Satellite parity property: scalar-f64, simd-f64 and simd-f32 agree
    /// on best/second-best *distances* (not assignment ids — ties resolve
    /// freely) across the d × K grid, for raw and pre-centered data.
    #[test]
    fn parity_scalar_f64_simd_f64_simd_f32() {
        let pool = ThreadPool::new(2);
        let mut rng = Pcg32::seed_from_u64(0xF32D);
        for &d in &[1usize, 2, 5, 8, 9, 16, 33] {
            for &k in &[1usize, 5, 64] {
                for &centered in &[false, true] {
                    let (mut x, mut c) = grid_problem(&mut rng, d, k);
                    if centered {
                        // Center the samples and move the (sample-derived)
                        // centroids into the same frame.
                        let mean = crate::data::center(&mut x);
                        for j in 0..c.n() {
                            for (v, &m) in c.row_mut(j).iter_mut().zip(&mean) {
                                *v -= m;
                            }
                        }
                    } else {
                        // Push the data off-origin — the cancellation regime
                        // pre-centering exists to fix.
                        for i in 0..x.n() {
                            for v in x.row_mut(i).iter_mut() {
                                *v += 25.0;
                            }
                        }
                        for j in 0..c.n() {
                            for v in c.row_mut(j).iter_mut() {
                                *v += 25.0;
                            }
                        }
                    }
                    let ctx = format!("d={d} k={k} centered={centered}");

                    let mut scalar64 =
                        DistanceKernel::with_options(Precision::F64, SimdLevel::Scalar);
                    let mut simd64 = DistanceKernel::with_precision(Precision::F64);
                    let mut simd32 = DistanceKernel::with_precision(Precision::F32);
                    scalar64.prepare(&x, &c, &pool);
                    simd64.prepare(&x, &c, &pool);
                    simd32.prepare(&x, &c, &pool);

                    let collect = |kern: &DistanceKernel| {
                        let mut out = Vec::with_capacity(x.n());
                        kern.argmin2_range(&x, &c, 0..x.n(), |_, b| out.push(b));
                        out
                    };
                    let a = collect(&scalar64);
                    let b = collect(&simd64);
                    let f = collect(&simd32);

                    // f32 error envelope: ε₃₂ · (‖x‖² + ‖c‖²) per the module
                    // docs, padded for accumulation order.
                    let max_xn =
                        (0..x.n()).map(|i| linalg::norm_sq(x.row(i))).fold(0.0f64, f64::max);
                    let max_cn =
                        (0..c.n()).map(|j| linalg::norm_sq(c.row(j))).fold(0.0f64, f64::max);
                    let tol32 = 1e-5 * (1.0 + max_xn + max_cn);

                    for i in 0..x.n() {
                        assert!(
                            (a[i].best_d - b[i].best_d).abs() < 1e-9,
                            "{ctx}: sample {i} scalar/simd f64 best_d {} vs {}",
                            a[i].best_d,
                            b[i].best_d
                        );
                        assert!(
                            (f[i].best_d - a[i].best_d).abs() < tol32,
                            "{ctx}: sample {i} f32 best_d {} vs {} (tol {tol32})",
                            f[i].best_d,
                            a[i].best_d
                        );
                        if c.n() >= 2 {
                            assert!(
                                (a[i].second_d - b[i].second_d).abs() < 1e-9,
                                "{ctx}: sample {i} scalar/simd f64 second_d"
                            );
                            assert!(
                                (f[i].second_d - a[i].second_d).abs() < tol32,
                                "{ctx}: sample {i} f32 second_d {} vs {} (tol {tol32})",
                                f[i].second_d,
                                a[i].second_d
                            );
                        } else {
                            assert!(a[i].second_d.is_infinite());
                            assert!(b[i].second_d.is_infinite());
                            assert!(f[i].second_d.is_infinite());
                        }
                    }
                }
            }
        }
    }

    /// Runtime dispatch degrades cleanly: forcing AVX2 on a CPU without it
    /// must yield a working scalar kernel, and the forced-scalar kernel is
    /// always available and correct (checked above). On non-x86_64 the
    /// detector itself can only answer `Scalar` (cfg-asserted in `simd`).
    #[test]
    fn forced_avx_downgrades_when_unsupported() {
        let kern = DistanceKernel::with_options(Precision::F64, SimdLevel::Avx2Fma);
        if simd::detect() == SimdLevel::Scalar {
            assert_eq!(kern.simd_level(), SimdLevel::Scalar);
        } else {
            assert_eq!(kern.simd_level(), SimdLevel::Avx2Fma);
        }
        let scalar = DistanceKernel::with_options(Precision::F32, SimdLevel::Scalar);
        assert_eq!(scalar.simd_level(), SimdLevel::Scalar);
        assert_eq!(scalar.precision(), Precision::F32);
    }

    #[test]
    fn prepare_tracks_centroid_motion() {
        let mut rng = Pcg32::seed_from_u64(7);
        let x = synth::gaussian_blobs(&mut rng, 200, 5, 3, 2.0, 0.4);
        let mut c = x.gather_rows(&[0, 50, 100]);
        let pool = ThreadPool::new(1);
        let mut kernel = DistanceKernel::new();
        for round in 0..4 {
            kernel.prepare(&x, &c, &pool);
            check_round(&kernel, &x, &c, round);
            for j in 0..c.n() {
                for t in 0..c.d() {
                    c[(j, t)] += 0.1 * (j + t + 1) as f64;
                }
            }
        }

        fn check_round(kernel: &DistanceKernel, x: &DataMatrix, c: &DataMatrix, round: usize) {
            for i in (0..x.n()).step_by(17) {
                for j in 0..c.n() {
                    let e = linalg::dist_sq(x.row(i), c.row(j));
                    let g = kernel.dist_sq(x, c, i, j);
                    assert!((g - e).abs() < 1e-9, "round {round} pair ({i},{j})");
                }
            }
        }
    }

    #[test]
    fn invalidate_recomputes_sample_norms() {
        let pool = ThreadPool::new(1);
        let mut kernel = DistanceKernel::new();
        let x1 = DataMatrix::from_rows(&[&[1.0, 0.0], &[0.0, 2.0]]);
        let c = DataMatrix::from_rows(&[&[0.0, 0.0]]);
        kernel.prepare(&x1, &c, &pool);
        assert!((kernel.dist_sq(&x1, &c, 1, 0) - 4.0).abs() < 1e-12);
        kernel.invalidate();
        let x2 = DataMatrix::from_rows(&[&[3.0, 0.0], &[0.0, 5.0]]);
        kernel.prepare(&x2, &c, &pool);
        assert!((kernel.dist_sq(&x2, &c, 1, 0) - 25.0).abs() < 1e-12);
    }

    /// Satellite regression: in-place mutation of the sample matrix (same
    /// buffer address, same shape) must refresh the norm cache. The old
    /// `(buffer ptr, n, d)` key silently reused stale norms here; the
    /// generation stamp cannot.
    #[test]
    fn mutated_matrix_refreshes_norm_cache() {
        let pool = ThreadPool::new(1);
        for precision in [Precision::F64, Precision::F32] {
            let mut kernel = DistanceKernel::with_precision(precision);
            let mut x = DataMatrix::from_rows(&[&[1.0, 0.0], &[0.0, 2.0]]);
            let c = DataMatrix::from_rows(&[&[0.0, 0.0]]);
            kernel.prepare(&x, &c, &pool);
            assert!((kernel.dist_sq(&x, &c, 1, 0) - 4.0).abs() < 1e-6);
            // Same allocation, same shape, new contents — no invalidate().
            x.row_mut(1)[1] = 5.0;
            kernel.prepare(&x, &c, &pool);
            assert!(
                (kernel.dist_sq(&x, &c, 1, 0) - 25.0).abs() < 1e-6,
                "{}: stale norm cache survived an in-place mutation",
                precision.name()
            );
        }
    }

    #[test]
    fn precision_parse_roundtrip() {
        for p in [Precision::F64, Precision::F32] {
            assert_eq!(Precision::parse(p.name()), Some(p));
        }
        assert_eq!(Precision::parse("double"), Some(Precision::F64));
        assert_eq!(Precision::parse("single"), Some(Precision::F32));
        assert_eq!(Precision::parse("f16"), None);
        assert_eq!(Precision::default(), Precision::F64);
    }

    /// The f32 kernel path end-to-end: matches brute force within the f32
    /// envelope on centered data (the configuration the CLI sets up).
    #[test]
    fn f32_kernel_close_to_exact_on_centered_data() {
        let pool = ThreadPool::new(2);
        let mut rng = Pcg32::seed_from_u64(0xCE17);
        let mut x = synth::gaussian_blobs(&mut rng, 400, 12, 6, 2.0, 0.3);
        let _ = crate::data::center(&mut x);
        let c = x.gather_rows(&[0, 64, 128, 192, 256, 320]);
        let mut kernel = DistanceKernel::with_precision(Precision::F32);
        kernel.prepare(&x, &c, &pool);
        kernel.argmin2_range(&x, &c, 0..x.n(), |i, b| {
            let exact = exact_dists(&x, &c, i);
            let best = exact.iter().cloned().fold(f64::INFINITY, f64::min);
            assert!(
                (b.best_d - best).abs() < 1e-3,
                "sample {i}: f32 best_d {} vs exact {best}",
                b.best_d
            );
        });
    }
}
