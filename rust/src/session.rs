//! [`ClusterSession`] — an open clustering job with a warm
//! [`Workspace`](crate::kmeans::Workspace).
//!
//! `ClusterSession::open(request)` replaces the panicking `Solver::new`
//! construction path: it builds the engine fallibly (typed
//! [`ClusterError`]s, including the PJRT artifact case), owns the thread
//! pool and all solver scratch, and materializes + seeds the request's
//! data lazily, exactly once. Repeated [`ClusterSession::run`]s on the
//! same session therefore reuse the engine's bound state capacity, the
//! kernel norm caches, the Anderson history columns and the centroid /
//! assignment scratch across calls; returning finished reports through
//! [`ClusterSession::recycle`] closes the loop so steady-state reruns
//! leave the solver's own buffers untouched by the allocator.

use crate::config::EngineKind;
use crate::data::chunks::{self, ChunkSource, InMemoryChunks, MmapShardSource};
use crate::data::DataMatrix;
use crate::error::ClusterError;
use crate::init::seed_centroids;
use crate::kmeans::{RunReport, Solver, Workspace};
use crate::observe::{CancelToken, NoopObserver, Observer};
use crate::request::{ClusterRequest, DataSource, InitSpec};
use crate::rng::Pcg32;
use crate::stream::prefetch::PrefetchSource;
use std::sync::Arc;

/// An open clustering job: request + warm workspace + cached data/seeding.
pub struct ClusterSession {
    request: ClusterRequest,
    solver: Solver,
    data: Option<Arc<DataMatrix>>,
    c0: Option<DataMatrix>,
    no_cancel: CancelToken,
}

impl ClusterSession {
    /// Open a session for `request`, constructing a fresh [`Workspace`]
    /// (fallible: the PJRT engine loads artifacts here).
    pub fn open(request: ClusterRequest) -> Result<Self, ClusterError> {
        let ws = Workspace::open(&request.workspace_spec())?;
        Self::with_workspace(request, ws)
    }

    /// Open a session over an existing workspace (warm-start: the
    /// coordinator hands each worker's workspace from job to job). The
    /// workspace must match the request's [`ClusterRequest::workspace_spec`].
    pub fn with_workspace(request: ClusterRequest, ws: Workspace) -> Result<Self, ClusterError> {
        if !ws.matches(&request.workspace_spec()) {
            return Err(ClusterError::Engine {
                engine: ws.engine_name(),
                reason: format!(
                    "workspace spec {:?} does not match the request's {:?}",
                    ws.spec(),
                    request.workspace_spec()
                ),
            });
        }
        let solver = Solver::from_workspace(request.solver_config(), ws);
        Ok(Self { request, solver, data: None, c0: None, no_cancel: CancelToken::new() })
    }

    /// The request this session serves.
    pub fn request(&self) -> &ClusterRequest {
        &self.request
    }

    /// The workspace backing this session.
    pub fn workspace(&self) -> &Workspace {
        self.solver.workspace()
    }

    /// Materialized samples (materializing them now if needed).
    pub fn data(&mut self) -> Result<&Arc<DataMatrix>, ClusterError> {
        self.ensure_data()?;
        Ok(self.data.as_ref().expect("ensure_data just set it"))
    }

    /// Run the request to convergence (or its budgets).
    pub fn run(&mut self) -> Result<RunReport, ClusterError> {
        let token = self.no_cancel.clone();
        self.run_with(&mut NoopObserver, &token)
    }

    /// [`ClusterSession::run`] with a per-iteration [`Observer`] and a
    /// [`CancelToken`]. A token tripped before the run starts returns
    /// [`ClusterError::Cancelled`]; one tripped mid-run stops the solver at
    /// the next iteration boundary and the report comes back with
    /// [`RunReport::cancelled`] set (partial state preserved).
    pub fn run_with(
        &mut self,
        observer: &mut dyn Observer,
        cancel: &CancelToken,
    ) -> Result<RunReport, ClusterError> {
        if cancel.is_cancelled() {
            return Err(ClusterError::Cancelled);
        }
        if self.request.engine() == EngineKind::MiniBatch {
            return self.run_minibatch(observer, cancel);
        }
        self.ensure_data()?;
        let x = self.data.as_ref().expect("ensure_data just set it");
        let c0 = self.c0.as_ref().expect("ensure_data just set it");
        let mut report = self.solver.run_observed(x, c0, observer, cancel);
        if let Some(e) = report.error.take() {
            // A mid-iteration failure (today only the fault-injection
            // harness produces one on the full-batch path): recycle the
            // partial report's buffers and surface the typed error.
            self.solver.workspace_mut().recycle(report);
            return Err(e);
        }
        Ok(report)
    }

    /// The streaming path (`EngineKind::MiniBatch`): build a
    /// [`ChunkSource`] for the request's data — shards stream out-of-core
    /// through [`MmapShardSource`]; every other source is RAM-resident by
    /// nature and streams its materialized matrix — and run the
    /// Anderson-accelerated mini-batch solver on this session's warm
    /// workspace. The report counts *epochs* in `iterations` and carries
    /// no per-sample assignment (a streamed dataset is never resident).
    fn run_minibatch(
        &mut self,
        observer: &mut dyn Observer,
        cancel: &CancelToken,
    ) -> Result<RunReport, ClusterError> {
        let cfg = self.request.minibatch_config();
        // Extract the owned path first: the seeding helpers below need
        // `&mut self`, which cannot coexist with a borrow of the source.
        let shard_path = match self.request.source() {
            DataSource::Shard(path) => Some(path.clone()),
            _ => None,
        };
        let mut source: Box<dyn ChunkSource + Send> = match shard_path {
            Some(path) => {
                // One mapping serves both the seeding prefix and the run
                // (`MmapShardSource::open` is typed: IO and format faults
                // arrive as `ClusterError::Data`).
                let mut shard = MmapShardSource::open(&path)?;
                self.ensure_shard_seed(&mut shard)?;
                shard.rewind();
                Box::new(shard)
            }
            None => {
                self.ensure_data()?;
                let x = self.data.as_ref().expect("ensure_data just set it");
                Box::new(InMemoryChunks::new(Arc::clone(x)))
            }
        };
        let c0 = self.c0.as_ref().expect("seeding ran above");
        if !cfg.prefetch {
            return crate::stream::run_on_workspace(
                &cfg,
                self.solver.workspace_mut(),
                source.as_mut(),
                c0,
                observer,
                cancel,
            );
        }
        // Prefetch on: wrap the source behind the pipeline thread. The
        // two chunk buffers come from (and go back to) the workspace
        // scratch, so warm prefetched reruns allocate no chunk storage.
        // Wrapping happens *after* seeding: the seeding prefix reads with
        // varying chunk sizes, while the pipeline speculates at the
        // engine's fixed chunk cadence.
        let ws = self.solver.workspace_mut();
        let chunk_rows = cfg.chunk_size.max(1);
        let d = source.d();
        let b0 = ws.scratch.take_mat(chunk_rows, d);
        let b1 = ws.scratch.take_mat(chunk_rows, d);
        // With pinning on, park the prefetcher on the first CPU past the
        // sweep lanes (lanes pin to `lane % cores`, lane < threads) so it
        // never contends with a pinned sweep lane for a core.
        let cores = std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
        let pin_cpu = cfg.pin_threads.then(|| ws.pool.threads() % cores);
        let mut pf = PrefetchSource::with_buffers(source, chunk_rows, b0, b1, pin_cpu);
        let result = crate::stream::run_on_workspace(&cfg, ws, &mut pf, c0, observer, cancel);
        // Tear down and recycle the pipeline buffers regardless of the
        // outcome — an error must not strip the warm scratch.
        let (_inner, bufs) = pf.shutdown();
        let ws = self.solver.workspace_mut();
        for buf in bufs {
            ws.scratch.put_mat(buf);
        }
        result
    }

    /// Seed the initial centroids for a shard-backed streaming run from a
    /// bounded prefix of the stream (the full shard is never resident),
    /// validating shape against the shard header. Runs once; later runs
    /// of the session reuse the cached centroids verbatim. The caller
    /// rewinds the shard afterwards.
    fn ensure_shard_seed(&mut self, shard: &mut MmapShardSource) -> Result<(), ClusterError> {
        if self.c0.is_some() {
            return Ok(());
        }
        let k = self.request.k();
        if k > shard.n() {
            return Err(ClusterError::invalid(
                "k",
                format!("k={k} exceeds the shard's sample count {}", shard.n()),
            ));
        }
        let c0 = match self.request.init() {
            InitSpec::Method(method) => {
                let chunk = self.request.chunk_size();
                let cap = chunk.max(16 * k).min(shard.n());
                let buf = chunks::collect_source(shard, chunk, cap)?;
                let mut rng = Pcg32::seed_from_u64(self.request.seed());
                seed_centroids(&buf, k, *method, &mut rng)
            }
            InitSpec::Centroids(c0) => {
                if c0.d() != shard.d() {
                    return Err(ClusterError::invalid(
                        "init",
                        format!(
                            "initial centroids are {}-dimensional but the shard is \
                             {}-dimensional",
                            c0.d(),
                            shard.d()
                        ),
                    ));
                }
                DataMatrix::clone(c0)
            }
            InitSpec::WarmStart { registry, model } => {
                warm_start_centroids(registry, model, k, shard.d())?
            }
        };
        self.c0 = Some(c0);
        Ok(())
    }

    /// Return a finished report's buffers to the workspace pool so the next
    /// same-shape run's outputs are allocation-free too.
    pub fn recycle(&mut self, report: RunReport) {
        self.solver.workspace_mut().recycle(report);
    }

    /// Release the warm workspace (for reuse by the next session).
    pub fn into_workspace(self) -> Workspace {
        self.solver.into_workspace()
    }

    /// Materialize the data source and the initial centroids once; the
    /// request is immutable, so both are reused verbatim by later runs.
    fn ensure_data(&mut self) -> Result<(), ClusterError> {
        if self.data.is_some() {
            return Ok(());
        }
        let x = self.request.source().materialize()?;
        let k = self.request.k();
        let label = self.request.source().label();
        crate::request::validate_against_data(&x, k, self.request.init(), &label)?;
        let c0 = match self.request.init() {
            InitSpec::Method(method) => {
                let mut rng = Pcg32::seed_from_u64(self.request.seed());
                seed_centroids(&x, k, *method, &mut rng)
            }
            InitSpec::Centroids(c0) => DataMatrix::clone(c0),
            InitSpec::WarmStart { registry, model } => {
                warm_start_centroids(registry, model, k, x.d())?
            }
        };
        self.data = Some(x);
        self.c0 = Some(c0);
        Ok(())
    }
}

/// Load warm-start centroids from a registered model, validating its shape
/// against the request (typed errors: a mismatched model is a caller bug,
/// never a retry candidate).
fn warm_start_centroids(
    registry: &std::path::Path,
    model: &str,
    k: usize,
    d: usize,
) -> Result<DataMatrix, ClusterError> {
    let record = crate::registry::ModelRegistry::open(registry)?.load(model)?;
    if record.centroids.n() != k {
        return Err(ClusterError::invalid(
            "init",
            format!(
                "model '{model}' has k={} but the request asks for k={k}",
                record.centroids.n()
            ),
        ));
    }
    if record.centroids.d() != d {
        return Err(ClusterError::invalid(
            "init",
            format!(
                "model '{model}' is {}-dimensional but the data is {d}-dimensional",
                record.centroids.d()
            ),
        ));
    }
    Ok(record.centroids)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Acceleration, EngineKind};
    use crate::data::synth;
    use crate::observe::{EarlyStop, ObserverControl, TraceObserver};
    use crate::rng::Pcg32;

    fn blob_data(seed: u64, n: usize) -> Arc<DataMatrix> {
        let mut rng = Pcg32::seed_from_u64(seed);
        Arc::new(synth::gaussian_blobs(&mut rng, n, 4, 6, 2.0, 0.4))
    }

    fn request(data: Arc<DataMatrix>) -> ClusterRequest {
        ClusterRequest::builder()
            .inline(data)
            .k(6)
            .threads(1)
            .seed(7)
            .build()
            .expect("valid request")
    }

    #[test]
    fn session_runs_and_reruns_identically() {
        let data = blob_data(1, 1200);
        let mut session = ClusterSession::open(request(data)).unwrap();
        let r1 = session.run().unwrap();
        assert!(r1.converged);
        let it1 = r1.iterations;
        let e1 = r1.energy;
        session.recycle(r1);
        let r2 = session.run().unwrap();
        assert_eq!(r2.iterations, it1, "cached data + seeding: identical reruns");
        assert_eq!(r2.energy.to_bits(), e1.to_bits());
        assert!(
            !session.workspace().last_run_rebuilt_scratch(),
            "second run must reuse the workspace"
        );
    }

    #[test]
    fn run_time_shape_check_is_typed() {
        // k fits the builder check only for inline sources; a registry
        // source defers to run time.
        let req = ClusterRequest::builder()
            .registry("Birch", 0.0001)
            .k(100_000)
            .threads(1)
            .build()
            .unwrap();
        let mut session = ClusterSession::open(req).unwrap();
        match session.run() {
            Err(ClusterError::InvalidRequest { field: "k", .. }) => {}
            other => panic!("expected a typed k error, got ok={}", other.is_ok()),
        }
    }

    #[test]
    fn explicit_centroids_drive_the_run() {
        let data = blob_data(2, 600);
        let c0 = Arc::new(data.gather_rows(&[0, 100, 200, 300, 400, 500]));
        let req = ClusterRequest::builder()
            .inline(Arc::clone(&data))
            .k(6)
            .initial_centroids(c0)
            .threads(1)
            .build()
            .unwrap();
        let mut session = ClusterSession::open(req).unwrap();
        let report = session.run().unwrap();
        assert!(report.converged);
        assert_eq!(report.centroids.n(), 6);
    }

    #[test]
    fn observer_sees_iterations_and_early_stop_works() {
        // A slow-converging manifold problem: plenty of iterations with
        // small energy decreases for the early-stop rule to act on.
        let mut rng = Pcg32::seed_from_u64(31);
        let data = Arc::new(synth::noisy_curve(&mut rng, 2500, 3, 0.3));
        let req = ClusterRequest::builder()
            .inline(Arc::clone(&data))
            .k(8)
            .threads(1)
            .seed(5)
            .build()
            .unwrap();
        let mut session = ClusterSession::open(req.clone()).unwrap();
        let mut trace = TraceObserver::new();
        let token = CancelToken::new();
        let full = session.run_with(&mut trace, &token).unwrap();
        assert_eq!(trace.records().len(), full.iterations);
        assert!(trace.records().iter().all(|r| r.energy.is_finite()));
        assert!(full.iterations > 3, "need a multi-iteration run for the stop test");
        // An aggressive early-stop observer ends a fresh session sooner.
        let mut session2 = ClusterSession::open(req).unwrap();
        let mut stopper = EarlyStop::new(0.5, 1);
        let stopped = session2.run_with(&mut stopper, &token).unwrap();
        assert!(stopper.fired());
        assert!(stopped.stopped_early);
        assert!(stopped.iterations < full.iterations);
    }

    #[test]
    fn cancel_mid_run_stops_within_one_iteration() {
        // The observer trips the token after iteration 3; the solver must
        // notice at the next iteration boundary, so the report carries
        // exactly 3 productive iterations.
        use crate::observe::IterationInfo;
        struct CancelAt {
            at: usize,
            token: CancelToken,
        }
        impl Observer for CancelAt {
            fn on_iteration(&mut self, info: &IterationInfo<'_>) -> ObserverControl {
                if info.iteration == self.at {
                    self.token.cancel();
                }
                ObserverControl::Continue
            }
        }
        // A poorly separated problem that needs well over 3 iterations.
        let mut rng = Pcg32::seed_from_u64(9);
        let data = Arc::new(synth::noisy_curve(&mut rng, 3000, 3, 0.3));
        let req = ClusterRequest::builder()
            .inline(Arc::clone(&data))
            .k(10)
            .threads(1)
            .seed(11)
            .build()
            .unwrap();
        let mut session = ClusterSession::open(req.clone()).unwrap();
        let baseline = session.run().unwrap();
        assert!(baseline.iterations > 5, "need a long run for this test");

        let token = CancelToken::new();
        let mut observer = CancelAt { at: 3, token: token.clone() };
        let mut session = ClusterSession::open(req).unwrap();
        let report = session.run_with(&mut observer, &token).unwrap();
        assert!(report.cancelled);
        assert!(!report.converged);
        assert_eq!(report.iterations, 3, "cancel must land within one iteration");
        assert_eq!(report.assignment.len(), data.n(), "partial state stays consistent");
        assert!(report.energy.is_finite());
    }

    #[test]
    fn pre_cancelled_token_short_circuits() {
        let data = blob_data(4, 400);
        let mut session = ClusterSession::open(request(data)).unwrap();
        let token = CancelToken::new();
        token.cancel();
        match session.run_with(&mut NoopObserver, &token) {
            Err(ClusterError::Cancelled) => {}
            other => panic!("expected Cancelled, got ok={}", other.is_ok()),
        }
    }

    #[test]
    fn checkpointed_session_resumes_after_interrupt() {
        use crate::persist::CheckpointPolicy;
        let dir = std::env::temp_dir().join("aakm_session_tests/resume");
        let _ = std::fs::remove_dir_all(&dir);
        let data = blob_data(6, 900);
        let mut session = ClusterSession::open(request(Arc::clone(&data))).unwrap();
        let full = session.run().unwrap();
        assert!(full.converged);
        let cut = full.iterations / 2;
        assert!(cut >= 1, "need a multi-iteration run for the resume test");

        let make = |iters: usize| {
            ClusterRequest::builder()
                .inline(Arc::clone(&data))
                .k(6)
                .threads(1)
                .seed(7)
                .max_iters(iters)
                .checkpoint(CheckpointPolicy::new(&dir, 1))
                .build()
                .unwrap()
        };
        let mut first = ClusterSession::open(make(cut)).unwrap();
        let r1 = first.run().unwrap();
        assert!(!r1.converged, "the capped run must stop early");
        let mut resumed = ClusterSession::open(make(5000)).unwrap();
        let r2 = resumed.run().unwrap();
        assert!(r2.converged);
        assert_eq!(r2.iterations, full.iterations, "resume continues the trajectory");
        assert_eq!(r2.energy.to_bits(), full.energy.to_bits(), "bit-identical resume");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn all_engine_kinds_flow_through_the_builder() {
        let data = blob_data(5, 500);
        for engine in [
            EngineKind::Naive,
            EngineKind::Hamerly,
            EngineKind::Elkan,
            EngineKind::Yinyang,
        ] {
            let req = ClusterRequest::builder()
                .inline(Arc::clone(&data))
                .k(5)
                .engine(engine)
                .accel(Acceleration::DynamicM(2))
                .threads(1)
                .build()
                .unwrap();
            let mut session = ClusterSession::open(req).unwrap();
            let report = session.run().unwrap();
            assert!(report.converged, "{}", engine.name());
        }
        // PJRT is constructible through the same builder; without
        // artifacts it fails with a typed error instead of panicking.
        let req = ClusterRequest::builder()
            .inline(data)
            .k(5)
            .engine(EngineKind::Pjrt)
            .artifact_dir("/definitely/not/a/real/artifact/dir")
            .build()
            .unwrap();
        match ClusterSession::open(req) {
            Ok(_) => panic!("bogus artifact dir must not open"),
            Err(ClusterError::Engine { engine, .. }) => assert_eq!(engine, "pjrt"),
            Err(other) => panic!("expected an engine error, got {other}"),
        }
    }
}
