//! The shared safeguarded-Anderson fixed-point driver.
//!
//! The paper describes *one* scheme — Anderson extrapolation of a
//! monotone fixed-point map, guarded by the map's own merit energy, with
//! the dynamic-`m` trust-region rule — yet the repo grew three hand-rolled
//! copies of that loop (the full-batch accelerated solver, the plain Lloyd
//! baseline, and the streaming mini-batch epoch loop). This module is the
//! single audited implementation: [`FixedPointDriver`] owns the iteration
//! loop — history management through
//! [`AndersonAccelerator`](crate::anderson::AndersonAccelerator) and
//! [`MController`](crate::anderson::MController), the energy-guarded
//! accept/reject decision, restart-after-rejections, per-iteration trace
//! recording, [`Observer`] emission and cancel/time-budget bookkeeping —
//! parameterized over a small [`Step`] trait that supplies the map
//! application itself.
//!
//! Two guard disciplines cover every solver in the crate
//! ([`GuardMode`]):
//!
//! * **Deferred** (Algorithm 1, the full-batch solver): a proposal's
//!   energy is measured by the *next* iteration's fused assign+update
//!   pass, so the guard costs nothing extra; a rejected proposal reverts
//!   to the retained plain iterate (and the assignment engine rolls its
//!   bound state back to the pre-jump checkpoint).
//! * **Immediate** (the streaming epoch loop): one application of the map
//!   is a whole pass over the data, far too expensive to spend on an
//!   unguarded extrapolation, so the candidate's energy is measured by a
//!   dedicated checkpoint pass and the plain iterate is kept on
//!   non-decrease. Repeated rejections restart the Anderson history
//!   (epoch-level residuals are noisy; a stale history that keeps
//!   proposing uphill is worse than starting fresh).
//!
//! A new solver shape plugs in by implementing [`Step`]: provide the map
//! application ([`Step::advance`]), the revert/measure primitives for the
//! guard discipline it uses, and the driver contributes the entire
//! safeguarded-AA superstructure — which is how the three existing loops
//! ([`crate::kmeans::Solver`]'s two paths and
//! [`crate::stream::MiniBatchSolver`]) are built.

use crate::anderson::{AndersonAccelerator, MController};
use crate::config::Acceleration;
use crate::data::DataMatrix;
use crate::error::ClusterError;
use crate::metrics::{PhaseTimer, Stopwatch};
use crate::observe::{CancelToken, IterationInfo, Observer, ObserverControl};
use crate::persist::DriverSnap;
use std::time::Duration;

/// The run's interruption sources, bundled: wall-clock budget plus the
/// cooperative [`CancelToken`]. Steps and the driver consult the same
/// value, so "what counts as interrupted" cannot drift between loops.
#[derive(Clone, Copy)]
pub struct Budget<'a> {
    sw: &'a Stopwatch,
    limit: Option<Duration>,
    cancel: &'a CancelToken,
}

impl<'a> Budget<'a> {
    /// Bundle a running stopwatch, an optional wall-clock limit and a
    /// cancel token.
    pub fn new(sw: &'a Stopwatch, limit: Option<Duration>, cancel: &'a CancelToken) -> Self {
        Self { sw, limit, cancel }
    }

    /// `Some(cancelled)` when the run must stop — `true` for an explicit
    /// cancellation, `false` for an exhausted time budget — `None` to
    /// keep iterating. Cancellation wins when both apply.
    pub fn interrupted(&self) -> Option<bool> {
        if self.cancel.is_cancelled() {
            return Some(true);
        }
        if self.limit.is_some_and(|l| self.sw.elapsed() >= l) {
            return Some(false);
        }
        None
    }

    /// Whether the cancel token has tripped (used to attribute an
    /// interruption observed elsewhere, e.g. inside a checkpoint pass).
    pub fn is_cancelled(&self) -> bool {
        self.cancel.is_cancelled()
    }
}

/// Outcome of one application of the fixed-point map ([`Step::advance`]).
pub enum Advance {
    /// The map was applied; the merit energy of the resulting iterate is
    /// attached (`None` only for un-accelerated runs that were not asked
    /// to measure it).
    Evaluated(Option<f64>),
    /// The map's own convergence criterion fired (same assignment twice
    /// for the full-batch solvers; an empty source for the epoch step).
    Converged,
    /// Deferred-guard only: an accelerated iterate reproduced the
    /// previous assignment. The step reverted to the plain iterate and
    /// rolled the engine back — re-run the check without counting an
    /// iteration, per the paper's "fall-back iterate" convergence
    /// narrative.
    RetryPlain,
    /// The budget tripped at a step-defined boundary (`cancelled` is
    /// [`Budget::interrupted`]'s attribution). The step has already
    /// restored a consistent iterate.
    Interrupted {
        /// `true` for an explicit cancellation, `false` for budget.
        cancelled: bool,
    },
    /// The data source failed mid-pass (streaming). Carried in the
    /// outcome rather than thrown so callers can restore their buffers
    /// before surfacing it.
    Failed(ClusterError),
}

/// Outcome of reverting a rejected deferred-guard proposal
/// ([`Step::reject`]).
pub enum Rejection {
    /// Reverted to the plain iterate; its (re-measured) energy.
    Reverted(f64),
    /// The reverted iterate reproduced the previous assignment — the
    /// fall-back Lloyd step changed nothing, which is Algorithm 1's
    /// terminal state. The probe is not a productive iteration.
    Converged,
}

/// When the energy guard measures an accelerated candidate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GuardMode {
    /// Algorithm 1 (full batch): the candidate becomes the next iterate
    /// unguarded, and the next [`Step::advance`] measures it for free;
    /// non-decrease triggers [`Step::reject`].
    Deferred,
    /// Streaming: the candidate is measured immediately with
    /// [`Step::evaluate_candidate`] and only committed
    /// ([`Step::accept_candidate`]) when it strictly decreases the
    /// checkpoint energy.
    Immediate,
}

/// What the driver needs to know about a run, beyond the step itself.
pub struct DriverConfig {
    /// Acceleration mode (window size + dynamic-`m` on/off); `None`
    /// disables the accelerator, the controller and both guards.
    pub accel: Acceleration,
    /// History cap m̄ for the dynamic-`m` controller.
    pub m_max: usize,
    /// ε₁ from Algorithm 1 (shrink threshold).
    pub epsilon1: f64,
    /// ε₂ from Algorithm 1 (grow threshold).
    pub epsilon2: f64,
    /// Iteration (or epoch) cap.
    pub max_iters: usize,
    /// Record the per-iteration energy trace.
    pub record_trace: bool,
    /// Also record the per-iteration `m` trace (the full-batch Lloyd
    /// baseline records energies only; the epoch step records both even
    /// for un-accelerated runs, where `m` is constant 0).
    pub trace_m: bool,
    /// Guard discipline (see [`GuardMode`]).
    pub guard: GuardMode,
    /// Immediate-guard only: drop the Anderson history after this many
    /// consecutive rejections.
    pub restart_after_rejects: Option<u32>,
    /// Check the budget at the top of every driver iteration. The Lloyd
    /// baseline turns this off: it checks inside [`Step::advance`],
    /// *after* the assignment that may prove convergence, so a cancelled
    /// run still returns a consistent `(centroids, assignment)` pair.
    pub check_at_top: bool,
    /// Call [`Step::save_checkpoint`] after every this-many productive
    /// iterations (`0` disables checkpointing). An interruption — cancel
    /// token, time budget, observer stop — also flushes one final
    /// best-effort snapshot at the last committed boundary, so a stopped
    /// run is resumable without waiting for the next multiple.
    pub checkpoint_every: usize,
}

/// What one driver run produced; the caller combines it with its own
/// buffers (centroids, assignment, phase timings) into a
/// [`crate::kmeans::RunReport`].
pub struct DriverOutcome {
    /// Productive iterations (epochs for the streaming step).
    pub iterations: usize,
    /// Iterations whose accelerated candidate passed the energy guard.
    pub accepted: usize,
    /// Whether the step's convergence criterion fired.
    pub converged: bool,
    /// Whether a [`CancelToken`] ended the run.
    pub cancelled: bool,
    /// Whether the time budget or an [`Observer`] ended the run.
    pub stopped_early: bool,
    /// Per-iteration energies (only when `record_trace`).
    pub energy_trace: Vec<f64>,
    /// Per-iteration `m` values (only when `record_trace && trace_m`).
    pub m_trace: Vec<usize>,
    /// The last committed iterate's energy (`+inf` before the first);
    /// the epoch step's exact checkpoint energy for its final state.
    pub last_energy: f64,
    /// A carried data-source failure (streaming); the caller restores
    /// its buffers, then surfaces this.
    pub error: Option<ClusterError>,
}

/// One solver shape, pluggable into the [`FixedPointDriver`]: the
/// fixed-point map application plus the revert/measure primitives of its
/// guard discipline. Everything else — accept/reject decisions, `m`
/// control, history restarts, traces, observers, budgets — lives in the
/// driver, once.
pub trait Step {
    /// Apply the fixed-point map once (one assign+update for the
    /// full-batch solvers, one training pass + energy checkpoint for the
    /// epoch step) and report what happened.
    fn advance(&mut self) -> Advance;

    /// Deferred guard: the outstanding candidate failed to decrease the
    /// energy. Revert to the retained plain iterate (rolling engine
    /// bound state back to its checkpoint) and re-measure.
    fn reject(&mut self) -> Rejection {
        unreachable!("this step does not use the deferred guard")
    }

    /// Form the Anderson residual, ask the accelerator for a proposal
    /// (using at most `m_use` history columns), and stage it. Returns
    /// whether the proposal actually differs from the plain iterate.
    /// Deferred-guard steps checkpoint their engine's bound state here so
    /// a rejected jump can roll back.
    fn propose(&mut self, acc: &mut AndersonAccelerator, m_use: usize) -> bool;

    /// Immediate guard: measure the staged candidate's energy.
    /// `Ok(None)` means the measurement was interrupted — keep the plain
    /// iterate and let the next boundary check end the run.
    fn evaluate_candidate(&mut self) -> Result<Option<f64>, ClusterError> {
        unreachable!("this step does not use the immediate guard")
    }

    /// Immediate guard: commit the staged candidate as the new iterate.
    fn accept_candidate(&mut self) {
        unreachable!("this step does not use the immediate guard")
    }

    /// An interruption or observer stop landed while an unguarded
    /// candidate was outstanding (deferred guard): restore the plain
    /// iterate so the returned state is always guarded.
    fn discard_candidate(&mut self) {}

    /// Step-specific plateau convergence, checked after the observer
    /// using the previous (`e_prev`) and current (`e`) committed
    /// energies. The full-batch solvers converge on repeated assignments
    /// inside [`Step::advance`] instead and return `false` here.
    fn plateaued(&self, _e_prev: f64, _e: f64) -> bool {
        false
    }

    /// The centroids and phase timings shown to the [`Observer`] (the
    /// proposed next iterate for deferred-guard steps, the committed
    /// epoch iterate for the streaming step).
    fn observe(&self) -> (&DataMatrix, &PhaseTimer);

    /// Write a durable snapshot of the step's state (centroids, solver
    /// buffers, RNG streams) together with the driver state and Anderson
    /// history handed in. Called at every `checkpoint_every` boundary; an
    /// error aborts the run typed (a checkpointed run that silently stops
    /// checkpointing is worse than one that stops). The default is a
    /// no-op for steps without a durable backing.
    fn save_checkpoint(
        &mut self,
        _driver: &DriverSnap,
        _acc: Option<&AndersonAccelerator>,
    ) -> Result<(), ClusterError> {
        Ok(())
    }
}

/// The single safeguarded-Anderson iteration loop (see the module docs).
pub struct FixedPointDriver<'a> {
    cfg: DriverConfig,
    acc: Option<&'a mut AndersonAccelerator>,
    budget: Budget<'a>,
    energy_trace: Vec<f64>,
    m_trace: Vec<usize>,
    resume: Option<DriverSnap>,
}

impl<'a> FixedPointDriver<'a> {
    /// Driver over a config, an optional accelerator (required whenever
    /// `cfg.accel` is not `Acceleration::None` — typically borrowed from
    /// the workspace scratch so history columns stay warm across runs)
    /// and the run's budget. The trace buffers are taken over (and handed
    /// back through the outcome) so callers can pool them.
    pub fn new(
        cfg: DriverConfig,
        acc: Option<&'a mut AndersonAccelerator>,
        budget: Budget<'a>,
        energy_trace: Vec<f64>,
        m_trace: Vec<usize>,
    ) -> Self {
        Self { cfg, acc, budget, energy_trace, m_trace, resume: None }
    }

    /// Continue a run from a snapshot's driver state instead of from
    /// iteration zero: the loop locals (committed energy, decrease
    /// history, counters, the dynamic-`m` window, the deferred guard's
    /// outstanding flag) are seeded from the snapshot, and the iteration
    /// budget picks up where the saved run stopped. The caller is
    /// responsible for restoring the matching step buffers and Anderson
    /// history before calling [`FixedPointDriver::run`].
    pub fn resume_from(&mut self, snap: DriverSnap) {
        self.resume = Some(snap);
    }

    /// Run the loop to convergence, the iteration cap, the budget, or an
    /// observer stop.
    pub fn run(mut self, step: &mut dyn Step, observer: &mut dyn Observer) -> DriverOutcome {
        let (use_aa, m0, dynamic) = match self.cfg.accel {
            Acceleration::None => (false, 0, false),
            Acceleration::FixedM(m) => (true, m, false),
            Acceleration::DynamicM(m) => (true, m, true),
        };
        let mut controller = use_aa.then(|| {
            MController::new(
                m0.min(self.cfg.m_max),
                self.cfg.m_max,
                self.cfg.epsilon1,
                self.cfg.epsilon2,
            )
        });
        let mut out = DriverOutcome {
            iterations: 0,
            accepted: 0,
            converged: false,
            cancelled: false,
            stopped_early: false,
            energy_trace: self.energy_trace,
            m_trace: self.m_trace,
            last_energy: f64::INFINITY,
            error: None,
        };
        let mut e_prev = f64::INFINITY; // E^{t-1}
        let mut decrease_prev = f64::INFINITY; // E^{t-2} − E^{t-1}
        // Deferred guard: whether the current iterate is an unguarded
        // accelerated proposal from the previous iteration.
        let mut outstanding = false;
        let mut rejects = 0u32;
        let restart_after = self.cfg.restart_after_rejects.unwrap_or(u32::MAX);
        // Resuming: seed every loop local from the snapshot so the next
        // iteration continues the saved trajectory exactly.
        if let Some(snap) = self.resume.take() {
            out.iterations = snap.iterations as usize;
            out.accepted = snap.accepted as usize;
            e_prev = snap.energy;
            decrease_prev = snap.decrease_prev;
            outstanding = snap.outstanding;
            rejects = snap.rejects;
            if let Some(c) = controller.as_mut() {
                c.set_m(snap.m as usize);
            }
        }
        let mk_snap = |iterations: usize,
                       accepted: usize,
                       energy: f64,
                       decrease_prev: f64,
                       rejects: u32,
                       m: usize,
                       outstanding: bool| DriverSnap {
            iterations: iterations as u64,
            accepted: accepted as u64,
            energy,
            decrease_prev,
            rejects,
            m: m as u64,
            outstanding,
        };

        // Telemetry is batched in plain locals and flushed once after the
        // loop: the disabled path costs one relaxed load per run, and the
        // enabled path adds no atomics (and no allocation) per iteration.
        let telemetry_on = crate::telemetry::enabled();
        let iters_at_entry = out.iterations;
        let accepted_at_entry = out.accepted;
        let mut aa_proposals = 0u64;
        let mut aa_rejections = 0u64;
        let mut aa_restarts = 0u64;
        const PHASE_SNAP: usize = 32;
        let mut phase_base = [0u64; PHASE_SNAP];
        let mut phase_base_len = 0usize;
        if telemetry_on {
            // Phase totals may persist in warm workspaces, so record the
            // run's contribution as a delta against the entry totals.
            let (_, phases) = step.observe();
            for (i, (_, total, _)) in phases.phases().iter().enumerate().take(PHASE_SNAP) {
                phase_base[i] = total.as_micros() as u64;
                phase_base_len = i + 1;
            }
        }

        for _t in out.iterations..self.cfg.max_iters {
            // Fault-injection point: inert unless a `FaultPlan` arms the
            // solver-iteration site (robustness tests). Fires before the
            // iteration does any work, so the partial state stays exactly
            // the previous iterate's.
            if let Err(e) = crate::fault::check(crate::fault::FaultSite::SolverIteration) {
                if outstanding {
                    step.discard_candidate();
                }
                out.error = Some(e);
                break;
            }
            let at_top = if self.cfg.check_at_top {
                self.budget.interrupted()
            } else {
                None
            };
            if let Some(cancelled) = at_top {
                if outstanding {
                    step.discard_candidate();
                }
                // Best-effort final flush: the discarded candidate leaves
                // the step at its last committed (guarded) boundary.
                if self.cfg.checkpoint_every > 0 {
                    let m = controller.as_ref().map_or(0, MController::m);
                    let snap =
                        mk_snap(out.iterations, out.accepted, e_prev, decrease_prev, rejects, m, false);
                    let _ = step.save_checkpoint(&snap, self.acc.as_deref());
                }
                out.cancelled = cancelled;
                out.stopped_early = !cancelled;
                break;
            }
            let mut energy = match step.advance() {
                Advance::Evaluated(e) => e,
                Advance::Converged => {
                    out.converged = true;
                    break;
                }
                Advance::RetryPlain => {
                    outstanding = false;
                    continue;
                }
                Advance::Interrupted { cancelled } => {
                    // The step has already restored its last committed
                    // boundary; flush it so the run is resumable.
                    if self.cfg.checkpoint_every > 0 {
                        let m = controller.as_ref().map_or(0, MController::m);
                        let snap = mk_snap(
                            out.iterations,
                            out.accepted,
                            e_prev,
                            decrease_prev,
                            rejects,
                            m,
                            false,
                        );
                        let _ = step.save_checkpoint(&snap, self.acc.as_deref());
                    }
                    out.cancelled = cancelled;
                    out.stopped_early = !cancelled;
                    break;
                }
                Advance::Failed(e) => {
                    out.error = Some(e);
                    break;
                }
            };
            out.iterations += 1;
            let mut accepted_this = false;
            let mut candidate = false;
            if use_aa {
                let mut e = energy.expect("accelerated steps always measure energy");
                let controller = controller.as_mut().expect("accelerated runs have a controller");
                // Lines 8–12: adjust m from the energy-decrease ratio.
                if dynamic {
                    controller.adjust(e_prev - e, decrease_prev);
                }
                let acc = self.acc.as_deref_mut().expect("accelerated runs carry an accelerator");
                match self.cfg.guard {
                    // Lines 13–15: the previous proposal is measured by
                    // this iteration's pass; revert on non-decrease.
                    GuardMode::Deferred => {
                        if e >= e_prev {
                            if outstanding {
                                aa_rejections += 1;
                            }
                            match step.reject() {
                                Rejection::Converged => {
                                    // Terminal probe, not a productive
                                    // iteration.
                                    out.iterations -= 1;
                                    out.converged = true;
                                    break;
                                }
                                Rejection::Reverted(e_plain) => e = e_plain,
                            }
                        } else if outstanding {
                            out.accepted += 1;
                            accepted_this = true;
                        }
                        // Lines 17–19: stage the next proposal (unguarded
                        // until the next pass measures it).
                        outstanding = step.propose(acc, controller.m());
                        candidate = outstanding;
                        if candidate {
                            aa_proposals += 1;
                        }
                    }
                    // Immediate guard: measure the fresh proposal with a
                    // dedicated pass; commit only on strict decrease.
                    GuardMode::Immediate => {
                        candidate = step.propose(acc, controller.m());
                        if candidate {
                            aa_proposals += 1;
                            match step.evaluate_candidate() {
                                Ok(Some(e_cand)) if e_cand < e => {
                                    step.accept_candidate();
                                    e = e_cand;
                                    out.accepted += 1;
                                    accepted_this = true;
                                    rejects = 0;
                                }
                                Ok(Some(_)) => {
                                    aa_rejections += 1;
                                    rejects += 1;
                                    if rejects >= restart_after {
                                        acc.reset();
                                        rejects = 0;
                                        aa_restarts += 1;
                                    }
                                }
                                // Interrupted mid-guard: keep the plain
                                // iterate (its energy is exact); the next
                                // boundary check ends the run.
                                Ok(None) => {}
                                Err(err) => {
                                    out.error = Some(err);
                                    break;
                                }
                            }
                        }
                    }
                }
                energy = Some(e);
            }
            if self.cfg.record_trace {
                out.energy_trace.push(energy.expect("record_trace runs measure energy"));
                if self.cfg.trace_m {
                    out.m_trace.push(controller.as_ref().map_or(0, MController::m));
                }
            }
            // Plateau test uses the *previous* committed energy; compute
            // it before rolling e_prev forward.
            let plateaued = match energy {
                Some(e) => step.plateaued(e_prev, e),
                None => false,
            };
            if let Some(e) = energy {
                decrease_prev = e_prev - e;
                e_prev = e;
            }
            let (centroids, phases) = step.observe();
            let control = observer.on_iteration(&IterationInfo {
                iteration: out.iterations,
                energy,
                m: controller.as_ref().map_or(0, MController::m),
                accelerated_candidate: candidate,
                accepted: accepted_this,
                centroids,
                phases,
            });
            if control == ObserverControl::Stop {
                if outstanding {
                    step.discard_candidate();
                }
                if self.cfg.checkpoint_every > 0 {
                    let m = controller.as_ref().map_or(0, MController::m);
                    let snap =
                        mk_snap(out.iterations, out.accepted, e_prev, decrease_prev, rejects, m, false);
                    let _ = step.save_checkpoint(&snap, self.acc.as_deref());
                }
                out.stopped_early = true;
                break;
            }
            if plateaued {
                out.converged = true;
                break;
            }
            // Periodic durable snapshot at a committed iteration boundary.
            // A failed write aborts the run typed: the old snapshot (if
            // any) is still intact on disk, and a retry resumes from it.
            if self.cfg.checkpoint_every > 0 && out.iterations % self.cfg.checkpoint_every == 0 {
                let m = controller.as_ref().map_or(0, MController::m);
                let snap = mk_snap(
                    out.iterations,
                    out.accepted,
                    e_prev,
                    decrease_prev,
                    rejects,
                    m,
                    outstanding,
                );
                if let Err(err) = step.save_checkpoint(&snap, self.acc.as_deref()) {
                    if outstanding {
                        step.discard_candidate();
                    }
                    out.error = Some(err);
                    break;
                }
            }
        }
        out.last_energy = e_prev;
        if telemetry_on {
            let t = crate::telemetry::metrics();
            t.solver_runs.inc();
            let run_iters = out.iterations.saturating_sub(iters_at_entry) as u64;
            t.solver_iterations.add(run_iters);
            t.solver_run_iterations.observe(run_iters as f64);
            t.aa_proposals.add(aa_proposals);
            t.aa_accepted.add(out.accepted.saturating_sub(accepted_at_entry) as u64);
            t.aa_rejected.add(aa_rejections);
            t.aa_restarts.add(aa_restarts);
            t.solver_m.set(controller.as_ref().map_or(0, MController::m) as i64);
            let (_, phases) = step.observe();
            for (i, (name, total, _)) in phases.phases().iter().enumerate() {
                let base = if i < phase_base_len { phase_base[i] } else { 0 };
                let micros = (total.as_micros() as u64).saturating_sub(base);
                if micros > 0 {
                    t.solver_phase_micros.add(name, micros);
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::observe::NoopObserver;

    /// A scalar contraction step x ← a·x + b with energy |x − fix|²,
    /// exercising the deferred guard without any engine machinery.
    struct Contraction {
        a: f64,
        b: f64,
        x: f64, // current iterate (possibly an unguarded proposal)
        g: f64, // retained plain iterate G(x_prev)
        g_next: f64,
        centroids: DataMatrix,
        phases: PhaseTimer,
        f_t: Vec<f64>,
    }

    impl Contraction {
        fn new(a: f64, b: f64, x0: f64) -> Self {
            let g = a * x0 + b;
            Self {
                a,
                b,
                x: g,
                g,
                g_next: 0.0,
                centroids: DataMatrix::zeros(1, 1),
                phases: PhaseTimer::new(),
                f_t: vec![0.0],
            }
        }

        fn fixed_point(&self) -> f64 {
            self.b / (1.0 - self.a)
        }

        fn energy_of(&self, x: f64) -> f64 {
            let d = x - self.fixed_point();
            d * d
        }
    }

    impl Step for Contraction {
        fn advance(&mut self) -> Advance {
            let e = self.energy_of(self.x);
            if e < 1e-24 {
                return Advance::Converged;
            }
            self.g_next = self.a * self.x + self.b;
            Advance::Evaluated(Some(e))
        }

        fn reject(&mut self) -> Rejection {
            std::mem::swap(&mut self.x, &mut self.g);
            let e = self.energy_of(self.x);
            self.g_next = self.a * self.x + self.b;
            Rejection::Reverted(e)
        }

        fn propose(&mut self, acc: &mut AndersonAccelerator, m_use: usize) -> bool {
            std::mem::swap(&mut self.g, &mut self.g_next);
            self.f_t[0] = self.g - self.x;
            let g = [self.g];
            let mut out = [0.0];
            let candidate = acc.propose_into(&g, &self.f_t, m_use, &mut out);
            self.x = out[0];
            candidate
        }

        fn discard_candidate(&mut self) {
            self.x = self.g;
        }

        fn observe(&self) -> (&DataMatrix, &PhaseTimer) {
            (&self.centroids, &self.phases)
        }
    }

    /// The un-accelerated shape (mirroring `LloydStep`): the step commits
    /// its own next iterate inside `advance`, since the driver never
    /// calls `propose` when acceleration is off.
    struct PlainContraction {
        a: f64,
        b: f64,
        x: f64,
        centroids: DataMatrix,
        phases: PhaseTimer,
    }

    impl Step for PlainContraction {
        fn advance(&mut self) -> Advance {
            let fix = self.b / (1.0 - self.a);
            let e = (self.x - fix) * (self.x - fix);
            if e < 1e-24 {
                return Advance::Converged;
            }
            self.x = self.a * self.x + self.b;
            Advance::Evaluated(Some(e))
        }

        fn propose(&mut self, _acc: &mut AndersonAccelerator, _m_use: usize) -> bool {
            unreachable!("plain iteration never proposes")
        }

        fn observe(&self) -> (&DataMatrix, &PhaseTimer) {
            (&self.centroids, &self.phases)
        }
    }

    fn driver_cfg(accel: Acceleration, max_iters: usize) -> DriverConfig {
        DriverConfig {
            accel,
            m_max: 5,
            epsilon1: 0.02,
            epsilon2: 0.5,
            max_iters,
            record_trace: true,
            trace_m: true,
            guard: GuardMode::Deferred,
            restart_after_rejects: None,
            check_at_top: true,
            checkpoint_every: 0,
        }
    }

    #[test]
    fn deferred_guard_converges_faster_than_plain_iteration() {
        let sw = Stopwatch::start();
        let token = CancelToken::new();
        let budget = Budget::new(&sw, None, &token);
        let mut acc = AndersonAccelerator::new(5, 1);
        let mut step = Contraction::new(0.95, 1.0, 0.0);
        let driver = FixedPointDriver::new(
            driver_cfg(Acceleration::DynamicM(2), 10_000),
            Some(&mut acc),
            budget,
            Vec::new(),
            Vec::new(),
        );
        let out = driver.run(&mut step, &mut NoopObserver);
        assert!(out.converged, "driver must reach the fixed point");
        assert!(out.error.is_none());
        // Plain iteration contracts by 0.95 per step: reaching 1e-12 of
        // the gap takes hundreds of iterations; AA needs a handful.
        assert!(
            out.iterations < 100,
            "AA should beat plain contraction: {} iterations",
            out.iterations
        );
        assert!(out.accepted > 0, "some proposals must be accepted");
        assert_eq!(out.energy_trace.len(), out.iterations);
        assert_eq!(out.m_trace.len(), out.iterations);
        // The guard's contract: committed energies never increase.
        for w in out.energy_trace.windows(2) {
            assert!(w[1] <= w[0] + 1e-12, "energy increased: {} -> {}", w[0], w[1]);
        }
    }

    #[test]
    fn disabled_acceleration_is_plain_iteration() {
        let sw = Stopwatch::start();
        let token = CancelToken::new();
        let budget = Budget::new(&sw, None, &token);
        let mut step = PlainContraction {
            a: 0.5,
            b: 1.0,
            x: 0.0,
            centroids: DataMatrix::zeros(1, 1),
            phases: PhaseTimer::new(),
        };
        let driver = FixedPointDriver::new(
            driver_cfg(Acceleration::None, 200),
            None,
            budget,
            Vec::new(),
            Vec::new(),
        );
        let out = driver.run(&mut step, &mut NoopObserver);
        assert!(out.converged);
        assert_eq!(out.accepted, 0);
        assert!(out.iterations > 10, "a 0.5-contraction needs dozens of halvings");
        assert!(out.m_trace.iter().all(|&m| m == 0));
    }

    #[test]
    fn cancelled_budget_stops_at_the_top() {
        let sw = Stopwatch::start();
        let token = CancelToken::new();
        token.cancel();
        let budget = Budget::new(&sw, None, &token);
        let mut acc = AndersonAccelerator::new(5, 1);
        let mut step = Contraction::new(0.9, 1.0, 0.0);
        let driver = FixedPointDriver::new(
            driver_cfg(Acceleration::DynamicM(2), 100),
            Some(&mut acc),
            budget,
            Vec::new(),
            Vec::new(),
        );
        let out = driver.run(&mut step, &mut NoopObserver);
        assert!(out.cancelled && !out.stopped_early && !out.converged);
        assert_eq!(out.iterations, 0);
    }

    #[test]
    fn zero_time_budget_reports_stopped_early() {
        let sw = Stopwatch::start();
        let token = CancelToken::new();
        let budget = Budget::new(&sw, Some(Duration::ZERO), &token);
        let mut step = Contraction::new(0.9, 1.0, 0.0);
        let driver = FixedPointDriver::new(
            driver_cfg(Acceleration::None, 100),
            None,
            budget,
            Vec::new(),
            Vec::new(),
        );
        let out = driver.run(&mut step, &mut NoopObserver);
        assert!(out.stopped_early && !out.cancelled);
    }

    /// In-memory checkpoint sink: records the driver state, the step's
    /// iterate pair and the Anderson history at every snapshot boundary.
    struct CheckpointingContraction {
        inner: Contraction,
        saved: Option<(DriverSnap, f64, f64, Option<crate::persist::AndersonSnap>)>,
    }

    impl Step for CheckpointingContraction {
        fn advance(&mut self) -> Advance {
            self.inner.advance()
        }

        fn reject(&mut self) -> Rejection {
            self.inner.reject()
        }

        fn propose(&mut self, acc: &mut AndersonAccelerator, m_use: usize) -> bool {
            self.inner.propose(acc, m_use)
        }

        fn discard_candidate(&mut self) {
            self.inner.discard_candidate();
        }

        fn observe(&self) -> (&DataMatrix, &PhaseTimer) {
            self.inner.observe()
        }

        fn save_checkpoint(
            &mut self,
            driver: &DriverSnap,
            acc: Option<&AndersonAccelerator>,
        ) -> Result<(), ClusterError> {
            self.saved =
                Some((driver.clone(), self.inner.x, self.inner.g, acc.map(|a| a.snapshot())));
            Ok(())
        }
    }

    /// Truncate a run at iteration 6 with per-iteration checkpoints,
    /// resume from the snapshot in fresh buffers, and demand the stitched
    /// trajectory equals the uninterrupted reference bit for bit — the
    /// driver-level core of the crate's resume-parity guarantee.
    #[test]
    fn resumed_run_is_bit_identical_to_uninterrupted() {
        let sw = Stopwatch::start();
        let token = CancelToken::new();
        let budget = Budget::new(&sw, None, &token);

        // Uninterrupted reference.
        let mut acc_full = AndersonAccelerator::new(5, 1);
        let mut step_full = Contraction::new(0.95, 1.0, 0.0);
        let full = FixedPointDriver::new(
            driver_cfg(Acceleration::DynamicM(2), 10_000),
            Some(&mut acc_full),
            budget,
            Vec::new(),
            Vec::new(),
        )
        .run(&mut step_full, &mut NoopObserver);
        assert!(full.converged);

        // Same run truncated at 6 iterations, checkpointing every one.
        let mut cfg = driver_cfg(Acceleration::DynamicM(2), 6);
        cfg.checkpoint_every = 1;
        let mut acc_a = AndersonAccelerator::new(5, 1);
        let mut step_a = CheckpointingContraction {
            inner: Contraction::new(0.95, 1.0, 0.0),
            saved: None,
        };
        let truncated = FixedPointDriver::new(cfg, Some(&mut acc_a), budget, Vec::new(), Vec::new())
            .run(&mut step_a, &mut NoopObserver);
        assert!(!truncated.converged, "6 iterations must not finish a 0.95-contraction");
        assert_eq!(truncated.iterations, 6);
        let (snap, x, g, aa) = step_a.saved.expect("checkpoint_every=1 must have saved");
        assert_eq!(snap.iterations, 6);

        // Resume in completely fresh buffers.
        let mut acc_b = AndersonAccelerator::new(5, 1);
        acc_b.restore(&aa.expect("accelerated run saves its history"));
        let mut step_b = Contraction::new(0.95, 1.0, 0.0);
        step_b.x = x;
        step_b.g = g;
        let mut driver =
            FixedPointDriver::new(driver_cfg(Acceleration::DynamicM(2), 10_000), Some(&mut acc_b), budget, Vec::new(), Vec::new());
        driver.resume_from(snap);
        let resumed = driver.run(&mut step_b, &mut NoopObserver);
        assert!(resumed.converged);

        // Stitch the truncated prefix to the resumed suffix: identical to
        // the uninterrupted reference, bit for bit.
        assert_eq!(full.iterations, resumed.iterations, "total iteration counts must agree");
        assert_eq!(full.accepted, resumed.accepted, "acceptance counters must agree");
        let stitched: Vec<u64> = truncated
            .energy_trace
            .iter()
            .chain(resumed.energy_trace.iter())
            .map(|e| e.to_bits())
            .collect();
        let reference: Vec<u64> = full.energy_trace.iter().map(|e| e.to_bits()).collect();
        assert_eq!(stitched, reference, "energy trajectories must match bit-exactly");
        assert_eq!(
            truncated.m_trace.iter().chain(resumed.m_trace.iter()).collect::<Vec<_>>(),
            full.m_trace.iter().collect::<Vec<_>>(),
            "dynamic-m trajectories must match"
        );
        assert_eq!(full.last_energy.to_bits(), resumed.last_energy.to_bits());
        assert_eq!(step_full.x.to_bits(), step_b.x.to_bits(), "final iterates must agree");
    }

    #[test]
    fn budget_attribution_prefers_cancellation() {
        let sw = Stopwatch::start();
        let token = CancelToken::new();
        token.cancel();
        let budget = Budget::new(&sw, Some(Duration::ZERO), &token);
        assert_eq!(budget.interrupted(), Some(true));
        assert!(budget.is_cancelled());
    }
}
