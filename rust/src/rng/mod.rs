//! Pseudo-random number generation substrate.
//!
//! The offline crate set has no `rand`, so this module implements the PRNGs
//! and samplers the rest of the crate needs: [`Pcg32`] (O'Neill's PCG-XSH-RR
//! 64/32) for the main streams, [`SplitMix64`] for seeding, gaussian samples
//! via Box–Muller, weighted discrete sampling, and Fisher–Yates shuffles.
//!
//! All generators are deterministic from their seed; every experiment in the
//! bench harness records its seed so runs are exactly reproducible.

mod pcg;
mod sample;

pub use pcg::{Pcg32, SplitMix64};
pub use sample::{choose_weighted, reservoir_sample, sample_indices, shuffle};

/// Minimal RNG interface used across the crate.
pub trait Rng {
    /// Next uniform 32-bit value.
    fn next_u32(&mut self) -> u32;

    /// Next uniform 64-bit value.
    fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform `f64` in `[0, 1)` with 53 bits of precision.
    fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, bound)` (Lemire's method, bias-free for the
    /// bound sizes used here).
    fn next_below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0, "next_below bound must be positive");
        // 64-bit multiply-shift; bias is < 2^-32 for bounds < 2^32.
        let x = self.next_u64();
        ((x as u128 * bound as u128) >> 64) as usize
    }

    /// Uniform `f64` in `[lo, hi)`.
    fn next_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal sample (Box–Muller; one of the pair is discarded to
    /// keep the generator stateless beyond the stream).
    fn next_gaussian(&mut self) -> f64 {
        // Avoid log(0) by nudging u1 away from zero.
        let u1 = (self.next_f64()).max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn next_f64_in_unit_interval() {
        let mut rng = Pcg32::seed_from_u64(1);
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x), "{x} out of [0,1)");
        }
    }

    #[test]
    fn next_below_respects_bound() {
        let mut rng = Pcg32::seed_from_u64(2);
        for bound in [1usize, 2, 3, 7, 100, 12345] {
            for _ in 0..1000 {
                assert!(rng.next_below(bound) < bound);
            }
        }
    }

    #[test]
    fn next_below_covers_small_range() {
        let mut rng = Pcg32::seed_from_u64(3);
        let mut seen = [false; 5];
        for _ in 0..1000 {
            seen[rng.next_below(5)] = true;
        }
        assert!(seen.iter().all(|&s| s), "all of 0..5 should appear");
    }

    #[test]
    fn gaussian_moments_are_sane() {
        let mut rng = Pcg32::seed_from_u64(4);
        let n = 100_000;
        let (mut sum, mut sumsq) = (0.0, 0.0);
        for _ in 0..n {
            let g = rng.next_gaussian();
            sum += g;
            sumsq += g * g;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn next_range_bounds() {
        let mut rng = Pcg32::seed_from_u64(5);
        for _ in 0..1000 {
            let x = rng.next_range(-3.0, 4.5);
            assert!((-3.0..4.5).contains(&x));
        }
    }
}
