//! PCG-XSH-RR 64/32 (O'Neill 2014) and SplitMix64 (Steele et al. 2014).

use super::Rng;

/// SplitMix64 — used to expand a single `u64` seed into independent streams.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a raw seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64-bit output.
    pub fn next(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

impl Rng for SplitMix64 {
    fn next_u32(&mut self) -> u32 {
        (SplitMix64::next(self) >> 32) as u32
    }

    fn next_u64(&mut self) -> u64 {
        SplitMix64::next(self)
    }
}

/// PCG-XSH-RR 64/32: 64-bit LCG state, 32-bit xorshift-rotate output.
///
/// Small, fast, statistically solid — the workhorse generator for seeding,
/// synthetic data and the sampling-based initializers.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6_364_136_223_846_793_005;

impl Pcg32 {
    /// Construct from explicit state/stream (the PCG reference constructor).
    pub fn new(init_state: u64, init_seq: u64) -> Self {
        let mut rng = Self { state: 0, inc: (init_seq << 1) | 1 };
        rng.step();
        rng.state = rng.state.wrapping_add(init_state);
        rng.step();
        rng
    }

    /// Construct from a single seed, expanding with SplitMix64.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self::new(sm.next(), sm.next())
    }

    /// Derive an independent child stream (used to hand one RNG per worker
    /// thread / per dataset without sharing mutable state).
    pub fn split(&mut self) -> Self {
        Self::new(Rng::next_u64(self), Rng::next_u64(self))
    }

    /// The raw `(state, inc)` pair — what a checkpoint must persist to
    /// resume this stream mid-sequence. [`Pcg32::new`] transforms its
    /// arguments (it seeds, it does not restore), so round-tripping goes
    /// through [`Pcg32::from_parts`] instead.
    pub fn state_parts(&self) -> (u64, u64) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from raw `(state, inc)` parts previously read
    /// with [`Pcg32::state_parts`]; the restored stream continues exactly
    /// where the saved one left off.
    pub fn from_parts(state: u64, inc: u64) -> Self {
        Self { state, inc }
    }

    #[inline]
    fn step(&mut self) {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
    }
}

impl Rng for Pcg32 {
    #[inline]
    fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.step();
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pcg_reference_vector() {
        // First outputs of pcg32 with the reference demo seeding
        // (state=42, seq=54), from the PCG minimal C library.
        let mut rng = Pcg32::new(42, 54);
        let expected = [0xa15c_02b7u32, 0x7b47_f409, 0xba1d_3330, 0x83d2_f293];
        for &e in &expected {
            assert_eq!(rng.next_u32(), e);
        }
    }

    #[test]
    fn seeding_is_deterministic() {
        let mut a = Pcg32::seed_from_u64(99);
        let mut b = Pcg32::seed_from_u64(99);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg32::seed_from_u64(1);
        let mut b = Pcg32::seed_from_u64(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4, "streams should be effectively independent");
    }

    #[test]
    fn split_streams_diverge() {
        let mut root = Pcg32::seed_from_u64(5);
        let mut a = root.split();
        let mut b = root.split();
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn splitmix_known_value() {
        // SplitMix64(seed=0) first output, per the reference implementation.
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next(), 0xE220_A839_7B1D_CDAF);
    }
}
