//! Sampling utilities on top of the [`Rng`] trait: shuffles, index draws,
//! weighted choice (the core of k-means++ / afk-mc² seeding) and reservoir
//! sampling for streaming subsamples.

use super::Rng;

/// In-place Fisher–Yates shuffle.
pub fn shuffle<T, R: Rng>(items: &mut [T], rng: &mut R) {
    for i in (1..items.len()).rev() {
        let j = rng.next_below(i + 1);
        items.swap(i, j);
    }
}

/// Draw `count` distinct indices from `0..n` (Floyd's algorithm for small
/// `count`, shuffle-prefix otherwise).
pub fn sample_indices<R: Rng>(n: usize, count: usize, rng: &mut R) -> Vec<usize> {
    assert!(count <= n, "cannot draw {count} distinct indices from {n}");
    if count * 4 >= n {
        let mut all: Vec<usize> = (0..n).collect();
        shuffle(&mut all, rng);
        all.truncate(count);
        return all;
    }
    // Robert Floyd's sampling: O(count) expected, no O(n) allocation.
    let mut chosen = std::collections::HashSet::with_capacity(count);
    let mut out = Vec::with_capacity(count);
    for j in (n - count)..n {
        let t = rng.next_below(j + 1);
        let pick = if chosen.contains(&t) { j } else { t };
        chosen.insert(pick);
        out.push(pick);
    }
    out
}

/// Weighted discrete sample: returns an index `i` with probability
/// `weights[i] / sum(weights)`. Zero-total weight falls back to uniform.
pub fn choose_weighted<R: Rng>(weights: &[f64], rng: &mut R) -> usize {
    debug_assert!(!weights.is_empty());
    let total: f64 = weights.iter().sum();
    if total <= 0.0 || !total.is_finite() {
        return rng.next_below(weights.len());
    }
    let mut target = rng.next_f64() * total;
    for (i, &w) in weights.iter().enumerate() {
        target -= w;
        if target < 0.0 {
            return i;
        }
    }
    // Floating-point slack: return the last strictly-positive weight.
    weights
        .iter()
        .rposition(|&w| w > 0.0)
        .unwrap_or(weights.len() - 1)
}

/// Reservoir-sample `count` items from an iterator of unknown length
/// (Vitter's Algorithm R). Used by the streaming coordinator to keep a
/// bounded design sample for seeding.
pub fn reservoir_sample<T, I, R>(iter: I, count: usize, rng: &mut R) -> Vec<T>
where
    I: IntoIterator<Item = T>,
    R: Rng,
{
    let mut reservoir: Vec<T> = Vec::with_capacity(count);
    for (i, item) in iter.into_iter().enumerate() {
        if i < count {
            reservoir.push(item);
        } else {
            let j = rng.next_below(i + 1);
            if j < count {
                reservoir[j] = item;
            }
        }
    }
    reservoir
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::seed_from_u64(10);
        let mut v: Vec<usize> = (0..100).collect();
        shuffle(&mut v, &mut rng);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut rng = Pcg32::seed_from_u64(11);
        for (n, c) in [(10, 3), (10, 10), (1000, 5), (1000, 900)] {
            let idx = sample_indices(n, c, &mut rng);
            assert_eq!(idx.len(), c);
            let set: std::collections::HashSet<_> = idx.iter().collect();
            assert_eq!(set.len(), c, "indices must be distinct");
            assert!(idx.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn choose_weighted_respects_weights() {
        let mut rng = Pcg32::seed_from_u64(12);
        let weights = [0.0, 1.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[choose_weighted(&weights, &mut rng)] += 1;
        }
        assert_eq!(counts[0], 0, "zero-weight item must never be drawn");
        let ratio = counts[2] as f64 / counts[1] as f64;
        assert!((ratio - 3.0).abs() < 0.25, "ratio {ratio} should be ~3");
    }

    #[test]
    fn choose_weighted_zero_total_is_uniform() {
        let mut rng = Pcg32::seed_from_u64(13);
        let weights = [0.0, 0.0, 0.0];
        let mut seen = [false; 3];
        for _ in 0..200 {
            seen[choose_weighted(&weights, &mut rng)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn reservoir_sample_size_and_membership() {
        let mut rng = Pcg32::seed_from_u64(14);
        let sample = reservoir_sample(0..10_000, 32, &mut rng);
        assert_eq!(sample.len(), 32);
        assert!(sample.iter().all(|&x| x < 10_000));
    }

    #[test]
    fn reservoir_sample_short_input() {
        let mut rng = Pcg32::seed_from_u64(15);
        let sample = reservoir_sample(0..5, 32, &mut rng);
        assert_eq!(sample, vec![0, 1, 2, 3, 4]);
    }
}
