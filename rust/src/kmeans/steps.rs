//! The full-batch [`Step`] implementations behind [`super::Solver`].
//!
//! Both of the solver's paths are thin map applications over the shared
//! safeguarded-Anderson driver in [`crate::accel`]:
//!
//! * [`AndersonStep`] — Algorithm 1's map: one fused assign+update pass
//!   that yields `E(P^t, C^t)` and `C_AU^{t+1}` together, plus the
//!   deferred-guard primitives (revert to `C_AU`, engine bound rollback)
//!   and the Anderson residual/proposal staging.
//! * [`LloydStep`] — the plain Lloyd baseline: assign, optional energy,
//!   update. No acceleration state at all; the driver runs it with
//!   `Acceleration::None`.
//!
//! The steps own only borrowed workspace pieces (engine, pool) and the
//! per-run buffers taken from the workspace scratch; the solver takes the
//! buffers out before a run and puts them back after, so the warm-run
//! allocation contract (`tests/alloc_reuse.rs`) is unchanged.

use crate::accel::{Advance, Budget, Rejection, Step};
use crate::anderson::AndersonAccelerator;
use crate::data::DataMatrix;
use crate::error::ClusterError;
use crate::lloyd::{self, Assignment, AssignmentEngine};
use crate::metrics::PhaseTimer;
use crate::par::ThreadPool;
use crate::persist::{self, DriverSnap, FullBatchSnap, SolverSnapshot};
use std::path::PathBuf;

/// Where a step persists its durable snapshots, plus the request
/// fingerprint that gates resuming them
/// ([`SolverSnapshot::check_fingerprint`]). `None` disables the
/// [`Step::save_checkpoint`] hook entirely.
pub(super) struct CheckpointCtx {
    pub dir: PathBuf,
    pub fingerprint: String,
}

/// Both assignment buffers for a snapshot. The snapshot format requires
/// the pair to have equal lengths; before the first iteration completes
/// the scratch buffer is still empty, in which case the committed
/// assignment is stored twice (the scratch contents are never read on
/// resume before being overwritten by the next assignment pass).
fn assign_pair(committed: &Assignment, scratch: &Assignment) -> (Vec<u32>, Vec<u32>) {
    if scratch.len() == committed.len() {
        (scratch.clone(), committed.clone())
    } else {
        (committed.clone(), committed.clone())
    }
}

/// Algorithm 1's fixed-point map over the workspace engine (deferred
/// guard). Buffer roles mirror the paper: `c` is the current iterate
/// (possibly an unguarded proposal), `c_au` the retained plain iterate
/// `C_AU^t`, `c_next` the freshly computed `C_AU^{t+1}`.
pub(super) struct AndersonStep<'a> {
    pub x: &'a DataMatrix,
    pub engine: &'a mut dyn AssignmentEngine,
    pub pool: &'a ThreadPool,
    pub phases: PhaseTimer,
    pub c: DataMatrix,
    pub c_au: DataMatrix,
    pub c_next: DataMatrix,
    pub f_t: Vec<f64>,
    pub assign: Assignment,
    pub prev_assign: Assignment,
    pub update: lloyd::UpdateScratch,
    pub candidate_was_accel: bool,
    pub ckpt: Option<CheckpointCtx>,
    pub reseed_seed: Option<u64>,
}

/// Salt for the opt-in empty-cluster re-seed policy: an FNV-1a hash of
/// the freshly updated centroid bits. Tying the salt to the iterate
/// (rather than an iteration counter) makes the policy deterministic
/// across thread counts *and* checkpoint/resume boundaries without any
/// extra persisted state — a resumed run reaches the same centroids and
/// therefore draws the same donor member.
fn reseed_salt(c: &DataMatrix) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &v in c.as_slice() {
        h = (h ^ v.to_bits()).wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Apply [`lloyd::reseed_empty_clusters`] to a freshly updated iterate
/// when the policy is enabled.
fn maybe_reseed(
    reseed_seed: Option<u64>,
    x: &DataMatrix,
    assign: &Assignment,
    c_next: &mut DataMatrix,
) {
    if let Some(seed) = reseed_seed {
        let salt = reseed_salt(c_next);
        lloyd::reseed_empty_clusters(x, assign, c_next, seed, salt);
    }
}

impl Step for AndersonStep<'_> {
    fn advance(&mut self) -> Advance {
        let Self {
            x,
            engine,
            pool,
            phases,
            c,
            c_au,
            c_next,
            assign,
            prev_assign,
            update,
            candidate_was_accel,
            reseed_seed,
            ..
        } = self;
        // Line 3: P^t = Assignment-Step(X, C^t).
        phases.time("assign", || engine.assign(x, c, pool, assign));
        // Lines 4–6: converged when assignments repeat. The paper's own
        // convergence narrative ("… until the fall-back iterate using
        // Lloyd's algorithm results in the same assignment …") requires
        // the terminal iterate to be a *Lloyd* iterate: if the repeat
        // was produced by an accelerated C^t, fall back to C_AU (the
        // means of the same assignment — energy ≤ the accelerated
        // iterate's) and keep iterating until the joint fixed point is
        // verified. This makes the returned (C, P) exact: P is the
        // nearest-assignment of C and C the means of P.
        if prev_assign.as_slice() == assign.as_slice() {
            if !*candidate_was_accel {
                return Advance::Converged;
            }
            c.as_mut_slice().copy_from_slice(c_au.as_slice());
            engine.rollback();
            *candidate_was_accel = false;
            return Advance::RetryPlain;
        }
        // Line 7 + line 16, fused: one O(N·d) pass yields both
        // E^t = E(P^t, C^t) (energy at the *input* centroids) and
        // C_AU^{t+1} = Update-Step(X, P^t) — the accelerated solver
        // touches the samples exactly as often per iteration as Lloyd.
        let e = phases.time("update+energy", || {
            lloyd::update_and_energy_with(x, assign, c, c_next, pool, update)
        });
        maybe_reseed(*reseed_seed, x, assign, c_next);
        Advance::Evaluated(Some(e))
    }

    fn reject(&mut self) -> Rejection {
        let Self {
            x,
            engine,
            pool,
            phases,
            c,
            c_au,
            c_next,
            assign,
            prev_assign,
            update,
            reseed_seed,
            ..
        } = self;
        // Lines 13–15: energy guard — revert to the Lloyd iterate. The
        // engine rolls back to the bound state it had *before* the
        // rejected jump, so the revert assignment only drifts the bounds
        // by one small Lloyd step instead of the jump there-and-back.
        std::mem::swap(c, c_au); // C^t = C_AU^t
        engine.rollback();
        phases.time("assign", || engine.assign(x, c, pool, assign));
        // A reverted iterate might still match the previous assignment —
        // that is Algorithm 1's terminal state (the fall-back Lloyd step
        // changed nothing).
        if prev_assign.as_slice() == assign.as_slice() {
            return Rejection::Converged;
        }
        let e = phases.time("update+energy", || {
            lloyd::update_and_energy_with(x, assign, c, c_next, pool, update)
        });
        maybe_reseed(*reseed_seed, x, assign, c_next);
        Rejection::Reverted(e)
    }

    fn propose(&mut self, acc: &mut AndersonAccelerator, m_use: usize) -> bool {
        let Self {
            engine,
            phases,
            c,
            c_au,
            c_next,
            f_t,
            assign,
            prev_assign,
            candidate_was_accel,
            ..
        } = self;
        // c_next currently holds C_AU^{t+1}; rotate it into c_au.
        std::mem::swap(c_au, c_next);
        // Lines 17–19: Anderson extrapolation, written straight into `c`
        // (which becomes C^{t+1} — its old contents, C^t, are only needed
        // to form the residual f_t = G(C^t) − C^t first).
        let candidate = phases.time("anderson", || {
            crate::linalg::sub(c_au.as_slice(), c.as_slice(), f_t);
            acc.propose_into(c_au.as_slice(), f_t, m_use, c.as_mut_slice())
        });
        if candidate {
            // Save the bound state at C^t so a rejected jump can roll
            // back instead of paying two large bound drifts.
            engine.checkpoint();
        }
        std::mem::swap(prev_assign, assign);
        *candidate_was_accel = candidate;
        candidate
    }

    fn discard_candidate(&mut self) {
        // Fall back from an unguarded accelerated proposal to the last
        // Lloyd iterate so the returned state is always guarded.
        self.c.as_mut_slice().copy_from_slice(self.c_au.as_slice());
        self.candidate_was_accel = false;
    }

    fn observe(&self) -> (&DataMatrix, &PhaseTimer) {
        (&self.c, &self.phases)
    }

    fn save_checkpoint(
        &mut self,
        driver: &DriverSnap,
        acc: Option<&AndersonAccelerator>,
    ) -> Result<(), ClusterError> {
        let Some(ck) = &self.ckpt else { return Ok(()) };
        let (assign, prev_assign) = assign_pair(&self.prev_assign, &self.assign);
        let snap = SolverSnapshot {
            fingerprint: ck.fingerprint.clone(),
            driver: driver.clone(),
            k: self.c.n(),
            d: self.c.d(),
            centroids: self.c.as_slice().to_vec(),
            anderson: acc.map(|a| a.snapshot()),
            full_batch: Some(FullBatchSnap {
                c_au: self.c_au.as_slice().to_vec(),
                assign,
                prev_assign,
                candidate_was_accel: self.candidate_was_accel,
            }),
            stream: None,
        };
        persist::write_snapshot(&ck.dir, &snap).map(|_| ())
    }
}

/// Plain Lloyd's algorithm as a driver step: assignment + update until the
/// assignment repeats. The budget is checked *after* the assignment (and
/// its convergence test), so an interrupted run still returns a consistent
/// `(centroids, assignment)` state — hence `check_at_top: false`.
pub(super) struct LloydStep<'a> {
    pub x: &'a DataMatrix,
    pub engine: &'a mut dyn AssignmentEngine,
    pub pool: &'a ThreadPool,
    pub budget: Budget<'a>,
    pub phases: PhaseTimer,
    pub c: DataMatrix,
    pub c_next: DataMatrix,
    pub assign: Assignment,
    pub prev_assign: Assignment,
    pub update: lloyd::UpdateScratch,
    pub need_energy: bool,
    pub ckpt: Option<CheckpointCtx>,
    pub reseed_seed: Option<u64>,
    /// Set when a mid-advance interruption swapped the fresh (not yet
    /// committed) assignment into `prev_assign` for the return-state
    /// contract; the final checkpoint flush must then read the committed
    /// boundary out of `assign` instead.
    pub interrupted_swap: bool,
}

impl Step for LloydStep<'_> {
    fn advance(&mut self) -> Advance {
        let Self {
            x,
            engine,
            pool,
            budget,
            phases,
            c,
            c_next,
            assign,
            prev_assign,
            update,
            need_energy,
            reseed_seed,
            interrupted_swap,
            ..
        } = self;
        phases.time("assign", || engine.assign(x, c, pool, assign));
        if prev_assign.as_slice() == assign.as_slice() {
            return Advance::Converged;
        }
        // Iteration boundary: the freshly computed assignment pairs with
        // `c`, so an interrupted run still returns a consistent
        // (centroids, assignment) state.
        if let Some(cancelled) = budget.interrupted() {
            std::mem::swap(prev_assign, assign);
            *interrupted_swap = true;
            return Advance::Interrupted { cancelled };
        }
        let energy = if *need_energy {
            Some(phases.time("energy", || lloyd::energy(x, c, assign, pool)))
        } else {
            None
        };
        phases.time("update", || lloyd::update_step_with(x, assign, c, c_next, pool, update));
        maybe_reseed(*reseed_seed, x, assign, c_next);
        std::mem::swap(prev_assign, assign);
        std::mem::swap(c, c_next);
        Advance::Evaluated(energy)
    }

    fn propose(&mut self, _acc: &mut AndersonAccelerator, _m_use: usize) -> bool {
        unreachable!("the Lloyd baseline runs with Acceleration::None")
    }

    fn observe(&self) -> (&DataMatrix, &PhaseTimer) {
        (&self.c, &self.phases)
    }

    fn save_checkpoint(
        &mut self,
        driver: &DriverSnap,
        _acc: Option<&AndersonAccelerator>,
    ) -> Result<(), ClusterError> {
        let Some(ck) = &self.ckpt else { return Ok(()) };
        let (committed, scratch) = if self.interrupted_swap {
            (&self.assign, &self.prev_assign)
        } else {
            (&self.prev_assign, &self.assign)
        };
        let (assign, prev_assign) = assign_pair(committed, scratch);
        let snap = SolverSnapshot {
            fingerprint: ck.fingerprint.clone(),
            driver: driver.clone(),
            k: self.c.n(),
            d: self.c.d(),
            centroids: self.c.as_slice().to_vec(),
            anderson: None,
            // The Lloyd baseline has no retained plain iterate; its
            // committed centroids stand in so the snapshot keeps the
            // full-batch record's k×d shape invariant.
            full_batch: Some(FullBatchSnap {
                c_au: self.c.as_slice().to_vec(),
                assign,
                prev_assign,
                candidate_was_accel: false,
            }),
            stream: None,
        };
        persist::write_snapshot(&ck.dir, &snap).map(|_| ())
    }
}
