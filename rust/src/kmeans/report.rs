//! Run report: everything the paper's tables print about one solver run.

use crate::data::DataMatrix;
use crate::error::ClusterError;
use crate::lloyd::Assignment;
use crate::metrics::PhaseTimer;

/// Outcome of one clustering run.
#[derive(Debug)]
pub struct RunReport {
    /// Total solver iterations (the `b` in the paper's `a/b` column).
    pub iterations: usize,
    /// Iterations whose accelerated iterate was accepted (the `a`).
    pub accepted: usize,
    /// Wall-clock seconds for the whole run.
    pub seconds: f64,
    /// Final clustering energy (paper Eq. 1).
    pub energy: f64,
    /// Final mean squared error `E/N` (the paper's MSE column).
    pub mse: f64,
    /// True when the same-assignment criterion fired (vs. the iteration cap).
    pub converged: bool,
    /// True when the run was ended by a [`crate::observe::CancelToken`]
    /// before converging.
    pub cancelled: bool,
    /// True when an [`crate::observe::Observer`] or the configured time
    /// budget ended the run before the convergence criterion fired.
    pub stopped_early: bool,
    /// Typed error that ended the run mid-iteration, if any (the partial
    /// state above is still consistent). `ClusterSession` surfaces it as
    /// an `Err` after recycling the report's buffers.
    pub error: Option<ClusterError>,
    /// Per-iteration energy (only when `record_trace`).
    pub energy_trace: Vec<f64>,
    /// Per-iteration value of `m` (only for dynamic-m runs with trace).
    pub m_trace: Vec<usize>,
    /// Point–centroid distance evaluations performed by the engine.
    pub dist_evals: u64,
    /// Per-phase wall-clock breakdown (assign / update / energy / anderson).
    pub phases: PhaseTimer,
    /// Final centroids.
    pub centroids: DataMatrix,
    /// Final assignment.
    pub assignment: Assignment,
}

impl RunReport {
    /// The paper's `a/b` iteration cell (e.g. `"27 / 31"`).
    pub fn iter_cell(&self) -> String {
        format!("{} / {}", self.accepted, self.iterations)
    }

    /// Acceptance rate of accelerated iterates.
    pub fn acceptance_rate(&self) -> f64 {
        if self.iterations == 0 {
            0.0
        } else {
            self.accepted as f64 / self.iterations as f64
        }
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "{} iters ({} accepted), {:.3}s, energy {:.6e}, mse {:.4}, {} dist-evals{}",
            self.iterations,
            self.accepted,
            self.seconds,
            self.energy,
            self.mse,
            self.dist_evals,
            if self.converged {
                ""
            } else if self.cancelled {
                " [cancelled]"
            } else if self.stopped_early {
                " [stopped early]"
            } else {
                " [iteration cap hit]"
            },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy() -> RunReport {
        RunReport {
            iterations: 31,
            accepted: 27,
            seconds: 0.25,
            energy: 100.0,
            mse: 15.08,
            converged: true,
            cancelled: false,
            stopped_early: false,
            error: None,
            energy_trace: vec![],
            m_trace: vec![],
            dist_evals: 10,
            phases: PhaseTimer::new(),
            centroids: DataMatrix::zeros(1, 1),
            assignment: vec![0],
        }
    }

    #[test]
    fn iter_cell_matches_paper_format() {
        assert_eq!(dummy().iter_cell(), "27 / 31");
    }

    #[test]
    fn acceptance_rate() {
        let r = dummy();
        assert!((r.acceptance_rate() - 27.0 / 31.0).abs() < 1e-12);
    }

    #[test]
    fn summary_mentions_cap_when_not_converged() {
        let mut r = dummy();
        r.converged = false;
        assert!(r.summary().contains("cap"));
    }
}
