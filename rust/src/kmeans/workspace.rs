//! The reusable solver workspace: assignment engine, thread pool, kernel
//! caches and all solver scratch, owned across runs.
//!
//! One [`Workspace`] backs one [`crate::kmeans::Solver`] (and therefore one
//! [`crate::session::ClusterSession`]). Repeated runs on same-shape data
//! reuse every internal buffer — the engine's bound state and kernel norm
//! caches keep their capacity through `reset`, the Anderson history columns
//! are recycled, and the centroid/assignment scratch is taken and returned
//! per run. Report output buffers come from a recycle pool fed by
//! [`Workspace::recycle`], and the update-step reduce folds into per-lane
//! accumulators held here ([`crate::lloyd::UpdateScratch`]), so a
//! `run → recycle → run` cycle on same-shape data leaves the solver's own
//! buffers untouched by the allocator (remaining transients are a few
//! phase labels; the counting-allocator contract test is
//! `tests/alloc_reuse.rs`).

use crate::anderson::AndersonAccelerator;
use crate::config::{EngineKind, Precision, SolverConfig};
use crate::data::DataMatrix;
use crate::error::ClusterError;
use crate::kmeans::RunReport;
use crate::linalg::DistanceKernel;
use crate::lloyd::{self, Assignment, AssignmentEngine};
use crate::par::ThreadPool;
use std::path::PathBuf;

/// What a [`Workspace`] was built for. Reusing a workspace for a different
/// spec (another engine kind, precision, thread count or artifact set)
/// requires opening a fresh one — [`Workspace::matches`] is the check.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WorkspaceSpec {
    /// Assignment engine kind.
    pub engine: EngineKind,
    /// Kernel sample-storage precision.
    pub precision: Precision,
    /// Thread-pool lanes (0 = host-sized).
    pub threads: usize,
    /// Artifact directory for [`EngineKind::Pjrt`] (`None` = the default
    /// directory). Ignored by CPU engines.
    pub artifact_dir: Option<PathBuf>,
}

impl WorkspaceSpec {
    /// The spec a [`SolverConfig`] implies (no artifact directory).
    pub fn from_config(cfg: &SolverConfig) -> Self {
        Self {
            engine: cfg.engine,
            precision: cfg.precision,
            threads: cfg.threads,
            artifact_dir: None,
        }
    }
}

/// Engine + thread pool + solver scratch, reusable across runs.
pub struct Workspace {
    spec: WorkspaceSpec,
    pub(crate) engine: Box<dyn AssignmentEngine>,
    pub(crate) pool: ThreadPool,
    pub(crate) scratch: Scratch,
}

/// All per-run solver buffers, kept warm between runs.
#[derive(Default)]
pub(crate) struct Scratch {
    /// Internal centroid-matrix pool (the `c_au` / `c_next` rotation).
    mats: Vec<DataMatrix>,
    /// Anderson residual buffer.
    f_t: Vec<f64>,
    /// Assignment-buffer pool (working + previous + recycled outputs).
    assign_bufs: Vec<Assignment>,
    /// Accelerator, reusable while `(m_max, dim)` is unchanged.
    acc: Option<AndersonAccelerator>,
    acc_key: (usize, usize),
    /// Recycled output centroid matrices (fed by [`Workspace::recycle`]).
    spare_centroids: Vec<DataMatrix>,
    /// Recycled trace buffers.
    spare_f64: Vec<Vec<f64>>,
    spare_usize: Vec<Vec<usize>>,
    /// Per-lane accumulators for the update-step reduces (persist across
    /// runs; the last per-iteration allocator transients lived here).
    update: lloyd::UpdateScratch,
    /// Inference kernel for [`crate::registry::predict`], cached with the
    /// precision it was built at so warm predicts reuse its norm caches.
    predict_kernel: Option<(Precision, DistanceKernel)>,
    /// Whether the last run had to (re)allocate internal scratch.
    rebuilt: bool,
    runs: u64,
}

/// Reshape a matrix buffer to `k × d`, reusing its allocation.
fn reshape(m: DataMatrix, k: usize, d: usize) -> (DataMatrix, bool) {
    if m.n() == k && m.d() == d {
        return (m, false);
    }
    let mut v = m.into_vec();
    let grew = v.capacity() < k * d;
    v.clear();
    v.resize(k * d, 0.0);
    (DataMatrix::from_vec(v, k, d), grew)
}

impl Workspace {
    /// Open a workspace for `spec`, constructing the engine fallibly: CPU
    /// engines always succeed; [`EngineKind::Pjrt`] loads the AOT artifact
    /// manifest from `spec.artifact_dir` (or the default directory) and
    /// returns [`ClusterError::Engine`] when that fails.
    pub fn open(spec: &WorkspaceSpec) -> Result<Self, ClusterError> {
        let engine: Box<dyn AssignmentEngine> = match spec.engine {
            EngineKind::Pjrt => {
                let dir = spec
                    .artifact_dir
                    .clone()
                    .unwrap_or_else(crate::runtime::default_artifact_dir);
                let engine = crate::runtime::PjrtEngine::open(&dir).map_err(|e| {
                    ClusterError::Engine { engine: "pjrt", reason: format!("{e:#}") }
                })?;
                Box::new(engine)
            }
            other => lloyd::try_make_engine(other, spec.precision)?,
        };
        Ok(Self::from_engine(engine, spec.clone()))
    }

    /// Wrap a caller-built engine (e.g. a `runtime::PjrtEngine` sharing a
    /// runtime across jobs). The caller vouches that the engine matches
    /// `spec.engine` / `spec.precision`.
    pub fn from_engine(engine: Box<dyn AssignmentEngine>, spec: WorkspaceSpec) -> Self {
        let pool = if spec.threads == 0 {
            ThreadPool::host_sized()
        } else {
            ThreadPool::new(spec.threads)
        };
        Self { spec, engine, pool, scratch: Scratch::default() }
    }

    /// The spec this workspace was opened for.
    pub fn spec(&self) -> &WorkspaceSpec {
        &self.spec
    }

    /// Whether this workspace can serve a run with the given spec.
    pub fn matches(&self, spec: &WorkspaceSpec) -> bool {
        self.spec == *spec
    }

    /// Engine name (for reports / metadata).
    pub fn engine_name(&self) -> &'static str {
        self.engine.name()
    }

    /// Completed runs through this workspace.
    pub fn runs(&self) -> u64 {
        self.scratch.runs
    }

    /// Whether the most recent run had to (re)allocate internal solver
    /// scratch — `false` from the second same-shape run on, which is the
    /// warm-workspace contract the session API exists for.
    pub fn last_run_rebuilt_scratch(&self) -> bool {
        self.scratch.rebuilt
    }

    /// Return a finished report's buffers to the recycle pool, making the
    /// next same-shape run's outputs allocation-free as well.
    pub fn recycle(&mut self, report: RunReport) {
        let RunReport { centroids, assignment, energy_trace, m_trace, .. } = report;
        self.scratch.spare_centroids.push(centroids);
        self.recycle_buffers(assignment, energy_trace, m_trace);
    }

    /// Return a finished [`crate::registry::Prediction`]'s buffers so the
    /// next same-shape predict on this workspace is allocation-free.
    pub fn recycle_prediction(&mut self, labels: Assignment, distances: Vec<f64>) {
        self.scratch.put_assign(labels);
        self.scratch.put_trace_f64(distances);
    }

    /// Recycle the non-centroid output buffers of a finished run — for
    /// callers (like the coordinator) that keep the centroids but can
    /// return the assignment and trace buffers.
    pub fn recycle_buffers(
        &mut self,
        assignment: Assignment,
        energy_trace: Vec<f64>,
        m_trace: Vec<usize>,
    ) {
        if assignment.capacity() > 0 {
            self.scratch.assign_bufs.push(assignment);
        }
        if energy_trace.capacity() > 0 {
            self.scratch.spare_f64.push(energy_trace);
        }
        if m_trace.capacity() > 0 {
            self.scratch.spare_usize.push(m_trace);
        }
    }
}

impl Scratch {
    /// Start-of-run bookkeeping.
    pub(crate) fn begin_run(&mut self) {
        self.rebuilt = false;
        self.runs += 1;
    }

    /// Take an internal `k × d` matrix (the `c_au` / `c_next` rotation).
    pub(crate) fn take_mat(&mut self, k: usize, d: usize) -> DataMatrix {
        match self.mats.pop() {
            Some(m) => {
                let (m, grew) = reshape(m, k, d);
                self.rebuilt |= grew;
                m
            }
            None => {
                self.rebuilt = true;
                DataMatrix::zeros(k, d)
            }
        }
    }

    /// Return an internal matrix at the end of a run.
    pub(crate) fn put_mat(&mut self, m: DataMatrix) {
        self.mats.push(m);
    }

    /// Take the output centroid matrix (recycled report buffer when
    /// available — drawing a fresh one is *not* counted as a scratch
    /// rebuild, since un-recycled outputs necessarily allocate).
    pub(crate) fn take_output_mat(&mut self, k: usize, d: usize) -> DataMatrix {
        match self.spare_centroids.pop() {
            Some(m) => reshape(m, k, d).0,
            None => DataMatrix::zeros(k, d),
        }
    }

    /// Take a cleared assignment buffer.
    pub(crate) fn take_assign(&mut self) -> Assignment {
        let mut a = self.assign_bufs.pop().unwrap_or_default();
        a.clear();
        a
    }

    /// Return an assignment buffer.
    pub(crate) fn put_assign(&mut self, a: Assignment) {
        if a.capacity() > 0 {
            self.assign_bufs.push(a);
        }
    }

    /// Take the Anderson residual buffer, sized to `dim`.
    pub(crate) fn take_f_t(&mut self, dim: usize) -> Vec<f64> {
        let mut f = std::mem::take(&mut self.f_t);
        if f.capacity() < dim {
            self.rebuilt = true;
        }
        f.clear();
        f.resize(dim, 0.0);
        f
    }

    /// Return the residual buffer.
    pub(crate) fn put_f_t(&mut self, f: Vec<f64>) {
        self.f_t = f;
    }

    /// Take the accelerator for `(m_max, dim)`, reusing (and resetting) the
    /// cached one when the key matches.
    pub(crate) fn take_accelerator(&mut self, m_max: usize, dim: usize) -> AndersonAccelerator {
        let key = (m_max, dim);
        match self.acc.take() {
            Some(mut acc) if self.acc_key == key => {
                acc.reset();
                acc
            }
            _ => {
                self.rebuilt = true;
                self.acc_key = key;
                AndersonAccelerator::new(m_max, dim)
            }
        }
    }

    /// Return the accelerator.
    pub(crate) fn put_accelerator(&mut self, acc: AndersonAccelerator) {
        self.acc = Some(acc);
    }

    /// Take the update-reduce lane accumulators (persisted across runs).
    pub(crate) fn take_update(&mut self) -> lloyd::UpdateScratch {
        std::mem::take(&mut self.update)
    }

    /// Return the update-reduce lane accumulators.
    pub(crate) fn put_update(&mut self, update: lloyd::UpdateScratch) {
        self.update = update;
    }

    /// Take the inference kernel for `precision` (a cached one at another
    /// precision is discarded — registries mixing precisions per model pay
    /// one rebuild per switch, never a wrong-precision sweep).
    pub(crate) fn take_predict_kernel(&mut self, precision: Precision) -> DistanceKernel {
        match self.predict_kernel.take() {
            Some((p, kernel)) if p == precision => kernel,
            _ => DistanceKernel::with_precision(precision),
        }
    }

    /// Return the inference kernel (with the precision it serves).
    pub(crate) fn put_predict_kernel(&mut self, precision: Precision, kernel: DistanceKernel) {
        self.predict_kernel = Some((precision, kernel));
    }

    /// Take a cleared `f64` trace buffer.
    pub(crate) fn take_trace_f64(&mut self) -> Vec<f64> {
        let mut t = self.spare_f64.pop().unwrap_or_default();
        t.clear();
        t
    }

    /// Return an `f64` buffer to the spare pool (e.g. the mini-batch
    /// solver's per-centroid learning-rate counters).
    pub(crate) fn put_trace_f64(&mut self, t: Vec<f64>) {
        if t.capacity() > 0 {
            self.spare_f64.push(t);
        }
    }

    /// Take a cleared `usize` trace buffer.
    pub(crate) fn take_trace_usize(&mut self) -> Vec<usize> {
        let mut t = self.spare_usize.pop().unwrap_or_default();
        t.clear();
        t
    }

    /// Return a `usize` buffer to the spare pool (e.g. the mini-batch
    /// solver's replacement-sampling index scratch).
    pub(crate) fn put_trace_usize(&mut self, t: Vec<usize>) {
        if t.capacity() > 0 {
            self.spare_usize.push(t);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_cpu_engines_and_reject_pjrt_without_artifacts() {
        for engine in [
            EngineKind::Naive,
            EngineKind::Hamerly,
            EngineKind::Elkan,
            EngineKind::Yinyang,
        ] {
            let spec = WorkspaceSpec {
                engine,
                precision: Precision::F64,
                threads: 1,
                artifact_dir: None,
            };
            let ws = Workspace::open(&spec).expect("CPU engines are infallible");
            assert_eq!(ws.engine_name(), engine.name());
            assert!(ws.matches(&spec));
        }
        let spec = WorkspaceSpec {
            engine: EngineKind::Pjrt,
            precision: Precision::F64,
            threads: 1,
            artifact_dir: Some(PathBuf::from("/definitely/not/a/real/artifact/dir")),
        };
        match Workspace::open(&spec) {
            Err(ClusterError::Engine { engine, .. }) => assert_eq!(engine, "pjrt"),
            other => panic!("expected a typed engine error, got {:?}", other.is_ok()),
        }
    }

    #[test]
    fn scratch_reuse_is_stable_on_same_shape() {
        let mut s = Scratch::default();
        s.begin_run();
        let m1 = s.take_mat(4, 3);
        let m2 = s.take_mat(4, 3);
        let f = s.take_f_t(12);
        let acc = s.take_accelerator(5, 12);
        assert!(s.rebuilt, "first run must build scratch");
        s.put_mat(m1);
        s.put_mat(m2);
        s.put_f_t(f);
        s.put_accelerator(acc);

        s.begin_run();
        let m1 = s.take_mat(4, 3);
        let m2 = s.take_mat(4, 3);
        let f = s.take_f_t(12);
        let acc = s.take_accelerator(5, 12);
        assert!(!s.rebuilt, "same-shape second run must reuse scratch");
        s.put_mat(m1);
        s.put_mat(m2);
        s.put_f_t(f);
        s.put_accelerator(acc);

        s.begin_run();
        let m1 = s.take_mat(8, 3); // shape change: rebuild is expected
        s.put_mat(m1);
        assert!(s.rebuilt);
    }

    #[test]
    fn reshape_reuses_capacity() {
        let m = DataMatrix::zeros(6, 4);
        let (m2, grew) = reshape(m, 4, 6); // same 24 elements
        assert!(!grew);
        assert_eq!((m2.n(), m2.d()), (4, 6));
        let (m3, grew) = reshape(m2, 10, 10);
        assert!(grew);
        assert_eq!((m3.n(), m3.d()), (10, 10));
    }
}
